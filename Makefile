# Top-level drivers.  `make artifacts` runs the python AOT path once
# (data -> train -> quant -> HLO -> golden); everything rust-side loads
# the result.  `make tier1` is the CI gate (scripts/tier1.sh).

.PHONY: artifacts tier1 test-python

artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts

tier1:
	bash scripts/tier1.sh

test-python:
	cd python && python3 -m pytest tests -q
