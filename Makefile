# Top-level drivers.  `make artifacts` runs the python AOT path once
# (data -> train -> quant -> HLO -> golden); everything rust-side loads
# the result.  `make tier1` is the CI gate (scripts/tier1.sh; includes
# plan-check and — when jax/pytest are present — the python suite).
# `make tier1-bench` additionally runs the paged-KV benches against the
# committed baseline (scripts/bench_guard.py).  `make test-python` runs
# the python suite on its own.  .github/workflows/ci.yml runs these same
# targets so local and CI gates cannot drift.

.PHONY: artifacts tier1 tier1-bench test-python plan-check bench-guard \
	staticcheck linkcheck

artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts

tier1:
	bash scripts/tier1.sh

tier1-bench:
	bash scripts/tier1.sh --bench

test-python:
	cd python && python3 -m pytest tests -q

# Validate the cross-language QuantSpec golden fixture (python side;
# the rust side is rust/tests/plan_roundtrip.rs under `cargo test`).
plan-check:
	python3 python/compile/quant/spec.py check \
	    rust/tests/fixtures/quantspec_golden.json

# Cross-language consistency analyzer (DESIGN.md §14): seven passes
# over the mirrored surfaces (spec.py<->spec.rs, manifest keys,
# metrics, CLI flags, backend gating, test registry, doc parity).
# Pure stdlib, no cargo — also the first tier1.sh step.
staticcheck:
	python3 scripts/staticcheck

# Documentation link gate: relative paths and heading anchors in every
# checked-in markdown file must resolve.  Stdlib only.
linkcheck:
	python3 scripts/check_md_links.py

# Re-check the last bench run against the committed baseline without
# re-running the bench.
bench-guard:
	python3 scripts/bench_guard.py --bench BENCH_kvpaged.json \
	    --baseline BENCH_baseline.json
