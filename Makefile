# Top-level drivers.  `make artifacts` runs the python AOT path once
# (data -> train -> quant -> HLO -> golden); everything rust-side loads
# the result.  `make tier1` is the CI gate (scripts/tier1.sh; includes
# plan-check).  `make test-python` runs the python suite, including the
# QuantSpec schema tests (tests/test_spec.py).

.PHONY: artifacts tier1 test-python plan-check

artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts

tier1:
	bash scripts/tier1.sh

test-python:
	cd python && python3 -m pytest tests -q

# Validate the cross-language QuantSpec golden fixture (python side;
# the rust side is rust/tests/plan_roundtrip.rs under `cargo test`).
plan-check:
	python3 python/compile/quant/spec.py check \
	    rust/tests/fixtures/quantspec_golden.json
