//! Circuit-area model walkthrough: per-method PE totals (Table 3 column)
//! and component breakdowns (Tables 7/8/9), plus the analytic L1 TPU
//! estimates from DESIGN.md §8.
//!
//! ```bash
//! cargo run --release --example area_report
//! ```

use lqer::hwcost;
use lqer::util::bench::Table;

fn main() {
    let mut t = Table::new(
        "circuit area at matched 16-MAC/cycle throughput",
        &["method", "LUTs", "vs FP16"],
    );
    for method in [
        "fp16", "gptq-w4", "awq-w4", "llmint4", "smoothquant-w8a8",
        "clipq-w6a6", "mxint-w4a8", "l2qer-int-w4a8", "l2qer-w4a6",
        "l2qer-w4a8", "l2qer-w2a8",
    ] {
        let pe = hwcost::area_for_method(method).unwrap();
        t.row(vec![
            method.to_string(),
            format!("{:.0}", pe.total),
            format!("{:.2}x", pe.relative()),
        ]);
    }
    print!("{}", t.render());

    for method in ["llmint4", "awq-w4", "l2qer-w4a8"] {
        let pe = hwcost::area_for_method(method).unwrap();
        let mut bt = Table::new(&format!("breakdown: {method}"),
                                &["component", "LUTs", "share"]);
        for (name, luts) in &pe.components {
            bt.row(vec![
                name.clone(),
                format!("{luts:.0}"),
                format!("{:.1}%", luts / pe.total * 100.0),
            ]);
        }
        print!("{}", bt.render());
    }

    // L1 kernel VMEM/MXU analytics (DESIGN.md §8): per-tile footprint for
    // the fused LQER kernel at representative shapes.
    let mut vt = Table::new(
        "L1 Pallas kernel VMEM footprint per grid step (f32)",
        &["shape (K,bm,bn,r)", "KiB", "fits 16MiB VMEM"],
    );
    for (k, bm, bn, r) in
        [(768usize, 128usize, 128usize, 16usize),
         (768, 128, 128, 256),
         (12288, 128, 128, 32)]
    {
        let floats = bm * k + k * bn + k * r + r * bn + bm * bn;
        let kib = floats as f64 * 4.0 / 1024.0;
        vt.row(vec![
            format!("({k},{bm},{bn},{r})"),
            format!("{kib:.0}"),
            (kib < 16.0 * 1024.0).to_string(),
        ]);
    }
    print!("{}", vt.render());
}
