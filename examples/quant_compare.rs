//! Compare PTQ methods on one model: perplexity, memory, circuit area —
//! a Table-2/3-style report through the public API.
//!
//! ```bash
//! cargo run --release --example quant_compare [-- <model>]
//! ```

use lqer::config::Manifest;
use lqer::eval;
use lqer::hwcost;
use lqer::runtime::{ModelRunner, Runtime};
use lqer::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let model = std::env::args().nth(1).unwrap_or("opt-mini".into());
    let manifest = Manifest::load(&lqer::default_artifacts_dir())?;
    let rt = Runtime::cpu()?;
    let stream =
        lqer::util::read_u16_file(&manifest.data_dir().join("test.u16"))?;

    let methods = [
        "fp16", "mxint-w2a8", "lqer-w2a8", "l2qer-w2a8", "mxint-w4a8",
        "l2qer-w4a8", "gptq-w4", "awq-w4",
    ];
    let mut t = Table::new(
        &format!("quantization methods on {model}"),
        &["method", "ppl", "dPPL", "avg w bits", "circuit area"],
    );
    let mut fp16 = 0.0;
    for method in methods {
        let runner = ModelRunner::new(&manifest, &model, method)?;
        let r = eval::ppl::perplexity(&rt, &manifest, &runner, &stream, 8)?;
        if method == "fp16" {
            fp16 = r.ppl;
        }
        let bits = manifest
            .run_meta(manifest.run(&model, method)?)?
            .f64_at("avg_w_bits")
            .unwrap_or(f64::NAN);
        t.row(vec![
            method.to_string(),
            format!("{:.3}", r.ppl),
            format!("{:+.3}", r.ppl - fp16),
            format!("{bits:.2}"),
            hwcost::area_for_method(method)
                .map(|pe| format!("{:.2}x", pe.relative()))
                .unwrap_or("-".into()),
        ]);
    }
    print!("{}", t.render());
    println!("\nppl over 8 windows of the held-out stream; dPPL vs FP16.");
    Ok(())
}
