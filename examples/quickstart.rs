//! Quickstart: load a quantized model through the public API and serve a
//! few requests.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! This walks the whole stack: manifest -> LQTW weights -> HLO-text
//! compile on the PJRT CPU client -> serving engine (continuous batcher +
//! KV cache) -> tokenizer round-trip.

use lqer::config::Manifest;
use lqer::coordinator::{EngineConfig, EngineHandle, Request, Sampling};
use lqer::tokenizer::Tokenizer;

fn main() -> anyhow::Result<()> {
    let artifacts = lqer::default_artifacts_dir();
    let manifest = Manifest::load(&artifacts)?;
    let tok = Tokenizer::from_file(
        &manifest.data_dir().join("vocab.json"))?;

    println!("== LQER quickstart ==");
    println!("model:  {} (L2QER W4A8, k=16)", manifest.serve.model);

    // One engine per (model, method); it owns the PJRT runtime.
    let engine = EngineHandle::spawn(
        artifacts.clone(),
        EngineConfig {
            model: manifest.serve.model.clone(),
            method: "l2qer-w4a8".into(),
            decode_batch: 4,
            prefill_buckets: manifest
                .serve
                .prefill_shapes
                .iter()
                .map(|(_, t)| *t)
                .collect(),
            tokens_per_step: 0, // engine default: batch + largest bucket
            // device-resident KV cache (set true for the legacy
            // host round-trip oracle)
            host_cache: false,
            // flat per-lane cache; see `lqer bench kv` / DESIGN.md §10
            // for the paged allocator
            paged: None,
            // speculative decode is opt-in; see `lqer generate
            // --speculate` / DESIGN.md §13
            spec: None,
            admission: Default::default(),
            trace_capacity: 0,
        },
    )?;

    // Grab a few grammatical prompts from the corpus prompt set.
    let prompts = lqer::coordinator::loadtest::load_prompts(&manifest)?;
    for (i, prompt) in prompts.iter().take(3).enumerate() {
        let resp = engine.generate(Request {
            id: i as u64 + 1,
            prompt: prompt.clone(),
            max_new_tokens: 16,
            sampling: if i == 0 {
                Sampling::Greedy
            } else {
                Sampling::TopK { k: 8, temperature: 0.8, seed: 7 }
            },
            priority: Default::default(),
        })?;
        println!("\nprompt {} : {}", i + 1,
                 tok.decode_clean(&prompt[1..].to_vec()));
        println!("output   : {}", tok.decode_clean(&resp.tokens));
        println!("           ({} tokens, ttft {:.0} ms, total {:.0} ms, \
                  {:?})",
                 resp.tokens.len(), resp.ttft_ms, resp.total_ms,
                 resp.finish);
    }

    let metrics = engine.metrics()?;
    println!("\nengine: {}", metrics.report());
    engine.shutdown();
    Ok(())
}
