//! End-to-end serving driver (the prompt-mandated E2E validation): load
//! the small trained model quantized with L²QER-W4A8, serve a batched
//! request workload through the continuous-batching engine, and report
//! latency/throughput — then repeat with the FP16 baseline for
//! comparison.  Results are recorded in EXPERIMENTS.md.
//!
//! ```bash
//! cargo run --release --example serve_bench [-- <requests> <max_new>]
//! ```

use lqer::config::Manifest;
use lqer::coordinator::{loadtest, EngineConfig};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let requests: usize =
        args.get(1).and_then(|v| v.parse().ok()).unwrap_or(24);
    let max_new: usize =
        args.get(2).and_then(|v| v.parse().ok()).unwrap_or(24);

    let manifest = Manifest::load(&lqer::default_artifacts_dir())?;
    println!(
        "== serve_bench: {} requests x {} new tokens on {} ==",
        requests, max_new, manifest.serve.model
    );

    for method in manifest.serve.methods.clone() {
        let batch = *manifest.serve.decode_batches.iter().max().unwrap();
        let cfg = EngineConfig {
            model: manifest.serve.model.clone(),
            method: method.clone(),
            decode_batch: batch,
            prefill_buckets: manifest
                .serve
                .prefill_shapes
                .iter()
                .map(|(_, t)| *t)
                .collect(),
            tokens_per_step: 0, // engine default: batch + largest bucket
            host_cache: false,
            paged: None,
            spec: None,
            admission: Default::default(),
            trace_capacity: 0,
        };
        let t0 = std::time::Instant::now();
        let stats = loadtest::run_loadtest(&manifest, &cfg, requests,
                                           max_new)?;
        let wall = t0.elapsed().as_secs_f64();
        println!("\n[{method}] wall {:.1}s  ({:.1} req/s, {:.1} gen tok/s \
                  end-to-end)", wall, requests as f64 / wall,
                 stats.tokens_generated as f64 / wall);
        println!("  {}", stats.report());
        println!(
            "  runtime split: exec {:.0}ms upload {:.0}ms download {:.0}ms",
            stats.exec.exec_ns as f64 / 1e6,
            stats.exec.upload_ns as f64 / 1e6,
            stats.exec.download_ns as f64 / 1e6
        );
    }
    Ok(())
}
