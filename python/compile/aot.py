"""AOT build driver: python runs ONCE here, never on the request path.

    python -m compile.aot --out-dir ../artifacts [--models tiny,micro,mini]

Stages (all incremental -- existing artifacts are reused):

  data    TinyPajama corpus + task suite            -> artifacts/data/
  train   the synthetic model family                -> artifacts/models/
  quant   every (model x method) PTQ run            -> artifacts/runs/
  hlo     lowered HLO *text* graphs                 -> artifacts/hlo/
  golden  cross-language test vectors               -> artifacts/golden/

HLO text (not serialized protos) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

weights.bin ("LQTW" format): magic LQTW0001 | u32 manifest_len | JSON
manifest | pad to 64 | raw f32 little-endian tensors.  The manifest lists
tensors in *jax tree-flatten order*, which is exactly the HLO parameter
order of every lowered graph.
"""

from __future__ import annotations

import argparse
import json
import os
import struct
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import calibration, data as D, model as M, pipeline, train
from .quant import formats, lqer
from .quant import spec as qspec

# ----------------------------------------------------------------------------
# Experiment grid
# ----------------------------------------------------------------------------

DEFAULT_MODELS = ["opt-tiny", "opt-micro", "opt-mini"]
SERVE_MODEL = "opt-mini"
SERVE_METHODS = ["fp16", "l2qer-w4a8"]
FIG3_MODEL = "opt-micro"
FIG3_RANKS = [1, 2, 4, 8, 16, 32, 64, 128]
FIG1A_LAYER = "layers.2.fc1"     # of opt-mini
SCORE_B, SCORE_T = 4, 96
PREFILL_SHAPES = [(1, 16), (1, 96)]
DECODE_BATCHES = [1, 4, 8]
# Paged-KV geometry (DESIGN.md §10): token rows per block.  Must divide
# every prefill bucket and t_max; a decode batch b pairs with a pool of
# b * (t_max // PAGED_BLOCK_SIZE) + 1 blocks (block 0 is the sentinel
# that absorbs dead writes of free lanes), i.e. the same memory as the
# flat (b, t_max) cache plus one block.
PAGED_BLOCK_SIZE = 16
# Self-speculative decoding (DESIGN.md §13): default max draft length.
# The verify graph is lowered at its widest shape, S = SPEC_GAMMA + 1
# (gamma drafted tokens plus the carried last-sampled token).
SPEC_GAMMA = 4


def paged_num_blocks(batch: int, t_max: int) -> int:
    """Pool size (incl. sentinel) the paged graphs are lowered with."""
    assert t_max % PAGED_BLOCK_SIZE == 0, (t_max, PAGED_BLOCK_SIZE)
    return batch * (t_max // PAGED_BLOCK_SIZE) + 1

TRAIN_STEPS = {"opt-tiny": 400, "opt-micro": 500, "opt-mini": 500,
               "opt-small": 500}


# ----------------------------------------------------------------------------
# HLO lowering helpers (see /opt/xla-example/gen_hlo.py)
# ----------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_graph(fn, *specs) -> str:
    return to_hlo_text(jax.jit(fn).lower(*specs))


def _tok_spec(b: int, t: int):
    return jax.ShapeDtypeStruct((b, t), jnp.int32)


# ----------------------------------------------------------------------------
# LQTW weight files
# ----------------------------------------------------------------------------


def write_lqtw(path: str, params, extra_meta: dict) -> None:
    flat = M.flatten_with_names(params)
    manifest = {"tensors": [], "meta": extra_meta}
    offset = 0
    for name, arr in flat:
        nbytes = arr.size * 4
        manifest["tensors"].append({
            "name": name, "shape": list(arr.shape), "offset": offset,
            "nbytes": nbytes})
        offset += nbytes
    mjson = json.dumps(manifest).encode("utf-8")
    with open(path, "wb") as fh:
        fh.write(b"LQTW0001")
        fh.write(struct.pack("<I", len(mjson)))
        fh.write(mjson)
        pad = (-fh.tell()) % 64
        fh.write(b"\0" * pad)
        for _, arr in flat:
            fh.write(np.ascontiguousarray(arr, np.float32).tobytes())


# ----------------------------------------------------------------------------
# Stages
# ----------------------------------------------------------------------------


def stage_data(out_dir: str) -> D.Dataset:
    ddir = os.path.join(out_dir, "data")
    ds = D.build_dataset()
    if not os.path.exists(os.path.join(ddir, "meta.json")):
        print("[aot] generating TinyPajama corpus + tasks")
        D.export_dataset(ds, ddir)
    return ds


def stage_train(out_dir: str, ds: D.Dataset, models: list[str]) -> dict:
    trained = {}
    for name in models:
        cfg = M.make_config(name, vocab=ds.vocab.size)
        mdir = os.path.join(out_dir, "models", name)
        params = train.train_model(
            cfg, ds.train, ds.val, mdir, steps=TRAIN_STEPS.get(name, 500))
        trained[name] = (cfg, params)
    return trained


def _method_runs(models: list[str]) -> list[tuple[str, str, qspec.QuantSpec]]:
    """(model, run_name, plan) for the full experiment grid."""
    runs = []
    for name in models:
        for method, spec in pipeline.METHODS.items():
            if method == "mxint-w3a8" and name != FIG3_MODEL:
                continue  # fig-3 baseline only needed on the sweep model
            runs.append((name, method, spec))
    # Figure 3 rank sweep on the sweep model (W2A8, LQER vs L2QER --
    # difficulty-matched to the paper's W3-on-1.3B setting, see DESIGN.md).
    for k in FIG3_RANKS:
        runs.append((FIG3_MODEL, f"lqer-w2a8-k{k}",
                     pipeline.rank_sweep_spec(k, scaled=False)))
        runs.append((FIG3_MODEL, f"l2qer-w2a8-k{k}",
                     pipeline.rank_sweep_spec(k, scaled=True)))
    return runs


def _rank_pad_for(run_name: str, spec) -> int:
    k = qspec.QuantSpec.coerce(spec).max_rank()
    if k == 0:
        return 0
    import re
    if re.search(r"-k\d+$", run_name):  # fig-3 sweep shares one K graph
        return max(FIG3_RANKS)
    if k <= 16:
        return 16
    return k


def stage_quant(out_dir: str, ds: D.Dataset, trained: dict,
                models: list[str]) -> list[dict]:
    run_index = []
    stats_cache: dict[str, dict] = {}
    for name in models:
        cfg, params = trained[name]
        for model_name, run_name, spec in _method_runs(models):
            if model_name != name:
                continue
            rdir = os.path.join(out_dir, "runs", name, run_name)
            wpath = os.path.join(rdir, "weights.bin")
            mpath = os.path.join(rdir, "meta.json")
            rank_pad = _rank_pad_for(run_name, spec)
            gv = pipeline.graph_variant_for(spec, rank_pad)
            entry = {"model": name, "method": run_name,
                     "graph": gv.tag, "weights": wpath, "meta": mpath,
                     "plan": spec.to_json_dict()}
            run_index.append(entry)
            if os.path.exists(mpath):
                continue
            if name not in stats_cache and spec.needs_calibration():
                print(f"[aot] calibrating {name} (32 samples)")
                stats_cache[name] = calibration.collect_stats(
                    params, ds.calib, cfg)
            print(f"[aot] quantizing {name} / {run_name}")
            spectra_layer = (FIG1A_LAYER
                             if name == "opt-mini"
                             and run_name == "l2qer-w4a8" else None)
            qparams, meta = pipeline.quantize_model(
                params, cfg, spec, stats_cache.get(name),
                rank_pad=rank_pad, spectra_layer=spectra_layer)
            os.makedirs(rdir, exist_ok=True)
            meta.update({"model": name, "method": run_name,
                         "model_cfg": dataclasses_dict(cfg)})
            write_lqtw(wpath, qparams, {"model": name, "method": run_name,
                                        "graph": gv.tag,
                                        "plan": spec.to_json_dict()})
            with open(mpath, "w") as fh:
                json.dump(meta, fh, indent=1)
    return run_index


def dataclasses_dict(cfg: M.ModelConfig) -> dict:
    return {"name": cfg.name, "vocab": cfg.vocab, "d": cfg.d,
            "layers": cfg.layers, "heads": cfg.heads, "ffn": cfg.ffn,
            "t_max": cfg.t_max}


def stage_hlo(out_dir: str, trained: dict, models: list[str],
              run_index: list[dict]) -> list[dict]:
    """Lower every graph variant any run needs, plus the serving graphs."""
    graph_index = []
    needed: dict[tuple, M.GraphVariant] = {}
    for entry in run_index:
        name = entry["model"]
        tag = entry["graph"]
        act = tag.split("_k")[0].replace("act-", "")
        rank = int(tag.split("_k")[1])
        needed[(name, tag, "score", SCORE_B, SCORE_T)] = M.GraphVariant(
            act=act, rank=rank)
    for method in SERVE_METHODS:
        for e in run_index:
            if e["model"] == SERVE_MODEL and e["method"] == method:
                tag = e["graph"]
                act = tag.split("_k")[0].replace("act-", "")
                rank = int(tag.split("_k")[1])
                gv = M.GraphVariant(act=act, rank=rank)
                for (b, t) in PREFILL_SHAPES:
                    needed[(SERVE_MODEL, tag, "prefill", b, t)] = gv
                serve_t_max = trained[SERVE_MODEL][0].t_max
                for b in DECODE_BATCHES:
                    # legacy host-cache step + device-resident step
                    needed[(SERVE_MODEL, tag, "decode", b, 0)] = gv
                    needed[(SERVE_MODEL, tag, "decode_dev", b, 0)] = gv
                    # paged device-resident step (block-table operand)
                    needed[(SERVE_MODEL, tag, "decode_paged", b, 0)] = gv
                    # Prefill-slot scatter: parameter-free, so one graph
                    # per (batch, bucket) under the fixed "cache" tag
                    # serves every method (rust looks it up by that tag).
                    # The paged variant is keyed by its *pool size* NB —
                    # that is what the rust runner knows at lookup time.
                    nb = paged_num_blocks(b, serve_t_max)
                    for (_, t) in PREFILL_SHAPES:
                        needed[(SERVE_MODEL, "cache", "kvwrite", b, t)] = gv
                        needed[(SERVE_MODEL, "cache", "kvwrite_paged",
                                nb, t)] = gv
                        # Fused chunked-prefill step (DESIGN.md §12):
                        # prefill + per-chunk block scatter in one
                        # graph, keyed by pool size like kvwrite_paged.
                        needed[(SERVE_MODEL, tag, "prefill_chunk",
                                nb, t)] = gv
                # Self-speculative decoding (DESIGN.md §13): the draft
                # graph is the same quantized backbone with the low-rank
                # correction clamped off (rank-0 variant, the manifest
                # plan's draft_of); the verify graph replays the drafted
                # tokens through the corrected model in one pass.  Only
                # lowered for methods that carry a low-rank term —
                # drafting with the full model would verify itself.
                if rank > 0:
                    draft_gv = M.GraphVariant(act=act, rank=0)
                    # Both passes are lowered per decode bucket: the
                    # engine's batched round issues ONE draft launch per
                    # speculation round and ONE verify launch per tick
                    # across all lanes (DESIGN.md §13), so the graphs
                    # must exist at every serving batch, not just b=1.
                    for b in DECODE_BATCHES:
                        needed[(SERVE_MODEL, draft_gv.tag,
                                "decode_draft", b, 0)] = draft_gv
                        needed[(SERVE_MODEL, tag, "verify_batch",
                                b, SPEC_GAMMA + 1)] = gv

    for (name, tag, entry_kind, b, t), gv in sorted(needed.items()):
        cfg, params = trained[name]
        hdir = os.path.join(out_dir, "hlo", name)
        os.makedirs(hdir, exist_ok=True)
        fname = (f"{tag}_{entry_kind}_b{b}" +
                 (f"_t{t}" if entry_kind in ("score", "prefill", "kvwrite",
                                             "kvwrite_paged",
                                             "prefill_chunk",
                                             "verify_batch")
                  else "") + ".hlo.txt")
        path = os.path.join(hdir, fname)
        graph_index.append({"model": name, "graph": tag,
                            "entry": entry_kind, "b": b, "t": t,
                            "path": path})
        if os.path.exists(path):
            continue
        t0 = time.time()
        cache = jax.ShapeDtypeStruct(
            (cfg.layers, b, cfg.t_max, cfg.d), jnp.float32)
        if entry_kind == "kvwrite":
            # Pure cache scatter: no model parameters.
            pre = jax.ShapeDtypeStruct(
                (cfg.layers, 1, t, cfg.d), jnp.float32)
            slot = jax.ShapeDtypeStruct((), jnp.int32)
            text = lower_graph(M.kv_write_prefill, cache, cache, pre, pre,
                               slot)
        elif entry_kind == "kvwrite_paged":
            # Pure block scatter; `b` IS the pool size here (see the
            # `needed` construction above).
            pcache = jax.ShapeDtypeStruct(
                (cfg.layers, b, PAGED_BLOCK_SIZE, cfg.d), jnp.float32)
            pre = jax.ShapeDtypeStruct(
                (cfg.layers, 1, t, cfg.d), jnp.float32)
            ids = jax.ShapeDtypeStruct((t // PAGED_BLOCK_SIZE,),
                                       jnp.int32)
            text = lower_graph(M.kv_write_prefill_paged, pcache, pcache,
                               pre, pre, ids)
        elif entry_kind == "prefill_chunk":
            # Fused prefill + chunk scatter; `b` IS the pool size here
            # (see the `needed` construction above).
            vparams = M.attach_variant_params(
                jax.tree_util.tree_map(np.asarray, params), cfg, gv)
            pspecs = M.param_specs(vparams)
            pcache = jax.ShapeDtypeStruct(
                (cfg.layers, b, PAGED_BLOCK_SIZE, cfg.d), jnp.float32)
            ids = jax.ShapeDtypeStruct((t // PAGED_BLOCK_SIZE,),
                                       jnp.int32)
            fn = lambda p, tok_, kc, vc, bi: M.prefill_chunk(
                p, tok_, kc, vc, bi, cfg, gv)
            text = lower_graph(fn, pspecs, _tok_spec(1, t), pcache,
                               pcache, ids)
        elif entry_kind == "decode_paged":
            vparams = M.attach_variant_params(
                jax.tree_util.tree_map(np.asarray, params), cfg, gv)
            pspecs = M.param_specs(vparams)
            nb = paged_num_blocks(b, cfg.t_max)
            pcache = jax.ShapeDtypeStruct(
                (cfg.layers, nb, PAGED_BLOCK_SIZE, cfg.d), jnp.float32)
            tok = jax.ShapeDtypeStruct((b,), jnp.int32)
            pos = jax.ShapeDtypeStruct((b,), jnp.int32)
            tbl = jax.ShapeDtypeStruct(
                (b, cfg.t_max // PAGED_BLOCK_SIZE), jnp.int32)
            fn = lambda p, tok_, kc, vc, pos_, bt: M.decode_paged(
                p, tok_, kc, vc, pos_, bt, cfg, gv)
            text = lower_graph(fn, pspecs, tok, pcache, pcache, pos, tbl)
        else:
            vparams = M.attach_variant_params(
                jax.tree_util.tree_map(np.asarray, params), cfg, gv)
            pspecs = M.param_specs(vparams)
            if entry_kind == "score":
                fn = lambda p, toks: (M.score(p, toks, cfg, gv),)
                text = lower_graph(fn, pspecs, _tok_spec(b, t))
            elif entry_kind == "prefill":
                fn = lambda p, toks: M.prefill(p, toks, cfg, gv)
                text = lower_graph(fn, pspecs, _tok_spec(b, t))
            elif entry_kind == "verify_batch":
                # Speculation verify pass (DESIGN.md §13): `t` is the
                # token-window width S = gamma + 1.
                fn = lambda p, toks, kc, vc, pos: M.verify_batch(
                    p, toks, kc, vc, pos, cfg, gv)
                pos = jax.ShapeDtypeStruct((b,), jnp.int32)
                text = lower_graph(fn, pspecs, _tok_spec(b, t), cache,
                                   cache, pos)
            else:  # decode | decode_dev | decode_draft
                step = (M.decode
                        if entry_kind == "decode" else M.decode_resident)
                fn = lambda p, tok, kc, vc, pos: step(
                    p, tok, kc, vc, pos, cfg, gv)
                tok = jax.ShapeDtypeStruct((b,), jnp.int32)
                pos = jax.ShapeDtypeStruct((b,), jnp.int32)
                text = lower_graph(fn, pspecs, tok, cache, cache, pos)
        with open(path, "w") as fh:
            fh.write(text)
        print(f"[aot] lowered {name}/{fname} "
              f"({len(text) // 1024} KiB, {time.time() - t0:.1f}s)")
    return graph_index


def stage_golden(out_dir: str, trained: dict) -> None:
    """Cross-language vectors: rust quant/svd must match these exactly."""
    gdir = os.path.join(out_dir, "golden")
    if os.path.exists(os.path.join(gdir, "golden.json")):
        return
    os.makedirs(gdir, exist_ok=True)
    rng = np.random.default_rng(42)
    cases = []

    def dump(name, arr):
        p = os.path.join(gdir, name + ".f32")
        np.ascontiguousarray(arr, np.float32).tofile(p)
        return {"file": name + ".f32", "shape": list(arr.shape)}

    # MXINT weight + act orientations, several bit widths.
    for bits in (2, 3, 4, 8):
        w = rng.normal(0, 0.4, size=(64, 48)).astype(np.float32)
        wq = np.asarray(formats.mxint_quant_weight(w, bits), np.float32)
        cases.append({"kind": "mxint_weight", "bits": bits,
                      "exp_bits": 4, "block": 16,
                      "input": dump(f"mxw{bits}_in", w),
                      "output": dump(f"mxw{bits}_out", wq)})
        x = (rng.normal(0, 1.5, size=(8, 64)) ** 3).astype(np.float32)
        xq = np.asarray(formats.mxint_quant_act(x, bits, 8), np.float32)
        cases.append({"kind": "mxint_act", "bits": bits,
                      "exp_bits": 8, "block": 16,
                      "input": dump(f"mxa{bits}_in", x),
                      "output": dump(f"mxa{bits}_out", xq)})
    # INT group quant.
    for bits, group in ((4, 128), (8, 128), (2, 128)):
        w = rng.normal(0, 0.3, size=(256, 32)).astype(np.float32)
        wq = np.asarray(formats.int_quant_group(w, bits, group, axis=0),
                        np.float32)
        cases.append({"kind": "int_group", "bits": bits, "group": group,
                      "input": dump(f"ig{bits}_in", w),
                      "output": dump(f"ig{bits}_out", wq)})
    # Per-token int8.
    x = rng.normal(0, 2.0, size=(16, 96)).astype(np.float32)
    xq = np.asarray(formats.int_quant_per_token(x, 8), np.float32)
    cases.append({"kind": "int_per_token", "bits": 8,
                  "input": dump("pt8_in", x), "output": dump("pt8_out", xq)})
    # SVD case: quantization error of a real trained layer (fig 1a data).
    if "opt-mini" in trained:
        cfg, params = trained["opt-mini"]
        li, lname = 2, "fc1"
        w = np.asarray(params["layers"][li][lname]["w"], np.float32)
        wq = np.asarray(formats.mxint_quant_weight(w, 3), np.float32)
        eq = w - wq
        sv = np.linalg.svd(eq.astype(np.float64), compute_uv=False)
        cases.append({"kind": "svd", "input": dump("svd_in", eq),
                      "singular_values": dump(
                          "svd_out", sv.astype(np.float32))})
    with open(os.path.join(gdir, "golden.json"), "w") as fh:
        json.dump({"cases": cases}, fh, indent=1)
    print(f"[aot] wrote {len(cases)} golden cases")


def stage_fig1a(out_dir: str, ds: D.Dataset, trained: dict) -> dict | None:
    """Export E_q and the Appendix-A scale vector for the Figure-1a layer;
    the rust analysis module computes both spectra with its own SVD."""
    if "opt-mini" not in trained:
        return None
    fdir = os.path.join(out_dir, "fig1a")
    jpath = os.path.join(fdir, "fig1a.json")
    if os.path.exists(jpath):
        with open(jpath) as fh:
            return json.load(fh)
    os.makedirs(fdir, exist_ok=True)
    cfg, params = trained["opt-mini"]
    stats = calibration.collect_stats(params, ds.calib, cfg,
                                      need_hessian=False)
    li = int(FIG1A_LAYER.split(".")[1])
    lname = FIG1A_LAYER.split(".")[2]
    w = np.asarray(params["layers"][li][lname]["w"], np.float32)
    qfn = pipeline.weight_quant_fn(qspec.Mxint(3))
    wq = qfn(w)
    eq = (w - wq).astype(np.float32)
    s_diag = lqer.calib_scale_matrix(stats[FIG1A_LAYER].a_bar)
    eq.tofile(os.path.join(fdir, "eq.f32"))
    s_diag.astype(np.float32).tofile(os.path.join(fdir, "s.f32"))
    # Python-side spectra for cross-checking the rust SVD.
    spectra = lqer.error_spectra(w, qfn, s_diag)
    info = {"layer": FIG1A_LAYER, "shape": list(eq.shape),
            "eq": "eq.f32", "s": "s.f32",
            "spectrum_lqer": spectra["lqer"].tolist(),
            "spectrum_l2qer": spectra["l2qer"].tolist()}
    with open(jpath, "w") as fh:
        json.dump(info, fh)
    print("[aot] exported fig1a error matrix + spectra")
    return info


# ----------------------------------------------------------------------------
# Main
# ----------------------------------------------------------------------------


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--models", default=",".join(DEFAULT_MODELS))
    ap.add_argument("--stage", default="all",
                    choices=["all", "data", "train", "quant", "hlo",
                             "golden"])
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out_dir)
    models = [m if m.startswith("opt-") else f"opt-{m}"
              for m in args.models.split(",")]
    os.makedirs(out_dir, exist_ok=True)

    t0 = time.time()
    ds = stage_data(out_dir)
    if args.stage == "data":
        return
    trained = stage_train(out_dir, ds, models)
    if args.stage == "train":
        return
    run_index = []
    graph_index = []
    if args.stage in ("all", "quant"):
        run_index = stage_quant(out_dir, ds, trained, models)
    if args.stage in ("all", "hlo"):
        graph_index = stage_hlo(out_dir, trained, models, run_index)
    if args.stage in ("all", "golden"):
        stage_golden(out_dir, trained)
    fig1a = stage_fig1a(out_dir, ds, trained) if args.stage == "all" else None

    if args.stage == "all":
        serve = {"model": SERVE_MODEL, "methods": SERVE_METHODS,
                 "prefill_shapes": PREFILL_SHAPES,
                 "decode_batches": DECODE_BATCHES}
        if SERVE_MODEL in trained:
            # Geometry the paged graphs were lowered with; rust derives
            # num_blocks = batch * blocks_per_lane + 1 from this.
            serve["paged"] = {
                "block_size": PAGED_BLOCK_SIZE,
                "blocks_per_lane":
                    trained[SERVE_MODEL][0].t_max // PAGED_BLOCK_SIZE,
            }
            # Fused chunked-prefill graphs (DESIGN.md §12): their
            # presence gates the device-paged chunk path in rust.
            serve["chunk"] = {
                "block_size": PAGED_BLOCK_SIZE,
                "buckets": [t for _, t in PREFILL_SHAPES],
            }
            # Self-speculative decoding (DESIGN.md §13): default draft
            # window for `--speculate` when the CLI passes --gamma 0,
            # plus the batched graph entry names — both passes are
            # lowered per decode bucket so the engine's batched round
            # can draft every lane in one launch and verify every
            # lane's window in another.
            serve["spec"] = {
                "gamma": SPEC_GAMMA,
                "draft_entry": "decode_draft",
                "verify_entry": "verify_batch",
            }
        manifest = {
            "created": time.strftime("%Y-%m-%d %H:%M:%S"),
            "models": {
                name: {**dataclasses_dict(trained[name][0]),
                       "n_params": trained[name][0].param_count()}
                for name in models},
            "runs": run_index,
            "graphs": graph_index,
            "score_shape": [SCORE_B, SCORE_T],
            "serve": serve,
            "fig3": {"model": FIG3_MODEL, "ranks": FIG3_RANKS},
            "fig1a": fig1a and {"layer": fig1a["layer"],
                                "shape": fig1a["shape"]},
            "data": {"dir": "data"},
        }
        with open(os.path.join(out_dir, "manifest.json"), "w") as fh:
            json.dump(manifest, fh, indent=1)
        print(f"[aot] done in {time.time() - t0:.0f}s; "
              f"manifest at {out_dir}/manifest.json")


if __name__ == "__main__":
    main()
