from . import awq, clipq, gptq, llm_int4, rtn, smoothquant  # noqa: F401
