"""AWQ (Lin et al., 2023): activation-aware per-channel weight scaling.

Salient weights -- those multiplied by large-magnitude activation channels
-- are protected by scaling them *up* before quantization and folding the
inverse scale into the activation side.  Since the inverse scale is folded
back into the weight after dequantization (s and 1/s cancel analytically),
the net effect is that the quantization grid is allocated per channel
proportionally to activation importance:

    s_ch   = a_max_ch^alpha / mean(a_max^alpha)     (alpha grid-searched)
    W_eff  = q(W * s) / s

alpha is chosen per layer to minimize ||X W - X W_eff||_F on the
calibration sample, exactly AWQ's data-driven grid search (no gradients).
"""

from __future__ import annotations

import numpy as np

from ..quant import formats


def quantize(w: np.ndarray, a_max: np.ndarray, x_sample: np.ndarray,
             bits: int = 4, group: int = 128,
             n_grid: int = 20) -> dict:
    """w: (m, n); a_max: (m,) channel abs-max; x_sample: (t, m) calib acts."""
    w = np.asarray(w, np.float32)
    a = np.asarray(a_max, np.float64)
    a = np.maximum(a, 1e-8)
    y_ref = x_sample.astype(np.float64) @ w.astype(np.float64)

    best = None
    for gi in range(n_grid + 1):
        alpha = gi / n_grid
        s = a ** alpha
        s = s / np.exp(np.mean(np.log(s)))  # geomean-normalize
        s = np.clip(s, 1e-4, 1e4).astype(np.float32)
        wq = np.asarray(
            formats.int_quant_group(w * s[:, None], bits, group, axis=0),
            np.float32)
        w_eff = wq / s[:, None]
        err = float(np.linalg.norm(
            x_sample.astype(np.float64) @ w_eff.astype(np.float64) - y_ref))
        if best is None or err < best[0]:
            best = (err, alpha, w_eff)
    _, alpha, w_eff = best
    return {"w": w_eff.astype(np.float32), "alpha": alpha}
