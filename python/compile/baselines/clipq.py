"""clipq: gradient-free learnable-weight-clipping baseline (the OmniQuant
stand-in, DESIGN.md section 2).

OmniQuant's main lever (LWC) learns a per-group clipping ratio by SGD on
WikiText2 for 20 epochs.  clipq grid-searches the same per-group clip
ratio directly against reconstruction error on the calibration sample --
the gradient-free core of the idea at PTQ cost parity with LQER.
"""

from __future__ import annotations

import numpy as np

from ..quant.formats import effective_group


def _clip_quant(w: np.ndarray, bits: int, group: int,
                ratio: float) -> np.ndarray:
    m, n = w.shape
    g = effective_group(m, group)
    qmax = 2.0 ** (bits - 1) - 1
    out = np.empty_like(w)
    for gi in range(m // g):
        blk = w[gi * g:(gi + 1) * g, :]
        amax = np.max(np.abs(blk), axis=0) * ratio
        s = np.where(amax > 0, amax / qmax, 1.0)
        s = s.astype(np.float16).astype(np.float32)
        out[gi * g:(gi + 1) * g, :] = (
            np.clip(np.round(blk / s), -qmax - 1, qmax) * s)
    return out


def quantize(w: np.ndarray, x_sample: np.ndarray, bits: int = 4,
             group: int = 128,
             ratios=(1.0, 0.95, 0.9, 0.85, 0.8, 0.75, 0.7)) -> dict:
    """Pick the clip ratio minimizing ||X W - X W_q|| on calib acts."""
    w = np.asarray(w, np.float32)
    y_ref = x_sample.astype(np.float64) @ w.astype(np.float64)
    best = None
    for r in ratios:
        wq = _clip_quant(w, bits, group, r)
        err = float(np.linalg.norm(
            x_sample.astype(np.float64) @ wq.astype(np.float64) - y_ref))
        if best is None or err < best[0]:
            best = (err, r, wq)
    _, ratio, wq = best
    return {"w": wq.astype(np.float32), "ratio": ratio}
