"""GPTQ (Frantar et al., 2022): Hessian-guided error-compensating rounding.

Implements the standard GPTQ algorithm (the Cholesky formulation of OBQ
with lazy batch updates removed -- our layers are small enough to process
column-by-column):

  H = X^T X + lambda I          (from calibration, see calibration.py)
  Hinv = Cholesky^-1 upper factorization trick
  for each input feature i (in order):
      q_i   = quantize(row W[i, :])
      err_i = (W[i, :] - q_i) / Hinv[i, i]
      W[i+1:, :] -= Hinv[i+1:, i] x err_i     (compensate later rows)

Quantization of each element uses the same INT-g128 grid as the RTN/AWQ
baselines so Table 3's w-only comparison is apples-to-apples.

Note the transpose convention: our W is (in_features m, out_features n),
i.e. the paper's W^T; GPTQ iterates over *input* features, which are our
rows.
"""

from __future__ import annotations

import numpy as np

from ..quant.formats import effective_group


def _group_scales(w: np.ndarray, bits: int, group: int) -> np.ndarray:
    """Precompute per-(group, out) scales from the original weight, as
    GPTQ does (scales frozen before error compensation)."""
    m, n = w.shape
    g = effective_group(m, group)
    qmax = 2.0 ** (bits - 1) - 1
    scales = np.empty((m // g, n), np.float32)
    for gi in range(m // g):
        blk = w[gi * g:(gi + 1) * g, :]
        amax = np.max(np.abs(blk), axis=0)
        s = np.where(amax > 0, amax / qmax, 1.0)
        scales[gi] = s.astype(np.float16).astype(np.float32)
    return scales


def quantize(w: np.ndarray, h: np.ndarray, bits: int = 4,
             group: int = 128, damp: float = 0.01) -> dict:
    """GPTQ-quantize one (m, n) weight with Hessian proxy h (m, m)."""
    w = np.array(w, np.float64)
    m, n = w.shape
    g = effective_group(m, group)
    qmax = 2.0 ** (bits - 1) - 1
    scales = _group_scales(w.astype(np.float32), bits, group)

    hm = np.array(h, np.float64)
    # dampening: lambda = damp * mean(diag(H))
    dead = np.diag(hm) == 0
    hm[dead, dead] = 1.0
    w[dead, :] = 0.0
    lam = damp * np.mean(np.diag(hm))
    hm[np.diag_indices(m)] += lam
    # Upper Cholesky factor U of H^-1 (H^-1 = U^T U), as in the reference
    # implementation's torch.linalg.cholesky(Hinv, upper=True).
    hinv = np.linalg.inv(hm)
    hinv = (hinv + hinv.T) / 2.0
    u = np.linalg.cholesky(hinv).T  # upper triangular

    q_out = np.empty_like(w)
    for i in range(m):
        s = scales[i // g]                       # (n,)
        qi = np.clip(np.round(w[i, :] / s), -qmax - 1, qmax) * s
        q_out[i, :] = qi
        err = (w[i, :] - qi) / u[i, i]
        if i + 1 < m:
            w[i + 1:, :] -= np.outer(u[i, i + 1:], err)
    return {"w": q_out.astype(np.float32)}
