"""LLM.int8()/int4() (Dettmers et al., 2022): mixed-precision outlier
decomposition.

Activation channels whose magnitude exceeds a threshold tau are computed
in high precision (FP16), the rest in low-precision fixed point.  On the
weight side this splits W by *rows* (input features): outlier-feature rows
stay FP16, the rest are quantized.  On the activation side the same
channel mask selects which features are fake-quantized
(model._act_quant's ``actmask`` parameter).

This is the computation the paper contrasts LQER against: the thresholding
forces Scatter/Gather of irregular columns at runtime (priced in the
hwcost model, Table 7).

The paper uses tau = 6.0 on real LLM activations; our synthetic models
have a different activation scale, so tau is set per layer as a high
quantile of |x| matching LLM.int8()'s reported outlier fraction
(~0.1-1% of channels).
"""

from __future__ import annotations

import numpy as np

from ..quant import formats


def quantize(w: np.ndarray, a_max: np.ndarray, bits: int = 4,
             outlier_frac: float = 0.01) -> dict:
    """Returns effective weight + the activation outlier mask
    (1 = quantize, 0 = keep high precision)."""
    w = np.asarray(w, np.float32)
    m, _ = w.shape
    n_out = max(1, int(round(outlier_frac * m)))
    order = np.argsort(np.asarray(a_max))[::-1]
    outliers = order[:n_out]
    mask = np.ones(m, np.float32)
    mask[outliers] = 0.0
    # LLM.int8() quantizes vector-wise (per input-feature row, no groups).
    wq = np.asarray(formats.int_quant_group(w, bits, group=w.shape[1],
                                            axis=1), np.float32)
    w_eff = wq.copy()
    w_eff[outliers, :] = w[outliers, :]  # FP16 rows for outlier features
    return {"w": w_eff, "actmask": mask, "n_outliers": int(n_out)}
