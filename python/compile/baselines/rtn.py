"""Round-to-nearest baselines: plain MXINT / plain INT group quantization.

"Plain MXINT" is the Table-2 baseline ("the whole network is simply MXINT
quantized without any special treatments").
"""

from __future__ import annotations

import numpy as np

from ..quant import formats


def quantize_mxint(w: np.ndarray, bits: int, exp_bits: int = 4,
                   block: int = 16) -> dict:
    wq = np.asarray(formats.mxint_quant_weight(w, bits, exp_bits, block),
                    np.float32)
    return {"w": wq}


def quantize_int(w: np.ndarray, bits: int, group: int = 128) -> dict:
    wq = np.asarray(formats.int_quant_group(w, bits, group, axis=0),
                    np.float32)
    return {"w": wq}
