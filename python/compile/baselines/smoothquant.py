"""SmoothQuant (Xiao et al., 2023): migrate activation outliers into the
weights with a per-channel smoothing factor

    s_ch = max|X_ch|^alpha / max|W_ch|^(1-alpha)      (alpha = 0.5)

At inference X is divided by s (the ``smooth`` parameter in the lowered
graph) and W is multiplied by s before quantization, so the product is
unchanged but activation ranges shrink.  In the real method s is fused
into the *preceding* layer; our graphs apply it at the linear input, which
is compute-equivalent for PTQ fidelity (DESIGN.md section 2 notes the
substitution).
"""

from __future__ import annotations

import numpy as np

from ..quant import formats


def quantize(w: np.ndarray, a_max: np.ndarray, bits: int = 8,
             alpha: float = 0.5, group: int = 128) -> dict:
    w = np.asarray(w, np.float32)
    a = np.maximum(np.asarray(a_max, np.float64), 1e-8)
    w_ch = np.maximum(np.max(np.abs(w), axis=1), 1e-8)  # (m,)
    s = (a ** alpha) / (w_ch ** (1.0 - alpha))
    s = np.clip(s / np.exp(np.mean(np.log(np.maximum(s, 1e-12)))),
                1e-4, 1e4).astype(np.float32)
    wq = np.asarray(
        formats.int_quant_group(w * s[:, None], bits, group, axis=0),
        np.float32)
    return {"w": wq, "smooth": s}
