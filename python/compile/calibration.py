"""Activation calibration (paper Appendix A + baselines' statistics).

Runs the FP32 model over the 32-sample calibration set and collects, per
linear layer:

  * ``a_bar``  -- the Appendix-A channel magnitude profile: mean |x_ch|
    over tokens within each sample, then max over samples (Eq. 13); feeds
    the L2QER scale matrix S (Eq. 14),
  * ``a_max``  -- max |x_ch| over all tokens (AWQ / SmoothQuant / the
    LLM.int4() outlier threshold),
  * ``h``      -- the Gram matrix  X^T X  accumulated over all calibration
    tokens (GPTQ's Hessian proxy).

No gradients anywhere -- this is the "32 samples, profiling only"
calibration the paper contrasts with OmniQuant's 20-epoch training.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from . import model as M


@dataclasses.dataclass
class LinearStats:
    a_bar: np.ndarray    # (m,) Appendix-A profile
    a_max: np.ndarray    # (m,) channel abs-max
    h: np.ndarray        # (m, m) X^T X accumulated
    n_tokens: int
    x_sample: np.ndarray | None = None  # (t', m) raw acts for grid searches


def collect_stats(params, calib: np.ndarray, cfg: M.ModelConfig,
                  need_hessian: bool = True,
                  sample_tokens: int = 384) -> dict[str, LinearStats]:
    """calib: (n_samples, t) int token matrix -> per-linear stats keyed by
    'layers.<i>.<name>'."""
    gv = M.GraphVariant(act="none", rank=0)

    def fwd(p, toks):
        collect: dict = {}
        M.score(p, toks, cfg, gv, collect=collect)
        return collect

    fwd_j = jax.jit(fwd)
    stats: dict[str, LinearStats] = {}
    for i in range(calib.shape[0]):
        toks = calib[i:i + 1].astype(np.int32)
        acts = {k: np.asarray(v) for k, v in fwd_j(params, toks).items()}
        for name, x in acts.items():
            x2 = x.reshape(-1, x.shape[-1]).astype(np.float64)  # (t, m)
            sample_bar = np.mean(np.abs(x2), axis=0)
            amax = np.max(np.abs(x2), axis=0)
            if name not in stats:
                m = x2.shape[1]
                stats[name] = LinearStats(
                    a_bar=np.zeros(m), a_max=np.zeros(m),
                    h=np.zeros((m, m)), n_tokens=0)
            st = stats[name]
            st.a_bar = np.maximum(st.a_bar, sample_bar)   # max over samples
            st.a_max = np.maximum(st.a_max, amax)
            if need_hessian:
                st.h += x2.T @ x2
            if st.x_sample is None:
                st.x_sample = x2.astype(np.float32)
            elif st.x_sample.shape[0] < sample_tokens:
                st.x_sample = np.concatenate(
                    [st.x_sample, x2.astype(np.float32)])[:sample_tokens]
            st.n_tokens += x2.shape[0]
    return stats
