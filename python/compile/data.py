"""TinyPajama: a deterministic synthetic corpus + downstream task suite.

This module is the substitution for the paper's evaluation data (WikiText-2
perplexity, SlimPajama calibration, and the six lm-eval-harness downstream
tasks).  See DESIGN.md section 2 for the substitution rationale.

The corpus is a small templated language over a 512-word vocabulary with
real statistical structure for a language model to learn:

  * a Zipfian unigram distribution within each part-of-speech category,
  * deterministic noun->verb agreement classes (each noun belongs to an
    "animacy" class; each class licenses a subset of verbs),
  * document-level topics that skew the noun distribution,
  * question/answer lines ("does the cat sing ? no .") whose answers are
    derivable from the agreement classes, and
  * recall lines ("the cat chases the fish . the cat chases the fish .")
    that reward induction heads.

Six downstream tasks mirror the *formats* of the paper's suite:

  paper task        ours            format
  --------------    ------------    ------------------------------------
  ARC (easy)        arc_easy        4-way continuation, random distractors
  ARC (challenge)   arc_challenge   4-way continuation, same-category
                                    near-miss distractors
  LAMBADA           lambada         exact final-word prediction
  PIQA              piqa            2-way sentence plausibility
  BoolQ             boolq           yes/no agreement question
  OpenBookQA        openbook        4-way recall of a fact in context

All generation is seeded and reproducible; train / validation / test /
calibration splits are disjoint by construction (different seeds and
different topic mixtures are NOT used -- only different draws -- so the
eval split is in-distribution, like WikiText-2 test vs train).
"""

from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

# ----------------------------------------------------------------------------
# Vocabulary
# ----------------------------------------------------------------------------

PAD, BOS, EOS, UNK = 0, 1, 2, 3
SPECIALS = ["<pad>", "<bos>", "<eos>", "<unk>"]

_ONSETS = ["b", "d", "f", "g", "k", "l", "m", "n", "p", "r", "s", "t", "v", "z",
           "ch", "sh", "br", "cl", "dr", "gr", "pl", "st", "tr"]
_NUCLEI = ["a", "e", "i", "o", "u", "ai", "ea", "oo"]
_CODAS = ["", "n", "m", "r", "l", "s", "t", "k", "nd", "st"]

N_NOUNS = 160
N_VERBS = 96
N_ADJS = 64
N_ADVS = 32
N_NAMES = 48
FUNCTION_WORDS = [
    "the", "a", "and", "but", "then", "while", "near", "inside", "with",
    "yes", "no", "does", "did", "will", "?", ".", ":", ",", "who", "what",
    "which", "because", "so", "very", "quite", "not", "also", "again",
    "question", "answer", "fact", "story", "recall", "it", "they", "is",
]

N_AGREE_CLASSES = 4  # noun animacy classes; each licenses half the verbs
N_TOPICS = 8


def _make_words(rng: np.random.Generator, n: int, suffix: str) -> list[str]:
    """Generate ``n`` distinct pronounceable words, tagged by POS suffix."""
    words: set[str] = set()
    out: list[str] = []
    while len(out) < n:
        syll = lambda: (_ONSETS[rng.integers(len(_ONSETS))]
                        + _NUCLEI[rng.integers(len(_NUCLEI))]
                        + _CODAS[rng.integers(len(_CODAS))])
        w = syll() + (syll() if rng.random() < 0.6 else "")
        w = w + suffix
        if w not in words and w not in FUNCTION_WORDS:
            words.add(w)
            out.append(w)
    return out


@dataclasses.dataclass
class Vocab:
    words: list[str]                  # id -> string, specials first
    word_to_id: dict[str, int]
    nouns: np.ndarray                 # token ids
    verbs: np.ndarray
    adjs: np.ndarray
    advs: np.ndarray
    names: np.ndarray
    func: dict[str, int]              # function word -> id

    @property
    def size(self) -> int:
        return len(self.words)

    def encode(self, text: str) -> list[int]:
        return [self.word_to_id.get(w, UNK) for w in text.split()]

    def decode(self, ids) -> str:
        return " ".join(self.words[int(i)] for i in ids)


def build_vocab(seed: int = 7) -> Vocab:
    rng = np.random.default_rng(seed)
    nouns = _make_words(rng, N_NOUNS, "")
    verbs = _make_words(rng, N_VERBS, "s")
    adjs = _make_words(rng, N_ADJS, "y")
    advs = _make_words(rng, N_ADVS, "ly")
    names = _make_words(rng, N_NAMES, "o")
    words = list(SPECIALS) + FUNCTION_WORDS + nouns + verbs + adjs + advs + names
    assert len(words) == len(set(words)), "vocabulary collision"
    w2i = {w: i for i, w in enumerate(words)}
    return Vocab(
        words=words,
        word_to_id=w2i,
        nouns=np.array([w2i[w] for w in nouns]),
        verbs=np.array([w2i[w] for w in verbs]),
        adjs=np.array([w2i[w] for w in adjs]),
        advs=np.array([w2i[w] for w in advs]),
        names=np.array([w2i[w] for w in names]),
        func={w: w2i[w] for w in FUNCTION_WORDS},
    )


# ----------------------------------------------------------------------------
# Grammar
# ----------------------------------------------------------------------------


class Grammar:
    """Deterministic agreement structure + topic-conditional distributions."""

    def __init__(self, vocab: Vocab, seed: int = 11):
        self.v = vocab
        rng = np.random.default_rng(seed)
        # Noun -> agreement class (round-robin so classes are balanced).
        self.noun_class = np.arange(N_NOUNS) % N_AGREE_CLASSES
        # Class -> licensed verbs (each class licenses a distinct half).
        perm = rng.permutation(N_VERBS)
        halves = np.split(perm, 2)
        self.class_verbs = [
            np.sort(np.concatenate([halves[0], halves[1]])[: N_VERBS // 2]),
        ]
        # Build per-class verb subsets: overlapping windows over a permutation.
        self.class_verbs = []
        win = N_VERBS // 2
        for c in range(N_AGREE_CLASSES):
            start = (c * N_VERBS // N_AGREE_CLASSES) % N_VERBS
            idx = [(start + j) % N_VERBS for j in range(win)]
            self.class_verbs.append(np.sort(perm[idx]))
        # Topic -> noun weights (Zipf base reweighted by topic affinity).
        zipf = 1.0 / np.arange(1, N_NOUNS + 1) ** 0.8
        self.topic_noun_w = np.empty((N_TOPICS, N_NOUNS))
        for t in range(N_TOPICS):
            boost = np.where(np.arange(N_NOUNS) % N_TOPICS == t, 6.0, 1.0)
            w = zipf * boost
            self.topic_noun_w[t] = w / w.sum()
        self.verb_w = 1.0 / np.arange(1, N_VERBS + 1) ** 0.7
        self.adj_w = 1.0 / np.arange(1, N_ADJS + 1) ** 0.9
        self.adv_w = 1.0 / np.arange(1, N_ADVS + 1) ** 0.9

    # -- draws ---------------------------------------------------------------
    def draw_noun(self, rng, topic: int) -> int:
        i = rng.choice(N_NOUNS, p=self.topic_noun_w[topic])
        return int(self.v.nouns[i])

    def noun_index(self, noun_id: int) -> int:
        return int(np.where(self.v.nouns == noun_id)[0][0])

    def draw_verb_for(self, rng, noun_id: int) -> int:
        cls = self.noun_class[self.noun_index(noun_id)]
        allowed = self.class_verbs[cls]
        w = self.verb_w[allowed]
        i = rng.choice(len(allowed), p=w / w.sum())
        return int(self.v.verbs[allowed[i]])

    def draw_verb_not_for(self, rng, noun_id: int) -> int:
        cls = self.noun_class[self.noun_index(noun_id)]
        allowed = set(self.class_verbs[cls].tolist())
        bad = np.array([i for i in range(N_VERBS) if i not in allowed])
        w = self.verb_w[bad]
        i = rng.choice(len(bad), p=w / w.sum())
        return int(self.v.verbs[bad[i]])

    def verb_agrees(self, noun_id: int, verb_id: int) -> bool:
        cls = self.noun_class[self.noun_index(noun_id)]
        vi = int(np.where(self.v.verbs == verb_id)[0][0])
        return vi in set(self.class_verbs[cls].tolist())

    def draw_adj(self, rng) -> int:
        i = rng.choice(N_ADJS, p=self.adj_w / self.adj_w.sum())
        return int(self.v.adjs[i])

    def draw_adv(self, rng) -> int:
        i = rng.choice(N_ADVS, p=self.adv_w / self.adv_w.sum())
        return int(self.v.advs[i])


# ----------------------------------------------------------------------------
# Sentence / document generation
# ----------------------------------------------------------------------------


class CorpusGen:
    def __init__(self, vocab: Vocab, grammar: Grammar, seed: int):
        self.v = vocab
        self.g = grammar
        self.rng = np.random.default_rng(seed)
        self.f = vocab.func

    def sentence(self, topic: int) -> list[int]:
        """One declarative sentence as token ids (ends with '.')."""
        r = self.rng
        f = self.f
        n1 = self.g.draw_noun(r, topic)
        verb = self.g.draw_verb_for(r, n1)
        kind = r.random()
        toks = [f["the"]]
        if r.random() < 0.35:
            toks.append(self.g.draw_adj(r))
        toks += [n1, verb]
        if kind < 0.55:  # transitive
            toks.append(f["the"])
            if r.random() < 0.25:
                toks.append(self.g.draw_adj(r))
            toks.append(self.g.draw_noun(r, topic))
        elif kind < 0.8:  # adverbial
            toks.append(self.g.draw_adv(r))
        if r.random() < 0.2:
            toks += [f["and"], self.g.draw_verb_for(r, n1),
                     f["the"], self.g.draw_noun(r, topic)]
        toks.append(f["."])
        return toks

    def qa_line(self, topic: int) -> list[int]:
        """'question : does the NOUN VERB ? answer : yes/no .'"""
        r = self.rng
        f = self.f
        n = self.g.draw_noun(r, topic)
        if r.random() < 0.5:
            v = self.g.draw_verb_for(r, n)
            ans = f["yes"]
        else:
            v = self.g.draw_verb_not_for(r, n)
            ans = f["no"]
        return [f["question"], f[":"], f["does"], f["the"], n, v, f["?"],
                f["answer"], f[":"], ans, f["."]]

    def recall_line(self, topic: int) -> list[int]:
        """'fact : the N1 V the N2 . recall : the N1 V the N2 .'"""
        r = self.rng
        f = self.f
        n1 = self.g.draw_noun(r, topic)
        v = self.g.draw_verb_for(r, n1)
        n2 = self.g.draw_noun(r, topic)
        body = [f["the"], n1, v, f["the"], n2, f["."]]
        return [f["fact"], f[":"]] + body + [f["recall"], f[":"]] + body

    def document(self) -> list[int]:
        topic = int(self.rng.integers(N_TOPICS))
        toks = [BOS]
        n_lines = int(self.rng.integers(4, 10))
        for _ in range(n_lines):
            u = self.rng.random()
            if u < 0.62:
                toks += self.sentence(topic)
            elif u < 0.84:
                toks += self.qa_line(topic)
            else:
                toks += self.recall_line(topic)
        toks.append(EOS)
        return toks

    def stream(self, n_tokens: int) -> np.ndarray:
        out: list[int] = []
        while len(out) < n_tokens:
            out += self.document()
        return np.array(out[:n_tokens], dtype=np.uint16)


# ----------------------------------------------------------------------------
# Downstream tasks
# ----------------------------------------------------------------------------


def _mc_item(context: list[int], options: list[list[int]], answer: int,
             task: str) -> dict:
    return {"task": task, "context": context, "options": options,
            "answer": answer}


class TaskGen:
    """Generates the six downstream task sets (token-id level)."""

    def __init__(self, vocab: Vocab, grammar: Grammar, seed: int):
        self.v = vocab
        self.g = grammar
        self.rng = np.random.default_rng(seed)
        self.f = vocab.func
        self.cg = CorpusGen(vocab, grammar, seed + 1)

    def _random_words(self, n: int) -> list[int]:
        pools = np.concatenate([self.v.adjs, self.v.advs, self.v.names])
        return [int(pools[self.rng.integers(len(pools))]) for _ in range(n)]

    def arc_easy(self) -> dict:
        """Continuation choice; distractors are wrong-POS random words."""
        topic = int(self.rng.integers(N_TOPICS))
        n = self.g.draw_noun(self.rng, topic)
        v = self.g.draw_verb_for(self.rng, n)
        ctx = [BOS, self.f["the"], n]
        options = [[v]] + [[w] for w in self._random_words(3)]
        order = self.rng.permutation(4)
        options = [options[i] for i in order]
        return _mc_item(ctx, options, int(np.where(order == 0)[0][0]),
                        "arc_easy")

    def arc_challenge(self) -> dict:
        """Continuation choice; distractors are non-agreeing verbs."""
        topic = int(self.rng.integers(N_TOPICS))
        n = self.g.draw_noun(self.rng, topic)
        v = self.g.draw_verb_for(self.rng, n)
        ds = []
        while len(ds) < 3:
            d = self.g.draw_verb_not_for(self.rng, n)
            if d != v and d not in ds:
                ds.append(d)
        options = [[v]] + [[d] for d in ds]
        order = self.rng.permutation(4)
        options = [options[i] for i in order]
        ctx = [BOS, self.f["the"], n]
        return _mc_item(ctx, options, int(np.where(order == 0)[0][0]),
                        "arc_challenge")

    def lambada(self) -> dict:
        """Recall-style passage; predict the exact final word."""
        topic = int(self.rng.integers(N_TOPICS))
        line = self.cg.recall_line(topic)
        # final token before '.': strip trailing '.' then target is last tok
        assert line[-1] == self.f["."]
        ctx = [BOS] + line[:-2]
        target = line[-2]
        return {"task": "lambada", "context": ctx, "options": [[target]],
                "answer": 0}

    def piqa(self) -> dict:
        """Two sentences, one violating agreement; pick the plausible one."""
        topic = int(self.rng.integers(N_TOPICS))
        n = self.g.draw_noun(self.rng, topic)
        good = [self.f["the"], n, self.g.draw_verb_for(self.rng, n),
                self.f["."]]
        bad = [self.f["the"], n, self.g.draw_verb_not_for(self.rng, n),
               self.f["."]]
        options = [good, bad]
        order = self.rng.permutation(2)
        options = [options[i] for i in order]
        return _mc_item([BOS], options, int(np.where(order == 0)[0][0]),
                        "piqa")

    def boolq(self) -> dict:
        topic = int(self.rng.integers(N_TOPICS))
        n = self.g.draw_noun(self.rng, topic)
        agree = self.rng.random() < 0.5
        v = (self.g.draw_verb_for(self.rng, n) if agree
             else self.g.draw_verb_not_for(self.rng, n))
        f = self.f
        ctx = [BOS, f["question"], f[":"], f["does"], f["the"], n, v, f["?"],
               f["answer"], f[":"]]
        options = [[f["yes"]], [f["no"]]]
        return _mc_item(ctx, options, 0 if agree else 1, "boolq")

    def openbook(self) -> dict:
        """Fact in context; 4-way recall of the object noun."""
        topic = int(self.rng.integers(N_TOPICS))
        f = self.f
        n1 = self.g.draw_noun(self.rng, topic)
        v = self.g.draw_verb_for(self.rng, n1)
        n2 = self.g.draw_noun(self.rng, topic)
        ctx = [BOS, f["fact"], f[":"], f["the"], n1, v, f["the"], n2, f["."],
               f["recall"], f[":"], f["the"], n1, v, f["the"]]
        ds = []
        while len(ds) < 3:
            d = self.g.draw_noun(self.rng, topic)
            if d != n2 and d not in ds:
                ds.append(d)
        options = [[n2]] + [[d] for d in ds]
        order = self.rng.permutation(4)
        options = [options[i] for i in order]
        return _mc_item(ctx, options, int(np.where(order == 0)[0][0]),
                        "openbook")

    def suite(self, n_per_task: int) -> list[dict]:
        out = []
        for gen in (self.arc_easy, self.arc_challenge, self.lambada,
                    self.piqa, self.boolq, self.openbook):
            for _ in range(n_per_task):
                out.append(gen())
        return out


TASK_NAMES = ["arc_easy", "arc_challenge", "lambada", "piqa", "boolq",
              "openbook"]


# ----------------------------------------------------------------------------
# Dataset bundle + export
# ----------------------------------------------------------------------------


@dataclasses.dataclass
class Dataset:
    vocab: Vocab
    grammar: Grammar
    train: np.ndarray       # uint16 token stream
    val: np.ndarray
    test: np.ndarray
    calib: np.ndarray       # [n_calib, calib_len] token matrix
    tasks: list[dict]
    judge_prompts: list[list[int]]   # prompts for the AlpacaEval-style judge


def build_dataset(train_tokens: int = 1_500_000,
                  val_tokens: int = 32_768,
                  test_tokens: int = 49_152,
                  n_calib: int = 32,
                  calib_len: int = 96,
                  n_per_task: int = 200,
                  n_judge: int = 100,
                  seed: int = 1234) -> Dataset:
    vocab = build_vocab()
    grammar = Grammar(vocab)
    train = CorpusGen(vocab, grammar, seed).stream(train_tokens)
    val = CorpusGen(vocab, grammar, seed + 1).stream(val_tokens)
    test = CorpusGen(vocab, grammar, seed + 2).stream(test_tokens)
    calib_stream = CorpusGen(vocab, grammar, seed + 3).stream(
        n_calib * calib_len)
    calib = calib_stream.reshape(n_calib, calib_len)
    tasks = TaskGen(vocab, grammar, seed + 4).suite(n_per_task)
    # Judge prompts: short contexts the engine will continue from.
    jg = CorpusGen(vocab, grammar, seed + 5)
    judge_prompts = []
    for _ in range(n_judge):
        topic = int(jg.rng.integers(N_TOPICS))
        sent = jg.sentence(topic)
        judge_prompts.append([BOS] + sent[: max(3, len(sent) // 2)])
    return Dataset(vocab, grammar, train, val, test, calib, tasks,
                   judge_prompts)


def export_dataset(ds: Dataset, out_dir: str) -> None:
    """Write data artifacts consumed by the rust layer."""
    os.makedirs(out_dir, exist_ok=True)
    ds.train.tofile(os.path.join(out_dir, "train.u16"))
    ds.val.tofile(os.path.join(out_dir, "val.u16"))
    ds.test.tofile(os.path.join(out_dir, "test.u16"))
    ds.calib.astype(np.uint16).tofile(os.path.join(out_dir, "calib.u16"))
    with open(os.path.join(out_dir, "vocab.json"), "w") as fh:
        json.dump({"words": ds.vocab.words,
                   "specials": {"pad": PAD, "bos": BOS, "eos": EOS,
                                "unk": UNK}}, fh)
    with open(os.path.join(out_dir, "tasks.json"), "w") as fh:
        json.dump({"tasks": ds.tasks, "names": TASK_NAMES}, fh)
    with open(os.path.join(out_dir, "judge_prompts.json"), "w") as fh:
        json.dump({"prompts": ds.judge_prompts}, fh)
    meta = {"n_calib": int(ds.calib.shape[0]),
            "calib_len": int(ds.calib.shape[1]),
            "vocab_size": ds.vocab.size}
    with open(os.path.join(out_dir, "meta.json"), "w") as fh:
        json.dump(meta, fh)
