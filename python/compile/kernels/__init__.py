from .lqer_linear import lqer_linear  # noqa: F401
from .mxint import mxint_quant_act_pallas, mxint_quant_weight_pallas  # noqa: F401
from .intq import int_quant_per_token_pallas  # noqa: F401
