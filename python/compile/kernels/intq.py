"""Per-token symmetric fixed-point activation quantization as a Pallas
kernel (the activation side of the INTx w&a baselines, e.g. W4A8 g128).

Each token (row) shares one FP16 scale = amax / (2^(b-1) - 1); elements
round to b-bit signed integers.  The grid walks row tiles; scales live in
VMEM next to the tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _intq_kernel(x_ref, o_ref, *, bits: int):
    x = x_ref[...]
    qmax = 2.0 ** (bits - 1) - 1
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / qmax, 1.0)
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax)
    o_ref[...] = q * scale


def _pick_rows(m: int, target: int = 256) -> int:
    b = min(m, target)
    while m % b != 0:
        b -= 1
    return b


def int_quant_per_token_pallas(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    shape = x.shape
    x2 = jnp.asarray(x, jnp.float32).reshape(-1, shape[-1])
    m, n = x2.shape
    bm = _pick_rows(m)
    out = pl.pallas_call(
        functools.partial(_intq_kernel, bits=bits),
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x2)
    return out.reshape(shape)
