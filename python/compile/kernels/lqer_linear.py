"""The LQER inference pattern as a fused Pallas kernel (the paper's L1
compute hot-spot).

    Y = X W_q  +  (X A_k) B_k          (paper Eq. 9 / Eq. 12)

Hardware adaptation (DESIGN.md section 5).  The paper runs the dense
low-precision GEMM and the two skinny high-precision GEMMs as *parallel*
streams on GPU / parallel PE banks on FPGA.  On TPU the natural analogue is
to FUSE them into one kernel so the X row-panel is moved HBM->VMEM exactly
once and feeds both the W_q panel (MXU, the big matmul) and the A_k panel
(the skinny correction):

  grid = (M/bm, N/bn); at step (i, j) VMEM holds
      x   : (bm, K)    -- the shared row panel
      wq  : (K, bn)    -- low-precision weight panel
      ak  : (K, r)     -- low-rank left factor (whole, r is small)
      bk  : (r, bn)    -- low-rank right factor panel
      out : (bm, bn)

  out = x @ wq + (x @ ak) @ bk

VMEM budget per step (f32, worst case in this repo: K=768, bm=bn=128,
r=256): 128*768 + 768*128 + 768*256 + 256*128 + 128*128 floats
= 1.77 MiB << 16 MiB, leaving room for double buffering; for the paper's
OPT-175B shapes (K=12288, r=32) the same schedule holds with bk-tiling of
K.  The extra multiplies of the correction are (m+n)*k vs m*n for the main
GEMM -- the paper's ~0.01*k% overhead formula -- so MXU utilization is
dominated by the W_q panel.

``interpret=True`` everywhere: the CPU PJRT backend cannot execute Mosaic
custom-calls, so the kernel is lowered through the Pallas interpreter into
plain HLO (numerically identical; see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(n: int, target: int = 128) -> int:
    """Largest divisor of n that is <= target (tile sizes must tile n)."""
    b = min(n, target)
    while n % b != 0:
        b -= 1
    return b


def _kernel_lowrank(x_ref, wq_ref, ak_ref, bk_ref, o_ref):
    x = x_ref[...]
    y = jnp.dot(x, wq_ref[...], preferred_element_type=jnp.float32)
    p = jnp.dot(x, ak_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = y + jnp.dot(p, bk_ref[...],
                             preferred_element_type=jnp.float32)


def _kernel_plain(x_ref, wq_ref, o_ref):
    o_ref[...] = jnp.dot(x_ref[...], wq_ref[...],
                         preferred_element_type=jnp.float32)


def lqer_linear(x: jnp.ndarray, wq: jnp.ndarray,
                ak: jnp.ndarray | None = None,
                bk: jnp.ndarray | None = None,
                block_m: int = 128, block_n: int = 128) -> jnp.ndarray:
    """Apply the LQER linear pattern to ``x`` of shape (..., K).

    wq: (K, N) effective (already fake-quantized) weight.
    ak: (K, r) / bk: (r, N) low-rank error reconstruction, or None.
    """
    orig_shape = x.shape
    k_in = orig_shape[-1]
    n = wq.shape[1]
    assert wq.shape[0] == k_in
    x2 = x.reshape(-1, k_in)
    m = x2.shape[0]
    # Perf (EXPERIMENTS.md §Perf-L1): decode-path calls have tiny M
    # (= batch size).  Tiling those like a big GEMM buys nothing and pays
    # one XLA loop iteration per output tile; a single wide tile keeps the
    # whole output row panel in one grid step (VMEM: K*N f32 <= 590 KiB at
    # this repo's largest shapes, far under the 16 MiB budget).
    if m <= 32:
        bm = m
        bn = _pick_block(n, 512)
    else:
        bm = _pick_block(m, block_m)
        bn = _pick_block(n, block_n)
    grid = (m // bm, n // bn)

    has_lowrank = ak is not None and bk is not None and ak.shape[1] > 0
    if has_lowrank:
        r = ak.shape[1]
        out = pl.pallas_call(
            _kernel_lowrank,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, k_in), lambda i, j: (i, 0)),
                pl.BlockSpec((k_in, bn), lambda i, j: (0, j)),
                pl.BlockSpec((k_in, r), lambda i, j: (0, 0)),
                pl.BlockSpec((r, bn), lambda i, j: (0, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
            interpret=True,
        )(x2, wq, ak, bk)
    else:
        out = pl.pallas_call(
            _kernel_plain,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, k_in), lambda i, j: (i, 0)),
                pl.BlockSpec((k_in, bn), lambda i, j: (0, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
            interpret=True,
        )(x2, wq)
    return out.reshape(*orig_shape[:-1], n)
