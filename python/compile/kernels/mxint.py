"""MXINT fake-quantization as Pallas kernels.

Two variants, matching the paper's block orientations (section 4.1):

  * activations: block [1, 16] -- 16 consecutive channels of one token
    share an 8-bit exponent;
  * weights:     block [16, 1] -- 16 consecutive input-features of one
    output column share a 4-bit exponent.

Both reduce to the same 1-D kernel over a (rows, cols) view whose last
axis is the blocked one; the weight variant transposes in and out.

The kernel walks a 1-D grid of row tiles; each step owns a
(tile_rows, cols) VMEM block, reshapes it to (tile_rows, cols/16, 16),
and applies shared-exponent rounding:

    E    = clamp(floor(log2(max |block|)), exp_min, exp_max)
    step = 2^(E - m + 2)
    out  = clamp(round_half_even(x / step), -2^(m-1), 2^(m-1)-1) * step

floor(log2(.)) is computed from the f32 bit pattern (frexp semantics), so
the result is exact and matches the rust twin (rust/src/quant/mxint.rs)
bit-for-bit -- verified by the cross-language golden vectors.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mxint_kernel(x_ref, o_ref, *, elem_bits: int, exp_bits: int,
                  block: int):
    x = x_ref[...]
    rows, cols = x.shape
    xb = x.reshape(rows, cols // block, block)
    amax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    _, e = jnp.frexp(amax)
    e = e - 1  # floor(log2 amax) for amax > 0
    exp_min = -(2 ** (exp_bits - 1))
    exp_max = 2 ** (exp_bits - 1) - 1
    e = jnp.where(amax > 0, e, exp_min)
    e = jnp.clip(e, exp_min, exp_max).astype(jnp.float32)
    step = jnp.exp2(e - (elem_bits - 2))
    qmin = -(2.0 ** (elem_bits - 1))
    qmax = 2.0 ** (elem_bits - 1) - 1
    q = jnp.clip(jnp.round(xb / step), qmin, qmax)
    o_ref[...] = (q * step).reshape(rows, cols)


def _pick_rows(m: int, target: int = 256) -> int:
    b = min(m, target)
    while m % b != 0:
        b -= 1
    return b


def _mxint_2d(x2: jnp.ndarray, elem_bits: int, exp_bits: int,
              block: int) -> jnp.ndarray:
    m, n = x2.shape
    assert n % block == 0, f"last dim {n} not divisible by block {block}"
    bm = _pick_rows(m)
    kern = functools.partial(_mxint_kernel, elem_bits=elem_bits,
                             exp_bits=exp_bits, block=block)
    return pl.pallas_call(
        kern,
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x2)


def mxint_quant_act_pallas(x: jnp.ndarray, elem_bits: int,
                           exp_bits: int = 8, block: int = 16) -> jnp.ndarray:
    """Blocks of [1, block] along the channel (last) axis."""
    shape = x.shape
    x2 = jnp.asarray(x, jnp.float32).reshape(-1, shape[-1])
    return _mxint_2d(x2, elem_bits, exp_bits, block).reshape(shape)


def mxint_quant_weight_pallas(w: jnp.ndarray, elem_bits: int,
                              exp_bits: int = 4,
                              block: int = 16) -> jnp.ndarray:
    """Blocks of [block, 1] along input features (axis 0 of (in, out))."""
    assert w.ndim == 2
    wt = jnp.asarray(w, jnp.float32).T
    return _mxint_2d(wt, elem_bits, exp_bits, block).T
