"""Pure-jnp oracles for the L1 Pallas kernels.

The quantization oracles are the shared implementations in
``compile.quant.formats`` (also the source of the rust golden vectors);
``lqer_linear_ref`` is the mathematical definition of the paper's
inference pattern (Eq. 9 / Eq. 12):

    Y = X W_q + (X A_k) B_k

pytest (python/tests/test_kernels.py) asserts each Pallas kernel matches
its oracle to float32 tolerance across hypothesis-swept shapes.
"""

from __future__ import annotations

import jax.numpy as jnp

from ..quant import formats


def mxint_quant_act_ref(x, elem_bits: int, exp_bits: int = 8,
                        block: int = 16):
    return formats.mxint_quant_act(x, elem_bits, exp_bits, block)


def mxint_quant_weight_ref(w, elem_bits: int, exp_bits: int = 4,
                           block: int = 16):
    return formats.mxint_quant_weight(w, elem_bits, exp_bits, block)


def int_quant_per_token_ref(x, bits: int):
    return formats.int_quant_per_token(x, bits)


def lqer_linear_ref(x: jnp.ndarray, wq: jnp.ndarray,
                    ak: jnp.ndarray | None,
                    bk: jnp.ndarray | None) -> jnp.ndarray:
    """Y = X W_q + (X A_k) B_k   (LQER inference pattern, paper Eq. 9)."""
    y = jnp.dot(x, wq, preferred_element_type=jnp.float32)
    if ak is not None and bk is not None and ak.shape[1] > 0:
        y = y + jnp.dot(jnp.dot(x, ak, preferred_element_type=jnp.float32),
                        bk, preferred_element_type=jnp.float32)
    return y
