"""L2: OPT-style decoder-only transformer in pure JAX.

Every linear layer in the transformer blocks runs the paper's LQER
inference pattern via the fused L1 Pallas kernel
(``kernels.lqer_linear``):

    Y = Xq W_q + (Xq A_k) B_k

where Xq is the (optionally fake-quantized) activation and (A_k, B_k) is
the low-rank error reconstruction.  For non-LQER methods the same graph is
lowered without the low-rank branch; the *weights are HLO parameters*, so
one lowered graph serves every quantization method that shares
(activation mode, rank) -- see DESIGN.md section 3.

Entry points lowered to HLO text for the rust runtime:

  score(params, tokens[B,T])              -> logits[B,T,V]
  prefill(params, tokens[B,T])            -> logits[B,T,V], k/v caches
  decode(params, token[B], kc, vc, pos[B])-> logits[B,V], k_new, v_new
  decode_resident(params, token[B], kc, vc, pos[B])
                                          -> logits[B,V], kc', vc'
  kv_write_prefill(kc, vc, k_pre, v_pre, slot)
                                          -> kc', vc'

``decode`` is the legacy host-cache step: rust owns the KV cache arrays
and writes (k_new, v_new) into position pos after each step, paying an
O(L*B*T_max*d) cache upload per generated token.  ``decode_resident`` is
the device-resident step (DESIGN.md section 6): the row append happens
in-graph via dynamic-update-slice and the *updated full caches* are
returned as outputs, so the runtime can re-feed the output buffers as the
next step's inputs and only token ids / positions / logits ever cross the
PJRT boundary.  ``kv_write_prefill`` scatters one prefilled sequence
(shape (L, 1, t, d)) into batch slot ``slot`` of a resident cache; it
takes no model parameters.

Activation modes (``act``):
  "none"  : f32 activations (the FP16 baseline and w-only setups)
  "mx8"/"mx6": MXINT fake-quant, 8-bit shared exponent, block [1,16]
  "int8"/"int6": per-token symmetric fixed point, with an optional
      per-channel smoothing vector (SmoothQuant) and an outlier mask
      (LLM.int4(): masked channels stay high-precision) -- both are
      parameters, defaulting to ones.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import lqer_linear
from .quant import formats

# ----------------------------------------------------------------------------
# Configuration
# ----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d: int          # embedding dim
    layers: int
    heads: int
    ffn: int
    t_max: int      # maximum positions

    @property
    def head_dim(self) -> int:
        return self.d // self.heads

    def param_count(self) -> int:
        d, f = self.d, self.ffn
        per_layer = 4 * d * d + 2 * d * f + 4 * d + f + d + 4 * d
        return (self.vocab * d + self.t_max * d
                + self.layers * per_layer + 2 * d)


MODEL_FAMILY = {
    # name        d    L  H  ffn
    "opt-tiny": dict(d=64, layers=2, heads=2, ffn=256),
    "opt-micro": dict(d=128, layers=4, heads=4, ffn=512),
    "opt-mini": dict(d=192, layers=6, heads=6, ffn=768),
    "opt-small": dict(d=256, layers=8, heads=8, ffn=1024),
}

LINEAR_NAMES = ["wq", "wk", "wv", "wo", "fc1", "fc2"]


def make_config(name: str, vocab: int, t_max: int = 160) -> ModelConfig:
    spec = MODEL_FAMILY[name]
    return ModelConfig(name=name, vocab=vocab, t_max=t_max, **spec)


@dataclasses.dataclass(frozen=True)
class GraphVariant:
    """One lowered-HLO graph shape: activation mode x low-rank rank."""
    act: str          # none | mx8 | mx6 | int8 | int6
    rank: int         # 0 = no low-rank branch; >0 = padded rank of A/B

    @property
    def tag(self) -> str:
        return f"act-{self.act}_k{self.rank}"

    @property
    def act_bits(self) -> int:
        return {"none": 16, "mx8": 8, "mx6": 6,
                "int8": 8, "int6": 6}[self.act]

    @property
    def needs_smooth(self) -> bool:
        return self.act in ("int8", "int6")


# ----------------------------------------------------------------------------
# Parameters
# ----------------------------------------------------------------------------


def init_params(cfg: ModelConfig, seed: int = 0) -> dict:
    """FP32 initialization (GPT-2 style scaled normal)."""
    rng = np.random.default_rng(seed)
    d, f = cfg.d, cfg.ffn

    def nrm(*shape, scale):
        return rng.normal(0.0, scale, size=shape).astype(np.float32)

    params: dict[str, Any] = {
        "embed": nrm(cfg.vocab, d, scale=0.05),
        "pos": nrm(cfg.t_max, d, scale=0.02),
        "ln_f": {"scale": np.ones(d, np.float32),
                 "bias": np.zeros(d, np.float32)},
        "layers": [],
    }
    resid = 1.0 / np.sqrt(2 * cfg.layers)
    for _ in range(cfg.layers):
        layer = {
            "ln1": {"scale": np.ones(d, np.float32),
                    "bias": np.zeros(d, np.float32)},
            "ln2": {"scale": np.ones(d, np.float32),
                    "bias": np.zeros(d, np.float32)},
            "wq": {"w": nrm(d, d, scale=0.08)},
            "wk": {"w": nrm(d, d, scale=0.08)},
            "wv": {"w": nrm(d, d, scale=0.08)},
            "wo": {"w": nrm(d, d, scale=0.08 * resid)},
            "fc1": {"w": nrm(d, f, scale=0.08)},
            "fc2": {"w": nrm(f, d, scale=0.08 * resid)},
            "bq": np.zeros(d, np.float32), "bk": np.zeros(d, np.float32),
            "bv": np.zeros(d, np.float32), "bo": np.zeros(d, np.float32),
            "b1": np.zeros(f, np.float32), "b2": np.zeros(d, np.float32),
        }
        params["layers"].append(layer)
    return params


def attach_variant_params(params: dict, cfg: ModelConfig,
                          gv: GraphVariant) -> dict:
    """Extend an FP32 param tree with the per-linear tensors a graph
    variant expects (identity defaults).  The PTQ pipeline overwrites
    these with real factors / scales / masks."""
    out = jax.tree_util.tree_map(lambda x: x, params)
    for layer in out["layers"]:
        for name in LINEAR_NAMES:
            lin = dict(layer[name])
            m, n = lin["w"].shape
            if gv.rank > 0:
                lin.setdefault("a", np.zeros((m, gv.rank), np.float32))
                lin.setdefault("b", np.zeros((gv.rank, n), np.float32))
            else:
                lin.pop("a", None)
                lin.pop("b", None)
            if gv.needs_smooth:
                lin.setdefault("smooth", np.ones(m, np.float32))
                lin.setdefault("actmask", np.ones(m, np.float32))
            else:
                lin.pop("smooth", None)
                lin.pop("actmask", None)
            layer[name] = lin
    return out


def param_specs(params):
    """Shape/dtype specs for lowering (weights become HLO parameters)."""
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(np.shape(x), jnp.float32), params)


def _key_name(k) -> str:
    """One path component as a bare name (jax.tree_util.keystr only grew
    simple=/separator= in jax 0.5; this works on 0.4.x too)."""
    for attr in ("key", "idx", "name"):
        if hasattr(k, attr):
            return str(getattr(k, attr))
    return str(k)


def flatten_with_names(params) -> list[tuple[str, np.ndarray]]:
    """Deterministic (name, array) list in jax tree-flatten order -- this
    exact order is the HLO parameter order recorded in weights.bin."""
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        name = ".".join(_key_name(k) for k in path)
        out.append((name, np.asarray(leaf, np.float32)))
    return out


# ----------------------------------------------------------------------------
# Forward pieces
# ----------------------------------------------------------------------------


def layer_norm(x, scale, bias, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def gelu(x):
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654
                                     * (x + 0.044715 * x * x * x)))


def _act_quant(x, gv: GraphVariant, lin: dict):
    """Simulate the activation-side number format at a linear input."""
    if gv.act == "none":
        return x
    if gv.act in ("mx8", "mx6"):
        return formats.mxint_quant_act(x, gv.act_bits)
    # int8 / int6: optional SmoothQuant division + LLM.int4() outlier mask.
    xs = x / lin["smooth"]
    xq = formats.int_quant_per_token(xs, gv.act_bits)
    mask = lin["actmask"]
    return mask * xq + (1.0 - mask) * xs


def linear(x, lin: dict, gv: GraphVariant, collect=None, name: str = ""):
    """One LQER linear: act-quant then the fused Pallas kernel."""
    if collect is not None:
        collect[name] = x
    xq = _act_quant(x, gv, lin)
    return lqer_linear(xq, lin["w"], lin.get("a"), lin.get("b"))


def _split_heads(x, cfg: ModelConfig):
    b, t, _ = x.shape
    return x.reshape(b, t, cfg.heads, cfg.head_dim).transpose(0, 2, 1, 3)


def _merge_heads(x, cfg: ModelConfig):
    b, h, t, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, h * hd)


def block_full(h, layer, cfg: ModelConfig, gv: GraphVariant,
               collect=None, idx: int = 0):
    """One transformer block over a full (B, T, d) sequence (causal)."""
    b, t, d = h.shape
    x = layer_norm(h, layer["ln1"]["scale"], layer["ln1"]["bias"])
    pre = f"layers.{idx}."
    q = linear(x, layer["wq"], gv, collect, pre + "wq") + layer["bq"]
    k = linear(x, layer["wk"], gv, collect, pre + "wk") + layer["bk"]
    v = linear(x, layer["wv"], gv, collect, pre + "wv") + layer["bv"]
    qh, kh, vh = (_split_heads(z, cfg) for z in (q, k, v))
    scores = jnp.einsum("bhqd,bhkd->bhqk", qh, kh) / np.sqrt(cfg.head_dim)
    causal = jnp.tril(jnp.ones((t, t), bool))
    scores = jnp.where(causal, scores, -1e30)
    att = jax.nn.softmax(scores, axis=-1)
    ctx = _merge_heads(jnp.einsum("bhqk,bhkd->bhqd", att, vh), cfg)
    h = h + linear(ctx, layer["wo"], gv, collect, pre + "wo") + layer["bo"]

    x = layer_norm(h, layer["ln2"]["scale"], layer["ln2"]["bias"])
    u = gelu(linear(x, layer["fc1"], gv, collect, pre + "fc1") + layer["b1"])
    h = h + linear(u, layer["fc2"], gv, collect, pre + "fc2") + layer["b2"]
    return h, (k, v)


def score(params, tokens, cfg: ModelConfig, gv: GraphVariant,
          collect=None):
    """Full-sequence logits (perplexity / task scoring graph)."""
    b, t = tokens.shape
    h = params["embed"][tokens] + params["pos"][:t]
    for i, layer in enumerate(params["layers"]):
        h, _ = block_full(h, layer, cfg, gv, collect, i)
    h = layer_norm(h, params["ln_f"]["scale"], params["ln_f"]["bias"])
    return jnp.einsum("btd,vd->btv", h, params["embed"])


def prefill(params, tokens, cfg: ModelConfig, gv: GraphVariant):
    """Like score, but also returns per-layer K/V caches (L, B, T, d)."""
    b, t = tokens.shape
    h = params["embed"][tokens] + params["pos"][:t]
    ks, vs = [], []
    for i, layer in enumerate(params["layers"]):
        h, (k, v) = block_full(h, layer, cfg, gv, None, i)
        ks.append(k)
        vs.append(v)
    h = layer_norm(h, params["ln_f"]["scale"], params["ln_f"]["bias"])
    logits = jnp.einsum("btd,vd->btv", h, params["embed"])
    return logits, jnp.stack(ks), jnp.stack(vs)


def decode(params, token, k_cache, v_cache, pos, cfg: ModelConfig,
           gv: GraphVariant):
    """One decode step.

    token:  (B,) int32 current tokens
    k/v_cache: (L, B, T_max, d) -- positions < pos[b] are valid
    pos:    (B,) int32 position of the current token
    returns logits (B, V), k_new (L, B, d), v_new (L, B, d)
    """
    b = token.shape[0]
    t_max = k_cache.shape[2]
    h = params["embed"][token] + params["pos"][pos]  # (B, d)
    h = h[:, None, :]                                # (B, 1, d)
    k_news, v_news = [], []
    for li, layer in enumerate(params["layers"]):
        x = layer_norm(h, layer["ln1"]["scale"], layer["ln1"]["bias"])
        q = linear(x, layer["wq"], gv) + layer["bq"]
        k = linear(x, layer["wk"], gv) + layer["bk"]
        v = linear(x, layer["wv"], gv) + layer["bv"]
        k_news.append(k[:, 0, :])
        v_news.append(v[:, 0, :])
        qh = _split_heads(q, cfg)                        # (B, H, 1, hd)
        kc = k_cache[li].reshape(b, t_max, cfg.heads, cfg.head_dim)
        kc = kc.transpose(0, 2, 1, 3)                    # (B, H, T, hd)
        vc = v_cache[li].reshape(b, t_max, cfg.heads, cfg.head_dim)
        vc = vc.transpose(0, 2, 1, 3)
        s_cache = (jnp.einsum("bhqd,bhkd->bhqk", qh, kc)
                   / np.sqrt(cfg.head_dim))
        valid = jnp.arange(t_max)[None, :] < pos[:, None]  # (B, T_max)
        s_cache = jnp.where(valid[:, None, None, :], s_cache, -1e30)
        kh = _split_heads(k, cfg)
        vh = _split_heads(v, cfg)
        s_self = (jnp.einsum("bhqd,bhkd->bhqk", qh, kh)
                  / np.sqrt(cfg.head_dim))
        s_all = jnp.concatenate([s_cache, s_self], axis=-1)
        att = jax.nn.softmax(s_all, axis=-1)
        ctx = (jnp.einsum("bhqk,bhkd->bhqd", att[..., :t_max], vc)
               + jnp.einsum("bhqk,bhkd->bhqd", att[..., t_max:], vh))
        ctx = _merge_heads(ctx, cfg)
        h = h + linear(ctx, layer["wo"], gv) + layer["bo"]
        x = layer_norm(h, layer["ln2"]["scale"], layer["ln2"]["bias"])
        u = gelu(linear(x, layer["fc1"], gv) + layer["b1"])
        h = h + linear(u, layer["fc2"], gv) + layer["b2"]
    h = layer_norm(h, params["ln_f"]["scale"], params["ln_f"]["bias"])
    logits = jnp.einsum("btd,vd->btv", h, params["embed"])[:, 0, :]
    return logits, jnp.stack(k_news), jnp.stack(v_news)


def _scatter_rows(cache, rows, pos):
    """Write rows (L, B, d) into cache (L, B, T_max, d) at positions pos
    (B,), one dynamic-update-slice per (layer, batch) cell.

    The unrolled DUS lattice keeps every write a contiguous d-length row —
    no gather/scatter over irregular memory, matching the paper's
    hardware-friendliness argument.  Note rows are written for *every*
    batch lane, including free slots (the host slot manager passes pos=0
    for them); those rows are dead because attention masks positions
    >= pos and admission overwrites positions 0..len before they become
    visible.
    """
    n_layers, batch = rows.shape[0], rows.shape[1]
    zero = jnp.int32(0)
    for li in range(n_layers):
        for bi in range(batch):
            cache = jax.lax.dynamic_update_slice(
                cache, rows[li, bi][None, None, None, :],
                (jnp.int32(li), jnp.int32(bi), pos[bi], zero))
    return cache


def decode_resident(params, token, k_cache, v_cache, pos, cfg: ModelConfig,
                    gv: GraphVariant):
    """One decode step with the in-graph cache append (device-resident
    serving path).

    Same inputs as ``decode``; returns (logits (B, V), k_cache', v_cache')
    where the primed caches contain this step's K/V rows at position
    pos[b].  Bit-identical to running ``decode`` and appending the
    returned rows host-side.
    """
    logits, k_new, v_new = decode(params, token, k_cache, v_cache, pos,
                                  cfg, gv)
    return (logits,
            _scatter_rows(k_cache, k_new, pos),
            _scatter_rows(v_cache, v_new, pos))


def _gather_paged(cache, tables, b, t_view):
    """Gather per-lane contiguous cache views from a paged pool.

    cache:  (L, NB, bs, d) block pool; tables: (B, M) int32 block ids.
    Returns (L, B, M*bs, d) — lane b's logical rows 0..M*bs in order.
    Table entries past a sequence's allocated blocks may point anywhere
    (the engine pads with the sentinel); those rows sit at positions
    >= pos and are masked by attention, exactly like right-padding in
    the flat cache.
    """
    L, _, bs, d = cache.shape
    g = cache[:, tables]                    # (L, B, M, bs, d)
    return g.reshape(L, b, t_view, d)


def _scatter_rows_paged(cache, rows, pos, tables):
    """Write rows (L, B, d) into the block pool at each lane's logical
    position ``pos[b]``: physical block ``tables[b, pos[b] // bs]``,
    offset ``pos[b] % bs``.

    Same unrolled DUS lattice as :func:`_scatter_rows` — one contiguous
    d-length row per (layer, lane) — with the row index resolved through
    the block table.  A row is written for *every* lane; the engine
    points free lanes at the sentinel block (id 0) with pos 0, so their
    dead writes land in storage no live sequence owns.
    """
    n_layers, batch = rows.shape[0], rows.shape[1]
    bs = cache.shape[2]
    zero = jnp.int32(0)
    for li in range(n_layers):
        for bi in range(batch):
            chunk = pos[bi] // bs
            off = pos[bi] - chunk * bs
            blk = tables[bi, chunk]
            cache = jax.lax.dynamic_update_slice(
                cache, rows[li, bi][None, None, None, :],
                (jnp.int32(li), blk, off, zero))
    return cache


def decode_paged(params, token, k_cache, v_cache, pos, tables,
                 cfg: ModelConfig, gv: GraphVariant):
    """One decode step over a *paged* resident cache (DESIGN.md §10).

    k/v_cache: (L, NB, bs, d) block pools; tables: (B, M) int32 block
    ids with M * bs == t_max; pos: (B,) int32.  Returns
    (logits (B, V), k_cache', v_cache') with this step's K/V rows
    written through the tables.  Bit-identical to ``decode_resident``
    on the gathered flat view: the gathered lanes have exactly the
    flat (L, B, t_max, d) shape, so the attention computation is the
    same graph.
    """
    b = token.shape[0]
    t_view = tables.shape[1] * k_cache.shape[2]
    kc = _gather_paged(k_cache, tables, b, t_view)
    vc = _gather_paged(v_cache, tables, b, t_view)
    logits, k_new, v_new = decode(params, token, kc, vc, pos, cfg, gv)
    return (logits,
            _scatter_rows_paged(k_cache, k_new, pos, tables),
            _scatter_rows_paged(v_cache, v_new, pos, tables))


def kv_write_prefill_paged(k_cache, v_cache, k_pre, v_pre, block_ids):
    """Scatter a prefilled sequence into pool blocks.

    k/v_cache: (L, NB, bs, d); k/v_pre: (L, 1, t, d) with
    t == len(block_ids) * bs; block_ids: (n_chunks,) int32.  Chunk c
    (rows c*bs..(c+1)*bs of the right-padded prefill) lands in block
    ``block_ids[c]``; fully-padded chunks carry the sentinel id so the
    padding is parked in storage no sequence reads.  No model
    parameters: one lowered graph per (NB, t) serves every method.
    """
    bs = k_cache.shape[2]
    n_chunks = k_pre.shape[2] // bs
    zero = jnp.int32(0)
    for c in range(n_chunks):
        idx = (zero, block_ids[c], zero, zero)
        k_chunk = k_pre[:, :, c * bs:(c + 1) * bs, :]
        v_chunk = v_pre[:, :, c * bs:(c + 1) * bs, :]
        k_cache = jax.lax.dynamic_update_slice(k_cache, k_chunk, idx)
        v_cache = jax.lax.dynamic_update_slice(v_cache, v_chunk, idx)
    return k_cache, v_cache


def prefill_chunk(params, tokens, k_cache, v_cache, block_ids,
                  cfg: ModelConfig, gv: GraphVariant):
    """One fused chunked-prefill step over a paged pool (DESIGN.md §12).

    tokens: (1, t) right-padded prefix (t a prefill bucket, multiple of
    the pool's block size); k/v_cache: (L, NB, bs, d) block pools;
    block_ids: (t // bs,) int32.  Computes the full-prefix prefill and
    scatters each ``bs``-row chunk of its K/V into ``block_ids[c]`` —
    the engine passes the sentinel id for chunks earlier ticks already
    installed and for right-padding, so a slice write never re-touches
    finalized blocks.  Returns (logits (1, t, V), k_cache', v_cache').

    Bit-exactness: the prefill compute is position-causal, so the
    logits and the scattered rows of the final chunk are identical to a
    monolithic ``prefill`` + ``kv_write_prefill_paged`` of the whole
    prompt — chunking only changes *when* rows land, never their
    values.
    """
    logits, k_pre, v_pre = prefill(params, tokens, cfg, gv)
    k_cache, v_cache = kv_write_prefill_paged(
        k_cache, v_cache, k_pre, v_pre, block_ids)
    return logits, k_cache, v_cache


def verify_batch(params, tokens, k_cache, v_cache, pos, cfg: ModelConfig,
                 gv: GraphVariant):
    """Speculative-decode verify pass (DESIGN.md §13): score S
    consecutive tokens per lane in one graph.

    tokens: (B, S) int32 — lane b's tokens at logical positions
    ``pos[b] .. pos[b] + S - 1`` (the sampled-last token followed by the
    draft's proposals); k/v_cache: (L, B, T_max, d); pos: (B,) int32.
    Returns (logits (B, S, V), k_cache', v_cache') with all S K/V rows
    appended.

    Lowered as S unrolled ``decode_resident`` steps so position j's
    logits see rows < pos + j plus its own K/V — *bit-identical* to
    feeding the same tokens through S sequential decode steps, which is
    what makes speculative acceptance exact rather than approximate:
    one fused parameter load (the corrected model's W_q, A_k, B_k)
    scores all S positions.
    """
    s = tokens.shape[1]
    outs = []
    for j in range(s):
        logits, k_cache, v_cache = decode_resident(
            params, tokens[:, j], k_cache, v_cache, pos + j, cfg, gv)
        outs.append(logits)
    return jnp.stack(outs, axis=1), k_cache, v_cache


def kv_write_prefill(k_cache, v_cache, k_pre, v_pre, slot):
    """Scatter a prefilled sequence into batch slot ``slot`` of a resident
    cache.

    k/v_cache: (L, B, T_max, d); k/v_pre: (L, 1, t, d) with t <= T_max;
    slot: scalar int32.  Writes the whole t-row block (including
    right-padded prompt rows past the true length); rows at positions
    >= len stay invisible until a decode step overwrites them, because
    attention masks positions >= pos.  No model parameters: one lowered
    graph per (B, t) serves every method.
    """
    zero = jnp.int32(0)
    idx = (zero, slot, zero, zero)
    return (jax.lax.dynamic_update_slice(k_cache, k_pre, idx),
            jax.lax.dynamic_update_slice(v_cache, v_pre, idx))


# ----------------------------------------------------------------------------
# Training-time forward (plain f32, no Pallas -- keeps training fast)
# ----------------------------------------------------------------------------


def train_forward(params, tokens, cfg: ModelConfig):
    """Plain f32 forward used by the trainer (jnp.dot, no fake quant)."""
    b, t = tokens.shape
    h = params["embed"][tokens] + params["pos"][:t]
    causal = jnp.tril(jnp.ones((t, t), bool))
    for layer in params["layers"]:
        x = layer_norm(h, layer["ln1"]["scale"], layer["ln1"]["bias"])
        q = x @ layer["wq"]["w"] + layer["bq"]
        k = x @ layer["wk"]["w"] + layer["bk"]
        v = x @ layer["wv"]["w"] + layer["bv"]
        qh, kh, vh = (_split_heads(z, cfg) for z in (q, k, v))
        s = jnp.einsum("bhqd,bhkd->bhqk", qh, kh) / np.sqrt(cfg.head_dim)
        s = jnp.where(causal, s, -1e30)
        ctx = _merge_heads(
            jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(s, axis=-1), vh),
            cfg)
        h = h + ctx @ layer["wo"]["w"] + layer["bo"]
        x = layer_norm(h, layer["ln2"]["scale"], layer["ln2"]["bias"])
        u = gelu(x @ layer["fc1"]["w"] + layer["b1"])
        h = h + u @ layer["fc2"]["w"] + layer["b2"]
    h = layer_norm(h, params["ln_f"]["scale"], params["ln_f"]["bias"])
    return jnp.einsum("btd,vd->btv", h, params["embed"])
