"""PTQ pipeline: the per-model quantization driver over QuantSpec plans.

A *plan* (``quant.spec.QuantSpec``) fully determines how each linear
layer of a model is quantized and which lowered graph variant serves it:
a model-wide default ``LayerSpec`` —

  weight : Mxint(bits, exp_bits, block) | IntGroup(bits, group) | Fp16()
  act    : "none" | "mx8" | "mx6" | "int8" | "int6"
  algo   : how W_eff is produced  (rtn / gptq / awq / llmint4 /
           smoothquant / clipq)
  lowrank: None or LowRank(k, scaled, bits)  -- LQER (scaled=False) or
           L2QER (scaled=True, uses the Appendix-A scale matrix S)

— plus ordered per-layer-name overrides, so rank and weight format can
vary layer by layer (mixed precision).  The legacy string-keyed method
registry lives on as ``spec.METHODS`` (plan constructors) and every
entry point accepts a method-name string or legacy dict via
``QuantSpec.coerce``.

``quantize_model`` walks every linear of a trained model, resolves the
plan for that layer, applies it, and returns the parameter tree for the
matching GraphVariant plus a metadata record (plan, per-layer
plan-derived bits, average weight bits, per-layer approximation error,
optimization wall-time) consumed by the rust benches and the
``lqer plan`` CLI.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from . import model as M
from .baselines import awq, clipq, gptq, llm_int4, rtn, smoothquant
from .calibration import LinearStats
from .quant import formats, lqer
from .quant import spec as qspec
from .quant.spec import Fp16, IntGroup, LayerSpec, Mxint, QuantSpec

# Legacy re-exports: the registry and sweep constructor are pure data and
# live in quant/spec.py (shared contract with rust); this module remains
# their historical import path.
METHODS = qspec.METHODS
rank_sweep_spec = qspec.rank_sweep_spec

# The low-rank factors default to 8-bit MXINT ([16,1] blocks) -- the
# paper's b_h = 8.
LOWRANK_BITS = qspec.LOWRANK_DEFAULT_BITS
LOWRANK_AVG_BITS = qspec.mxint_avg_bits(LOWRANK_BITS, 4, 16)


def graph_variant_for(plan, rank_pad: int) -> M.GraphVariant:
    plan = QuantSpec.coerce(plan)
    rank = rank_pad if plan.max_rank() > 0 else 0
    return M.GraphVariant(act=plan.default.act, rank=rank)


# ----------------------------------------------------------------------------
# Weight-grid quantizers
# ----------------------------------------------------------------------------


def weight_quant_fn(weight):
    """Quantize-dequantize closure for a WeightFormat (legacy tuples
    like ("mxint", 4) are accepted for compatibility)."""
    w_fmt = qspec.weight_from_legacy(weight)
    if isinstance(w_fmt, Fp16):
        return lambda w: np.asarray(w, np.float32)
    if isinstance(w_fmt, Mxint):
        return lambda w: np.asarray(
            formats.mxint_quant_weight(w, w_fmt.bits, w_fmt.exp_bits,
                                       w_fmt.block), np.float32)
    if w_fmt.group == 0:  # vector-wise (LLM.int8 style)
        return lambda w: np.asarray(
            formats.int_quant_group(w, w_fmt.bits, w.shape[1], axis=1),
            np.float32)
    return lambda w: np.asarray(
        formats.int_quant_group(w, w_fmt.bits, w_fmt.group, axis=0),
        np.float32)


def weight_avg_bits(weight) -> float:
    """Plan-derived average bits of a weight format (legacy tuples
    accepted).  Single source of truth: quant/spec.py."""
    return qspec.weight_from_legacy(weight).avg_bits()


# ----------------------------------------------------------------------------
# Per-model quantization
# ----------------------------------------------------------------------------


def _quantize_linear(w: np.ndarray, ls: LayerSpec,
                     st: LinearStats | None) -> dict:
    """Produce the effective low-precision weight for one linear."""
    algo = ls.algo
    if algo in ("none", "rtn"):
        return {"w": weight_quant_fn(ls.weight)(w)}
    assert st is not None, f"algo '{algo}' needs calibration stats"
    assert isinstance(ls.weight, IntGroup), ls.weight
    bits, group = ls.weight.bits, ls.weight.group
    if algo == "gptq":
        return gptq.quantize(w, st.h, bits=bits, group=group)
    if algo == "awq":
        return awq.quantize(w, st.a_max, st.x_sample, bits=bits,
                            group=group)
    if algo == "llmint4":
        return llm_int4.quantize(w, st.a_max, bits=bits)
    if algo == "smoothquant":
        return smoothquant.quantize(w, st.a_max, bits=bits, group=group)
    if algo == "clipq":
        return clipq.quantize(w, st.x_sample, bits=bits, group=group)
    raise ValueError(f"unknown algo {algo}")


def quantize_model(params, cfg: M.ModelConfig, plan,
                   stats: dict[str, LinearStats] | None,
                   rank_pad: int | None = None,
                   spectra_layer: str | None = None) -> tuple[dict, dict]:
    """Apply one plan to every linear layer, resolving per-layer specs.

    ``plan`` may be a QuantSpec, a legacy method dict, or a method-name
    string.  Returns (variant_params, meta).  meta carries the resolved
    plan, per-layer plan-derived bits, avg weight bits, per-linear
    approximation errors (Figure 4), optional singular-value spectra
    (Figure 1a) and the optimization wall-time (section 4.3's
    optimization-cost comparison).
    """
    t0 = time.time()
    plan = QuantSpec.coerce(plan).validate()
    max_k = plan.max_rank()
    rank_pad = rank_pad if rank_pad is not None else max_k
    assert rank_pad >= max_k, (
        f"rank_pad {rank_pad} < plan max rank {max_k}")
    gv = graph_variant_for(plan, rank_pad)
    out = M.attach_variant_params(
        jax.tree_util.tree_map(np.asarray, params), cfg, gv)

    total_w = 0
    total_bits = 0.0
    approx_errs: dict[str, float] = {}
    spectra: dict[str, dict] = {}
    plan_bits: dict[str, float] = {}

    for li, layer in enumerate(out["layers"]):
        for name in M.LINEAR_NAMES:
            key = f"layers.{li}.{name}"
            ls = plan.resolve(key)
            lin = layer[name]
            w = np.asarray(lin["w"], np.float32)
            m, n = w.shape
            st = stats.get(key) if stats else None
            lowrank = ls.lowrank

            # With a low-rank term and plain rounding, W_q comes from
            # lqer_quantize below — skip the redundant base pass.  Other
            # algos still run for their side outputs (smooth/actmask),
            # though lqer_quantize's grid likewise wins for w itself.
            if lowrank is not None and ls.algo in ("none", "rtn"):
                res = {}
            else:
                res = _quantize_linear(w, ls, st)
            w_eff = res.get("w")
            if lowrank is not None:
                s_diag = None
                if lowrank.scaled:
                    assert st is not None, "L2QER needs calibration"
                    s_diag = lqer.calib_scale_matrix(st.a_bar)
                fac = lqer.lqer_quantize(
                    w, weight_quant_fn(ls.weight), lowrank.k,
                    s_diag=s_diag, lowrank_bits=lowrank.bits,
                    pad_to=rank_pad)
                w_eff = fac.w_q
                lin["a"] = fac.a_k
                lin["b"] = fac.b_k
                approx_errs[key] = fac.approx_err
                if spectra_layer == key:
                    spectra[key] = {
                        "spectrum": fac.singular_values.tolist()}
            lin["w"] = w_eff
            if "smooth" in res:
                lin["smooth"] = res["smooth"]
            if "actmask" in res:
                lin["actmask"] = res["actmask"]

            # Plan-derived bits (the cross-language contract: rust
            # recomputes these from the plan and asserts equality).
            plan_bits[key] = ls.avg_bits(m, n)
            bits = m * n * plan_bits[key]
            if ls.algo == "llmint4":
                # outlier rows stay FP16 in memory-bits accounting — a
                # data-dependent correction on top of the plan number
                n_out = res.get("n_outliers", 0)
                bits = ((m - n_out) * n * ls.weight.avg_bits()
                        + n_out * n * 16.0)
            total_w += m * n
            total_bits += bits

    shapes = qspec.layer_shapes(cfg.d, cfg.ffn, cfg.layers)
    meta = {
        "avg_w_bits": total_bits / max(total_w, 1),
        "plan_avg_bits": plan.model_avg_bits(shapes),
        "plan_bits": plan_bits,
        "plan": plan.to_json_dict(),
        "approx_err": approx_errs,
        "spectra": spectra,
        "opt_seconds": time.time() - t0,
        "rank": max_k,
        "rank_pad": rank_pad,
        "graph": gv.tag,
        "spec": plan.default.to_legacy_dict(),
    }
    return out, meta
