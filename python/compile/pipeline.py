"""PTQ pipeline: the method registry and the per-model quantization driver.

A *method spec* fully determines how each linear layer of a model is
quantized and which lowered graph variant serves it:

  weight : ("mxint", bits) | ("int", bits, group) | ("fp",)
  act    : "none" | "mx8" | "mx6" | "int8" | "int6"
  algo   : how W_eff is produced  (rtn / gptq / awq / llmint4 /
           smoothquant / clipq)
  lowrank: None or {"k": int, "scaled": bool}  -- LQER (scaled=False) or
           L2QER (scaled=True, uses the Appendix-A scale matrix S)

``quantize_model`` walks every linear of a trained model, applies the
method, and returns the parameter tree for the matching GraphVariant plus
a metadata record (average weight bits, per-layer approximation error,
optimization wall-time) consumed by the rust benches.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from . import model as M
from .baselines import awq, clipq, gptq, llm_int4, rtn, smoothquant
from .calibration import LinearStats
from .quant import formats, lqer

# ----------------------------------------------------------------------------
# Method registry (the paper's Table 3/4/6 configurations)
# ----------------------------------------------------------------------------

METHODS: dict[str, dict] = {
    # name                    weight           act     algo        lowrank
    "fp16": dict(weight=("fp",), act="none", algo="none", lowrank=None),
    # Table 2: plain MXINT vs LQER vs L2QER (W4A8)
    "mxint-w4a8": dict(weight=("mxint", 4), act="mx8", algo="rtn",
                       lowrank=None),
    "lqer-w4a8": dict(weight=("mxint", 4), act="mx8", algo="rtn",
                      lowrank={"k": 16, "scaled": False}),
    "l2qer-w4a8": dict(weight=("mxint", 4), act="mx8", algo="rtn",
                       lowrank={"k": 16, "scaled": True}),
    # Table 3 w&a: MXINT W4A6
    "l2qer-w4a6": dict(weight=("mxint", 4), act="mx6", algo="rtn",
                       lowrank={"k": 16, "scaled": True}),
    # Table 3 w-only: L2QER-INT (INT4 g128 weights, FP16 acts)
    "l2qer-int-w4": dict(weight=("int", 4, 128), act="none", algo="rtn",
                         lowrank={"k": 16, "scaled": True}),
    # Table 3 w&a: L2QER-INT W4A8 g128
    "l2qer-int-w4a8": dict(weight=("int", 4, 128), act="int8", algo="rtn",
                           lowrank={"k": 16, "scaled": True}),
    # w-only baselines
    "gptq-w4": dict(weight=("int", 4, 128), act="none", algo="gptq",
                    lowrank=None),
    "awq-w4": dict(weight=("int", 4, 128), act="none", algo="awq",
                   lowrank=None),
    "rtn-w4": dict(weight=("int", 4, 128), act="none", algo="rtn",
                   lowrank=None),
    # w&a baselines
    "llmint4": dict(weight=("int", 4, 0), act="int8", algo="llmint4",
                    lowrank=None),
    "smoothquant-w8a8": dict(weight=("int", 8, 128), act="int8",
                             algo="smoothquant", lowrank=None),
    "clipq-w6a6": dict(weight=("int", 6, 128), act="int6", algo="clipq",
                       lowrank=None),
    # 2-bit setup (Table 6 / Table 10)
    "awq-w2": dict(weight=("int", 2, 128), act="none", algo="awq",
                   lowrank=None),
    "clipq-w2": dict(weight=("int", 2, 128), act="none", algo="clipq",
                     lowrank=None),
    "l2qer-w2a8": dict(weight=("mxint", 2), act="mx8", algo="rtn",
                       lowrank={"k": 64, "scaled": True}),
    # Difficulty-matched Table-2 trio: at toy scale W4 is already lossless
    # (EXPERIMENTS.md), so the paper's W4-on-7B regime maps to W2 here.
    "mxint-w2a8": dict(weight=("mxint", 2), act="mx8", algo="rtn",
                       lowrank=None),
    "lqer-w2a8": dict(weight=("mxint", 2), act="mx8", algo="rtn",
                      lowrank={"k": 64, "scaled": False}),
    # Figure 3 rank-sweep baseline (W3, kept for the spectra figure).
    "mxint-w3a8": dict(weight=("mxint", 3), act="mx8", algo="rtn",
                       lowrank=None),
    # Ablation: precision of the low-rank factors (paper stores them at
    # b_h = 8; what do 4-bit or unquantized factors change?).
    "l2qer-w2a8-lr4": dict(weight=("mxint", 2), act="mx8", algo="rtn",
                           lowrank={"k": 64, "scaled": True, "bits": 4}),
    "l2qer-w2a8-lrfp": dict(weight=("mxint", 2), act="mx8", algo="rtn",
                            lowrank={"k": 64, "scaled": True,
                                     "bits": None}),
    # Ablation: LQER rank at fixed budget (k=16 vs 64 on W2).
    "l2qer-w2a8-rank16": dict(weight=("mxint", 2), act="mx8", algo="rtn",
                           lowrank={"k": 16, "scaled": True}),
}

# The low-rank factors are stored as 8-bit MXINT ([16,1] blocks) -- the
# paper's b_h = 8.
LOWRANK_BITS = 8
LOWRANK_AVG_BITS = formats.mxint_avg_bits(LOWRANK_BITS, 4, 16)


def rank_sweep_spec(k: int, scaled: bool, w_bits: int = 2) -> dict:
    """Method spec for the Figure-3 perplexity-vs-rank sweep."""
    return dict(weight=("mxint", w_bits), act="mx8", algo="rtn",
                lowrank={"k": k, "scaled": scaled})


def graph_variant_for(spec: dict, rank_pad: int) -> M.GraphVariant:
    rank = rank_pad if spec["lowrank"] else 0
    return M.GraphVariant(act=spec["act"], rank=rank)


# ----------------------------------------------------------------------------
# Weight-grid quantizers
# ----------------------------------------------------------------------------


def weight_quant_fn(weight_spec: tuple):
    kind = weight_spec[0]
    if kind == "fp":
        return lambda w: np.asarray(w, np.float32)
    if kind == "mxint":
        bits = weight_spec[1]
        return lambda w: np.asarray(
            formats.mxint_quant_weight(w, bits), np.float32)
    if kind == "int":
        bits, group = weight_spec[1], weight_spec[2]
        if group == 0:  # vector-wise (LLM.int8 style)
            return lambda w: np.asarray(
                formats.int_quant_group(w, bits, w.shape[1], axis=1),
                np.float32)
        return lambda w: np.asarray(
            formats.int_quant_group(w, bits, group, axis=0), np.float32)
    raise ValueError(f"unknown weight spec {weight_spec}")


def weight_avg_bits(weight_spec: tuple) -> float:
    kind = weight_spec[0]
    if kind == "fp":
        return 16.0
    if kind == "mxint":
        return formats.mxint_avg_bits(weight_spec[1], 4, 16)
    if kind == "int":
        bits, group = weight_spec[1], weight_spec[2]
        return formats.int_group_avg_bits(bits, group if group else 4096)
    raise ValueError(weight_spec)


# ----------------------------------------------------------------------------
# Per-model quantization
# ----------------------------------------------------------------------------


def quantize_model(params, cfg: M.ModelConfig, spec: dict,
                   stats: dict[str, LinearStats] | None,
                   rank_pad: int | None = None,
                   spectra_layer: str | None = None) -> tuple[dict, dict]:
    """Apply one method to every linear layer.

    Returns (variant_params, meta).  meta carries avg weight bits,
    per-linear approximation errors (Figure 4), optional singular-value
    spectra (Figure 1a) and the optimization wall-time (section 4.3's
    optimization-cost comparison).
    """
    t0 = time.time()
    lowrank = spec["lowrank"]
    k = lowrank["k"] if lowrank else 0
    rank_pad = rank_pad if rank_pad is not None else k
    gv = graph_variant_for(spec, rank_pad)
    qfn = weight_quant_fn(spec["weight"])
    out = M.attach_variant_params(
        jax.tree_util.tree_map(np.asarray, params), cfg, gv)

    total_w = 0
    total_bits = 0.0
    approx_errs: dict[str, float] = {}
    spectra: dict[str, dict] = {}

    for li, layer in enumerate(out["layers"]):
        for name in M.LINEAR_NAMES:
            key = f"layers.{li}.{name}"
            lin = layer[name]
            w = np.asarray(lin["w"], np.float32)
            m, n = w.shape
            st = stats.get(key) if stats else None
            algo = spec["algo"]

            if algo in ("none", "rtn"):
                res = {"w": qfn(w)}
            elif algo == "gptq":
                assert st is not None
                res = gptq.quantize(w, st.h, bits=spec["weight"][1],
                                    group=spec["weight"][2])
            elif algo == "awq":
                assert st is not None
                res = awq.quantize(w, st.a_max, st.x_sample,
                                   bits=spec["weight"][1],
                                   group=spec["weight"][2])
            elif algo == "llmint4":
                assert st is not None
                res = llm_int4.quantize(w, st.a_max,
                                        bits=spec["weight"][1])
            elif algo == "smoothquant":
                assert st is not None
                res = smoothquant.quantize(w, st.a_max,
                                           bits=spec["weight"][1],
                                           group=spec["weight"][2])
            elif algo == "clipq":
                assert st is not None
                res = clipq.quantize(w, st.x_sample,
                                     bits=spec["weight"][1],
                                     group=spec["weight"][2])
            else:
                raise ValueError(f"unknown algo {algo}")

            w_eff = res["w"]
            if lowrank:
                s_diag = None
                if lowrank["scaled"]:
                    assert st is not None, "L2QER needs calibration"
                    s_diag = lqer.calib_scale_matrix(st.a_bar)
                lr_bits = lowrank.get("bits", LOWRANK_BITS)
                fac = lqer.lqer_quantize(
                    w, qfn, k, s_diag=s_diag,
                    lowrank_bits=lr_bits, pad_to=rank_pad)
                w_eff = fac.w_q
                lin["a"] = fac.a_k
                lin["b"] = fac.b_k
                approx_errs[key] = fac.approx_err
                if spectra_layer == key:
                    spectra[key] = {
                        "spectrum": fac.singular_values.tolist()}
            lin["w"] = w_eff
            if "smooth" in res:
                lin["smooth"] = res["smooth"]
            if "actmask" in res:
                lin["actmask"] = res["actmask"]

            bits = m * n * weight_avg_bits(spec["weight"])
            if lowrank:
                lr_bits = lowrank.get("bits", LOWRANK_BITS)
                lr_avg = (32.0 if lr_bits is None
                          else formats.mxint_avg_bits(lr_bits, 4, 16))
                bits += (m + n) * k * lr_avg
            if algo == "llmint4":
                # outlier rows stay FP16 in memory-bits accounting
                n_out = res.get("n_outliers", 0)
                bits = ((m - n_out) * n * weight_avg_bits(spec["weight"])
                        + n_out * n * 16.0)
            total_w += m * n
            total_bits += bits

    meta = {
        "avg_w_bits": total_bits / max(total_w, 1),
        "approx_err": approx_errs,
        "spectra": spectra,
        "opt_seconds": time.time() - t0,
        "rank": k,
        "rank_pad": rank_pad,
        "graph": gv.tag,
        "spec": {"weight": list(spec["weight"]), "act": spec["act"],
                 "algo": spec["algo"], "lowrank": lowrank},
    }
    return out, meta
