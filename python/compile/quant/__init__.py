from . import formats  # noqa: F401
