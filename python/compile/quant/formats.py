"""Number formats: MXINT (block floating point) and fixed-point group quant.

These are the *fake-quantization* (quantize-dequantize) reference
implementations in pure jnp.  They are used

  * by the PTQ pipeline to produce effective weights on the quantization
    grid (build time),
  * inside the lowered L2 graphs to simulate low-precision activations on
    the f32 CPU PJRT backend, and
  * as the correctness oracle for the L1 Pallas kernels
    (python/compile/kernels/*) and for the bit-exact rust twins
    (rust/src/quant/*, via golden vectors).

MXINT(e, m, B): a block of B numbers shares an e-bit exponent; each element
is an m-bit (sign + m-1 magnitude) fixed-point mantissa.  Following the
paper (section 4.1): activations use 8-bit shared exponents and block
[1, 16] (along channels); weights and low-rank factors use 4-bit shared
exponents and block [16, 1] (along input features).  "WxAy" refers to the
element (mantissa) width.

Quantization step within a block with shared exponent E:

    step = 2^(E - m + 2)        # so the max magnitude ~2^(E+1) is covered
    q    = clamp(round_half_even(x / step), -2^(m-1), 2^(m-1) - 1)
    x_q  = q * step

E = floor(log2(max|block|)) clamped to the e-bit two's complement range
[-2^(e-1), 2^(e-1)-1].  All-zero blocks use E = exp_min.  This matches the
rust implementation bit-for-bit (both use frexp for floor(log2(.)) and
round-half-to-even).
"""

from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np


def _floor_log2(amax: jnp.ndarray) -> jnp.ndarray:
    """floor(log2(amax)) for amax > 0, computed exactly via frexp."""
    _, e = jnp.frexp(amax)
    return e - 1  # amax = f * 2^e with f in [0.5, 1)


def mxint_quant(x: jnp.ndarray, elem_bits: int, exp_bits: int,
                block: int, axis: int = -1) -> jnp.ndarray:
    """MXINT fake-quantization along ``axis`` with block size ``block``.

    The axis length must be divisible by ``block`` (the model dims in this
    repo are all multiples of 16).
    """
    x = jnp.asarray(x, jnp.float32)
    axis = axis % x.ndim
    n = x.shape[axis]
    assert n % block == 0, f"axis len {n} not divisible by block {block}"
    # Move target axis last, reshape to (..., n/block, block).
    xm = jnp.moveaxis(x, axis, -1)
    shape = xm.shape
    xb = xm.reshape(*shape[:-1], n // block, block)

    amax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    exp_min = -(2 ** (exp_bits - 1))
    exp_max = 2 ** (exp_bits - 1) - 1
    e = jnp.where(amax > 0, _floor_log2(amax), exp_min)
    e = jnp.clip(e, exp_min, exp_max).astype(jnp.float32)

    step = jnp.exp2(e - (elem_bits - 2))
    qmin = -(2.0 ** (elem_bits - 1))
    qmax = 2.0 ** (elem_bits - 1) - 1
    q = jnp.clip(jnp.round(xb / step), qmin, qmax)
    out = (q * step).reshape(shape)
    return jnp.moveaxis(out, -1, axis)


def mxint_quant_weight(w: jnp.ndarray, elem_bits: int,
                       exp_bits: int = 4, block: int = 16) -> jnp.ndarray:
    """Weight-side MXINT: blocks of [16, 1], i.e. along input features
    (axis 0 of an (in, out) weight matrix)."""
    return mxint_quant(w, elem_bits, exp_bits, block, axis=0)


def mxint_quant_act(x: jnp.ndarray, elem_bits: int,
                    exp_bits: int = 8, block: int = 16) -> jnp.ndarray:
    """Activation-side MXINT: blocks of [1, 16], i.e. along channels
    (last axis of a (tokens, channels) activation)."""
    return mxint_quant(x, elem_bits, exp_bits, block, axis=-1)


def effective_group(n: int, group: int) -> int:
    """Largest divisor of n that is <= group (ragged tail groups are not
    modeled; layer dims in this repo always admit a near-target divisor)."""
    g = min(group, n)
    while n % g != 0:
        g -= 1
    return g


def int_quant_group(w: jnp.ndarray, bits: int, group: int = 128,
                    axis: int = 0) -> jnp.ndarray:
    """Symmetric fixed-point group quantization (the GPTQ/AWQ 'INTb gG'
    configuration).  Each group of ``group`` values along ``axis`` shares
    an FP16 scale = amax / (2^(b-1) - 1)."""
    w = jnp.asarray(w, jnp.float32)
    axis = axis % w.ndim
    n = w.shape[axis]
    g = effective_group(n, group)
    wm = jnp.moveaxis(w, axis, -1)
    shape = wm.shape
    wb = wm.reshape(*shape[:-1], n // g, g)
    qmax = 2.0 ** (bits - 1) - 1
    amax = jnp.max(jnp.abs(wb), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / qmax, 1.0)
    # FP16 scale, as in deployed kernels.
    scale = scale.astype(jnp.float16).astype(jnp.float32)
    q = jnp.clip(jnp.round(wb / scale), -qmax - 1, qmax)
    out = (q * scale).reshape(shape)
    return jnp.moveaxis(out, -1, axis)


def int_quant_per_token(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Symmetric per-token (last-axis) fixed-point activation quant."""
    x = jnp.asarray(x, jnp.float32)
    qmax = 2.0 ** (bits - 1) - 1
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / qmax, 1.0)
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax)
    return q * scale


# ----------------------------------------------------------------------------
# Memory accounting (the "Avg. w bits" column of Table 3).  The formulas
# live in quant/spec.py — the QuantSpec contract shared with rust — and
# are re-exported here for their historical import path.
# ----------------------------------------------------------------------------

from .spec import (int_group_avg_bits, lqer_avg_bits,  # noqa: E402,F401
                   mxint_avg_bits)


# ----------------------------------------------------------------------------
# Numpy twins (exact, for golden-vector generation)
# ----------------------------------------------------------------------------


def mxint_quant_np(x: np.ndarray, elem_bits: int, exp_bits: int,
                   block: int, axis: int = -1) -> np.ndarray:
    out = np.asarray(
        mxint_quant(jnp.asarray(x), elem_bits, exp_bits, block, axis))
    return out


def int_quant_group_np(w: np.ndarray, bits: int, group: int = 128,
                       axis: int = 0) -> np.ndarray:
    return np.asarray(int_quant_group(jnp.asarray(w), bits, group, axis))


@functools.lru_cache(maxsize=None)
def format_name(kind: str, bits: int) -> str:
    return f"{kind.upper()}{bits}"
