"""LQER / L2QER: low-rank quantization error reconstruction (paper sec. 3).

Given a trained weight W (in_features x out_features), a quantizer q(.),
and (for L2QER) an activation-induced diagonal scale S:

  LQER  (sec 3.1):   E_q = W - q(W);  SVD(E_q)   -> A_k = U_k, B_k = S_k V_k^T
  L2QER (sec 3.2):   SVD(S E_q) -> A_k = S^-1 U'_k, B_k = S'_k V'_k^T

The low-rank factors are themselves quantized to the "high precision"
format (8-bit MXINT by default, matching the paper's (b_l, b_h) pairs).

Shape convention: the paper writes X (t x m) @ W (m x n); our weights are
stored (in_features m, out_features n), so S scales E_q's *rows* (input
channels), exactly as the paper's left-multiplication.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from . import formats


@dataclasses.dataclass
class LqerFactors:
    """Result of quantizing one linear layer with LQER/L2QER."""
    w_q: np.ndarray            # (m, n) effective low-precision weight
    a_k: np.ndarray            # (m, k) high-precision left factor
    b_k: np.ndarray            # (k, n) high-precision right factor
    singular_values: np.ndarray  # full spectrum of the (scaled) error
    approx_err: float          # e_a = mean |E_q - A_k B_k|  (paper Eq. 15)


def calib_scale_matrix(a_bar: np.ndarray) -> np.ndarray:
    """Appendix A, Eq. 14: s_i = a_i / sqrt(min(a) * max(a)).

    ``a_bar`` is the per-channel activation magnitude profile (Eq. 13).
    Channels that never fire are floored to the smallest observed non-zero
    magnitude so S stays invertible (the paper notes no LLM channel is
    always zero; the synthetic corpus can starve a channel at tiny scale).
    """
    a = np.asarray(a_bar, np.float64).copy()
    nz = a[a > 0]
    floor = nz.min() if nz.size else 1.0
    a[a <= 0] = floor
    denom = np.sqrt(a.min() * a.max())
    return a / denom


def svd_truncate(e: np.ndarray, k: int):
    """Rank-k truncated SVD of e: returns (U_k, s_k, Vt_k, full_spectrum)."""
    u, s, vt = np.linalg.svd(e.astype(np.float64), full_matrices=False)
    k = min(k, s.shape[0])
    return u[:, :k], s[:k], vt[:k, :], s


def lqer_quantize(w: np.ndarray, quantize_fn, k: int,
                  s_diag: np.ndarray | None = None,
                  lowrank_bits: int = 8,
                  pad_to: int | None = None) -> LqerFactors:
    """Quantize one weight matrix with LQER (s_diag=None) or L2QER.

    quantize_fn: W -> W_q on the low-precision grid (MXINT4/INT4/...).
    k: reconstruction rank. pad_to: zero-pad factors to this rank so that
    several ranks can share one lowered HLO graph (DESIGN.md section 3).
    """
    w = np.asarray(w, np.float32)
    m, n = w.shape
    w_q = np.asarray(quantize_fn(w), np.float32)
    e_q = (w - w_q).astype(np.float64)

    if s_diag is not None:
        s_diag = np.asarray(s_diag, np.float64)
        assert s_diag.shape == (m,), (s_diag.shape, m)
        scaled = e_q * s_diag[:, None]          # S E_q (row scaling)
        u_k, sv_k, vt_k, spectrum = svd_truncate(scaled, k)
        a_k = (u_k / s_diag[:, None])           # S^-1 U'_k
        b_k = sv_k[:, None] * vt_k              # Sigma'_k V'_k^T
    else:
        u_k, sv_k, vt_k, spectrum = svd_truncate(e_q, k)
        a_k = u_k
        b_k = sv_k[:, None] * vt_k

    a_k = a_k.astype(np.float32)
    b_k = b_k.astype(np.float32)
    # High-precision factors are stored in the b_h format (8-bit MXINT,
    # [16,1] blocks, 4-bit shared exponent -- paper section 4.1).  For
    # ranks below the block size (figure-3 sweep) the block shrinks to k.
    if lowrank_bits is not None:
        a_k = np.asarray(formats.mxint_quant_weight(a_k, lowrank_bits),
                         np.float32)
        blk_b = min(16, b_k.shape[0])
        assert b_k.shape[0] % blk_b == 0
        b_k = np.asarray(
            formats.mxint_quant_weight(b_k, lowrank_bits, block=blk_b),
            np.float32)

    e_tilde = a_k.astype(np.float64) @ b_k.astype(np.float64)
    approx_err = float(np.mean(np.abs(e_q - e_tilde)))

    if pad_to is not None and pad_to > a_k.shape[1]:
        pad = pad_to - a_k.shape[1]
        a_k = np.pad(a_k, ((0, 0), (0, pad)))
        b_k = np.pad(b_k, ((0, pad), (0, 0)))

    return LqerFactors(w_q=w_q, a_k=a_k, b_k=b_k,
                       singular_values=spectrum.astype(np.float32),
                       approx_err=approx_err)


def error_spectra(w: np.ndarray, quantize_fn,
                  s_diag: np.ndarray) -> dict[str, np.ndarray]:
    """Figure 1a: normalized singular-value spectra of E_q vs S E_q.

    Both spectra are normalized to the same Frobenius norm (the paper's
    footnote 1: E_q is rescaled by alpha so ||alpha E_q||_F = ||S E_q||_F).
    """
    w = np.asarray(w, np.float32)
    w_q = np.asarray(quantize_fn(w), np.float32)
    e_q = (w - w_q).astype(np.float64)
    scaled = e_q * np.asarray(s_diag, np.float64)[:, None]
    alpha = np.linalg.norm(scaled) / max(np.linalg.norm(e_q), 1e-30)
    s_plain = np.linalg.svd(alpha * e_q, compute_uv=False)
    s_scaled = np.linalg.svd(scaled, compute_uv=False)
    return {"lqer": s_plain.astype(np.float32),
            "l2qer": s_scaled.astype(np.float32)}
