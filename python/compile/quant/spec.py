"""QuantSpec: the typed, per-layer quantization-plan schema.

This file is the *contract* between the python compiler and the rust
runtime (rust/src/quant/spec.rs is its bit-for-bit mirror).  A plan is a
model-wide default ``LayerSpec`` plus ordered per-layer-name overrides:

    {"version": 1,
     "default": {"weight": {"kind": "mxint", "bits": 4,
                            "exp_bits": 4, "block": 16},
                 "act": "mx8", "algo": "rtn",
                 "lowrank": {"k": 16, "scaled": true, "bits": 8}},
     "overrides": [{"match": "layers.*.fc1", "spec": {...LayerSpec...}}]}

Weight formats: ``mxint`` (block floating point), ``int`` (fixed point
with an FP16 group scale; ``group: 0`` means vector-wise, LLM.int8
style), ``fp16`` (unquantized baseline).  ``lowrank`` is ``null`` or
``{k, scaled, bits}`` — LQER (``scaled: false``) or L2QER (``scaled:
true``); ``bits: null`` stores the factors unquantized (fp32 ablation).

Override patterns match full layer keys (``layers.3.fc1``) literally
except that ``*`` matches any run of characters; the first matching
override wins, else the default applies.  ``act`` must be uniform across
a plan because the activation mode is *graph structure* (one lowered HLO
variant per act mode), whereas weights/rank are data.

Canonical serialization is ``json.dumps(plan.to_json_dict(),
separators=(",", ":"))`` — key order fixed, no whitespace, ints only —
and is byte-identical to the rust emitter, which is what the golden
fixture (rust/tests/fixtures/quantspec_golden.json) asserts.

This module is deliberately pure standard library (no jax/numpy) so the
tier-1 ``plan-check`` step can run it directly:

    python3 python/compile/quant/spec.py check \
        rust/tests/fixtures/quantspec_golden.json
"""

from __future__ import annotations

import dataclasses
import json
import re
import sys

SCHEMA_VERSION = 1

ACTS = ("none", "mx8", "mx6", "int8", "int6")
ALGOS = ("none", "rtn", "gptq", "awq", "llmint4", "smoothquant", "clipq")
ACT_BITS = {"none": 16, "mx8": 8, "mx6": 6, "int8": 8, "int6": 6}

# Algorithms that operate on the INT grid (they take bits and, except
# llmint4, a group size) and therefore require an IntGroup weight
# format; plain rtn rounding works on any grid.
INT_ONLY_ALGOS = ("gptq", "awq", "smoothquant", "clipq", "llmint4")

# The low-rank factors default to the paper's b_h = 8 (8-bit MXINT,
# [16, 1] blocks, 4-bit shared exponent).
LOWRANK_DEFAULT_BITS = 8


class SpecError(ValueError):
    """A plan failed schema validation; the message is path-qualified."""


# ----------------------------------------------------------------------------
# Average-bits accounting — the single source of truth for "Avg. w bits"
# (Table 3).  rust/src/quant/spec.rs mirrors these formulas exactly.
# ----------------------------------------------------------------------------


def mxint_avg_bits(elem_bits: int, exp_bits: int, block: int) -> float:
    """Average bits per element of an MXINT tensor (shared exponent
    amortized over the block)."""
    return elem_bits + exp_bits / block


def int_group_avg_bits(bits: int, group: int, scale_bits: int = 16) -> float:
    """Average bits per element of group-quantized fixed point with an
    FP16 scale per group."""
    return bits + scale_bits / group


def lqer_avg_bits(m: int, n: int, k: int, w_bits_avg: float,
                  lowrank_bits_avg: float) -> float:
    """Average weight bits of an LQER layer: W_q plus the rank-k factors
    amortized over the m*n nominal weights (paper Appendix D)."""
    total = m * n * w_bits_avg + (m + n) * k * lowrank_bits_avg
    return total / (m * n)


# ----------------------------------------------------------------------------
# Weight formats
# ----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Mxint:
    """Block floating point: ``bits``-bit mantissas sharing an
    ``exp_bits``-bit exponent per ``block`` input features."""
    bits: int
    exp_bits: int = 4
    block: int = 16

    def avg_bits(self) -> float:
        return mxint_avg_bits(self.bits, self.exp_bits, self.block)

    def describe(self) -> str:
        return f"MXINT{self.bits}[e{self.exp_bits}/b{self.block}]"


@dataclasses.dataclass(frozen=True)
class IntGroup:
    """Fixed point with an FP16 scale per ``group`` input features;
    ``group == 0`` is vector-wise (one scale per input row)."""
    bits: int
    group: int = 128

    def avg_bits(self) -> float:
        # Vector-wise scales amortize over the whole row; 4096 is the
        # legacy accounting stand-in for "a full LLM row".
        return int_group_avg_bits(self.bits, self.group or 4096)

    def describe(self) -> str:
        g = f"g{self.group}" if self.group else "vec"
        return f"INT{self.bits} {g}"


@dataclasses.dataclass(frozen=True)
class Fp16:
    """Unquantized FP16 baseline weights."""

    def avg_bits(self) -> float:
        return 16.0

    def describe(self) -> str:
        return "FP16"


WeightFormat = Mxint | IntGroup | Fp16


@dataclasses.dataclass(frozen=True)
class LowRank:
    """LQER/L2QER error-reconstruction factors: rank ``k``, Appendix-A
    scaling when ``scaled``, stored at ``bits``-bit MXINT (None = fp32)."""
    k: int
    scaled: bool = False
    bits: int | None = LOWRANK_DEFAULT_BITS

    def avg_bits(self) -> float:
        if self.bits is None:
            return 32.0
        return mxint_avg_bits(self.bits, 4, 16)


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """How one linear layer is quantized."""
    weight: WeightFormat
    act: str = "none"
    algo: str = "rtn"
    lowrank: LowRank | None = None

    def avg_bits(self, m: int, n: int) -> float:
        """Plan-derived average weight bits of an (m, n) linear."""
        base = self.weight.avg_bits()
        if self.lowrank is None:
            return base
        return lqer_avg_bits(m, n, self.lowrank.k, base,
                             self.lowrank.avg_bits())

    def to_json_dict(self) -> dict:
        return {
            "weight": _weight_to_json(self.weight),
            "act": self.act,
            "algo": self.algo,
            "lowrank": None if self.lowrank is None else {
                "k": self.lowrank.k,
                "scaled": self.lowrank.scaled,
                "bits": self.lowrank.bits,
            },
        }

    def to_legacy_dict(self) -> dict:
        """The pre-QuantSpec method-spec shape (kept in run metadata so
        old readers keep working)."""
        if isinstance(self.weight, Fp16):
            weight: tuple = ("fp",)
        elif isinstance(self.weight, Mxint):
            weight = ("mxint", self.weight.bits)
        else:
            weight = ("int", self.weight.bits, self.weight.group)
        lowrank = None
        if self.lowrank is not None:
            lowrank = {"k": self.lowrank.k, "scaled": self.lowrank.scaled}
            if self.lowrank.bits != LOWRANK_DEFAULT_BITS:
                lowrank["bits"] = self.lowrank.bits
        return {"weight": list(weight), "act": self.act, "algo": self.algo,
                "lowrank": lowrank}


@dataclasses.dataclass(frozen=True)
class Override:
    """One per-layer-name override: full LayerSpec for matching layers."""
    match: str
    spec: LayerSpec


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """A complete quantization plan: default + ordered overrides."""
    default: LayerSpec
    overrides: tuple[Override, ...] = ()

    # -- resolution ---------------------------------------------------------

    def resolve(self, layer_name: str) -> LayerSpec:
        """First matching override wins; else the model-wide default."""
        for ov in self.overrides:
            if glob_match(ov.match, layer_name):
                return ov.spec
        return self.default

    def layer_specs(self):
        yield self.default
        for ov in self.overrides:
            yield ov.spec

    def max_rank(self) -> int:
        """Largest low-rank k any layer may use (the graph's pad rank)."""
        return max((ls.lowrank.k for ls in self.layer_specs()
                    if ls.lowrank is not None), default=0)

    def needs_calibration(self) -> bool:
        """True when quantizing consumes calibration stats: any algo
        beyond plain rounding, or an Appendix-A-scaled low-rank term."""
        return any(ls.algo not in ("none", "rtn")
                   or (ls.lowrank is not None and ls.lowrank.scaled)
                   for ls in self.layer_specs())

    def model_avg_bits(self, shapes: dict[str, tuple[int, int]]) -> float:
        """Plan-derived model average weight bits over named linears."""
        total_w = 0
        total_bits = 0.0
        for name, (m, n) in shapes.items():
            total_w += m * n
            total_bits += m * n * self.resolve(name).avg_bits(m, n)
        return total_bits / max(total_w, 1)

    # -- validation ---------------------------------------------------------

    def validate(self) -> "QuantSpec":
        _validate_layer(self.default, "plan.default")
        for i, ov in enumerate(self.overrides):
            path = f"plan.overrides[{i}]"
            if not ov.match:
                raise SpecError(f"{path}.match: must be a non-empty string")
            # Printable ASCII only: layer keys are ASCII, and this keeps
            # the canonical JSON byte-identical across the two emitters
            # (python escapes non-ASCII, the rust writer does not).
            if not ov.match.isascii() or any(ord(c) < 0x20
                                             for c in ov.match):
                raise SpecError(
                    f"{path}.match: must be printable ASCII")
            _validate_layer(ov.spec, f"{path}.spec")
            if ov.spec.act != self.default.act:
                raise SpecError(
                    f"{path}.spec.act: '{ov.spec.act}' differs from the "
                    f"default act '{self.default.act}' — the activation "
                    "mode is graph structure and must be uniform")
        return self

    # -- serialization ------------------------------------------------------

    def to_json_dict(self) -> dict:
        return {
            "version": SCHEMA_VERSION,
            "default": self.default.to_json_dict(),
            "overrides": [{"match": ov.match,
                           "spec": ov.spec.to_json_dict()}
                          for ov in self.overrides],
        }

    def to_json(self) -> str:
        """Canonical form: byte-identical to the rust emitter."""
        return json.dumps(self.to_json_dict(), separators=(",", ":"))

    @staticmethod
    def from_json_dict(obj, path: str = "plan") -> "QuantSpec":
        d = _obj(obj, path)
        _check_keys(d, ("version", "default", "overrides"), path)
        version = _int(_field(d, "version", path), f"{path}.version", 0)
        if version != SCHEMA_VERSION:
            raise SpecError(f"{path}.version: unsupported version "
                            f"{version} (expected {SCHEMA_VERSION})")
        default = _parse_layer(_field(d, "default", path), f"{path}.default")
        ov_list = d.get("overrides", [])
        if not isinstance(ov_list, list):
            raise SpecError(f"{path}.overrides: expected an array")
        overrides = []
        for i, ov in enumerate(ov_list):
            opath = f"{path}.overrides[{i}]"
            od = _obj(ov, opath)
            _check_keys(od, ("match", "spec"), opath)
            overrides.append(Override(
                match=_str(_field(od, "match", opath), f"{opath}.match"),
                spec=_parse_layer(_field(od, "spec", opath),
                                  f"{opath}.spec", base=default)))
        return QuantSpec(default=default,
                         overrides=tuple(overrides)).validate()

    @staticmethod
    def from_json(text: str) -> "QuantSpec":
        try:
            obj = json.loads(text)
        except json.JSONDecodeError as e:
            raise SpecError(f"plan: invalid JSON ({e})") from e
        return QuantSpec.from_json_dict(obj)

    @staticmethod
    def coerce(value) -> "QuantSpec":
        """Accept a QuantSpec, a legacy method-spec dict, a plan JSON
        dict, or a method-name string — the compatibility shim."""
        if isinstance(value, QuantSpec):
            return value
        if isinstance(value, str):
            return from_method_name(value)
        if isinstance(value, dict):
            if "version" in value or "default" in value:
                return QuantSpec.from_json_dict(value)
            return from_legacy_dict(value)
        raise SpecError(f"cannot build a QuantSpec from {type(value)!r}")


def draft_of(plan: QuantSpec) -> QuantSpec:
    """The self-speculative draft plan (DESIGN.md §13): the same
    quantized backbone with every low-rank error-reconstruction term
    clamped to ``null`` — default and overrides alike.  The draft
    shares W_q with the corrected model, so drafting streams only the
    backbone weights; the ``(m + n) * k`` low-rank traffic is paid once
    per *verify* pass instead of once per token.  Mirrored by
    ``quant::spec::draft_of`` in rust/src/quant/spec.rs."""
    default = dataclasses.replace(plan.default, lowrank=None)
    overrides = tuple(
        Override(ov.match, dataclasses.replace(ov.spec, lowrank=None))
        for ov in plan.overrides)
    return QuantSpec(default=default, overrides=overrides).validate()


# ----------------------------------------------------------------------------
# Pattern matching (mirrored in rust — keep trivially simple)
# ----------------------------------------------------------------------------


def glob_match(pattern: str, name: str) -> bool:
    """Literal match except '*' matches any (possibly empty) run."""
    pi = si = 0
    star = -1
    mark = 0
    while si < len(name):
        if pi < len(pattern) and pattern[pi] == "*":
            star = pi
            mark = si
            pi += 1
        elif pi < len(pattern) and pattern[pi] == name[si]:
            pi += 1
            si += 1
        elif star >= 0:
            pi = star + 1
            mark += 1
            si = mark
        else:
            return False
    while pi < len(pattern) and pattern[pi] == "*":
        pi += 1
    return pi == len(pattern)


# ----------------------------------------------------------------------------
# Strict parsing helpers (path-qualified errors)
# ----------------------------------------------------------------------------


def _obj(v, path: str) -> dict:
    if not isinstance(v, dict):
        raise SpecError(f"{path}: expected an object")
    return v


def _check_keys(d: dict, allowed: tuple, path: str) -> None:
    for k in d:
        if k not in allowed:
            raise SpecError(f"{path}: unknown key '{k}'")


def _field(d: dict, key: str, path: str):
    if key not in d:
        raise SpecError(f"{path}: missing key '{key}'")
    return d[key]


def _int(v, path: str, lo: int, hi: int | None = None) -> int:
    # Integral floats (4.0) are accepted to match the rust parser, whose
    # JSON numbers are all f64; canonical emitters only produce ints.
    if isinstance(v, float) and v.is_integer():
        v = int(v)
    if isinstance(v, bool) or not isinstance(v, int):
        raise SpecError(f"{path}: expected an integer")
    if v < lo or (hi is not None and v > hi):
        raise SpecError(f"{path}: {v} out of range "
                        f"[{lo}, {hi if hi is not None else 'inf'}]")
    return v


def _bool(v, path: str) -> bool:
    if not isinstance(v, bool):
        raise SpecError(f"{path}: expected a boolean")
    return v


def _str(v, path: str) -> str:
    if not isinstance(v, str):
        raise SpecError(f"{path}: expected a string")
    return v


def _weight_to_json(w: WeightFormat) -> dict:
    if isinstance(w, Fp16):
        return {"kind": "fp16"}
    if isinstance(w, Mxint):
        return {"kind": "mxint", "bits": w.bits, "exp_bits": w.exp_bits,
                "block": w.block}
    return {"kind": "int", "bits": w.bits, "group": w.group}


def _parse_weight(obj, path: str) -> WeightFormat:
    d = _obj(obj, path)
    kind = _str(_field(d, "kind", path), f"{path}.kind")
    if kind == "fp16":
        _check_keys(d, ("kind",), path)
        return Fp16()
    if kind == "mxint":
        _check_keys(d, ("kind", "bits", "exp_bits", "block"), path)
        return Mxint(
            bits=_int(_field(d, "bits", path), f"{path}.bits", 2, 8),
            exp_bits=_int(_field(d, "exp_bits", path),
                          f"{path}.exp_bits", 1, 8),
            block=_int(_field(d, "block", path), f"{path}.block", 1))
    if kind == "int":
        _check_keys(d, ("kind", "bits", "group"), path)
        return IntGroup(
            bits=_int(_field(d, "bits", path), f"{path}.bits", 2, 8),
            group=_int(_field(d, "group", path), f"{path}.group", 0))
    raise SpecError(f"{path}.kind: unknown weight format '{kind}'")


def _parse_layer(obj, path: str,
                 base: LayerSpec | None = None) -> LayerSpec:
    """Parse a LayerSpec.  With ``base`` (override specs), keys may be
    omitted and inherit from the plan default — so an override of
    ``{"lowrank": null}`` alone cleanly strips the low-rank term of the
    matching layers (the draft-plan idiom, DESIGN.md §13).  The default
    spec (``base is None``) must be complete.  Canonical emission is
    always the full form, so partial input round-trips semantically,
    not byte-identically."""
    d = _obj(obj, path)
    _check_keys(d, ("weight", "act", "algo", "lowrank"), path)

    def _base_or(key: str) -> LayerSpec:
        if base is None:
            raise SpecError(f"{path}: missing key '{key}'")
        return base

    if "act" in d:
        act = _str(d["act"], f"{path}.act")
        if act not in ACTS:
            raise SpecError(f"{path}.act: unknown activation mode '{act}'")
    else:
        act = _base_or("act").act
    if "algo" in d:
        algo = _str(d["algo"], f"{path}.algo")
        if algo not in ALGOS:
            raise SpecError(f"{path}.algo: unknown algorithm '{algo}'")
    else:
        algo = _base_or("algo").algo
    if "lowrank" in d:
        lowrank = None
        lr = d["lowrank"]
        if lr is not None:
            lpath = f"{path}.lowrank"
            ld = _obj(lr, lpath)
            _check_keys(ld, ("k", "scaled", "bits"), lpath)
            bits = _field(ld, "bits", lpath)
            lowrank = LowRank(
                k=_int(_field(ld, "k", lpath), f"{lpath}.k", 1),
                scaled=_bool(_field(ld, "scaled", lpath),
                             f"{lpath}.scaled"),
                bits=None if bits is None
                else _int(bits, f"{lpath}.bits", 2, 8))
    else:
        lowrank = _base_or("lowrank").lowrank
    if "weight" in d:
        weight = _parse_weight(d["weight"], f"{path}.weight")
    else:
        weight = _base_or("weight").weight
    return LayerSpec(weight=weight, act=act, algo=algo, lowrank=lowrank)


def _validate_layer(ls: LayerSpec, path: str) -> None:
    if ls.algo in INT_ONLY_ALGOS and not isinstance(ls.weight, IntGroup):
        raise SpecError(
            f"{path}: algo '{ls.algo}' requires an int weight format, "
            f"got '{ls.weight.describe()}'")
    if ls.lowrank is not None:
        if ls.lowrank.k < 1:
            raise SpecError(f"{path}.lowrank.k: must be >= 1")
        if ls.lowrank.bits is not None and not 2 <= ls.lowrank.bits <= 8:
            raise SpecError(f"{path}.lowrank.bits: "
                            f"{ls.lowrank.bits} out of range [2, 8]")


# ----------------------------------------------------------------------------
# Legacy compatibility shims
# ----------------------------------------------------------------------------


def weight_from_legacy(weight_spec) -> WeightFormat:
    """('fp',) | ('mxint', bits) | ('int', bits, group) -> WeightFormat."""
    if isinstance(weight_spec, (Mxint, IntGroup, Fp16)):
        return weight_spec
    kind = weight_spec[0]
    if kind == "fp":
        return Fp16()
    if kind == "mxint":
        return Mxint(bits=weight_spec[1])
    if kind == "int":
        return IntGroup(bits=weight_spec[1], group=weight_spec[2])
    raise SpecError(f"unknown legacy weight spec {weight_spec!r}")


def from_legacy_dict(d: dict) -> QuantSpec:
    """The pre-QuantSpec method-spec dict -> a single-default plan."""
    known = {"weight", "act", "algo", "lowrank"}
    unknown = set(d) - known
    if unknown:
        raise SpecError(f"legacy spec: unknown key(s) {sorted(unknown)}")
    lowrank = None
    if d.get("lowrank"):
        lr = d["lowrank"]
        lowrank = LowRank(k=lr["k"], scaled=bool(lr.get("scaled", False)),
                          bits=lr.get("bits", LOWRANK_DEFAULT_BITS))
    return QuantSpec(default=LayerSpec(
        weight=weight_from_legacy(tuple(d["weight"])),
        act=d.get("act", "none"), algo=d.get("algo", "rtn"),
        lowrank=lowrank)).validate()


# ----------------------------------------------------------------------------
# The method registry (the paper's Table 3/4/6 configurations), expressed
# as QuantSpec constructors.  Names are the legacy string contract; the
# rust shim (QuantSpec::from_method_name) mirrors this table exactly.
# ----------------------------------------------------------------------------


def _plan(weight: WeightFormat, act: str, algo: str,
          lowrank: LowRank | None = None) -> QuantSpec:
    return QuantSpec(default=LayerSpec(weight=weight, act=act, algo=algo,
                                       lowrank=lowrank)).validate()


METHODS: dict[str, QuantSpec] = {
    "fp16": _plan(Fp16(), "none", "none"),
    # Table 2: plain MXINT vs LQER vs L2QER (W4A8)
    "mxint-w4a8": _plan(Mxint(4), "mx8", "rtn"),
    "lqer-w4a8": _plan(Mxint(4), "mx8", "rtn", LowRank(16)),
    "l2qer-w4a8": _plan(Mxint(4), "mx8", "rtn", LowRank(16, scaled=True)),
    # Table 3 w&a: MXINT W4A6
    "l2qer-w4a6": _plan(Mxint(4), "mx6", "rtn", LowRank(16, scaled=True)),
    # Table 3 w-only: L2QER-INT (INT4 g128 weights, FP16 acts)
    "l2qer-int-w4": _plan(IntGroup(4, 128), "none", "rtn",
                          LowRank(16, scaled=True)),
    # Table 3 w&a: L2QER-INT W4A8 g128
    "l2qer-int-w4a8": _plan(IntGroup(4, 128), "int8", "rtn",
                            LowRank(16, scaled=True)),
    # w-only baselines
    "gptq-w4": _plan(IntGroup(4, 128), "none", "gptq"),
    "awq-w4": _plan(IntGroup(4, 128), "none", "awq"),
    "rtn-w4": _plan(IntGroup(4, 128), "none", "rtn"),
    # w&a baselines
    "llmint4": _plan(IntGroup(4, 0), "int8", "llmint4"),
    "smoothquant-w8a8": _plan(IntGroup(8, 128), "int8", "smoothquant"),
    "clipq-w6a6": _plan(IntGroup(6, 128), "int6", "clipq"),
    # 2-bit setup (Table 6 / Table 10)
    "awq-w2": _plan(IntGroup(2, 128), "none", "awq"),
    "clipq-w2": _plan(IntGroup(2, 128), "none", "clipq"),
    "l2qer-w2a8": _plan(Mxint(2), "mx8", "rtn", LowRank(64, scaled=True)),
    # Difficulty-matched Table-2 trio: at toy scale W4 is already lossless
    # (EXPERIMENTS.md), so the paper's W4-on-7B regime maps to W2 here.
    "mxint-w2a8": _plan(Mxint(2), "mx8", "rtn"),
    "lqer-w2a8": _plan(Mxint(2), "mx8", "rtn", LowRank(64)),
    # Figure 3 rank-sweep baseline (W3, kept for the spectra figure).
    "mxint-w3a8": _plan(Mxint(3), "mx8", "rtn"),
    # Ablation: precision of the low-rank factors (paper stores them at
    # b_h = 8; what do 4-bit or unquantized factors change?).
    "l2qer-w2a8-lr4": _plan(Mxint(2), "mx8", "rtn",
                            LowRank(64, scaled=True, bits=4)),
    "l2qer-w2a8-lrfp": _plan(Mxint(2), "mx8", "rtn",
                             LowRank(64, scaled=True, bits=None)),
    # Ablation: LQER rank at fixed budget (k=16 vs 64 on W2).
    "l2qer-w2a8-rank16": _plan(Mxint(2), "mx8", "rtn",
                               LowRank(16, scaled=True)),
}

_SWEEP_RE = re.compile(r"^(lqer|l2qer)-w2a8-k(\d+)$")


def rank_sweep_spec(k: int, scaled: bool, w_bits: int = 2) -> QuantSpec:
    """Plan for the Figure-3 perplexity-vs-rank sweep."""
    return _plan(Mxint(w_bits), "mx8", "rtn", LowRank(k, scaled=scaled))


def from_method_name(name: str) -> QuantSpec:
    """Resolve a legacy method-name string to its plan."""
    if name in METHODS:
        return METHODS[name]
    m = _SWEEP_RE.match(name)
    if m and int(m.group(2)) >= 1:
        return rank_sweep_spec(int(m.group(2)), scaled=m.group(1) == "l2qer")
    raise SpecError(f"unknown method name '{name}'")


# ----------------------------------------------------------------------------
# Model layer shapes (mirrors model.LINEAR_NAMES without importing jax)
# ----------------------------------------------------------------------------


def layer_shapes(d: int, ffn: int, layers: int) -> dict[str, tuple[int, int]]:
    """(in, out) shape of every linear key ``layers.{i}.{name}``."""
    dims = {"wq": (d, d), "wk": (d, d), "wv": (d, d), "wo": (d, d),
            "fc1": (d, ffn), "fc2": (ffn, d)}
    return {f"layers.{li}.{name}": shape
            for li in range(layers) for name, shape in dims.items()}


# ----------------------------------------------------------------------------
# Golden fixture: serialized by python, parsed by rust (and vice versa).
# ----------------------------------------------------------------------------

GOLDEN_DIMS = {"d": 64, "ffn": 256, "layers": 2}


def heterogeneous_example() -> QuantSpec:
    """The acceptance-criteria plan: rank k=32 on FFN linears, k=8
    elsewhere, INT4 g128 on the output projection, MXINT4 default."""
    base = LayerSpec(weight=Mxint(4), act="mx8", algo="rtn",
                     lowrank=LowRank(8, scaled=True))
    ffn = dataclasses.replace(base, lowrank=LowRank(32, scaled=True))
    wo = dataclasses.replace(base, weight=IntGroup(4, 128),
                             lowrank=LowRank(8, scaled=True))
    return QuantSpec(default=base, overrides=(
        Override("layers.*.fc1", ffn),
        Override("layers.*.fc2", ffn),
        Override("layers.*.wo", wo),
    )).validate()


GOLDEN_CASES = ["fp16", "mxint-w4a8", "l2qer-w4a8", "l2qer-int-w4a8",
                "llmint4", "l2qer-w2a8-lrfp", "lqer-w2a8", "l2qer-w2a8-k4"]

GOLDEN_REJECTS = [
    ("top-level-unknown-key",
     '{"version":1,"default":{"weight":{"kind":"fp16"},"act":"none",'
     '"algo":"none","lowrank":null},"overrides":[],"extra":1}'),
    ("bad-version",
     '{"version":2,"default":{"weight":{"kind":"fp16"},"act":"none",'
     '"algo":"none","lowrank":null},"overrides":[]}'),
    ("unknown-weight-kind",
     '{"version":1,"default":{"weight":{"kind":"fp8"},"act":"none",'
     '"algo":"none","lowrank":null},"overrides":[]}'),
    ("unknown-weight-key",
     '{"version":1,"default":{"weight":{"kind":"mxint","bits":4,'
     '"exp_bits":4,"block":16,"zero_point":true},"act":"mx8",'
     '"algo":"rtn","lowrank":null},"overrides":[]}'),
    ("unknown-act",
     '{"version":1,"default":{"weight":{"kind":"mxint","bits":4,'
     '"exp_bits":4,"block":16},"act":"fp8","algo":"rtn",'
     '"lowrank":null},"overrides":[]}'),
    ("unknown-algo",
     '{"version":1,"default":{"weight":{"kind":"mxint","bits":4,'
     '"exp_bits":4,"block":16},"act":"mx8","algo":"magic",'
     '"lowrank":null},"overrides":[]}'),
    ("lowrank-zero-rank",
     '{"version":1,"default":{"weight":{"kind":"mxint","bits":4,'
     '"exp_bits":4,"block":16},"act":"mx8","algo":"rtn",'
     '"lowrank":{"k":0,"scaled":true,"bits":8}},"overrides":[]}'),
    ("lowrank-unknown-key",
     '{"version":1,"default":{"weight":{"kind":"mxint","bits":4,'
     '"exp_bits":4,"block":16},"act":"mx8","algo":"rtn",'
     '"lowrank":{"k":16,"scaled":true,"bits":8,"rank_pad":32}},'
     '"overrides":[]}'),
    ("weight-bits-out-of-range",
     '{"version":1,"default":{"weight":{"kind":"mxint","bits":12,'
     '"exp_bits":4,"block":16},"act":"mx8","algo":"rtn",'
     '"lowrank":null},"overrides":[]}'),
    ("override-mixed-act",
     '{"version":1,"default":{"weight":{"kind":"mxint","bits":4,'
     '"exp_bits":4,"block":16},"act":"mx8","algo":"rtn","lowrank":null},'
     '"overrides":[{"match":"layers.*.fc1","spec":{"weight":'
     '{"kind":"mxint","bits":4,"exp_bits":4,"block":16},"act":"int8",'
     '"algo":"rtn","lowrank":null}}]}'),
    ("missing-default",
     '{"version":1,"overrides":[]}'),
    ("int-algo-on-mxint-weight",
     '{"version":1,"default":{"weight":{"kind":"mxint","bits":4,'
     '"exp_bits":4,"block":16},"act":"none","algo":"gptq",'
     '"lowrank":null},"overrides":[]}'),
]


def build_golden() -> dict:
    """The cross-language fixture checked in at
    rust/tests/fixtures/quantspec_golden.json."""
    shapes = layer_shapes(**GOLDEN_DIMS)
    cases = []
    named = [(name, from_method_name(name), True) for name in GOLDEN_CASES]
    named.append(("het-ffn-rank", heterogeneous_example(), False))
    for name, plan, is_method in named:
        cases.append({
            "name": name,
            "method": is_method,
            "canonical": plan.to_json(),
            "model_avg_bits": plan.model_avg_bits(shapes),
            "layer_bits": {key: plan.resolve(key).avg_bits(m, n)
                           for key, (m, n) in shapes.items()},
        })
    methods = {name: from_method_name(name).to_json()
               for name in sorted(METHODS)}
    return {
        "dims": GOLDEN_DIMS,
        "cases": cases,
        "methods": methods,
        "rejects": [{"name": n, "json": j} for n, j in GOLDEN_REJECTS],
    }


def check_golden(path: str) -> int:
    """Validate a golden fixture against this implementation (the
    tier-1 ``plan-check`` step).  Returns a process exit code."""
    with open(path) as fh:
        fixture = json.load(fh)
    dims = fixture["dims"]
    shapes = layer_shapes(d=dims["d"], ffn=dims["ffn"],
                          layers=dims["layers"])
    errors = []
    for case in fixture["cases"]:
        name = case["name"]
        try:
            plan = QuantSpec.from_json(case["canonical"])
        except SpecError as e:
            errors.append(f"{name}: failed to parse: {e}")
            continue
        if plan.to_json() != case["canonical"]:
            errors.append(f"{name}: canonical serialization drifted")
        if case["method"]:
            if from_method_name(name) != plan:
                errors.append(f"{name}: method-name shim disagrees")
        got = plan.model_avg_bits(shapes)
        if abs(got - case["model_avg_bits"]) > 1e-9:
            errors.append(f"{name}: model_avg_bits {got} != "
                          f"{case['model_avg_bits']}")
        for key, want in case["layer_bits"].items():
            m, n = shapes[key]
            got = plan.resolve(key).avg_bits(m, n)
            if abs(got - want) > 1e-9:
                errors.append(f"{name}/{key}: layer bits {got} != {want}")
    for name, canonical in fixture["methods"].items():
        try:
            if from_method_name(name).to_json() != canonical:
                errors.append(f"methods/{name}: shim serialization drifted")
        except SpecError as e:
            errors.append(f"methods/{name}: {e}")
    for rej in fixture["rejects"]:
        try:
            QuantSpec.from_json(rej["json"])
            errors.append(f"rejects/{rej['name']}: parsed but must fail")
        except SpecError:
            pass
    if errors:
        for e in errors:
            print(f"[plan-check] FAIL {e}", file=sys.stderr)
        return 1
    print(f"[plan-check] ok: {len(fixture['cases'])} plans, "
          f"{len(fixture['methods'])} methods, "
          f"{len(fixture['rejects'])} rejects")
    return 0


def main(argv: list[str]) -> int:
    if len(argv) >= 2 and argv[0] == "check":
        return check_golden(argv[1])
    if len(argv) >= 2 and argv[0] == "emit-golden":
        with open(argv[1], "w") as fh:
            json.dump(build_golden(), fh, indent=1)
            fh.write("\n")
        print(f"wrote {argv[1]}")
        return 0
    print("usage: spec.py check <fixture.json> | emit-golden <out.json>",
          file=sys.stderr)
    return 2


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
