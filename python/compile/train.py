"""Build-time trainer for the synthetic model family.

Hand-rolled AdamW (no optax in this image) + cosine LR schedule + gradient
clipping.  Trains each family member on the TinyPajama corpus until it is
genuinely predictive (val PPL well under the unigram baseline), then
checkpoints to ``artifacts/models/<name>/params.npz``.  Quantization acts
on these *trained* weights -- the singular-value structure of E_q that
drives LQER only exists for real weight/activation statistics.
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M


def cross_entropy(params, tokens, cfg: M.ModelConfig):
    """Next-token CE over (B, T) batches, ignoring PAD targets."""
    logits = M.train_forward(params, tokens[:, :-1], cfg)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    mask = (targets != 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def _tree_zeros_like(t):
    return jax.tree_util.tree_map(jnp.zeros_like, t)


def make_update_step(cfg: M.ModelConfig, base_lr: float, total_steps: int,
                     weight_decay: float = 0.01, clip: float = 1.0):
    """One jitted AdamW step: (params, m, v, step, batch) -> updated."""

    def step_fn(params, m, v, step, batch):
        loss, grads = jax.value_and_grad(cross_entropy)(params, batch, cfg)
        # global-norm clip
        leaves = jax.tree_util.tree_leaves(grads)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in leaves))
        scale = jnp.minimum(1.0, clip / (gnorm + 1e-9))
        grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
        # cosine schedule with 40-step warmup
        warm = jnp.minimum(step / 40.0, 1.0)
        prog = jnp.clip(step / total_steps, 0.0, 1.0)
        lr = base_lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        b1, b2, eps = 0.9, 0.95, 1e-8
        m = jax.tree_util.tree_map(lambda a, g: b1 * a + (1 - b1) * g,
                                   m, grads)
        v = jax.tree_util.tree_map(lambda a, g: b2 * a + (1 - b2) * g * g,
                                   v, grads)
        t = step + 1.0
        mhat = jax.tree_util.tree_map(lambda a: a / (1 - b1 ** t), m)
        vhat = jax.tree_util.tree_map(lambda a: a / (1 - b2 ** t), v)
        params = jax.tree_util.tree_map(
            lambda p, a, b: p - lr * (a / (jnp.sqrt(b) + eps)
                                      + weight_decay * p),
            params, mhat, vhat)
        return params, m, v, loss, gnorm

    return jax.jit(step_fn)


def batches(stream: np.ndarray, batch: int, seq: int, seed: int):
    """Random crops from the token stream, forever."""
    rng = np.random.default_rng(seed)
    n = len(stream) - seq - 1
    while True:
        idx = rng.integers(0, n, size=batch)
        yield np.stack([stream[i:i + seq + 1] for i in idx]).astype(np.int32)


def eval_ppl(params, stream: np.ndarray, cfg: M.ModelConfig,
             batch: int = 8, seq: int = 96, n_batches: int = 16) -> float:
    """Val perplexity on contiguous windows (mirrors the rust evaluator)."""
    fn = jax.jit(lambda p, t: cross_entropy(p, t, cfg))
    losses = []
    for i in range(n_batches):
        start = i * batch * seq
        rows = []
        for b in range(batch):
            s = start + b * seq
            if s + seq + 1 > len(stream):
                break
            rows.append(stream[s:s + seq + 1])
        if len(rows) < batch:
            break
        losses.append(float(fn(params, np.stack(rows).astype(np.int32))))
    return float(np.exp(np.mean(losses)))


def train_model(cfg: M.ModelConfig, train_stream: np.ndarray,
                val_stream: np.ndarray, out_dir: str,
                steps: int = 600, batch: int = 16, seq: int = 96,
                lr: float = 3e-3, seed: int = 0,
                log_every: int = 50) -> dict:
    """Train one model; returns params. Caches to out_dir/params.npz."""
    ckpt = os.path.join(out_dir, "params.npz")
    if os.path.exists(ckpt):
        return load_params(out_dir, cfg)

    os.makedirs(out_dir, exist_ok=True)
    params = M.init_params(cfg, seed=seed)
    params = jax.tree_util.tree_map(jnp.asarray, params)
    m = _tree_zeros_like(params)
    v = _tree_zeros_like(params)
    update = make_update_step(cfg, lr, steps)
    gen = batches(train_stream, batch, seq, seed + 1)
    log = []
    t0 = time.time()
    for step in range(steps):
        bt = next(gen)
        params, m, v, loss, gnorm = update(params, m, v, float(step), bt)
        if step % log_every == 0 or step == steps - 1:
            entry = {"step": step, "loss": float(loss),
                     "gnorm": float(gnorm), "sec": time.time() - t0}
            log.append(entry)
            print(f"[train {cfg.name}] step {step:4d} "
                  f"loss {float(loss):.4f} ({entry['sec']:.0f}s)",
                  flush=True)
    ppl = eval_ppl(params, val_stream, cfg)
    print(f"[train {cfg.name}] val ppl {ppl:.3f}")

    save_params(params, out_dir)
    with open(os.path.join(out_dir, "train_log.json"), "w") as fh:
        json.dump({"log": log, "val_ppl": ppl,
                   "params": cfg.param_count()}, fh, indent=1)
    return jax.tree_util.tree_map(np.asarray, params)


def save_params(params, out_dir: str) -> None:
    flat = M.flatten_with_names(params)
    np.savez(os.path.join(out_dir, "params.npz"),
             **{name: arr for name, arr in flat})


def load_params(out_dir: str, cfg: M.ModelConfig) -> dict:
    """Rebuild the param tree from the flat npz checkpoint."""
    data = np.load(os.path.join(out_dir, "params.npz"))
    skeleton = M.init_params(cfg, seed=0)
    flat_names = [n for n, _ in M.flatten_with_names(skeleton)]
    leaves = [np.asarray(data[n], np.float32) for n in flat_names]
    treedef = jax.tree_util.tree_structure(skeleton)
    return jax.tree_util.tree_unflatten(treedef, leaves)
