import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np
import pytest

from compile import data as D, model as M


@pytest.fixture(scope="session")
def dataset():
    """Small dataset shared across the test session."""
    return D.build_dataset(train_tokens=30_000, val_tokens=4_096,
                           test_tokens=4_096, n_per_task=8, n_judge=4)


@pytest.fixture(scope="session")
def tiny_cfg(dataset):
    return M.make_config("opt-tiny", vocab=dataset.vocab.size)


@pytest.fixture(scope="session")
def tiny_params(tiny_cfg):
    return M.init_params(tiny_cfg, seed=3)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
