"""AOT export path: LQTW weight files and HLO-text lowering."""

import json
import os
import struct

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M


def test_lqtw_roundtrip(tmp_path):
    params = {"embed": np.arange(6, dtype=np.float32).reshape(2, 3),
              "layers": [{"wq": {"w": np.ones((4, 2), np.float32) * 2}}]}
    path = tmp_path / "w.bin"
    aot.write_lqtw(str(path), params, {"model": "x"})
    raw = path.read_bytes()
    assert raw[:8] == b"LQTW0001"
    (mlen,) = struct.unpack("<I", raw[8:12])
    manifest = json.loads(raw[12:12 + mlen])
    assert manifest["meta"]["model"] == "x"
    names = [t["name"] for t in manifest["tensors"]]
    assert names == ["embed", "layers.0.wq.w"]
    data_start = ((12 + mlen) + 63) // 64 * 64
    first = np.frombuffer(raw[data_start:data_start + 24], np.float32)
    np.testing.assert_array_equal(first,
                                  np.arange(6, dtype=np.float32))


def test_hlo_text_lowering_smoke(dataset):
    cfg = M.ModelConfig(name="t", vocab=dataset.vocab.size, d=32,
                        layers=1, heads=2, ffn=64, t_max=16)
    params = M.init_params(cfg)
    gv = M.GraphVariant(act="mx8", rank=4)
    vp = M.attach_variant_params(params, cfg, gv)
    text = aot.lower_graph(
        lambda p, t: (M.score(p, t, cfg, gv),),
        M.param_specs(vp), jax.ShapeDtypeStruct((1, 8), jnp.int32))
    assert "HloModule" in text
    assert "f32[1,8,%d]" % cfg.vocab in text


def test_rank_pad_rules():
    import compile.pipeline as pipeline
    assert aot._rank_pad_for("l2qer-w4a8",
                             pipeline.METHODS["l2qer-w4a8"]) == 16
    assert aot._rank_pad_for("fp16", pipeline.METHODS["fp16"]) == 0
    assert aot._rank_pad_for(
        "l2qer-w2a8-k4", pipeline.rank_sweep_spec(4, True)) == max(
            aot.FIG3_RANKS)
    assert aot._rank_pad_for("l2qer-w2a8",
                             pipeline.METHODS["l2qer-w2a8"]) == 64


def test_method_runs_cover_grid():
    runs = aot._method_runs(["opt-tiny", "opt-micro"])
    names = {(m, r) for m, r, _ in runs}
    assert ("opt-tiny", "fp16") in names
    assert ("opt-micro", "l2qer-w4a8") in names
    # sweep only on the fig-3 model
    assert ("opt-micro", "l2qer-w2a8-k1") in names
    assert ("opt-tiny", "l2qer-w2a8-k1") not in names


@pytest.mark.skipif(not os.path.exists(
    os.path.join(os.path.dirname(__file__), "..", "..", "artifacts",
                 "manifest.json")),
    reason="full artifacts not built")
def test_built_manifest_consistent():
    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    with open(os.path.join(root, "manifest.json")) as fh:
        m = json.load(fh)
    for run in m["runs"]:
        assert os.path.exists(run["weights"]), run["weights"]
        assert os.path.exists(run["meta"]), run["meta"]
        # every run's graph must have a lowered score HLO
        tags = {(g["model"], g["graph"], g["entry"]) for g in m["graphs"]}
        assert (run["model"], run["graph"], "score") in tags
    for g in m["graphs"]:
        assert os.path.exists(g["path"]), g["path"]
