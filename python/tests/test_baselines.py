"""Baseline PTQ algorithms: GPTQ, AWQ, LLM.int4(), SmoothQuant, clipq."""

import numpy as np
import pytest

from compile.baselines import awq, clipq, gptq, llm_int4, rtn, smoothquant


@pytest.fixture
def layer():
    """A weight + correlated calibration activations."""
    rng = np.random.default_rng(0)
    m, n, t = 128, 64, 256
    w = rng.normal(0, 0.3, size=(m, n)).astype(np.float32)
    # correlated activations with a few dominant channels
    base = rng.normal(size=(t, m)).astype(np.float32)
    boost = np.ones(m, np.float32)
    boost[:6] = 8.0
    x = base * boost
    h = (x.astype(np.float64).T @ x.astype(np.float64))
    return w, x, h


def _out_err(w, w_eff, x):
    y = x.astype(np.float64) @ w.astype(np.float64)
    yq = x.astype(np.float64) @ w_eff.astype(np.float64)
    return np.linalg.norm(y - yq)


def test_gptq_beats_rtn_on_output_error(layer):
    w, x, h = layer
    w_rtn = rtn.quantize_int(w, bits=3)["w"]
    w_gptq = gptq.quantize(w, h, bits=3)["w"]
    assert _out_err(w, w_gptq, x) < _out_err(w, w_rtn, x)


def test_gptq_stays_on_grid_shape(layer):
    w, _, h = layer
    q = gptq.quantize(w, h, bits=4)["w"]
    assert q.shape == w.shape
    assert np.isfinite(q).all()


def test_awq_not_worse_than_rtn(layer):
    w, x, _ = layer
    a_max = np.abs(x).max(0)
    w_rtn = rtn.quantize_int(w, bits=3)["w"]
    res = awq.quantize(w, a_max, x, bits=3)
    # alpha=0 is RTN, so grid search can never be worse on calib data
    assert _out_err(w, res["w"], x) <= _out_err(w, w_rtn, x) * (1 + 1e-9)
    assert 0.0 <= res["alpha"] <= 1.0


def test_llmint4_preserves_outlier_rows(layer):
    w, x, _ = layer
    a_max = np.abs(x).max(0)
    res = llm_int4.quantize(w, a_max, bits=4, outlier_frac=0.05)
    outliers = np.argsort(a_max)[::-1][:res["n_outliers"]]
    # outlier-feature rows are bit-exact FP
    np.testing.assert_array_equal(res["w"][outliers], w[outliers])
    # mask marks exactly those channels as high-precision (0)
    assert res["actmask"][outliers].sum() == 0
    assert res["actmask"].sum() == w.shape[0] - res["n_outliers"]


def test_smoothquant_shrinks_activation_range(layer):
    w, x, _ = layer
    a_max = np.abs(x).max(0)
    res = smoothquant.quantize(w, a_max, bits=8)
    x_s = x / res["smooth"]
    assert np.abs(x_s).max() < np.abs(x).max()


def test_smoothquant_product_preserved_before_quant(layer):
    w, x, _ = layer
    a_max = np.abs(x).max(0)
    s = smoothquant.quantize(w, a_max, bits=16)["smooth"]
    # (x / s) @ (w * s) == x @ w up to float error (16-bit grid ~ exact-ish)
    y = x @ w
    ys = (x / s) @ (w * s[:, None])
    np.testing.assert_allclose(y, ys, rtol=1e-3, atol=1e-3)


def test_clipq_picks_clipping_when_outliers_hurt():
    rng = np.random.default_rng(1)
    w = rng.normal(0, 0.1, size=(128, 32)).astype(np.float32)
    w[0, :] = 5.0  # weight outlier stretches the group scale
    x = rng.normal(size=(64, 128)).astype(np.float32)
    res = clipq.quantize(w, x, bits=3)
    assert res["ratio"] <= 1.0
    assert np.isfinite(res["w"]).all()


def test_rtn_mxint_and_int_shapes():
    rng = np.random.default_rng(2)
    w = rng.normal(size=(64, 32)).astype(np.float32)
    assert rtn.quantize_mxint(w, 4)["w"].shape == w.shape
    assert rtn.quantize_int(w, 4)["w"].shape == w.shape
