"""Chunked prefill (DESIGN.md §12): streaming a prompt through
``prefill_chunk`` slices — each computing its prefix at the slice's own
bucket and scattering only its blocks — must reproduce the monolithic
``prefill`` + ``kv_write_prefill_paged`` pool and final-row logits."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

BS = 8      # block rows used by these tests (aot uses PAGED_BLOCK_SIZE)
SENT = 0    # sentinel block id


@pytest.fixture(scope="module")
def setup():
    cfg = M.ModelConfig(name="t", vocab=64, d=32, layers=2, heads=2,
                        ffn=64, t_max=24)
    params = M.init_params(cfg, seed=1)
    return cfg, params


def pad(toks, t):
    out = np.zeros((1, t), np.int32)
    out[0, :len(toks)] = toks
    return out


def test_prefill_chunk_stream_matches_monolithic(setup):
    cfg, params = setup
    gv = M.GraphVariant(act="none", rank=0)
    rng = np.random.default_rng(11)
    nb, plen = 8, 20
    prompt = rng.integers(4, cfg.vocab, size=plen).astype(np.int32)
    blocks = [3, 1, 5]  # deliberately out-of-order physical blocks

    kc0 = rng.normal(size=(cfg.layers, nb, BS, cfg.d)).astype(np.float32)
    vc0 = rng.normal(size=(cfg.layers, nb, BS, cfg.d)).astype(np.float32)

    # Monolithic reference: one bucket-24 prefill scattered whole.
    ref_logits, k_pre, v_pre = M.prefill(params, pad(prompt, 24), cfg, gv)
    kc_ref, vc_ref = M.kv_write_prefill_paged(
        jnp.asarray(kc0), jnp.asarray(vc0), k_pre, v_pre,
        np.array(blocks, np.int32))

    # Chunked: rows [0,8) at bucket 8, [8,16) at bucket 16, [16,20) at
    # bucket 24 — already-installed chunks park in the sentinel, exactly
    # as the engine masks them.
    kc, vc = jnp.asarray(kc0), jnp.asarray(vc0)
    logits = None
    for end, bucket, ids in [
        (8, 8, [blocks[0]]),
        (16, 16, [SENT, blocks[1]]),
        (20, 24, [SENT, SENT, blocks[2]]),
    ]:
        logits, kc, vc = M.prefill_chunk(
            params, pad(prompt[:end], bucket), kc, vc,
            np.array(ids, np.int32), cfg, gv)

    # The final chunk runs the same bucket as the monolithic prefill, so
    # the sampled row is bit-identical.
    np.testing.assert_array_equal(
        np.asarray(logits)[0, plen - 1],
        np.asarray(ref_logits)[0, plen - 1])
    # The prompt's blocks hold the monolithic rows (causal prefill: a
    # position's K/V is independent of right-padding, so each chunk's
    # bucket reproduces the same rows).
    np.testing.assert_array_equal(np.asarray(kc)[:, blocks],
                                  np.asarray(kc_ref)[:, blocks])
    np.testing.assert_array_equal(np.asarray(vc)[:, blocks],
                                  np.asarray(vc_ref)[:, blocks])
    # Blocks no chunk listed (beyond the sentinel scribble pad) are
    # untouched.
    others = [b for b in range(1, nb) if b not in blocks]
    np.testing.assert_array_equal(np.asarray(kc)[:, others],
                                  kc0[:, others])


def test_prefill_chunk_sentinel_masks_earlier_chunks(setup):
    """A re-scatter with all-sentinel ids must leave every non-sentinel
    block untouched — the contract that lets the engine re-drive a
    prefix without re-touching finalized blocks."""
    cfg, params = setup
    gv = M.GraphVariant(act="none", rank=0)
    rng = np.random.default_rng(3)
    nb = 5
    prompt = rng.integers(4, cfg.vocab, size=2 * BS).astype(np.int32)
    kc0 = rng.normal(size=(cfg.layers, nb, BS, cfg.d)).astype(np.float32)
    vc0 = rng.normal(size=(cfg.layers, nb, BS, cfg.d)).astype(np.float32)
    _, kc, vc = M.prefill_chunk(
        params, pad(prompt, 2 * BS), jnp.asarray(kc0), jnp.asarray(vc0),
        np.array([SENT, SENT], np.int32), cfg, gv)
    np.testing.assert_array_equal(np.asarray(kc)[:, 1:], kc0[:, 1:])
    np.testing.assert_array_equal(np.asarray(vc)[:, 1:], vc0[:, 1:])
