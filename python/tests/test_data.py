"""TinyPajama corpus + downstream task generators."""

import numpy as np

from compile import data as D


def test_vocab_deterministic_and_unique():
    v1 = D.build_vocab()
    v2 = D.build_vocab()
    assert v1.words == v2.words
    assert len(set(v1.words)) == len(v1.words)
    assert v1.words[D.PAD] == "<pad>"


def test_vocab_encode_decode_roundtrip():
    v = D.build_vocab()
    text = v.decode([v.func["the"], int(v.nouns[0]), int(v.verbs[0])])
    assert v.encode(text) == [v.func["the"], int(v.nouns[0]),
                              int(v.verbs[0])]


def test_corpus_deterministic(dataset):
    v = dataset.vocab
    g = D.Grammar(v)
    s1 = D.CorpusGen(v, g, seed=5).stream(1000)
    s2 = D.CorpusGen(v, g, seed=5).stream(1000)
    np.testing.assert_array_equal(s1, s2)
    s3 = D.CorpusGen(v, g, seed=6).stream(1000)
    assert not np.array_equal(s1, s3)


def test_stream_tokens_in_vocab(dataset):
    assert dataset.train.max() < dataset.vocab.size
    assert dataset.train.dtype == np.uint16


def test_agreement_is_learnable_signal(dataset):
    """Verb draws respect noun classes (the core task signal)."""
    g = dataset.grammar
    v = dataset.vocab
    rng = np.random.default_rng(0)
    for _ in range(50):
        n = g.draw_noun(rng, topic=0)
        verb = g.draw_verb_for(rng, n)
        assert g.verb_agrees(n, verb)
        bad = g.draw_verb_not_for(rng, n)
        assert not g.verb_agrees(n, bad)


def test_task_items_well_formed(dataset):
    assert len(dataset.tasks) == 6 * 8
    names = {t["task"] for t in dataset.tasks}
    assert names == set(D.TASK_NAMES)
    for item in dataset.tasks:
        assert 0 <= item["answer"] < len(item["options"])
        assert all(len(o) >= 1 for o in item["options"])
        assert item["context"][0] == D.BOS


def test_boolq_answers_follow_agreement(dataset):
    g = dataset.grammar
    v = dataset.vocab
    yes = v.func["yes"]
    for item in dataset.tasks:
        if item["task"] != "boolq":
            continue
        noun = item["context"][5]
        verb = item["context"][6]
        agrees = g.verb_agrees(noun, verb)
        chosen = item["options"][item["answer"]][0]
        assert (chosen == yes) == agrees


def test_openbook_answer_in_context(dataset):
    for item in dataset.tasks:
        if item["task"] != "openbook":
            continue
        answer_tok = item["options"][item["answer"]][0]
        assert answer_tok in item["context"]


def test_splits_disjoint_draws(dataset):
    # different seeds -> streams differ (not literally disjoint texts, but
    # distinct draws, like WikiText train/test)
    assert not np.array_equal(dataset.train[:4096], dataset.val[:4096])
    assert not np.array_equal(dataset.val, dataset.test[:len(dataset.val)])


def test_export_dataset_files(tmp_path, dataset):
    D.export_dataset(dataset, str(tmp_path))
    for f in ["train.u16", "val.u16", "test.u16", "calib.u16",
              "vocab.json", "tasks.json", "judge_prompts.json",
              "meta.json"]:
        assert (tmp_path / f).exists(), f
    raw = np.fromfile(tmp_path / "train.u16", dtype=np.uint16)
    np.testing.assert_array_equal(raw, dataset.train)
