"""Tests for scripts/check_md_links.py — the documentation link gate.

Fixture-level: GitHub slug rule, fences, images, anchors across files.
Repo-level: every checked-in markdown file must pass (the same
invocation tier1.sh and CI run).
"""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))
SCRIPTS = os.path.join(REPO, "scripts")
if SCRIPTS not in sys.path:
    sys.path.insert(0, SCRIPTS)

import check_md_links  # noqa: E402


# ---------------------------------------------------------------------------
# slug rule
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("heading,slug", [
    ("Quickstart", "quickstart"),
    ("CLI reference", "cli-reference"),
    ("§16 Forked decoding", "16-forked-decoding"),
    ("`lqer serve` flags", "lqer-serve-flags"),
    ("Admission, preemption & swap", "admission-preemption--swap"),
    ("GET /metrics", "get-metrics"),
    ("reading_the_trace", "reading_the_trace"),
])
def test_slugify_matches_github(heading, slug):
    assert check_md_links.slugify(heading) == slug


def test_duplicate_headings_get_numeric_suffixes(tmp_path):
    md = tmp_path / "a.md"
    md.write_text("# Setup\n\n## Setup\n\ntext\n\n## Setup\n")
    assert check_md_links.anchors(str(md)) == {
        "setup", "setup-1", "setup-2"}


# ---------------------------------------------------------------------------
# link checking
# ---------------------------------------------------------------------------


def write_tree(tmp_path, files):
    for rel, content in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(content)
    return tmp_path


def problems(tmp_path):
    out = []
    for md in check_md_links.find_markdown(str(tmp_path)):
        out.extend(check_md_links.check_file(md, str(tmp_path)))
    return out


def test_clean_tree_passes(tmp_path):
    write_tree(tmp_path, {
        "README.md": (
            "# Top\n\n"
            "See [design](docs/design.md) and "
            "[the table](docs/design.md#the-table), or jump "
            "[down](#local).\n\n"
            "External: [site](https://example.com/x) and "
            "<mailto:[email protected]>.\n\n"
            "## Local\n\ntext\n"),
        "docs/design.md": (
            "# Design\n\n[back](../README.md)\n\n## The table\n"),
    })
    assert problems(tmp_path) == []


def test_broken_relative_path_is_reported(tmp_path):
    write_tree(tmp_path, {"README.md": "[gone](docs/missing.md)\n"})
    out = problems(tmp_path)
    assert len(out) == 1
    assert "broken path 'docs/missing.md'" in out[0]


def test_broken_intra_doc_anchor_is_reported(tmp_path):
    write_tree(tmp_path, {
        "README.md": "# Only\n\n[jump](#nowhere)\n"})
    out = problems(tmp_path)
    assert len(out) == 1
    assert "broken anchor '#nowhere'" in out[0]


def test_broken_cross_file_anchor_is_reported(tmp_path):
    write_tree(tmp_path, {
        "README.md": "[x](docs/d.md#absent-section)\n",
        "docs/d.md": "# Present\n"})
    out = problems(tmp_path)
    assert len(out) == 1
    assert "no anchor '#absent-section'" in out[0]


def test_fenced_code_and_inline_code_are_ignored(tmp_path):
    write_tree(tmp_path, {
        "README.md": (
            "# A\n\n"
            "```\n[not a link](nope.md)\n# not a heading\n```\n\n"
            "Inline `[also not](gone.md)` example.\n")})
    assert problems(tmp_path) == []


def test_image_targets_are_checked(tmp_path):
    write_tree(tmp_path, {"README.md": "![fig](img/missing.png)\n"})
    out = problems(tmp_path)
    assert len(out) == 1
    assert "img/missing.png" in out[0]


# ---------------------------------------------------------------------------
# the real repo's docs are link-clean (same invocation as tier1/CI)
# ---------------------------------------------------------------------------


def test_repo_markdown_is_link_clean():
    proc = subprocess.run(
        [sys.executable, os.path.join(SCRIPTS, "check_md_links.py"),
         "--root", REPO],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "check_md_links: OK" in proc.stdout
