"""Number-format properties (MXINT / INT group quantization)."""

import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # not in every image; skip, do not break collection
from hypothesis import given, settings, strategies as st

from compile.quant import formats

SETTINGS = dict(max_examples=30, deadline=None)


@given(seed=st.integers(0, 2**16),
       bits=st.sampled_from([2, 3, 4, 8]),
       scale=st.sampled_from([1e-4, 1.0, 1e4]))
@settings(**SETTINGS)
def test_mxint_error_bounded(seed, bits, scale):
    rng = np.random.default_rng(seed)
    x = (rng.normal(0, scale, size=(8, 64))).astype(np.float32)
    q = np.asarray(formats.mxint_quant_act(jnp.asarray(x), bits))
    # per-block error bound: one grid step of the block's scale
    xb = x.reshape(8, 4, 16)
    qb = q.reshape(8, 4, 16)
    amax = np.abs(xb).max(-1, keepdims=True)
    step = 2.0 ** (np.floor(np.log2(np.maximum(amax, 1e-38)))
                   - (bits - 2))
    assert np.all(np.abs(xb - qb) <= step + 1e-30)


def test_mxint_blocks_independent():
    x = np.zeros((1, 32), np.float32)
    x[0, :16] = 100.0
    x[0, 16:] = 0.001
    q = np.asarray(formats.mxint_quant_act(jnp.asarray(x), 4))
    # small-magnitude block keeps fine resolution despite the big block
    assert np.abs(q[0, 16:] - 0.001).max() < 1e-4


def test_mxint_exp_clamping():
    # 4-bit exponent clamps at +7: huge values saturate the grid
    x = np.full((16, 1), 1e30, np.float32)
    q = np.asarray(formats.mxint_quant_weight(jnp.asarray(x), 4,
                                              exp_bits=4))
    assert np.all(np.isfinite(q))
    assert np.all(q <= 2.0 ** 9)  # qmax * 2^(7-2)


@given(seed=st.integers(0, 2**16), bits=st.sampled_from([2, 4, 8]))
@settings(**SETTINGS)
def test_int_group_idempotent(seed, bits):
    rng = np.random.default_rng(seed)
    w = rng.normal(0, 0.3, size=(256, 8)).astype(np.float32)
    q1 = np.asarray(formats.int_quant_group(jnp.asarray(w), bits))
    q2 = np.asarray(formats.int_quant_group(jnp.asarray(q1), bits))
    np.testing.assert_allclose(q1, q2, atol=1e-6)


def test_effective_group():
    assert formats.effective_group(256, 128) == 128
    assert formats.effective_group(192, 128) == 96
    assert formats.effective_group(64, 128) == 64
    assert formats.effective_group(100, 128) == 100


def test_per_token_rows_independent():
    x = np.array([[1.0, -2.0, 0.5], [100.0, 50.0, -25.0]], np.float32)
    q = np.asarray(formats.int_quant_per_token(jnp.asarray(x), 8))
    assert abs(q[0, 0] - 1.0) < 0.02
    assert abs(q[1, 0] - 100.0) < 1.0


@pytest.mark.parametrize("bits,expected", [(4, 4.25), (8, 8.25), (2, 2.25)])
def test_mxint_avg_bits(bits, expected):
    assert formats.mxint_avg_bits(bits, 4, 16) == pytest.approx(expected)


def test_int_group_avg_bits():
    assert formats.int_group_avg_bits(4, 128) == pytest.approx(4.125)


def test_lqer_avg_bits_overhead():
    # paper appendix D: overhead shrinks with layer size
    small = formats.lqer_avg_bits(128, 128, 16, 4.25, 8.25)
    large = formats.lqer_avg_bits(12288, 49152, 32, 4.25, 8.25)
    assert small > large
    assert large < 4.3
