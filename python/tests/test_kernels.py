"""L1 correctness: every Pallas kernel must match its pure-jnp oracle.

This is the CORE correctness signal of the compile path — hypothesis
sweeps shapes, bit widths, and value scales.
"""

import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # not in every image; skip, do not break collection
from hypothesis import given, settings, strategies as st

from compile.kernels import (int_quant_per_token_pallas, lqer_linear,
                             mxint_quant_act_pallas,
                             mxint_quant_weight_pallas)
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


def _rand(rng_seed, *shape, scale=1.0):
    rng = np.random.default_rng(rng_seed)
    return (rng.normal(0, scale, size=shape)).astype(np.float32)


@given(rows=st.sampled_from([1, 3, 8]),
       blocks=st.sampled_from([1, 2, 5]),
       bits=st.sampled_from([2, 3, 4, 6, 8]),
       scale=st.sampled_from([1e-3, 1.0, 100.0]),
       seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_mxint_act_kernel_matches_ref(rows, blocks, bits, scale, seed):
    x = _rand(seed, rows, blocks * 16, scale=scale)
    got = mxint_quant_act_pallas(jnp.asarray(x), bits)
    want = ref.mxint_quant_act_ref(jnp.asarray(x), bits)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(in_blocks=st.sampled_from([1, 2, 4]),
       cols=st.sampled_from([1, 8, 48]),
       bits=st.sampled_from([2, 4, 8]),
       seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_mxint_weight_kernel_matches_ref(in_blocks, cols, bits, seed):
    w = _rand(seed, in_blocks * 16, cols, scale=0.5)
    got = mxint_quant_weight_pallas(jnp.asarray(w), bits)
    want = ref.mxint_quant_weight_ref(jnp.asarray(w), bits)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(rows=st.sampled_from([1, 4, 16]),
       cols=st.sampled_from([16, 96]),
       bits=st.sampled_from([4, 6, 8]),
       seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_int_per_token_kernel_matches_ref(rows, cols, bits, seed):
    x = _rand(seed, rows, cols, scale=3.0)
    got = int_quant_per_token_pallas(jnp.asarray(x), bits)
    want = ref.int_quant_per_token_ref(jnp.asarray(x), bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=1e-6)


@given(m=st.sampled_from([2, 6, 24]),
       k_in=st.sampled_from([32, 96]),
       n=st.sampled_from([40, 160]),
       r=st.sampled_from([0, 1, 8, 16]),
       seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_lqer_linear_kernel_matches_ref(m, k_in, n, r, seed):
    x = _rand(seed, m, k_in)
    w = _rand(seed + 1, k_in, n, scale=0.3)
    a = _rand(seed + 2, k_in, r, scale=0.3) if r else None
    b = _rand(seed + 3, r, n, scale=0.3) if r else None
    got = lqer_linear(jnp.asarray(x), jnp.asarray(w),
                      None if a is None else jnp.asarray(a),
                      None if b is None else jnp.asarray(b))
    want = ref.lqer_linear_ref(jnp.asarray(x), jnp.asarray(w),
                               None if a is None else jnp.asarray(a),
                               None if b is None else jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_lqer_linear_batched_shape():
    x = _rand(0, 2, 5, 32)  # (B, T, K)
    w = _rand(1, 32, 48)
    y = lqer_linear(jnp.asarray(x), jnp.asarray(w))
    assert y.shape == (2, 5, 48)


def test_lqer_linear_zero_rank_equals_plain():
    x = _rand(2, 4, 32)
    w = _rand(3, 32, 16)
    a = np.zeros((32, 4), np.float32)
    b = np.zeros((4, 16), np.float32)
    y0 = lqer_linear(jnp.asarray(x), jnp.asarray(w))
    y1 = lqer_linear(jnp.asarray(x), jnp.asarray(w), jnp.asarray(a),
                     jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-6)


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_mxint_requantization_drift_bounded(bits):
    # Exact idempotence fails when a value lands on -2^(m-1): the block
    # max then doubles and the shared exponent shifts by one (a property
    # of the real MXINT grid, not a bug).  Drift is bounded by one step
    # of the coarser grid.
    x = _rand(7, 4, 32)
    q1 = np.asarray(mxint_quant_act_pallas(jnp.asarray(x), bits))
    q2 = np.asarray(mxint_quant_act_pallas(jnp.asarray(q1), bits))
    xb = q1.reshape(-1, 16)
    step = 2.0 ** (np.floor(np.log2(np.maximum(
        np.abs(xb).max(-1, keepdims=True), 1e-38))) - (bits - 2))
    assert np.all(np.abs(q2.reshape(-1, 16) - xb) <= step + 1e-30)
