"""LQER / L2QER algebra (paper section 3)."""

import numpy as np
import pytest

from compile.quant import formats, lqer


def _w(seed=0, m=64, n=48, scale=0.4):
    rng = np.random.default_rng(seed)
    return rng.normal(0, scale, size=(m, n)).astype(np.float32)


def _qfn(bits=3):
    import jax.numpy as jnp
    return lambda w: np.asarray(
        formats.mxint_quant_weight(jnp.asarray(w), bits), np.float32)


def test_full_rank_recovers_error_exactly():
    """With k = min(m,n) and no factor quantization, W_q + A_k B_k == W."""
    w = _w()
    fac = lqer.lqer_quantize(w, _qfn(), k=48, lowrank_bits=None)
    recon = fac.w_q + fac.a_k @ fac.b_k
    np.testing.assert_allclose(recon, w, atol=1e-4)
    assert fac.approx_err < 1e-6


def test_rank_monotone_improvement():
    w = _w(1)
    errs = [lqer.lqer_quantize(w, _qfn(), k=k, lowrank_bits=None).approx_err
            for k in (1, 4, 16, 48)]
    for a, b in zip(errs, errs[1:]):
        assert b <= a + 1e-9, errs


def test_scaled_svd_cancels_scaling():
    """L2QER: S^-1 (S E_q)_k must equal E_q exactly at full rank."""
    w = _w(2)
    s = np.abs(np.random.default_rng(3).normal(1.5, 0.5, size=64)) + 0.2
    fac = lqer.lqer_quantize(w, _qfn(), k=48, s_diag=s, lowrank_bits=None)
    recon = fac.w_q + fac.a_k @ fac.b_k
    np.testing.assert_allclose(recon, w, atol=1e-4)


def test_l2qer_weights_salient_rows():
    """The scaled reconstruction must approximate high-S rows better."""
    w = _w(4, m=64, n=64)
    s = np.ones(64)
    s[:8] = 50.0  # "salient" activation channels
    plain = lqer.lqer_quantize(w, _qfn(), k=4, lowrank_bits=None)
    scaled = lqer.lqer_quantize(w, _qfn(), k=4, s_diag=s,
                                lowrank_bits=None)
    eq = w - plain.w_q
    err_plain = np.abs(eq - plain.a_k @ plain.b_k)[:8].mean()
    err_scaled = np.abs(eq - scaled.a_k @ scaled.b_k)[:8].mean()
    assert err_scaled < err_plain


def test_pad_to_extends_with_zeros():
    w = _w(5)
    fac = lqer.lqer_quantize(w, _qfn(), k=4, pad_to=16)
    assert fac.a_k.shape == (64, 16)
    assert fac.b_k.shape == (16, 48)
    assert np.all(fac.a_k[:, 4:] == 0.0)
    assert np.all(fac.b_k[4:, :] == 0.0)


def test_calib_scale_matrix_formula():
    a = np.array([1.0, 4.0, 2.0])
    s = lqer.calib_scale_matrix(a)
    denom = np.sqrt(1.0 * 4.0)
    np.testing.assert_allclose(s, a / denom)


def test_calib_scale_matrix_floors_zero_channels():
    a = np.array([0.0, 2.0, 8.0])
    s = lqer.calib_scale_matrix(a)
    assert np.all(s > 0)  # S stays invertible


def test_error_spectra_normalized():
    """Footnote 1: both spectra share the same Frobenius norm."""
    w = _w(6)
    s = np.abs(np.random.default_rng(7).normal(1, 0.5, size=64)) + 0.3
    sp = lqer.error_spectra(w, _qfn(), s)
    f_lqer = np.sqrt((sp["lqer"] ** 2).sum())
    f_l2qer = np.sqrt((sp["l2qer"] ** 2).sum())
    assert f_lqer == pytest.approx(f_l2qer, rel=1e-4)


def test_spectra_sorted_descending():
    w = _w(8)
    sp = lqer.error_spectra(w, _qfn(), np.ones(64))
    assert np.all(np.diff(sp["lqer"]) <= 1e-6)
