"""L2 model: shapes, variant params, and the critical prefill/decode
consistency invariant (KV-cache decode must reproduce full-sequence
scoring)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


@pytest.fixture(scope="module")
def setup():
    cfg = M.ModelConfig(name="t", vocab=64, d=32, layers=2, heads=2,
                        ffn=64, t_max=24)
    params = M.init_params(cfg, seed=1)
    return cfg, params


def test_param_count_matches_tree(setup):
    cfg, params = setup
    total = sum(np.asarray(a).size for _, a in M.flatten_with_names(params))
    assert total == cfg.param_count()


def test_flatten_names_deterministic(setup):
    cfg, params = setup
    n1 = [n for n, _ in M.flatten_with_names(params)]
    n2 = [n for n, _ in M.flatten_with_names(M.init_params(cfg, seed=9))]
    assert n1 == n2
    assert "layers.0.fc1.w" in n1


def test_attach_variant_adds_and_removes(setup):
    cfg, params = setup
    gv = M.GraphVariant(act="int8", rank=4)
    vp = M.attach_variant_params(params, cfg, gv)
    lin = vp["layers"][0]["wq"]
    assert lin["a"].shape == (32, 4)
    assert lin["smooth"].shape == (32,)
    gv0 = M.GraphVariant(act="none", rank=0)
    vp0 = M.attach_variant_params(vp, cfg, gv0)
    assert "a" not in vp0["layers"][0]["wq"]
    assert "smooth" not in vp0["layers"][0]["wq"]


def test_score_shapes(setup):
    cfg, params = setup
    gv = M.GraphVariant(act="none", rank=0)
    toks = np.arange(2 * 8, dtype=np.int32).reshape(2, 8) % cfg.vocab
    logits = M.score(params, toks, cfg, gv)
    assert logits.shape == (2, 8, cfg.vocab)


def test_causality(setup):
    """Changing a future token must not change past logits."""
    cfg, params = setup
    gv = M.GraphVariant(act="none", rank=0)
    t1 = np.ones((1, 8), np.int32)
    t2 = t1.copy()
    t2[0, 7] = 5
    l1 = np.asarray(M.score(params, t1, cfg, gv))
    l2 = np.asarray(M.score(params, t2, cfg, gv))
    np.testing.assert_allclose(l1[0, :7], l2[0, :7], atol=1e-5)
    assert np.abs(l1[0, 7] - l2[0, 7]).max() > 1e-6


def test_prefill_matches_score(setup):
    cfg, params = setup
    gv = M.GraphVariant(act="mx8", rank=0)
    vp = M.attach_variant_params(params, cfg, gv)
    toks = (np.arange(8, dtype=np.int32) * 3 % cfg.vocab)[None, :]
    l_score = np.asarray(M.score(vp, toks, cfg, gv))
    l_pre, k, v = M.prefill(vp, toks, cfg, gv)
    np.testing.assert_allclose(np.asarray(l_pre), l_score, atol=1e-5)
    assert k.shape == (cfg.layers, 1, 8, cfg.d)


def test_decode_consistent_with_score(setup):
    """Prefill t tokens then decode token t: logits must equal the
    full-sequence score at position t.  This validates the whole KV-cache
    path end-to-end."""
    cfg, params = setup
    gv = M.GraphVariant(act="none", rank=0)
    rng = np.random.default_rng(0)
    seq = rng.integers(4, cfg.vocab, size=10).astype(np.int32)
    t_pre = 6

    full = np.asarray(M.score(params, seq[None, :], cfg, gv))[0]

    _, k, v = M.prefill(params, seq[None, :t_pre], cfg, gv)
    kc = np.zeros((cfg.layers, 1, cfg.t_max, cfg.d), np.float32)
    vc = np.zeros_like(kc)
    kc[:, :, :t_pre] = np.asarray(k)
    vc[:, :, :t_pre] = np.asarray(v)
    for i in range(t_pre, 10):
        logits, kn, vn = M.decode(
            params, seq[i:i + 1], jnp.asarray(kc), jnp.asarray(vc),
            np.array([i], np.int32), cfg, gv)
        np.testing.assert_allclose(
            np.asarray(logits)[0], full[i], rtol=1e-4, atol=1e-4)
        kc[:, 0, i] = np.asarray(kn)[:, 0]
        vc[:, 0, i] = np.asarray(vn)[:, 0]


def test_decode_batch_entries_independent(setup):
    """A garbage row in the decode batch must not affect other rows."""
    cfg, params = setup
    gv = M.GraphVariant(act="none", rank=0)
    kc = np.random.default_rng(1).normal(
        size=(cfg.layers, 2, cfg.t_max, cfg.d)).astype(np.float32)
    vc = kc * 0.5
    tok = np.array([7, 9], np.int32)
    pos = np.array([3, 5], np.int32)
    l2, _, _ = M.decode(params, tok, kc, vc, pos, cfg, gv)
    # change row 1's cache & token; row 0 logits unchanged
    kc2 = kc.copy()
    kc2[:, 1] *= 2.0
    tok2 = np.array([7, 11], np.int32)
    l2b, _, _ = M.decode(params, tok2, kc2, vc, pos, cfg, gv)
    np.testing.assert_allclose(np.asarray(l2)[0], np.asarray(l2b)[0],
                               atol=1e-5)


def test_train_forward_matches_quantless_variant(setup):
    """train_forward (plain jnp) == score with act=none, rank=0."""
    cfg, params = setup
    gv = M.GraphVariant(act="none", rank=0)
    toks = np.arange(2 * 6, dtype=np.int32).reshape(2, 6) % cfg.vocab
    a = np.asarray(M.train_forward(params, toks, cfg))
    b = np.asarray(M.score(params, toks, cfg, gv))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_act_quant_modes_change_output(setup):
    cfg, params = setup
    toks = np.arange(6, dtype=np.int32)[None, :] % cfg.vocab
    outs = {}
    for act in ["none", "mx8", "mx6", "int8"]:
        gv = M.GraphVariant(act=act, rank=0)
        vp = M.attach_variant_params(params, cfg, gv)
        outs[act] = np.asarray(M.score(vp, toks, cfg, gv))
    assert np.abs(outs["none"] - outs["mx6"]).max() > 1e-5
    # lower precision -> larger deviation from fp32
    d8 = np.abs(outs["none"] - outs["mx8"]).mean()
    d6 = np.abs(outs["none"] - outs["mx6"]).mean()
    assert d6 > d8
