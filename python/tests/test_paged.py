"""Paged KV-cache graphs (DESIGN.md §10): ``decode_paged`` must equal
the flat ``decode`` on the gathered view, ``kv_write_prefill_paged``
must scatter bucket-chunks into the listed blocks, and dead writes of
free lanes must park in the sentinel block (id 0)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M

BS = 8  # block rows used by these tests (aot uses PAGED_BLOCK_SIZE)


@pytest.fixture(scope="module")
def setup():
    cfg = M.ModelConfig(name="t", vocab=64, d=32, layers=2, heads=2,
                        ffn=64, t_max=24)
    params = M.init_params(cfg, seed=1)
    return cfg, params


def gather_numpy(pool, tables):
    """Reference gather: (L, NB, bs, d) x (B, M) -> (L, B, M*bs, d)."""
    L, _, bs, d = pool.shape
    b, m = tables.shape
    out = np.zeros((L, b, m * bs, d), pool.dtype)
    for bi in range(b):
        for c in range(m):
            out[:, bi, c * bs:(c + 1) * bs] = pool[:, tables[bi, c]]
    return out


def test_decode_paged_matches_flat_decode_on_gathered_view(setup):
    cfg, params = setup
    gv = M.GraphVariant(act="none", rank=0)
    rng = np.random.default_rng(7)
    batch, nb = 3, 10
    m_blocks = cfg.t_max // BS
    kc = rng.normal(size=(cfg.layers, nb, BS, cfg.d)).astype(np.float32)
    vc = rng.normal(size=(cfg.layers, nb, BS, cfg.d)).astype(np.float32)
    # lanes 0/1 own scrambled non-sentinel blocks; lane 2 is a free lane
    # (empty table -> all-sentinel padding, pos 0)
    tables = np.array([[1, 4, 2], [3, 5, 7], [0, 0, 0]], np.int32)
    assert tables.shape == (batch, m_blocks)
    tok = np.array([5, 9, 0], np.int32)
    pos = np.array([2, 17, 0], np.int32)

    kc_flat = gather_numpy(kc, tables)
    vc_flat = gather_numpy(vc, tables)
    ref_logits, kn, vn = M.decode(params, tok, kc_flat, vc_flat, pos,
                                  cfg, gv)
    out_logits, kc2, vc2 = M.decode_paged(params, tok, kc, vc, pos,
                                          tables, cfg, gv)
    np.testing.assert_array_equal(np.asarray(out_logits),
                                  np.asarray(ref_logits))

    # Expected pool: every lane's new row written through its table;
    # the free lane's dead row lands in the sentinel block at offset 0.
    kc_want, vc_want = kc.copy(), vc.copy()
    for bi in range(batch):
        blk = tables[bi, pos[bi] // BS]
        off = pos[bi] % BS
        kc_want[:, blk, off] = np.asarray(kn)[:, bi]
        vc_want[:, blk, off] = np.asarray(vn)[:, bi]
    np.testing.assert_array_equal(np.asarray(kc2), kc_want)
    np.testing.assert_array_equal(np.asarray(vc2), vc_want)
    # the sentinel write really happened (free lane parked there)
    assert not np.array_equal(kc_want[:, 0, 0], kc[:, 0, 0])


def test_kv_write_prefill_paged_places_chunks(setup):
    cfg, _ = setup
    nb, t = 6, 2 * BS
    rng = np.random.default_rng(5)
    kc = rng.normal(size=(cfg.layers, nb, BS, cfg.d)).astype(np.float32)
    vc = kc * 0.5
    kp = rng.normal(size=(cfg.layers, 1, t, cfg.d)).astype(np.float32)
    vp = kp * 2.0
    ids = np.array([4, 2], np.int32)
    kc2, vc2 = M.kv_write_prefill_paged(kc, vc, kp, vp, ids)
    kc2, vc2 = np.asarray(kc2), np.asarray(vc2)
    np.testing.assert_array_equal(kc2[:, 4], kp[:, 0, :BS])
    np.testing.assert_array_equal(kc2[:, 2], kp[:, 0, BS:])
    np.testing.assert_array_equal(vc2[:, 4], vp[:, 0, :BS])
    np.testing.assert_array_equal(vc2[:, 2], vp[:, 0, BS:])
    for other in range(nb):
        if other not in (2, 4):
            np.testing.assert_array_equal(kc2[:, other], kc[:, other])
            np.testing.assert_array_equal(vc2[:, other], vc[:, other])


def test_decode_paged_consistent_with_score(setup):
    """Maintain the cache across steps through the paged graphs: logits
    must still reproduce full-sequence scoring (the serving-path
    invariant, like the flat decode_resident test)."""
    cfg, params = setup
    gv = M.GraphVariant(act="none", rank=0)
    rng = np.random.default_rng(0)
    seq = rng.integers(4, cfg.vocab, size=12).astype(np.int32)
    t_pre = BS  # one full block, a valid prefill bucket

    full = np.asarray(M.score(params, seq[None, :], cfg, gv))[0]

    nb = 8
    m_blocks = cfg.t_max // BS
    kc = jnp.zeros((cfg.layers, nb, BS, cfg.d), jnp.float32)
    vc = jnp.zeros_like(kc)
    _, k, v = M.prefill(params, seq[None, :t_pre], cfg, gv)
    # the sequence owns blocks [5, 3, 6]; prefill fills the first chunk
    table = np.array([[5, 3, 6]], np.int32)
    assert table.shape[1] == m_blocks
    kc, vc = M.kv_write_prefill_paged(kc, vc, k, v,
                                      np.array([5], np.int32))
    for i in range(t_pre, 12):
        logits, kc, vc = M.decode_paged(
            params, seq[i:i + 1], kc, vc, np.array([i], np.int32),
            table, cfg, gv)
        np.testing.assert_allclose(
            np.asarray(logits)[0], full[i], rtol=1e-4, atol=1e-4)


def test_lowered_paged_graphs_have_dus_and_pool_outputs(setup):
    """The paged entries must lower to HLO with table-indexed DUS
    appends and the full block pools as outputs."""
    cfg, params = setup
    gv = M.GraphVariant(act="none", rank=0)
    b = 2
    bs = aot.PAGED_BLOCK_SIZE
    cfg16 = M.ModelConfig(name="t16", vocab=cfg.vocab, d=cfg.d,
                          layers=cfg.layers, heads=cfg.heads,
                          ffn=cfg.ffn, t_max=2 * bs)
    params16 = M.init_params(cfg16, seed=2)
    nb = aot.paged_num_blocks(b, cfg16.t_max)
    pool = jax.ShapeDtypeStruct((cfg16.layers, nb, bs, cfg16.d),
                                jnp.float32)
    tok = jax.ShapeDtypeStruct((b,), jnp.int32)
    pos = jax.ShapeDtypeStruct((b,), jnp.int32)
    tbl = jax.ShapeDtypeStruct((b, cfg16.t_max // bs), jnp.int32)
    text = aot.lower_graph(
        lambda p, t_, kc, vc, p_, bt: M.decode_paged(p, t_, kc, vc, p_,
                                                     bt, cfg16, gv),
        M.param_specs(params16), tok, pool, pool, pos, tbl)
    assert "HloModule" in text
    assert "dynamic-update-slice" in text
    assert "f32[%d,%d,%d,%d]" % (cfg16.layers, nb, bs, cfg16.d) in text

    pre = jax.ShapeDtypeStruct((cfg16.layers, 1, bs, cfg16.d),
                               jnp.float32)
    ids = jax.ShapeDtypeStruct((1,), jnp.int32)
    text = aot.lower_graph(M.kv_write_prefill_paged, pool, pool, pre,
                           pre, ids)
    assert "dynamic-update-slice" in text
