"""PTQ pipeline + calibration on a tiny trained-ish model."""

import jax
import numpy as np
import pytest

from compile import calibration, model as M, pipeline


@pytest.fixture(scope="module")
def quant_setup(dataset):
    cfg = M.make_config("opt-tiny", vocab=dataset.vocab.size)
    params = M.init_params(cfg, seed=2)
    stats = calibration.collect_stats(params, dataset.calib[:6], cfg)
    return cfg, params, stats


def test_calibration_stats_shapes(quant_setup):
    cfg, params, stats = quant_setup
    assert len(stats) == cfg.layers * 6
    st = stats["layers.0.fc1"]
    assert st.a_bar.shape == (cfg.d,)
    assert st.h.shape == (cfg.d, cfg.d)
    assert np.all(st.a_bar >= 0)
    assert st.n_tokens > 0
    assert st.x_sample is not None and st.x_sample.shape[1] == cfg.d


def test_hessian_is_psd(quant_setup):
    _, _, stats = quant_setup
    h = stats["layers.0.wq"].h
    eig = np.linalg.eigvalsh((h + h.T) / 2)
    assert eig.min() > -1e-6


@pytest.mark.parametrize("method", ["fp16", "mxint-w4a8", "l2qer-w4a8",
                                    "gptq-w4", "awq-w4", "llmint4",
                                    "smoothquant-w8a8", "clipq-w6a6"])
def test_quantize_model_every_method(quant_setup, method):
    cfg, params, stats = quant_setup
    spec = pipeline.METHODS[method]
    qp, meta = pipeline.quantize_model(params, cfg, spec, stats,
                                       rank_pad=16)
    assert meta["avg_w_bits"] > 0
    gv = pipeline.graph_variant_for(spec, 16)
    # variant params must match the graph's expectations
    lin = qp["layers"][0]["wq"]
    assert ("a" in lin) == (gv.rank > 0)
    assert ("smooth" in lin) == gv.needs_smooth
    # weights must be finite and shaped
    for name, arr in M.flatten_with_names(qp):
        assert np.isfinite(arr).all(), name


def test_avg_bits_ordering(quant_setup):
    cfg, params, stats = quant_setup
    bits = {}
    for m in ["fp16", "mxint-w4a8", "l2qer-w4a8", "smoothquant-w8a8"]:
        _, meta = pipeline.quantize_model(
            params, cfg, pipeline.METHODS[m], stats)
        bits[m] = meta["avg_w_bits"]
    assert bits["fp16"] == 16.0
    assert bits["mxint-w4a8"] == pytest.approx(4.25)
    assert bits["l2qer-w4a8"] > bits["mxint-w4a8"]  # low-rank overhead
    assert bits["l2qer-w4a8"] < bits["smoothquant-w8a8"]


def test_l2qer_reduces_weight_error_vs_plain(quant_setup):
    """The reconstructed weight must be closer to W than plain W_q."""
    cfg, params, stats = quant_setup
    spec_plain = pipeline.METHODS["mxint-w2a8"]
    spec_l2 = pipeline.METHODS["l2qer-w2a8"]
    qp_p, _ = pipeline.quantize_model(params, cfg, spec_plain, stats)
    qp_l, _ = pipeline.quantize_model(params, cfg, spec_l2, stats)
    w = np.asarray(params["layers"][0]["fc1"]["w"])
    wq = np.asarray(qp_p["layers"][0]["fc1"]["w"])
    lin = qp_l["layers"][0]["fc1"]
    w_recon = np.asarray(lin["w"]) + np.asarray(lin["a"]) @ np.asarray(
        lin["b"])
    assert np.abs(w - w_recon).mean() < np.abs(w - wq).mean()


def test_graph_tags_stable(quant_setup):
    spec = pipeline.METHODS["l2qer-w4a8"]
    gv = pipeline.graph_variant_for(spec, 16)
    assert gv.tag == "act-mx8_k16"
    gv0 = pipeline.graph_variant_for(pipeline.METHODS["fp16"], 0)
    assert gv0.tag == "act-none_k0"


def test_heterogeneous_plan_end_to_end(quant_setup):
    """Acceptance plan: k=32 on FFN linears, k=8 elsewhere, INT4 on the
    output projection, MXINT4 default — through quantize_model."""
    from compile.quant import spec as qspec
    cfg, params, stats = quant_setup
    plan = qspec.heterogeneous_example()
    qp, meta = pipeline.quantize_model(params, cfg, plan, stats)
    gv = pipeline.graph_variant_for(plan, meta["rank_pad"])
    assert meta["rank_pad"] == 32 and gv.tag == "act-mx8_k32"
    lin_ffn = qp["layers"][0]["fc1"]
    lin_att = qp["layers"][0]["wq"]
    # One padded graph rank for every layer...
    assert lin_ffn["a"].shape == (cfg.d, 32)
    assert lin_att["a"].shape == (cfg.d, 32)
    # ...but the k=8 layers only carry 8 live factor columns.
    assert np.abs(lin_att["a"][:, 8:]).max() == 0
    assert np.abs(lin_ffn["a"][:, 8:32]).max() > 0
    # Mixed precision: plan-derived bits differ per layer and match the
    # schema's own accounting (the rust side asserts the same numbers).
    pb = meta["plan_bits"]
    assert pb["layers.0.fc1"] > pb["layers.0.wq"]
    m, n = cfg.d, cfg.ffn
    assert pb["layers.0.fc1"] == pytest.approx(
        plan.resolve("layers.0.fc1").avg_bits(m, n), abs=1e-12)
    # The resolved plan is embedded in the meta and round-trips.
    back = qspec.QuantSpec.from_json_dict(meta["plan"])
    assert back == plan
    assert meta["plan_avg_bits"] == pytest.approx(
        plan.model_avg_bits(qspec.layer_shapes(cfg.d, cfg.ffn, cfg.layers)))
    # The INT4 override actually changed the grid on wo: its effective
    # weight equals the INT4-g128 quantization of the original weight,
    # not the MXINT4 one the default would have produced.
    from compile.quant.spec import IntGroup, Mxint
    w_orig = np.asarray(params["layers"][0]["wo"]["w"], np.float32)
    w_int4 = pipeline.weight_quant_fn(IntGroup(4, 128))(w_orig)
    w_mx4 = pipeline.weight_quant_fn(Mxint(4))(w_orig)
    w_got = np.asarray(qp["layers"][0]["wo"]["w"])
    np.testing.assert_array_equal(w_got, w_int4)
    assert not np.array_equal(w_got, w_mx4)
    assert pb["layers.0.wo"] != pb["layers.0.wq"]
    # meta keeps the legacy single-spec view of the *default*.
    assert meta["spec"]["weight"] == ["mxint", 4]


def test_method_name_string_still_accepted(quant_setup):
    """Legacy compatibility shim: a bare method-name string quantizes."""
    cfg, params, stats = quant_setup
    _, meta = pipeline.quantize_model(params, cfg, "mxint-w4a8", stats)
    assert meta["avg_w_bits"] == pytest.approx(4.25)
    assert meta["plan"]["default"]["weight"]["kind"] == "mxint"


def test_opt_cost_recorded(quant_setup):
    cfg, params, stats = quant_setup
    _, meta = pipeline.quantize_model(
        params, cfg, pipeline.METHODS["l2qer-w4a8"], stats)
    assert meta["opt_seconds"] > 0
    assert meta["spec"]["algo"] == "rtn"
