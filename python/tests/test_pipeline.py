"""PTQ pipeline + calibration on a tiny trained-ish model."""

import jax
import numpy as np
import pytest

from compile import calibration, model as M, pipeline


@pytest.fixture(scope="module")
def quant_setup(dataset):
    cfg = M.make_config("opt-tiny", vocab=dataset.vocab.size)
    params = M.init_params(cfg, seed=2)
    stats = calibration.collect_stats(params, dataset.calib[:6], cfg)
    return cfg, params, stats


def test_calibration_stats_shapes(quant_setup):
    cfg, params, stats = quant_setup
    assert len(stats) == cfg.layers * 6
    st = stats["layers.0.fc1"]
    assert st.a_bar.shape == (cfg.d,)
    assert st.h.shape == (cfg.d, cfg.d)
    assert np.all(st.a_bar >= 0)
    assert st.n_tokens > 0
    assert st.x_sample is not None and st.x_sample.shape[1] == cfg.d


def test_hessian_is_psd(quant_setup):
    _, _, stats = quant_setup
    h = stats["layers.0.wq"].h
    eig = np.linalg.eigvalsh((h + h.T) / 2)
    assert eig.min() > -1e-6


@pytest.mark.parametrize("method", ["fp16", "mxint-w4a8", "l2qer-w4a8",
                                    "gptq-w4", "awq-w4", "llmint4",
                                    "smoothquant-w8a8", "clipq-w6a6"])
def test_quantize_model_every_method(quant_setup, method):
    cfg, params, stats = quant_setup
    spec = pipeline.METHODS[method]
    qp, meta = pipeline.quantize_model(params, cfg, spec, stats,
                                       rank_pad=16)
    assert meta["avg_w_bits"] > 0
    gv = pipeline.graph_variant_for(spec, 16)
    # variant params must match the graph's expectations
    lin = qp["layers"][0]["wq"]
    assert ("a" in lin) == (gv.rank > 0)
    assert ("smooth" in lin) == gv.needs_smooth
    # weights must be finite and shaped
    for name, arr in M.flatten_with_names(qp):
        assert np.isfinite(arr).all(), name


def test_avg_bits_ordering(quant_setup):
    cfg, params, stats = quant_setup
    bits = {}
    for m in ["fp16", "mxint-w4a8", "l2qer-w4a8", "smoothquant-w8a8"]:
        _, meta = pipeline.quantize_model(
            params, cfg, pipeline.METHODS[m], stats)
        bits[m] = meta["avg_w_bits"]
    assert bits["fp16"] == 16.0
    assert bits["mxint-w4a8"] == pytest.approx(4.25)
    assert bits["l2qer-w4a8"] > bits["mxint-w4a8"]  # low-rank overhead
    assert bits["l2qer-w4a8"] < bits["smoothquant-w8a8"]


def test_l2qer_reduces_weight_error_vs_plain(quant_setup):
    """The reconstructed weight must be closer to W than plain W_q."""
    cfg, params, stats = quant_setup
    spec_plain = pipeline.METHODS["mxint-w2a8"]
    spec_l2 = pipeline.METHODS["l2qer-w2a8"]
    qp_p, _ = pipeline.quantize_model(params, cfg, spec_plain, stats)
    qp_l, _ = pipeline.quantize_model(params, cfg, spec_l2, stats)
    w = np.asarray(params["layers"][0]["fc1"]["w"])
    wq = np.asarray(qp_p["layers"][0]["fc1"]["w"])
    lin = qp_l["layers"][0]["fc1"]
    w_recon = np.asarray(lin["w"]) + np.asarray(lin["a"]) @ np.asarray(
        lin["b"])
    assert np.abs(w - w_recon).mean() < np.abs(w - wq).mean()


def test_graph_tags_stable(quant_setup):
    spec = pipeline.METHODS["l2qer-w4a8"]
    gv = pipeline.graph_variant_for(spec, 16)
    assert gv.tag == "act-mx8_k16"
    gv0 = pipeline.graph_variant_for(pipeline.METHODS["fp16"], 0)
    assert gv0.tag == "act-none_k0"


def test_opt_cost_recorded(quant_setup):
    cfg, params, stats = quant_setup
    _, meta = pipeline.quantize_model(
        params, cfg, pipeline.METHODS["l2qer-w4a8"], stats)
    assert meta["opt_seconds"] > 0
    assert meta["spec"]["algo"] == "rtn"
