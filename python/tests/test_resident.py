"""Device-resident KV-cache graphs: the in-graph row append
(``decode_resident``) and the prefill-slot scatter (``kv_write_prefill``)
must be bit-identical to the host-side cache management they replace."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M


@pytest.fixture(scope="module")
def setup():
    cfg = M.ModelConfig(name="t", vocab=64, d=32, layers=2, heads=2,
                        ffn=64, t_max=24)
    params = M.init_params(cfg, seed=1)
    return cfg, params


def test_decode_resident_matches_host_append(setup):
    """decode_resident == decode + host-side row write, bit for bit."""
    cfg, params = setup
    gv = M.GraphVariant(act="none", rank=0)
    rng = np.random.default_rng(3)
    batch = 3
    kc = rng.normal(size=(cfg.layers, batch, cfg.t_max, cfg.d)).astype(
        np.float32)
    vc = rng.normal(size=(cfg.layers, batch, cfg.t_max, cfg.d)).astype(
        np.float32)
    tok = np.array([5, 9, 11], np.int32)
    pos = np.array([2, 7, 0], np.int32)

    l_host, kn, vn = M.decode(params, tok, kc, vc, pos, cfg, gv)
    kc_host, vc_host = kc.copy(), vc.copy()
    for bi in range(batch):
        kc_host[:, bi, pos[bi]] = np.asarray(kn)[:, bi]
        vc_host[:, bi, pos[bi]] = np.asarray(vn)[:, bi]

    l_dev, kc_dev, vc_dev = M.decode_resident(params, tok, kc, vc, pos,
                                              cfg, gv)
    np.testing.assert_array_equal(np.asarray(l_dev), np.asarray(l_host))
    np.testing.assert_array_equal(np.asarray(kc_dev), kc_host)
    np.testing.assert_array_equal(np.asarray(vc_dev), vc_host)


def test_decode_resident_consistent_with_score(setup):
    """Let the graph maintain the cache across steps: logits must still
    reproduce full-sequence scoring (the serving-path invariant)."""
    cfg, params = setup
    gv = M.GraphVariant(act="none", rank=0)
    rng = np.random.default_rng(0)
    seq = rng.integers(4, cfg.vocab, size=10).astype(np.int32)
    t_pre = 6

    full = np.asarray(M.score(params, seq[None, :], cfg, gv))[0]

    _, k, v = M.prefill(params, seq[None, :t_pre], cfg, gv)
    kc = jnp.zeros((cfg.layers, 1, cfg.t_max, cfg.d), jnp.float32)
    vc = jnp.zeros_like(kc)
    kc, vc = M.kv_write_prefill(kc, vc, k, v, jnp.int32(0))
    for i in range(t_pre, 10):
        logits, kc, vc = M.decode_resident(
            params, seq[i:i + 1], kc, vc, np.array([i], np.int32), cfg, gv)
        np.testing.assert_allclose(
            np.asarray(logits)[0], full[i], rtol=1e-4, atol=1e-4)


def test_kv_write_prefill_targets_one_slot(setup):
    cfg, _ = setup
    batch, t = 4, 8
    rng = np.random.default_rng(5)
    kc = rng.normal(size=(cfg.layers, batch, cfg.t_max, cfg.d)).astype(
        np.float32)
    vc = kc * 0.5
    kp = rng.normal(size=(cfg.layers, 1, t, cfg.d)).astype(np.float32)
    vp = kp * 2.0
    slot = 2
    kc2, vc2 = M.kv_write_prefill(kc, vc, kp, vp, jnp.int32(slot))
    kc2, vc2 = np.asarray(kc2), np.asarray(vc2)
    # target slot: first t rows replaced, tail untouched
    np.testing.assert_array_equal(kc2[:, slot, :t], kp[:, 0])
    np.testing.assert_array_equal(vc2[:, slot, :t], vp[:, 0])
    np.testing.assert_array_equal(kc2[:, slot, t:], kc[:, slot, t:])
    # other slots untouched
    for other in range(batch):
        if other != slot:
            np.testing.assert_array_equal(kc2[:, other], kc[:, other])
            np.testing.assert_array_equal(vc2[:, other], vc[:, other])


def test_lowered_graphs_have_dynamic_update_slice(setup):
    """The resident entries must lower to HLO with in-graph DUS appends
    and the full caches as outputs."""
    cfg, params = setup
    gv = M.GraphVariant(act="none", rank=0)
    b = 2
    cache = jax.ShapeDtypeStruct((cfg.layers, b, cfg.t_max, cfg.d),
                                 jnp.float32)
    tok = jax.ShapeDtypeStruct((b,), jnp.int32)
    pos = jax.ShapeDtypeStruct((b,), jnp.int32)
    text = aot.lower_graph(
        lambda p, t_, kc, vc, p_: M.decode_resident(p, t_, kc, vc, p_,
                                                    cfg, gv),
        M.param_specs(params), tok, cache, cache, pos)
    assert "HloModule" in text
    assert "dynamic-update-slice" in text
    # updated caches appear as full-shape outputs
    assert "f32[%d,%d,%d,%d]" % (cfg.layers, b, cfg.t_max, cfg.d) in text

    pre = jax.ShapeDtypeStruct((cfg.layers, 1, 6, cfg.d), jnp.float32)
    slot = jax.ShapeDtypeStruct((), jnp.int32)
    text = aot.lower_graph(M.kv_write_prefill, cache, cache, pre, pre, slot)
    assert "dynamic-update-slice" in text
