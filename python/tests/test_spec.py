"""QuantSpec schema: round-tripping, overrides, rejection, golden fixture.

Runs without PJRT or artifacts (quant/spec.py is pure standard library).
"""

import dataclasses
import json
import os

import pytest

from compile.quant import spec
from compile.quant.spec import (Fp16, IntGroup, LayerSpec, LowRank, Mxint,
                                Override, QuantSpec, SpecError)

FIXTURE = os.path.join(os.path.dirname(__file__), "..", "..", "rust",
                       "tests", "fixtures", "quantspec_golden.json")


def test_every_method_roundtrips():
    for name, plan in spec.METHODS.items():
        back = QuantSpec.from_json(plan.to_json())
        assert back == plan, name
        assert spec.from_method_name(name) == plan


def test_sweep_names_resolve():
    p = spec.from_method_name("lqer-w2a8-k8")
    assert p.default.lowrank == LowRank(8, scaled=False)
    p = spec.from_method_name("l2qer-w2a8-k128")
    assert p.default.lowrank == LowRank(128, scaled=True)
    with pytest.raises(SpecError):
        spec.from_method_name("nope")
    # k=0 is not a valid rank (the rust shim rejects it identically).
    with pytest.raises(SpecError):
        spec.from_method_name("l2qer-w2a8-k0")


def test_validate_rejects_zero_rank():
    plan = QuantSpec(default=LayerSpec(weight=Mxint(4), act="mx8",
                                       algo="rtn", lowrank=LowRank(0)))
    with pytest.raises(SpecError, match="lowrank.k"):
        plan.validate()


def test_integral_floats_accepted_like_rust():
    """The rust parser's JSON numbers are all f64, so 4.0 parses as 4
    there; the python parser mirrors that."""
    d = spec.METHODS["l2qer-w4a8"].to_json_dict()
    d["default"]["weight"]["bits"] = 4.0
    d["default"]["lowrank"]["k"] = 16.0
    assert QuantSpec.from_json_dict(d) == spec.METHODS["l2qer-w4a8"]


def test_override_resolution_first_match_wins():
    plan = spec.heterogeneous_example()
    assert plan.resolve("layers.0.fc1").lowrank.k == 32
    assert plan.resolve("layers.7.fc2").lowrank.k == 32
    assert plan.resolve("layers.0.wq").lowrank.k == 8
    assert isinstance(plan.resolve("layers.0.wo").weight, IntGroup)
    assert plan.max_rank() == 32
    back = QuantSpec.from_json(plan.to_json())
    assert back == plan


def test_glob_match():
    assert spec.glob_match("layers.*.fc1", "layers.12.fc1")
    assert not spec.glob_match("layers.*.fc1", "layers.1.fc2")
    assert spec.glob_match("*", "anything")
    assert spec.glob_match("a*b*c", "axxbyyc")
    assert not spec.glob_match("a*b*c", "axxbyy")
    assert spec.glob_match("ab**", "ab")
    assert not spec.glob_match("layers.0.wq", "layers.0.wqx")


def test_rejects_unknown_fields_with_paths():
    plan = spec.METHODS["l2qer-w4a8"].to_json_dict()
    plan["default"]["weight"]["zero_point"] = True
    with pytest.raises(SpecError, match=r"plan\.default\.weight.*zero_point"):
        QuantSpec.from_json_dict(plan)


def test_rejects_mixed_act():
    base = spec.METHODS["l2qer-w4a8"].default
    other = dataclasses.replace(base, act="int8")
    plan = QuantSpec(default=base,
                     overrides=(Override("layers.*.fc1", other),))
    with pytest.raises(SpecError, match="uniform"):
        plan.validate()


def test_rejects_non_ascii_pattern():
    base = spec.METHODS["l2qer-w4a8"].default
    plan = QuantSpec(default=base,
                     overrides=(Override("läyers.*", base),))
    with pytest.raises(SpecError, match="printable ASCII"):
        plan.validate()


def test_rejects_int_algo_on_mxint():
    with pytest.raises(SpecError, match="int weight format"):
        QuantSpec(default=LayerSpec(weight=Mxint(4), act="none",
                                    algo="gptq")).validate()


def test_legacy_dict_coercion():
    legacy = dict(weight=("mxint", 4), act="mx8", algo="rtn",
                  lowrank={"k": 16, "scaled": True})
    assert QuantSpec.coerce(legacy) == spec.METHODS["l2qer-w4a8"]
    legacy_fp = dict(weight=("fp",), act="none", algo="none", lowrank=None)
    assert QuantSpec.coerce(legacy_fp) == spec.METHODS["fp16"]
    assert QuantSpec.coerce("l2qer-w4a8") == spec.METHODS["l2qer-w4a8"]
    # lowrank "bits": None is the fp32-factor ablation, not the default.
    legacy_lrfp = dict(weight=("mxint", 2), act="mx8", algo="rtn",
                       lowrank={"k": 64, "scaled": True, "bits": None})
    assert QuantSpec.coerce(legacy_lrfp) == spec.METHODS["l2qer-w2a8-lrfp"]


def test_legacy_dict_view_roundtrips():
    for name, plan in spec.METHODS.items():
        assert QuantSpec.coerce(plan.default.to_legacy_dict()) == plan, name


def test_avg_bits_formulas():
    assert Fp16().avg_bits() == 16.0
    assert Mxint(4).avg_bits() == 4.25
    assert IntGroup(4, 128).avg_bits() == 4.125
    ls = spec.METHODS["l2qer-w4a8"].default
    want = spec.lqer_avg_bits(256, 256, 16, 4.25, 8.25)
    assert ls.avg_bits(256, 256) == pytest.approx(want, abs=1e-12)
    # fp32 factors cost 32 bits each.
    lrfp = spec.METHODS["l2qer-w2a8-lrfp"].default
    assert lrfp.lowrank.avg_bits() == 32.0


def test_partial_override_inherits_default():
    """An override carrying only ``lowrank: null`` strips the low-rank
    term and inherits weight/act/algo from the default (DESIGN.md §13,
    the draft-plan idiom)."""
    d = spec.METHODS["l2qer-w4a8"].to_json_dict()
    d["overrides"] = [{"match": "layers.*.fc2", "spec": {"lowrank": None}}]
    plan = QuantSpec.from_json_dict(d)
    ov = plan.resolve("layers.1.fc2")
    assert ov.lowrank is None
    assert ov.weight == plan.default.weight
    assert ov.act == plan.default.act
    assert ov.algo == plan.default.algo
    # Canonical emission is the full form; round-trips semantically.
    assert QuantSpec.from_json(plan.to_json()) == plan
    # The default itself must still be complete.
    with pytest.raises(SpecError, match="missing key"):
        QuantSpec.from_json_dict(
            {"version": 1, "default": {"lowrank": None}, "overrides": []})


def test_draft_of_clamps_all_lowrank():
    base = spec.METHODS["l2qer-w4a8"]
    plan = QuantSpec(
        default=base.default,
        overrides=(Override(
            "layers.*.fc1",
            dataclasses.replace(base.default,
                                lowrank=LowRank(32, scaled=True))),),
    ).validate()
    draft = spec.draft_of(plan)
    assert all(ls.lowrank is None for ls in draft.layer_specs())
    assert draft.max_rank() == 0
    assert draft.default.weight == plan.default.weight
    assert draft.overrides[0].match == "layers.*.fc1"
    # The draft streams strictly fewer weight bits.
    shapes = spec.layer_shapes(64, 256, 2)
    assert draft.model_avg_bits(shapes) < plan.model_avg_bits(shapes)
    # Idempotent; a no-op on plans without low-rank terms.
    assert spec.draft_of(draft) == draft
    assert spec.draft_of(spec.METHODS["fp16"]) == spec.METHODS["fp16"]


def test_checked_in_fixture_validates():
    assert os.path.exists(FIXTURE), "golden fixture missing"
    assert spec.check_golden(FIXTURE) == 0


def test_checked_in_fixture_is_current():
    """The fixture must be regenerated whenever the schema changes."""
    with open(FIXTURE) as fh:
        on_disk = json.load(fh)
    assert on_disk == spec.build_golden(), (
        "fixture stale — rerun: python3 python/compile/quant/spec.py "
        "emit-golden rust/tests/fixtures/quantspec_golden.json")
