"""Speculative verify graph (DESIGN.md §13): ``verify_batch`` scores S
consecutive tokens per lane in one graph and must be *bit-identical* to
feeding the same tokens through S sequential ``decode_resident`` steps —
the property that makes speculative acceptance exact rather than
approximate."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


@pytest.fixture(scope="module")
def setup():
    cfg = M.ModelConfig(name="t", vocab=64, d=32, layers=2, heads=2,
                        ffn=64, t_max=24)
    params = M.init_params(cfg, seed=1)
    return cfg, params


def test_verify_batch_matches_sequential_decode(setup):
    cfg, params = setup
    gv = M.GraphVariant(act="none", rank=0)
    rng = np.random.default_rng(7)
    b, s = 2, 4
    # Lanes at different depths; rows < pos are "prefilled" (random —
    # decode only reads them, it never checks how they got there).
    pos = np.array([5, 9], np.int32)
    kc0 = rng.normal(size=(cfg.layers, b, cfg.t_max, cfg.d))
    vc0 = rng.normal(size=(cfg.layers, b, cfg.t_max, cfg.d))
    kc0, vc0 = kc0.astype(np.float32), vc0.astype(np.float32)
    tokens = rng.integers(0, cfg.vocab, size=(b, s)).astype(np.int32)

    # Sequential reference: S decode_resident steps, one token at a time.
    kc, vc = jnp.asarray(kc0), jnp.asarray(vc0)
    ref = []
    for j in range(s):
        logits, kc, vc = M.decode_resident(
            params, tokens[:, j], kc, vc, pos + j, cfg, gv)
        ref.append(np.asarray(logits))

    out, kc_v, vc_v = M.verify_batch(
        params, tokens, jnp.asarray(kc0), jnp.asarray(vc0), pos, cfg, gv)

    np.testing.assert_array_equal(np.asarray(out),
                                  np.stack(ref, axis=1))
    np.testing.assert_array_equal(np.asarray(kc_v), np.asarray(kc))
    np.testing.assert_array_equal(np.asarray(vc_v), np.asarray(vc))
    # All S K/V rows landed; rows outside [pos, pos+S) are untouched.
    for lane in range(b):
        changed = np.any(np.asarray(kc_v)[:, lane] != kc0[:, lane],
                         axis=(0, 2))
        assert not changed[:pos[lane]].any()
        assert changed[pos[lane]:pos[lane] + s].all()
        assert not changed[pos[lane] + s:].any()


def test_verify_batch_lanes_are_independent(setup):
    """Lane b's logits and K/V rows must not depend on any other lane's
    window, cache, or position — the property that lets the engine pack
    heterogeneous per-lane windows (padded with dead rows) into ONE
    batched verify launch per tick (DESIGN.md §13)."""
    cfg, params = setup
    gv = M.GraphVariant(act="none", rank=0)
    rng = np.random.default_rng(21)
    b, s, keep = 3, 4, 1
    pos = np.array([2, 7, 11], np.int32)
    kc0 = rng.normal(
        size=(cfg.layers, b, cfg.t_max, cfg.d)).astype(np.float32)
    vc0 = rng.normal(
        size=(cfg.layers, b, cfg.t_max, cfg.d)).astype(np.float32)
    tokens = rng.integers(0, cfg.vocab, size=(b, s)).astype(np.int32)

    out, kc_v, vc_v = M.verify_batch(
        params, tokens, jnp.asarray(kc0), jnp.asarray(vc0), pos, cfg, gv)

    # Scramble every lane except `keep`: different tokens, caches, and
    # positions — the garbage a padded batched launch would carry.
    tokens2 = rng.integers(0, cfg.vocab, size=(b, s)).astype(np.int32)
    pos2 = np.array([9, 0, 3], np.int32)
    kc2 = rng.normal(
        size=(cfg.layers, b, cfg.t_max, cfg.d)).astype(np.float32)
    vc2 = rng.normal(
        size=(cfg.layers, b, cfg.t_max, cfg.d)).astype(np.float32)
    tokens2[keep], pos2[keep] = tokens[keep], pos[keep]
    kc2[:, keep], vc2[:, keep] = kc0[:, keep], vc0[:, keep]

    out2, kc_v2, vc_v2 = M.verify_batch(
        params, tokens2, jnp.asarray(kc2), jnp.asarray(vc2), pos2, cfg,
        gv)
    np.testing.assert_array_equal(np.asarray(out)[keep],
                                  np.asarray(out2)[keep])
    np.testing.assert_array_equal(np.asarray(kc_v)[:, keep],
                                  np.asarray(kc_v2)[:, keep])
    np.testing.assert_array_equal(np.asarray(vc_v)[:, keep],
                                  np.asarray(vc_v2)[:, keep])


def test_verify_batch_s1_is_one_decode_step(setup):
    cfg, params = setup
    gv = M.GraphVariant(act="none", rank=0)
    rng = np.random.default_rng(13)
    kc0 = rng.normal(size=(cfg.layers, 1, cfg.t_max, cfg.d))
    kc0 = kc0.astype(np.float32)
    vc0 = np.zeros_like(kc0)
    pos = np.array([3], np.int32)
    tok = np.array([[17]], np.int32)

    ref, kc, vc = M.decode_resident(
        params, tok[:, 0], jnp.asarray(kc0), jnp.asarray(vc0), pos,
        cfg, gv)
    out, kc_v, vc_v = M.verify_batch(
        params, tok, jnp.asarray(kc0), jnp.asarray(vc0), pos, cfg, gv)
    np.testing.assert_array_equal(np.asarray(out)[:, 0], np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(kc_v), np.asarray(kc))
    np.testing.assert_array_equal(np.asarray(vc_v), np.asarray(vc))
