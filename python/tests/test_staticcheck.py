"""Tests for scripts/staticcheck (DESIGN.md §14).

Strategy: build a *synthetic fixture tree* that replicates the repo
layout with minimal internally-consistent surfaces, assert every pass
reports zero findings on it, then inject one known drift per pass and
assert the documented finding code fires.  The fixtures are
deliberately tiny — they prove the extraction logic, while the runner
test at the bottom proves the passes hold on the real repo.
"""

import os
import json
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))
SC_DIR = os.path.join(REPO, "scripts", "staticcheck")
if SC_DIR not in sys.path:
    sys.path.insert(0, SC_DIR)

import p1_mirror  # noqa: E402
import p2_manifest  # noqa: E402
import p3_metrics  # noqa: E402
import p4_cli  # noqa: E402
import p5_backend  # noqa: E402
import p6_registry  # noqa: E402
import p7_docs  # noqa: E402
import sccore  # noqa: E402

# ---------------------------------------------------------------------------
# fixture tree
# ---------------------------------------------------------------------------

PY_SPEC = '''\
LOWRANK_DEFAULT_BITS = 8
ACTS = ("none", "mx8")
ALGOS = ("none", "rtn", "gptq")
INT_ONLY_ALGOS = ("gptq",)


class Fp16:
    pass


class Mxint:
    bits: int
    exp_bits: int = 4
    block: int = 16


class LowRank:
    k: int
    scaled: bool = False
    bits: int | None = LOWRANK_DEFAULT_BITS


class SpecError(ValueError):
    pass


def _parse_weight(obj, path):
    _check_keys(obj, ("kind", "bits"), path)
    bits = _int(_field(obj, "bits", path), f"{path}.bits", 2, 8)
    if bits is None:
        raise SpecError(f"{path}: expected an integer in [2, 8]")
    return bits


def from_method_name(name):
    if name not in METHODS:
        raise SpecError(f"unknown method name '{name}'")
    return METHODS[name]


METHODS: dict = {
    "fp16": _plan(Fp16(), "none", "none"),
    "mxint-w4a8": _plan(Mxint(4), "mx8", "rtn"),
    "l2qer-w4a8": _plan(Mxint(4), "mx8", "rtn",
                        LowRank(16, scaled=True)),
}
'''

RS_SPEC = '''\
pub const LOWRANK_DEFAULT_BITS: u32 = 8;

pub enum ActFormat { None, Mx8 }

impl ActFormat {
    pub fn as_str(&self) -> &'static str {
        match self {
            ActFormat::None => "none",
            ActFormat::Mx8 => "mx8",
        }
    }
}

pub enum Algo { None, Rtn, Gptq }

impl Algo {
    pub fn as_str(&self) -> &'static str {
        match self {
            Algo::None => "none",
            Algo::Rtn => "rtn",
            Algo::Gptq => "gptq",
        }
    }

    pub fn needs_int_weights(&self) -> bool {
        matches!(self, Algo::Gptq)
    }
}

fn mx(bits: u32) -> WeightFormat {
    WeightFormat::Mxint { bits, exp_bits: 4, block: 16 }
}

fn lr(k: u32, scaled: bool) -> Option<LowRank> {
    Some(LowRank { k, scaled, bits: Some(LOWRANK_DEFAULT_BITS) })
}

fn parse_weight(v: &Value, path: &str) -> Result<i64> {
    check_keys(v, &["kind", "bits"], path)?;
    let bits = int_field(v, "bits", path, 2, 8)?;
    if bits < 0 {
        bail!("{path}: expected an integer in [2, 8]");
    }
    Ok(bits)
}

pub fn method_registry(name: &str) -> Result<Plan> {
    use ActFormat::{Mx8, None as ANone};
    use Algo::{None as GNone, Rtn};
    Ok(match name {
        "fp16" => plan(WeightFormat::Fp16, ANone, GNone, None),
        "mxint-w4a8" => plan(mx(4), Mx8, Rtn, None),
        "l2qer-w4a8" => plan(mx(4), Mx8, Rtn, lr(16, true)),
        _ => bail!("unknown method name '{name}'"),
    })
}
'''

PY_AOT = '''\
def dataclasses_dict(cfg):
    return {"name": cfg.name, "vocab": cfg.vocab, "t_max": cfg.t_max}


def stage_quant(run_index):
    entry = {"model": "m", "method": "fp16", "weights": "w.bin"}
    run_index.append(entry)


def stage_hlo(graph_index):
    needed = {}
    needed[("m", "tag", "score", 4, 96)] = 1
    needed[("m", "tag", "decode", 4, 0)] = 1
    for key in sorted(needed):
        graph_index.append({"model": "m", "entry": key[2], "b": key[3],
                            "t": key[4], "path": "x.hlo"})


def main(trained, models, run_index, graph_index):
    serve = {"model": "m", "methods": ["fp16"]}
    serve["paged"] = {"block_size": 16}
    manifest = {
        "created": "now",
        "models": {name: {**dataclasses_dict(trained[name]),
                          "n_params": 10} for name in models},
        "runs": run_index,
        "graphs": graph_index,
        "serve": serve,
    }
    return manifest
'''

RS_CONFIG = '''\
impl Manifest {
    fn from_value(v: &Value) -> Result<Manifest> {
        let created = v.get("created");
        for (name, m) in obj_entries(v.req("models")?, "models")? {
            let _ = m.get("name");
            let _ = m.usize_at("vocab")?;
            let _ = m.usize_at("t_max")?;
            let _ = m.usize_at("n_params")?;
        }
        for r in arr_entries(v.req("runs")?, "runs")? {
            let _ = r.str_at("model")?;
            let _ = r.str_at("method")?;
            let _ = r.str_at("weights")?;
        }
        for g in arr_entries(v.req("graphs")?, "graphs")? {
            let _ = g.str_at("entry")?;
            let _ = g.usize_at("b")?;
            let _ = g.usize_at("t")?;
            let _ = g.str_at("path")?;
        }
        let sv = v.req("serve")?;
        let _ = sv.str_at("model")?;
        let _ = sv.req("methods")?;
        if let Some(p) = sv.get("paged") {
            let _ = p.usize_at("block_size")?;
        }
        Ok(Manifest)
    }
}
'''

RS_RUNTIME = '''\
impl ModelRunner {
    fn outputs_for(entry: &str) -> usize {
        match entry {
            "score" => 1,
            "decode" => 3,
            _ => 1,
        }
    }
}
'''

RS_METRICS = '''\
pub struct EngineMetrics {
    pub completed: u64,
    pub decode_ns: u64,
    pub ttft_ms: LatencyHistogram,
    pub exec: ExecStats,
}

impl EngineMetrics {
    pub fn report(&self) -> String {
        format!("done {} | {:.1} tok/s | ttft p50 {:.0}",
                self.completed, self.decode_tokens_per_sec(),
                self.ttft_ms.percentile(50.0))
    }
}
'''

RS_SERVER = '''\
fn route(req: &HttpRequest) -> String {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/metrics") => http_response(
            &json::obj(vec![
                ("completed", json::num(m.completed as f64)),
                ("decode_tok_per_sec",
                 json::num(m.decode_tokens_per_sec())),
                ("ttft_ms_p50", json::num(m.ttft_ms.percentile(50.0))),
            ])
            .to_string(),
        ),
        _ => http_response(404),
    }
}
'''

RS_TRACE = '''\
pub enum TraceEvent {
    Admitted { blocks: usize },
    Decoded,
    Finished { reason: FinishReason },
}

impl TraceEvent {
    pub fn kind(&self) -> &'static str {
        match self {
            TraceEvent::Admitted { .. } => "admitted",
            TraceEvent::Decoded => "decoded",
            TraceEvent::Finished { .. } => "finished",
        }
    }
}
'''

DESIGN_MD = '''\
# fixture design notes

## §14 Static consistency

Registry of drift passes.

## §15 Flight recorder

| event | meaning |
| --- | --- |
| `Admitted` | request joined a lane |
| `Decoded` | one decode step committed a token |
| `Finished` | terminal transition |
'''

RS_MAIN = '''\
fn serve(argv: &[String]) -> Result<()> {
    let a = Args::new("serve", "HTTP serving frontend")
        .opt("model", "m", "model name")
        .opt("max-prefill-per-step", "", "deprecated alias for budget")
        .flag("paged", "paged KV")
        .parse(argv)?;
    Ok(())
}

fn generate(argv: &[String]) -> Result<()> {
    let a = Args::new("generate", "one request")
        .opt("model", "m", "model name")
        .opt("prompt", "the", "prompt text")
        .opt("max-prefill-per-step", "", "deprecated alias for budget")
        .flag("paged", "paged KV")
        .parse(argv)?;
    Ok(())
}

fn serve_bench(argv: &[String]) -> Result<()> {
    let a = Args::new("serve-bench", "load test")
        .opt("model", "m", "model name")
        .opt("max-prefill-per-step", "", "deprecated alias for budget")
        .flag("paged", "paged KV")
        .parse(argv)?;
    Ok(())
}

fn bench_kv(a: &Args) -> Result<()> {
    let out = json::obj(vec![
        ("completed", json::num(1.0)),
        ("rejected", json::num(0.0)),
        ("tokens_per_sec", json::num(1.0)),
    ]);
    Ok(())
}
'''

RS_BACKEND = '''\
pub trait DecodeBackend {
    fn vocab(&self) -> usize;
    fn decode(&mut self) -> Result<Vec<f32>>;
    fn supports_paged(&self) -> bool {
        false
    }
    fn supports_block_ops(&self) -> bool {
        false
    }
    fn supports_speculation(&self) -> bool {
        false
    }
    fn prefill_chunk_paged(&mut self) -> Result<()> {
        bail!("backend has no paged KV backing")
    }
    fn decode_paged(&mut self) -> Result<Vec<f32>> {
        bail!("backend has no paged KV backing")
    }
    fn copy_block(&mut self) -> Result<()> {
        bail!("backend has no block ops")
    }
    fn export_block(&mut self) -> Result<()> {
        bail!("backend has no block ops")
    }
    fn import_block(&mut self) -> Result<()> {
        bail!("backend has no block ops")
    }
    fn draft_step(&mut self) -> Result<()> {
        bail!("backend has no speculation")
    }
    fn verify_tokens(&mut self) -> Result<()> {
        bail!("backend has no speculation")
    }
    fn draft_step_batch(&mut self) -> Result<Vec<f32>> {
        bail!("backend has no batched speculation")
    }
    fn verify_tokens_batch(&mut self) -> Result<Vec<f32>> {
        bail!("backend has no batched speculation")
    }
}

pub struct FakeBackend;

impl DecodeBackend for FakeBackend {
    fn vocab(&self) -> usize {
        7
    }
    fn decode(&mut self) -> Result<Vec<f32>> {
        Ok(vec![])
    }
    fn supports_paged(&self) -> bool {
        true
    }
    fn prefill_chunk_paged(&mut self) -> Result<()> {
        Ok(())
    }
    fn decode_paged(&mut self) -> Result<Vec<f32>> {
        Ok(vec![])
    }
}
'''

BENCH_GUARD = '''\
HIGHER_IS_BETTER = {"completed", "tokens_per_sec"}
LOWER_IS_BETTER = {"rejected"}
'''

CARGO_TOML = '''\
[package]
name = "fixture"

[[test]]
name = "integration"
path = "rust/tests/integration.rs"
'''

README_MD = '''\
# fixture

Serving quickstart; drift passes are indexed in DESIGN.md §14.

## CLI

| flag | meaning |
| --- | --- |
| `--model` | model name |
| `--prompt` | prompt text |
| `--paged` | paged KV |
| `--max-prefill-per-step` | deprecated alias |

## HTTP

`GET /metrics` returns the engine counters as JSON.
'''

TREE = {
    "python/compile/quant/spec.py": PY_SPEC,
    "python/compile/aot.py": PY_AOT,
    "rust/src/quant/spec.rs": RS_SPEC,
    "rust/src/config/mod.rs": RS_CONFIG,
    "rust/src/runtime/mod.rs": RS_RUNTIME,
    "rust/src/coordinator/metrics.rs": RS_METRICS,
    "rust/src/coordinator/server.rs": RS_SERVER,
    "rust/src/coordinator/backend.rs": RS_BACKEND,
    "rust/src/coordinator/trace.rs": RS_TRACE,
    "rust/src/main.rs": RS_MAIN,
    "DESIGN.md": DESIGN_MD,
    "scripts/bench_guard.py": BENCH_GUARD,
    "Cargo.toml": CARGO_TOML,
    "rust/tests/integration.rs": "fn main() {}\n",
    "README.md": README_MD,
    "BENCH_baseline.json": json.dumps(
        {"bench": {"paged": {"completed": 4, "rejected": 0,
                             "tokens_per_sec": 0.0}}}),
}

ALL_PASSES = [p1_mirror, p2_manifest, p3_metrics, p4_cli, p5_backend,
              p6_registry, p7_docs]


@pytest.fixture()
def tree(tmp_path):
    for rel, content in TREE.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(content)
    return tmp_path


def mutate(tree, rel, old, new):
    p = tree / rel
    text = p.read_text()
    assert old in text, f"mutation anchor missing in {rel}: {old!r}"
    p.write_text(text.replace(old, new))


def codes(findings):
    return sorted(f.code for f in findings)


def keys(findings):
    return sorted(f.key for f in findings)


# ---------------------------------------------------------------------------
# clean tree: zero findings everywhere
# ---------------------------------------------------------------------------


def test_clean_tree_has_zero_findings(tree):
    for mod in ALL_PASSES:
        found = mod.run(str(tree))
        assert found == [], (
            f"{mod.PASS_ID} on the clean fixture: "
            + "; ".join(f.render() for f in found))


# ---------------------------------------------------------------------------
# one injected drift per pass -> the documented code fires
# ---------------------------------------------------------------------------


def test_p1_renamed_enum_variant_fires_sc101(tree):
    # rust renames the mx8 act format: drift on both sides of the set.
    mutate(tree, "rust/src/quant/spec.rs",
           'ActFormat::Mx8 => "mx8",', 'ActFormat::Mx8 => "mx9",')
    found = p1_mirror.run(str(tree))
    assert "SC101:acts:mx8" in keys(found)
    assert "SC101:acts:mx9" in keys(found)
    assert codes(found) == ["SC101", "SC101"]


def test_p1_dropped_method_fires_sc104(tree):
    mutate(tree, "rust/src/quant/spec.rs",
           '"mxint-w4a8" => plan(mx(4), Mx8, Rtn, None),', "")
    assert "SC104:py:mxint-w4a8" in keys(p1_mirror.run(str(tree)))


def test_p1_default_drift_fires_sc104_plan(tree):
    # rust changes the Mxint block default: every mx() method drifts.
    mutate(tree, "rust/src/quant/spec.rs", "block: 16", "block: 32")
    found = keys(p1_mirror.run(str(tree)))
    assert "SC104:plan:mxint-w4a8" in found
    assert "SC104:plan:l2qer-w4a8" in found


def test_p1_message_drift_fires_sc105(tree):
    mutate(tree, "rust/src/quant/spec.rs",
           'bail!("{path}: expected an integer in [2, 8]")',
           'bail!("{path}: expected an int in [2, 8]")')
    found = codes(p1_mirror.run(str(tree)))
    assert found == ["SC105", "SC105"], found


def test_p1_constant_drift_fires_sc106(tree):
    mutate(tree, "python/compile/quant/spec.py",
           "LOWRANK_DEFAULT_BITS = 8", "LOWRANK_DEFAULT_BITS = 6")
    found = p1_mirror.run(str(tree))
    assert "SC106:LOWRANK_DEFAULT_BITS" in keys(found)


def test_p2_dropped_consumer_fires_sc201(tree):
    mutate(tree, "rust/src/config/mod.rs",
           'let created = v.get("created");', "")
    assert "SC201:created" in keys(p2_manifest.run(str(tree)))


def test_p2_orphan_consumer_fires_sc202(tree):
    mutate(tree, "rust/src/config/mod.rs",
           'let _ = sv.str_at("model")?;',
           'let _ = sv.str_at("model")?;\n'
           '        let _ = sv.get("spec");')
    assert "SC202:spec" in keys(p2_manifest.run(str(tree)))


def test_p2_entry_kind_drift_fires_sc203(tree):
    mutate(tree, "python/compile/aot.py",
           'needed[("m", "tag", "decode", 4, 0)] = 1',
           'needed[("m", "tag", "decode", 4, 0)] = 1\n'
           '    needed[("m", "tag", "decode_draft", 4, 0)] = 1')
    assert "SC203:py:decode_draft" in keys(p2_manifest.run(str(tree)))


def test_p3_unreported_metric_fires_sc301_and_sc302(tree):
    mutate(tree, "rust/src/coordinator/metrics.rs",
           "pub completed: u64,",
           "pub completed: u64,\n    pub preemptions: u64,")
    found = keys(p3_metrics.run(str(tree)))
    assert "SC301:preemptions" in found
    assert "SC302:preemptions" in found


def test_p3_missing_bench_key_fires_sc303(tree):
    mutate(tree, "rust/src/main.rs",
           '("tokens_per_sec", json::num(1.0)),', "")
    found = keys(p3_metrics.run(str(tree)))
    assert "SC303:BENCH_baseline.json:tokens_per_sec" in found


def test_p3_undocumented_trace_variant_fires_sc304(tree):
    # DESIGN.md §15 loses the Decoded row: the taxonomy drifts.
    mutate(tree, "DESIGN.md",
           "| `Decoded` | one decode step committed a token |\n", "")
    found = keys(p3_metrics.run(str(tree)))
    assert "SC304:Decoded" in found
    assert "SC305:Decoded" not in found


def test_p3_unserialized_trace_variant_fires_sc305(tree):
    # kind() drops its Decoded arm: the variant vanishes from GET /trace.
    mutate(tree, "rust/src/coordinator/trace.rs",
           '            TraceEvent::Decoded => "decoded",\n', "")
    found = keys(p3_metrics.run(str(tree)))
    assert "SC305:Decoded" in found
    assert "SC304:Decoded" not in found


def test_p4_missing_cli_flag_fires_sc401(tree):
    mutate(tree, "rust/src/main.rs",
           '        .flag("paged", "paged KV")\n        .parse(argv)?;\n'
           '    Ok(())\n}\n\nfn serve_bench',
           '        .parse(argv)?;\n    Ok(())\n}\n\nfn serve_bench')
    assert "SC401:paged:generate" in keys(p4_cli.run(str(tree)))


def test_p4_alias_drift_fires_sc402(tree):
    mutate(tree, "rust/src/main.rs",
           '    let a = Args::new("serve-bench", "load test")\n'
           '        .opt("model", "m", "model name")\n'
           '        .opt("max-prefill-per-step", "", '
           '"deprecated alias for budget")',
           '    let a = Args::new("serve-bench", "load test")\n'
           '        .opt("model", "m", "model name")\n'
           '        .opt("max-prefill-per-step", "", "alias for budget")')
    found = keys(p4_cli.run(str(tree)))
    assert "SC402:max-prefill-per-step:serve-bench:unmarked" in found


def test_p5_ungated_backend_method_fires_sc503(tree):
    # FakeBackend claims supports_paged but drops a gated override.
    mutate(tree, "rust/src/coordinator/backend.rs",
           "    fn decode_paged(&mut self) -> Result<Vec<f32>> {\n"
           "        Ok(vec![])\n    }\n", "")
    found = keys(p5_backend.run(str(tree)))
    assert "SC503:FakeBackend:decode_paged" in found


def test_p5_new_bail_method_without_gate_fires_sc501(tree):
    mutate(tree, "rust/src/coordinator/backend.rs",
           "pub struct FakeBackend;",
           "pub struct FakeBackend;\n"
           "pub trait Extra {}\n")
    mutate(tree, "rust/src/coordinator/backend.rs",
           "    fn vocab(&self) -> usize;",
           "    fn vocab(&self) -> usize;\n"
           "    fn fork_lane(&mut self) -> Result<()> {\n"
           "        bail!(\"backend cannot fork\")\n    }")
    assert "SC501:fork_lane" in keys(p5_backend.run(str(tree)))


def test_p5_partial_batched_spec_override_fires_sc503(tree):
    # An impl that claims supports_speculation must override ALL four
    # gated spec methods — the batched pair included.  Overriding
    # everything but verify_tokens_batch is a finding, not silent drift.
    mutate(tree, "rust/src/coordinator/backend.rs",
           "    fn decode_paged(&mut self) -> Result<Vec<f32>> {\n"
           "        Ok(vec![])\n    }\n}",
           "    fn decode_paged(&mut self) -> Result<Vec<f32>> {\n"
           "        Ok(vec![])\n    }\n"
           "    fn supports_speculation(&self) -> bool {\n"
           "        true\n    }\n"
           "    fn draft_step(&mut self) -> Result<()> {\n"
           "        Ok(())\n    }\n"
           "    fn verify_tokens(&mut self) -> Result<()> {\n"
           "        Ok(())\n    }\n"
           "    fn draft_step_batch(&mut self) -> Result<Vec<f32>> {\n"
           "        Ok(vec![])\n    }\n}")
    found = keys(p5_backend.run(str(tree)))
    assert "SC503:FakeBackend:verify_tokens_batch" in found
    assert "SC503:FakeBackend:draft_step_batch" not in found


def test_p5_ungated_batched_spec_method_fires_sc501(tree):
    # A batched spec method whose bail! default is not listed in GATES
    # would let an unsupported backend panic at runtime instead of
    # being refused at config time.
    mutate(tree, "rust/src/coordinator/backend.rs",
           "    fn vocab(&self) -> usize;",
           "    fn vocab(&self) -> usize;\n"
           "    fn draft_tree_batch(&mut self) -> Result<Vec<f32>> {\n"
           "        bail!(\"backend has no tree speculation\")\n    }")
    assert "SC501:draft_tree_batch" in keys(p5_backend.run(str(tree)))


def test_p5_panic_macro_fires_sc502(tree):
    mutate(tree, "rust/src/runtime/mod.rs",
           '"decode" => 3,', '"decode" => todo!("later"),')
    found = keys(p5_backend.run(str(tree)))
    assert "SC502:rust/src/runtime/mod.rs:todo!" in found


def test_p6_unregistered_test_fires_sc601(tree):
    (tree / "rust" / "tests" / "extra.rs").write_text("fn main() {}\n")
    found = keys(p6_registry.run(str(tree)))
    assert "SC601:rust/tests/extra.rs" in found


def test_p6_dangling_entry_fires_sc604(tree):
    (tree / "rust" / "tests" / "integration.rs").unlink()
    found = keys(p6_registry.run(str(tree)))
    assert "SC604:integration" in found


def test_p7_undocumented_flag_fires_sc701(tree):
    mutate(tree, "README.md", "| `--paged` | paged KV |\n", "")
    found = keys(p7_docs.run(str(tree)))
    assert "SC701:paged" in found


def test_p7_undocumented_route_fires_sc702(tree):
    mutate(tree, "README.md",
           "`GET /metrics` returns the engine counters as JSON.\n", "")
    found = keys(p7_docs.run(str(tree)))
    assert "SC702:GET:/metrics" in found


def test_p7_dangling_design_reference_fires_sc703(tree):
    # Only the section number is swapped so this source file never
    # contains the dangling `DESIGN.md §N` literal SC703 scans for.
    mutate(tree, "README.md", "§14", "§99")
    found = keys(p7_docs.run(str(tree)))
    assert "SC703:README.md:99" in found


def test_p7_stale_doc_flag_fires_sc704(tree):
    mutate(tree, "README.md", "| `--paged` | paged KV |",
           "| `--paged` | paged KV |\n| `--turbo` | removed long ago |")
    found = keys(p7_docs.run(str(tree)))
    assert "SC704:README.md:turbo" in found


# ---------------------------------------------------------------------------
# framework: allowlist plumbing
# ---------------------------------------------------------------------------


def test_allowlist_requires_justification_and_flags_stale(tmp_path):
    path = tmp_path / "allow.txt"
    path.write_text("SC101:acts:mx8  # known, tracked in #42\n"
                    "SC104:py:bare-key\n")
    allow = sccore.Allowlist.load(str(path))
    assert [f.code for f in allow.problems] == ["SC002"]
    hit = sccore.finding("SC101", "acts:mx8", "drift")
    miss = sccore.finding("SC999", "other", "kept")
    active, suppressed, stale = allow.split([hit, miss])
    assert [f.key for f in suppressed] == ["SC101:acts:mx8"]
    assert [f.key for f in active] == ["SC999:other"]
    assert stale == ["SC104:py:bare-key"]


def test_missing_surface_reports_sc001(tree):
    (tree / "rust" / "src" / "quant" / "spec.rs").unlink()
    found = p1_mirror.run(str(tree))
    assert [f.code for f in found] == ["SC001"]


# ---------------------------------------------------------------------------
# the real repo passes through the checked-in runner + allowlist
# ---------------------------------------------------------------------------


def test_real_repo_is_clean_via_runner():
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "scripts", "staticcheck")],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "staticcheck: OK" in proc.stdout


def test_back_compat_shim_still_works():
    proc = subprocess.run(
        [sys.executable,
         os.path.join(REPO, "scripts", "check_test_registry.py")],
        capture_output=True, text=True, cwd=REPO)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "check_test_registry: OK" in proc.stdout
