"""Trainer: the hand-rolled AdamW must actually learn, and checkpoints
must round-trip through the flat npz format."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M, train as T


@pytest.fixture(scope="module")
def tiny(dataset):
    cfg = M.ModelConfig(name="t", vocab=dataset.vocab.size, d=32,
                        layers=1, heads=2, ffn=64, t_max=64)
    return cfg, dataset


def test_update_steps_reduce_loss(tiny):
    cfg, ds = tiny
    params = jax.tree_util.tree_map(jnp.asarray, M.init_params(cfg, 0))
    m = jax.tree_util.tree_map(jnp.zeros_like, params)
    v = jax.tree_util.tree_map(jnp.zeros_like, params)
    update = T.make_update_step(cfg, 3e-3, 60)
    gen = T.batches(ds.train, batch=8, seq=32, seed=1)
    losses = []
    for step in range(60):
        params, m, v, loss, gnorm = update(params, m, v, float(step),
                                           next(gen))
        losses.append(float(loss))
        assert np.isfinite(float(loss))
        assert float(gnorm) >= 0
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.5, \
        f"no learning: {losses[:3]} -> {losses[-3:]}"


def test_cross_entropy_ignores_pad(tiny):
    cfg, _ = tiny
    params = M.init_params(cfg, 0)
    toks = np.full((1, 9), 5, np.int32)
    base = float(T.cross_entropy(params, toks, cfg))
    # replacing a target with PAD must drop it from the average
    toks_pad = toks.copy()
    toks_pad[0, 4] = 0
    padded = float(T.cross_entropy(params, toks_pad, cfg))
    assert np.isfinite(base) and np.isfinite(padded)
    assert padded != pytest.approx(base)


def test_batches_shapes_and_determinism(tiny):
    _, ds = tiny
    g1 = T.batches(ds.train, batch=4, seq=16, seed=9)
    g2 = T.batches(ds.train, batch=4, seq=16, seed=9)
    b1, b2 = next(g1), next(g2)
    assert b1.shape == (4, 17)  # seq + 1 for the shifted targets
    np.testing.assert_array_equal(b1, b2)


def test_save_load_roundtrip(tiny, tmp_path):
    cfg, _ = tiny
    params = M.init_params(cfg, seed=4)
    T.save_params(params, str(tmp_path))
    loaded = T.load_params(str(tmp_path), cfg)
    for (n1, a), (n2, b) in zip(M.flatten_with_names(params),
                                M.flatten_with_names(loaded)):
        assert n1 == n2
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_eval_ppl_finite(tiny):
    cfg, ds = tiny
    params = M.init_params(cfg, 0)
    ppl = T.eval_ppl(params, ds.val, cfg, batch=2, seq=32, n_batches=2)
    # untrained model ~ uniform: ppl near vocab size
    assert 50 < ppl < 2000
