//! Ablations over the design choices DESIGN.md calls out:
//!
//!  * b_h — precision of the low-rank factors A_k/B_k (paper fixes 8-bit
//!    MXINT; we sweep {4, 8, fp32}),
//!  * k   — reconstruction rank at the W2A8 stress setting (16 vs 64),
//!  * S   — the activation-induced scaling (LQER vs L²QER at equal k).
//!
//! Usage: `cargo bench --bench ablations [-- --fast]`

use lqer::config::Manifest;
use lqer::eval;
use lqer::runtime::{ModelRunner, Runtime};
use lqer::util::bench::Table;

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let windows = if fast { 4 } else { 12 };
    let m = Manifest::load(&lqer::default_artifacts_dir())
        .expect("run `make artifacts` first");
    let rt = Runtime::cpu().unwrap();
    let stream =
        lqer::util::read_u16_file(&m.data_dir().join("test.u16")).unwrap();
    let model = "opt-mini";

    let rows: &[(&str, &str)] = &[
        ("FP16 reference", "fp16"),
        ("plain MXINT W2A8 (no reconstruction)", "mxint-w2a8"),
        ("LQER k=64 (no S)", "lqer-w2a8"),
        ("L2QER k=16", "l2qer-w2a8-rank16"),
        ("L2QER k=64, b_h=4", "l2qer-w2a8-lr4"),
        ("L2QER k=64, b_h=8 (paper)", "l2qer-w2a8"),
        ("L2QER k=64, b_h=fp32", "l2qer-w2a8-lrfp"),
    ];
    let mut t = Table::new(
        &format!("ablations on {model} (W2A8 stress setting, {windows} \
                  ppl windows)"),
        &["variant", "ppl", "avg w bits"],
    );
    for (label, method) in rows {
        let runner = ModelRunner::new(&m, model, method)
            .unwrap_or_else(|e| panic!("{method}: {e:#}"));
        let r = eval::ppl::perplexity(&rt, &m, &runner, &stream, windows)
            .unwrap();
        let bits = m
            .run(model, method)
            .ok()
            .and_then(|run| m.run_meta(run).ok())
            .and_then(|meta| meta.f64_at("avg_w_bits").ok())
            .unwrap_or(f64::NAN);
        t.row(vec![label.to_string(), format!("{:.3}", r.ppl),
                   format!("{bits:.2}")]);
    }
    print!("{}", t.render());
    println!("\nreading: the factor precision b_h trades ~2 bits/weight \
              of overhead for error-reconstruction fidelity; k trades \
              compute (+(m+n)k MACs) for recovery.");
}
