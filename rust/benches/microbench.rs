//! Microbenchmarks of the L3 substrates (quantizers, SVD, JSON, sampling,
//! KV-cache ops) — the profile base for the §Perf iteration log.
//!
//! Usage: `cargo bench --bench microbench [-- --fast]`

use lqer::kvcache::KvCache;
use lqer::linalg::{svd, Mat};
use lqer::quant::{intq, mxint::MxFormat};
use lqer::util::bench::{Bench, Stats};
use lqer::util::json;
use lqer::util::rng::Rng;

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let b = if fast { Bench::quick() } else { Bench::default() };
    let mut rng = Rng::new(42);
    let mut report: Vec<Stats> = Vec::new();

    // MXINT weight quantization of a mini-sized fc1 (192x768).
    let w: Vec<f32> =
        (0..192 * 768).map(|_| rng.normal() as f32 * 0.3).collect();
    report.push(b.run("mxint4 quant_cols 192x768", || {
        let mut data = w.clone();
        MxFormat::weight(4).quant_cols(&mut data, 768);
        std::hint::black_box(&data);
    }));
    report.push(b.run("mxint8 quant_rows 384x192 (act)", || {
        let mut data = w[..384 * 192].to_vec();
        MxFormat::act(8).quant_rows(&mut data, 192);
        std::hint::black_box(&data);
    }));
    report.push(b.run("int4 g128 quant 192x768", || {
        let mut data = w.clone();
        intq::int_quant_group_cols(&mut data, 768, 4, 128);
        std::hint::black_box(&data);
    }));

    // SVD of a quantization-error-sized matrix.
    let e: Vec<f64> = (0..96 * 192).map(|_| rng.normal() * 0.01).collect();
    let mat = Mat::from_vec(96, 192, e);
    report.push(b.run("jacobi svd 96x192", || {
        std::hint::black_box(svd::singular_values(&mat));
    }));

    // JSON parse of a manifest-sized document.
    let doc = {
        let mut items = Vec::new();
        for i in 0..200 {
            items.push(format!(
                r#"{{"model":"opt-mini","method":"m{i}","graph":"act-mx8_k16","weights":"runs/w{i}.bin","meta":"runs/m{i}.json"}}"#
            ));
        }
        format!(r#"{{"runs":[{}]}}"#, items.join(","))
    };
    report.push(b.run("json parse 200-run manifest", || {
        std::hint::black_box(json::parse(&doc).unwrap());
    }));

    // Sampling from a vocab-sized logits row.
    let logits: Vec<f32> = (0..440).map(|_| rng.normal() as f32).collect();
    let mut srng = Rng::new(1);
    report.push(b.run("top-8 sample from 440 logits", || {
        std::hint::black_box(lqer::coordinator::sample(
            &logits,
            lqer::coordinator::Sampling::TopK {
                k: 8,
                temperature: 0.8,
                seed: 3,
            },
            &mut srng,
        ));
    }));
    report.push(b.run("log_prob over 440 logits", || {
        std::hint::black_box(lqer::eval::log_prob(&logits, 17));
    }));

    // KV-cache append for a mini-sized decode batch.
    let (layers, batch, t_max, d) = (6, 8, 160, 192);
    let mut cache = KvCache::new(layers, batch, t_max, d);
    let slots: Vec<usize> =
        (0..batch).map(|i| cache.alloc(i as u64).unwrap()).collect();
    let k_new = vec![0.1f32; layers * batch * d];
    report.push(b.run("kvcache append_rows L6 B8 d192", || {
        // reset positions by re-alloc when full
        if cache.pos(0) >= t_max {
            for &s in &slots {
                cache.free(s);
            }
            for i in 0..batch {
                cache.alloc(100 + i as u64);
            }
        }
        cache.append_rows(&slots, &k_new, &k_new).unwrap();
    }));

    println!("\n== microbench ==");
    for s in &report {
        println!("{}", s.report());
    }
}
