//! Regenerates the paper's *figures* as printed series (DESIGN.md §4):
//!
//!   Figure 1a — singular-value spectra of E_q vs S·E_q (rust SVD)
//!   Figure 3  — perplexity vs rank k, LQER vs L²QER
//!   Figure 4  — per-layer approximation error e_a (Eq. 15)
//!
//! Usage: `cargo bench --bench paper_figures [-- --fig 1a|3|4] [-- --fast]`

use lqer::analysis;
use lqer::config::Manifest;
use lqer::eval;
use lqer::runtime::{ModelRunner, Runtime};
use lqer::util::bench::Table;

fn fig1a(m: &Manifest) {
    let s = analysis::fig1a_spectra(&m.dir.join("fig1a"))
        .expect("fig1a artifacts");
    println!("\nFigure 1a — normalized singular values of the W3 \
              quantization error ({})", s.layer);
    let mut t = Table::new(
        "spectra (equal Frobenius norm, paper footnote 1)",
        &["i", "LQER: sigma_i(E_q)", "L2QER: sigma_i(S E_q)"],
    );
    let step = (s.lqer.len() / 24).max(1);
    for i in (0..s.lqer.len()).step_by(step) {
        t.row(vec![i.to_string(), format!("{:.4}", s.lqer[i]),
                   format!("{:.4}", s.l2qer[i])]);
    }
    print!("{}", t.render());
    let mut e = Table::new("top-k energy fraction (steeper = better)",
                           &["k", "LQER", "L2QER"]);
    for k in [4, 8, 16, 32, 64, 128] {
        e.row(vec![
            k.to_string(),
            format!("{:.3}", analysis::Spectra::energy_at(&s.lqer, k)),
            format!("{:.3}", analysis::Spectra::energy_at(&s.l2qer, k)),
        ]);
    }
    print!("{}", e.render());
}

fn fig3(m: &Manifest, windows: usize) {
    let rt = Runtime::cpu().unwrap();
    let stream =
        lqer::util::read_u16_file(&m.data_dir().join("test.u16")).unwrap();
    let model = m.fig3_model.clone();
    let fp16 = {
        let runner = ModelRunner::new(m, &model, "fp16").unwrap();
        eval::ppl::perplexity(&rt, m, &runner, &stream, windows)
            .unwrap()
            .ppl
    };
    let plain = {
        let runner = ModelRunner::new(m, &model, "mxint-w2a8").unwrap();
        eval::ppl::perplexity(&rt, m, &runner, &stream, windows)
            .unwrap()
            .ppl
    };
    println!("\nFigure 3 — perplexity vs rank k ({model}, W2A8; FP16 = \
              {fp16:.3}, plain MXINT = {plain:.3})");
    let mut t = Table::new("ppl vs k", &["k", "LQER", "L2QER"]);
    for &k in &m.fig3_ranks {
        let mut row = vec![k.to_string()];
        for prefix in ["lqer", "l2qer"] {
            let runner = ModelRunner::new(
                m, &model, &format!("{prefix}-w2a8-k{k}")).unwrap();
            let p = eval::ppl::perplexity(&rt, m, &runner, &stream,
                                          windows)
                .unwrap()
                .ppl;
            row.push(format!("{p:.3}"));
        }
        t.row(row);
    }
    print!("{}", t.render());
}

fn fig4(m: &Manifest) {
    println!("\nFigure 4 — per-layer approximation error e_a (Eq. 15), \
              LQER vs L2QER (W2A8, k=64, {})", m.serve.model);
    let lqer_meta = m
        .run_meta(m.run(&m.serve.model, "lqer-w2a8").unwrap())
        .unwrap();
    let l2_meta = m
        .run_meta(m.run(&m.serve.model, "l2qer-w2a8").unwrap())
        .unwrap();
    let e1 = analysis::approx_errors(&lqer_meta);
    let e2 = analysis::approx_errors(&l2_meta);
    let mut t = Table::new("approximation error per linear layer",
                           &["layer", "LQER e_a", "L2QER e_a", "winner"]);
    let mut l2_wins = 0;
    for ((k1, v1), (_, v2)) in e1.iter().zip(&e2) {
        let win = if v2 < v1 { "L2QER" } else { "LQER" };
        if v2 < v1 {
            l2_wins += 1;
        }
        t.row(vec![k1.clone(), format!("{v1:.5}"), format!("{v2:.5}"),
                   win.into()]);
    }
    print!("{}", t.render());
    println!("L2QER reconstructs better on {l2_wins}/{} layers",
             e1.len());
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let fast = args.iter().any(|a| a == "--fast");
    let fig: Option<String> = args
        .iter()
        .position(|a| a == "--fig")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let m = Manifest::load(&lqer::default_artifacts_dir())
        .expect("run `make artifacts` first");
    let want = |f: &str| fig.is_none() || fig.as_deref() == Some(f);
    if want("1a") {
        fig1a(&m);
    }
    if want("3") {
        fig3(&m, if fast { 4 } else { 12 });
    }
    if want("4") {
        fig4(&m);
    }
}
