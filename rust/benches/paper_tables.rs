//! Regenerates every *table* of the paper's evaluation section
//! (DESIGN.md §4 experiment index):
//!
//!   Table 2  — plain MXINT vs LQER vs L²QER perplexity
//!   Table 3  — perplexity across methods + avg weight bits + circuit area
//!   Table 4  — downstream task accuracy (+ per-model Tables 11-18 rows)
//!   Table 5  — AlpacaEval-style pairwise win rate
//!   Table 6  — 2-bit quantization
//!   Tables 7/8/9 — circuit-area breakdowns
//!
//! Usage: `cargo bench --bench paper_tables [-- --table N] [-- --fast]`
//! Absolute numbers come from the synthetic testbed; the *shape* (who
//! wins, by roughly what factor) is the reproduction target.

use std::collections::BTreeMap;

use lqer::config::Manifest;
use lqer::eval;
use lqer::hwcost;
use lqer::runtime::{ModelRunner, Runtime};
use lqer::util::bench::Table;

struct Ctx {
    m: Manifest,
    rt: Runtime,
    stream: Vec<u16>,
    windows: usize,
    per_task: usize,
    judge_n: usize,
    ppl_cache: BTreeMap<(String, String), f64>,
}

impl Ctx {
    fn ppl(&mut self, model: &str, method: &str) -> f64 {
        let key = (model.to_string(), method.to_string());
        if let Some(v) = self.ppl_cache.get(&key) {
            return *v;
        }
        let runner = ModelRunner::new(&self.m, model, method)
            .unwrap_or_else(|e| panic!("{model}/{method}: {e:#}"));
        let r = eval::ppl::perplexity(&self.rt, &self.m, &runner,
                                      &self.stream, self.windows)
            .unwrap();
        self.ppl_cache.insert(key, r.ppl);
        r.ppl
    }

    fn avg_bits(&self, model: &str, method: &str) -> f64 {
        let run = self.m.run(model, method).unwrap();
        self.m
            .run_meta(run)
            .ok()
            .and_then(|v| v.f64_at("avg_w_bits").ok())
            .unwrap_or(f64::NAN)
    }
}

fn models(m: &Manifest) -> Vec<String> {
    m.models.iter().map(|x| x.name.clone()).collect()
}

fn fmt_delta(v: f64, base: f64) -> String {
    format!("{v:.3} ({:+.3})", v - base)
}

fn table2(ctx: &mut Ctx) {
    // Paper Table 2 compares plain/LQER/L2QER at W4A8 on two models.  At
    // toy scale W4 is lossless (reported anyway), so the difficulty-
    // matched W2A8 trio carries the paper's ordering claim.
    for (tag, trio) in [
        ("W4A8 (paper config)",
         ["mxint-w4a8", "lqer-w4a8", "l2qer-w4a8"]),
        ("W2A8 (difficulty-matched)",
         ["mxint-w2a8", "lqer-w2a8", "l2qer-w2a8"]),
    ] {
        let mut t = Table::new(
            &format!("Table 2 — perplexity, {tag}"),
            &["model", "plain MXINT", "LQER", "L2QER", "FP16"],
        );
        for model in models(&ctx.m) {
            let fp = ctx.ppl(&model, "fp16");
            let row: Vec<String> = trio
                .iter()
                .map(|meth| fmt_delta(ctx.ppl(&model, meth), fp))
                .collect();
            t.row(vec![model.clone(), row[0].clone(), row[1].clone(),
                       row[2].clone(), format!("{fp:.3}")]);
        }
        print!("{}", t.render());
    }
}

fn table3(ctx: &mut Ctx) {
    let methods: &[(&str, &str, &str)] = &[
        // (display, method, setup)
        ("FP16", "fp16", "-"),
        ("GPTQ (INT4 g128)", "gptq-w4", "w-only"),
        ("AWQ (INT4 g128)", "awq-w4", "w-only"),
        ("RTN (INT4 g128)", "rtn-w4", "w-only"),
        ("L2QER-INT (W4)", "l2qer-int-w4", "w-only"),
        ("LLM.int4()", "llmint4", "w&a"),
        ("SmoothQuant (W8A8)", "smoothquant-w8a8", "w&a"),
        ("clipq (W6A6)*", "clipq-w6a6", "w&a"),
        ("L2QER-INT (W4A8)", "l2qer-int-w4a8", "w&a"),
        ("L2QER-MXINT (W4A6)", "l2qer-w4a6", "w&a"),
        ("L2QER-MXINT (W4A8)", "l2qer-w4a8", "w&a"),
    ];
    let ms = models(&ctx.m);
    let mut header = vec!["setup", "method"];
    let model_cols: Vec<String> =
        ms.iter().map(|s| s.replace("opt-", "")).collect();
    header.extend(model_cols.iter().map(|s| s.as_str()));
    header.extend(["avg dPPL", "w bits", "area"]);
    let mut t = Table::new(
        "Table 3 — WikiText-style perplexity + memory + circuit area \
         (* clipq = gradient-free OmniQuant stand-in)",
        &header,
    );
    let fp16: Vec<f64> =
        ms.iter().map(|mo| ctx.ppl(mo, "fp16")).collect();
    for (display, method, setup) in methods {
        let mut row = vec![setup.to_string(), display.to_string()];
        let mut dsum = 0.0;
        for (i, mo) in ms.iter().enumerate() {
            let p = ctx.ppl(mo, method);
            dsum += p - fp16[i];
            row.push(format!("{p:.3}"));
        }
        row.push(format!("{:+.3}", dsum / ms.len() as f64));
        row.push(format!("{:.2}", ctx.avg_bits(&ms[0], method)));
        row.push(
            hwcost::area_for_method(method)
                .map(|pe| format!("{:.2}x", pe.relative()))
                .unwrap_or_else(|| "-".into()),
        );
        t.row(row);
    }
    print!("{}", t.render());
}

fn table4(ctx: &mut Ctx, full: bool) {
    let items = eval::tasks::load_tasks(
        &ctx.m.data_dir().join("tasks.json"))
        .unwrap();
    let methods = ["fp16", "gptq-w4", "awq-w4", "llmint4", "clipq-w6a6",
                   "l2qer-int-w4a8", "l2qer-w4a6", "l2qer-w4a8"];
    let ms = models(&ctx.m);
    let mut t = Table::new(
        "Table 4 — average downstream accuracy over six tasks",
        &{
            let mut h = vec!["method"];
            h.extend(ms.iter().map(|s| s.as_str()));
            h.push("avg dAcc");
            h
        },
    );
    let mut fp16_acc = Vec::new();
    let mut rows = Vec::new();
    for method in methods {
        let mut row = vec![method.to_string()];
        let mut accs = Vec::new();
        for mo in &ms {
            let runner = ModelRunner::new(&ctx.m, mo, method).unwrap();
            let scores = eval::tasks::evaluate(
                &ctx.rt, &ctx.m, &runner, &items, ctx.per_task)
                .unwrap();
            if full {
                let mut ft = Table::new(
                    &format!("Tables 11-18 analog — {mo} / {method}"),
                    &["task", "accuracy"],
                );
                for (name, acc, _) in &scores.per_task {
                    ft.row(vec![name.clone(),
                                format!("{:.1}%", acc * 100.0)]);
                }
                print!("{}", ft.render());
            }
            accs.push(scores.average());
            row.push(format!("{:.1}%", scores.average() * 100.0));
        }
        if method == "fp16" {
            fp16_acc = accs.clone();
        }
        let davg: f64 = accs
            .iter()
            .zip(&fp16_acc)
            .map(|(a, f)| a - f)
            .sum::<f64>()
            / accs.len() as f64;
        row.push(format!("{:+.1}%", davg * 100.0));
        rows.push(row);
    }
    for row in rows {
        t.row(row);
    }
    print!("{}", t.render());
}

fn table5(ctx: &Ctx) {
    let model = ctx.m.serve.model.clone();
    let mut t = Table::new(
        "Table 5 — pairwise preference, FP16 judge (AlpacaEval analog)",
        &["pair", "win rate", "length-controlled", "n"],
    );
    for (a, b) in [("l2qer-w4a8", "awq-w4"), ("l2qer-w4a8", "fp16")] {
        let r = lqer::coordinator::loadtest::run_judge(
            &ctx.m, &model, a, b, ctx.judge_n, 16)
            .unwrap();
        t.row(vec![
            format!("{a} vs {b}"),
            format!("{:.1}%", r.win_rate() * 100.0),
            format!("{:.1}%", r.lc_win_rate() * 100.0),
            r.n.to_string(),
        ]);
    }
    print!("{}", t.render());
}

fn table6(ctx: &mut Ctx) {
    let mut t = Table::new(
        "Table 6 — 2-bit quantization perplexity",
        &["method", "setup", "micro", "mini"],
    );
    let pairs = [
        ("FP16", "fp16", "-"),
        ("AWQ (INT2 g128)", "awq-w2", "w-only"),
        ("clipq (INT2 g128)*", "clipq-w2", "w-only"),
        ("L2QER (W2A8, k=64)", "l2qer-w2a8", "w&a"),
    ];
    for (display, method, setup) in pairs {
        t.row(vec![
            display.to_string(),
            setup.to_string(),
            format!("{:.3}", ctx.ppl("opt-micro", method)),
            format!("{:.3}", ctx.ppl("opt-mini", method)),
        ]);
    }
    print!("{}", t.render());
}

fn tables789() {
    for (title, pe) in [
        ("Table 7 — LLM.int4() PE breakdown", hwcost::llmint4_pe()),
        ("Table 8 — AWQ PE breakdown", hwcost::dequant_pe("awq")),
        ("Table 9 — L2QER PE breakdown",
         hwcost::l2qer_pe("l2qer-w4a8", 4, 8, true)),
    ] {
        let mut t = Table::new(title, &["component", "LUTs", "share"]);
        for (name, luts) in &pe.components {
            t.row(vec![name.clone(), format!("{luts:.0}"),
                       format!("{:.1}%", luts / pe.total * 100.0)]);
        }
        t.row(vec!["TOTAL".into(), format!("{:.0}", pe.total),
                   format!("{:.2}x FP16", pe.relative())]);
        print!("{}", t.render());
    }
}

fn opt_cost(ctx: &Ctx) {
    // Section 4.3 "Optimization cost": PTQ seconds per method from the
    // run metadata (vs OmniQuant's hours of gradient training).
    let mut t = Table::new(
        "Optimization cost (PTQ seconds on opt-mini; cf. paper sec 4.3)",
        &["method", "opt seconds"],
    );
    for method in ["mxint-w4a8", "l2qer-w4a8", "gptq-w4", "awq-w4",
                   "clipq-w6a6"] {
        if let Ok(run) = ctx.m.run("opt-mini", method) {
            if let Ok(meta) = ctx.m.run_meta(run) {
                t.row(vec![
                    method.to_string(),
                    format!("{:.2}",
                            meta.f64_at("opt_seconds").unwrap_or(f64::NAN)),
                ]);
            }
        }
    }
    print!("{}", t.render());
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let fast = args.iter().any(|a| a == "--fast");
    let table: Option<u32> = args
        .iter()
        .position(|a| a == "--table")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok());
    let full = args.iter().any(|a| a == "--full");

    let m = Manifest::load(&lqer::default_artifacts_dir())
        .expect("run `make artifacts` first");
    let stream =
        lqer::util::read_u16_file(&m.data_dir().join("test.u16")).unwrap();
    let mut ctx = Ctx {
        rt: Runtime::cpu().unwrap(),
        m,
        stream,
        windows: if fast { 4 } else { 16 },
        per_task: if fast { 8 } else { 24 },
        judge_n: if fast { 8 } else { 24 },
        ppl_cache: BTreeMap::new(),
    };
    let want = |n: u32| table.is_none() || table == Some(n);
    if want(2) {
        table2(&mut ctx);
    }
    if want(3) {
        table3(&mut ctx);
        opt_cost(&ctx);
    }
    if want(4) {
        table4(&mut ctx, full);
    }
    if want(5) {
        table5(&ctx);
    }
    if want(6) {
        table6(&mut ctx);
    }
    if want(7) || want(8) || want(9) || table == Some(789) {
        tables789();
    }
}
