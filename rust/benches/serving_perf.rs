//! Serving performance bench (the prompt-mandated end-to-end driver and
//! the §Perf measurement base): batched load through the engine for the
//! FP16 baseline vs L²QER-W4A8, across decode batch buckets.
//!
//! Reports decode tokens/s, mean step latency, runtime-boundary overhead
//! (upload/download vs execute), and batch-occupancy.
//!
//! Usage: `cargo bench --bench serving_perf [-- --fast]`

use lqer::config::Manifest;
use lqer::coordinator::{loadtest, EngineConfig};
use lqer::util::bench::Table;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let fast = args.iter().any(|a| a == "--fast");
    let m = Manifest::load(&lqer::default_artifacts_dir())
        .expect("run `make artifacts` first");
    let requests = if fast { 8 } else { 24 };
    let max_new = if fast { 12 } else { 24 };

    let mut t = Table::new(
        &format!(
            "serving load test — {} ({requests} requests x {max_new} \
             new tokens)",
            m.serve.model
        ),
        &[
            "method", "batch", "decode tok/s", "step ms", "prefill ms",
            "occupancy", "exec %", "upload %", "download %",
        ],
    );
    for method in m.serve.methods.clone() {
        for &batch in &m.serve.decode_batches.clone() {
            let cfg = EngineConfig {
                model: m.serve.model.clone(),
                method: method.clone(),
                decode_batch: batch,
                prefill_buckets: m
                    .serve
                    .prefill_shapes
                    .iter()
                    .map(|(_, tt)| *tt)
                    .collect(),
                max_prefill_per_step: 2,
            };
            let stats = loadtest::run_loadtest(&m, &cfg, requests, max_new)
                .expect("loadtest");
            let step_ms = if stats.decode_steps > 0 {
                stats.decode_ns as f64 / stats.decode_steps as f64 / 1e6
            } else {
                0.0
            };
            let prefill_ms = if stats.prefill_steps > 0 {
                stats.prefill_ns as f64 / stats.prefill_steps as f64 / 1e6
            } else {
                0.0
            };
            let total_ns = (stats.exec.exec_ns + stats.exec.upload_ns
                + stats.exec.download_ns)
                .max(1);
            t.row(vec![
                method.clone(),
                batch.to_string(),
                format!("{:.0}", stats.decode_tokens_per_sec()),
                format!("{step_ms:.2}"),
                format!("{prefill_ms:.1}"),
                format!("{:.2}", stats.mean_batch_occupancy()),
                format!("{:.0}%",
                        stats.exec.exec_ns as f64 / total_ns as f64 * 100.0),
                format!("{:.0}%",
                        stats.exec.upload_ns as f64 / total_ns as f64
                        * 100.0),
                format!("{:.0}%",
                        stats.exec.download_ns as f64 / total_ns as f64
                        * 100.0),
            ]);
        }
    }
    print!("{}", t.render());
    println!(
        "\nnote: FP16 vs L2QER wall-clock is expected to be ~equal on the \
         CPU PJRT backend (numerics are simulated in f32); the TPU-side \
         win is analytic — see DESIGN.md §8 and EXPERIMENTS.md §Perf-L1."
    );
}
