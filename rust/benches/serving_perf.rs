//! Serving performance bench (the prompt-mandated end-to-end driver and
//! the §Perf measurement base): batched load through the engine for the
//! FP16 baseline vs L²QER-W4A8, across decode batch buckets — and for
//! both KV-cache modes:
//!
//! * `device` — the resident-cache path: per decode step only O(B) token
//!   ids/positions go up and O(B·vocab) logits come down;
//! * `host` — the legacy oracle: the full (L, B, T_max, d) K/V caches
//!   round-trip the PJRT boundary every step, O(L·B·T_max·d) per token.
//!
//! The `B/step` column is the *measured* per-decode-step host↔device
//! traffic (ExecStats byte counters), the headline number of the
//! device-resident refactor.
//!
//! Usage: `cargo bench --bench serving_perf [-- --fast]`

use lqer::config::Manifest;
use lqer::coordinator::{loadtest, EngineConfig};
use lqer::util::bench::Table;

fn fmt_bytes(b: f64) -> String {
    if b >= 1e6 {
        format!("{:.2} MB", b / 1e6)
    } else if b >= 1e3 {
        format!("{:.1} KB", b / 1e3)
    } else {
        format!("{b:.0} B")
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let fast = args.iter().any(|a| a == "--fast");
    let m = Manifest::load(&lqer::default_artifacts_dir())
        .expect("run `make artifacts` first");
    let requests = if fast { 8 } else { 24 };
    let max_new = if fast { 12 } else { 24 };

    let mut t = Table::new(
        &format!(
            "serving load test — {} ({requests} requests x {max_new} \
             new tokens)",
            m.serve.model
        ),
        &[
            "method", "cache", "batch", "decode tok/s", "step ms",
            "B/step", "prefill ms", "occupancy", "exec %", "upload %",
            "download %",
        ],
    );
    for method in m.serve.methods.clone() {
        for &batch in &m.serve.decode_batches.clone() {
            for host_cache in [false, true] {
                let cfg = EngineConfig {
                    model: m.serve.model.clone(),
                    method: method.clone(),
                    decode_batch: batch,
                    prefill_buckets: m
                        .serve
                        .prefill_shapes
                        .iter()
                        .map(|(_, tt)| *tt)
                        .collect(),
                    tokens_per_step: 0, // engine default: batch + largest bucket
                    host_cache,
                    paged: None,
                    spec: None,
                    admission: Default::default(),
                    trace_capacity: 0,
                };
                let stats =
                    loadtest::run_loadtest(&m, &cfg, requests, max_new)
                        .expect("loadtest");
                let step_ms = if stats.decode_steps > 0 {
                    stats.decode_ns as f64 / stats.decode_steps as f64 / 1e6
                } else {
                    0.0
                };
                let prefill_ms = if stats.prefill_steps > 0 {
                    stats.prefill_ns as f64 / stats.prefill_steps as f64
                        / 1e6
                } else {
                    0.0
                };
                let total_ns = (stats.exec.exec_ns + stats.exec.upload_ns
                    + stats.exec.download_ns)
                    .max(1);
                t.row(vec![
                    method.clone(),
                    if host_cache { "host" } else { "device" }.to_string(),
                    batch.to_string(),
                    format!("{:.0}", stats.decode_tokens_per_sec()),
                    format!("{step_ms:.2}"),
                    fmt_bytes(stats.decode_exec.bytes_per_call()),
                    format!("{prefill_ms:.1}"),
                    format!("{:.2}", stats.mean_batch_occupancy()),
                    format!(
                        "{:.0}%",
                        stats.exec.exec_ns as f64 / total_ns as f64 * 100.0
                    ),
                    format!(
                        "{:.0}%",
                        stats.exec.upload_ns as f64 / total_ns as f64
                            * 100.0
                    ),
                    format!(
                        "{:.0}%",
                        stats.exec.download_ns as f64 / total_ns as f64
                            * 100.0
                    ),
                ]);
            }
        }
    }
    print!("{}", t.render());
    println!(
        "\nnote: `device` keeps the (L,B,T_max,d) K/V caches resident and \
         re-feeds the decode outputs as next-step inputs — B/step drops \
         from O(L*B*T_max*d) to O(B*(1+vocab)).  FP16 vs L2QER wall-clock \
         is expected to be ~equal on the CPU PJRT backend (numerics are \
         simulated in f32); the TPU-side win is analytic — see DESIGN.md \
         §8 and EXPERIMENTS.md §Perf-L1."
    );
}
