//! Analysis tooling behind the paper's figures.
//!
//! * Figure 1a: singular-value spectra of E_q vs S·E_q, computed with the
//!   in-crate Jacobi SVD on the exported error matrix of a trained layer.
//! * Figure 4: per-layer approximation error e_a (Eq. 15), read from the
//!   PTQ run metadata.
//! * Figure 3: perplexity-vs-rank series (driven by eval::ppl over the
//!   rank-sweep runs; assembled by the bench harness).

use std::path::Path;

use anyhow::Result;

use crate::linalg::{svd, Mat};
use crate::util::json;

/// Normalized spectra of the quantization error with and without the
/// activation-induced scaling (paper Figure 1a, footnote 1).
#[derive(Debug, Clone)]
pub struct Spectra {
    pub layer: String,
    pub lqer: Vec<f64>,  // sigma(alpha * E_q)
    pub l2qer: Vec<f64>, // sigma(S * E_q)
}

impl Spectra {
    /// Cumulative energy fraction captured by the top-k components.
    pub fn energy_at(series: &[f64], k: usize) -> f64 {
        let total: f64 = series.iter().map(|s| s * s).sum();
        if total == 0.0 {
            return 0.0;
        }
        series[..k.min(series.len())]
            .iter()
            .map(|s| s * s)
            .sum::<f64>()
            / total
    }
}

/// Compute Figure-1a spectra from the exported artifacts
/// (`artifacts/fig1a/{fig1a.json, eq.f32, s.f32}`).
pub fn fig1a_spectra(fig1a_dir: &Path) -> Result<Spectra> {
    let info = json::parse_file(&fig1a_dir.join("fig1a.json"))?;
    let shape = info.req("shape")?;
    let m = shape.as_array().unwrap()[0].as_usize().unwrap();
    let n = shape.as_array().unwrap()[1].as_usize().unwrap();
    let eq_raw =
        crate::util::read_f32_file(&fig1a_dir.join(info.str_at("eq")?))?;
    let s_raw =
        crate::util::read_f32_file(&fig1a_dir.join(info.str_at("s")?))?;
    anyhow::ensure!(eq_raw.len() == m * n, "eq size");
    anyhow::ensure!(s_raw.len() == m, "s size");

    let eq = Mat::from_f32(m, n, &eq_raw);
    let mut scaled = eq.clone();
    for r in 0..m {
        scaled.scale_row(r, s_raw[r] as f64);
    }
    // Footnote 1: rescale E_q to share the Frobenius norm of S E_q.
    let alpha = scaled.frobenius() / eq.frobenius().max(1e-30);
    let mut eq_n = eq;
    for v in &mut eq_n.data {
        *v *= alpha;
    }
    Ok(Spectra {
        layer: info.str_at("layer")?,
        lqer: svd::singular_values(&eq_n),
        l2qer: svd::singular_values(&scaled),
    })
}

/// Figure-4 data: per-layer approximation error for one PTQ run, ordered
/// by (layer index, linear name).
pub fn approx_errors(meta: &json::Value) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    if let Some(errs) = meta.get("approx_err").and_then(|v| v.as_object()) {
        for (k, v) in errs {
            if let Some(f) = v.as_f64() {
                out.push((k.clone(), f));
            }
        }
    }
    out.sort_by_key(|(k, _)| {
        let parts: Vec<&str> = k.split('.').collect();
        let layer: usize = parts.get(1).and_then(|p| p.parse().ok())
            .unwrap_or(0);
        let lin = ["wq", "wk", "wv", "wo", "fc1", "fc2"]
            .iter()
            .position(|n| parts.get(2) == Some(n))
            .unwrap_or(9);
        layer * 10 + lin
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn energy_fraction_monotone() {
        let s = vec![4.0, 2.0, 1.0, 0.5];
        let e1 = Spectra::energy_at(&s, 1);
        let e2 = Spectra::energy_at(&s, 2);
        let e4 = Spectra::energy_at(&s, 4);
        assert!(e1 < e2 && e2 < e4);
        assert!((e4 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn approx_errors_sorted_by_layer_then_linear() {
        let meta = json::parse(
            r#"{"approx_err": {"layers.1.wq": 0.2, "layers.0.fc2": 0.1,
                               "layers.0.wq": 0.3}}"#,
        )
        .unwrap();
        let errs = approx_errors(&meta);
        let keys: Vec<&str> =
            errs.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys,
                   vec!["layers.0.wq", "layers.0.fc2", "layers.1.wq"]);
    }

    #[test]
    fn empty_meta_no_errors() {
        let meta = json::parse("{}").unwrap();
        assert!(approx_errors(&meta).is_empty());
    }
}
