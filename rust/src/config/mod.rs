//! Typed views over the artifact manifest (`artifacts/manifest.json`) and
//! per-run metadata — the contract between the python AOT path and the
//! rust runtime.

use std::path::{Path, PathBuf};

use crate::util::json::{self, Value};

/// Architecture of one trained model.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelInfo {
    pub name: String,
    pub vocab: usize,
    pub d: usize,
    pub layers: usize,
    pub heads: usize,
    pub ffn: usize,
    pub t_max: usize,
    pub n_params: usize,
}

/// One PTQ run: a (model, method) pair with its weights + metadata.
#[derive(Debug, Clone)]
pub struct RunInfo {
    pub model: String,
    pub method: String,
    pub graph: String, // graph-variant tag, e.g. "act-mx8_k16"
    pub weights: PathBuf,
    pub meta: PathBuf,
}

/// One lowered HLO graph.
#[derive(Debug, Clone)]
pub struct GraphInfo {
    pub model: String,
    pub graph: String,
    pub entry: String, // score | prefill | decode | decode_dev | kvwrite
    pub b: usize,
    pub t: usize,
    pub path: PathBuf,
}

#[derive(Debug, Clone)]
pub struct ServeInfo {
    pub model: String,
    pub methods: Vec<String>,
    pub decode_batches: Vec<usize>,
    pub prefill_shapes: Vec<(usize, usize)>,
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: Vec<ModelInfo>,
    pub runs: Vec<RunInfo>,
    pub graphs: Vec<GraphInfo>,
    pub serve: ServeInfo,
    pub score_shape: (usize, usize),
    pub fig3_model: String,
    pub fig3_ranks: Vec<usize>,
}

fn as_usize_list(v: &Value) -> Vec<usize> {
    v.as_array()
        .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
        .unwrap_or_default()
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> anyhow::Result<Manifest> {
        let path = artifacts_dir.join("manifest.json");
        let v = json::parse_file(&path)?;

        let mut models = Vec::new();
        for (name, m) in v.req("models")?.as_object().unwrap_or(&[]) {
            models.push(ModelInfo {
                name: name.clone(),
                vocab: m.usize_at("vocab")?,
                d: m.usize_at("d")?,
                layers: m.usize_at("layers")?,
                heads: m.usize_at("heads")?,
                ffn: m.usize_at("ffn")?,
                t_max: m.usize_at("t_max")?,
                n_params: m.usize_at("n_params")?,
            });
        }

        let fix_path = |p: &str| -> PathBuf {
            let pb = PathBuf::from(p);
            if pb.is_absolute() {
                pb
            } else {
                artifacts_dir.join(p)
            }
        };

        let mut runs = Vec::new();
        for r in v.req("runs")?.as_array().unwrap_or(&[]) {
            runs.push(RunInfo {
                model: r.str_at("model")?,
                method: r.str_at("method")?,
                graph: r.str_at("graph")?,
                weights: fix_path(&r.str_at("weights")?),
                meta: fix_path(&r.str_at("meta")?),
            });
        }

        let mut graphs = Vec::new();
        for g in v.req("graphs")?.as_array().unwrap_or(&[]) {
            graphs.push(GraphInfo {
                model: g.str_at("model")?,
                graph: g.str_at("graph")?,
                entry: g.str_at("entry")?,
                b: g.usize_at("b")?,
                t: g.usize_at("t")?,
                path: fix_path(&g.str_at("path")?),
            });
        }

        let sv = v.req("serve")?;
        let serve = ServeInfo {
            model: sv.str_at("model")?,
            methods: sv
                .req("methods")?
                .as_array()
                .unwrap_or(&[])
                .iter()
                .filter_map(|x| x.as_str().map(str::to_string))
                .collect(),
            decode_batches: as_usize_list(sv.req("decode_batches")?),
            prefill_shapes: sv
                .req("prefill_shapes")?
                .as_array()
                .unwrap_or(&[])
                .iter()
                .map(|p| {
                    let l = as_usize_list(p);
                    (l[0], l[1])
                })
                .collect(),
        };

        let ss = as_usize_list(v.req("score_shape")?);
        let fig3 = v.req("fig3")?;
        Ok(Manifest {
            dir: artifacts_dir.to_path_buf(),
            models,
            runs,
            graphs,
            serve,
            score_shape: (ss[0], ss[1]),
            fig3_model: fig3.str_at("model")?,
            fig3_ranks: as_usize_list(fig3.req("ranks")?),
        })
    }

    pub fn model(&self, name: &str) -> anyhow::Result<&ModelInfo> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| anyhow::anyhow!("unknown model '{name}'"))
    }

    pub fn run(&self, model: &str, method: &str) -> anyhow::Result<&RunInfo> {
        self.runs
            .iter()
            .find(|r| r.model == model && r.method == method)
            .ok_or_else(|| {
                anyhow::anyhow!("no run for model={model} method={method}")
            })
    }

    pub fn graph(
        &self,
        model: &str,
        graph: &str,
        entry: &str,
        b: usize,
        t: usize,
    ) -> anyhow::Result<&GraphInfo> {
        self.graphs
            .iter()
            .find(|g| {
                g.model == model
                    && g.graph == graph
                    && g.entry == entry
                    && g.b == b
                    && (entry == "decode" || g.t == t)
            })
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no graph model={model} tag={graph} entry={entry} b={b} t={t}"
                )
            })
    }

    pub fn methods_for(&self, model: &str) -> Vec<String> {
        self.runs
            .iter()
            .filter(|r| r.model == model)
            .map(|r| r.method.clone())
            .collect()
    }

    pub fn data_dir(&self) -> PathBuf {
        self.dir.join("data")
    }

    /// Per-run metadata (avg bits, approximation errors, opt seconds).
    pub fn run_meta(&self, run: &RunInfo) -> anyhow::Result<Value> {
        json::parse_file(&run.meta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_manifest() {
        let dir = std::env::temp_dir().join("lqer_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let manifest = r#"{
          "models": {"opt-x": {"vocab": 440, "d": 64, "layers": 2,
                               "heads": 2, "ffn": 256, "t_max": 160,
                               "n_params": 1000, "name": "opt-x"}},
          "runs": [{"model": "opt-x", "method": "fp16",
                    "graph": "act-none_k0", "weights": "runs/w.bin",
                    "meta": "runs/meta.json"}],
          "graphs": [{"model": "opt-x", "graph": "act-none_k0",
                      "entry": "score", "b": 4, "t": 96,
                      "path": "hlo/x.hlo.txt"}],
          "serve": {"model": "opt-x", "methods": ["fp16"],
                    "decode_batches": [1, 4],
                    "prefill_shapes": [[1, 16]]},
          "score_shape": [4, 96],
          "fig3": {"model": "opt-x", "ranks": [1, 2]}
        }"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model("opt-x").unwrap().d, 64);
        assert!(m.model("nope").is_err());
        let r = m.run("opt-x", "fp16").unwrap();
        assert!(r.weights.ends_with("runs/w.bin"));
        assert!(m.graph("opt-x", "act-none_k0", "score", 4, 96).is_ok());
        assert!(m.graph("opt-x", "act-none_k0", "score", 8, 96).is_err());
        assert_eq!(m.serve.decode_batches, vec![1, 4]);
        assert_eq!(m.fig3_ranks, vec![1, 2]);
    }
}
