//! Typed views over the artifact manifest (`artifacts/manifest.json`) and
//! per-run metadata — the contract between the python AOT path and the
//! rust runtime.
//!
//! Every run carries a typed [`QuantSpec`] plan (parsed from the
//! manifest's `plan` object when present, else resolved from the legacy
//! method-name string via the compatibility shim), so downstream modules
//! consume structured per-layer quantization specs instead of re-parsing
//! strings.  Parsing is *strict*: malformed fields fail at load time
//! with a path-qualified error (`manifest.json: runs[3].plan...`)
//! instead of silently defaulting and panicking at a later index.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use crate::quant::spec::QuantSpec;
use crate::util::json::{self, Value};

/// Path-qualifying context for manifest errors: the vendored `anyhow`
/// only implements `Context` for std errors, so qualify `anyhow::Result`
/// values through `Error::context` directly.
trait PathCtx<T> {
    fn path_ctx(self, f: impl FnOnce() -> String) -> Result<T>;
}

impl<T> PathCtx<T> for Result<T> {
    fn path_ctx(self, f: impl FnOnce() -> String) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

/// Architecture of one trained model.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelInfo {
    pub name: String,
    pub vocab: usize,
    pub d: usize,
    pub layers: usize,
    pub heads: usize,
    pub ffn: usize,
    pub t_max: usize,
    pub n_params: usize,
}

/// One PTQ run: a (model, method) pair with its weights + metadata and
/// the typed quantization plan that produced it.
#[derive(Debug, Clone)]
pub struct RunInfo {
    pub model: String,
    pub method: String,
    pub graph: String, // graph-variant tag, e.g. "act-mx8_k16"
    pub plan: QuantSpec,
    pub weights: PathBuf,
    pub meta: PathBuf,
}

/// One lowered HLO graph.  `entry` is one of the kinds aot.py lowers:
/// score | prefill | decode | decode_dev | decode_paged | kvwrite |
/// kvwrite_paged | prefill_chunk | decode_draft | verify_batch
/// (staticcheck P2 keeps this set in lockstep with
/// `ModelRunner::outputs_for`).
#[derive(Debug, Clone)]
pub struct GraphInfo {
    pub model: String,
    pub graph: String,
    pub entry: String,
    pub b: usize,
    pub t: usize,
    pub path: PathBuf,
}

/// Figure-1a error-matrix export summary: the layer it was cut from and
/// the `E_q` shape, so consumers can size buffers without opening
/// `fig1a/fig1a.json` (null/absent when the AOT run skipped the stage).
#[derive(Debug, Clone)]
pub struct Fig1aInfo {
    pub layer: String,
    pub shape: (usize, usize),
}

/// Paged-KV geometry the AOT path lowered the paged graphs with
/// (DESIGN.md §10).  A decode batch `b` pairs with a pool of
/// `b * blocks_per_lane + 1` blocks (the `+1` is the sentinel), the same
/// memory as the flat `(b, t_max)` cache.
#[derive(Debug, Clone)]
pub struct PagedServeInfo {
    pub block_size: usize,
    pub blocks_per_lane: usize,
}

impl PagedServeInfo {
    /// Pool size (including the sentinel) for one decode batch.
    pub fn num_blocks(&self, decode_batch: usize) -> usize {
        decode_batch * self.blocks_per_lane + 1
    }
}

/// Chunked-prefill graph contract (DESIGN.md §12): the artifacts carry
/// fused `prefill_chunk` graphs — prefill + per-chunk block scatter in
/// one call — lowered for these buckets at this block size.
#[derive(Debug, Clone)]
pub struct ChunkServeInfo {
    pub block_size: usize,
    /// Prefill buckets the `prefill_chunk` graphs were lowered with
    /// (each a multiple of `block_size`).
    pub buckets: Vec<usize>,
}

/// Self-speculative decoding contract (DESIGN.md §13): the artifacts
/// carry `decode_draft` (rank-0 backbone) and `verify_batch` graphs,
/// and this is the default draft window `--speculate` uses when the CLI
/// does not pin one with `--gamma`.
#[derive(Debug, Clone)]
pub struct SpecServeInfo {
    pub gamma: usize,
    /// Graph entry name of the batched backbone draft step, lowered
    /// per decode bucket (one launch drafts every lane's next token).
    /// Legacy manifests omit it; the historical name is the default.
    pub draft_entry: String,
    /// Graph entry name of the batched corrected verify pass, lowered
    /// per decode bucket at window `gamma + 1`.  Legacy manifests omit
    /// it (their `verify_batch` was lowered at b=1 only).
    pub verify_entry: String,
}

#[derive(Debug, Clone)]
pub struct ServeInfo {
    pub model: String,
    pub methods: Vec<String>,
    pub decode_batches: Vec<usize>,
    pub prefill_shapes: Vec<(usize, usize)>,
    /// Present when the artifacts carry paged graphs
    /// (`decode_paged` / `kvwrite_paged`).
    pub paged: Option<PagedServeInfo>,
    /// Present when the artifacts carry fused `prefill_chunk` graphs;
    /// absent (legacy artifacts) makes the device-paged backend fall
    /// back to prefill + `kvwrite_paged` per chunk.
    pub chunk: Option<ChunkServeInfo>,
    /// Present when the artifacts carry speculation graphs
    /// (`decode_draft` / `verify_batch`); absent on legacy artifacts,
    /// where `--speculate` without an explicit `--gamma` falls back to
    /// the built-in default.
    pub spec: Option<SpecServeInfo>,
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    /// Build timestamp stamped by aot.py (absent on legacy manifests).
    pub created: Option<String>,
    pub models: Vec<ModelInfo>,
    pub runs: Vec<RunInfo>,
    pub graphs: Vec<GraphInfo>,
    pub serve: ServeInfo,
    pub score_shape: (usize, usize),
    pub fig3_model: String,
    pub fig3_ranks: Vec<usize>,
    pub fig1a: Option<Fig1aInfo>,
    /// Dataset subdirectory named by the manifest's `data.dir`
    /// (legacy manifests without a `data` object keep the old layout).
    data_subdir: String,
}

/// Strict array-of-usize accessor: a malformed manifest fails here with
/// the offending path, not as a later index panic.
fn usize_list(v: &Value, path: &str) -> Result<Vec<usize>> {
    let arr = v
        .as_array()
        .ok_or_else(|| anyhow!("{path}: expected an array"))?;
    arr.iter()
        .enumerate()
        .map(|(i, x)| {
            x.as_usize().ok_or_else(|| {
                anyhow!("{path}[{i}]: expected a non-negative integer")
            })
        })
        .collect()
}

fn usize_pair(v: &Value, path: &str) -> Result<(usize, usize)> {
    let l = usize_list(v, path)?;
    anyhow::ensure!(l.len() == 2, "{path}: expected exactly 2 entries");
    Ok((l[0], l[1]))
}

fn obj_entries<'a>(
    v: &'a Value,
    path: &str,
) -> Result<&'a [(String, Value)]> {
    v.as_object()
        .ok_or_else(|| anyhow!("{path}: expected an object"))
}

fn arr_entries<'a>(v: &'a Value, path: &str) -> Result<&'a [Value]> {
    v.as_array()
        .ok_or_else(|| anyhow!("{path}: expected an array"))
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> Result<Manifest> {
        let path = artifacts_dir.join("manifest.json");
        let v = json::parse_file(&path)?;
        Self::from_value(&v, artifacts_dir)
            .path_ctx(|| format!("{}", path.display()))
    }

    fn from_value(v: &Value, artifacts_dir: &Path) -> Result<Manifest> {
        let mut models = Vec::new();
        for (name, m) in obj_entries(v.req("models")?, "models")? {
            let ctx = || format!("models.{name}");
            // aot.py stamps each entry with its own map key under
            // "name"; a mismatch means the manifest was hand-edited.
            if let Some(n) = m.get("name").and_then(|n| n.as_str()) {
                anyhow::ensure!(
                    n == name,
                    "models.{name}: entry name '{n}' does not match \
                     its key"
                );
            }
            models.push(ModelInfo {
                name: name.clone(),
                vocab: m.usize_at("vocab").path_ctx(ctx)?,
                d: m.usize_at("d").path_ctx(ctx)?,
                layers: m.usize_at("layers").path_ctx(ctx)?,
                heads: m.usize_at("heads").path_ctx(ctx)?,
                ffn: m.usize_at("ffn").path_ctx(ctx)?,
                t_max: m.usize_at("t_max").path_ctx(ctx)?,
                n_params: m.usize_at("n_params").path_ctx(ctx)?,
            });
        }

        let fix_path = |p: &str| -> PathBuf {
            let pb = PathBuf::from(p);
            if pb.is_absolute() {
                pb
            } else {
                artifacts_dir.join(p)
            }
        };

        let mut runs = Vec::new();
        for (i, r) in arr_entries(v.req("runs")?, "runs")?.iter().enumerate() {
            let ctx = || format!("runs[{i}]");
            let method = r.str_at("method").path_ctx(ctx)?;
            // Typed plan: prefer the embedded plan object; legacy
            // manifests fall back to the method-name shim.
            let plan = match r.get("plan") {
                Some(p) => QuantSpec::parse(p, &format!("runs[{i}].plan"))?,
                None => QuantSpec::from_method_name(&method).map_err(|e| {
                    anyhow!(
                        "runs[{i}]: no plan and the method name is not a \
                         known legacy method: {e}"
                    )
                })?,
            };
            runs.push(RunInfo {
                model: r.str_at("model").path_ctx(ctx)?,
                method,
                graph: r.str_at("graph").path_ctx(ctx)?,
                plan,
                weights: fix_path(&r.str_at("weights").path_ctx(ctx)?),
                meta: fix_path(&r.str_at("meta").path_ctx(ctx)?),
            });
        }

        let mut graphs = Vec::new();
        for (i, g) in
            arr_entries(v.req("graphs")?, "graphs")?.iter().enumerate()
        {
            let ctx = || format!("graphs[{i}]");
            graphs.push(GraphInfo {
                model: g.str_at("model").path_ctx(ctx)?,
                graph: g.str_at("graph").path_ctx(ctx)?,
                entry: g.str_at("entry").path_ctx(ctx)?,
                b: g.usize_at("b").path_ctx(ctx)?,
                t: g.usize_at("t").path_ctx(ctx)?,
                path: fix_path(&g.str_at("path").path_ctx(ctx)?),
            });
        }

        let sv = v.req("serve")?;
        let mut methods = Vec::new();
        for (i, x) in
            arr_entries(sv.req("methods")?, "serve.methods")?.iter().enumerate()
        {
            methods.push(
                x.as_str()
                    .ok_or_else(|| {
                        anyhow!("serve.methods[{i}]: expected a string")
                    })?
                    .to_string(),
            );
        }
        let serve = ServeInfo {
            model: sv.str_at("model").path_ctx(|| "serve".to_string())?,
            methods,
            decode_batches: usize_list(
                sv.req("decode_batches").path_ctx(|| "serve".to_string())?,
                "serve.decode_batches",
            )?,
            prefill_shapes: arr_entries(
                sv.req("prefill_shapes").path_ctx(|| "serve".to_string())?,
                "serve.prefill_shapes",
            )?
            .iter()
            .enumerate()
            .map(|(i, p)| {
                usize_pair(p, &format!("serve.prefill_shapes[{i}]"))
            })
            .collect::<Result<Vec<_>>>()?,
            paged: match sv.get("paged") {
                Some(p) => Some(PagedServeInfo {
                    block_size: p
                        .usize_at("block_size")
                        .path_ctx(|| "serve.paged".to_string())?,
                    blocks_per_lane: p
                        .usize_at("blocks_per_lane")
                        .path_ctx(|| "serve.paged".to_string())?,
                }),
                None => None,
            },
            chunk: match sv.get("chunk") {
                Some(c) => {
                    let info = ChunkServeInfo {
                        block_size: c
                            .usize_at("block_size")
                            .path_ctx(|| "serve.chunk".to_string())?,
                        buckets: usize_list(
                            c.req("buckets")
                                .path_ctx(|| "serve.chunk".to_string())?,
                            "serve.chunk.buckets",
                        )?,
                    };
                    anyhow::ensure!(
                        info.block_size > 0
                            && info
                                .buckets
                                .iter()
                                .all(|b| b % info.block_size == 0),
                        "serve.chunk: buckets {:?} must be positive \
                         multiples of block_size {}",
                        info.buckets,
                        info.block_size
                    );
                    Some(info)
                }
                None => None,
            },
            spec: match sv.get("spec") {
                Some(s) => {
                    let info = SpecServeInfo {
                        gamma: s
                            .usize_at("gamma")
                            .path_ctx(|| "serve.spec".to_string())?,
                        draft_entry: match s.get("draft_entry") {
                            Some(_) => s
                                .str_at("draft_entry")
                                .path_ctx(|| "serve.spec".to_string())?,
                            None => "decode_draft".to_string(),
                        },
                        verify_entry: match s.get("verify_entry") {
                            Some(_) => s
                                .str_at("verify_entry")
                                .path_ctx(|| "serve.spec".to_string())?,
                            None => "verify_batch".to_string(),
                        },
                    };
                    anyhow::ensure!(
                        info.gamma >= 1,
                        "serve.spec: gamma must be >= 1, got {}",
                        info.gamma
                    );
                    Some(info)
                }
                None => None,
            },
        };

        let score_shape = usize_pair(v.req("score_shape")?, "score_shape")?;
        let fig3 = v.req("fig3")?;
        // aot.py emits `"fig1a": null` when the export stage was
        // skipped; only an object carries the summary.
        let fig1a = match v.get("fig1a") {
            Some(f) if !matches!(f, Value::Null) => Some(Fig1aInfo {
                layer: f
                    .str_at("layer")
                    .path_ctx(|| "fig1a".to_string())?,
                shape: usize_pair(f.req("shape")?, "fig1a.shape")?,
            }),
            _ => None,
        };
        let data_subdir = match v.get("data") {
            Some(d) => d.str_at("dir").path_ctx(|| "data".to_string())?,
            None => "data".to_string(),
        };
        Ok(Manifest {
            dir: artifacts_dir.to_path_buf(),
            created: v
                .get("created")
                .and_then(|c| c.as_str().map(str::to_string)),
            models,
            runs,
            graphs,
            serve,
            score_shape,
            fig3_model: fig3.str_at("model").path_ctx(|| "fig3".to_string())?,
            fig3_ranks: usize_list(fig3.req("ranks")?, "fig3.ranks")?,
            fig1a,
            data_subdir,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| anyhow!("unknown model '{name}'"))
    }

    pub fn run(&self, model: &str, method: &str) -> Result<&RunInfo> {
        self.runs
            .iter()
            .find(|r| r.model == model && r.method == method)
            .ok_or_else(|| {
                anyhow!("no run for model={model} method={method}")
            })
    }

    pub fn graph(
        &self,
        model: &str,
        graph: &str,
        entry: &str,
        b: usize,
        t: usize,
    ) -> Result<&GraphInfo> {
        self.graphs
            .iter()
            .find(|g| {
                g.model == model
                    && g.graph == graph
                    && g.entry == entry
                    && g.b == b
                    && (entry == "decode" || g.t == t)
            })
            .ok_or_else(|| {
                anyhow!(
                    "no graph model={model} tag={graph} entry={entry} b={b} t={t}"
                )
            })
    }

    pub fn methods_for(&self, model: &str) -> Vec<String> {
        self.runs
            .iter()
            .filter(|r| r.model == model)
            .map(|r| r.method.clone())
            .collect()
    }

    pub fn data_dir(&self) -> PathBuf {
        self.dir.join(&self.data_subdir)
    }

    /// Per-run metadata (avg bits, approximation errors, opt seconds).
    pub fn run_meta(&self, run: &RunInfo) -> Result<Value> {
        json::parse_file(&run.meta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINIMAL: &str = r#"{
      "models": {"opt-x": {"vocab": 440, "d": 64, "layers": 2,
                           "heads": 2, "ffn": 256, "t_max": 160,
                           "n_params": 1000, "name": "opt-x"}},
      "runs": [{"model": "opt-x", "method": "fp16",
                "graph": "act-none_k0", "weights": "runs/w.bin",
                "meta": "runs/meta.json"}],
      "graphs": [{"model": "opt-x", "graph": "act-none_k0",
                  "entry": "score", "b": 4, "t": 96,
                  "path": "hlo/x.hlo.txt"}],
      "serve": {"model": "opt-x", "methods": ["fp16"],
                "decode_batches": [1, 4],
                "prefill_shapes": [[1, 16]]},
      "score_shape": [4, 96],
      "fig3": {"model": "opt-x", "ranks": [1, 2]}
    }"#;

    fn write_manifest(tag: &str, body: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("lqer_cfg_{tag}"));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
        dir
    }

    #[test]
    fn parses_minimal_manifest() {
        let dir = write_manifest("minimal", MINIMAL);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model("opt-x").unwrap().d, 64);
        assert!(m.model("nope").is_err());
        let r = m.run("opt-x", "fp16").unwrap();
        assert!(r.weights.ends_with("runs/w.bin"));
        // Legacy run without an embedded plan resolves via the shim.
        assert_eq!(r.plan, QuantSpec::from_method_name("fp16").unwrap());
        assert!(m.graph("opt-x", "act-none_k0", "score", 4, 96).is_ok());
        assert!(m.graph("opt-x", "act-none_k0", "score", 8, 96).is_err());
        assert_eq!(m.serve.decode_batches, vec![1, 4]);
        assert_eq!(m.fig3_ranks, vec![1, 2]);
    }

    #[test]
    fn parses_paged_serve_info() {
        let body = MINIMAL.replace(
            "\"prefill_shapes\": [[1, 16]]",
            "\"prefill_shapes\": [[1, 16]],
             \"paged\": {\"block_size\": 16, \"blocks_per_lane\": 10}",
        );
        let dir = write_manifest("paged", &body);
        let m = Manifest::load(&dir).unwrap();
        let p = m.serve.paged.as_ref().unwrap();
        assert_eq!(p.block_size, 16);
        assert_eq!(p.num_blocks(4), 41, "4 lanes x 10 blocks + sentinel");
        // absent on legacy manifests
        let m0 =
            Manifest::load(&write_manifest("paged_none", MINIMAL)).unwrap();
        assert!(m0.serve.paged.is_none());
        assert!(m0.serve.chunk.is_none());
    }

    #[test]
    fn parses_chunk_serve_info() {
        let body = MINIMAL.replace(
            "\"prefill_shapes\": [[1, 16]]",
            "\"prefill_shapes\": [[1, 16]],
             \"chunk\": {\"block_size\": 16, \"buckets\": [16, 96]}",
        );
        let dir = write_manifest("chunk", &body);
        let m = Manifest::load(&dir).unwrap();
        let c = m.serve.chunk.as_ref().unwrap();
        assert_eq!(c.block_size, 16);
        assert_eq!(c.buckets, vec![16, 96]);

        // Unaligned buckets are a manifest bug, caught at load.
        let body = MINIMAL.replace(
            "\"prefill_shapes\": [[1, 16]]",
            "\"prefill_shapes\": [[1, 16]],
             \"chunk\": {\"block_size\": 16, \"buckets\": [16, 20]}",
        );
        let dir = write_manifest("chunk_bad", &body);
        let msg = format!("{:#}", Manifest::load(&dir).unwrap_err());
        assert!(msg.contains("serve.chunk"), "{msg}");
    }

    #[test]
    fn parses_spec_serve_info() {
        let body = MINIMAL.replace(
            "\"prefill_shapes\": [[1, 16]]",
            "\"prefill_shapes\": [[1, 16]],
             \"spec\": {\"gamma\": 4,
                        \"draft_entry\": \"decode_draft\",
                        \"verify_entry\": \"verify_batch\"}",
        );
        let dir = write_manifest("spec", &body);
        let m = Manifest::load(&dir).unwrap();
        let sp = m.serve.spec.as_ref().unwrap();
        assert_eq!(sp.gamma, 4);
        assert_eq!(sp.draft_entry, "decode_draft");
        assert_eq!(sp.verify_entry, "verify_batch");

        // entry names are optional: legacy spec manifests carried only
        // gamma, and the historical graph names are the defaults.
        let body = MINIMAL.replace(
            "\"prefill_shapes\": [[1, 16]]",
            "\"prefill_shapes\": [[1, 16]],
             \"spec\": {\"gamma\": 2}",
        );
        let dir = write_manifest("spec_legacy", &body);
        let m1 = Manifest::load(&dir).unwrap();
        let sp1 = m1.serve.spec.as_ref().unwrap();
        assert_eq!(sp1.gamma, 2);
        assert_eq!(sp1.draft_entry, "decode_draft");
        assert_eq!(sp1.verify_entry, "verify_batch");
        // absent on legacy manifests
        let m0 =
            Manifest::load(&write_manifest("spec_none", MINIMAL)).unwrap();
        assert!(m0.serve.spec.is_none());

        // gamma 0 is a manifest bug, caught at load.
        let body = MINIMAL.replace(
            "\"prefill_shapes\": [[1, 16]]",
            "\"prefill_shapes\": [[1, 16]],
             \"spec\": {\"gamma\": 0}",
        );
        let dir = write_manifest("spec_bad", &body);
        let msg = format!("{:#}", Manifest::load(&dir).unwrap_err());
        assert!(msg.contains("serve.spec"), "{msg}");
    }

    #[test]
    fn parses_embedded_plan() {
        let body = MINIMAL.replace(
            "\"meta\": \"runs/meta.json\"",
            "\"meta\": \"runs/meta.json\",
             \"plan\": {\"version\": 1, \"default\": {
                \"weight\": {\"kind\": \"mxint\", \"bits\": 4,
                             \"exp_bits\": 4, \"block\": 16},
                \"act\": \"mx8\", \"algo\": \"rtn\",
                \"lowrank\": {\"k\": 16, \"scaled\": true, \"bits\": 8}},
              \"overrides\": []}",
        );
        let dir = write_manifest("plan", &body);
        let m = Manifest::load(&dir).unwrap();
        let r = m.run("opt-x", "fp16").unwrap();
        assert_eq!(r.plan,
                   QuantSpec::from_method_name("l2qer-w4a8").unwrap());
    }

    #[test]
    fn parses_created_fig1a_and_data_dir() {
        let body = MINIMAL.replace(
            "\"score_shape\": [4, 96],",
            "\"score_shape\": [4, 96],
             \"created\": \"2026-08-08 12:00:00\",
             \"fig1a\": {\"layer\": \"layers.1.w_down\",
                         \"shape\": [256, 64]},
             \"data\": {\"dir\": \"corpus\"},",
        );
        let dir = write_manifest("extras", &body);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.created.as_deref(), Some("2026-08-08 12:00:00"));
        let f = m.fig1a.as_ref().unwrap();
        assert_eq!(f.layer, "layers.1.w_down");
        assert_eq!(f.shape, (256, 64));
        assert!(m.data_dir().ends_with("corpus"));
        // Legacy manifest: all absent, data dir keeps the old layout.
        let m0 =
            Manifest::load(&write_manifest("extras_none", MINIMAL)).unwrap();
        assert!(m0.created.is_none() && m0.fig1a.is_none());
        assert!(m0.data_dir().ends_with("data"));
    }

    #[test]
    fn fig1a_null_is_absent_and_name_mismatch_fails() {
        // aot.py writes `"fig1a": null` when the stage was skipped.
        let body = MINIMAL.replace("\"score_shape\": [4, 96],",
                                   "\"score_shape\": [4, 96],
                                    \"fig1a\": null,");
        let m =
            Manifest::load(&write_manifest("fig1a_null", &body)).unwrap();
        assert!(m.fig1a.is_none());

        let body = MINIMAL.replace("\"name\": \"opt-x\"",
                                   "\"name\": \"opt-y\"");
        let msg = format!(
            "{:#}",
            Manifest::load(&write_manifest("name_bad", &body)).unwrap_err()
        );
        assert!(msg.contains("does not match"), "{msg}");
    }

    #[test]
    fn unknown_method_without_plan_is_an_error() {
        let body = MINIMAL.replace("\"method\": \"fp16\"",
                                   "\"method\": \"mystery-w4\"");
        let dir = write_manifest("nomethod", &body);
        let err = Manifest::load(&dir).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("runs[0]") && msg.contains("mystery-w4"),
                "{msg}");
    }

    #[test]
    fn malformed_arrays_fail_with_path() {
        // decode_batches with a non-integer entry.
        let body = MINIMAL.replace("\"decode_batches\": [1, 4]",
                                   "\"decode_batches\": [1, \"four\"]");
        let dir = write_manifest("badlist", &body);
        let msg = format!("{:#}", Manifest::load(&dir).unwrap_err());
        assert!(msg.contains("serve.decode_batches[1]"), "{msg}");

        // prefill shape with the wrong arity.
        let body = MINIMAL.replace("\"prefill_shapes\": [[1, 16]]",
                                   "\"prefill_shapes\": [[1]]");
        let dir = write_manifest("badshape", &body);
        let msg = format!("{:#}", Manifest::load(&dir).unwrap_err());
        assert!(msg.contains("serve.prefill_shapes[0]"), "{msg}");

        // fig3.ranks not an array at all.
        let body = MINIMAL.replace("\"ranks\": [1, 2]", "\"ranks\": 2");
        let dir = write_manifest("badranks", &body);
        let msg = format!("{:#}", Manifest::load(&dir).unwrap_err());
        assert!(msg.contains("fig3.ranks"), "{msg}");

        // models not an object (checked before anything else).
        let dir = write_manifest("badmodels", r#"{"models": []}"#);
        let msg = format!("{:#}", Manifest::load(&dir).unwrap_err());
        assert!(msg.contains("models"), "{msg}");
    }

    #[test]
    fn malformed_plan_fails_with_path() {
        let body = MINIMAL.replace(
            "\"meta\": \"runs/meta.json\"",
            "\"meta\": \"runs/meta.json\",
             \"plan\": {\"version\": 1, \"default\": {
                \"weight\": {\"kind\": \"warp\"},
                \"act\": \"mx8\", \"algo\": \"rtn\", \"lowrank\": null},
              \"overrides\": []}",
        );
        let dir = write_manifest("badplan", &body);
        let msg = format!("{:#}", Manifest::load(&dir).unwrap_err());
        assert!(msg.contains("runs[0].plan.default.weight"), "{msg}");
        assert!(msg.contains("warp"), "{msg}");
    }
}
