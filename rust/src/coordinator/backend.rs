//! Decode backends: the engine schedules, a backend executes.
//!
//! [`DecodeBackend`] is the seam between the scheduler (slot accounting,
//! sampling, finish detection — all host-side, backend-agnostic) and the
//! model execution + cache storage.  Two implementations exist:
//!
//! * [`PjrtBackend`] — the real runtime.  Its cache backing is selected
//!   by `EngineConfig::host_cache`:
//!   - **device-resident** (default): a [`DeviceKvSession`] keeps the
//!     `(L, B, T_max, d)` caches on the device; each step re-feeds the
//!     previous step's cache outputs and moves only O(B) ids/positions
//!     up and O(B·vocab) logits down (DESIGN.md §6);
//!   - **host** (legacy oracle): a [`HostKvMirror`] round-trips the full
//!     caches through the PJRT boundary every step, exactly as the
//!     pre-refactor engine did.  Kept behind the flag as the
//!     bit-exactness reference.
//! * [`crate::coordinator::testbackend::FakeBackend`] — a deterministic
//!   in-process model used by the golden equality and slot-leak tests; it
//!   emulates both cache modes without PJRT.

use std::path::Path;

use anyhow::Result;

use super::EngineConfig;
use crate::config::Manifest;
use crate::kvcache::paged::{BlockTable, PagedHostKv, SwappedBlock};
use crate::kvcache::HostKvMirror;
use crate::runtime::{DeviceKvSession, ExecStats, ModelRunner, Runtime};

/// Executes prefill/decode steps and owns the cache tensors; the engine
/// owns the [`crate::kvcache::SlotMap`] and drives this trait with it.
pub trait DecodeBackend {
    fn vocab(&self) -> usize;
    fn t_max(&self) -> usize;
    fn batch(&self) -> usize;

    /// One chunked-prefill slice (DESIGN.md §12): `toks` is the
    /// prompt's first `len` tokens right-padded to `bucket` (a prefill
    /// bucket, `bucket >= len`), and rows `[row_offset, len)` are the
    /// slice to install into batch lane `slot` — earlier rows are
    /// already in the cache from previous chunks.  The backend
    /// recomputes the whole prefix (the shape-specialized b=1 prefill
    /// graphs are the oracle; a dedicated chunk graph may skip the
    /// redundant compute) but must only (re-)write rows with the values
    /// the monolithic prefill would produce — re-scattering an earlier
    /// row with its identical recomputed bytes is allowed, which is
    /// exactly what the whole-slice `kvwrite` device path does.
    /// Returns the prefix logits, `bucket * vocab` row-major; the
    /// engine samples from row `len - 1` after the final chunk.  A
    /// monolithic prefill is the special case `row_offset == 0` with
    /// `len` the full prompt.
    fn prefill_chunk(
        &mut self,
        slot: usize,
        toks: &[i32],
        bucket: usize,
        len: usize,
        row_offset: usize,
    ) -> Result<Vec<f32>>;

    /// One decode step over the whole batch bucket.  `pos` is the
    /// per-lane position vector, `active` the occupied lanes.  Appends
    /// this step's K/V rows to the backing cache (the engine advances the
    /// slot positions afterwards).  Returns logits, `batch * vocab`
    /// row-major.
    fn decode(
        &mut self,
        tokens: &[i32],
        pos: &[i32],
        active: &[usize],
    ) -> Result<Vec<f32>>;

    // --- paged-KV variants (DESIGN.md §10, §11) --------------------------
    //
    // The engine owns the `BlockAllocator` and per-lane `BlockTable`s;
    // backends that store their cache block-granularly implement these
    // and address rows through the tables.  Backends without paged
    // storage keep the defaults and the engine refuses paged configs.

    /// Whether the backend's cache backing is block-granular.
    fn supports_paged(&self) -> bool {
        false
    }

    /// Whether the backend can copy/export/import whole blocks — the
    /// primitives behind copy-on-write forks and block-level swap
    /// (DESIGN.md §11).  The engine refuses prefix-sharing / swap
    /// configs over a backend without them (the device-paged path is
    /// gated here until the real PJRT bindings land).
    fn supports_block_ops(&self) -> bool {
        false
    }

    /// Paged twin of [`Self::prefill_chunk`]: the slice's cache rows
    /// land in the blocks mapped by `table` (which must cover `len`
    /// rows) instead of a flat lane.  The first `shared_blocks` table
    /// entries are **read-only** (prefix-shared; they already hold
    /// exactly the rows this prompt would write): the backend must not
    /// write any row living in them — skip those rows, or park the
    /// device DUS chunk in the sentinel block.
    #[allow(clippy::too_many_arguments)]
    fn prefill_chunk_paged(
        &mut self,
        _slot: usize,
        _table: &BlockTable,
        _toks: &[i32],
        _bucket: usize,
        _len: usize,
        _row_offset: usize,
        _shared_blocks: usize,
    ) -> Result<Vec<f32>> {
        anyhow::bail!("backend has no paged KV backing")
    }

    /// Copy block `src`'s K/V rows over block `dst` (COW fork).
    fn copy_block(&mut self, _src: u32, _dst: u32) -> Result<()> {
        anyhow::bail!("backend has no block copy")
    }

    /// Copy block `id`'s K/V rows out for the host swap area.
    fn export_block(&self, _id: u32) -> Result<SwappedBlock> {
        anyhow::bail!("backend has no block export")
    }

    /// Copy swapped-out rows back into block `id` (swap-in).
    fn import_block(&mut self, _id: u32, _blk: &SwappedBlock)
        -> Result<()> {
        anyhow::bail!("backend has no block import")
    }

    /// Bytes of K/V payload one block holds (0 when not paged) — used
    /// for the bytes-saved metric.
    fn block_bytes(&self) -> usize {
        0
    }

    /// Paged decode step: `tables` is indexed by lane (free lanes hold an
    /// empty table).  Appended K/V rows go to
    /// `tables[lane].physical(pos[lane])`; dead writes of free lanes park
    /// in the sentinel block.
    fn decode_paged(
        &mut self,
        _tokens: &[i32],
        _pos: &[i32],
        _active: &[usize],
        _tables: &[BlockTable],
    ) -> Result<Vec<f32>> {
        anyhow::bail!("backend has no paged KV backing")
    }

    // --- self-speculative decoding (DESIGN.md §13) -----------------------
    //
    // LQER's decomposition `W ≈ W_q + A@B` gives every corrected model a
    // free draft model: the same quantized backbone *without* the
    // low-rank term (`draft_of(plan)` in the quant spec).  The engine
    // drafts γ tokens per lane with the cheap pass, then verifies them
    // in one multi-token corrected pass; backends without lowered draft
    // graphs keep the defaults and the engine refuses `spec` configs.

    /// Whether the backend implements the speculative draft/verify
    /// passes.  The PJRT path is gated until the `decode_draft` /
    /// `verify_batch` graphs are wired through the real bindings
    /// (ROADMAP); the FakeBackend implements both.
    fn supports_speculation(&self) -> bool {
        false
    }

    /// One draft-model decode step for a single lane: feed `tok` at row
    /// `pos` (flat lane `slot`, or through `table` when paged), append
    /// the K/V row, and return the draft logits (`vocab` floats).  The
    /// draft model is the quantized backbone without the low-rank
    /// correction, so this pass skips the `(m+n)·k` weight stream.
    fn draft_step(
        &mut self,
        _slot: usize,
        _table: Option<&BlockTable>,
        _pos: usize,
        _tok: i32,
    ) -> Result<Vec<f32>> {
        anyhow::bail!("backend has no speculative draft pass")
    }

    /// Corrected verify pass over one lane: feed `tokens[i]` at row
    /// `start_pos + i`, writing each position's K/V row exactly as
    /// sequential decode would, and return `tokens.len() * vocab`
    /// logits row-major — row `i` is the corrected next-token
    /// distribution after feeding `tokens[i]`.  One call streams the
    /// corrected weights once for all positions, which is the
    /// speculation win; the engine samples the agreeing prefix from
    /// these rows and rewinds the rest.
    fn verify_tokens(
        &mut self,
        _slot: usize,
        _table: Option<&BlockTable>,
        _start_pos: usize,
        _tokens: &[i32],
    ) -> Result<Vec<f32>> {
        anyhow::bail!("backend has no speculative verify pass")
    }

    /// One batched draft round across the whole batch: feed
    /// `tokens[s]` at row `pos[s]` for every lane `s` in `active`,
    /// append each active lane's K/V row, and return `batch * vocab`
    /// logits row-major (lane `s`'s row at `s * vocab`; rows of
    /// inactive lanes are unspecified).  `tables` is per-lane when
    /// paged (indexed by slot), `None` on a flat cache.  Lanes *not*
    /// in `active` must not have any live cache row disturbed — a
    /// lattice that writes every lane parks dead rows in the sentinel
    /// block (paged) or the `t_max - 1` DUS-clamp row (flat), exactly
    /// like batched plain decode.  One launch replaces `|active|`
    /// [`DecodeBackend::draft_step`] calls.
    fn draft_step_batch(
        &mut self,
        _tokens: &[i32],
        _pos: &[i32],
        _active: &[usize],
        _tables: Option<&[BlockTable]>,
    ) -> Result<Vec<f32>> {
        anyhow::bail!("backend has no batched speculative draft pass")
    }

    /// One batched corrected verify pass across the whole batch:
    /// `tokens` is `batch * width` row-major (lane `s`'s fed window at
    /// `tokens[s * width ..]`), of which only the first `lens[s]`
    /// entries are live for lane `s`; feed token `i` at row
    /// `start_pos[s] + i`, writing each live position's K/V row
    /// exactly as sequential decode would.  Returns
    /// `batch * width * vocab` logits row-major — lane `s`, position
    /// `i` at `(s * width + i) * vocab`; rows past `lens[s]` and rows
    /// of lanes not in `active` are unspecified, and their writes (if
    /// the lattice emits them) must be parked dead like
    /// [`DecodeBackend::draft_step_batch`]'s.  One launch replaces
    /// `|active|` [`DecodeBackend::verify_tokens`] calls.
    fn verify_tokens_batch(
        &mut self,
        _tokens: &[i32],
        _lens: &[usize],
        _start_pos: &[i32],
        _active: &[usize],
        _tables: Option<&[BlockTable]>,
    ) -> Result<Vec<f32>> {
        anyhow::bail!("backend has no batched speculative verify pass")
    }

    /// Runtime-boundary statistics, when the backend measures them.
    fn exec_stats(&self) -> ExecStats {
        ExecStats::default()
    }

    /// Statistics for one graph entry (e.g. "decode" / "decode_dev").
    fn entry_stats(&self, _entry: &str) -> ExecStats {
        ExecStats::default()
    }
}

/// Which cache backing a [`PjrtBackend`] runs with.
enum CacheBacking {
    Device(DeviceKvSession),
    Host(HostKvMirror),
    /// Block-pool host storage + the legacy flat `decode` graph as the
    /// execution oracle: each step gathers the active lanes' rows into
    /// flat scratch caches, so the paged path is fully working (and
    /// bit-exact) without PJRT-side paged graphs.
    PagedHost {
        kv: PagedHostKv,
        scratch_k: Vec<f32>,
        scratch_v: Vec<f32>,
    },
    /// Device-resident block pool driven by the `decode_paged` /
    /// `kvwrite_paged` graphs (block-table index operands); activates
    /// with a real PJRT backend per ROADMAP.md.
    PagedDevice(DeviceKvSession),
}

/// The real backend: PJRT runtime + lowered graphs of one (model, method).
pub struct PjrtBackend {
    manifest: Manifest,
    rt: Runtime,
    runner: ModelRunner,
    backing: CacheBacking,
    batch: usize,
}

impl PjrtBackend {
    /// Build the runtime, pre-compile the serving graphs (so
    /// first-request latency is honest), and allocate the cache backing.
    /// Returns the backend plus the tokenizer's EOS id.
    pub fn new(
        artifacts: &Path,
        cfg: &EngineConfig,
    ) -> Result<(PjrtBackend, u32)> {
        let manifest = Manifest::load(artifacts)?;
        let rt = Runtime::cpu()?;
        let runner = ModelRunner::new(&manifest, &cfg.model, &cfg.method)?;
        let info = runner.model.clone();
        let tok = crate::tokenizer::Tokenizer::from_file(
            &manifest.data_dir().join("vocab.json"),
        )?;
        match (cfg.host_cache, &cfg.paged) {
            (true, _) => {
                runner.executable(&rt, &manifest, "decode",
                                  cfg.decode_batch, 0)?;
            }
            (false, None) => {
                runner.executable(&rt, &manifest, "decode_dev",
                                  cfg.decode_batch, 0)?;
                for &t in &cfg.prefill_buckets {
                    runner.executable(&rt, &manifest, "kvwrite",
                                      cfg.decode_batch, t)?;
                }
            }
            (false, Some(p)) => {
                runner.executable(&rt, &manifest, "decode_paged",
                                  cfg.decode_batch, 0)?;
                // kvwrite_paged / prefill_chunk graphs are keyed by
                // *pool size* in the manifest (what the runtime knows
                // at lookup time), not by decode batch.
                for &t in &cfg.prefill_buckets {
                    runner.executable(&rt, &manifest, "kvwrite_paged",
                                      p.num_blocks, t)?;
                    if manifest.serve.chunk.is_some() {
                        runner.executable(&rt, &manifest,
                                          "prefill_chunk",
                                          p.num_blocks, t)?;
                    }
                }
            }
        }
        for &t in &cfg.prefill_buckets {
            runner.executable(&rt, &manifest, "prefill", 1, t)?;
        }
        // Speculation graphs: the batched draft round and the batched
        // verify pass are lowered per decode bucket (manifest
        // `serve.spec` names the entries); pre-compile them at the
        // engine's decode batch so a `--speculate` run pays compilation
        // up front like every other serving graph.  The engine still
        // gates the spec path on `supports_speculation` (ROADMAP) —
        // this only proves the artifacts carry the graphs.
        if cfg.spec.is_some() {
            if let Some(sp) = &manifest.serve.spec {
                runner.executable(&rt, &manifest, &sp.draft_entry,
                                  cfg.decode_batch, 0)?;
                runner.executable(&rt, &manifest, &sp.verify_entry,
                                  cfg.decode_batch, sp.gamma + 1)?;
            }
        }
        let backing = match (cfg.host_cache, &cfg.paged) {
            (true, None) => CacheBacking::Host(HostKvMirror::new(
                info.layers, cfg.decode_batch, info.t_max, info.d,
            )),
            (false, None) => CacheBacking::Device(DeviceKvSession::new(
                &rt, info.layers, cfg.decode_batch, info.t_max, info.d,
            )?),
            (true, Some(p)) => {
                let n = info.layers * cfg.decode_batch * info.t_max
                    * info.d;
                CacheBacking::PagedHost {
                    kv: PagedHostKv::new(
                        info.layers, p.num_blocks, p.block_size, info.d,
                    ),
                    scratch_k: vec![0.0; n],
                    scratch_v: vec![0.0; n],
                }
            }
            (false, Some(p)) => {
                CacheBacking::PagedDevice(DeviceKvSession::new_paged(
                    &rt, info.layers, p.num_blocks, p.block_size, info.d,
                )?)
            }
        };
        Ok((
            PjrtBackend {
                manifest,
                rt,
                runner,
                backing,
                batch: cfg.decode_batch,
            },
            tok.specials.eos,
        ))
    }

    /// "device" / "host" / "paged-host" / "paged-device" — for logs and
    /// bench tables.
    pub fn cache_mode(&self) -> &'static str {
        match self.backing {
            CacheBacking::Device(_) => "device",
            CacheBacking::Host(_) => "host",
            CacheBacking::PagedHost { .. } => "paged-host",
            CacheBacking::PagedDevice(_) => "paged-device",
        }
    }
}

impl DecodeBackend for PjrtBackend {
    fn vocab(&self) -> usize {
        self.runner.model.vocab
    }

    fn t_max(&self) -> usize {
        self.runner.model.t_max
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn prefill_chunk(
        &mut self,
        slot: usize,
        toks: &[i32],
        bucket: usize,
        len: usize,
        _row_offset: usize,
    ) -> Result<Vec<f32>> {
        // Both flat backings re-drive the existing bucketed write path
        // over the whole prefix: rows below `row_offset` are re-written
        // with their identical recomputed bytes (prefill is
        // deterministic), which keeps chunked and monolithic cache
        // states bit-equal without new graphs.
        match &mut self.backing {
            CacheBacking::Device(session) => {
                // K/V stay on device: scatter the retained prefill
                // outputs straight into the resident cache.
                let (logits, k, v) = self.runner.prefill_retained(
                    &self.rt, &self.manifest, toks, 1, bucket,
                )?;
                self.runner.write_prefill_resident(
                    &self.rt, &self.manifest, session, slot, &k, &v, bucket,
                )?;
                Ok(logits.data)
            }
            CacheBacking::Host(mirror) => {
                let (logits, k, v) = self.runner.prefill(
                    &self.rt, &self.manifest, toks, 1, bucket,
                )?;
                mirror.write_prefill(slot, &k.data, &v.data, bucket, len)?;
                Ok(logits.data)
            }
            CacheBacking::PagedHost { .. }
            | CacheBacking::PagedDevice(_) => {
                anyhow::bail!("paged backing requires prefill_chunk_paged")
            }
        }
    }

    fn decode(
        &mut self,
        tokens: &[i32],
        pos: &[i32],
        active: &[usize],
    ) -> Result<Vec<f32>> {
        match &mut self.backing {
            CacheBacking::Device(session) => {
                // O(B) up, O(B·vocab) down; the cache append happens
                // in-graph and the updated caches never leave the device.
                let logits = self.runner.decode_resident(
                    &self.rt, &self.manifest, session, tokens, pos,
                )?;
                Ok(logits.data)
            }
            CacheBacking::Host(mirror) => {
                // Legacy oracle: O(L·B·T_max·d) cache upload per token.
                let (logits, k_new, v_new) = self.runner.decode(
                    &self.rt,
                    &self.manifest,
                    tokens,
                    mirror.k_data(),
                    mirror.v_data(),
                    pos,
                    self.batch,
                )?;
                let rows: Vec<(usize, usize)> = active
                    .iter()
                    .map(|&s| (s, pos[s] as usize))
                    .collect();
                mirror.append_rows(&rows, &k_new.data, &v_new.data)?;
                Ok(logits.data)
            }
            CacheBacking::PagedHost { .. }
            | CacheBacking::PagedDevice(_) => {
                anyhow::bail!("paged backing requires decode_paged")
            }
        }
    }

    fn supports_paged(&self) -> bool {
        matches!(
            self.backing,
            CacheBacking::PagedHost { .. } | CacheBacking::PagedDevice(_)
        )
    }

    fn supports_block_ops(&self) -> bool {
        // The device-paged session would need block-copy graphs (or a
        // host round-trip) for COW/swap; gated with the real PJRT
        // bindings (ROADMAP).
        matches!(self.backing, CacheBacking::PagedHost { .. })
    }

    #[allow(clippy::too_many_arguments)]
    fn prefill_chunk_paged(
        &mut self,
        _slot: usize,
        table: &BlockTable,
        toks: &[i32],
        bucket: usize,
        len: usize,
        row_offset: usize,
        shared_blocks: usize,
    ) -> Result<Vec<f32>> {
        match &mut self.backing {
            CacheBacking::PagedHost { kv, .. } => {
                let (logits, k, v) = self.runner.prefill(
                    &self.rt, &self.manifest, toks, 1, bucket,
                )?;
                // Rows below the chunk are already installed, and rows
                // in the shared prefix blocks are read-only (they
                // already hold exactly these values); start past both.
                let start =
                    row_offset.max(shared_blocks * kv.block_size());
                kv.write_prefill_from(
                    table, &k.data, &v.data, bucket, len, start,
                )?;
                Ok(logits.data)
            }
            CacheBacking::PagedDevice(session) => {
                anyhow::ensure!(
                    shared_blocks == 0,
                    "prefix sharing is gated off on the device-paged \
                     path (no block ops yet)"
                );
                // Prefill K/V stay on device.  With new artifacts the
                // fused `prefill_chunk` graph computes the prefix and
                // scatters only this chunk's blocks in one call
                // (manifest `serve.chunk`); legacy artifacts fall back
                // to prefill + the `kvwrite_paged` scatter, with chunks
                // below `row_offset` parked in the sentinel so earlier
                // blocks are never re-touched.
                if self.manifest.serve.chunk.is_some() {
                    let logits = self.runner.prefill_chunk_resident_paged(
                        &self.rt, &self.manifest, session, table, toks,
                        bucket, row_offset,
                    )?;
                    return Ok(logits.data);
                }
                let (logits, k, v) = self.runner.prefill_retained(
                    &self.rt, &self.manifest, toks, 1, bucket,
                )?;
                self.runner.write_prefill_resident_paged(
                    &self.rt, &self.manifest, session, table, &k, &v,
                    bucket, row_offset,
                )?;
                Ok(logits.data)
            }
            _ => anyhow::bail!("flat backing has no prefill_chunk_paged"),
        }
    }

    fn decode_paged(
        &mut self,
        tokens: &[i32],
        pos: &[i32],
        active: &[usize],
        tables: &[BlockTable],
    ) -> Result<Vec<f32>> {
        let t_max = self.runner.model.t_max;
        match &mut self.backing {
            CacheBacking::PagedHost { kv, scratch_k, scratch_v } => {
                // Oracle bridge: gather each active lane's valid rows
                // into the flat scratch caches and run the legacy flat
                // decode graph.  Rows at positions >= pos are masked by
                // the graph, so stale scratch contents are invisible.
                for &s in active {
                    kv.gather_lane(
                        &tables[s], pos[s] as usize, s, self.batch, t_max,
                        scratch_k, scratch_v,
                    )?;
                }
                let (logits, k_new, v_new) = self.runner.decode(
                    &self.rt, &self.manifest, tokens, scratch_k,
                    scratch_v, pos, self.batch,
                )?;
                for &s in active {
                    kv.append_row(
                        &tables[s], pos[s] as usize, s, self.batch,
                        &k_new.data, &v_new.data,
                    )?;
                }
                Ok(logits.data)
            }
            CacheBacking::PagedDevice(session) => {
                let logits = self.runner.decode_resident_paged(
                    &self.rt, &self.manifest, session, tokens, pos,
                    tables, t_max,
                )?;
                Ok(logits.data)
            }
            _ => anyhow::bail!("flat backing has no decode_paged"),
        }
    }

    fn copy_block(&mut self, src: u32, dst: u32) -> Result<()> {
        match &mut self.backing {
            CacheBacking::PagedHost { kv, .. } => kv.copy_block(src, dst),
            _ => anyhow::bail!("no block copy on this backing"),
        }
    }

    fn export_block(&self, id: u32) -> Result<SwappedBlock> {
        match &self.backing {
            CacheBacking::PagedHost { kv, .. } => kv.export_block(id),
            _ => anyhow::bail!("no block export on this backing"),
        }
    }

    fn import_block(&mut self, id: u32, blk: &SwappedBlock) -> Result<()> {
        match &mut self.backing {
            CacheBacking::PagedHost { kv, .. } => kv.import_block(id, blk),
            _ => anyhow::bail!("no block import on this backing"),
        }
    }

    fn block_bytes(&self) -> usize {
        match &self.backing {
            CacheBacking::PagedHost { kv, .. } => kv.block_bytes(),
            _ => 0,
        }
    }

    fn exec_stats(&self) -> ExecStats {
        self.runner.stats()
    }

    fn entry_stats(&self, entry: &str) -> ExecStats {
        self.runner.entry_stats(entry)
    }
}
