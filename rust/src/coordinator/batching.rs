//! Batching policy helpers: prefill length buckets and admission ordering.
//!
//! Prefill graphs are shape-specialized (b=1, t in a small bucket set);
//! the scheduler right-pads each prompt to the smallest bucket that fits.
//! Padding waste is the price of AOT shape specialization — the bucket set
//! is chosen so waste stays under ~50% for the corpus length distribution.

/// Smallest bucket >= len (buckets need not be sorted).
pub fn pick_bucket(buckets: &[usize], len: usize) -> Option<usize> {
    buckets
        .iter()
        .copied()
        .filter(|&b| b >= len.max(1))
        .min()
}

/// Padding overhead fraction for a given prompt length.
pub fn padding_waste(buckets: &[usize], len: usize) -> Option<f64> {
    pick_bucket(buckets, len).map(|b| (b - len) as f64 / b as f64)
}

/// Greedy micro-batch packing: group waiting prompt lengths so each group
/// shares a bucket (used by the batched-scoring evaluator, which *can*
/// batch prefills, unlike the b=1 serving prefill graphs).
pub fn pack_by_bucket(
    buckets: &[usize],
    lens: &[usize],
    group: usize,
) -> Vec<(usize, Vec<usize>)> {
    // (bucket, indices) groups, preserving FIFO order within a bucket.
    let mut groups: Vec<(usize, Vec<usize>)> = Vec::new();
    for (i, &len) in lens.iter().enumerate() {
        let Some(b) = pick_bucket(buckets, len) else { continue };
        match groups
            .iter_mut()
            .find(|(gb, idxs)| *gb == b && idxs.len() < group)
        {
            Some((_, idxs)) => idxs.push(i),
            None => groups.push((b, vec![i])),
        }
    }
    groups
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn picks_smallest_fitting_bucket() {
        let buckets = [96, 16];
        assert_eq!(pick_bucket(&buckets, 1), Some(16));
        assert_eq!(pick_bucket(&buckets, 16), Some(16));
        assert_eq!(pick_bucket(&buckets, 17), Some(96));
        assert_eq!(pick_bucket(&buckets, 96), Some(96));
        assert_eq!(pick_bucket(&buckets, 97), None);
    }

    #[test]
    fn waste_is_fractional() {
        let buckets = [16];
        assert_eq!(padding_waste(&buckets, 16), Some(0.0));
        assert_eq!(padding_waste(&buckets, 8), Some(0.5));
    }

    #[test]
    fn packing_respects_group_size_and_fifo() {
        let buckets = [16, 96];
        let lens = [4, 8, 40, 12, 16, 90];
        let groups = pack_by_bucket(&buckets, &lens, 3);
        // bucket 16 gets (0,1,3) then (4); bucket 96 gets (2,5).
        assert_eq!(groups[0], (16, vec![0, 1, 3]));
        assert!(groups.contains(&(96, vec![2, 5])));
        assert!(groups.contains(&(16, vec![4])));
        // FIFO within groups:
        for (_, idxs) in &groups {
            let mut sorted = idxs.clone();
            sorted.sort_unstable();
            assert_eq!(&sorted, idxs);
        }
    }

    #[test]
    fn too_long_prompts_dropped_from_packing() {
        let groups = pack_by_bucket(&[16], &[4, 99], 4);
        assert_eq!(groups.len(), 1);
        assert_eq!(groups[0].1, vec![0]);
    }
}
