//! Batching policy helpers: prefill length buckets and admission ordering.
//!
//! Prefill graphs are shape-specialized (b=1, t in a small bucket set);
//! the scheduler right-pads each prompt to the smallest bucket that fits.
//! Padding waste is the price of AOT shape specialization — the bucket set
//! is chosen so waste stays under ~50% for the corpus length distribution.

/// Smallest bucket >= len (buckets need not be sorted).
pub fn pick_bucket(buckets: &[usize], len: usize) -> Option<usize> {
    buckets
        .iter()
        .copied()
        .filter(|&b| b >= len.max(1))
        .min()
}

/// Padding overhead fraction for a given prompt length.
pub fn padding_waste(buckets: &[usize], len: usize) -> Option<f64> {
    pick_bucket(buckets, len).map(|b| (b - len) as f64 / b as f64)
}

/// Chunked-prefill slice size (DESIGN.md §12, Sarathi-style stall-free
/// batching): how many new prompt rows a Prefilling sequence may
/// process this tick.  `len` is the full prompt length, `next_row` the
/// rows already present, `budget` the tick's remaining token budget,
/// and `align` the slice alignment — the paged block size (so chunk
/// writes stay whole-block for the `kvwrite_paged` / `prefill_chunk`
/// scatter graphs), 1 on a flat cache.
///
/// The final slice (everything left fits the budget) may end unaligned
/// — the prompt tail is what it is; intermediate slices end on an
/// alignment boundary, which also keeps `next_row` aligned for the
/// next call.  Returns 0 when the budget cannot fit one aligned slice;
/// the engine guarantees `tokens_per_step >= decode_batch + align`, so
/// the first Prefilling lane the packer visits always progresses.
/// Chunk *shapes* come from the existing prefill bucket set (each
/// chunk re-drives the bucketed b=1 prefill of its prefix), so no new
/// lowered graphs are needed.
pub fn chunk_len(
    len: usize,
    next_row: usize,
    budget: usize,
    align: usize,
) -> usize {
    let remaining = len.saturating_sub(next_row);
    if remaining <= budget {
        return remaining;
    }
    let a = align.max(1);
    (budget / a) * a
}

/// Greedy micro-batch packing: group waiting prompt lengths so each group
/// shares a bucket (used by the batched-scoring evaluator, which *can*
/// batch prefills, unlike the b=1 serving prefill graphs).
pub fn pack_by_bucket(
    buckets: &[usize],
    lens: &[usize],
    group: usize,
) -> Vec<(usize, Vec<usize>)> {
    // (bucket, indices) groups, preserving FIFO order within a bucket.
    let mut groups: Vec<(usize, Vec<usize>)> = Vec::new();
    for (i, &len) in lens.iter().enumerate() {
        let Some(b) = pick_bucket(buckets, len) else { continue };
        match groups
            .iter_mut()
            .find(|(gb, idxs)| *gb == b && idxs.len() < group)
        {
            Some((_, idxs)) => idxs.push(i),
            None => groups.push((b, vec![i])),
        }
    }
    groups
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn picks_smallest_fitting_bucket() {
        let buckets = [96, 16];
        assert_eq!(pick_bucket(&buckets, 1), Some(16));
        assert_eq!(pick_bucket(&buckets, 16), Some(16));
        assert_eq!(pick_bucket(&buckets, 17), Some(96));
        assert_eq!(pick_bucket(&buckets, 96), Some(96));
        assert_eq!(pick_bucket(&buckets, 97), None);
    }

    #[test]
    fn waste_is_fractional() {
        let buckets = [16];
        assert_eq!(padding_waste(&buckets, 16), Some(0.0));
        assert_eq!(padding_waste(&buckets, 8), Some(0.5));
    }

    #[test]
    fn chunk_len_takes_the_tail_whole_and_aligns_the_middle() {
        // Whatever remains fits the budget: take it all, even unaligned.
        assert_eq!(chunk_len(20, 16, 100, 8), 4);
        assert_eq!(chunk_len(20, 0, 20, 8), 20);
        // Budget smaller than the remainder: align down.
        assert_eq!(chunk_len(40, 0, 20, 8), 16);
        assert_eq!(chunk_len(40, 16, 20, 8), 16);
        // Budget below one aligned slice: no progress this tick.
        assert_eq!(chunk_len(40, 0, 7, 8), 0);
        // Flat cache (align 1): the budget is the slice.
        assert_eq!(chunk_len(40, 10, 7, 1), 7);
        // Nothing left to do.
        assert_eq!(chunk_len(20, 20, 50, 8), 0);
        // Degenerate align treated as 1.
        assert_eq!(chunk_len(40, 0, 7, 0), 7);
    }

    #[test]
    fn packing_respects_group_size_and_fifo() {
        let buckets = [16, 96];
        let lens = [4, 8, 40, 12, 16, 90];
        let groups = pack_by_bucket(&buckets, &lens, 3);
        // bucket 16 gets (0,1,3) then (4); bucket 96 gets (2,5).
        assert_eq!(groups[0], (16, vec![0, 1, 3]));
        assert!(groups.contains(&(96, vec![2, 5])));
        assert!(groups.contains(&(16, vec![4])));
        // FIFO within groups:
        for (_, idxs) in &groups {
            let mut sorted = idxs.clone();
            sorted.sort_unstable();
            assert_eq!(&sorted, idxs);
        }
    }

    #[test]
    fn too_long_prompts_dropped_from_packing() {
        let groups = pack_by_bucket(&[16], &[4, 99], 4);
        assert_eq!(groups.len(), 1);
        assert_eq!(groups[0].1, vec![0]);
    }
}
