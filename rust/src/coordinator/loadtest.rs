//! Load-test and pairwise-generation drivers built on the engine — used by
//! the `serve-bench` / `judge` CLI commands, the serving bench, and the
//! AlpacaEval-style Table 5 reproduction.

use std::sync::mpsc;

use anyhow::Result;

use super::{trace, EngineConfig, EngineHandle, EngineMetrics, Request,
            Response, Sampling};
use crate::config::Manifest;
use crate::util::json;

/// Prompts for driving the engine (the judge prompt set exported by the
/// AOT path: short corpus-grammar prefixes).
pub fn load_prompts(manifest: &Manifest) -> Result<Vec<Vec<u32>>> {
    let v = json::parse_file(
        &manifest.data_dir().join("judge_prompts.json"))?;
    let mut out = Vec::new();
    for p in v.req("prompts")?.as_array().unwrap_or(&[]) {
        out.push(
            p.as_array()
                .unwrap_or(&[])
                .iter()
                .filter_map(|x| x.as_usize().map(|u| u as u32))
                .collect(),
        );
    }
    anyhow::ensure!(!out.is_empty(), "no prompts");
    Ok(out)
}

/// Submit `n` one-shot requests open-loop and wait for all of them;
/// returns the engine metrics (throughput, latency percentiles, batch
/// occupancy).
pub fn run_loadtest(
    manifest: &Manifest,
    cfg: &EngineConfig,
    n: usize,
    max_new: usize,
) -> Result<EngineMetrics> {
    Ok(run_loadtest_traced(manifest, cfg, n, max_new, "oneshot")?.0)
}

/// [`run_loadtest`] with a traffic shape (DESIGN.md §16), also draining
/// the engine's flight-recorder ring (DESIGN.md §15) before shutdown so
/// the caller can write a Chrome trace of the run (`serve-bench
/// --trace-file --shape ...`):
///
/// * `oneshot` — `n` independent single-sample requests, the legacy
///   open-loop load;
/// * `chat`    — multi-turn conversations: `n` requests spread over
///   `n/3` sessions of 3 turns, every turn replaying the (bounded)
///   visible history so a session-budgeted engine re-maps it from the
///   parked KV chain;
/// * `agent`   — one long agent loop: `n` sequential short turns in a
///   single session, history growing each turn;
/// * `batch`   — batch-eval: `n` low-priority requests with 4 parallel
///   samples each (needs a paged, prefix-sharing engine).
pub fn run_loadtest_traced(
    manifest: &Manifest,
    cfg: &EngineConfig,
    n: usize,
    max_new: usize,
    shape: &str,
) -> Result<(EngineMetrics, Vec<trace::TraceRecord>)> {
    let prompts = load_prompts(manifest)?;
    let engine = EngineHandle::spawn(manifest.dir.clone(), cfg.clone())?;
    let req = |id: u64, prompt: Vec<u32>, fanout: usize,
               session: Option<u64>, priority: super::Priority|
        -> Request {
        Request {
            id,
            prompt,
            max_new_tokens: max_new,
            sampling: Sampling::Greedy,
            priority,
            n: fanout,
            beams: 0,
            session,
        }
    };
    // Closed-loop turn runner for the session shapes: one turn of each
    // live conversation in flight at a time, the next turn's prompt
    // extending the previous one with a bounded slice of the response
    // (so prompts stay inside the prefill buckets).
    let run_turns = |sessions: usize, turns: usize|
        -> Result<()> {
        let mut histories: Vec<Vec<u32>> = (0..sessions)
            .map(|s| prompts[s % prompts.len()].clone())
            .collect();
        let mut id = 0u64;
        for turn in 0..turns {
            let rxs: Vec<(usize, mpsc::Receiver<Response>)> =
                (0..sessions)
                    .map(|s| {
                        id += 1;
                        (s, engine.submit(req(
                            id,
                            histories[s].clone(),
                            1,
                            Some(1000 + s as u64),
                            super::Priority::Normal,
                        )))
                    })
                    .collect();
            for (s, rx) in rxs {
                let resp = rx.recv().map_err(|_| {
                    anyhow::anyhow!("request dropped by engine")
                })?;
                let keep = resp.tokens.len().min(8);
                histories[s].extend_from_slice(&resp.tokens[..keep]);
                let chunk =
                    &prompts[(s + turn + 1) % prompts.len()];
                histories[s]
                    .extend_from_slice(&chunk[..chunk.len().min(4)]);
            }
        }
        Ok(())
    };
    match shape {
        "oneshot" | "batch" => {
            let fanout = if shape == "batch" { 4 } else { 1 };
            let priority = if shape == "batch" {
                super::Priority::Low
            } else {
                super::Priority::Normal
            };
            let mut rxs: Vec<mpsc::Receiver<Response>> =
                Vec::with_capacity(n);
            for i in 0..n {
                rxs.push(engine.submit(req(
                    i as u64 + 1,
                    prompts[i % prompts.len()].clone(),
                    fanout,
                    None,
                    priority,
                )));
            }
            for rx in rxs {
                rx.recv().map_err(|_| {
                    anyhow::anyhow!("request dropped by engine")
                })?;
            }
        }
        "chat" => run_turns((n / 3).max(1), 3)?,
        "agent" => run_turns(1, n.max(1))?,
        other => anyhow::bail!(
            "unknown traffic shape {other:?} (expected: oneshot, chat, \
             agent, batch)"
        ),
    }
    let metrics = engine.metrics()?;
    let records = engine.trace()?;
    engine.shutdown();
    Ok((metrics, records))
}

/// Generate continuations for `prompts` with one engine.
pub fn generate_all(
    manifest: &Manifest,
    cfg: &EngineConfig,
    prompts: &[Vec<u32>],
    max_new: usize,
) -> Result<Vec<Vec<u32>>> {
    let engine = EngineHandle::spawn(manifest.dir.clone(), cfg.clone())?;
    let rxs: Vec<_> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            engine.submit(Request {
                id: i as u64 + 1,
                prompt: p.clone(),
                max_new_tokens: max_new,
                sampling: Sampling::Greedy,
                priority: super::Priority::Normal,
                n: 1,
                beams: 0,
                session: None,
            })
        })
        .collect();
    let mut by_id: Vec<Vec<u32>> = vec![Vec::new(); prompts.len()];
    for rx in rxs {
        let resp = rx
            .recv()
            .map_err(|_| anyhow::anyhow!("request dropped"))?;
        // The judge path must not silently compare empty generations:
        // an unservable prompt is a configuration error here.
        anyhow::ensure!(
            resp.finish != super::FinishReason::Rejected,
            "request {} rejected at admission (prompt len {})",
            resp.id,
            resp.prompt_len
        );
        by_id[(resp.id - 1) as usize] = resp.tokens;
    }
    engine.shutdown();
    Ok(by_id)
}

/// Table 5: generate with methods A and B, judge with the FP16 model.
pub fn run_judge(
    manifest: &Manifest,
    model: &str,
    method_a: &str,
    method_b: &str,
    n: usize,
    max_new: usize,
) -> Result<crate::eval::judge::JudgeResult> {
    let prompts: Vec<Vec<u32>> = load_prompts(manifest)?
        .into_iter()
        .take(n)
        .collect();
    let mk_cfg = |method: &str| EngineConfig {
        model: model.to_string(),
        method: method.to_string(),
        decode_batch: *manifest
            .serve
            .decode_batches
            .iter()
            .max()
            .unwrap_or(&4),
        prefill_buckets: manifest
            .serve
            .prefill_shapes
            .iter()
            .map(|(_, t)| *t)
            .collect(),
        tokens_per_step: 0, // engine default: batch + largest bucket
        host_cache: false,
        paged: None,
        spec: None,
        admission: super::AdmissionPolicy::default(),
        trace_capacity: 0,
    };
    let gens_a = generate_all(manifest, &mk_cfg(method_a), &prompts,
                              max_new)?;
    let gens_b = generate_all(manifest, &mk_cfg(method_b), &prompts,
                              max_new)?;

    let rt = crate::runtime::Runtime::cpu()?;
    let judge =
        crate::runtime::ModelRunner::new(manifest, model, "fp16")?;
    let mut result = crate::eval::judge::JudgeResult::default();
    let eos = {
        let tok = crate::tokenizer::Tokenizer::from_file(
            &manifest.data_dir().join("vocab.json"))?;
        tok.specials.eos
    };
    let strip = |g: &[u32]| -> Vec<u32> {
        g.iter().take_while(|&&t| t != eos).copied().collect()
    };
    for ((p, a), b) in prompts.iter().zip(&gens_a).zip(&gens_b) {
        crate::eval::judge::judge_pair(
            &rt, manifest, &judge, p, &strip(a), &strip(b), &mut result)?;
    }
    Ok(result)
}
