//! Engine metrics: throughput counters + streaming latency histograms.

use crate::runtime::ExecStats;

/// Fixed-bucket log-scale histogram for latencies (ms) / occupancy.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// Bucket upper bounds (exclusive), last bucket catches the rest.
    bounds: Vec<f64>,
    counts: Vec<u64>,
    sum: f64,
    n: u64,
    max: f64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        // 0.1ms .. ~100s, x2 per bucket.
        let mut bounds = Vec::new();
        let mut b = 0.1;
        while b < 1e5 {
            bounds.push(b);
            b *= 2.0;
        }
        let n = bounds.len();
        LatencyHistogram {
            bounds,
            counts: vec![0; n + 1],
            sum: 0.0,
            n: 0,
            max: 0.0,
        }
    }
}

impl LatencyHistogram {
    /// Add one sample (bucket count, running sum/max).
    pub fn record(&mut self, v: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|b| v < *b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.sum += v;
        self.n += 1;
        self.max = self.max.max(v);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Mean of all samples (0.0 when empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    /// Largest recorded sample (0.0 when empty).
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Approximate percentile from bucket boundaries (upper bound of the
    /// bucket containing the p-th sample).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let target = (p / 100.0 * self.n as f64).ceil() as u64;
        let mut acc = 0;
        for (i, c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return if i < self.bounds.len() {
                    self.bounds[i]
                } else {
                    self.max
                };
            }
        }
        self.max
    }
}

#[derive(Debug, Clone, Default)]
pub struct EngineMetrics {
    pub submitted: u64,
    pub completed: u64,
    /// Requests answered with `FinishReason::Rejected` (admission failed).
    pub rejected: u64,
    /// Requests answered with `FinishReason::Expired` (admission
    /// deadline passed while waiting in the queue).
    pub expired: u64,
    /// Running sequences evicted to reclaim KV blocks (swapped out or
    /// requeued for re-prefill; one request can be preempted several
    /// times).
    pub preemptions: u64,
    /// Of those, victims evicted *mid-prefill* (Prefilling phase,
    /// DESIGN.md §12): requeued outright, no state to swap.
    pub preempted_prefills: u64,
    /// Preemptions resolved by block-level swap-out to the host pool
    /// (sequence state preserved) instead of re-prefill.
    pub swap_outs: u64,
    /// Swapped sequences resumed (blocks re-allocated, bytes imported).
    pub swap_ins: u64,
    /// Preemptions that wanted to swap but fell back to re-prefill
    /// (swap pool full or backend export failed).
    pub swap_fallbacks: u64,
    /// Copy-on-write forks: a sequence about to write a shared block got
    /// a private copy first (the shared block is never mutated).
    pub cow_copies: u64,
    /// Prompt blocks served read-only from the prefix index instead of
    /// being recomputed and re-stored (cumulative).
    pub prefix_hit_blocks: u64,
    /// KV bytes those prefix hits did not duplicate (cumulative).
    pub prefix_bytes_saved: u64,
    /// Queue depth at the last metrics snapshot.
    pub waiting: u64,
    /// Lanes streaming their prompt in (Prefilling phase) at the last
    /// snapshot.
    pub prefilling: u64,
    /// The engine's resolved per-tick token budget (DESIGN.md §12).
    pub tokens_per_step: u64,
    /// Sequences parked in the swap pool at the last snapshot.
    pub swapped_seqs: u64,
    /// Paged-KV gauges at the last snapshot (0 when the engine runs the
    /// flat per-lane cache).
    pub kv_block_size: u64,
    pub kv_blocks_total: u64,
    pub kv_blocks_in_use: u64,
    pub kv_utilization: f64,
    /// Usable blocks currently mapped into more than one table.
    pub kv_shared_blocks: u64,
    /// References beyond the first across all blocks — block copies the
    /// prefix sharing is saving right now.
    pub kv_shared_refs: u64,
    pub swap_blocks_in_use: u64,
    pub swap_blocks_total: u64,
    /// Candidate decode tails forked off prefilled prompts (DESIGN.md
    /// §16): `n`-sampling and beam-search siblings, primaries excluded.
    pub forks: u64,
    /// Candidate forks dropped because no free lane was left; the
    /// group completes with the candidates that fit.
    pub fork_denied: u64,
    /// Beam-search hypotheses pruned (their lanes re-forked from a
    /// surviving beam, freed tail blocks revivable).
    pub beam_prunes: u64,
    /// Admissions whose `session` id matched a parked conversation —
    /// the near-zero-prefill re-admission path (DESIGN.md §16).
    pub session_hits: u64,
    /// Parked sessions dropped — past the block budget or reclaimed
    /// under capacity pressure (their blocks stay revivable).
    pub session_evictions: u64,
    /// Conversations currently parked in the session store, at the
    /// last snapshot.
    pub sessions_live: u64,
    /// Block references those parked sessions hold, at the last
    /// snapshot.
    pub session_blocks_held: u64,
    pub tokens_generated: u64,
    /// Speculative decoding (DESIGN.md §13): tokens proposed by the
    /// draft (backbone-only) passes.
    pub draft_tokens: u64,
    /// Draft tokens the corrected verify pass agreed with (each saved
    /// one full corrected decode step).
    pub accepted_tokens: u64,
    /// Whole KV blocks released by speculative rewinds (rejected-tail
    /// truncation of lane block tables).
    pub rewind_blocks: u64,
    /// Model launches issued to the backend (prefill chunks, batched
    /// decode steps, draft rounds, verify passes) — the host-side
    /// launch economics the batched speculative path optimizes
    /// (DESIGN.md §13): per tick, batched speculation spends at most
    /// `max_γ + 1` launches where the per-lane loop spent
    /// `B · (γ + 1)`.
    pub backend_launches: u64,
    /// Draft-pass launches: one per speculation *round* on the batched
    /// path (≤ `max_γ` per tick), one per drafted token per lane on
    /// the serial reference path.
    pub draft_launches: u64,
    /// Corrected verify-pass launches: one per speculative tick on the
    /// batched path, one per lane per tick on the serial reference
    /// path.
    pub verify_launches: u64,
    pub prefill_steps: u64,
    pub prefill_ns: u64,
    pub decode_steps: u64,
    pub decode_ns: u64,
    /// Wall-clock spent executing prefill chunks in ticks that also had
    /// at least one decoding lane — the head-of-line-blocking tax a
    /// whole-prompt prefill levies on running decodes.  Chunking keeps
    /// each tick's share bounded by `tokens_per_step`; the monolithic
    /// configuration (a budget covering the largest bucket) shows the
    /// old stall here.
    pub decode_stall_ns: u64,
    /// Wall-clock of corrected verify passes inside speculative rounds
    /// (DESIGN.md §15); part of each round's `decode_ns`.
    pub verify_ns: u64,
    /// Wall-clock of block export/import during swap-outs/swap-ins.
    pub swap_ns: u64,
    /// Whole engine ticks measured end-to-end (`tick_ns / ticks` is
    /// the mean tick time the flight-recorder overhead budget is
    /// asserted against).
    pub tick_ns: u64,
    /// Engine ticks executed.
    pub ticks: u64,
    /// Flight-recorder events ever recorded (DESIGN.md §15), at the
    /// last snapshot.
    pub trace_events_total: u64,
    /// Flight-recorder events evicted by ring wraparound.
    pub trace_dropped_total: u64,
    pub ttft_ms: LatencyHistogram,
    pub total_ms: LatencyHistogram,
    /// Gap between consecutive sampled tokens of a sequence (ms) — the
    /// p99 of this is what stall-free chunked prefill protects.  Time a
    /// sequence spent swapped out counts: the client experienced it.
    pub itl_ms: LatencyHistogram,
    /// Tokens of work packed per tick (decode lanes + prefill chunk
    /// rows); its max never exceeds `tokens_per_step`
    /// (property-tested).
    pub packed_tokens: LatencyHistogram,
    /// The prefill-chunk share of each tick's packed tokens.
    pub packed_prefill_tokens: LatencyHistogram,
    pub batch_occupancy: LatencyHistogram,
    /// Pool utilization (percent) sampled at every decode step; its max
    /// is the peak block pressure of the run.
    pub kv_util: LatencyHistogram,
    pub exec: ExecStats,
    /// Runtime-boundary stats of the decode entry alone — its
    /// `bytes_per_call()` is the per-decode-step host↔device traffic
    /// (the number the device-resident cache refactor shrinks).
    pub decode_exec: ExecStats,
}

impl EngineMetrics {
    /// Decode throughput over time actually spent in decode steps
    /// (0.0 before the first step).
    pub fn decode_tokens_per_sec(&self) -> f64 {
        if self.decode_ns == 0 {
            0.0
        } else {
            self.tokens_generated as f64 / (self.decode_ns as f64 / 1e9)
        }
    }

    /// Mean decoding lanes running per engine tick.
    pub fn mean_batch_occupancy(&self) -> f64 {
        self.batch_occupancy.mean()
    }

    /// Fraction of drafted tokens the verify pass accepted (0.0 with
    /// speculation off or before the first round).
    pub fn acceptance_rate(&self) -> f64 {
        if self.draft_tokens == 0 {
            0.0
        } else {
            self.accepted_tokens as f64 / self.draft_tokens as f64
        }
    }

    /// Cumulative decode-stall time in milliseconds (see
    /// [`Self::decode_stall_ns`]).
    pub fn decode_stall_ms(&self) -> f64 {
        self.decode_stall_ns as f64 / 1e6
    }

    /// One-line human summary of every counter (the `serve-bench`
    /// footer); `GET /metrics` serves the same fields as JSON.
    pub fn report(&self) -> String {
        let spec = if self.draft_tokens > 0 {
            format!(
                " | spec {} drafted, {} accepted ({:.0}%), {} blocks \
                 rewound, {} draft + {} verify launches",
                self.draft_tokens,
                self.accepted_tokens,
                self.acceptance_rate() * 100.0,
                self.rewind_blocks,
                self.draft_launches,
                self.verify_launches,
            )
        } else {
            String::new()
        };
        let paged = if self.kv_blocks_total > 0 {
            format!(
                " | kv {}/{} blocks of {} rows ({:.0}% now, {:.0}% \
                 peak) | {} preempted ({} mid-prefill, {} swapped out, \
                 {} back in, {} fallbacks) | swap pool {}/{} blocks, \
                 {} seqs parked | {} shared blocks ({} extra refs), {} \
                 cow, {} prefix hits ({} B saved) | {} forks ({} \
                 denied), {} beams pruned | sessions {} live ({} \
                 blocks held, {} hits, {} evicted)",
                self.kv_blocks_in_use,
                self.kv_blocks_total,
                self.kv_block_size,
                self.kv_utilization * 100.0,
                self.kv_util.max(),
                self.preemptions,
                self.preempted_prefills,
                self.swap_outs,
                self.swap_ins,
                self.swap_fallbacks,
                self.swap_blocks_in_use,
                self.swap_blocks_total,
                self.swapped_seqs,
                self.kv_shared_blocks,
                self.kv_shared_refs,
                self.cow_copies,
                self.prefix_hit_blocks,
                self.prefix_bytes_saved,
                self.forks,
                self.fork_denied,
                self.beam_prunes,
                self.sessions_live,
                self.session_blocks_held,
                self.session_hits,
                self.session_evictions,
            )
        } else {
            String::new()
        };
        format!(
            "requests {}/{} done ({} rejected, {} expired; {} waiting, \
             {} prefilling) | tokens {} \
             | prefill {} \
             steps {:.1} ms avg \
             | decode {} steps {:.2} ms avg | {:.1} tok/s decode | occupancy \
             {:.2} | ttft p50 {:.0} ms p99 {:.0} ms | itl p50 {:.2} ms \
             p99 {:.2} ms | e2e p50 {:.0} ms p99 {:.0} ms \
             | budget {}/tick (packed mean {:.1}, max {:.0}, prefill \
             share {:.1}) \
             | decode stalled {:.1} ms | verify {:.1} ms swap {:.1} ms \
             | {} launches | {} ticks {:.2} ms avg | trace {} events \
             ({} dropped){spec}{paged}",
            self.completed,
            self.submitted,
            self.rejected,
            self.expired,
            self.waiting,
            self.prefilling,
            self.tokens_generated,
            self.prefill_steps,
            if self.prefill_steps > 0 {
                self.prefill_ns as f64 / self.prefill_steps as f64 / 1e6
            } else {
                0.0
            },
            self.decode_steps,
            if self.decode_steps > 0 {
                self.decode_ns as f64 / self.decode_steps as f64 / 1e6
            } else {
                0.0
            },
            self.decode_tokens_per_sec(),
            self.mean_batch_occupancy(),
            self.ttft_ms.percentile(50.0),
            self.ttft_ms.percentile(99.0),
            self.itl_ms.percentile(50.0),
            self.itl_ms.percentile(99.0),
            self.total_ms.percentile(50.0),
            self.total_ms.percentile(99.0),
            self.tokens_per_step,
            self.packed_tokens.mean(),
            self.packed_tokens.max(),
            self.packed_prefill_tokens.mean(),
            self.decode_stall_ms(),
            self.verify_ns as f64 / 1e6,
            self.swap_ns as f64 / 1e6,
            self.backend_launches,
            self.ticks,
            if self.ticks > 0 {
                self.tick_ns as f64 / self.ticks as f64 / 1e6
            } else {
                0.0
            },
            self.trace_events_total,
            self.trace_dropped_total,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_counts_and_mean() {
        let mut h = LatencyHistogram::default();
        for v in [1.0, 2.0, 3.0] {
            h.record(v);
        }
        assert_eq!(h.count(), 3);
        assert!((h.mean() - 2.0).abs() < 1e-12);
        assert_eq!(h.max(), 3.0);
    }

    #[test]
    fn percentiles_monotone() {
        let mut h = LatencyHistogram::default();
        for i in 0..1000 {
            h.record(i as f64 / 10.0);
        }
        let p50 = h.percentile(50.0);
        let p99 = h.percentile(99.0);
        assert!(p50 <= p99);
        assert!(p50 >= 25.0 && p50 <= 102.4, "{p50}");
    }

    #[test]
    fn empty_histogram_safe() {
        let h = LatencyHistogram::default();
        assert_eq!(h.percentile(99.0), 0.0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn tokens_per_sec() {
        let m = EngineMetrics {
            tokens_generated: 100,
            decode_ns: 2_000_000_000,
            ..Default::default()
        };
        assert!((m.decode_tokens_per_sec() - 50.0).abs() < 1e-9);
    }
}
