//! L3 coordinator: request types, admission queue, continuous batcher, and
//! the serving engine loop.
//!
//! Architecture (vLLM-style, scaled to this testbed):
//!
//! ```text
//!  clients ── submit(Request + reply Sender) ──► admission queue (FIFO)
//!                                                     │
//!                                  engine thread (owns PJRT runtime)
//!                                                     │
//!        ┌─────────── scheduler iteration ────────────┤
//!        │ 1. admit waiting requests into free KV slots (prefill, b=1,
//!        │    bucketed sequence lengths, right-padded); failures free
//!        │    the slot and answer with FinishReason::Rejected
//!        │ 2. one batched decode step over all active slots
//!        │ 3. sample, detect EOS/limits, free slots, send responses
//!        └────────────────────────────────────────────┘
//! ```
//!
//! The engine is generic over a [`backend::DecodeBackend`]: the scheduler
//! (slot accounting via [`SlotMap`], sampling, finish detection) is pure
//! host logic, while the backend executes the graphs and owns the cache
//! tensors — device-resident by default, or the legacy host round-trip
//! behind `EngineConfig::host_cache` (DESIGN.md §6).
//!
//! The PJRT client is not `Send`, so the engine thread constructs and owns
//! the entire runtime; callers talk to it exclusively through channels
//! ([`EngineHandle`]).  Continuous batching falls out of the slot design:
//! new sequences join the decode batch as soon as a slot frees up, without
//! draining the batch.

pub mod backend;
pub mod batching;
pub mod loadtest;
pub mod metrics;
pub mod server;
pub mod testbackend;

use std::sync::mpsc;
use std::time::Instant;

use anyhow::Result;

use crate::kvcache::SlotMap;
use crate::util::rng::Rng;

use backend::{DecodeBackend, PjrtBackend};

pub use metrics::{EngineMetrics, LatencyHistogram};

/// Decoding strategy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Sampling {
    Greedy,
    /// top-k sampling with temperature.
    TopK { k: usize, temperature: f32, seed: u64 },
}

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    pub sampling: Sampling,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    Eos,
    Length,
    CacheFull,
    /// The request could not be admitted (empty/over-long prompt, or
    /// prefill failed); no tokens were generated.  Clients receive this
    /// instead of a dropped reply channel.
    Rejected,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub prompt_len: usize,
    pub tokens: Vec<u32>,
    pub finish: FinishReason,
    /// Wall-clock from submit to first generated token (ms).
    pub ttft_ms: f64,
    /// Wall-clock from submit to completion (ms).
    pub total_ms: f64,
}

enum Msg {
    Submit(Request, mpsc::Sender<Response>),
    Metrics(mpsc::Sender<EngineMetrics>),
    Shutdown,
}

/// Client-side handle to a running engine.
pub struct EngineHandle {
    tx: mpsc::Sender<Msg>,
    join: Option<std::thread::JoinHandle<()>>,
}

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub model: String,
    pub method: String,
    /// Decode batch bucket (must have a lowered decode graph).
    pub decode_batch: usize,
    /// Prefill length buckets (must have lowered prefill graphs, b=1).
    pub prefill_buckets: Vec<usize>,
    /// Max prefills admitted per scheduler iteration (batching policy).
    pub max_prefill_per_step: usize,
    /// Use the legacy host-side KV cache (full cache upload/download per
    /// decode step) instead of the device-resident session.  Kept as the
    /// bit-exactness oracle; `false` is the serving default.
    pub host_cache: bool,
}

impl EngineHandle {
    /// Start an engine thread for one (model, method) run.
    pub fn spawn(
        artifacts: std::path::PathBuf,
        cfg: EngineConfig,
    ) -> Result<EngineHandle> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("lqer-engine".into())
            .spawn(move || {
                match Engine::from_artifacts(&artifacts, &cfg) {
                    Ok(mut engine) => {
                        let _ = ready_tx.send(Ok(()));
                        engine.run(rx);
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                    }
                }
            })?;
        ready_rx.recv()??;
        Ok(EngineHandle { tx, join: Some(join) })
    }

    /// Submit a request; returns the channel the response arrives on.
    pub fn submit(&self, req: Request) -> mpsc::Receiver<Response> {
        let (tx, rx) = mpsc::channel();
        let _ = self.tx.send(Msg::Submit(req, tx));
        rx
    }

    /// Convenience: submit and wait.
    pub fn generate(&self, req: Request) -> Result<Response> {
        let rx = self.submit(req);
        rx.recv().map_err(|_| anyhow::anyhow!("engine dropped request"))
    }

    pub fn metrics(&self) -> Result<EngineMetrics> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Msg::Metrics(tx))?;
        rx.recv().map_err(|_| anyhow::anyhow!("engine gone"))
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for EngineHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Engine (runs on the engine thread; drivable directly in tests)
// ---------------------------------------------------------------------------

struct ActiveSeq {
    request: Request,
    reply: mpsc::Sender<Response>,
    submitted: Instant,
    ttft_ms: Option<f64>,
    generated: Vec<u32>,
    last_token: u32,
    rng: Rng,
}

struct Waiting {
    request: Request,
    reply: mpsc::Sender<Response>,
    submitted: Instant,
}

/// The scheduler: generic over the execution backend so tests can drive
/// it with a deterministic in-process model
/// ([`testbackend::FakeBackend`]).
pub struct Engine<B: DecodeBackend> {
    backend: B,
    slots: SlotMap,
    cfg: EngineConfig,
    eos: u32,
    waiting: std::collections::VecDeque<Waiting>,
    active: Vec<Option<ActiveSeq>>, // indexed by KV slot
    metrics: EngineMetrics,
}

impl Engine<PjrtBackend> {
    /// Build the real engine from an artifacts directory.
    pub fn from_artifacts(
        artifacts: &std::path::Path,
        cfg: &EngineConfig,
    ) -> Result<Engine<PjrtBackend>> {
        let (backend, eos) = PjrtBackend::new(artifacts, cfg)?;
        Ok(Engine::with_backend(backend, cfg.clone(), eos))
    }
}

impl<B: DecodeBackend> Engine<B> {
    /// Assemble an engine around any backend (tests construct this with a
    /// [`testbackend::FakeBackend`] and drive [`Engine::tick`] directly).
    pub fn with_backend(backend: B, cfg: EngineConfig, eos: u32) -> Engine<B> {
        assert_eq!(
            backend.batch(),
            cfg.decode_batch,
            "backend batch must match decode_batch"
        );
        let slots = SlotMap::new(cfg.decode_batch, backend.t_max());
        let active = (0..cfg.decode_batch).map(|_| None).collect();
        Engine {
            backend,
            slots,
            cfg,
            eos,
            waiting: Default::default(),
            active,
            metrics: EngineMetrics::default(),
        }
    }

    /// Queue a request for admission (the threaded path does this from
    /// `Msg::Submit`).
    pub fn enqueue(&mut self, request: Request, reply: mpsc::Sender<Response>) {
        self.metrics.submitted += 1;
        self.waiting.push_back(Waiting {
            request,
            reply,
            submitted: Instant::now(),
        });
    }

    /// Anything queued or in flight?
    pub fn has_work(&self) -> bool {
        !self.waiting.is_empty()
            || self.slots.free_count() != self.slots.batch()
    }

    pub fn free_slots(&self) -> usize {
        self.slots.free_count()
    }

    pub fn kv_batch(&self) -> usize {
        self.slots.batch()
    }

    pub fn metrics_snapshot(&self) -> EngineMetrics {
        let mut m = self.metrics.clone();
        m.exec = self.backend.exec_stats();
        m.decode_exec = self.backend.entry_stats("decode");
        m.decode_exec.merge(&self.backend.entry_stats("decode_dev"));
        m
    }

    fn run(&mut self, rx: mpsc::Receiver<Msg>) {
        loop {
            // 1. Drain control/submission messages (block only when idle).
            let idle = !self.has_work();
            loop {
                let msg = if idle && self.waiting.is_empty() {
                    match rx.recv() {
                        Ok(m) => m,
                        Err(_) => return,
                    }
                } else {
                    match rx.try_recv() {
                        Ok(m) => m,
                        Err(mpsc::TryRecvError::Empty) => break,
                        Err(mpsc::TryRecvError::Disconnected) => return,
                    }
                };
                match msg {
                    Msg::Submit(request, reply) => {
                        self.enqueue(request, reply);
                    }
                    Msg::Metrics(tx) => {
                        let _ = tx.send(self.metrics_snapshot());
                    }
                    Msg::Shutdown => return,
                }
                if !idle {
                    // Drain whatever is queued without blocking, then serve.
                    continue;
                }
            }

            // 2.+3. One scheduler iteration.
            self.tick();
        }
    }

    /// One scheduler iteration: admit waiting requests into free slots,
    /// then run one batched decode step over all active slots.
    pub fn tick(&mut self) {
        let mut admitted = 0;
        while admitted < self.cfg.max_prefill_per_step
            && self.slots.free_count() > 0
            && !self.waiting.is_empty()
        {
            let w = self.waiting.pop_front().unwrap();
            self.admit(w);
            admitted += 1;
        }

        if !self.slots.active_slots().is_empty() {
            if let Err(e) = self.decode_step() {
                crate::info!("decode step failed: {e:#}");
            }
        }
    }

    /// Answer a request that cannot be served; the slot (if any) has
    /// already been freed by the caller.
    fn reject(&mut self, w: Waiting, why: &str) {
        crate::info!("request {} rejected: {why}", w.request.id);
        self.metrics.rejected += 1;
        let total_ms = w.submitted.elapsed().as_secs_f64() * 1e3;
        let _ = w.reply.send(Response {
            id: w.request.id,
            prompt_len: w.request.prompt.len(),
            tokens: Vec::new(),
            finish: FinishReason::Rejected,
            ttft_ms: total_ms,
            total_ms,
        });
    }

    fn admit(&mut self, w: Waiting) {
        let vocab = self.backend.vocab();
        let t_max = self.backend.t_max();
        let prompt: Vec<u32> = w
            .request
            .prompt
            .iter()
            .copied()
            .filter(|&t| (t as usize) < vocab)
            .collect();
        let len = prompt.len().min(t_max - 1);
        if len == 0 {
            self.reject(w, "empty prompt");
            return;
        }
        let Some(bucket) =
            batching::pick_bucket(&self.cfg.prefill_buckets, len)
        else {
            self.reject(w, "prompt longer than any prefill bucket");
            return;
        };
        let Some(slot) = self.slots.alloc(w.request.id) else {
            self.reject(w, "no free KV slot");
            return;
        };

        // Right-pad the prompt to the bucket length.
        let mut toks = vec![0i32; bucket];
        for (i, t) in prompt.iter().take(len).enumerate() {
            toks[i] = *t as i32;
        }
        let t0 = Instant::now();
        let logits =
            match self.backend.prefill_into(slot, &toks, bucket, len) {
                Ok(l) => l,
                Err(e) => {
                    // Prefill failed after the slot was claimed: free it
                    // (this used to leak) and answer with Rejected
                    // instead of dropping the reply sender.
                    self.slots.free(slot);
                    self.reject(w, &format!("prefill failed: {e:#}"));
                    return;
                }
            };
        self.metrics.prefill_steps += 1;
        self.metrics.prefill_ns += t0.elapsed().as_nanos() as u64;
        if logits.len() < bucket * vocab {
            self.slots.free(slot);
            self.reject(w, "prefill returned short logits");
            return;
        }
        if let Err(e) = self.slots.set_pos(slot, len) {
            self.slots.free(slot);
            self.reject(w, &format!("slot update failed: {e:#}"));
            return;
        }

        // Sample the first generated token from the last prompt position.
        let row = &logits[(len - 1) * vocab..len * vocab];
        let mut seq = ActiveSeq {
            rng: Rng::new(match w.request.sampling {
                Sampling::TopK { seed, .. } => seed ^ w.request.id,
                Sampling::Greedy => w.request.id,
            }),
            request: w.request,
            reply: w.reply,
            submitted: w.submitted,
            ttft_ms: None,
            generated: Vec::new(),
            last_token: 0,
        };
        let first = sample(row, seq.request.sampling, &mut seq.rng);
        seq.ttft_ms = Some(seq.submitted.elapsed().as_secs_f64() * 1e3);
        seq.generated.push(first);
        seq.last_token = first;
        self.active[slot] = Some(seq);
        // The sampled token will be fed at position `len` by decode_step;
        // finish immediately if it is EOS or the request wants one token.
        self.maybe_finish(slot);
    }

    fn decode_step(&mut self) -> Result<()> {
        let b = self.slots.batch();
        let active = self.slots.active_slots();
        if active.is_empty() {
            return Ok(());
        }
        let mut tokens = vec![0i32; b];
        for &s in &active {
            tokens[s] = self.active[s].as_ref().unwrap().last_token as i32;
        }
        let pos = self.slots.pos_vector();
        let t0 = Instant::now();
        let logits = self.backend.decode(&tokens, &pos, &active)?;
        self.metrics.decode_steps += 1;
        self.metrics.decode_ns += t0.elapsed().as_nanos() as u64;
        self.metrics.batch_occupancy.record(active.len() as f64);

        // The backend appended this step's K/V rows; account for them.
        self.slots.advance(&active)?;

        let vsize = self.backend.vocab();
        anyhow::ensure!(logits.len() >= b * vsize, "decode logits size");
        for &s in &active {
            let row = &logits[s * vsize..(s + 1) * vsize];
            let seq = self.active[s].as_mut().unwrap();
            let tok = sample(row, seq.request.sampling, &mut seq.rng);
            seq.generated.push(tok);
            seq.last_token = tok;
            self.metrics.tokens_generated += 1;
            self.maybe_finish(s);
        }
        Ok(())
    }

    fn maybe_finish(&mut self, slot: usize) {
        let t_max = self.backend.t_max();
        let pos = self.slots.pos(slot);
        let finish = {
            let seq = self.active[slot].as_ref().unwrap();
            if seq.generated.last() == Some(&self.eos) {
                Some(FinishReason::Eos)
            } else if seq.generated.len() >= seq.request.max_new_tokens {
                Some(FinishReason::Length)
            } else if pos + 1 >= t_max {
                Some(FinishReason::CacheFull)
            } else {
                None
            }
        };
        if let Some(reason) = finish {
            let seq = self.active[slot].take().unwrap();
            self.slots.free(slot);
            let total_ms = seq.submitted.elapsed().as_secs_f64() * 1e3;
            self.metrics.completed += 1;
            self.metrics.ttft_ms.record(seq.ttft_ms.unwrap_or(total_ms));
            self.metrics.total_ms.record(total_ms);
            let _ = seq.reply.send(Response {
                id: seq.request.id,
                prompt_len: seq.request.prompt.len(),
                tokens: seq.generated,
                finish: reason,
                ttft_ms: seq.ttft_ms.unwrap_or(total_ms),
                total_ms,
            });
        }
    }
}

/// Sample a token id from a logits row.
pub fn sample(logits: &[f32], strategy: Sampling, rng: &mut Rng) -> u32 {
    match strategy {
        Sampling::Greedy => argmax(logits) as u32,
        Sampling::TopK { k, temperature, .. } => {
            let k = k.max(1).min(logits.len());
            let mut idx: Vec<usize> = (0..logits.len()).collect();
            if k < idx.len() {
                // Partial selection: O(V) per token instead of the former
                // full-vocab O(V log V) sort.  idx[..k] holds the k
                // largest logits (unordered — softmax weights don't care).
                idx.select_nth_unstable_by(k - 1, |&a, &b| {
                    logits[b].partial_cmp(&logits[a]).unwrap()
                });
                idx.truncate(k);
            }
            let t = temperature.max(1e-3);
            let mx = idx
                .iter()
                .map(|&i| logits[i])
                .fold(f32::NEG_INFINITY, f32::max);
            let weights: Vec<f64> = idx
                .iter()
                .map(|&i| (((logits[i] - mx) / t) as f64).exp())
                .collect();
            idx[rng.weighted(&weights)] as u32
        }
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, x) in xs.iter().enumerate() {
        if *x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_sampling_is_argmax() {
        let mut rng = Rng::new(0);
        let logits = vec![0.1, 2.0, -1.0, 1.9];
        assert_eq!(sample(&logits, Sampling::Greedy, &mut rng), 1);
    }

    #[test]
    fn topk_stays_in_top_k() {
        let mut rng = Rng::new(0);
        let logits = vec![-5.0, 10.0, 9.5, -7.0, 9.9];
        for _ in 0..200 {
            let t = sample(
                &logits,
                Sampling::TopK { k: 3, temperature: 1.0, seed: 1 },
                &mut rng,
            );
            assert!([1u32, 2, 4].contains(&t), "sampled {t}");
        }
    }

    #[test]
    fn topk_low_temperature_nearly_greedy() {
        let mut rng = Rng::new(0);
        let logits = vec![0.0, 5.0, 4.0];
        let mut ones = 0;
        for _ in 0..100 {
            if sample(
                &logits,
                Sampling::TopK { k: 2, temperature: 0.05, seed: 2 },
                &mut rng,
            ) == 1
            {
                ones += 1;
            }
        }
        assert!(ones >= 99, "{ones}");
    }

    #[test]
    fn topk_equals_full_vocab_is_safe() {
        let mut rng = Rng::new(3);
        let logits = vec![1.0, 2.0, 3.0];
        for _ in 0..50 {
            let t = sample(
                &logits,
                Sampling::TopK { k: 10, temperature: 0.5, seed: 4 },
                &mut rng,
            );
            assert!(t < 3);
        }
    }
}
