//! L3 coordinator: request types, admission queue, continuous batcher, and
//! the serving engine loop.
//!
//! Architecture (vLLM-style, scaled to this testbed):
//!
//! ```text
//!  clients ── submit(Request + reply Sender) ──► admission queue
//!                                          (bounded FIFO + deadline)
//!                                                     │
//!                                  engine thread (owns PJRT runtime)
//!                                                     │
//!        ┌──── token-budget step (DESIGN.md §12) ────────────┤
//!        │ 0. expire waiters past their deadline (FinishReason::Expired)
//!        │ 1. reserve 1 budget token per decoding lane (decode steps
//!        │    are never stalled behind whole-prompt prefills)
//!        │ 2. pack the remaining budget with chunked-prefill slices,
//!        │    round-robin over the Prefilling lanes; a sequence whose
//!        │    final chunk lands samples its first token (TTFT) and
//!        │    becomes Decoding
//!        │ 3. admit while capacity lasts — a free lane AND (paged mode)
//!        │    enough free KV blocks for the whole prompt; admission is
//!        │    bookkeeping only: the lane enters the Prefilling phase
//!        │    and streams in chunk slices from the next tick (a prompt
//!        │    fully resident via the prefix index completes now,
//!        │    charged against the leftover budget)
//!        │ 4. grow block tables for the next append; if the pool is dry,
//!        │    preempt the lowest-priority-then-youngest sequence
//!        │    (mid-prefill victims requeue, decoding victims swap out
//!        │    or requeue for deterministic re-prefill)
//!        │ 5. one batched decode step over the lanes that were decoding
//!        │    at the top of the tick; sample, detect EOS/limits, respond
//!        └────────────────────────────────────────────┘
//! ```
//!
//! The engine is generic over a [`backend::DecodeBackend`]: the scheduler
//! (slot accounting via [`SlotMap`], block accounting via
//! [`crate::kvcache::paged::BlockAllocator`] + per-lane
//! [`BlockTable`]s in paged mode, sampling, finish detection) is pure
//! host logic, while the backend executes the graphs and owns the cache
//! tensors — device-resident by default, the legacy host round-trip
//! behind `EngineConfig::host_cache` (DESIGN.md §6), or the paged block
//! pool behind `EngineConfig::paged` (DESIGN.md §10).
//!
//! The PJRT client is not `Send`, so the engine thread constructs and owns
//! the entire runtime; callers talk to it exclusively through channels
//! ([`EngineHandle`]).  Continuous batching falls out of the slot design:
//! new sequences join the decode batch as soon as a slot frees up, without
//! draining the batch.

/// The `DecodeBackend` trait and its PJRT-backed implementations.
pub mod backend;
/// Prefill bucketing and chunk-length selection (DESIGN.md §12).
pub mod batching;
/// Closed-loop `serve-bench` driver and its traffic shapes
/// (DESIGN.md §16).
pub mod loadtest;
/// [`EngineMetrics`]: every counter, gauge, and histogram the
/// engine exports.
pub mod metrics;
/// Minimal HTTP/1.1 front end (`/generate`, `/metrics`, `/trace`).
pub mod server;
/// Deterministic fake backend for tests and benches.
pub mod testbackend;
/// Flight recorder: bounded event ring + span timers
/// (DESIGN.md §15).
pub mod trace;

use std::sync::mpsc;

use anyhow::Result;

use crate::kvcache::paged::{
    chain_hash, BlockAllocator, BlockTable, PrefixIndex, SwapPool,
    SwappedBlock, PREFIX_SEED,
};
use crate::kvcache::SlotMap;
use crate::util::rng::Rng;

use backend::{DecodeBackend, PjrtBackend};
use trace::{now_ns, ns_to_ms, TraceEvent};

pub use metrics::{EngineMetrics, LatencyHistogram};

/// Decoding strategy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Sampling {
    Greedy,
    /// top-k sampling with temperature.
    TopK { k: usize, temperature: f32, seed: u64 },
}

/// Eviction class of a request: when the block pool runs dry the engine
/// preempts the lowest-priority (then youngest-by-tokens) running
/// sequence first (DESIGN.md §11).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum Priority {
    /// Evicted first under memory pressure (batch / best-effort work).
    Low,
    #[default]
    Normal,
    /// Evicted only when no lower-priority victim exists.
    High,
}

impl Priority {
    /// Parse "low" / "normal" / "high" (the HTTP API's spelling).
    pub fn parse(s: &str) -> Option<Priority> {
        match s {
            "low" => Some(Priority::Low),
            "normal" => Some(Priority::Normal),
            "high" => Some(Priority::High),
            _ => None,
        }
    }
}

/// One generation request as the engine sees it.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    pub sampling: Sampling,
    pub priority: Priority,
    /// Parallel-sampling fanout (DESIGN.md §16): the prompt is admitted
    /// and prefilled once, then `n` decode tails fork off it, sharing
    /// every prompt block read-only (copy-on-write on first divergent
    /// write).  0 and 1 both mean the plain single-sequence path; the
    /// candidates come back ranked in [`Response::candidates`].
    /// Requires a paged engine with block ops; mutually exclusive with
    /// `beams`.
    pub n: usize,
    /// Beam-search width (DESIGN.md §16): fork `beams` hypotheses off
    /// the prefilled prompt and re-rank them in lockstep each decode
    /// step by cumulative log-probability, re-forking pruned beams'
    /// lanes from survivors via the block table (freed tail blocks stay
    /// revivable).  0 and 1 both mean off.  Beam ranking is
    /// deterministic (greedy over the expansion set) regardless of
    /// `sampling`.
    pub beams: usize,
    /// Conversation id for multi-turn session persistence (DESIGN.md
    /// §16): when set and the engine has a session budget, a finished
    /// turn parks its KV tail in the prefix index keyed by content, so
    /// a follow-up turn extending the conversation re-admits with only
    /// its new suffix to prefill.
    pub session: Option<u64>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    Eos,
    Length,
    CacheFull,
    /// The request could not be admitted (empty/over-long prompt,
    /// prefill failed, or — under [`AdmissionPolicy::RejectOnFull`] —
    /// no capacity); no tokens were generated.  Clients receive this
    /// instead of a dropped reply channel.
    Rejected,
    /// The request waited in the admission queue past its deadline
    /// ([`AdmissionPolicy::Wait`]); no tokens were generated.
    Expired,
}

/// One completed candidate of a forked request (`n` parallel samples
/// or `beams` beam-search hypotheses), ranked best-first in
/// [`Response::candidates`].
#[derive(Debug, Clone)]
pub struct Candidate {
    pub tokens: Vec<u32>,
    pub finish: FinishReason,
    /// Cumulative natural-log probability of `tokens` under the
    /// model's per-step softmax — the ranking key (ties break toward
    /// the lower candidate index, so greedy fanouts stay
    /// deterministic).
    pub score: f64,
}

/// The engine's answer to a [`Request`].
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub prompt_len: usize,
    /// The generated stream — for a forked request, the best
    /// candidate's stream (`candidates[0].tokens`).
    pub tokens: Vec<u32>,
    pub finish: FinishReason,
    /// Wall-clock from submit to first generated token (ms).  Recorded
    /// when the token is sampled, so time spent swapped out later never
    /// inflates it (the generated stream survives a swap).
    pub ttft_ms: f64,
    /// Wall-clock from submit to completion (ms); includes any time
    /// spent swapped out.
    pub total_ms: f64,
    /// Wall-clock this sequence spent swapped out to the host pool (ms);
    /// part of `total_ms`, never of `ttft_ms`.
    pub swapped_ms: f64,
    /// Every candidate of a forked request (`n` > 1 or `beams` >= 2),
    /// best first; empty on the plain single-sequence path, where
    /// `tokens` is the only stream.
    pub candidates: Vec<Candidate>,
}

enum Msg {
    Submit(Request, mpsc::Sender<Response>),
    Metrics(mpsc::Sender<EngineMetrics>),
    Trace(mpsc::Sender<Vec<trace::TraceRecord>>),
    Shutdown,
}

/// Client-side handle to a running engine.
pub struct EngineHandle {
    tx: mpsc::Sender<Msg>,
    join: Option<std::thread::JoinHandle<()>>,
}

/// Paged-KV geometry (DESIGN.md §10): cache rows live in fixed-size
/// blocks acquired on demand instead of a flat `T_max`-row lane per
/// sequence.
#[derive(Debug, Clone)]
pub struct PagedKvConfig {
    /// Token rows per block; must divide every prefill bucket and the
    /// model's `t_max` (the device DUS lattice writes whole chunks).
    pub block_size: usize,
    /// Total pool size including the reserved sentinel block 0, so
    /// usable capacity is `num_blocks - 1` blocks.
    pub num_blocks: usize,
    /// Map block-aligned shared prompt prefixes read-only into new
    /// requests' tables (copy-on-write on first divergent write) instead
    /// of re-storing them per sequence (DESIGN.md §11).  Requires a
    /// backend with block ops (host-paged backings; the device path is
    /// gated).
    pub prefix_sharing: bool,
    /// Host swap pool size in blocks: preemption copies a victim's
    /// blocks out and resumes it later instead of discarding the
    /// sequence for re-prefill.  0 disables swapping (re-prefill
    /// fallback only).
    pub swap_blocks: usize,
    /// Budget (in blocks) for parked multi-turn sessions (DESIGN.md
    /// §16): a finished turn with [`Request::session`] set keeps its
    /// tail blocks referenced and prefix-indexed so the next turn
    /// re-admits with near-zero prefill.  Oldest sessions are dropped
    /// past the budget, and any parked session is reclaimed before the
    /// engine preempts live work.  0 disables persistence; requires
    /// `prefix_sharing`.
    pub session_blocks: usize,
}

/// Self-speculative decoding (DESIGN.md §13): the quantized backbone
/// (the serving plan with its low-rank correction clamped off —
/// `draft_of(plan)`) drafts tokens cheaply and the corrected model
/// verifies them in one multi-token pass per lane.
#[derive(Debug, Clone)]
pub struct SpecConfig {
    /// Maximum draft tokens per lane per round.  Each lane adapts its
    /// own depth within `[1, gamma]` from a running acceptance-rate
    /// EWMA; a round is charged `γ + 1` tokens against
    /// `tokens_per_step`.
    pub gamma: usize,
}

/// What happens to a request that does not fit right now.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Answer `FinishReason::Rejected` immediately when no lane / KV
    /// blocks are free — an instant-shed baseline for A/B comparison
    /// against the paged waiting queue.  (The pre-paging engine held
    /// over-capacity requests in an *unbounded* queue; that behavior
    /// is `Wait` with a large depth and no deadline, the default.)
    RejectOnFull,
    /// Hold up to `queue_depth` requests in the admission queue (beyond
    /// that, reject at submit); each may wait up to `deadline_ms`
    /// (0 = forever) before being answered `FinishReason::Expired`.
    /// Preempted sequences re-enter at the queue head and may
    /// transiently exceed `queue_depth`.
    Wait { queue_depth: usize, deadline_ms: u64 },
}

impl Default for AdmissionPolicy {
    fn default() -> Self {
        AdmissionPolicy::Wait { queue_depth: 4096, deadline_ms: 0 }
    }
}

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub model: String,
    pub method: String,
    /// Decode batch bucket (must have a lowered decode graph).
    pub decode_batch: usize,
    /// Prefill length buckets (must have lowered prefill graphs, b=1).
    pub prefill_buckets: Vec<usize>,
    /// Per-tick token budget (DESIGN.md §12): every decoding lane takes
    /// 1 token off the top, and the remainder is packed with
    /// chunked-prefill slices — the Sarathi-style stall-free schedule
    /// that replaced the old whole-prompt `max_prefill_per_step`
    /// admission.  0 resolves to `decode_batch + max(prefill_buckets)`
    /// (one full prefill bucket per tick, the closest analogue of the
    /// legacy behavior); the engine requires the resolved value to be
    /// at least `decode_batch + chunk alignment` so a prefilling lane
    /// can always make progress.
    pub tokens_per_step: usize,
    /// Use the legacy host-side KV cache (full cache upload/download per
    /// decode step) instead of the device-resident session.  Kept as the
    /// bit-exactness oracle; `false` is the serving default.
    pub host_cache: bool,
    /// Block-granular KV allocation; `None` keeps the flat per-lane
    /// reservation.
    pub paged: Option<PagedKvConfig>,
    /// Self-speculative decoding; `None` keeps plain one-token decode
    /// steps.  Requires a backend with draft/verify passes, and the
    /// emitted stream is bit-identical to non-speculative decoding
    /// (golden-tested in rust/tests/spec_decode.rs).
    pub spec: Option<SpecConfig>,
    /// Overload behavior of the admission queue.
    pub admission: AdmissionPolicy,
    /// Flight-recorder ring capacity in events (DESIGN.md §15); 0
    /// resolves to [`trace::DEFAULT_CAPACITY`].
    pub trace_capacity: usize,
}

impl EngineHandle {
    /// Start an engine thread for one (model, method) run.
    pub fn spawn(
        artifacts: std::path::PathBuf,
        cfg: EngineConfig,
    ) -> Result<EngineHandle> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("lqer-engine".into())
            .spawn(move || {
                match Engine::from_artifacts(&artifacts, &cfg) {
                    Ok(mut engine) => {
                        let _ = ready_tx.send(Ok(()));
                        engine.run(rx);
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                    }
                }
            })?;
        ready_rx.recv()??;
        Ok(EngineHandle { tx, join: Some(join) })
    }

    /// Submit a request; returns the channel the response arrives on.
    pub fn submit(&self, req: Request) -> mpsc::Receiver<Response> {
        let (tx, rx) = mpsc::channel();
        let _ = self.tx.send(Msg::Submit(req, tx));
        rx
    }

    /// Convenience: submit and wait.
    pub fn generate(&self, req: Request) -> Result<Response> {
        let rx = self.submit(req);
        rx.recv().map_err(|_| anyhow::anyhow!("engine dropped request"))
    }

    /// Snapshot of the engine's counters (one channel round-trip).
    pub fn metrics(&self) -> Result<EngineMetrics> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Msg::Metrics(tx))?;
        rx.recv().map_err(|_| anyhow::anyhow!("engine gone"))
    }

    /// Flight-recorder contents (DESIGN.md §15), oldest first.
    pub fn trace(&self) -> Result<Vec<trace::TraceRecord>> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Msg::Trace(tx))?;
        rx.recv().map_err(|_| anyhow::anyhow!("engine gone"))
    }

    /// Stop the engine thread and join it.  In-flight work is dropped;
    /// waiting callers see their reply channel close.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for EngineHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Engine (runs on the engine thread; drivable directly in tests)
// ---------------------------------------------------------------------------

struct ActiveSeq {
    request: Request,
    reply: mpsc::Sender<Response>,
    /// Submission timestamp ([`now_ns`]) — the single monotonic clock
    /// every latency metric derives from.
    submitted: u64,
    ttft_ms: Option<f64>,
    /// Accumulated wall-clock spent swapped out (ms): counts into total
    /// latency, never into TTFT (the first token predates any swap).
    swapped_ms: f64,
    generated: Vec<u32>,
    last_token: u32,
    /// When the previous token was sampled ([`now_ns`]) — feeds the
    /// inter-token latency histogram (the metric chunked prefill exists
    /// to protect).  Time spent swapped out counts: the client
    /// experienced the gap.
    last_token_at: u64,
    rng: Rng,
    /// Current speculation depth (DESIGN.md §13), adapted per round
    /// within `[1, SpecConfig::gamma]`; unused when speculation is off.
    /// Travels with the sequence through swap-out/in, so a resumed
    /// lane keeps its learned depth.
    gamma: usize,
    /// Acceptance-rate EWMA driving the γ adaptation.  Starts
    /// optimistic (1.0): the first rounds run at full depth and the
    /// depth backs off only on observed rejections.
    accept_ewma: f64,
    /// Fork-group key (the request id) when this lane is one candidate
    /// of a forked request (DESIGN.md §16); `None` on the plain
    /// single-sequence path.
    group: Option<u64>,
    /// Candidate index within the group (0 = the primary, whose RNG
    /// stream is bit-identical to the unforked request's).
    cand: usize,
    /// Cumulative log-probability of the emitted tokens; ranks the
    /// candidates when the group completes.  Only maintained for
    /// grouped lanes — the plain path never computes it.
    score: f64,
}

/// A sequence in the Prefilling phase (DESIGN.md §12): its lane and KV
/// blocks are committed, but the prompt is still streaming into the
/// cache in chunk-sized, block-aligned slices across ticks.  No token
/// has been sampled yet; TTFT starts when the final chunk lands.
struct PrefillSeq {
    request: Request,
    reply: mpsc::Sender<Response>,
    /// Submission timestamp ([`now_ns`]).
    submitted: u64,
    /// Canonical (vocab-filtered, `t_max`-capped) prompt being
    /// streamed; its length is the prefill target.
    prompt: Vec<u32>,
    /// Rows already present in the cache: the shared prefix hits mapped
    /// at admission plus every chunk written so far.  Mirrors
    /// `SlotMap::pos` for this lane, so the device DUS lattice's dead
    /// write for a mid-prefill lane lands on the next unwritten row —
    /// storage the following chunk overwrites before anyone reads it.
    next_row: usize,
    /// Leading prefix-index hits mapped read-only at admission (paged);
    /// chunk writes skip (or sentinel-park) rows inside them.
    shared_blocks: usize,
}

/// One decode lane's scheduling phase.  `Waiting` lives in the queue
/// and `Decoding` in the batch; `Prefilling` is the third phase in
/// between, introduced by the chunked-prefill scheduler.
enum Lane {
    Idle,
    Prefilling(PrefillSeq),
    Decoding(ActiveSeq),
}

impl Lane {
    fn take(&mut self) -> Lane {
        std::mem::replace(self, Lane::Idle)
    }

    fn is_decoding(&self) -> bool {
        matches!(self, Lane::Decoding(_))
    }

    fn is_prefilling(&self) -> bool {
        matches!(self, Lane::Prefilling(_))
    }

    /// The owning request, in either live phase.
    fn request(&self) -> Option<&Request> {
        match self {
            Lane::Idle => None,
            Lane::Prefilling(p) => Some(&p.request),
            Lane::Decoding(a) => Some(&a.request),
        }
    }
}

struct Waiting {
    request: Request,
    reply: mpsc::Sender<Response>,
    /// Submission timestamp ([`now_ns`]).
    submitted: u64,
    /// True for requests put back by preemption: they were already
    /// admitted once, so the admission deadline no longer applies
    /// (expiring them would turn preemption into request loss and
    /// break the "preemption never changes output" guarantee).
    preempted: bool,
}

/// Block accounting of the paged engine: the allocator plus one block
/// table per decode lane (empty while the lane is free).  The cache
/// *storage* lives in the backend; this is pure bookkeeping, like
/// [`SlotMap`].
struct PagedState {
    alloc: BlockAllocator,
    tables: Vec<BlockTable>,
    /// Content-addressed prompt-prefix index (empty when
    /// `prefix_sharing` is off).
    index: PrefixIndex,
    /// Bounded accounting for host-swapped blocks (`max_blocks` 0 when
    /// swapping is off).
    swap: SwapPool,
    sharing: bool,
    /// Parked multi-turn sessions (DESIGN.md §16), oldest first: each
    /// entry holds one reference on every block of a finished turn's
    /// KV chain, keeping the bytes resident for the next turn's prefix
    /// match.  Empty when `session_budget` is 0.
    sessions: Vec<SessionEntry>,
    /// [`PagedKvConfig::session_blocks`].
    session_budget: usize,
}

/// One finished conversation's parked KV tail: the block references of
/// its final token chain, still registered in the prefix index so a
/// follow-up turn re-maps them instead of re-prefilling.
struct SessionEntry {
    id: u64,
    blocks: Vec<u32>,
    /// Valid cache rows the blocks cover (prompt + generated tokens
    /// except the never-written last one).
    rows: usize,
}

impl PagedState {
    /// Allocate a block for *new* content: whatever prefix its old bytes
    /// backed is gone the moment someone writes to it, so drop its index
    /// entry.
    fn alloc_fresh(&mut self) -> Option<u32> {
        let id = self.alloc.alloc()?;
        self.index.forget_block(id);
        Some(id)
    }

    /// Blocks currently held by parked sessions (each holds one
    /// reference per block; shared blocks count once per session).
    fn session_blocks_held(&self) -> usize {
        self.sessions.iter().map(|e| e.blocks.len()).sum()
    }

    /// Drop the oldest parked session, releasing its block references.
    /// The bytes stay prefix-indexed, so a later matching turn can
    /// still revive them from the free list — eviction only gives up
    /// the *guarantee* of residency.  Returns false when none is
    /// parked.
    fn evict_oldest_session(&mut self) -> bool {
        if self.sessions.is_empty() {
            return false;
        }
        let e = self.sessions.remove(0);
        for b in e.blocks {
            self.alloc.free(b);
        }
        true
    }
}

/// Shared completion state of a forked request (`n` > 1 sampling or
/// beam search): the candidates finish independently, and the single
/// [`Response`] is assembled and sent when the last one lands.
struct ForkGroup {
    reply: mpsc::Sender<Response>,
    prompt_len: usize,
    /// Submission timestamp ([`now_ns`]) — group latency clock.
    submitted: u64,
    /// Beam-search group: [`Engine::beam_step`] re-ranks and prunes
    /// its lanes in lockstep instead of sampling them independently.
    beams: bool,
    /// Lanes still decoding for this group.
    live: usize,
    /// Finished candidates as `(candidate index, candidate)`.
    done: Vec<(usize, Candidate)>,
    /// TTFT of the shared prefill (all candidates fork after it).
    ttft_ms: Option<f64>,
    swapped_ms: f64,
}

/// A preempted sequence living in the host swap pool: the full decode
/// state plus its blocks' bytes, restored verbatim on swap-in
/// (DESIGN.md §11).
struct SwappedSeq {
    seq: ActiveSeq,
    /// Valid cache rows at swap-out (the slot position to restore).
    pos: usize,
    data: Vec<SwappedBlock>,
    /// Swap-out timestamp ([`now_ns`]).
    swapped_at: u64,
}

/// Admission plan for the queue head: what admitting it would cost.
struct AdmitPlan {
    /// Canonical prompt ([`Engine::canonical_prompt`]) — the one
    /// truncation/filter rule shared with the prefix index and the
    /// chunk stream, so chunking can never diverge from planning.
    prompt: Vec<u32>,
    /// Blocks to allocate fresh (beyond the shared prefix hits).
    blocks: usize,
    /// Prefix-index hits to map read-only, in logical order:
    /// `(block id, needs revival from the free list)`.
    shared: Vec<(u32, bool)>,
}

impl AdmitPlan {
    /// Free-list draw of this plan: fresh blocks plus revivals (a
    /// revived block leaves the free list too).
    fn free_blocks_needed(&self) -> usize {
        self.blocks
            + self.shared.iter().filter(|&&(_, revive)| revive).count()
    }
}

/// The scheduler: generic over the execution backend so tests can drive
/// it with a deterministic in-process model
/// ([`testbackend::FakeBackend`]).
pub struct Engine<B: DecodeBackend> {
    backend: B,
    slots: SlotMap,
    cfg: EngineConfig,
    eos: u32,
    waiting: std::collections::VecDeque<Waiting>,
    lanes: Vec<Lane>, // indexed by KV slot
    paged: Option<PagedState>,
    /// Preempted sequences parked in the host swap pool, oldest first;
    /// swap-in resumes them before any new admission.
    swapped: std::collections::VecDeque<SwappedSeq>,
    /// In-flight fork groups (DESIGN.md §16), keyed by request id: one
    /// entry per forked request from the moment its candidates fork at
    /// prefill completion until the last one finishes.
    groups: std::collections::HashMap<u64, ForkGroup>,
    /// Round-robin start of the chunk packer, so one long prompt cannot
    /// monopolize the prefill budget tick after tick.
    prefill_cursor: usize,
    /// Reused across ticks so the hot path stops allocating fresh
    /// active-slot / token / position `Vec`s per decode step.
    scratch_active: Vec<usize>,
    scratch_tokens: Vec<i32>,
    scratch_pos: Vec<i32>,
    /// Beam-group ids present in the current decode step, collected
    /// once per tick, sorted and deduped — membership checks in the
    /// sample loop are a binary search instead of a linear scan.
    scratch_groups: Vec<u64>,
    /// Speculative-round scratch (DESIGN.md §13, batched path), all
    /// slot-indexed and reused across ticks: planned depth, base cache
    /// position, fed-token windows (`batch × (max_γ + 1)` row-major),
    /// per-lane window lengths, cloned draft RNGs + sampling
    /// snapshots, and the per-round active-lane list.
    scratch_gamma: Vec<usize>,
    scratch_base: Vec<usize>,
    scratch_fed: Vec<i32>,
    scratch_lens: Vec<usize>,
    scratch_rng: Vec<Rng>,
    scratch_sampling: Vec<Sampling>,
    scratch_round: Vec<usize>,
    /// Serve speculation with the PR 6 per-lane draft/verify loop
    /// instead of the batched round — kept as the bit-exactness
    /// reference the golden tests and the batched-vs-serial proptest
    /// compare against ([`Engine::set_spec_serial`]).
    spec_serial: bool,
    /// Lanes decoding at the top of the current tick — the set the
    /// budget reserved for and the decode step serves (sequences whose
    /// final chunk lands mid-tick join the batch next tick, keeping the
    /// packed-token count under the budget).
    tick_decode: Vec<usize>,
    /// Per-slot speculation depth planned at the top of the tick
    /// (DESIGN.md §13): each decoding lane's round is charged `γ + 1`
    /// budget tokens, so the chunk packer sees the real reservation.
    /// All zeros when speculation is off.
    tick_gamma: Vec<usize>,
    metrics: EngineMetrics,
    /// Flight recorder (DESIGN.md §15): bounded ring of lifecycle
    /// events, snapshot via `GET /trace` / [`Engine::trace_snapshot`].
    recorder: trace::Recorder,
    /// Logical tick index stamped on every trace event — deterministic
    /// across runs, so golden tests compare event sequences.
    tick_idx: u64,
}

impl Engine<PjrtBackend> {
    /// Build the real engine from an artifacts directory.
    pub fn from_artifacts(
        artifacts: &std::path::Path,
        cfg: &EngineConfig,
    ) -> Result<Engine<PjrtBackend>> {
        let (backend, eos) = PjrtBackend::new(artifacts, cfg)?;
        Ok(Engine::with_backend(backend, cfg.clone(), eos))
    }
}

impl<B: DecodeBackend> Engine<B> {
    /// Assemble an engine around any backend (tests construct this with a
    /// [`testbackend::FakeBackend`] and drive [`Engine::tick`] directly).
    pub fn with_backend(
        backend: B,
        mut cfg: EngineConfig,
        eos: u32,
    ) -> Engine<B> {
        assert_eq!(
            backend.batch(),
            cfg.decode_batch,
            "backend batch must match decode_batch"
        );
        // Resolve the token budget.  The chunk alignment is the paged
        // block size (chunk writes stay whole-block for the device
        // scatter graphs) or 1 on a flat cache; requiring the budget to
        // cover every lane decoding *plus* one aligned slice guarantees
        // the first prefilling lane the packer visits always makes
        // progress — no starvation (property-tested).
        let align =
            cfg.paged.as_ref().map(|p| p.block_size).unwrap_or(1);
        if cfg.tokens_per_step == 0 {
            cfg.tokens_per_step = cfg.decode_batch
                + cfg.prefill_buckets.iter().copied().max().unwrap_or(1);
        }
        assert!(
            cfg.tokens_per_step >= cfg.decode_batch + align,
            "tokens_per_step {} must be >= decode_batch {} + chunk \
             alignment {align}",
            cfg.tokens_per_step,
            cfg.decode_batch
        );
        if let Some(sc) = &cfg.spec {
            assert!(sc.gamma >= 1, "speculation needs gamma >= 1");
            assert!(
                backend.supports_speculation(),
                "speculative config over a backend without draft/verify \
                 passes (the PJRT draft graphs are gated, see ROADMAP)"
            );
        }
        let paged = cfg.paged.as_ref().map(|p| {
            assert!(
                backend.supports_paged(),
                "paged engine config over a backend without paged KV"
            );
            assert!(p.num_blocks >= 2,
                    "paged pool needs >= 2 blocks (block 0 is the sentinel)");
            assert_eq!(backend.t_max() % p.block_size, 0,
                       "block_size must divide t_max");
            for &b in &cfg.prefill_buckets {
                assert_eq!(b % p.block_size, 0,
                           "block_size must divide prefill bucket {b}");
            }
            assert!(
                (!p.prefix_sharing && p.swap_blocks == 0)
                    || backend.supports_block_ops(),
                "prefix sharing / swap need backend block ops (the \
                 device-paged path is gated, see ROADMAP)"
            );
            assert!(
                p.session_blocks == 0 || p.prefix_sharing,
                "session persistence re-admits via the prefix index; \
                 session_blocks needs prefix_sharing"
            );
            PagedState {
                alloc: BlockAllocator::new(p.num_blocks, p.block_size),
                tables: (0..cfg.decode_batch)
                    .map(|_| BlockTable::new())
                    .collect(),
                index: PrefixIndex::new(),
                swap: SwapPool::new(p.swap_blocks),
                sharing: p.prefix_sharing,
                sessions: Vec::new(),
                session_budget: p.session_blocks,
            }
        });
        let slots = SlotMap::new(cfg.decode_batch, backend.t_max());
        let lanes = (0..cfg.decode_batch).map(|_| Lane::Idle).collect();
        let recorder = trace::Recorder::new(cfg.trace_capacity);
        Engine {
            backend,
            slots,
            cfg,
            eos,
            waiting: Default::default(),
            lanes,
            paged,
            swapped: Default::default(),
            groups: Default::default(),
            prefill_cursor: 0,
            scratch_active: Vec::new(),
            scratch_tokens: Vec::new(),
            scratch_pos: Vec::new(),
            scratch_groups: Vec::new(),
            scratch_gamma: Vec::new(),
            scratch_base: Vec::new(),
            scratch_fed: Vec::new(),
            scratch_lens: Vec::new(),
            scratch_rng: Vec::new(),
            scratch_sampling: Vec::new(),
            scratch_round: Vec::new(),
            spec_serial: false,
            tick_decode: Vec::new(),
            tick_gamma: Vec::new(),
            metrics: EngineMetrics::default(),
            recorder,
            tick_idx: 0,
        }
    }

    /// Route speculative ticks through the per-lane PR 6 draft/verify
    /// loop instead of the batched round.  Token streams are
    /// bit-identical either way (the batching changes launch shape,
    /// not sampling order) — golden tests and the batched-vs-serial
    /// proptest pin exactly that, and `lqer bench spec` uses it to
    /// measure the launch-count delta.
    pub fn set_spec_serial(&mut self, serial: bool) {
        self.spec_serial = serial;
    }

    /// Queue a request for admission (the threaded path does this from
    /// `Msg::Submit`).  Under [`AdmissionPolicy::Wait`] the queue is
    /// bounded: overflow is answered `Rejected` immediately rather than
    /// queued forever.
    pub fn enqueue(&mut self, request: Request, reply: mpsc::Sender<Response>) {
        self.metrics.submitted += 1;
        let w = Waiting {
            request,
            reply,
            submitted: now_ns(),
            preempted: false,
        };
        if let AdmissionPolicy::Wait { queue_depth, .. } =
            self.cfg.admission
        {
            if self.waiting.len() >= queue_depth {
                self.reject(w, "admission queue full",
                            FinishReason::Rejected);
                return;
            }
        }
        self.waiting.push_back(w);
    }

    /// Anything queued, swapped out, or in flight?
    pub fn has_work(&self) -> bool {
        !self.waiting.is_empty()
            || !self.swapped.is_empty()
            || self.slots.free_count() != self.slots.batch()
    }

    /// Sequences currently parked in the swap pool.
    pub fn swapped_len(&self) -> usize {
        self.swapped.len()
    }

    /// Decode lanes currently unoccupied.
    pub fn free_slots(&self) -> usize {
        self.slots.free_count()
    }

    /// Decode batch size (lane count) the engine was built with.
    pub fn kv_batch(&self) -> usize {
        self.slots.batch()
    }

    /// Requests parked in the admission queue.
    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    /// Lanes currently streaming their prompt in (Prefilling phase).
    pub fn prefilling_len(&self) -> usize {
        self.lanes.iter().filter(|l| l.is_prefilling()).count()
    }

    /// `(request id, rows present, prompt length)` of every Prefilling
    /// lane — the chunk-progress view the no-starvation property test
    /// watches.
    pub fn prefill_progress(&self) -> Vec<(u64, usize, usize)> {
        self.lanes
            .iter()
            .filter_map(|l| match l {
                Lane::Prefilling(p) => {
                    Some((p.request.id, p.next_row, p.prompt.len()))
                }
                _ => None,
            })
            .collect()
    }

    /// The resolved per-tick token budget.
    pub fn tokens_per_step(&self) -> usize {
        self.cfg.tokens_per_step
    }

    /// Free blocks in the paged pool (0 when flat).
    pub fn free_blocks(&self) -> usize {
        self.paged.as_ref().map(|p| p.alloc.free_count()).unwrap_or(0)
    }

    /// Direct (non-channel) metrics snapshot with live gauges filled
    /// in — the in-process view tests and benches read.
    pub fn metrics_snapshot(&self) -> EngineMetrics {
        let mut m = self.metrics.clone();
        m.exec = self.backend.exec_stats();
        m.decode_exec = self.backend.entry_stats("decode");
        m.decode_exec.merge(&self.backend.entry_stats("decode_dev"));
        m.decode_exec.merge(&self.backend.entry_stats("decode_paged"));
        m.waiting = self.waiting.len() as u64;
        m.tokens_per_step = self.cfg.tokens_per_step as u64;
        m.prefilling = self.prefilling_len() as u64;
        if let Some(p) = &self.paged {
            m.kv_block_size = p.alloc.block_size() as u64;
            m.kv_blocks_total = p.alloc.capacity() as u64;
            m.kv_blocks_in_use = p.alloc.in_use() as u64;
            m.kv_utilization = p.alloc.utilization();
            m.kv_shared_blocks = p.alloc.shared_blocks() as u64;
            m.kv_shared_refs = p.alloc.shared_refs();
            m.swapped_seqs = self.swapped.len() as u64;
            m.swap_blocks_in_use = p.swap.blocks_in_use() as u64;
            m.swap_blocks_total = p.swap.max_blocks() as u64;
            m.sessions_live = p.sessions.len() as u64;
            m.session_blocks_held = p.session_blocks_held() as u64;
        }
        m.trace_events_total = self.recorder.total();
        m.trace_dropped_total = self.recorder.dropped();
        m
    }

    /// Flight-recorder contents, oldest first (DESIGN.md §15) — the
    /// direct-drive twin of [`EngineHandle::trace`] for tests and
    /// benches.
    pub fn trace_snapshot(&self) -> Vec<trace::TraceRecord> {
        self.recorder.snapshot()
    }

    fn run(&mut self, rx: mpsc::Receiver<Msg>) {
        loop {
            // 1. Drain control/submission messages (block only when idle).
            let idle = !self.has_work();
            loop {
                let msg = if idle && self.waiting.is_empty() {
                    match rx.recv() {
                        Ok(m) => m,
                        Err(_) => return,
                    }
                } else {
                    match rx.try_recv() {
                        Ok(m) => m,
                        Err(mpsc::TryRecvError::Empty) => break,
                        Err(mpsc::TryRecvError::Disconnected) => return,
                    }
                };
                match msg {
                    Msg::Submit(request, reply) => {
                        self.enqueue(request, reply);
                    }
                    Msg::Metrics(tx) => {
                        let _ = tx.send(self.metrics_snapshot());
                    }
                    Msg::Trace(tx) => {
                        let _ = tx.send(self.recorder.snapshot());
                    }
                    Msg::Shutdown => return,
                }
                if !idle {
                    // Drain whatever is queued without blocking, then serve.
                    continue;
                }
            }

            // 2.+3. One scheduler iteration.
            self.tick();
        }
    }

    /// One token-budget step (DESIGN.md §12): expire overdue waiters,
    /// swap preempted sequences back in, reserve one budget token per
    /// decoding lane, pack the remaining budget with chunked-prefill
    /// slices, admit queued requests into the Prefilling phase while
    /// capacity (lanes *and* KV blocks) lasts, then run one batched
    /// decode step over the lanes that were decoding at the top of the
    /// tick.
    pub fn tick(&mut self) {
        let tick_t0 = now_ns();
        self.tick_idx += 1;
        self.expire_waiting();
        self.swap_in_ready();
        // Snapshot the decode set.  Sequences completing their final
        // chunk mid-tick join the batch next tick, so decode + chunk
        // tokens can never exceed the budget.
        self.tick_decode.clear();
        for s in 0..self.lanes.len() {
            if self.lanes[s].is_decoding() {
                self.tick_decode.push(s);
            }
        }
        let budget = self.cfg.tokens_per_step;
        // With speculation each decoding lane reserves γ + 1 tokens (γ
        // drafts + the verify's bonus position) instead of 1; the depth
        // is planned here, at the top of the tick, so the chunk packer
        // and the decode phase agree on the reservation.
        self.tick_gamma.clear();
        self.tick_gamma.resize(self.lanes.len(), 0);
        let mut decode_tokens = self.tick_decode.len();
        if self.cfg.spec.is_some() {
            let mut extra = budget.saturating_sub(decode_tokens);
            let t_max = self.backend.t_max();
            for i in 0..self.tick_decode.len() {
                let s = self.tick_decode[i];
                let Lane::Decoding(seq) = &self.lanes[s] else {
                    unreachable!();
                };
                let pos = self.slots.pos(s);
                // Drafting past the cache or the request's token limit
                // is pure waste: rows pos..pos+γ must all be writable
                // (non-speculative decode never writes past t_max - 2),
                // and at most `remaining - 1` drafts can be accepted.
                let cache_cap =
                    t_max.saturating_sub(2).saturating_sub(pos);
                let len_cap = seq
                    .request
                    .max_new_tokens
                    .saturating_sub(seq.generated.len())
                    .saturating_sub(1);
                let g =
                    seq.gamma.min(cache_cap).min(len_cap).min(extra);
                self.tick_gamma[s] = g;
                extra -= g;
                decode_tokens += g;
            }
        }
        let chunk_budget = budget.saturating_sub(decode_tokens);
        // In-flight Prefilling lanes pack first — the no-starvation
        // guarantee (first-visited lane always gets an aligned slice)
        // holds no matter what admission does with the leftovers.
        let prefill_tokens = self.prefill_chunks(chunk_budget);
        let admit_spent = self
            .admit_waiting(chunk_budget.saturating_sub(prefill_tokens));
        self.metrics
            .packed_prefill_tokens
            .record((admit_spent + prefill_tokens) as f64);
        self.metrics.packed_tokens.record(
            (decode_tokens + admit_spent + prefill_tokens) as f64,
        );
        if !self.tick_decode.is_empty() {
            let r = if self.cfg.spec.is_some() {
                self.decode_step_spec()
            } else {
                self.decode_step()
            };
            if let Err(e) = r {
                crate::info!("decode step failed: {e:#}");
            }
        }
        self.metrics.ticks += 1;
        self.metrics.tick_ns += now_ns().saturating_sub(tick_t0);
    }

    /// Admit queue heads while capacity lasts.  Admission commits the
    /// lane and every KV block the whole prompt needs up front, but
    /// processes no prompt tokens — those stream in chunk slices, so an
    /// arriving 2k-token prompt no longer stalls running decodes by a
    /// full prefill.  The one exception is a prompt *fully resident*
    /// via the prefix index: its zero-row final chunk must run at
    /// admission (a Prefilling lane may not sit with its position
    /// inside a shared block — see [`PrefillSeq`]), and that forward
    /// still costs a whole-prefix prefill execution on the graphs
    /// (they recompute; only a future incremental-attention chunk
    /// graph would not — ROADMAP).  Each such admission is therefore
    /// charged its full prompt length against `chunk_budget`, clamped
    /// to what remains so an over-budget prompt is not starved
    /// forever; at most one clamped execution lands per tick, the same
    /// per-tick bound the packer gives regular chunks.  A fully-shared
    /// head waits for the next tick once the budget is spent.  Returns
    /// the tokens charged.
    fn admit_waiting(&mut self, mut chunk_budget: usize) -> usize {
        let bs = self
            .paged
            .as_ref()
            .map(|p| p.alloc.block_size())
            .unwrap_or(1);
        let mut spent = 0usize;
        while !self.waiting.is_empty() {
            // Swapped-out sequences are older than anything in the
            // waiting queue; while any is parked, new admissions hold
            // back so the blocks they would take go to resumption
            // instead.  RejectOnFull keeps its instant accept-or-shed
            // contract: non-preempted heads are rejected rather than
            // silently queued behind the parked sequences.
            if !self.swapped.is_empty() {
                match self.cfg.admission {
                    AdmissionPolicy::RejectOnFull
                        if !self.waiting[0].preempted =>
                    {
                        let w = self.waiting.pop_front().unwrap();
                        self.reject(
                            w,
                            "capacity reserved for swapped sequences",
                            FinishReason::Rejected,
                        );
                        continue;
                    }
                    _ => break, // heads wait for resumption
                }
            }
            if self.slots.free_count() == 0
                && matches!(self.cfg.admission,
                            AdmissionPolicy::Wait { .. })
            {
                // No lane: the head waits.  Checked before planning so
                // a blocked head is not re-planned (prompt re-filtered
                // and re-allocated) on every decode tick.
                break;
            }
            match self.plan_admission(&self.waiting[0].request) {
                Err(why) => {
                    // Permanently unservable regardless of capacity.
                    let w = self.waiting.pop_front().unwrap();
                    self.reject(w, &why, FinishReason::Rejected);
                }
                Ok(plan) if self.has_capacity(&plan) => {
                    let len = plan.prompt.len();
                    let fully_shared = plan.shared.len() * bs >= len;
                    if fully_shared && chunk_budget == 0 {
                        // Its immediate final chunk would bust the
                        // tick's budget; the head keeps its queue spot
                        // until the next tick.
                        break;
                    }
                    let w = self.waiting.pop_front().unwrap();
                    self.admit(w, plan);
                    if fully_shared {
                        let charge = len.min(chunk_budget);
                        chunk_budget -= charge;
                        spent += charge;
                    }
                }
                // Capacity miss.  Parked sessions are reclaimed first
                // (their blocks stay revivable via the index); only
                // then do preempted-entry / shed rules apply.
                // Preempted entries always wait — they were already
                // admitted once, and shedding them would turn
                // preemption into request loss even under RejectOnFull.
                Ok(_) => {
                    if self.reclaim_session_blocks() {
                        continue; // re-plan with the larger free list
                    }
                    match self.cfg.admission {
                        AdmissionPolicy::RejectOnFull
                            if !self.waiting[0].preempted =>
                        {
                            let w = self.waiting.pop_front().unwrap();
                            self.reject(w, "no free KV capacity",
                                        FinishReason::Rejected);
                        }
                        _ => break, // head waits
                    }
                }
            }
        }
        spent
    }

    /// Drop queue entries whose admission deadline has passed, answering
    /// each with `FinishReason::Expired`.
    fn expire_waiting(&mut self) {
        let AdmissionPolicy::Wait { deadline_ms, .. } = self.cfg.admission
        else {
            return;
        };
        if deadline_ms == 0 {
            return;
        }
        let deadline_ns = deadline_ms.saturating_mul(1_000_000);
        let now = now_ns();
        let mut i = 0;
        while i < self.waiting.len() {
            if !self.waiting[i].preempted
                && now.saturating_sub(self.waiting[i].submitted)
                    >= deadline_ns
            {
                let w = self.waiting.remove(i).unwrap();
                self.reject(w, "admission deadline exceeded",
                            FinishReason::Expired);
            } else {
                i += 1;
            }
        }
    }

    /// The vocab-filtered, `t_max`-capped form of a prompt — exactly
    /// what [`Self::plan_admission`] serves and what the prefix index
    /// was keyed on at registration.
    fn canonical_prompt(&self, prompt: &[u32]) -> Vec<u32> {
        let vocab = self.backend.vocab();
        let mut p: Vec<u32> = prompt
            .iter()
            .copied()
            .filter(|&t| (t as usize) < vocab)
            .collect();
        p.truncate(self.backend.t_max() - 1);
        p
    }

    /// What admitting this request costs, or why it can never be served.
    /// The prompt served is exactly [`Self::canonical_prompt`] — one
    /// truncation/filter rule shared with the chunk stream and the
    /// prefix index, so they cannot diverge.
    fn plan_admission(&self, request: &Request)
        -> Result<AdmitPlan, String> {
        if request.n > 1 || request.beams > 1 {
            // Forked workloads (DESIGN.md §16) need the COW block
            // machinery; on anything else they are permanently
            // unservable, not a capacity miss.
            if request.n > 1 && request.beams > 1 {
                return Err(
                    "n > 1 and beams > 1 are mutually exclusive".into()
                );
            }
            if self.paged.is_none()
                || !self.backend.supports_block_ops()
            {
                return Err("parallel sampling / beam search need a \
                            paged engine with block ops"
                    .into());
            }
            if self.cfg.spec.is_some() {
                return Err("parallel sampling / beam search are not \
                            supported on a speculative engine"
                    .into());
            }
        }
        let prompt = self.canonical_prompt(&request.prompt);
        let len = prompt.len();
        if len == 0 {
            return Err("empty prompt".into());
        }
        if batching::pick_bucket(&self.cfg.prefill_buckets, len).is_none()
        {
            return Err("prompt longer than any prefill bucket".into());
        }
        let mut shared = Vec::new();
        let blocks = match &self.paged {
            Some(p) => {
                let need = p.alloc.blocks_for_rows(len);
                if need > p.alloc.capacity() {
                    return Err(format!(
                        "prompt needs {need} blocks, pool holds only {}",
                        p.alloc.capacity()
                    ));
                }
                if p.sharing {
                    shared = Self::match_prefix(p, &prompt);
                }
                need - shared.len()
            }
            None => 0,
        };
        Ok(AdmitPlan { prompt, blocks, shared })
    }

    /// Longest prefix-index match for a (canonical) prompt: full blocks
    /// along the chain, then — only when every full block hit — the
    /// whole-prompt tail entry covering the trailing partial block.
    /// Each hit is `(block, needs_revive)`: a hit on a live block is
    /// retained (one more reference), a hit on a recently-freed block
    /// is revived out of the free list.
    fn match_prefix(p: &PagedState, prompt: &[u32]) -> Vec<(u32, bool)> {
        let len = prompt.len();
        let bs = p.alloc.block_size();
        let full = len / bs;
        let mut shared = Vec::new();
        let mut parent = PREFIX_SEED;
        for i in 0..full {
            let span = &prompt[i * bs..(i + 1) * bs];
            let Some(b) = p.index.lookup(parent, span) else { break };
            shared.push((b, p.alloc.ref_count(b) == 0));
            parent = chain_hash(parent, span);
        }
        if shared.len() == full && len % bs != 0 {
            if let Some(b) = p.index.lookup(parent, &prompt[full * bs..len])
            {
                shared.push((b, p.alloc.ref_count(b) == 0));
            }
        }
        shared
    }

    /// Can the queue head be admitted *now*?  Flat mode counts lanes;
    /// paged mode additionally counts the free-list draw (fresh blocks
    /// plus revived prefix hits).
    fn has_capacity(&self, plan: &AdmitPlan) -> bool {
        if self.slots.free_count() == 0 {
            return false;
        }
        match &self.paged {
            Some(p) => p.alloc.free_count() >= plan.free_blocks_needed(),
            None => true,
        }
    }

    /// Under capacity pressure, parked sessions are the first thing to
    /// go: drop the oldest one so its blocks return to the free list
    /// (still prefix-indexed — a later matching turn can revive them).
    /// Returns true when something was reclaimed and the caller should
    /// retry its allocation.
    fn reclaim_session_blocks(&mut self) -> bool {
        let Some(p) = &mut self.paged else { return false };
        if p.evict_oldest_session() {
            self.metrics.session_evictions += 1;
            return true;
        }
        false
    }

    /// Return a lane's blocks (if paged) and the lane itself.
    fn release_slot(&mut self, slot: usize) {
        if let Some(p) = &mut self.paged {
            for id in p.tables[slot].take_blocks() {
                p.alloc.free(id);
            }
        }
        self.slots.free(slot);
    }

    /// Answer a request that will not be served; the slot (if any) has
    /// already been released by the caller.  Every terminal outcome —
    /// rejected or expired — records a latency sample so the p50/p99
    /// histograms are not survivorship-biased toward served requests.
    fn reject(&mut self, w: Waiting, why: &str, finish: FinishReason) {
        crate::info!("request {} {:?}: {why}", w.request.id, finish);
        match finish {
            FinishReason::Expired => self.metrics.expired += 1,
            _ => self.metrics.rejected += 1,
        }
        if finish == FinishReason::Expired {
            self.recorder.emit(
                self.tick_idx,
                w.request.id,
                None,
                0,
                TraceEvent::Expired,
            );
        }
        self.recorder.emit(
            self.tick_idx,
            w.request.id,
            None,
            0,
            TraceEvent::Finished { reason: finish },
        );
        let total_ms =
            ns_to_ms(now_ns().saturating_sub(w.submitted));
        self.metrics.ttft_ms.record(total_ms);
        self.metrics.total_ms.record(total_ms);
        let _ = w.reply.send(Response {
            id: w.request.id,
            prompt_len: w.request.prompt.len(),
            tokens: Vec::new(),
            finish,
            ttft_ms: total_ms,
            total_ms,
            swapped_ms: 0.0,
            candidates: Vec::new(),
        });
    }

    /// Commit a lane plus every KV block the prompt needs and park the
    /// sequence in the Prefilling phase; no prompt token is processed
    /// here.  A prompt fully served by the prefix index (every row
    /// already resident) runs its zero-row final chunk immediately — it
    /// has no prefill work to spread over ticks, only logits to fetch.
    fn admit(&mut self, w: Waiting, plan: AdmitPlan) {
        let AdmitPlan { prompt, blocks, shared } = plan;
        let len = prompt.len();
        if let (Some(sid), Some(p)) =
            (w.request.session, &mut self.paged)
        {
            // A returning conversation: count the hit and LRU-touch the
            // parked entry.  The prefix hits in `shared` do the actual
            // block reuse — sharing is content-addressed, not
            // session-id-keyed, so an edited history simply matches
            // less.
            if let Some(i) =
                p.sessions.iter().position(|e| e.id == sid)
            {
                let e = p.sessions.remove(i);
                p.sessions.push(e);
                self.metrics.session_hits += 1;
            }
        }
        let Some(slot) = self.slots.alloc(w.request.id) else {
            self.reject(w, "no free KV slot", FinishReason::Rejected);
            return;
        };
        if let Some(p) = &mut self.paged {
            debug_assert!(p.tables[slot].is_empty(), "stale block table");
            // Map the prefix hits first (read-only): live blocks gain a
            // reference, recently-freed ones are revived with their
            // bytes intact.  Plans are made and applied back-to-back on
            // the engine thread, so a planned revival cannot race.
            for &(id, revive) in &shared {
                if revive {
                    assert!(p.alloc.revive(id), "planned revival raced");
                } else {
                    p.alloc.retain(id);
                }
                p.tables[slot].push(id);
            }
            for _ in 0..blocks {
                match p.alloc_fresh() {
                    Some(id) => p.tables[slot].push(id),
                    None => {
                        // has_capacity checked free blocks; defensive.
                        self.release_slot(slot);
                        self.reject(w, "block pool exhausted",
                                    FinishReason::Rejected);
                        return;
                    }
                }
            }
        }

        // Rows already resident via the read-only prefix hits.  Hits
        // are a leading run of full blocks, plus — only when every full
        // block hit — the whole-prompt tail, in which case the entire
        // prompt is present and `shared.len() * bs` overshoots `len`.
        let bs = self
            .paged
            .as_ref()
            .map(|p| p.alloc.block_size())
            .unwrap_or(1);
        let shared_rows = (shared.len() * bs).min(len);
        if self.slots.set_pos(slot, shared_rows).is_err() {
            self.release_slot(slot);
            self.reject(w, "slot update failed", FinishReason::Rejected);
            return;
        }
        let rid = w.request.id;
        self.lanes[slot] = Lane::Prefilling(PrefillSeq {
            request: w.request,
            reply: w.reply,
            submitted: w.submitted,
            prompt,
            next_row: shared_rows,
            shared_blocks: shared.len(),
        });
        self.recorder.emit(
            self.tick_idx,
            rid,
            Some(slot),
            0,
            TraceEvent::Admitted { blocks, shared: shared.len() },
        );
        if shared_rows == len {
            // Whole prompt already resident: the final chunk processes
            // zero new rows, so run it now for its logits rather than
            // holding a lane through a no-op Prefilling tick.  (This
            // also keeps a mid-prefill lane's position out of shared
            // blocks — see the dead-write note on [`PrefillSeq`].)
            // Its wall-clock stalls live decodes exactly like a packed
            // chunk, so it feeds the same gauge.
            let t0 = now_ns();
            self.run_chunk(slot, len, 0);
            if !self.tick_decode.is_empty() {
                self.metrics.decode_stall_ns +=
                    now_ns().saturating_sub(t0);
            }
        }
    }

    /// Fill the tick's remaining token budget with chunked-prefill
    /// slices, round-robin from a rotating cursor so every Prefilling
    /// lane keeps making progress.  Returns the prompt rows processed;
    /// wall-clock spent here while decode lanes were waiting feeds the
    /// decode-stall gauge.
    fn prefill_chunks(&mut self, mut left: usize) -> usize {
        let b = self.lanes.len();
        if b == 0 || left == 0 {
            return 0;
        }
        let align = self
            .paged
            .as_ref()
            .map(|p| p.alloc.block_size())
            .unwrap_or(1);
        let stall_t0 = now_ns();
        let decoding = !self.tick_decode.is_empty();
        let start = self.prefill_cursor % b;
        let mut packed = 0usize;
        for off in 0..b {
            if left == 0 {
                break;
            }
            let slot = (start + off) % b;
            let Lane::Prefilling(seq) = &self.lanes[slot] else {
                continue;
            };
            let take = batching::chunk_len(
                seq.prompt.len(),
                seq.next_row,
                left,
                align,
            );
            if take == 0 {
                continue;
            }
            let chunk_end = seq.next_row + take;
            let done =
                self.run_chunk(slot, chunk_end, left.saturating_sub(take));
            packed += done;
            left = left.saturating_sub(done);
        }
        self.prefill_cursor = self.prefill_cursor.wrapping_add(1);
        if decoding && packed > 0 {
            self.metrics.decode_stall_ns +=
                now_ns().saturating_sub(stall_t0);
        }
        packed
    }

    /// Execute one prefill chunk for a Prefilling lane: process prompt
    /// rows `[next_row, chunk_end)`.  The backend recomputes the whole
    /// prefix through the existing bucketed b=1 prefill path (the
    /// bit-exactness oracle; the gated device `prefill_chunk` graph
    /// fuses it) but installs only rows earlier chunks have not
    /// finalized.  On the final chunk the first token is sampled (TTFT)
    /// and the lane transitions to Decoding.  Returns the new rows
    /// processed; a backend failure releases the lane and answers
    /// `Rejected`.  `budget_left` is the tick budget remaining after
    /// this chunk — pure trace payload (the fully-shared admission
    /// chunk passes 0: it is charged against the leftover budget by
    /// its caller).
    fn run_chunk(
        &mut self,
        slot: usize,
        chunk_end: usize,
        budget_left: usize,
    ) -> usize {
        let vocab = self.backend.vocab();
        let Some(bucket) =
            batching::pick_bucket(&self.cfg.prefill_buckets, chunk_end)
        else {
            // plan_admission proved the full prompt fits a bucket, and
            // chunk_end <= len; defensive.
            self.fail_prefill(slot, "no prefill bucket for chunk");
            return 0;
        };
        let (len, row_offset, shared_blocks, toks, rid) = {
            let Lane::Prefilling(seq) = &self.lanes[slot] else {
                unreachable!("chunk on a non-prefilling lane");
            };
            debug_assert!(
                seq.next_row <= chunk_end
                    && chunk_end <= seq.prompt.len()
            );
            // Right-pad the prefix to the chunk's bucket.
            let mut toks = vec![0i32; bucket];
            for (i, t) in seq.prompt.iter().take(chunk_end).enumerate()
            {
                toks[i] = *t as i32;
            }
            (
                seq.prompt.len(),
                seq.next_row,
                seq.shared_blocks,
                toks,
                seq.request.id,
            )
        };
        let (result, chunk_ns) = {
            let span = trace::Span::new(&mut self.metrics.prefill_ns);
            let r = match &self.paged {
                Some(p) => self.backend.prefill_chunk_paged(
                    slot, &p.tables[slot], &toks, bucket, chunk_end,
                    row_offset, shared_blocks,
                ),
                None => self.backend.prefill_chunk(
                    slot, &toks, bucket, chunk_end, row_offset,
                ),
            };
            let ns = span.elapsed_ns();
            (r, ns)
        };
        let logits = match result {
            Ok(l) => l,
            Err(e) => {
                self.fail_prefill(
                    slot,
                    &format!("prefill chunk failed: {e:#}"),
                );
                return 0;
            }
        };
        self.metrics.prefill_steps += 1;
        self.metrics.backend_launches += 1;
        if logits.len() < bucket * vocab {
            self.fail_prefill(slot, "prefill returned short logits");
            return 0;
        }
        if self.slots.set_pos(slot, chunk_end).is_err() {
            self.fail_prefill(slot, "slot update failed");
            return 0;
        }
        let processed = chunk_end - row_offset;
        self.recorder.emit(
            self.tick_idx,
            rid,
            Some(slot),
            chunk_ns,
            TraceEvent::ChunkPrefilled { rows: processed, budget_left },
        );
        if chunk_end < len {
            let Lane::Prefilling(seq) = &mut self.lanes[slot] else {
                unreachable!();
            };
            seq.next_row = chunk_end;
        } else {
            self.complete_prefill(slot, &logits);
        }
        processed
    }

    /// A backend error mid-prefill: release the lane + blocks and
    /// answer `Rejected` (nothing was generated yet).
    fn fail_prefill(&mut self, slot: usize, why: &str) {
        let Lane::Prefilling(seq) = self.lanes[slot].take() else {
            unreachable!("prefill failure on a non-prefilling lane");
        };
        self.release_slot(slot);
        self.reject(
            Waiting {
                request: seq.request,
                reply: seq.reply,
                submitted: seq.submitted,
                preempted: false,
            },
            why,
            FinishReason::Rejected,
        );
    }

    /// The final chunk landed: account the sharing win, register the
    /// prompt's freshly-written blocks in the prefix index (only now —
    /// a partially-prefilled or failed prompt must never be shared),
    /// sample the first token (TTFT), and move the lane to Decoding.
    fn complete_prefill(&mut self, slot: usize, logits: &[f32]) {
        let vocab = self.backend.vocab();
        let block_bytes = self.backend.block_bytes() as u64;
        let Lane::Prefilling(pre) = self.lanes[slot].take() else {
            unreachable!("completion of a non-prefilling lane");
        };
        let PrefillSeq {
            request,
            reply,
            submitted,
            prompt,
            shared_blocks,
            ..
        } = pre;
        let len = prompt.len();
        if let Some(p) = &mut self.paged {
            if p.sharing {
                self.metrics.prefix_hit_blocks += shared_blocks as u64;
                self.metrics.prefix_bytes_saved +=
                    shared_blocks as u64 * block_bytes;
                let bs = p.alloc.block_size();
                let full = len / bs;
                let mut parent = PREFIX_SEED;
                for i in 0..full {
                    let span = &prompt[i * bs..(i + 1) * bs];
                    if i >= shared_blocks {
                        p.index.insert(parent, span,
                                       p.tables[slot].blocks()[i]);
                    }
                    parent = chain_hash(parent, span);
                }
                if len % bs != 0 && shared_blocks <= full {
                    p.index.insert(parent, &prompt[full * bs..len],
                                   p.tables[slot].blocks()[full]);
                }
            }
        }

        // Sample the first generated token from the last prompt position.
        let row = &logits[(len - 1) * vocab..len * vocab];
        let fanout = request.n.max(1).max(request.beams);
        let beams = request.beams > 1;
        let rid = request.id;
        let mut seq = ActiveSeq {
            rng: Rng::new(match request.sampling {
                Sampling::TopK { seed, .. } => seed ^ request.id,
                Sampling::Greedy => request.id,
            }),
            request,
            reply,
            submitted,
            ttft_ms: None,
            swapped_ms: 0.0,
            generated: Vec::new(),
            last_token: 0,
            last_token_at: now_ns(),
            gamma: self
                .cfg
                .spec
                .as_ref()
                .map(|sc| sc.gamma)
                .unwrap_or(0),
            accept_ewma: 1.0,
            group: None,
            cand: 0,
            score: 0.0,
        };
        // Fanout (DESIGN.md §16): the primary candidate IS the plain
        // sequence — same RNG stream, same first-token draw — so the
        // n=1 path stays bit-identical by construction.  Beam search
        // ranks deterministically: candidate i starts from the i-th
        // best first token.
        let ranked = if fanout > 1 {
            top_tokens(row, fanout)
        } else {
            Vec::new()
        };
        let first = if beams {
            ranked[0].0
        } else {
            sample(row, seq.request.sampling, &mut seq.rng)
        };
        if fanout > 1 {
            seq.group = Some(rid);
            seq.score = if beams {
                ranked[0].1
            } else {
                token_logprob(row, first)
            };
        }
        seq.ttft_ms =
            Some(ns_to_ms(now_ns().saturating_sub(seq.submitted)));
        seq.generated.push(first);
        seq.last_token = first;
        seq.last_token_at = now_ns();
        self.lanes[slot] = Lane::Decoding(seq);
        if fanout > 1 {
            // Siblings fork before the primary's finish check so the
            // group exists by the time any candidate completes.
            self.fork_group(slot, rid, fanout, beams, row, &ranked);
        }
        // The sampled token will be fed at position `len` by decode_step;
        // finish immediately if it is EOS or the request wants one token.
        self.maybe_finish(slot);
    }

    /// Fork `fanout - 1` sibling decode tails off a freshly-prefilled
    /// lane (DESIGN.md §16): each sibling's block table retains every
    /// block of the primary's table read-only (COW splits the tail on
    /// the first divergent write, so K candidates cost ~1x the prompt),
    /// draws its own first token from the same final-chunk logits row,
    /// and joins the request's [`ForkGroup`].  Siblings beyond the free
    /// lane supply are dropped (`fork_denied`) — the group completes
    /// with the candidates that fit.
    fn fork_group(
        &mut self,
        primary: usize,
        rid: u64,
        fanout: usize,
        beams: bool,
        row: &[f32],
        ranked: &[(u32, f64)],
    ) {
        let (reply, submitted, request, ttft_ms) = {
            let Lane::Decoding(seq) = &self.lanes[primary] else {
                unreachable!("fork off a non-decoding lane");
            };
            (
                seq.reply.clone(),
                seq.submitted,
                seq.request.clone(),
                seq.ttft_ms,
            )
        };
        self.groups.insert(
            rid,
            ForkGroup {
                reply: reply.clone(),
                prompt_len: request.prompt.len(),
                submitted,
                beams,
                live: 1, // the primary
                done: Vec::new(),
                ttft_ms,
                swapped_ms: 0.0,
            },
        );
        let parent_pos = self.slots.pos(primary);
        let parent_blocks: Vec<u32> = self
            .paged
            .as_ref()
            .map(|p| p.tables[primary].blocks().to_vec())
            .unwrap_or_default();
        let base_seed = match request.sampling {
            Sampling::TopK { seed, .. } => seed ^ rid,
            Sampling::Greedy => rid,
        };
        let mut sibs: Vec<usize> = Vec::new();
        for i in 1..fanout {
            if beams && i >= ranked.len() {
                break; // vocabulary smaller than the beam width
            }
            let Some(slot) = self.slots.alloc(rid) else {
                self.metrics.fork_denied += (fanout - i) as u64;
                break;
            };
            if self.slots.set_pos(slot, parent_pos).is_err() {
                self.slots.free(slot);
                self.metrics.fork_denied += (fanout - i) as u64;
                break;
            }
            if let Some(p) = &mut self.paged {
                debug_assert!(
                    p.tables[slot].is_empty(),
                    "stale fork table"
                );
                for &b in &parent_blocks {
                    p.alloc.retain(b);
                    p.tables[slot].push(b);
                }
            }
            // Each sampling sibling decorrelates its RNG stream from
            // the primary's with an odd-constant mix of its candidate
            // index; beam candidates are deterministic and never draw.
            let mut rng = Rng::new(
                base_seed
                    ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
            );
            let (first, score) = if beams {
                ranked[i]
            } else {
                let t = sample(row, request.sampling, &mut rng);
                (t, token_logprob(row, t))
            };
            let now = now_ns();
            self.lanes[slot] = Lane::Decoding(ActiveSeq {
                request: request.clone(),
                reply: reply.clone(),
                submitted,
                ttft_ms,
                swapped_ms: 0.0,
                generated: vec![first],
                last_token: first,
                last_token_at: now,
                rng,
                gamma: 0,
                accept_ewma: 1.0,
                group: Some(rid),
                cand: i,
                score,
            });
            sibs.push(slot);
        }
        if let Some(g) = self.groups.get_mut(&rid) {
            g.live += sibs.len();
        }
        self.metrics.forks += sibs.len() as u64;
        self.recorder.emit(
            self.tick_idx,
            rid,
            Some(primary),
            0,
            TraceEvent::Forked { siblings: sibs.len() },
        );
        for s in sibs {
            self.maybe_finish(s);
        }
    }

    /// Make every decoding lane's next append writable: grow its table
    /// when `pos` crosses a block boundary, and copy-on-write fork the
    /// target block when it is shared (prefix hit still mapped by
    /// someone else) — a shared block is never mutated in place.
    /// Prefilling lanes are skipped: their blocks were committed at
    /// admission and their chunk writes never touch shared rows.  When
    /// the pool runs dry, evict the lowest-priority-then-youngest
    /// sequence — Prefilling lanes included: a mid-prefill victim is
    /// requeued (nothing sampled yet), a decoding victim's blocks are
    /// swapped out to the host pool (state preserved, resumed later)
    /// or — when the swap pool is full or disabled — the request
    /// re-enters the queue head for re-prefill (deterministic sampling
    /// replays the same stream).
    fn ensure_paged_capacity(&mut self) -> Result<()> {
        if self.paged.is_none() {
            return Ok(());
        }
        let bs = self.paged.as_ref().unwrap().alloc.block_size();
        loop {
            // What does some decoding lane need before this step's
            // append?  `None` cow = grow; `Some((idx, old))` = fork
            // table entry `idx` away from shared block `old`.
            let need = {
                let p = self.paged.as_ref().unwrap();
                self.slots.active_iter().find_map(|s| {
                    if !self.lanes[s].is_decoding() {
                        return None;
                    }
                    let pos = self.slots.pos(s);
                    if pos >= p.tables[s].capacity_rows(bs) {
                        return Some((s, None));
                    }
                    let (blk, _) =
                        p.tables[s].physical(pos, bs).unwrap();
                    if p.alloc.is_shared(blk) {
                        return Some((s, Some((pos / bs, blk))));
                    }
                    None
                })
            };
            let Some((s, cow)) = need else { return Ok(()) };
            if let Some(id) = self.paged.as_mut().unwrap().alloc_fresh() {
                match cow {
                    None => {
                        self.paged.as_mut().unwrap().tables[s].push(id);
                    }
                    Some((idx, old)) => {
                        if let Err(e) = self.backend.copy_block(old, id) {
                            // Don't leak the fork target on a broken
                            // backend path.
                            self.paged.as_mut().unwrap().alloc.free(id);
                            return Err(e);
                        }
                        let p = self.paged.as_mut().unwrap();
                        let prev = p.tables[s].replace(idx, id);
                        debug_assert_eq!(prev, old, "COW table drift");
                        // Drop this lane's reference to the original;
                        // the other holders (and the prefix index) keep
                        // it untouched.
                        p.alloc.free(old);
                        self.metrics.cow_copies += 1;
                        let rid = self.lanes[s]
                            .request()
                            .expect("COW on a live lane")
                            .id;
                        self.recorder.emit(
                            self.tick_idx,
                            rid,
                            Some(s),
                            0,
                            TraceEvent::CowFork,
                        );
                    }
                }
                continue;
            }
            // Pool dry: parked sessions go before live work — their
            // blocks stay revivable via the index, so reclaiming one is
            // strictly cheaper than preempting a running sequence.
            if self.reclaim_session_blocks() {
                continue;
            }
            let victim = self
                .slots
                .active_iter()
                .min_by_key(|&x| {
                    let r = self.lanes[x]
                        .request()
                        .expect("allocated lane has a sequence");
                    (r.priority, self.slots.pos(x), x)
                })
                .expect("needy lane implies an active lane");
            if victim == s && self.slots.active_iter().count() == 1 {
                // Alone and out of memory: evicting itself would replay
                // straight into the same wall, so finish with what fits.
                crate::info!(
                    "request {} hit the block pool ceiling",
                    self.lanes[s].request().unwrap().id
                );
                self.finish(s, FinishReason::CacheFull);
                return Ok(());
            }
            let victim_grouped = matches!(
                &self.lanes[victim],
                Lane::Decoding(seq) if seq.group.is_some()
            );
            if victim_grouped {
                // A forked candidate never requeues (re-admission would
                // re-fork the whole group) or swaps (beam lanes move in
                // lockstep): close it with the tokens it has — the
                // group completes from the surviving candidates.
                self.finish(victim, FinishReason::CacheFull);
                continue;
            }
            self.preempt(victim);
        }
    }

    /// Evict a sequence to reclaim KV blocks.  A mid-prefill victim is
    /// requeued outright (no sampled state exists to preserve — the
    /// replay is trivially identical); a decoding victim tries a
    /// block-level swap-out first, with full re-prefill requeue as the
    /// fallback.
    fn preempt(&mut self, slot: usize) {
        self.metrics.preemptions += 1;
        let rid = self.lanes[slot]
            .request()
            .expect("preempt of a live lane")
            .id;
        self.recorder.emit(
            self.tick_idx,
            rid,
            Some(slot),
            0,
            TraceEvent::Preempted,
        );
        if self.lanes[slot].is_prefilling() {
            let Lane::Prefilling(seq) = self.lanes[slot].take() else {
                unreachable!();
            };
            self.metrics.preempted_prefills += 1;
            crate::info!(
                "preempting request {} mid-prefill (slot {slot}, {} of \
                 {} rows): pool dry",
                seq.request.id,
                seq.next_row,
                seq.prompt.len()
            );
            self.release_slot(slot);
            self.recorder.emit(
                self.tick_idx,
                rid,
                Some(slot),
                0,
                TraceEvent::Evicted,
            );
            self.waiting.push_front(Waiting {
                request: seq.request,
                reply: seq.reply,
                submitted: seq.submitted,
                preempted: true,
            });
            return;
        }
        if self.try_swap_out(slot) {
            return;
        }
        let Lane::Decoding(seq) = self.lanes[slot].take() else {
            unreachable!("preempt of free lane");
        };
        crate::info!(
            "preempting request {} (slot {slot}, {} cache rows): pool dry",
            seq.request.id,
            self.slots.pos(slot)
        );
        self.release_slot(slot);
        self.recorder.emit(
            self.tick_idx,
            rid,
            Some(slot),
            0,
            TraceEvent::Evicted,
        );
        // Generated tokens are discarded; greedy and seeded top-k both
        // replay identically after re-prefill, and the original submit
        // time is kept so latency metrics stay honest.  `preempted`
        // exempts the entry from the admission deadline — it was
        // already admitted once.
        self.waiting.push_front(Waiting {
            request: seq.request,
            reply: seq.reply,
            submitted: seq.submitted,
            preempted: true,
        });
    }

    /// Copy a victim's blocks out to the bounded host swap pool and park
    /// the full decode state for later resumption.  Returns false (and
    /// counts a fallback) when swapping is off, the pool is full, or the
    /// backend cannot export — the caller then requeues for re-prefill.
    fn try_swap_out(&mut self, slot: usize) -> bool {
        let Some(p) = &self.paged else { return false };
        if p.swap.max_blocks() == 0 {
            return false;
        }
        let n = p.tables[slot].len();
        if !p.swap.fits(n) {
            self.metrics.swap_fallbacks += 1;
            return false;
        }
        // Shared blocks are copied out like private ones; their other
        // holders keep the originals.  The export loop is the swap
        // phase's device cost: it feeds `swap_ns` and the event span.
        let t0 = now_ns();
        let mut data = Vec::with_capacity(n);
        for &b in p.tables[slot].blocks() {
            match self.backend.export_block(b) {
                Ok(blk) => data.push(blk),
                Err(e) => {
                    crate::info!("swap-out export failed: {e:#}");
                    self.metrics.swap_fallbacks += 1;
                    return false;
                }
            }
        }
        let export_ns = now_ns().saturating_sub(t0);
        self.metrics.swap_ns += export_ns;
        let pos = self.slots.pos(slot);
        let Lane::Decoding(seq) = self.lanes[slot].take() else {
            unreachable!("swap of a non-decoding lane");
        };
        crate::info!(
            "swapping out request {} (slot {slot}, {n} blocks, {} rows)",
            seq.request.id,
            pos
        );
        self.release_slot(slot);
        self.paged.as_mut().unwrap().swap.reserve(n);
        self.metrics.swap_outs += 1;
        self.recorder.emit(
            self.tick_idx,
            seq.request.id,
            Some(slot),
            export_ns,
            TraceEvent::SwappedOut,
        );
        self.swapped.push_back(SwappedSeq {
            seq,
            pos,
            data,
            swapped_at: now_ns(),
        });
        true
    }

    /// Resume swapped-out sequences (oldest first) while a lane and
    /// enough blocks are free: fresh blocks are allocated, the swapped
    /// bytes imported verbatim, and decode continues exactly where it
    /// stopped — generated tokens, RNG state, and TTFT all survive; only
    /// total latency absorbs the time parked.
    fn swap_in_ready(&mut self) {
        loop {
            let Some(head) = self.swapped.front() else { return };
            let n = head.data.len();
            // Re-map still-indexed *full prompt* blocks (live or
            // revivable) instead of importing private copies: that
            // restores the sharing the eviction broke and shrinks the
            // free-list draw needed to resume.  Tail/growth blocks hold
            // generated rows and always come back from the swapped
            // bytes.
            let hits = {
                let Some(p) = &self.paged else { return };
                if p.sharing {
                    let prompt =
                        self.canonical_prompt(&head.seq.request.prompt);
                    let full = prompt.len() / p.alloc.block_size();
                    let mut hits = Self::match_prefix(p, &prompt);
                    hits.truncate(full.min(n));
                    hits
                } else {
                    Vec::new()
                }
            };
            let draw = n - hits.len()
                + hits.iter().filter(|&&(_, revive)| revive).count();
            if self.slots.free_count() == 0 {
                return;
            }
            if self.paged.as_ref().unwrap().alloc.free_count() < draw {
                // Parked sessions yield to resumption, like they yield
                // to admission and growth; the retry recomputes the
                // prefix hits against the changed refcounts.
                if self.reclaim_session_blocks() {
                    continue;
                }
                return;
            }
            let entry = self.swapped.pop_front().unwrap();
            let slot = self
                .slots
                .alloc(entry.seq.request.id)
                .expect("free lane was checked");
            if let Some(p) = &mut self.paged {
                for &(id, revive) in &hits {
                    if revive {
                        assert!(p.alloc.revive(id),
                                "planned revival raced");
                    } else {
                        p.alloc.retain(id);
                    }
                    p.tables[slot].push(id);
                }
            }
            let block_bytes = self.backend.block_bytes() as u64;
            self.metrics.prefix_hit_blocks += hits.len() as u64;
            self.metrics.prefix_bytes_saved +=
                hits.len() as u64 * block_bytes;
            let mut ok = true;
            let t0 = now_ns();
            for blk in entry.data.iter().skip(hits.len()) {
                let id = self
                    .paged
                    .as_mut()
                    .unwrap()
                    .alloc_fresh()
                    .expect("free blocks were checked");
                self.paged.as_mut().unwrap().tables[slot].push(id);
                if let Err(e) = self.backend.import_block(id, blk) {
                    crate::info!("swap-in import failed: {e:#}");
                    ok = false;
                    break;
                }
            }
            let import_ns = now_ns().saturating_sub(t0);
            self.metrics.swap_ns += import_ns;
            self.paged.as_mut().unwrap().swap.release(n);
            let mut seq = entry.seq;
            seq.swapped_ms +=
                ns_to_ms(now_ns().saturating_sub(entry.swapped_at));
            if !ok || self.slots.set_pos(slot, entry.pos).is_err() {
                // Broken backend path: fail the request cleanly instead
                // of resuming over a half-imported cache.
                self.release_slot(slot);
                self.metrics.rejected += 1;
                self.recorder.emit(
                    self.tick_idx,
                    seq.request.id,
                    Some(slot),
                    0,
                    TraceEvent::Finished {
                        reason: FinishReason::Rejected,
                    },
                );
                let total_ms =
                    ns_to_ms(now_ns().saturating_sub(seq.submitted));
                let ttft = seq.ttft_ms.unwrap_or(total_ms);
                self.metrics.ttft_ms.record(ttft);
                self.metrics.total_ms.record(total_ms);
                let _ = seq.reply.send(Response {
                    id: seq.request.id,
                    prompt_len: seq.request.prompt.len(),
                    tokens: Vec::new(),
                    finish: FinishReason::Rejected,
                    ttft_ms: ttft,
                    total_ms,
                    swapped_ms: seq.swapped_ms,
                    candidates: Vec::new(),
                });
                continue;
            }
            crate::info!(
                "swapped request {} back in (slot {slot}, {n} blocks)",
                seq.request.id
            );
            self.metrics.swap_ins += 1;
            self.recorder.emit(
                self.tick_idx,
                seq.request.id,
                Some(slot),
                import_ns,
                TraceEvent::SwappedIn,
            );
            self.lanes[slot] = Lane::Decoding(seq);
        }
    }

    fn decode_step(&mut self) -> Result<()> {
        let b = self.slots.batch();
        if self.paged.is_some() {
            self.ensure_paged_capacity()?;
        }
        // Serve the tick-start snapshot, minus lanes preemption just
        // evicted (ensure_paged_capacity may swap out or requeue a
        // snapshotted lane).  Lanes whose final chunk landed this tick
        // are *not* in the snapshot: they decode from the next tick, so
        // the budget the snapshot reserved stays exact.
        self.scratch_active.clear();
        for i in 0..self.tick_decode.len() {
            let s = self.tick_decode[i];
            if self.lanes[s].is_decoding() {
                self.scratch_active.push(s);
            }
        }
        if self.scratch_active.is_empty() {
            return Ok(());
        }
        self.scratch_tokens.clear();
        self.scratch_tokens.resize(b, 0);
        for i in 0..self.scratch_active.len() {
            let s = self.scratch_active[i];
            let Lane::Decoding(seq) = &self.lanes[s] else {
                unreachable!();
            };
            self.scratch_tokens[s] = seq.last_token as i32;
        }
        self.slots.pos_into(&mut self.scratch_pos);
        let (logits, step_ns) = {
            let span = trace::Span::new(&mut self.metrics.decode_ns);
            let logits = match &self.paged {
                Some(p) => self.backend.decode_paged(
                    &self.scratch_tokens,
                    &self.scratch_pos,
                    &self.scratch_active,
                    &p.tables,
                )?,
                None => self.backend.decode(
                    &self.scratch_tokens,
                    &self.scratch_pos,
                    &self.scratch_active,
                )?,
            };
            let ns = span.elapsed_ns();
            (logits, ns)
        };
        self.metrics.decode_steps += 1;
        self.metrics.backend_launches += 1;
        self.metrics
            .batch_occupancy
            .record(self.scratch_active.len() as f64);
        if let Some(p) = &self.paged {
            self.metrics.kv_util.record(p.alloc.utilization() * 100.0);
        }

        // The backend appended this step's K/V rows; account for them.
        self.slots.advance(&self.scratch_active)?;

        let vsize = self.backend.vocab();
        anyhow::ensure!(logits.len() >= b * vsize, "decode logits size");
        // Beam-search lanes are re-ranked per group after this loop
        // (from the same batched logits) instead of sampled
        // independently.  Collect every active lane's group id once,
        // sort + dedup, then drop the non-beam ids — the sample loop
        // below tests membership by binary search (O(lanes · log
        // groups) per tick, not O(lanes²)), and each group is fetched
        // from the map once here instead of once per lane.
        self.scratch_groups.clear();
        for &s in &self.scratch_active {
            if let Lane::Decoding(seq) = &self.lanes[s] {
                if let Some(gid) = seq.group {
                    self.scratch_groups.push(gid);
                }
            }
        }
        self.scratch_groups.sort_unstable();
        self.scratch_groups.dedup();
        let groups = &self.groups;
        self.scratch_groups
            .retain(|gid| groups.get(gid).map_or(false, |g| g.beams));
        for i in 0..self.scratch_active.len() {
            let s = self.scratch_active[i];
            let row = &logits[s * vsize..(s + 1) * vsize];
            let Lane::Decoding(seq) = &mut self.lanes[s] else {
                unreachable!();
            };
            if seq.group.map_or(false, |gid| {
                self.scratch_groups.binary_search(&gid).is_ok()
            }) {
                continue;
            }
            let tok = sample(row, seq.request.sampling, &mut seq.rng);
            if seq.group.is_some() {
                seq.score += token_logprob(row, tok);
            }
            seq.generated.push(tok);
            seq.last_token = tok;
            let now = now_ns();
            self.metrics.itl_ms.record(ns_to_ms(
                now.saturating_sub(seq.last_token_at),
            ));
            seq.last_token_at = now;
            self.metrics.tokens_generated += 1;
            self.recorder.emit(
                self.tick_idx,
                seq.request.id,
                Some(s),
                step_ns,
                TraceEvent::Decoded,
            );
            self.maybe_finish(s);
        }
        // Ascending-id group order (scratch_groups is sorted); groups
        // own disjoint lane sets, so expansion order cannot change any
        // stream.
        for i in 0..self.scratch_groups.len() {
            let gid = self.scratch_groups[i];
            self.beam_step(gid, &logits, step_ns);
        }
        Ok(())
    }

    /// One lockstep beam-search expansion for group `gid` (DESIGN.md
    /// §16).  All live beams sit at the same cache position (they
    /// forked at the same prefill completion and advance together), so
    /// their logits rows come from the same batched decode step that
    /// just ran.  Expand each live beam by its top-`width`
    /// continuations, keep the `width` globally best by cumulative
    /// log-probability, and re-point the lanes: a beam whose best
    /// continuation survives keeps its lane; a pruned beam's lane is
    /// re-forked from a surviving beam's block table (`beam_prunes`,
    /// with its freed divergent tail blocks going back to the free
    /// list, revivable).  An EOS continuation finishes its beam into
    /// the group, shrinking the width for later steps.
    fn beam_step(&mut self, gid: u64, logits: &[f32], step_ns: u64) {
        let vsize = self.backend.vocab();
        // Live lanes of this group, in lane order — deterministic.
        let members: Vec<usize> = self
            .scratch_active
            .iter()
            .copied()
            .filter(|&s| match &self.lanes[s] {
                Lane::Decoding(seq) => seq.group == Some(gid),
                _ => false,
            })
            .collect();
        let width = members.len();
        if width == 0 {
            return;
        }
        // Expansion set: per-beam top-`width` continuations, globally
        // re-ranked by cumulative score (ties: source lane, then token
        // id — fully deterministic).
        let mut cand: Vec<(f64, usize, u32)> = Vec::new();
        for &s in &members {
            let Lane::Decoding(seq) = &self.lanes[s] else {
                unreachable!();
            };
            let row = &logits[s * vsize..(s + 1) * vsize];
            for (tok, lp) in top_tokens(row, width) {
                cand.push((seq.score + lp, s, tok));
            }
        }
        cand.sort_by(|a, b| {
            b.0.partial_cmp(&a.0)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.1.cmp(&b.1))
                .then(a.2.cmp(&b.2))
        });
        cand.truncate(width);
        // Assignment: each source's first winner continues in its own
        // lane; extra winners take over lanes whose beam got pruned.
        let mut seen_src: Vec<usize> = Vec::new();
        let mut inplace: Vec<(usize, u32, f64)> = Vec::new();
        let mut refork: Vec<(usize, u32, f64)> = Vec::new();
        for &(score, src, tok) in &cand {
            if seen_src.contains(&src) {
                refork.push((src, tok, score));
            } else {
                seen_src.push(src);
                inplace.push((src, tok, score));
            }
        }
        let mut pruned: Vec<usize> = members
            .iter()
            .copied()
            .filter(|s| !seen_src.contains(s))
            .collect();
        // Snapshot re-fork sources *before* the in-place pushes mutate
        // them: a re-forked beam branches from its source's pre-step
        // history plus its own divergent token.
        let snaps: Vec<(Vec<u32>, Vec<u32>)> = refork
            .iter()
            .map(|&(src, _, _)| {
                let Lane::Decoding(seq) = &self.lanes[src] else {
                    unreachable!();
                };
                let blocks = self
                    .paged
                    .as_ref()
                    .map(|p| p.tables[src].blocks().to_vec())
                    .unwrap_or_default();
                (seq.generated.clone(), blocks)
            })
            .collect();
        let now = now_ns();
        let mut touched: Vec<usize> = Vec::new();
        for &(s, tok, score) in &inplace {
            let Lane::Decoding(seq) = &mut self.lanes[s] else {
                unreachable!();
            };
            seq.generated.push(tok);
            seq.last_token = tok;
            seq.score = score;
            self.metrics.itl_ms.record(ns_to_ms(
                now.saturating_sub(seq.last_token_at),
            ));
            seq.last_token_at = now;
            self.metrics.tokens_generated += 1;
            self.recorder.emit(
                self.tick_idx,
                gid,
                Some(s),
                step_ns,
                TraceEvent::Decoded,
            );
            touched.push(s);
        }
        for (i, &(_, tok, score)) in refork.iter().enumerate() {
            let Some(d) = pruned.pop() else {
                // |refork| == |pruned| by construction; defensive.
                break;
            };
            self.metrics.beam_prunes += 1;
            self.recorder.emit(
                self.tick_idx,
                gid,
                Some(d),
                0,
                TraceEvent::BeamPruned,
            );
            let (gen, blocks) = &snaps[i];
            if let Some(p) = &mut self.paged {
                // Drop the dead beam's references (its divergent tail
                // goes back to the free list, revivable) and retain the
                // survivor's table wholesale — positions are equal by
                // lockstep, so no set_pos is needed.
                for id in p.tables[d].take_blocks() {
                    p.alloc.free(id);
                }
                for &b in blocks {
                    p.alloc.retain(b);
                    p.tables[d].push(b);
                }
            }
            let Lane::Decoding(seq) = &mut self.lanes[d] else {
                unreachable!();
            };
            let mut g = gen.clone();
            g.push(tok);
            seq.generated = g;
            seq.last_token = tok;
            seq.score = score;
            self.metrics.itl_ms.record(ns_to_ms(
                now.saturating_sub(seq.last_token_at),
            ));
            seq.last_token_at = now;
            self.metrics.tokens_generated += 1;
            self.recorder.emit(
                self.tick_idx,
                gid,
                Some(d),
                step_ns,
                TraceEvent::Decoded,
            );
            touched.push(d);
        }
        // Leftover pruned lanes happen only when the expansion set was
        // smaller than the width (vocabulary < width): those beams die
        // without a candidate.
        for d in pruned {
            self.metrics.beam_prunes += 1;
            self.recorder.emit(
                self.tick_idx,
                gid,
                Some(d),
                0,
                TraceEvent::BeamPruned,
            );
            self.lanes[d] = Lane::Idle;
            self.release_slot(d);
            if let Some(g) = self.groups.get_mut(&gid) {
                g.live -= 1;
            }
        }
        for s in touched {
            if self.lanes[s].is_decoding() {
                self.maybe_finish(s);
            }
        }
        self.finish_group_if_done(gid);
    }

    /// Grow lane `s`'s block table to cover the speculative write range
    /// `[pos, pos + gamma]`.  Unlike the base capacity guarantee
    /// ([`Self::ensure_paged_capacity`], which already ran and COWed /
    /// grew row `pos`), this never preempts: a dry pool just shrinks
    /// the round's depth to the rows already covered — speculation
    /// degrades before it displaces anyone.  Rows past `pos` only ever
    /// live in the (now private) block holding row `pos` or in blocks
    /// pushed fresh here, so the write range is never shared and the
    /// rewind can free the tail without touching prefix/COW refcounts.
    fn grow_for_speculation(&mut self, s: usize, gamma: usize) -> usize {
        let pos = self.slots.pos(s);
        let Some(p) = &mut self.paged else {
            return gamma;
        };
        let bs = p.alloc.block_size();
        let mut gamma = gamma;
        while p.tables[s].capacity_rows(bs) < pos + gamma + 1 {
            if let Some(id) = p.alloc_fresh() {
                p.tables[s].push(id);
            } else {
                gamma = p.tables[s]
                    .capacity_rows(bs)
                    .saturating_sub(pos + 1);
                break;
            }
        }
        gamma
    }

    /// Speculative decode phase (DESIGN.md §13).  Dispatches to the
    /// batched round ([`Self::decode_step_spec_batched`], the default:
    /// at most `max_γ + 1` launches per tick across all lanes) or the
    /// per-lane PR 6 loop ([`Self::decode_step_spec_serial`],
    /// `B · (γ + 1)` launches, retained as the bit-exactness
    /// reference).  Both produce identical token streams: speculation
    /// batching changes launch shape, never sampling order.
    fn decode_step_spec(&mut self) -> Result<()> {
        if self.spec_serial {
            self.decode_step_spec_serial()
        } else {
            self.decode_step_spec_batched()
        }
    }

    /// Per-lane speculative round (the PR 6 path): one draft/verify
    /// loop per decoding lane instead of the single batched decode
    /// step.
    ///
    /// Per lane: draft `γ` tokens with the backbone-only pass (sampling
    /// from a *clone* of the lane RNG, so the real stream state only
    /// ever advances for emitted tokens), verify the `γ + 1` fed tokens
    /// in one corrected pass, emit the agreeing prefix by sampling each
    /// verify row with the real RNG, then rewind the rejected rows by
    /// truncating the lane's block table (flat lanes just keep `pos`
    /// short of the stale rows — nothing reads at or past `pos`).
    ///
    /// Bit-exactness with sequential decoding: verify row `j` is
    /// computed from exactly the cache rows and fed token sequential
    /// decode would have seen *provided* every earlier draft was
    /// accepted — and the accept loop stops at the first divergence, so
    /// every sample actually consumed matches its sequential
    /// counterpart, including the RNG draw order (one draw per emitted
    /// token, none for rejected drafts).
    fn decode_step_spec_serial(&mut self) -> Result<()> {
        if self.paged.is_some() {
            self.ensure_paged_capacity()?;
        }
        self.scratch_active.clear();
        for i in 0..self.tick_decode.len() {
            let s = self.tick_decode[i];
            if self.lanes[s].is_decoding() {
                self.scratch_active.push(s);
            }
        }
        if self.scratch_active.is_empty() {
            return Ok(());
        }
        let vsize = self.backend.vocab();
        for i in 0..self.scratch_active.len() {
            let s = self.scratch_active[i];
            if !self.lanes[s].is_decoding() {
                continue;
            }
            let gamma = self.grow_for_speculation(s, self.tick_gamma[s]);
            let pos = self.slots.pos(s);
            let (sampling, mut draft_rng, last_token, rid) = {
                let Lane::Decoding(seq) = &self.lanes[s] else {
                    unreachable!();
                };
                (
                    seq.request.sampling,
                    seq.rng.clone(),
                    seq.last_token,
                    seq.request.id,
                )
            };
            let round_t0 = now_ns();
            // Draft phase: the backbone proposes the next γ tokens.
            let mut fed: Vec<i32> = Vec::with_capacity(gamma + 1);
            fed.push(last_token as i32);
            for r in 0..gamma {
                let logits = match &self.paged {
                    Some(p) => self.backend.draft_step(
                        s, Some(&p.tables[s]), pos + r, fed[r],
                    )?,
                    None => self
                        .backend
                        .draft_step(s, None, pos + r, fed[r])?,
                };
                let d = sample(&logits, sampling, &mut draft_rng);
                fed.push(d as i32);
            }
            self.metrics.draft_tokens += gamma as u64;
            // Serial launch economics: one draft launch per token per
            // lane — what the batched round collapses.
            self.metrics.draft_launches += gamma as u64;
            self.metrics.backend_launches += gamma as u64;
            // Verify phase: one corrected pass over all fed tokens.
            // The verify span is the event's duration; the whole round
            // (draft + verify) still lands in `decode_ns` below.
            let (logits, verify_ns) = {
                let span =
                    trace::Span::new(&mut self.metrics.verify_ns);
                let logits = match &self.paged {
                    Some(p) => self.backend.verify_tokens(
                        s, Some(&p.tables[s]), pos, &fed,
                    )?,
                    None => {
                        self.backend.verify_tokens(s, None, pos, &fed)?
                    }
                };
                let ns = span.elapsed_ns();
                (logits, ns)
            };
            self.metrics.decode_steps += 1;
            self.metrics.verify_launches += 1;
            self.metrics.backend_launches += 1;
            self.metrics.decode_ns +=
                now_ns().saturating_sub(round_t0);
            anyhow::ensure!(
                logits.len() >= fed.len() * vsize,
                "verify logits size"
            );
            // Accept phase: emit until the first divergence (whose
            // corrected sample is itself emitted — the "free" token),
            // EOS, or the length limit.
            let mut emitted = 0usize;
            let mut accepted = 0usize;
            {
                let Lane::Decoding(seq) = &mut self.lanes[s] else {
                    unreachable!();
                };
                for j in 0..fed.len() {
                    let row = &logits[j * vsize..(j + 1) * vsize];
                    let tok = sample(row, sampling, &mut seq.rng);
                    seq.generated.push(tok);
                    seq.last_token = tok;
                    emitted += 1;
                    let now = now_ns();
                    self.metrics.itl_ms.record(ns_to_ms(
                        now.saturating_sub(seq.last_token_at),
                    ));
                    seq.last_token_at = now;
                    self.metrics.tokens_generated += 1;
                    if tok == self.eos
                        || seq.generated.len()
                            >= seq.request.max_new_tokens
                    {
                        break;
                    }
                    if j + 1 < fed.len() {
                        if tok as i32 != fed[j + 1] {
                            break;
                        }
                        accepted += 1;
                    }
                }
                self.metrics.accepted_tokens += accepted as u64;
                // γ adaptation: lean into lanes whose drafts stick,
                // back off where the backbone keeps being corrected.
                if gamma > 0 {
                    let rate = accepted as f64 / gamma as f64;
                    seq.accept_ewma =
                        0.7 * seq.accept_ewma + 0.3 * rate;
                    let max_gamma =
                        self.cfg.spec.as_ref().unwrap().gamma;
                    if seq.accept_ewma > 0.8 {
                        seq.gamma = (seq.gamma + 1).min(max_gamma);
                    } else if seq.accept_ewma < 0.5 {
                        seq.gamma = seq.gamma.saturating_sub(1).max(1);
                    }
                }
            }
            // Commit: keep exactly the rows feeding the emitted stream
            // (`fed[..emitted]` at rows `pos..pos+emitted`), rewind the
            // rejected tail.  Freed tail blocks were allocated fresh
            // for this round or a previous one — never prefix-shared —
            // so a plain `free` is refcount-correct.
            let new_pos = pos + emitted;
            self.slots.set_pos(s, new_pos)?;
            let mut rewound = 0usize;
            if let Some(p) = &mut self.paged {
                let bs = p.alloc.block_size();
                let freed = p.tables[s].truncate_rows(new_pos, bs);
                self.metrics.rewind_blocks += freed.len() as u64;
                rewound = freed.len();
                for id in freed {
                    p.alloc.free(id);
                }
            }
            self.recorder.emit(
                self.tick_idx,
                rid,
                Some(s),
                verify_ns,
                TraceEvent::SpecRound { gamma, accepted, rewound },
            );
            self.maybe_finish(s);
        }
        self.metrics
            .batch_occupancy
            .record(self.scratch_active.len() as f64);
        if let Some(p) = &self.paged {
            self.metrics.kv_util.record(p.alloc.utilization() * 100.0);
        }
        Ok(())
    }

    /// Batched speculative round: the whole batch advances through one
    /// phase-structured launch sequence per tick instead of a
    /// draft/verify loop per lane.
    ///
    /// 1. **Plan** — grow every decoding lane's block table up front
    ///    ([`Self::grow_for_speculation`]), snapshot per-lane depth
    ///    `γ_s`, base position, sampling mode, and a *clone* of the
    ///    lane RNG for drafting.
    /// 2. **Draft** — `max_γ` rounds of one batched
    ///    [`DecodeBackend::draft_step_batch`] launch each; a lane
    ///    whose `γ_s` is exhausted drops out of later rounds and its
    ///    lattice row lands dead (sentinel block / DUS-clamp row),
    ///    exactly like idle lanes under plain batched decode.
    /// 3. **Verify** — one [`DecodeBackend::verify_tokens_batch`]
    ///    launch over every lane's fed window (`γ_s + 1` live rows,
    ///    padded to `max_γ + 1`).
    /// 4. **Accept** — the per-lane accept/EWMA/rewind walk of the
    ///    serial path, unchanged, over the batched logits.
    ///
    /// Launch count per tick: at most `max_γ + 1`, down from
    /// `B · (γ + 1)`.  Bit-exactness with the serial path is by
    /// construction — each lane's draft RNG clone and accept-walk RNG
    /// are independent of every other lane's, the model is
    /// lane-independent, and the accept walk runs in lane order — so
    /// batching changes launch shape, not sampling order.  One
    /// observable difference under a *starved* pool: growing all
    /// tables before any lane rewinds can shrink a later lane's γ
    /// where the serial path's interleaved rewinds would have freed
    /// blocks first.  Depth only bounds how far a round speculates —
    /// the emitted stream is identical, only draft-volume metrics can
    /// differ.
    fn decode_step_spec_batched(&mut self) -> Result<()> {
        if self.paged.is_some() {
            self.ensure_paged_capacity()?;
        }
        self.scratch_active.clear();
        for i in 0..self.tick_decode.len() {
            let s = self.tick_decode[i];
            if self.lanes[s].is_decoding() {
                self.scratch_active.push(s);
            }
        }
        if self.scratch_active.is_empty() {
            return Ok(());
        }
        let b = self.slots.batch();
        let vsize = self.backend.vocab();
        let round_t0 = now_ns();

        // Phase 1 — plan.  Grow every lane's table first (the serial
        // path interleaved growth with rewinds; see the doc comment),
        // then snapshot the per-lane round state into the slot-indexed
        // scratch.
        self.scratch_gamma.clear();
        self.scratch_gamma.resize(b, 0);
        self.scratch_base.clear();
        self.scratch_base.resize(b, 0);
        self.scratch_lens.clear();
        self.scratch_lens.resize(b, 0);
        self.scratch_sampling.clear();
        self.scratch_sampling.resize(b, Sampling::Greedy);
        self.scratch_rng.resize_with(b, || Rng::new(0));
        let mut max_gamma = 0usize;
        for i in 0..self.scratch_active.len() {
            let s = self.scratch_active[i];
            let gamma = self.grow_for_speculation(s, self.tick_gamma[s]);
            let pos = self.slots.pos(s);
            let Lane::Decoding(seq) = &self.lanes[s] else {
                unreachable!();
            };
            self.scratch_gamma[s] = gamma;
            self.scratch_base[s] = pos;
            self.scratch_lens[s] = gamma + 1;
            self.scratch_sampling[s] = seq.request.sampling;
            self.scratch_rng[s] = seq.rng.clone();
            max_gamma = max_gamma.max(gamma);
        }
        let width = max_gamma + 1;
        self.scratch_fed.clear();
        self.scratch_fed.resize(b * width, 0);
        for i in 0..self.scratch_active.len() {
            let s = self.scratch_active[i];
            let Lane::Decoding(seq) = &self.lanes[s] else {
                unreachable!();
            };
            self.scratch_fed[s * width] = seq.last_token as i32;
        }

        // Phase 2 — batched draft rounds: one launch per round, each
        // lane sampling its proposal from its own RNG clone.
        // `scratch_pos` starts from the true per-slot positions so
        // lanes outside the round keep the same dead-write row plain
        // batched decode gives them.
        for r in 0..max_gamma {
            self.scratch_round.clear();
            self.scratch_tokens.clear();
            self.scratch_tokens.resize(b, 0);
            self.slots.pos_into(&mut self.scratch_pos);
            for i in 0..self.scratch_active.len() {
                let s = self.scratch_active[i];
                if self.scratch_gamma[s] > r {
                    self.scratch_round.push(s);
                    self.scratch_tokens[s] =
                        self.scratch_fed[s * width + r];
                    self.scratch_pos[s] =
                        (self.scratch_base[s] + r) as i32;
                }
            }
            if self.scratch_round.is_empty() {
                break; // starved pool planned γ = 0 everywhere
            }
            let logits = match &self.paged {
                Some(p) => self.backend.draft_step_batch(
                    &self.scratch_tokens,
                    &self.scratch_pos,
                    &self.scratch_round,
                    Some(&p.tables),
                )?,
                None => self.backend.draft_step_batch(
                    &self.scratch_tokens,
                    &self.scratch_pos,
                    &self.scratch_round,
                    None,
                )?,
            };
            self.metrics.draft_launches += 1;
            self.metrics.backend_launches += 1;
            anyhow::ensure!(
                logits.len() >= b * vsize,
                "draft logits size"
            );
            for i in 0..self.scratch_round.len() {
                let s = self.scratch_round[i];
                let row = &logits[s * vsize..(s + 1) * vsize];
                let d = sample(
                    row,
                    self.scratch_sampling[s],
                    &mut self.scratch_rng[s],
                );
                self.scratch_fed[s * width + r + 1] = d as i32;
                self.metrics.draft_tokens += 1;
            }
        }

        // Phase 3 — one batched verify over every lane's fed window.
        self.slots.pos_into(&mut self.scratch_pos);
        for i in 0..self.scratch_active.len() {
            let s = self.scratch_active[i];
            self.scratch_pos[s] = self.scratch_base[s] as i32;
        }
        let (logits, verify_ns) = {
            let span = trace::Span::new(&mut self.metrics.verify_ns);
            let logits = match &self.paged {
                Some(p) => self.backend.verify_tokens_batch(
                    &self.scratch_fed,
                    &self.scratch_lens,
                    &self.scratch_pos,
                    &self.scratch_active,
                    Some(&p.tables),
                )?,
                None => self.backend.verify_tokens_batch(
                    &self.scratch_fed,
                    &self.scratch_lens,
                    &self.scratch_pos,
                    &self.scratch_active,
                    None,
                )?,
            };
            let ns = span.elapsed_ns();
            (logits, ns)
        };
        self.metrics.verify_launches += 1;
        self.metrics.backend_launches += 1;
        self.metrics.decode_ns += now_ns().saturating_sub(round_t0);
        anyhow::ensure!(
            logits.len() >= b * width * vsize,
            "verify logits size"
        );

        // Phase 4 — per-lane accept/EWMA/rewind walk over the batched
        // logits, in lane order: identical to the serial path row for
        // row, draw for draw.
        for i in 0..self.scratch_active.len() {
            let s = self.scratch_active[i];
            let gamma = self.scratch_gamma[s];
            let pos = self.scratch_base[s];
            let fed_len = self.scratch_lens[s];
            let sampling = self.scratch_sampling[s];
            // This lane's verify window still cost a full corrected
            // pass; `decode_steps` stays per-lane so modeled cost
            // units and the `spec_rounds == decode_steps` bench
            // invariant carry over from the serial path.
            self.metrics.decode_steps += 1;
            let mut emitted = 0usize;
            let mut accepted = 0usize;
            let rid;
            {
                let Lane::Decoding(seq) = &mut self.lanes[s] else {
                    unreachable!();
                };
                rid = seq.request.id;
                for j in 0..fed_len {
                    let row =
                        &logits[(s * width + j) * vsize..][..vsize];
                    let tok = sample(row, sampling, &mut seq.rng);
                    seq.generated.push(tok);
                    seq.last_token = tok;
                    emitted += 1;
                    let now = now_ns();
                    self.metrics.itl_ms.record(ns_to_ms(
                        now.saturating_sub(seq.last_token_at),
                    ));
                    seq.last_token_at = now;
                    self.metrics.tokens_generated += 1;
                    if tok == self.eos
                        || seq.generated.len()
                            >= seq.request.max_new_tokens
                    {
                        break;
                    }
                    if j + 1 < fed_len {
                        if tok as i32
                            != self.scratch_fed[s * width + j + 1]
                        {
                            break;
                        }
                        accepted += 1;
                    }
                }
                self.metrics.accepted_tokens += accepted as u64;
                // γ adaptation: identical EWMA walk to the serial path.
                if gamma > 0 {
                    let rate = accepted as f64 / gamma as f64;
                    seq.accept_ewma =
                        0.7 * seq.accept_ewma + 0.3 * rate;
                    let max_g =
                        self.cfg.spec.as_ref().unwrap().gamma;
                    if seq.accept_ewma > 0.8 {
                        seq.gamma = (seq.gamma + 1).min(max_g);
                    } else if seq.accept_ewma < 0.5 {
                        seq.gamma = seq.gamma.saturating_sub(1).max(1);
                    }
                }
            }
            // Commit the emitted prefix, rewind the rejected tail —
            // freed tail blocks were pushed fresh by this or an
            // earlier round, never prefix-shared, so a plain `free`
            // is refcount-correct.
            let new_pos = pos + emitted;
            self.slots.set_pos(s, new_pos)?;
            let mut rewound = 0usize;
            if let Some(p) = &mut self.paged {
                let bs = p.alloc.block_size();
                let freed = p.tables[s].truncate_rows(new_pos, bs);
                self.metrics.rewind_blocks += freed.len() as u64;
                rewound = freed.len();
                for id in freed {
                    p.alloc.free(id);
                }
            }
            self.recorder.emit(
                self.tick_idx,
                rid,
                Some(s),
                verify_ns,
                TraceEvent::SpecRound { gamma, accepted, rewound },
            );
            self.maybe_finish(s);
        }
        self.metrics
            .batch_occupancy
            .record(self.scratch_active.len() as f64);
        if let Some(p) = &self.paged {
            self.metrics.kv_util.record(p.alloc.utilization() * 100.0);
        }
        Ok(())
    }

    fn maybe_finish(&mut self, slot: usize) {
        let t_max = self.backend.t_max();
        let pos = self.slots.pos(slot);
        let finish = {
            let Lane::Decoding(seq) = &self.lanes[slot] else {
                unreachable!("finish check on a non-decoding lane");
            };
            if seq.generated.last() == Some(&self.eos) {
                Some(FinishReason::Eos)
            } else if seq.generated.len() >= seq.request.max_new_tokens {
                Some(FinishReason::Length)
            } else if pos + 1 >= t_max {
                Some(FinishReason::CacheFull)
            } else {
                None
            }
        };
        if let Some(reason) = finish {
            self.finish(slot, reason);
        }
    }

    /// Complete a running sequence: persist its KV tail when it closes
    /// a session turn (otherwise release lane + blocks), then either
    /// send the response (plain path) or bank the candidate into its
    /// fork group, answering once the last candidate lands.
    fn finish(&mut self, slot: usize, reason: FinishReason) {
        let Lane::Decoding(seq) = self.lanes[slot].take() else {
            unreachable!("finish of a non-decoding lane");
        };
        if self.persist_session(slot, &seq, reason) {
            // Block references moved into the session store; only the
            // lane itself is returned.
            self.slots.free(slot);
        } else {
            self.release_slot(slot);
        }
        self.recorder.emit(
            self.tick_idx,
            seq.request.id,
            Some(slot),
            0,
            TraceEvent::Finished { reason },
        );
        // Each candidate of a forked request counts as a completion
        // (it occupied a lane like any sequence); latency histograms
        // record once per *request*, at group completion.
        self.metrics.completed += 1;
        let total_ms =
            ns_to_ms(now_ns().saturating_sub(seq.submitted));
        if let Some(gid) = seq.group {
            let ttft = seq.ttft_ms;
            let swapped = seq.swapped_ms;
            if let Some(g) = self.groups.get_mut(&gid) {
                g.done.push((
                    seq.cand,
                    Candidate {
                        tokens: seq.generated,
                        finish: reason,
                        score: seq.score,
                    },
                ));
                g.ttft_ms = match (g.ttft_ms, ttft) {
                    (Some(a), Some(b)) => Some(a.min(b)),
                    (a, b) => a.or(b),
                };
                if swapped > g.swapped_ms {
                    g.swapped_ms = swapped;
                }
                g.live -= 1;
            }
            self.finish_group_if_done(gid);
            return;
        }
        self.metrics.ttft_ms.record(seq.ttft_ms.unwrap_or(total_ms));
        self.metrics.total_ms.record(total_ms);
        let _ = seq.reply.send(Response {
            id: seq.request.id,
            prompt_len: seq.request.prompt.len(),
            tokens: seq.generated,
            finish: reason,
            ttft_ms: seq.ttft_ms.unwrap_or(total_ms),
            total_ms,
            swapped_ms: seq.swapped_ms,
            candidates: Vec::new(),
        });
    }

    /// Send the assembled response once every candidate of a fork group
    /// has finished: candidates rank by cumulative log-probability
    /// (ties toward the lower candidate index, keeping greedy fanouts
    /// deterministic), and the best one doubles as the response's
    /// primary `tokens` / `finish`.
    fn finish_group_if_done(&mut self, gid: u64) {
        let done = self
            .groups
            .get(&gid)
            .map(|g| g.live == 0)
            .unwrap_or(false);
        if !done {
            return;
        }
        let mut g = self.groups.remove(&gid).unwrap();
        g.done.sort_by(|a, b| {
            b.1.score
                .partial_cmp(&a.1.score)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.0.cmp(&b.0))
        });
        let candidates: Vec<Candidate> =
            g.done.into_iter().map(|(_, c)| c).collect();
        let Some(best) = candidates.first().cloned() else {
            // Every candidate died without output (vocabulary narrower
            // than the beam width on a one-token run); defensive.
            return;
        };
        let total_ms =
            ns_to_ms(now_ns().saturating_sub(g.submitted));
        let ttft = g.ttft_ms.unwrap_or(total_ms);
        self.metrics.ttft_ms.record(ttft);
        self.metrics.total_ms.record(total_ms);
        let _ = g.reply.send(Response {
            id: gid,
            prompt_len: g.prompt_len,
            tokens: best.tokens,
            finish: best.finish,
            ttft_ms: ttft,
            total_ms,
            swapped_ms: g.swapped_ms,
            candidates,
        });
    }

    /// Park a finished conversation turn's KV tail (DESIGN.md §16):
    /// register the full token chain (prompt + generated, minus the
    /// never-written last token) in the prefix index and move the
    /// lane's block references into the session store, so the next
    /// turn's prompt — this conversation plus a suffix — re-admits
    /// with only the suffix to prefill.  Returns true when the blocks
    /// were moved (the caller must then skip freeing them).  Grouped
    /// candidates, speculative lanes, and pressure finishes
    /// (`CacheFull`) never persist.
    fn persist_session(
        &mut self,
        slot: usize,
        seq: &ActiveSeq,
        reason: FinishReason,
    ) -> bool {
        let Some(sid) = seq.request.session else { return false };
        if seq.group.is_some()
            || self.cfg.spec.is_some()
            || !matches!(
                reason,
                FinishReason::Eos | FinishReason::Length
            )
        {
            return false;
        }
        let prompt = self.canonical_prompt(&seq.request.prompt);
        let Some(p) = &mut self.paged else { return false };
        if p.session_budget == 0 || !p.sharing {
            return false;
        }
        let m = seq.generated.len();
        // Valid resident rows: the prompt plus every generated token
        // except the last — sampled, but never fed back and written.
        let chain: Vec<u32> = prompt
            .iter()
            .copied()
            .chain(
                seq.generated[..m.saturating_sub(1)].iter().copied(),
            )
            .collect();
        let rows = chain.len();
        let bs = p.alloc.block_size();
        let full = rows / bs;
        let blocks = p.tables[slot].blocks();
        if blocks.len() < full + usize::from(rows % bs != 0) {
            return false; // defensive: table shorter than the chain
        }
        // Index the chain's full blocks.  Prompt blocks are already
        // registered (complete_prefill); entries are content-addressed
        // and first-writer-wins, so re-insertion is a no-op and the
        // new entries cover the generated tail.
        let mut parent = PREFIX_SEED;
        for i in 0..full {
            let span = &chain[i * bs..(i + 1) * bs];
            p.index.insert(parent, span, blocks[i]);
            parent = chain_hash(parent, span);
        }
        if rows % bs != 0 {
            p.index.insert(parent, &chain[full * bs..rows],
                           blocks[full]);
        }
        let count = p.tables[slot].len();
        let taken = p.tables[slot].take_blocks();
        // One parked turn per conversation: a newer turn supersedes
        // the older entry (whose blocks mostly overlap — the retains
        // differ only in the new tail).
        if let Some(i) = p.sessions.iter().position(|e| e.id == sid) {
            let old = p.sessions.remove(i);
            for b in old.blocks {
                p.alloc.free(b);
            }
        }
        p.sessions.push(SessionEntry { id: sid, blocks: taken, rows });
        let mut evictions = 0u64;
        while p.session_blocks_held() > p.session_budget {
            if !p.evict_oldest_session() {
                break;
            }
            evictions += 1;
        }
        self.metrics.session_evictions += evictions;
        self.recorder.emit(
            self.tick_idx,
            seq.request.id,
            Some(slot),
            0,
            TraceEvent::SessionPersisted { blocks: count },
        );
        true
    }
}

/// Sample a token id from a logits row.
pub fn sample(logits: &[f32], strategy: Sampling, rng: &mut Rng) -> u32 {
    match strategy {
        Sampling::Greedy => argmax(logits) as u32,
        Sampling::TopK { k, temperature, .. } => {
            let k = k.max(1).min(logits.len());
            let mut idx: Vec<usize> = (0..logits.len()).collect();
            if k < idx.len() {
                // Partial selection: O(V) per token instead of the former
                // full-vocab O(V log V) sort.  idx[..k] holds the k
                // largest logits (unordered — softmax weights don't care).
                idx.select_nth_unstable_by(k - 1, |&a, &b| {
                    logits[b].partial_cmp(&logits[a]).unwrap()
                });
                idx.truncate(k);
            }
            let t = temperature.max(1e-3);
            let mx = idx
                .iter()
                .map(|&i| logits[i])
                .fold(f32::NEG_INFINITY, f32::max);
            let weights: Vec<f64> = idx
                .iter()
                .map(|&i| (((logits[i] - mx) / t) as f64).exp())
                .collect();
            idx[rng.weighted(&weights)] as u32
        }
    }
}

/// Natural-log probability of `tok` under the row's softmax — the
/// candidate-ranking currency of fanout and beam search (DESIGN.md
/// §16).
fn token_logprob(row: &[f32], tok: u32) -> f64 {
    let mx = row.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
    let lse: f64 =
        row.iter().map(|&x| f64::from(x - mx).exp()).sum();
    f64::from(row[tok as usize] - mx) - lse.ln()
}

/// The `k` highest-logit tokens of a row with their log-probabilities,
/// best first; ties break toward the lower token id, so beam expansion
/// is fully deterministic.
fn top_tokens(row: &[f32], k: usize) -> Vec<(u32, f64)> {
    let k = k.max(1).min(row.len());
    let mut idx: Vec<usize> = (0..row.len()).collect();
    idx.sort_by(|&a, &b| {
        row[b]
            .partial_cmp(&row[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    idx.truncate(k);
    let mx = row.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
    let lse: f64 =
        row.iter().map(|&x| f64::from(x - mx).exp()).sum();
    idx.into_iter()
        .map(|i| (i as u32, f64::from(row[i] - mx) - lse.ln()))
        .collect()
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, x) in xs.iter().enumerate() {
        if *x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top_tokens_orders_and_scores() {
        let row = vec![0.1, 2.0, -1.0, 1.9];
        let top = top_tokens(&row, 3);
        assert_eq!(
            top.iter().map(|t| t.0).collect::<Vec<_>>(),
            vec![1, 3, 0]
        );
        // Scores are genuine log-probabilities: descending, and the
        // full distribution sums to 1.
        assert!(top[0].1 > top[1].1 && top[1].1 > top[2].1);
        let total: f64 = top_tokens(&row, row.len())
            .iter()
            .map(|t| t.1.exp())
            .sum();
        assert!((total - 1.0).abs() < 1e-9, "{total}");
    }

    #[test]
    fn token_logprob_matches_top_tokens() {
        let row = vec![-0.5, 3.0, 0.25];
        for (tok, lp) in top_tokens(&row, row.len()) {
            assert!((token_logprob(&row, tok) - lp).abs() < 1e-12);
        }
    }

    #[test]
    fn top_tokens_breaks_ties_by_token_id() {
        let row = vec![1.0, 2.0, 2.0, 1.0];
        let top = top_tokens(&row, 4);
        assert_eq!(
            top.iter().map(|t| t.0).collect::<Vec<_>>(),
            vec![1, 2, 0, 3]
        );
    }

    #[test]
    fn greedy_sampling_is_argmax() {
        let mut rng = Rng::new(0);
        let logits = vec![0.1, 2.0, -1.0, 1.9];
        assert_eq!(sample(&logits, Sampling::Greedy, &mut rng), 1);
    }

    #[test]
    fn topk_stays_in_top_k() {
        let mut rng = Rng::new(0);
        let logits = vec![-5.0, 10.0, 9.5, -7.0, 9.9];
        for _ in 0..200 {
            let t = sample(
                &logits,
                Sampling::TopK { k: 3, temperature: 1.0, seed: 1 },
                &mut rng,
            );
            assert!([1u32, 2, 4].contains(&t), "sampled {t}");
        }
    }

    #[test]
    fn topk_low_temperature_nearly_greedy() {
        let mut rng = Rng::new(0);
        let logits = vec![0.0, 5.0, 4.0];
        let mut ones = 0;
        for _ in 0..100 {
            if sample(
                &logits,
                Sampling::TopK { k: 2, temperature: 0.05, seed: 2 },
                &mut rng,
            ) == 1
            {
                ones += 1;
            }
        }
        assert!(ones >= 99, "{ones}");
    }

    #[test]
    fn topk_equals_full_vocab_is_safe() {
        let mut rng = Rng::new(3);
        let logits = vec![1.0, 2.0, 3.0];
        for _ in 0..50 {
            let t = sample(
                &logits,
                Sampling::TopK { k: 10, temperature: 0.5, seed: 4 },
                &mut rng,
            );
            assert!(t < 3);
        }
    }
}
