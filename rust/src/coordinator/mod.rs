//! L3 coordinator: request types, admission queue, continuous batcher, and
//! the serving engine loop.
//!
//! Architecture (vLLM-style, scaled to this testbed):
//!
//! ```text
//!  clients ── submit(Request + reply Sender) ──► admission queue (FIFO)
//!                                                     │
//!                                  engine thread (owns PJRT runtime)
//!                                                     │
//!        ┌─────────── scheduler iteration ────────────┤
//!        │ 1. admit waiting requests into free KV slots (prefill, b=1,
//!        │    bucketed sequence lengths, right-padded)
//!        │ 2. one batched decode step over all active slots
//!        │ 3. sample, detect EOS/limits, free slots, send responses
//!        └────────────────────────────────────────────┘
//! ```
//!
//! The PJRT client is not `Send`, so the engine thread constructs and owns
//! the entire runtime; callers talk to it exclusively through channels
//! ([`EngineHandle`]).  Continuous batching falls out of the slot design:
//! new sequences join the decode batch as soon as a slot frees up, without
//! draining the batch.

pub mod batching;
pub mod loadtest;
pub mod metrics;
pub mod server;

use std::sync::mpsc;
use std::time::Instant;

use anyhow::Result;

use crate::config::Manifest;
use crate::kvcache::KvCache;
use crate::runtime::{ModelRunner, Runtime};
use crate::util::rng::Rng;

pub use metrics::{EngineMetrics, LatencyHistogram};

/// Decoding strategy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Sampling {
    Greedy,
    /// top-k sampling with temperature.
    TopK { k: usize, temperature: f32, seed: u64 },
}

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    pub sampling: Sampling,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    Eos,
    Length,
    CacheFull,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub prompt_len: usize,
    pub tokens: Vec<u32>,
    pub finish: FinishReason,
    /// Wall-clock from submit to first generated token (ms).
    pub ttft_ms: f64,
    /// Wall-clock from submit to completion (ms).
    pub total_ms: f64,
}

enum Msg {
    Submit(Request, mpsc::Sender<Response>),
    Metrics(mpsc::Sender<EngineMetrics>),
    Shutdown,
}

/// Client-side handle to a running engine.
pub struct EngineHandle {
    tx: mpsc::Sender<Msg>,
    join: Option<std::thread::JoinHandle<()>>,
}

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub model: String,
    pub method: String,
    /// Decode batch bucket (must have a lowered decode graph).
    pub decode_batch: usize,
    /// Prefill length buckets (must have lowered prefill graphs, b=1).
    pub prefill_buckets: Vec<usize>,
    /// Max prefills admitted per scheduler iteration (batching policy).
    pub max_prefill_per_step: usize,
}

impl EngineHandle {
    /// Start an engine thread for one (model, method) run.
    pub fn spawn(
        artifacts: std::path::PathBuf,
        cfg: EngineConfig,
    ) -> Result<EngineHandle> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("lqer-engine".into())
            .spawn(move || {
                match Engine::new(&artifacts, &cfg) {
                    Ok(mut engine) => {
                        let _ = ready_tx.send(Ok(()));
                        engine.run(rx);
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                    }
                }
            })?;
        ready_rx.recv()??;
        Ok(EngineHandle { tx, join: Some(join) })
    }

    /// Submit a request; returns the channel the response arrives on.
    pub fn submit(&self, req: Request) -> mpsc::Receiver<Response> {
        let (tx, rx) = mpsc::channel();
        let _ = self.tx.send(Msg::Submit(req, tx));
        rx
    }

    /// Convenience: submit and wait.
    pub fn generate(&self, req: Request) -> Result<Response> {
        let rx = self.submit(req);
        rx.recv().map_err(|_| anyhow::anyhow!("engine dropped request"))
    }

    pub fn metrics(&self) -> Result<EngineMetrics> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Msg::Metrics(tx))?;
        rx.recv().map_err(|_| anyhow::anyhow!("engine gone"))
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for EngineHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Engine internals (runs on the engine thread)
// ---------------------------------------------------------------------------

struct ActiveSeq {
    request: Request,
    reply: mpsc::Sender<Response>,
    submitted: Instant,
    ttft_ms: Option<f64>,
    generated: Vec<u32>,
    last_token: u32,
    rng: Rng,
}

struct Waiting {
    request: Request,
    reply: mpsc::Sender<Response>,
    submitted: Instant,
}

struct Engine {
    manifest: Manifest,
    rt: Runtime,
    runner: ModelRunner,
    cache: KvCache,
    cfg: EngineConfig,
    eos: u32,
    waiting: std::collections::VecDeque<Waiting>,
    active: Vec<Option<ActiveSeq>>, // indexed by KV slot
    metrics: EngineMetrics,
}

impl Engine {
    fn new(artifacts: &std::path::Path, cfg: &EngineConfig) -> Result<Engine> {
        let manifest = Manifest::load(artifacts)?;
        let rt = Runtime::cpu()?;
        let runner = ModelRunner::new(&manifest, &cfg.model, &cfg.method)?;
        let info = runner.model.clone();
        let tok = crate::tokenizer::Tokenizer::from_file(
            &manifest.data_dir().join("vocab.json"),
        )?;
        let cache = KvCache::new(info.layers, cfg.decode_batch, info.t_max,
                                 info.d);
        // Pre-compile the decode + prefill graphs so first-request latency
        // is honest (XLA CPU compilation takes seconds per graph).
        runner.executable(&rt, &manifest, "decode", cfg.decode_batch, 0)?;
        for &t in &cfg.prefill_buckets {
            runner.executable(&rt, &manifest, "prefill", 1, t)?;
        }
        Ok(Engine {
            manifest,
            rt,
            runner,
            cache,
            cfg: cfg.clone(),
            eos: tok.specials.eos,
            waiting: Default::default(),
            active: (0..cfg.decode_batch).map(|_| None).collect(),
            metrics: EngineMetrics::default(),
        })
    }

    fn run(&mut self, rx: mpsc::Receiver<Msg>) {
        loop {
            // 1. Drain control/submission messages (block only when idle).
            let idle = self.waiting.is_empty() && self.cache.free_count()
                == self.cache.batch;
            loop {
                let msg = if idle && self.waiting.is_empty() {
                    match rx.recv() {
                        Ok(m) => m,
                        Err(_) => return,
                    }
                } else {
                    match rx.try_recv() {
                        Ok(m) => m,
                        Err(mpsc::TryRecvError::Empty) => break,
                        Err(mpsc::TryRecvError::Disconnected) => return,
                    }
                };
                match msg {
                    Msg::Submit(request, reply) => {
                        self.metrics.submitted += 1;
                        self.waiting.push_back(Waiting {
                            request,
                            reply,
                            submitted: Instant::now(),
                        });
                    }
                    Msg::Metrics(tx) => {
                        let mut m = self.metrics.clone();
                        m.exec = self.runner.stats();
                        let _ = tx.send(m);
                    }
                    Msg::Shutdown => return,
                }
                if !idle {
                    // Drain whatever is queued without blocking, then serve.
                    continue;
                }
            }

            // 2. Admit waiting requests into free slots (prefill).
            let mut admitted = 0;
            while admitted < self.cfg.max_prefill_per_step
                && self.cache.free_count() > 0
                && !self.waiting.is_empty()
            {
                let w = self.waiting.pop_front().unwrap();
                if let Err(e) = self.admit(w) {
                    crate::info!("admit failed: {e:#}");
                }
                admitted += 1;
            }

            // 3. One batched decode step over all active slots.
            if !self.cache.active_slots().is_empty() {
                if let Err(e) = self.decode_step() {
                    crate::info!("decode step failed: {e:#}");
                }
            }
        }
    }

    fn admit(&mut self, w: Waiting) -> Result<()> {
        let info = &self.runner.model;
        let prompt: Vec<u32> = w
            .request
            .prompt
            .iter()
            .copied()
            .filter(|&t| (t as usize) < info.vocab)
            .collect();
        let len = prompt.len().min(info.t_max - 1);
        let bucket = batching::pick_bucket(&self.cfg.prefill_buckets, len)
            .ok_or_else(|| anyhow::anyhow!("prompt longer than buckets"))?;
        let slot = self
            .cache
            .alloc(w.request.id)
            .ok_or_else(|| anyhow::anyhow!("no free slot"))?;

        // Right-pad the prompt to the bucket length.
        let mut toks = vec![0i32; bucket];
        for (i, t) in prompt.iter().take(len).enumerate() {
            toks[i] = *t as i32;
        }
        let t0 = Instant::now();
        let (logits, k, v) =
            self.runner
                .prefill(&self.rt, &self.manifest, &toks, 1, bucket)?;
        self.metrics.prefill_steps += 1;
        self.metrics.prefill_ns += t0.elapsed().as_nanos() as u64;
        self.cache
            .write_prefill(slot, &k.data, &v.data, bucket, len)?;

        // Sample the first generated token from the last prompt position.
        let vsize = info.vocab;
        let row = &logits.data[(len - 1) * vsize..len * vsize];
        let mut seq = ActiveSeq {
            rng: Rng::new(match w.request.sampling {
                Sampling::TopK { seed, .. } => seed ^ w.request.id,
                Sampling::Greedy => w.request.id,
            }),
            request: w.request,
            reply: w.reply,
            submitted: w.submitted,
            ttft_ms: None,
            generated: Vec::new(),
            last_token: 0,
        };
        let first = sample(row, seq.request.sampling, &mut seq.rng);
        seq.ttft_ms =
            Some(seq.submitted.elapsed().as_secs_f64() * 1e3);
        seq.generated.push(first);
        seq.last_token = first;
        self.active[slot] = Some(seq);
        // The sampled token will be fed at position `len` by decode_step;
        // finish immediately if it is EOS or the request wants one token.
        self.maybe_finish(slot);
        Ok(())
    }

    fn decode_step(&mut self) -> Result<()> {
        let b = self.cfg.decode_batch;
        let slots = self.cache.active_slots();
        if slots.is_empty() {
            return Ok(());
        }
        let mut tokens = vec![0i32; b];
        for &s in &slots {
            tokens[s] = self.active[s].as_ref().unwrap().last_token as i32;
        }
        let pos = self.cache.pos_vector();
        let t0 = Instant::now();
        let (logits, k_new, v_new) = self.runner.decode(
            &self.rt,
            &self.manifest,
            &tokens,
            self.cache.k_data(),
            self.cache.v_data(),
            &pos,
            b,
        )?;
        self.metrics.decode_steps += 1;
        self.metrics.decode_ns += t0.elapsed().as_nanos() as u64;
        self.metrics.batch_occupancy.record(slots.len() as f64);

        self.cache.append_rows(&slots, &k_new.data, &v_new.data)?;
        let vsize = self.runner.model.vocab;
        for &s in &slots {
            let row = &logits.data[s * vsize..(s + 1) * vsize];
            let seq = self.active[s].as_mut().unwrap();
            let tok = sample(row, seq.request.sampling, &mut seq.rng);
            seq.generated.push(tok);
            seq.last_token = tok;
            self.metrics.tokens_generated += 1;
            self.maybe_finish(s);
        }
        Ok(())
    }

    fn maybe_finish(&mut self, slot: usize) {
        let info_tmax = self.runner.model.t_max;
        let pos = self.cache.pos(slot);
        let finish = {
            let seq = self.active[slot].as_ref().unwrap();
            if seq.generated.last() == Some(&self.eos) {
                Some(FinishReason::Eos)
            } else if seq.generated.len() >= seq.request.max_new_tokens {
                Some(FinishReason::Length)
            } else if pos + 1 >= info_tmax {
                Some(FinishReason::CacheFull)
            } else {
                None
            }
        };
        if let Some(reason) = finish {
            let seq = self.active[slot].take().unwrap();
            self.cache.free(slot);
            let total_ms = seq.submitted.elapsed().as_secs_f64() * 1e3;
            self.metrics.completed += 1;
            self.metrics.ttft_ms.record(seq.ttft_ms.unwrap_or(total_ms));
            self.metrics.total_ms.record(total_ms);
            let _ = seq.reply.send(Response {
                id: seq.request.id,
                prompt_len: seq.request.prompt.len(),
                tokens: seq.generated,
                finish: reason,
                ttft_ms: seq.ttft_ms.unwrap_or(total_ms),
                total_ms,
            });
        }
    }
}

/// Sample a token id from a logits row.
pub fn sample(logits: &[f32], strategy: Sampling, rng: &mut Rng) -> u32 {
    match strategy {
        Sampling::Greedy => argmax(logits) as u32,
        Sampling::TopK { k, temperature, .. } => {
            let k = k.max(1).min(logits.len());
            let mut idx: Vec<usize> = (0..logits.len()).collect();
            idx.sort_unstable_by(|&a, &b| {
                logits[b].partial_cmp(&logits[a]).unwrap()
            });
            let top = &idx[..k];
            let t = temperature.max(1e-3);
            let mx = logits[top[0]];
            let weights: Vec<f64> = top
                .iter()
                .map(|&i| (((logits[i] - mx) / t) as f64).exp())
                .collect();
            top[rng.weighted(&weights)] as u32
        }
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, x) in xs.iter().enumerate() {
        if *x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_sampling_is_argmax() {
        let mut rng = Rng::new(0);
        let logits = vec![0.1, 2.0, -1.0, 1.9];
        assert_eq!(sample(&logits, Sampling::Greedy, &mut rng), 1);
    }

    #[test]
    fn topk_stays_in_top_k() {
        let mut rng = Rng::new(0);
        let logits = vec![-5.0, 10.0, 9.5, -7.0, 9.9];
        for _ in 0..200 {
            let t = sample(
                &logits,
                Sampling::TopK { k: 3, temperature: 1.0, seed: 1 },
                &mut rng,
            );
            assert!([1u32, 2, 4].contains(&t), "sampled {t}");
        }
    }

    #[test]
    fn topk_low_temperature_nearly_greedy() {
        let mut rng = Rng::new(0);
        let logits = vec![0.0, 5.0, 4.0];
        let mut ones = 0;
        for _ in 0..100 {
            if sample(
                &logits,
                Sampling::TopK { k: 2, temperature: 0.05, seed: 2 },
                &mut rng,
            ) == 1
            {
                ones += 1;
            }
        }
        assert!(ones >= 99, "{ones}");
    }
}
