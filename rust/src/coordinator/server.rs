//! Minimal HTTP/1.1 frontend over the serving engine — the deployment
//! launcher (`lqer serve`).  No web framework is reachable offline; this
//! implements the small HTTP subset the API needs, with its own
//! request-parser tests.
//!
//! Endpoints:
//!   GET  /healthz            -> 200 "ok"
//!   GET  /metrics            -> engine counters as JSON
//!   GET  /metrics/prom       -> the same counters in Prometheus text
//!                               exposition format (DESIGN.md §15)
//!   GET  /trace?last=N       -> flight-recorder events as JSON
//!   GET  /trace/chrome       -> Chrome trace_event JSON for
//!                               about:tracing / Perfetto
//!   POST /generate           -> {"prompt": "...", "max_new_tokens": n,
//!                                "top_k": k?, "n": k?, "best_of": k?,
//!                                "beams": k?, "session": id?}  ->
//!                               {"output": "...", "tokens": n,
//!                                "candidates": [...], ...}
//!
//! One OS thread per connection (std::net); the engine itself is the
//! single consumer of the request channel, so concurrency is bounded by
//! the KV slot pool, not by connection count.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::Result;

use super::{trace, EngineHandle, EngineMetrics, Request, Sampling};
use crate::tokenizer::Tokenizer;
use crate::util::json::{self, Value};

/// Content type of the Prometheus text exposition format (the version
/// suffix is part of the format contract scrapers check).
pub const PROM_CONTENT_TYPE: &str =
    "text/plain; version=0.0.4; charset=utf-8";

/// A parsed HTTP request (the subset we serve).
#[derive(Debug, PartialEq)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub body: String,
}

/// Parse an HTTP/1.1 request from raw bytes (headers + optional body).
pub fn parse_http(raw: &str) -> Result<HttpRequest> {
    let (head, body) = match raw.find("\r\n\r\n") {
        Some(i) => (&raw[..i], &raw[i + 4..]),
        None => (raw, ""),
    };
    let mut lines = head.lines();
    let request_line = lines
        .next()
        .ok_or_else(|| anyhow::anyhow!("empty request"))?;
    let mut parts = request_line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("no method"))?
        .to_string();
    let path = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("no path"))?
        .to_string();
    let mut content_length = 0usize;
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap_or(0);
            }
        }
    }
    Ok(HttpRequest {
        method,
        path,
        body: body.chars().take(content_length.max(body.len())).collect(),
    })
}

/// Format an HTTP response.
pub fn http_response(status: u16, content_type: &str, body: &str) -> String {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        _ => "Internal Server Error",
    };
    format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
}

/// Serve requests on `addr` until the process exits.
pub fn serve(
    addr: &str,
    engine: EngineHandle,
    tokenizer: Tokenizer,
) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    crate::info!("listening on http://{addr}");
    let engine = Arc::new(engine);
    let tokenizer = Arc::new(tokenizer);
    let next_id = Arc::new(AtomicU64::new(1));
    for stream in listener.incoming() {
        let Ok(stream) = stream else { continue };
        let engine = engine.clone();
        let tokenizer = tokenizer.clone();
        let next_id = next_id.clone();
        std::thread::spawn(move || {
            let _ = handle_conn(stream, &engine, &tokenizer, &next_id);
        });
    }
    Ok(())
}

fn handle_conn(
    mut stream: TcpStream,
    engine: &EngineHandle,
    tokenizer: &Tokenizer,
    next_id: &AtomicU64,
) -> Result<()> {
    stream.set_read_timeout(Some(std::time::Duration::from_secs(10)))?;
    let mut buf = vec![0u8; 64 * 1024];
    let mut total = 0usize;
    // Read until we have headers + declared body.
    loop {
        let n = stream.read(&mut buf[total..])?;
        if n == 0 {
            break;
        }
        total += n;
        let text = String::from_utf8_lossy(&buf[..total]);
        if let Some(i) = text.find("\r\n\r\n") {
            let cl = text
                .lines()
                .find_map(|l| {
                    let (k, v) = l.split_once(':')?;
                    k.trim()
                        .eq_ignore_ascii_case("content-length")
                        .then(|| v.trim().parse::<usize>().ok())?
                })
                .unwrap_or(0);
            if total >= i + 4 + cl {
                break;
            }
        }
        if total == buf.len() {
            break;
        }
    }
    let text = String::from_utf8_lossy(&buf[..total]).to_string();
    let response = match parse_http(&text) {
        Ok(req) => route(&req, engine, tokenizer, next_id),
        Err(e) => http_response(400, "text/plain", &format!("{e}")),
    };
    stream.write_all(response.as_bytes())?;
    Ok(())
}

fn route(
    req: &HttpRequest,
    engine: &EngineHandle,
    tokenizer: &Tokenizer,
    next_id: &AtomicU64,
) -> String {
    // Split off the query string so `/trace?last=N` routes on `/trace`.
    let (path, query) = match req.path.split_once('?') {
        Some((p, q)) => (p, q),
        None => (req.path.as_str(), ""),
    };
    match (req.method.as_str(), path) {
        ("GET", "/healthz") => http_response(200, "text/plain", "ok"),
        ("GET", "/metrics") => match engine.metrics() {
            Ok(m) => http_response(
                200,
                "application/json",
                &json::obj(vec![
                    ("submitted", json::num(m.submitted as f64)),
                    ("completed", json::num(m.completed as f64)),
                    ("rejected", json::num(m.rejected as f64)),
                    ("expired", json::num(m.expired as f64)),
                    ("waiting", json::num(m.waiting as f64)),
                    ("prefilling", json::num(m.prefilling as f64)),
                    ("tokens_per_step",
                     json::num(m.tokens_per_step as f64)),
                    ("packed_tokens_mean",
                     json::num(m.packed_tokens.mean())),
                    ("packed_tokens_max",
                     json::num(m.packed_tokens.max())),
                    ("packed_prefill_tokens_mean",
                     json::num(m.packed_prefill_tokens.mean())),
                    ("decode_stall_ms", json::num(m.decode_stall_ms())),
                    ("preemptions", json::num(m.preemptions as f64)),
                    ("preempted_prefills",
                     json::num(m.preempted_prefills as f64)),
                    ("swap_outs", json::num(m.swap_outs as f64)),
                    ("swap_ins", json::num(m.swap_ins as f64)),
                    ("swap_fallbacks",
                     json::num(m.swap_fallbacks as f64)),
                    ("swapped_seqs", json::num(m.swapped_seqs as f64)),
                    ("swap_blocks_in_use",
                     json::num(m.swap_blocks_in_use as f64)),
                    ("swap_blocks_total",
                     json::num(m.swap_blocks_total as f64)),
                    ("forks", json::num(m.forks as f64)),
                    ("fork_denied", json::num(m.fork_denied as f64)),
                    ("beam_prunes", json::num(m.beam_prunes as f64)),
                    ("session_hits",
                     json::num(m.session_hits as f64)),
                    ("session_evictions",
                     json::num(m.session_evictions as f64)),
                    ("sessions_live",
                     json::num(m.sessions_live as f64)),
                    ("session_blocks_held",
                     json::num(m.session_blocks_held as f64)),
                    ("cow_copies", json::num(m.cow_copies as f64)),
                    ("prefix_hit_blocks",
                     json::num(m.prefix_hit_blocks as f64)),
                    ("prefix_bytes_saved",
                     json::num(m.prefix_bytes_saved as f64)),
                    ("kv_shared_blocks",
                     json::num(m.kv_shared_blocks as f64)),
                    ("kv_shared_refs",
                     json::num(m.kv_shared_refs as f64)),
                    ("kv_block_size",
                     json::num(m.kv_block_size as f64)),
                    ("kv_blocks_in_use",
                     json::num(m.kv_blocks_in_use as f64)),
                    ("kv_blocks_total",
                     json::num(m.kv_blocks_total as f64)),
                    ("kv_utilization", json::num(m.kv_utilization)),
                    ("kv_util_peak_pct", json::num(m.kv_util.max())),
                    ("tokens_generated",
                     json::num(m.tokens_generated as f64)),
                    ("draft_tokens",
                     json::num(m.draft_tokens as f64)),
                    ("accepted_tokens",
                     json::num(m.accepted_tokens as f64)),
                    ("acceptance_rate",
                     json::num(m.acceptance_rate())),
                    ("rewind_blocks",
                     json::num(m.rewind_blocks as f64)),
                    ("backend_launches",
                     json::num(m.backend_launches as f64)),
                    ("draft_launches",
                     json::num(m.draft_launches as f64)),
                    ("verify_launches",
                     json::num(m.verify_launches as f64)),
                    ("prefill_steps",
                     json::num(m.prefill_steps as f64)),
                    ("prefill_ms_avg",
                     json::num(if m.prefill_steps > 0 {
                         m.prefill_ns as f64
                             / m.prefill_steps as f64
                             / 1e6
                     } else {
                         0.0
                     })),
                    ("decode_steps", json::num(m.decode_steps as f64)),
                    ("decode_tok_per_sec",
                     json::num(m.decode_tokens_per_sec())),
                    ("mean_batch_occupancy",
                     json::num(m.mean_batch_occupancy())),
                    ("ttft_ms_p50", json::num(m.ttft_ms.percentile(50.0))),
                    ("ttft_ms_p99", json::num(m.ttft_ms.percentile(99.0))),
                    ("itl_ms_p50", json::num(m.itl_ms.percentile(50.0))),
                    ("itl_ms_p99", json::num(m.itl_ms.percentile(99.0))),
                    ("total_ms_p50",
                     json::num(m.total_ms.percentile(50.0))),
                    ("total_ms_p99",
                     json::num(m.total_ms.percentile(99.0))),
                    ("verify_ns", json::num(m.verify_ns as f64)),
                    ("swap_ns", json::num(m.swap_ns as f64)),
                    ("tick_ns", json::num(m.tick_ns as f64)),
                    ("ticks", json::num(m.ticks as f64)),
                    ("trace_events_total",
                     json::num(m.trace_events_total as f64)),
                    ("trace_dropped_total",
                     json::num(m.trace_dropped_total as f64)),
                ])
                .to_string(),
            ),
            Err(e) => http_response(500, "text/plain", &format!("{e}")),
        },
        ("GET", "/metrics/prom") => match engine.metrics() {
            Ok(m) => http_response(200, PROM_CONTENT_TYPE, &prom_text(&m)),
            Err(e) => http_response(500, "text/plain", &format!("{e}")),
        },
        ("GET", "/trace") => match engine.trace() {
            Ok(records) => {
                let records = match query_last(query) {
                    Ok(Some(n)) => {
                        let skip = records.len().saturating_sub(n);
                        records[skip..].to_vec()
                    }
                    Ok(None) => records,
                    Err(msg) => {
                        return http_response(400, "text/plain", msg)
                    }
                };
                http_response(
                    200,
                    "application/json",
                    &trace::to_json(&records).to_string(),
                )
            }
            Err(e) => http_response(500, "text/plain", &format!("{e}")),
        },
        ("GET", "/trace/chrome") => match engine.trace() {
            Ok(records) => http_response(
                200,
                "application/json",
                &trace::to_chrome_json(&records).to_string(),
            ),
            Err(e) => http_response(500, "text/plain", &format!("{e}")),
        },
        ("POST", "/generate") => generate(req, engine, tokenizer, next_id),
        _ => http_response(404, "text/plain", "not found"),
    }
}

/// Parse the `last=N` query parameter of `GET /trace?last=N`.
fn query_last(query: &str) -> Result<Option<usize>, &'static str> {
    for pair in query.split('&').filter(|p| !p.is_empty()) {
        let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
        if k == "last" {
            return match v.parse::<usize>() {
                Ok(n) => Ok(Some(n)),
                Err(_) => Err("last must be a non-negative integer"),
            };
        }
    }
    Ok(None)
}

/// Escape a Prometheus label value per the text exposition format:
/// backslash, double quote, and newline.
fn prom_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// Every `/metrics` key rendered in Prometheus text exposition format,
/// `lqer_`-prefixed with `# TYPE` annotations.
fn prom_text(m: &EngineMetrics) -> String {
    use std::fmt::Write as _;
    let counters: &[(&str, f64)] = &[
        ("submitted", m.submitted as f64),
        ("completed", m.completed as f64),
        ("rejected", m.rejected as f64),
        ("expired", m.expired as f64),
        ("preemptions", m.preemptions as f64),
        ("preempted_prefills", m.preempted_prefills as f64),
        ("swap_outs", m.swap_outs as f64),
        ("swap_ins", m.swap_ins as f64),
        ("swap_fallbacks", m.swap_fallbacks as f64),
        ("forks", m.forks as f64),
        ("fork_denied", m.fork_denied as f64),
        ("beam_prunes", m.beam_prunes as f64),
        ("session_hits", m.session_hits as f64),
        ("session_evictions", m.session_evictions as f64),
        ("cow_copies", m.cow_copies as f64),
        ("prefix_hit_blocks", m.prefix_hit_blocks as f64),
        ("prefix_bytes_saved", m.prefix_bytes_saved as f64),
        ("tokens_generated", m.tokens_generated as f64),
        ("draft_tokens", m.draft_tokens as f64),
        ("accepted_tokens", m.accepted_tokens as f64),
        ("rewind_blocks", m.rewind_blocks as f64),
        ("backend_launches", m.backend_launches as f64),
        ("draft_launches", m.draft_launches as f64),
        ("verify_launches", m.verify_launches as f64),
        ("prefill_steps", m.prefill_steps as f64),
        ("decode_steps", m.decode_steps as f64),
        ("decode_stall_ms", m.decode_stall_ms()),
        ("verify_ns", m.verify_ns as f64),
        ("swap_ns", m.swap_ns as f64),
        ("tick_ns", m.tick_ns as f64),
        ("ticks", m.ticks as f64),
        ("trace_events_total", m.trace_events_total as f64),
        ("trace_dropped_total", m.trace_dropped_total as f64),
    ];
    let gauges: &[(&str, f64)] = &[
        ("waiting", m.waiting as f64),
        ("prefilling", m.prefilling as f64),
        ("tokens_per_step", m.tokens_per_step as f64),
        ("packed_tokens_mean", m.packed_tokens.mean()),
        ("packed_tokens_max", m.packed_tokens.max()),
        ("packed_prefill_tokens_mean", m.packed_prefill_tokens.mean()),
        ("sessions_live", m.sessions_live as f64),
        ("session_blocks_held", m.session_blocks_held as f64),
        ("swapped_seqs", m.swapped_seqs as f64),
        ("swap_blocks_in_use", m.swap_blocks_in_use as f64),
        ("swap_blocks_total", m.swap_blocks_total as f64),
        ("kv_shared_blocks", m.kv_shared_blocks as f64),
        ("kv_shared_refs", m.kv_shared_refs as f64),
        ("kv_block_size", m.kv_block_size as f64),
        ("kv_blocks_in_use", m.kv_blocks_in_use as f64),
        ("kv_blocks_total", m.kv_blocks_total as f64),
        ("kv_utilization", m.kv_utilization),
        ("kv_util_peak_pct", m.kv_util.max()),
        ("acceptance_rate", m.acceptance_rate()),
        ("prefill_ms_avg", if m.prefill_steps > 0 {
            m.prefill_ns as f64 / m.prefill_steps as f64 / 1e6
        } else {
            0.0
        }),
        ("decode_tok_per_sec", m.decode_tokens_per_sec()),
        ("mean_batch_occupancy", m.mean_batch_occupancy()),
        ("ttft_ms_p50", m.ttft_ms.percentile(50.0)),
        ("ttft_ms_p99", m.ttft_ms.percentile(99.0)),
        ("itl_ms_p50", m.itl_ms.percentile(50.0)),
        ("itl_ms_p99", m.itl_ms.percentile(99.0)),
        ("total_ms_p50", m.total_ms.percentile(50.0)),
        ("total_ms_p99", m.total_ms.percentile(99.0)),
    ];
    let mut out = String::new();
    let _ = writeln!(out, "# TYPE lqer_build_info gauge");
    let _ = writeln!(
        out,
        "lqer_build_info{{version=\"{}\"}} 1",
        prom_escape(env!("CARGO_PKG_VERSION"))
    );
    for (name, v) in counters {
        let _ = writeln!(out, "# TYPE lqer_{name} counter");
        let _ = writeln!(out, "lqer_{name} {v}");
    }
    for (name, v) in gauges {
        let _ = writeln!(out, "# TYPE lqer_{name} gauge");
        let _ = writeln!(out, "lqer_{name} {v}");
    }
    out
}

fn generate(
    req: &HttpRequest,
    engine: &EngineHandle,
    tokenizer: &Tokenizer,
    next_id: &AtomicU64,
) -> String {
    let parsed = match json::parse(&req.body) {
        Ok(v) => v,
        Err(e) => {
            return http_response(400, "text/plain",
                                 &format!("bad JSON: {e}"))
        }
    };
    let Some(prompt) = parsed.get("prompt").and_then(|v| v.as_str()) else {
        return http_response(400, "text/plain", "missing 'prompt'");
    };
    let max_new = parsed
        .get("max_new_tokens")
        .and_then(|v| v.as_usize())
        .unwrap_or(24);
    let sampling = match parsed.get("top_k").and_then(|v| v.as_usize()) {
        Some(k) if k > 0 => Sampling::TopK {
            k,
            temperature: parsed
                .get("temperature")
                .and_then(|v| v.as_f64())
                .unwrap_or(0.8) as f32,
            seed: parsed
                .get("seed")
                .and_then(|v| v.as_usize())
                .unwrap_or(17) as u64,
        },
        _ => Sampling::Greedy,
    };
    // Optional eviction class: "low" | "normal" | "high" (an unknown
    // string or a non-string value is a client error, not a silent
    // Normal).
    let priority = match parsed.get("priority") {
        None => super::Priority::Normal,
        Some(v) => {
            match v.as_str().and_then(super::Priority::parse) {
                Some(p) => p,
                None => {
                    return http_response(
                        400,
                        "text/plain",
                        "priority must be low|normal|high",
                    )
                }
            }
        }
    };
    // Multi-candidate knobs (DESIGN.md §16): `n` parallel samples,
    // `best_of` over-generation (fanout = max(n, best_of); only the top
    // `n` candidates are returned), `beams` for beam search.  A
    // non-integer value is a client error, not a silent 1.
    let n = match parsed.get("n") {
        None => 1usize,
        Some(v) => match v.as_usize() {
            Some(k) if k > 0 => k,
            _ => {
                return http_response(
                    400,
                    "text/plain",
                    "n must be a positive integer",
                )
            }
        },
    };
    let best_of = match parsed.get("best_of") {
        None => n,
        Some(v) => match v.as_usize() {
            Some(k) if k >= n => k,
            Some(_) => {
                return http_response(
                    400,
                    "text/plain",
                    "best_of must be >= n",
                )
            }
            None => {
                return http_response(
                    400,
                    "text/plain",
                    "best_of must be a positive integer",
                )
            }
        },
    };
    let beams = match parsed.get("beams") {
        None => 0usize,
        Some(v) => match v.as_usize() {
            Some(k) => k,
            None => {
                return http_response(
                    400,
                    "text/plain",
                    "beams must be a non-negative integer",
                )
            }
        },
    };
    let session = match parsed.get("session") {
        None => None,
        Some(v) => match v.as_usize() {
            Some(s) => Some(s as u64),
            None => {
                return http_response(
                    400,
                    "text/plain",
                    "session must be a non-negative integer",
                )
            }
        },
    };
    let id = next_id.fetch_add(1, Ordering::Relaxed);
    match engine.generate(Request {
        id,
        prompt: tokenizer.encode_prompt(prompt),
        max_new_tokens: max_new.min(256),
        sampling,
        priority,
        n: best_of,
        beams,
        session,
    }) {
        Ok(resp) => {
            // Truncate over-generated candidates to the requested `n`
            // (they are already sorted best-first by the engine).
            let cands: Vec<Value> = resp
                .candidates
                .iter()
                .take(n.max(beams))
                .map(|c| {
                    json::obj(vec![
                        ("output",
                         json::s(&tokenizer.decode_clean(&c.tokens))),
                        ("tokens", json::num(c.tokens.len() as f64)),
                        ("finish",
                         json::s(&format!("{:?}", c.finish))),
                        ("score", json::num(c.score)),
                    ])
                })
                .collect();
            http_response(
                200,
                "application/json",
                &json::obj(vec![
                    ("id", json::num(resp.id as f64)),
                    ("output",
                     json::s(&tokenizer.decode_clean(&resp.tokens))),
                    ("tokens", json::num(resp.tokens.len() as f64)),
                    ("finish", json::s(&format!("{:?}", resp.finish))),
                    ("candidates", json::arr(cands)),
                    ("ttft_ms", json::num(resp.ttft_ms)),
                    ("total_ms", json::num(resp.total_ms)),
                    ("swapped_ms", json::num(resp.swapped_ms)),
                ])
                .to_string(),
            )
        }
        Err(e) => http_response(500, "text/plain", &format!("{e}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_get() {
        let r = parse_http("GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n")
            .unwrap();
        assert_eq!(r.method, "GET");
        assert_eq!(r.path, "/healthz");
        assert_eq!(r.body, "");
    }

    #[test]
    fn parses_post_with_body() {
        let body = r#"{"prompt":"hi"}"#;
        let raw = format!(
            "POST /generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        );
        let r = parse_http(&raw).unwrap();
        assert_eq!(r.method, "POST");
        assert_eq!(r.body, body);
    }

    #[test]
    fn rejects_empty() {
        assert!(parse_http("").is_err());
        assert!(parse_http("GARBAGE").is_err());
    }

    #[test]
    fn response_has_content_length() {
        let resp = http_response(200, "text/plain", "hello");
        assert!(resp.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(resp.contains("Content-Length: 5\r\n"));
        assert!(resp.ends_with("hello"));
    }

    #[test]
    fn response_reason_phrases() {
        assert!(http_response(404, "text/plain", "").contains("Not Found"));
        assert!(http_response(400, "text/plain", "")
            .contains("Bad Request"));
    }

    #[test]
    fn prom_response_carries_exposition_content_type() {
        let resp = http_response(200, PROM_CONTENT_TYPE, "x 1\n");
        assert!(resp.contains(
            "Content-Type: text/plain; version=0.0.4; charset=utf-8\r\n"
        ));
    }

    #[test]
    fn prom_escape_handles_specials() {
        assert_eq!(prom_escape("plain"), "plain");
        assert_eq!(prom_escape("a\\b"), "a\\\\b");
        assert_eq!(prom_escape("a\"b"), "a\\\"b");
        assert_eq!(prom_escape("a\nb"), "a\\nb");
    }

    #[test]
    fn prom_text_exposes_every_metric_family() {
        let m = EngineMetrics::default();
        let text = prom_text(&m);
        assert!(text.contains("# TYPE lqer_submitted counter"));
        assert!(text.contains("lqer_submitted 0\n"));
        assert!(text.contains("# TYPE lqer_waiting gauge"));
        assert!(text.contains("lqer_ttft_ms_p50 0\n"));
        assert!(text.contains("lqer_trace_events_total 0\n"));
        assert!(text.contains("# TYPE lqer_backend_launches counter"));
        assert!(text.contains("lqer_draft_launches 0\n"));
        assert!(text.contains("lqer_verify_launches 0\n"));
        assert!(text.contains("lqer_build_info{version=\""));
        // Every line is either a comment or `name value`.
        for line in text.lines() {
            assert!(
                line.starts_with("# TYPE lqer_")
                    || line.starts_with("lqer_"),
                "bad exposition line: {line}"
            );
        }
    }

    #[test]
    fn query_last_parses() {
        assert_eq!(query_last(""), Ok(None));
        assert_eq!(query_last("last=5"), Ok(Some(5)));
        assert_eq!(query_last("foo=1&last=12"), Ok(Some(12)));
        assert!(query_last("last=abc").is_err());
    }
}
