//! Minimal HTTP/1.1 frontend over the serving engine — the deployment
//! launcher (`lqer serve`).  No web framework is reachable offline; this
//! implements the small HTTP subset the API needs, with its own
//! request-parser tests.
//!
//! Endpoints:
//!   GET  /healthz            -> 200 "ok"
//!   GET  /metrics            -> engine counters as JSON
//!   POST /generate           -> {"prompt": "...", "max_new_tokens": n,
//!                                "top_k": k?}  ->
//!                               {"output": "...", "tokens": n, ...}
//!
//! One OS thread per connection (std::net); the engine itself is the
//! single consumer of the request channel, so concurrency is bounded by
//! the KV slot pool, not by connection count.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::Result;

use super::{EngineHandle, Request, Sampling};
use crate::tokenizer::Tokenizer;
use crate::util::json::{self, Value};

/// A parsed HTTP request (the subset we serve).
#[derive(Debug, PartialEq)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub body: String,
}

/// Parse an HTTP/1.1 request from raw bytes (headers + optional body).
pub fn parse_http(raw: &str) -> Result<HttpRequest> {
    let (head, body) = match raw.find("\r\n\r\n") {
        Some(i) => (&raw[..i], &raw[i + 4..]),
        None => (raw, ""),
    };
    let mut lines = head.lines();
    let request_line = lines
        .next()
        .ok_or_else(|| anyhow::anyhow!("empty request"))?;
    let mut parts = request_line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("no method"))?
        .to_string();
    let path = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("no path"))?
        .to_string();
    let mut content_length = 0usize;
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap_or(0);
            }
        }
    }
    Ok(HttpRequest {
        method,
        path,
        body: body.chars().take(content_length.max(body.len())).collect(),
    })
}

/// Format an HTTP response.
pub fn http_response(status: u16, content_type: &str, body: &str) -> String {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        _ => "Internal Server Error",
    };
    format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
}

/// Serve requests on `addr` until the process exits.
pub fn serve(
    addr: &str,
    engine: EngineHandle,
    tokenizer: Tokenizer,
) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    crate::info!("listening on http://{addr}");
    let engine = Arc::new(engine);
    let tokenizer = Arc::new(tokenizer);
    let next_id = Arc::new(AtomicU64::new(1));
    for stream in listener.incoming() {
        let Ok(stream) = stream else { continue };
        let engine = engine.clone();
        let tokenizer = tokenizer.clone();
        let next_id = next_id.clone();
        std::thread::spawn(move || {
            let _ = handle_conn(stream, &engine, &tokenizer, &next_id);
        });
    }
    Ok(())
}

fn handle_conn(
    mut stream: TcpStream,
    engine: &EngineHandle,
    tokenizer: &Tokenizer,
    next_id: &AtomicU64,
) -> Result<()> {
    stream.set_read_timeout(Some(std::time::Duration::from_secs(10)))?;
    let mut buf = vec![0u8; 64 * 1024];
    let mut total = 0usize;
    // Read until we have headers + declared body.
    loop {
        let n = stream.read(&mut buf[total..])?;
        if n == 0 {
            break;
        }
        total += n;
        let text = String::from_utf8_lossy(&buf[..total]);
        if let Some(i) = text.find("\r\n\r\n") {
            let cl = text
                .lines()
                .find_map(|l| {
                    let (k, v) = l.split_once(':')?;
                    k.trim()
                        .eq_ignore_ascii_case("content-length")
                        .then(|| v.trim().parse::<usize>().ok())?
                })
                .unwrap_or(0);
            if total >= i + 4 + cl {
                break;
            }
        }
        if total == buf.len() {
            break;
        }
    }
    let text = String::from_utf8_lossy(&buf[..total]).to_string();
    let response = match parse_http(&text) {
        Ok(req) => route(&req, engine, tokenizer, next_id),
        Err(e) => http_response(400, "text/plain", &format!("{e}")),
    };
    stream.write_all(response.as_bytes())?;
    Ok(())
}

fn route(
    req: &HttpRequest,
    engine: &EngineHandle,
    tokenizer: &Tokenizer,
    next_id: &AtomicU64,
) -> String {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => http_response(200, "text/plain", "ok"),
        ("GET", "/metrics") => match engine.metrics() {
            Ok(m) => http_response(
                200,
                "application/json",
                &json::obj(vec![
                    ("submitted", json::num(m.submitted as f64)),
                    ("completed", json::num(m.completed as f64)),
                    ("rejected", json::num(m.rejected as f64)),
                    ("expired", json::num(m.expired as f64)),
                    ("waiting", json::num(m.waiting as f64)),
                    ("prefilling", json::num(m.prefilling as f64)),
                    ("tokens_per_step",
                     json::num(m.tokens_per_step as f64)),
                    ("packed_tokens_mean",
                     json::num(m.packed_tokens.mean())),
                    ("packed_tokens_max",
                     json::num(m.packed_tokens.max())),
                    ("packed_prefill_tokens_mean",
                     json::num(m.packed_prefill_tokens.mean())),
                    ("decode_stall_ms", json::num(m.decode_stall_ms())),
                    ("preemptions", json::num(m.preemptions as f64)),
                    ("preempted_prefills",
                     json::num(m.preempted_prefills as f64)),
                    ("swap_outs", json::num(m.swap_outs as f64)),
                    ("swap_ins", json::num(m.swap_ins as f64)),
                    ("swap_fallbacks",
                     json::num(m.swap_fallbacks as f64)),
                    ("swapped_seqs", json::num(m.swapped_seqs as f64)),
                    ("swap_blocks_in_use",
                     json::num(m.swap_blocks_in_use as f64)),
                    ("swap_blocks_total",
                     json::num(m.swap_blocks_total as f64)),
                    ("cow_copies", json::num(m.cow_copies as f64)),
                    ("prefix_hit_blocks",
                     json::num(m.prefix_hit_blocks as f64)),
                    ("prefix_bytes_saved",
                     json::num(m.prefix_bytes_saved as f64)),
                    ("kv_shared_blocks",
                     json::num(m.kv_shared_blocks as f64)),
                    ("kv_shared_refs",
                     json::num(m.kv_shared_refs as f64)),
                    ("kv_block_size",
                     json::num(m.kv_block_size as f64)),
                    ("kv_blocks_in_use",
                     json::num(m.kv_blocks_in_use as f64)),
                    ("kv_blocks_total",
                     json::num(m.kv_blocks_total as f64)),
                    ("kv_utilization", json::num(m.kv_utilization)),
                    ("kv_util_peak_pct", json::num(m.kv_util.max())),
                    ("tokens_generated",
                     json::num(m.tokens_generated as f64)),
                    ("draft_tokens",
                     json::num(m.draft_tokens as f64)),
                    ("accepted_tokens",
                     json::num(m.accepted_tokens as f64)),
                    ("acceptance_rate",
                     json::num(m.acceptance_rate())),
                    ("rewind_blocks",
                     json::num(m.rewind_blocks as f64)),
                    ("prefill_steps",
                     json::num(m.prefill_steps as f64)),
                    ("prefill_ms_avg",
                     json::num(if m.prefill_steps > 0 {
                         m.prefill_ns as f64
                             / m.prefill_steps as f64
                             / 1e6
                     } else {
                         0.0
                     })),
                    ("decode_steps", json::num(m.decode_steps as f64)),
                    ("decode_tok_per_sec",
                     json::num(m.decode_tokens_per_sec())),
                    ("mean_batch_occupancy",
                     json::num(m.mean_batch_occupancy())),
                    ("ttft_ms_p50", json::num(m.ttft_ms.percentile(50.0))),
                    ("ttft_ms_p99", json::num(m.ttft_ms.percentile(99.0))),
                    ("itl_ms_p50", json::num(m.itl_ms.percentile(50.0))),
                    ("itl_ms_p99", json::num(m.itl_ms.percentile(99.0))),
                    ("total_ms_p50",
                     json::num(m.total_ms.percentile(50.0))),
                    ("total_ms_p99",
                     json::num(m.total_ms.percentile(99.0))),
                ])
                .to_string(),
            ),
            Err(e) => http_response(500, "text/plain", &format!("{e}")),
        },
        ("POST", "/generate") => generate(req, engine, tokenizer, next_id),
        _ => http_response(404, "text/plain", "not found"),
    }
}

fn generate(
    req: &HttpRequest,
    engine: &EngineHandle,
    tokenizer: &Tokenizer,
    next_id: &AtomicU64,
) -> String {
    let parsed = match json::parse(&req.body) {
        Ok(v) => v,
        Err(e) => {
            return http_response(400, "text/plain",
                                 &format!("bad JSON: {e}"))
        }
    };
    let Some(prompt) = parsed.get("prompt").and_then(|v| v.as_str()) else {
        return http_response(400, "text/plain", "missing 'prompt'");
    };
    let max_new = parsed
        .get("max_new_tokens")
        .and_then(|v| v.as_usize())
        .unwrap_or(24);
    let sampling = match parsed.get("top_k").and_then(|v| v.as_usize()) {
        Some(k) if k > 0 => Sampling::TopK {
            k,
            temperature: parsed
                .get("temperature")
                .and_then(|v| v.as_f64())
                .unwrap_or(0.8) as f32,
            seed: parsed
                .get("seed")
                .and_then(|v| v.as_usize())
                .unwrap_or(17) as u64,
        },
        _ => Sampling::Greedy,
    };
    // Optional eviction class: "low" | "normal" | "high" (an unknown
    // string or a non-string value is a client error, not a silent
    // Normal).
    let priority = match parsed.get("priority") {
        None => super::Priority::Normal,
        Some(v) => {
            match v.as_str().and_then(super::Priority::parse) {
                Some(p) => p,
                None => {
                    return http_response(
                        400,
                        "text/plain",
                        "priority must be low|normal|high",
                    )
                }
            }
        }
    };
    let id = next_id.fetch_add(1, Ordering::Relaxed);
    match engine.generate(Request {
        id,
        prompt: tokenizer.encode_prompt(prompt),
        max_new_tokens: max_new.min(256),
        sampling,
        priority,
    }) {
        Ok(resp) => http_response(
            200,
            "application/json",
            &json::obj(vec![
                ("id", json::num(resp.id as f64)),
                ("output", json::s(&tokenizer.decode_clean(&resp.tokens))),
                ("tokens", json::num(resp.tokens.len() as f64)),
                ("finish", json::s(&format!("{:?}", resp.finish))),
                ("ttft_ms", json::num(resp.ttft_ms)),
                ("total_ms", json::num(resp.total_ms)),
                ("swapped_ms", json::num(resp.swapped_ms)),
            ])
            .to_string(),
        ),
        Err(e) => http_response(500, "text/plain", &format!("{e}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_get() {
        let r = parse_http("GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n")
            .unwrap();
        assert_eq!(r.method, "GET");
        assert_eq!(r.path, "/healthz");
        assert_eq!(r.body, "");
    }

    #[test]
    fn parses_post_with_body() {
        let body = r#"{"prompt":"hi"}"#;
        let raw = format!(
            "POST /generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        );
        let r = parse_http(&raw).unwrap();
        assert_eq!(r.method, "POST");
        assert_eq!(r.body, body);
    }

    #[test]
    fn rejects_empty() {
        assert!(parse_http("").is_err());
        assert!(parse_http("GARBAGE").is_err());
    }

    #[test]
    fn response_has_content_length() {
        let resp = http_response(200, "text/plain", "hello");
        assert!(resp.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(resp.contains("Content-Length: 5\r\n"));
        assert!(resp.ends_with("hello"));
    }

    #[test]
    fn response_reason_phrases() {
        assert!(http_response(404, "text/plain", "").contains("Not Found"));
        assert!(http_response(400, "text/plain", "")
            .contains("Bad Request"));
    }
}
