//! A deterministic in-process [`DecodeBackend`] used by the golden
//! host-vs-device equality test and the slot-leak property test (no PJRT
//! needed).
//!
//! The "model" is a toy recurrence whose logits depend on *every* visible
//! cache element, so any cache-management bug (wrong row, wrong slot,
//! stale data after slot reuse) changes the generated tokens:
//!
//! * each processed token writes a pseudo-random K/V row derived from
//!   (layer, token, position, feature);
//! * the logits of a lane are a hash of all cache rows at positions
//!   `< pos` of that lane plus the current token — exactly the visibility
//!   rule of the real attention mask.
//!
//! The two cache modes mirror the real backings' *write patterns*:
//!
//! * `Host` appends rows only for active lanes and copies only the `len`
//!   valid prefill rows — like the legacy [`crate::kvcache::HostKvMirror`]
//!   path;
//! * `Device` writes a row for **every** lane each step (free and
//!   mid-prefill lanes get a dead row at their position, as the lowered
//!   `decode_dev` dynamic-update-slice lattice does) and scatters the
//!   **whole** right-padded slice of each prefill chunk — like the
//!   `kvwrite` graph.
//!
//! Prefill arrives in chunks (DESIGN.md §12): each
//! [`DecodeBackend::prefill_chunk`] computes only its slice's logits,
//! *reading* rows earlier chunks installed out of the backing cache —
//! the cost shape of a real chunk graph, and a stronger oracle than
//! recomputation, since corrupting an installed row now changes every
//! later chunk.  A monolithic prefill is just the single-chunk case.
//!
//! The golden test asserts both modes produce identical token streams
//! over a multi-request continuous-batching trace, which is the same
//! masking argument that makes the real device path bit-exact with the
//! host oracle.
//!
//! [`FakeBackend::new_paged`] builds the paged twin (DESIGN.md §10): a
//! `(L, num_blocks, block_size, d)` block pool addressed through the
//! engine's block tables, emulating both paged write patterns (host:
//! valid rows of active lanes only; device: every lane each step +
//! whole padded prefill, with dead writes parked in the sentinel
//! block).  rust/tests/paged_kv.rs drives the same golden argument
//! across flat and paged engines.

use anyhow::Result;

use super::backend::DecodeBackend;
use crate::kvcache::paged::{
    BlockTable, PagedHostKv, SwappedBlock, SENTINEL_BLOCK,
};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FakeCacheMode {
    Host,
    Device,
}

/// Deterministic in-process `DecodeBackend` for tests and benches:
/// logits are a fixed function of (token, position), so any two
/// engines fed the same requests produce bit-identical streams.
/// Models the honest costs (chunked prefill reads earlier rows;
/// ~10% of draft argmaxes are skewed for speculation acceptance).
pub struct FakeBackend {
    vocab: usize,
    layers: usize,
    d: usize,
    t_max: usize,
    batch: usize,
    mode: FakeCacheMode,
    k: Vec<f32>, // (L, B, T_max, d)
    v: Vec<f32>,
    /// Block-pool backing of the paged variant — the *real*
    /// [`PagedHostKv`] store, so the golden tests exercise its layout
    /// rather than a re-implementation.
    paged: Option<(PagedHostKv, usize)>, // (pool, block_size)
    /// Fail `prefill_chunk` when the prompt's first token equals this —
    /// lets tests exercise the admission-failure path after slot alloc.
    pub fail_prefill_token: Option<i32>,
}

impl FakeBackend {
    /// Flat-cache backend (see [`FakeBackend::new_paged`] for the
    /// block-pool variant).
    pub fn new(
        mode: FakeCacheMode,
        vocab: usize,
        layers: usize,
        d: usize,
        t_max: usize,
        batch: usize,
    ) -> FakeBackend {
        let n = layers * batch * t_max * d;
        FakeBackend {
            vocab,
            layers,
            d,
            t_max,
            batch,
            mode,
            k: vec![0.0; n],
            v: vec![0.0; n],
            paged: None,
            fail_prefill_token: None,
        }
    }

    /// A paged twin: cache rows live in a `(L, num_blocks, block_size,
    /// d)` pool addressed through the engine's block tables, emulating
    /// the paged write patterns of both cache modes (`Host`: only valid
    /// rows of active lanes; `Device`: every lane + whole padded
    /// prefill, dead writes parked in the sentinel block).
    #[allow(clippy::too_many_arguments)]
    pub fn new_paged(
        mode: FakeCacheMode,
        vocab: usize,
        layers: usize,
        d: usize,
        t_max: usize,
        batch: usize,
        num_blocks: usize,
        block_size: usize,
    ) -> FakeBackend {
        let mut be = Self::new(mode, vocab, layers, d, t_max, batch);
        be.paged =
            Some((PagedHostKv::new(layers, num_blocks, block_size, d),
                  block_size));
        be
    }

    /// Which cache layout this instance models.
    pub fn mode(&self) -> FakeCacheMode {
        self.mode
    }

    #[inline]
    fn at(&self, l: usize, b: usize, p: usize, j: usize) -> usize {
        ((l * self.batch + b) * self.t_max + p) * self.d + j
    }

    /// Pseudo-random K/V row element for a processed token.
    fn kv_row(l: usize, tok: i32, p: usize, j: usize) -> (f32, f32) {
        let h = (l as i64) * 131
            + (p as i64) * 31
            + (j as i64) * 7
            + (tok as i64) * 17;
        let k = ((h.rem_euclid(251)) as f32) / 251.0;
        let v = (((h * 3 + 11).rem_euclid(241)) as f32) / 241.0;
        (k, v)
    }

    /// Logits of lane `b` with `pos_now` visible rows + current token.
    fn lane_logits(&self, b: usize, pos_now: usize, tok: i32) -> Vec<f32> {
        let mut s = 0.0f64;
        for l in 0..self.layers {
            for p in 0..pos_now.min(self.t_max) {
                for j in 0..self.d {
                    let w = ((l + 3 * p + 7 * j) % 13 + 1) as f64;
                    let idx = self.at(l, b, p, j);
                    s += self.k[idx] as f64 * w
                        + self.v[idx] as f64 * (w + 0.5);
                }
            }
        }
        s += tok as f64 * 0.618;
        (0..self.vocab)
            .map(|vv| ((s * (vv as f64 + 1.0)).sin()) as f32)
            .collect()
    }

    fn write_row(&mut self, b: usize, tok: i32, p: usize) {
        let p = p.min(self.t_max - 1); // DUS clamp semantics
        for l in 0..self.layers {
            for j in 0..self.d {
                let (kv, vv) = Self::kv_row(l, tok, p, j);
                let idx = self.at(l, b, p, j);
                self.k[idx] = kv;
                self.v[idx] = vv;
            }
        }
    }

    // --- paged-pool variants --------------------------------------------

    /// Physical (block, offset) of logical row `p`; rows beyond the
    /// table park in the sentinel block — exactly the dead-write rule of
    /// the `decode_paged`/`kvwrite_paged` DUS lattice.
    fn physical_or_sentinel(table: &BlockTable, p: usize, bs: usize)
        -> (u32, usize) {
        table
            .physical(p, bs)
            .unwrap_or((SENTINEL_BLOCK, p % bs))
    }

    /// Logits of the lane mapped by `table` with `pos_now` visible rows —
    /// same accumulation order as [`Self::lane_logits`], reading the
    /// block pool through the table, so flat and paged runs produce
    /// bit-identical values.
    fn lane_logits_paged(&self, table: &BlockTable, pos_now: usize,
                         tok: i32) -> Vec<f32> {
        let (store, bs) = self.paged.as_ref().expect("paged store");
        let mut s = 0.0f64;
        for l in 0..self.layers {
            for p in 0..pos_now.min(self.t_max) {
                let (block, off) =
                    Self::physical_or_sentinel(table, p, *bs);
                let (kr, vr) = store.rows_at(l, block, off);
                for j in 0..self.d {
                    let w = ((l + 3 * p + 7 * j) % 13 + 1) as f64;
                    s += kr[j] as f64 * w + vr[j] as f64 * (w + 0.5);
                }
            }
        }
        s += tok as f64 * 0.618;
        (0..self.vocab)
            .map(|vv| ((s * (vv as f64 + 1.0)).sin()) as f32)
            .collect()
    }

    fn write_row_paged(&mut self, table: &BlockTable, tok: i32, p: usize) {
        let layers = self.layers;
        let d = self.d;
        let (store, bs) = self.paged.as_mut().expect("paged store");
        let (block, off) = Self::physical_or_sentinel(table, p, *bs);
        for l in 0..layers {
            let (kr, vr) = store.rows_at_mut(l, block, off);
            for j in 0..d {
                let (kv, vv) = Self::kv_row(l, tok, p, j);
                kr[j] = kv;
                vr[j] = vv;
            }
        }
    }

    /// Deterministic draft-model divergence (DESIGN.md §13): the draft
    /// backbone (quantized weights without the low-rank correction)
    /// agrees with the corrected model on most steps but over-scores a
    /// hash-derived vocab entry on ~10% of (position, token) pairs —
    /// the quantization error the correction would have fixed.
    /// Hash-based so flat/paged and host/device runs diverge at
    /// identical points, keeping the golden cross-mode equality tests
    /// meaningful under speculation.
    fn draft_skew(&self, pos: usize, tok: i32) -> Option<usize> {
        let mut z = ((pos as u64) << 32) ^ u64::from(tok as u32);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^= z >> 31;
        (z % 10 == 0).then(|| ((z / 10) % self.vocab as u64) as usize)
    }

    /// Row bounds shared by the draft and verify passes: speculative
    /// rows must be *really* writable — unlike the decode paths there
    /// is no dead-write story, so a row beyond the lane/table is an
    /// engine capacity bug, not something to park in the sentinel.
    fn check_spec_row(&self, table: Option<&BlockTable>, p: usize)
        -> Result<()> {
        anyhow::ensure!(p < self.t_max, "speculative row {p} >= t_max");
        if let Some(t) = table {
            let bs = self.paged.as_ref().expect("paged store").1;
            anyhow::ensure!(
                t.physical(p, bs).is_some(),
                "speculative row {p} beyond table"
            );
        }
        Ok(())
    }

    /// One cached K/V element of the lane: the flat `(slot, q)` cell, or
    /// the block pool through the lane's table.
    fn cache_row(
        &self,
        slot: usize,
        table: Option<&BlockTable>,
        l: usize,
        q: usize,
        j: usize,
    ) -> (f32, f32) {
        match table {
            None => {
                let idx = self.at(l, slot, q, j);
                (self.k[idx], self.v[idx])
            }
            Some(t) => {
                let (store, bs) = self.paged.as_ref().expect("paged");
                let (block, off) = Self::physical_or_sentinel(t, q, *bs);
                let (kr, vr) = store.rows_at(l, block, off);
                (kr[j], vr[j])
            }
        }
    }

    /// Logits of a chunked-prefill slice: positions `[row_offset, len)`
    /// (clamped so the final zero-row chunk of a fully-shared prompt
    /// still yields row `len - 1`), each attending to rows below
    /// `row_offset` *read out of the backing cache* and to the slice's
    /// own freshly derived rows.  This is the true cost shape of a
    /// chunk graph — O(slice × prefix) instead of `O(prefix²)` — and it
    /// makes every later chunk *read* what earlier chunks wrote, so a
    /// scheduler bug that corrupts installed rows changes the stream.
    /// The accumulation order matches `lane_logits`/the monolithic
    /// prefill exactly, so chunked and monolithic logits are
    /// bit-identical.  Rows outside the slice are left zero; the engine
    /// only samples from row `len - 1` of the final chunk.
    fn chunk_logits(
        &self,
        slot: usize,
        table: Option<&BlockTable>,
        toks: &[i32],
        bucket: usize,
        len: usize,
        row_offset: usize,
    ) -> Vec<f32> {
        let mut logits = vec![0.0f32; bucket * self.vocab];
        let start = row_offset.min(len.saturating_sub(1));
        for p in start..len {
            let mut s = 0.0f64;
            for l in 0..self.layers {
                for q in 0..p {
                    for j in 0..self.d {
                        let w = ((l + 3 * q + 7 * j) % 13 + 1) as f64;
                        let (kq, vq) = if q < row_offset {
                            self.cache_row(slot, table, l, q, j)
                        } else {
                            Self::kv_row(l, toks[q], q, j)
                        };
                        s += kq as f64 * w + vq as f64 * (w + 0.5);
                    }
                }
            }
            s += toks[p] as f64 * 0.618;
            for vv in 0..self.vocab {
                logits[p * self.vocab + vv] =
                    ((s * (vv as f64 + 1.0)).sin()) as f32;
            }
        }
        logits
    }
}

impl DecodeBackend for FakeBackend {
    fn vocab(&self) -> usize {
        self.vocab
    }

    fn t_max(&self) -> usize {
        self.t_max
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn prefill_chunk(
        &mut self,
        slot: usize,
        toks: &[i32],
        bucket: usize,
        len: usize,
        row_offset: usize,
    ) -> Result<Vec<f32>> {
        anyhow::ensure!(toks.len() == bucket, "prefill bucket");
        anyhow::ensure!(row_offset <= len, "chunk offset past len");
        if self.fail_prefill_token == Some(toks[0]) {
            anyhow::bail!("injected prefill failure");
        }
        let logits =
            self.chunk_logits(slot, None, toks, bucket, len, row_offset);
        // Install the slice with the mode's write pattern.  Unlike the
        // real `kvwrite` path (which re-scatters the whole padded block
        // each chunk), the fake emulates a *true* chunk graph and only
        // writes from `row_offset` — the stricter discipline, so a
        // scheduler bug that depends on re-writes shows up here.
        let copy_rows = match self.mode {
            FakeCacheMode::Host => len,      // only valid rows
            FakeCacheMode::Device => bucket, // whole padded slice (DUS)
        };
        for p in row_offset..copy_rows.min(self.t_max) {
            for l in 0..self.layers {
                for j in 0..self.d {
                    let (kv, vv) = Self::kv_row(l, toks[p], p, j);
                    let idx = self.at(l, slot, p, j);
                    self.k[idx] = kv;
                    self.v[idx] = vv;
                }
            }
        }
        Ok(logits)
    }

    fn supports_paged(&self) -> bool {
        self.paged.is_some()
    }

    #[allow(clippy::too_many_arguments)]
    fn prefill_chunk_paged(
        &mut self,
        _slot: usize,
        table: &BlockTable,
        toks: &[i32],
        bucket: usize,
        len: usize,
        row_offset: usize,
        shared_blocks: usize,
    ) -> Result<Vec<f32>> {
        anyhow::ensure!(toks.len() == bucket, "prefill bucket");
        anyhow::ensure!(row_offset <= len, "chunk offset past len");
        anyhow::ensure!(self.paged.is_some(), "not a paged backend");
        if self.fail_prefill_token == Some(toks[0]) {
            anyhow::bail!("injected prefill failure");
        }
        let logits = self.chunk_logits(
            _slot, Some(table), toks, bucket, len, row_offset,
        );
        // Same per-mode write pattern as the flat path, but addressed
        // through the block table and starting at the chunk offset
        // (earlier rows belong to previous chunks and are never
        // re-touched — the true-chunk-graph discipline); Device-mode
        // padding rows beyond the table land in the sentinel block
        // (kvwrite_paged contract).  The first `shared_blocks` table
        // entries are read-only prefix hits: Host mode skips their rows
        // (the bytes are already there), Device mode parks the whole
        // chunk's writes in the sentinel — either way a shared block is
        // never mutated.
        let copy_rows = match self.mode {
            FakeCacheMode::Host => len,
            FakeCacheMode::Device => bucket,
        };
        let (layers, d, mode) = (self.layers, self.d, self.mode);
        let (store, bs) = self.paged.as_mut().unwrap();
        for p in row_offset..copy_rows.min(self.t_max) {
            if p / *bs < shared_blocks {
                if mode == FakeCacheMode::Host {
                    continue; // row already present in the shared block
                }
                // Device DUS lattice: dead write parked in the sentinel.
                for l in 0..layers {
                    let (kr, vr) =
                        store.rows_at_mut(l, SENTINEL_BLOCK, p % *bs);
                    for j in 0..d {
                        let (kv, vv) = Self::kv_row(l, toks[p], p, j);
                        kr[j] = kv;
                        vr[j] = vv;
                    }
                }
                continue;
            }
            anyhow::ensure!(
                mode == FakeCacheMode::Device
                    || table.physical(p, *bs).is_some(),
                "prefill row {p} beyond table"
            );
            let (block, off) = Self::physical_or_sentinel(table, p, *bs);
            for l in 0..layers {
                let (kr, vr) = store.rows_at_mut(l, block, off);
                for j in 0..d {
                    let (kv, vv) = Self::kv_row(l, toks[p], p, j);
                    kr[j] = kv;
                    vr[j] = vv;
                }
            }
        }
        Ok(logits)
    }

    fn supports_block_ops(&self) -> bool {
        self.paged.is_some()
    }

    fn supports_speculation(&self) -> bool {
        true
    }

    fn draft_step(
        &mut self,
        slot: usize,
        table: Option<&BlockTable>,
        pos: usize,
        tok: i32,
    ) -> Result<Vec<f32>> {
        self.check_spec_row(table, pos)?;
        let mut logits = match table {
            Some(t) => self.lane_logits_paged(t, pos, tok),
            None => self.lane_logits(slot, pos, tok),
        };
        // The backbone's quantization error: on divergent steps one
        // vocab entry is pushed past every sin-bounded logit, flipping
        // the argmax (and dominating top-k weights).
        if let Some(idx) = self.draft_skew(pos, tok) {
            logits[idx] = 2.0;
        }
        // The draft K/V row: `kv_row` is a pure function of (token,
        // position), which models the LQER structure — the backbone and
        // the corrected model share W_q, so re-processing the same
        // token at the same position lands the same cache row, and the
        // verify pass's re-write is idempotent.
        match table {
            Some(t) => self.write_row_paged(t, tok, pos),
            None => self.write_row(slot, tok, pos),
        }
        Ok(logits)
    }

    fn verify_tokens(
        &mut self,
        slot: usize,
        table: Option<&BlockTable>,
        start_pos: usize,
        tokens: &[i32],
    ) -> Result<Vec<f32>> {
        let mut logits = vec![0.0f32; tokens.len() * self.vocab];
        for (i, &tok) in tokens.iter().enumerate() {
            let p = start_pos + i;
            self.check_spec_row(table, p)?;
            // Row i reads everything below p — including the rows this
            // very pass wrote for tokens[..i] — and excludes row p
            // itself, exactly like sequential decode.
            let row = match table {
                Some(t) => self.lane_logits_paged(t, p, tok),
                None => self.lane_logits(slot, p, tok),
            };
            logits[i * self.vocab..(i + 1) * self.vocab]
                .copy_from_slice(&row);
            match table {
                Some(t) => self.write_row_paged(t, tok, p),
                None => self.write_row(slot, tok, p),
            }
        }
        Ok(logits)
    }

    fn draft_step_batch(
        &mut self,
        tokens: &[i32],
        pos: &[i32],
        active: &[usize],
        tables: Option<&[BlockTable]>,
    ) -> Result<Vec<f32>> {
        anyhow::ensure!(
            tokens.len() == self.batch && pos.len() == self.batch,
            "draft batch"
        );
        if let Some(t) = tables {
            anyhow::ensure!(
                t.len() == self.batch && self.paged.is_some(),
                "draft tables"
            );
        }
        let mut logits = vec![0.0f32; self.batch * self.vocab];
        let mut is_active = vec![false; self.batch];
        for &s in active {
            is_active[s] = true;
            let p = pos[s] as usize;
            let table = tables.map(|t| &t[s]);
            self.check_spec_row(table, p)?;
            let mut row = match table {
                Some(t) => self.lane_logits_paged(t, p, tokens[s]),
                None => self.lane_logits(s, p, tokens[s]),
            };
            // Same backbone quantization-error model as the per-lane
            // draft pass — hash of (position, token), lane-blind, so
            // batched and serial drafts diverge at identical points.
            if let Some(idx) = self.draft_skew(p, tokens[s]) {
                row[idx] = 2.0;
            }
            logits[s * self.vocab..(s + 1) * self.vocab]
                .copy_from_slice(&row);
            match table {
                Some(t) => self.write_row_paged(t, tokens[s], p),
                None => self.write_row(s, tokens[s], p),
            }
        }
        if self.mode == FakeCacheMode::Device {
            // The DUS lattice writes one row for every lane; lanes the
            // round dropped (γ exhausted, idle, mid-prefill) park
            // theirs exactly like plain batched decode — the sentinel
            // block when beyond-table, the clamp row when flat.
            for b in 0..self.batch {
                if is_active[b] {
                    continue;
                }
                match tables {
                    Some(t) => self.write_row_paged(
                        &t[b], tokens[b], pos[b] as usize),
                    None => self.write_row(
                        b, tokens[b], pos[b] as usize),
                }
            }
        }
        Ok(logits)
    }

    fn verify_tokens_batch(
        &mut self,
        tokens: &[i32],
        lens: &[usize],
        start_pos: &[i32],
        active: &[usize],
        tables: Option<&[BlockTable]>,
    ) -> Result<Vec<f32>> {
        anyhow::ensure!(
            lens.len() == self.batch
                && start_pos.len() == self.batch
                && !tokens.is_empty()
                && tokens.len() % self.batch == 0,
            "verify batch"
        );
        let width = tokens.len() / self.batch;
        if let Some(t) = tables {
            anyhow::ensure!(
                t.len() == self.batch && self.paged.is_some(),
                "verify tables"
            );
        }
        let mut logits = vec![0.0f32; self.batch * width * self.vocab];
        let mut is_active = vec![false; self.batch];
        for &s in active {
            is_active[s] = true;
            anyhow::ensure!(
                (1..=width).contains(&lens[s]),
                "verify window for lane {s}"
            );
            let table = tables.map(|t| &t[s]);
            for i in 0..lens[s] {
                let tok = tokens[s * width + i];
                let p = start_pos[s] as usize + i;
                self.check_spec_row(table, p)?;
                // Row i reads everything below p — including the rows
                // this pass wrote for the lane's earlier tokens and
                // nothing of any other lane; lane independence is what
                // makes one batched launch bit-identical to per-lane
                // verify.
                let row = match table {
                    Some(t) => self.lane_logits_paged(t, p, tok),
                    None => self.lane_logits(s, p, tok),
                };
                logits[(s * width + i) * self.vocab..][..self.vocab]
                    .copy_from_slice(&row);
                match table {
                    Some(t) => self.write_row_paged(t, tok, p),
                    None => self.write_row(s, tok, p),
                }
            }
        }
        if self.mode == FakeCacheMode::Device {
            // The unrolled lattice writes `width` rows per lane: the
            // padded tail of a short window and every row of a dropped
            // lane land dead — beyond-table rows park in the sentinel,
            // flat rows past a lane's committed prefix are never read
            // before a later pass rewrites them (DUS clamp at
            // `t_max - 1`).
            for b in 0..self.batch {
                let from = if is_active[b] { lens[b] } else { 0 };
                for i in from..width {
                    let p = start_pos[b] as usize + i;
                    match tables {
                        Some(t) => self.write_row_paged(
                            &t[b], tokens[b * width + i], p),
                        None => self.write_row(
                            b, tokens[b * width + i], p),
                    }
                }
            }
        }
        Ok(logits)
    }

    fn copy_block(&mut self, src: u32, dst: u32) -> Result<()> {
        let (store, _) = self.paged.as_mut().expect("paged store");
        store.copy_block(src, dst)
    }

    fn export_block(&self, id: u32) -> Result<SwappedBlock> {
        let (store, _) = self.paged.as_ref().expect("paged store");
        store.export_block(id)
    }

    fn import_block(&mut self, id: u32, blk: &SwappedBlock) -> Result<()> {
        let (store, _) = self.paged.as_mut().expect("paged store");
        store.import_block(id, blk)
    }

    fn block_bytes(&self) -> usize {
        self.paged
            .as_ref()
            .map(|(s, _)| s.block_bytes())
            .unwrap_or(0)
    }

    fn decode_paged(
        &mut self,
        tokens: &[i32],
        pos: &[i32],
        active: &[usize],
        tables: &[BlockTable],
    ) -> Result<Vec<f32>> {
        anyhow::ensure!(
            tokens.len() == self.batch
                && pos.len() == self.batch
                && tables.len() == self.batch,
            "decode batch"
        );
        anyhow::ensure!(self.paged.is_some(), "not a paged backend");
        let mut logits = vec![0.0f32; self.batch * self.vocab];
        for b in 0..self.batch {
            let row = self.lane_logits_paged(
                &tables[b], pos[b] as usize, tokens[b]);
            logits[b * self.vocab..(b + 1) * self.vocab]
                .copy_from_slice(&row);
        }
        match self.mode {
            FakeCacheMode::Device => {
                // The paged DUS lattice writes a row for every lane;
                // free lanes (empty tables, pos 0) park in the sentinel.
                for b in 0..self.batch {
                    self.write_row_paged(&tables[b], tokens[b],
                                         pos[b] as usize);
                }
            }
            FakeCacheMode::Host => {
                let bs = self.paged.as_ref().unwrap().1;
                for &s in active {
                    anyhow::ensure!(
                        tables[s]
                            .physical(pos[s] as usize, bs)
                            .is_some(),
                        "append row beyond table for lane {s}"
                    );
                    self.write_row_paged(&tables[s], tokens[s],
                                         pos[s] as usize);
                }
            }
        }
        Ok(logits)
    }

    fn decode(
        &mut self,
        tokens: &[i32],
        pos: &[i32],
        active: &[usize],
    ) -> Result<Vec<f32>> {
        anyhow::ensure!(
            tokens.len() == self.batch && pos.len() == self.batch,
            "decode batch"
        );
        let mut logits = vec![0.0f32; self.batch * self.vocab];
        for b in 0..self.batch {
            let row = self.lane_logits(b, pos[b] as usize, tokens[b]);
            logits[b * self.vocab..(b + 1) * self.vocab]
                .copy_from_slice(&row);
        }
        match self.mode {
            FakeCacheMode::Device => {
                // The DUS lattice writes a row for every lane.
                for b in 0..self.batch {
                    self.write_row(b, tokens[b], pos[b] as usize);
                }
            }
            FakeCacheMode::Host => {
                // The host mirror appends only for active lanes.
                for &s in active {
                    self.write_row(s, tokens[s], pos[s] as usize);
                }
            }
        }
        Ok(logits)
    }
}
