//! Flight recorder (DESIGN.md §15): a bounded ring buffer of typed
//! per-request lifecycle events, plus the single monotonic clock and
//! the `Span` scope-timer every engine phase measurement derives from.
//!
//! The recorder answers *what happened to request N at tick T*: every
//! scheduling decision the engine takes (admission, chunked prefill,
//! decode, speculative rounds, preemption, swap, COW forks, candidate
//! forks, beam prunes, session persistence, expiry, completion) lands
//! here as a [`TraceEvent`] stamped with the request
//! id, the decode lane, the logical tick index, and a monotonic-ns
//! timestamp.  Because the tick index is logical, event *sequences*
//! double as a correctness instrument: rust/tests/trace_events.rs pins
//! them identical flat-vs-paged and speculative-vs-sequential with the
//! timestamps stripped.
//!
//! Emission surfaces (server.rs / main.rs):
//!   * `GET /trace?last=N`   — structured JSON, oldest first;
//!   * `GET /trace/chrome`   — Chrome `trace_event` JSON for
//!     `about:tracing` / Perfetto, one track per lane, phase events
//!     (`chunk_prefilled`, `decoded`, `spec_round`, swaps) rendered as
//!     duration spans;
//!   * `--trace-file`        — the Chrome form written at shutdown;
//!     `lqer trace` re-reads and summarizes such a file.
//!
//! Overhead budget: one event is a fixed-size enum pushed onto a
//! pre-grown `VecDeque` — no allocation, no locks, no syscalls (the
//! timestamp is a cached-anchor `Instant` delta).  `lqer bench spec`
//! measures the per-event cost in-run and asserts the recorder costs
//! ≤2% of measured tick time at the default capacity.

use std::collections::VecDeque;
use std::sync::OnceLock;
use std::time::Instant;

use super::FinishReason;
use crate::util::json::{self, Value};

/// Ring capacity (events) that `trace_capacity: 0` / the
/// `--trace-capacity` default resolve to.
pub const DEFAULT_CAPACITY: usize = 4096;

/// Monotonic nanoseconds since the first call in this process — the
/// single clock source behind every engine timestamp and latency
/// metric (no more scattered `Instant` math; DESIGN.md §15).
pub fn now_ns() -> u64 {
    static ANCHOR: OnceLock<Instant> = OnceLock::new();
    ANCHOR.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// The one ns→ms conversion rule; `EngineMetrics::report()` and every
/// latency histogram sample derive their ms values through this.
pub fn ns_to_ms(ns: u64) -> f64 {
    ns as f64 / 1e6
}

/// Scope timer for one engine phase: measures from construction and
/// adds the elapsed ns to the target per-phase counter
/// (`prefill_ns`, `decode_ns`, `verify_ns`, `swap_ns`, `tick_ns`)
/// when dropped.  [`Span::elapsed_ns`] reads the running value so the
/// duration can also be attached to the trace event emitted for the
/// same scope.
#[must_use = "a span measures until it is dropped"]
pub struct Span<'a> {
    target: &'a mut u64,
    t0: u64,
}

impl<'a> Span<'a> {
    /// Start timing; the elapsed nanoseconds are added to `target`
    /// when the span drops.
    pub fn new(target: &'a mut u64) -> Span<'a> {
        Span { target, t0: now_ns() }
    }

    /// Nanoseconds elapsed since the span started.
    pub fn elapsed_ns(&self) -> u64 {
        now_ns().saturating_sub(self.t0)
    }
}

impl Drop for Span<'_> {
    fn drop(&mut self) {
        *self.target += now_ns().saturating_sub(self.t0);
    }
}

/// One engine lifecycle event (DESIGN.md §15 lists the taxonomy —
/// staticcheck SC304/SC305 pin this enum, that table, and the
/// `GET /trace` serializer to each other).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TraceEvent {
    /// Lane + all KV blocks committed; the prompt streams in from the
    /// next tick.  `blocks` fresh allocations, `shared` prefix-index
    /// hits mapped read-only.
    Admitted { blocks: usize, shared: usize },
    /// One prefill chunk executed: `rows` new prompt rows written,
    /// `budget_left` tick tokens remaining afterwards.
    ChunkPrefilled { rows: usize, budget_left: usize },
    /// One token sampled by the sequential decode path.
    Decoded,
    /// One speculative draft/verify/accept round (DESIGN.md §13).
    SpecRound { gamma: usize, accepted: usize, rewound: usize },
    /// Chosen as the eviction victim (followed by `SwappedOut` or
    /// `Evicted` depending on how the eviction was resolved).
    Preempted,
    /// Blocks exported to the host swap pool, state parked.
    SwappedOut,
    /// Parked sequence resumed: blocks re-imported, decode continues.
    SwappedIn,
    /// Copy-on-write fork: a private copy of a shared block.
    CowFork,
    /// Requeued for deterministic re-prefill (blocks discarded).
    Evicted,
    /// Dropped from the admission queue past its deadline.
    Expired,
    /// Prefill completed and `siblings` candidate lanes forked off the
    /// primary, sharing its blocks read-only (DESIGN.md §16).
    Forked { siblings: usize },
    /// A beam-search hypothesis was pruned; its lane was re-forked
    /// from a survivor (or released outright when no continuation was
    /// left for it).
    BeamPruned,
    /// A finished session turn parked `blocks` block references in the
    /// session store for near-zero-prefill re-admission.
    SessionPersisted { blocks: usize },
    /// Terminal outcome answered to the client.
    Finished { reason: FinishReason },
}

/// Stable lower-case spelling of a [`FinishReason`] for serializers.
pub fn reason_str(reason: FinishReason) -> &'static str {
    match reason {
        FinishReason::Eos => "eos",
        FinishReason::Length => "length",
        FinishReason::CacheFull => "cache_full",
        FinishReason::Rejected => "rejected",
        FinishReason::Expired => "expired",
    }
}

impl TraceEvent {
    /// snake_case event kind — the `"event"` key of `GET /trace` and
    /// the Chrome-trace `name`.  Every variant must have an arm here
    /// (staticcheck SC305).
    pub fn kind(&self) -> &'static str {
        match self {
            TraceEvent::Admitted { .. } => "admitted",
            TraceEvent::ChunkPrefilled { .. } => "chunk_prefilled",
            TraceEvent::Decoded => "decoded",
            TraceEvent::SpecRound { .. } => "spec_round",
            TraceEvent::Preempted => "preempted",
            TraceEvent::SwappedOut => "swapped_out",
            TraceEvent::SwappedIn => "swapped_in",
            TraceEvent::CowFork => "cow_fork",
            TraceEvent::Evicted => "evicted",
            TraceEvent::Expired => "expired",
            TraceEvent::Forked { .. } => "forked",
            TraceEvent::BeamPruned => "beam_pruned",
            TraceEvent::SessionPersisted { .. } => "session_persisted",
            TraceEvent::Finished { .. } => "finished",
        }
    }

    /// Variant payload as JSON fields (empty for unit variants).
    pub fn payload(&self) -> Vec<(&'static str, Value)> {
        match self {
            TraceEvent::Admitted { blocks, shared } => vec![
                ("blocks", json::num(*blocks as f64)),
                ("shared", json::num(*shared as f64)),
            ],
            TraceEvent::ChunkPrefilled { rows, budget_left } => vec![
                ("rows", json::num(*rows as f64)),
                ("budget_left", json::num(*budget_left as f64)),
            ],
            TraceEvent::SpecRound { gamma, accepted, rewound } => vec![
                ("gamma", json::num(*gamma as f64)),
                ("accepted", json::num(*accepted as f64)),
                ("rewound", json::num(*rewound as f64)),
            ],
            TraceEvent::Forked { siblings } => {
                vec![("siblings", json::num(*siblings as f64))]
            }
            TraceEvent::SessionPersisted { blocks } => {
                vec![("blocks", json::num(*blocks as f64))]
            }
            TraceEvent::Finished { reason } => {
                vec![("reason", json::s(reason_str(*reason)))]
            }
            TraceEvent::Decoded
            | TraceEvent::Preempted
            | TraceEvent::SwappedOut
            | TraceEvent::SwappedIn
            | TraceEvent::CowFork
            | TraceEvent::Evicted
            | TraceEvent::Expired
            | TraceEvent::BeamPruned => Vec::new(),
        }
    }
}

/// One recorded event with its scheduling coordinates.
#[derive(Debug, Clone)]
pub struct TraceRecord {
    pub request: u64,
    /// Decode lane; `None` for queue-side events (expiry, rejection)
    /// that never held a lane.
    pub lane: Option<usize>,
    /// Logical tick index — deterministic, so golden tests compare
    /// event sequences across engine configurations.
    pub tick: u64,
    /// Monotonic timestamp ([`now_ns`]) at emission.
    pub t_ns: u64,
    /// Span duration for phase events (chunk execution, decode step,
    /// verify pass, block export/import); 0 for instant events.
    pub dur_ns: u64,
    pub event: TraceEvent,
}

impl TraceRecord {
    /// The `GET /trace` serialization of one record.
    pub fn to_json(&self) -> Value {
        let mut fields: Vec<(&str, Value)> = vec![
            ("event", json::s(self.event.kind())),
            ("request", json::num(self.request as f64)),
            (
                "lane",
                match self.lane {
                    Some(l) => json::num(l as f64),
                    None => Value::Null,
                },
            ),
            ("tick", json::num(self.tick as f64)),
            ("t_ns", json::num(self.t_ns as f64)),
            ("dur_ns", json::num(self.dur_ns as f64)),
        ];
        fields.extend(self.event.payload());
        json::obj(fields)
    }
}

/// Bounded ring buffer of [`TraceRecord`]s: capacity-bound, oldest
/// evicted first, nothing lost below capacity (property-tested in
/// rust/tests/trace_events.rs).
#[derive(Debug)]
pub struct Recorder {
    buf: VecDeque<TraceRecord>,
    capacity: usize,
    total: u64,
    dropped: u64,
}

impl Recorder {
    /// `capacity == 0` resolves to [`DEFAULT_CAPACITY`].
    pub fn new(capacity: usize) -> Recorder {
        let capacity =
            if capacity == 0 { DEFAULT_CAPACITY } else { capacity };
        Recorder {
            // Pre-grow (bounded) so steady-state emission never
            // reallocates on the engine thread.
            buf: VecDeque::with_capacity(capacity.min(65_536)),
            capacity,
            total: 0,
            dropped: 0,
        }
    }

    /// Record an event now, evicting the oldest entry when full.
    pub fn emit(
        &mut self,
        tick: u64,
        request: u64,
        lane: Option<usize>,
        dur_ns: u64,
        event: TraceEvent,
    ) {
        self.push(TraceRecord {
            request,
            lane,
            tick,
            t_ns: now_ns(),
            dur_ns,
            event,
        });
    }

    /// Append one record, evicting the oldest past capacity
    /// (`dropped` counts evictions).
    pub fn push(&mut self, rec: TraceRecord) {
        if self.buf.len() == self.capacity {
            self.buf.pop_front();
            self.dropped += 1;
        }
        self.buf.push_back(rec);
        self.total += 1;
    }

    /// Records currently buffered.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True when nothing is buffered.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Ring capacity in records.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Events ever recorded (the `/metrics` `trace_events_total` key).
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Events evicted by wraparound (`trace_dropped_total`).
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Buffer contents, oldest first.
    pub fn snapshot(&self) -> Vec<TraceRecord> {
        self.buf.iter().cloned().collect()
    }

    /// The newest `n` records, still oldest-first.
    pub fn last(&self, n: usize) -> Vec<TraceRecord> {
        let skip = self.buf.len().saturating_sub(n);
        self.buf.iter().skip(skip).cloned().collect()
    }
}

/// `GET /trace?last=N`: the records as a JSON array, oldest first.
pub fn to_json(records: &[TraceRecord]) -> Value {
    json::arr(records.iter().map(|r| r.to_json()))
}

/// `GET /trace/chrome` / `--trace-file`: Chrome `trace_event` JSON
/// (object form) loadable in `about:tracing` and Perfetto.  One track
/// per decode lane (`tid = lane + 1`; queue-side events on `tid 0`),
/// phase events with a recorded duration as `ph:"X"` complete spans,
/// instant lifecycle events as `ph:"i"`.
pub fn to_chrome_json(records: &[TraceRecord]) -> Value {
    let mut events: Vec<Value> = Vec::with_capacity(records.len() + 8);
    let mut tids: Vec<usize> = Vec::new();
    for r in records {
        let tid = r.lane.map(|l| l + 1).unwrap_or(0);
        if !tids.contains(&tid) {
            tids.push(tid);
        }
        let mut args = vec![
            ("request", json::num(r.request as f64)),
            ("tick", json::num(r.tick as f64)),
        ];
        args.extend(r.event.payload());
        let mut fields = vec![
            ("name", json::s(r.event.kind())),
            ("cat", json::s("engine")),
            ("pid", json::num(1.0)),
            ("tid", json::num(tid as f64)),
        ];
        if r.dur_ns > 0 {
            // Complete event: ts is the span start, in microseconds.
            fields.push(("ph", json::s("X")));
            fields.push((
                "ts",
                json::num(r.t_ns.saturating_sub(r.dur_ns) as f64 / 1e3),
            ));
            fields.push(("dur", json::num(r.dur_ns as f64 / 1e3)));
        } else {
            fields.push(("ph", json::s("i")));
            fields.push(("ts", json::num(r.t_ns as f64 / 1e3)));
            fields.push(("s", json::s("t")));
        }
        fields.push(("args", json::obj(args)));
        events.push(json::obj(fields));
    }
    // Label the tracks so Perfetto shows "lane N" / "queue" instead of
    // bare thread ids.
    tids.sort_unstable();
    for tid in tids {
        let label = if tid == 0 {
            "queue".to_string()
        } else {
            format!("lane {}", tid - 1)
        };
        events.push(json::obj(vec![
            ("name", json::s("thread_name")),
            ("ph", json::s("M")),
            ("pid", json::num(1.0)),
            ("tid", json::num(tid as f64)),
            ("args", json::obj(vec![("name", json::s(&label))])),
        ]));
    }
    json::obj(vec![("traceEvents", json::arr(events))])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(i: u64) -> TraceRecord {
        TraceRecord {
            request: i,
            lane: Some(0),
            tick: i,
            t_ns: now_ns(),
            dur_ns: 0,
            event: TraceEvent::Decoded,
        }
    }

    #[test]
    fn now_ns_is_monotonic() {
        let a = now_ns();
        let b = now_ns();
        assert!(b >= a);
    }

    #[test]
    fn span_accumulates_into_target() {
        let mut counter = 0u64;
        {
            let span = Span::new(&mut counter);
            assert!(span.elapsed_ns() <= now_ns());
        }
        // The drop added *something* (possibly 0 on a coarse clock,
        // but the counter must not have been corrupted).
        let first = counter;
        {
            let _span = Span::new(&mut counter);
            std::hint::black_box(());
        }
        assert!(counter >= first);
    }

    #[test]
    fn ring_keeps_newest_and_counts_drops() {
        let mut r = Recorder::new(3);
        for i in 0..5u64 {
            r.push(rec(i));
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.total(), 5);
        assert_eq!(r.dropped(), 2);
        let ids: Vec<u64> =
            r.snapshot().iter().map(|x| x.request).collect();
        assert_eq!(ids, vec![2, 3, 4], "oldest evicted first");
        let last: Vec<u64> =
            r.last(2).iter().map(|x| x.request).collect();
        assert_eq!(last, vec![3, 4]);
    }

    #[test]
    fn zero_capacity_resolves_to_default() {
        let r = Recorder::new(0);
        assert_eq!(r.capacity(), DEFAULT_CAPACITY);
        assert!(r.is_empty());
    }

    #[test]
    fn every_event_kind_serializes_with_payload() {
        let events = vec![
            TraceEvent::Admitted { blocks: 2, shared: 1 },
            TraceEvent::ChunkPrefilled { rows: 8, budget_left: 3 },
            TraceEvent::Decoded,
            TraceEvent::SpecRound { gamma: 4, accepted: 3, rewound: 1 },
            TraceEvent::Preempted,
            TraceEvent::SwappedOut,
            TraceEvent::SwappedIn,
            TraceEvent::CowFork,
            TraceEvent::Evicted,
            TraceEvent::Expired,
            TraceEvent::Forked { siblings: 3 },
            TraceEvent::BeamPruned,
            TraceEvent::SessionPersisted { blocks: 4 },
            TraceEvent::Finished { reason: FinishReason::Eos },
        ];
        for e in events {
            let kind = e.kind().to_string();
            let r = TraceRecord {
                request: 7,
                lane: None,
                tick: 3,
                t_ns: 1_000,
                dur_ns: 0,
                event: e,
            };
            let text = r.to_json().to_string();
            assert!(
                text.contains(&format!("\"event\": \"{kind}\"")),
                "{text}"
            );
            assert!(text.contains("\"lane\": null"), "{text}");
        }
    }

    #[test]
    fn chrome_trace_shape() {
        let records = vec![
            TraceRecord {
                request: 1,
                lane: Some(2),
                tick: 1,
                t_ns: 5_000,
                dur_ns: 2_000,
                event: TraceEvent::ChunkPrefilled {
                    rows: 8,
                    budget_left: 0,
                },
            },
            TraceRecord {
                request: 1,
                lane: None,
                tick: 2,
                t_ns: 9_000,
                dur_ns: 0,
                event: TraceEvent::Expired,
            },
        ];
        let v = to_chrome_json(&records);
        let text = v.to_string();
        assert!(text.starts_with("{\"traceEvents\": ["), "{text}");
        // Span event: ph X at ts = (5000-2000)/1e3 us with dur 2 us,
        // on the lane-2 track (tid 3).
        assert!(text.contains("\"ph\": \"X\""), "{text}");
        assert!(text.contains("\"dur\": 2"), "{text}");
        assert!(text.contains("\"tid\": 3"), "{text}");
        // Instant event on the queue track.
        assert!(text.contains("\"ph\": \"i\""), "{text}");
        assert!(text.contains("\"tid\": 0"), "{text}");
        // Track labels.
        assert!(text.contains("\"lane 2\""), "{text}");
        assert!(text.contains("\"queue\""), "{text}");
    }
}
