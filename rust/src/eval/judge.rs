//! AlpacaEval-style pairwise evaluation (paper Table 5).
//!
//! The paper asks GPT-4-Turbo which of two model generations it prefers
//! (L2QER vs the AWQ reference) and reports the win rate plus a
//! length-controlled variant.  Our judge substitute (DESIGN.md §2) is the
//! FP16 model itself: for each prompt both quantized engines generate a
//! continuation greedily; the judge prefers the generation with the lower
//! FP16-model NLL (i.e. the continuation the full-precision model finds
//! more plausible).  The length-controlled variant compares *per-token*
//! NLL so verbose generations are not penalized.

use anyhow::Result;

use crate::config::Manifest;
use crate::runtime::{ModelRunner, Runtime};

#[derive(Debug, Clone, Default)]
pub struct JudgeResult {
    pub n: usize,
    pub wins: usize,
    pub lc_wins: usize,
    pub ties: usize,
}

impl JudgeResult {
    pub fn win_rate(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            (self.wins as f64 + 0.5 * self.ties as f64) / self.n as f64
        }
    }

    pub fn lc_win_rate(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            (self.lc_wins as f64 + 0.5 * self.ties as f64) / self.n as f64
        }
    }
}

/// NLL of `continuation` after `prompt` under the judge model (total and
/// per-token).
pub fn continuation_nll(
    rt: &Runtime,
    manifest: &Manifest,
    judge: &ModelRunner,
    prompt: &[u32],
    continuation: &[u32],
) -> Result<(f64, f64)> {
    let (b, t) = manifest.score_shape;
    let vocab = judge.model.vocab;
    anyhow::ensure!(
        prompt.len() + continuation.len() <= t,
        "sequence too long for score graph"
    );
    anyhow::ensure!(!continuation.is_empty(), "empty continuation");
    let mut tokens = vec![0i32; b * t];
    for (i, &tok) in prompt.iter().chain(continuation.iter()).enumerate() {
        tokens[i] = tok as i32;
    }
    let logits = judge.score(rt, manifest, &tokens, b, t)?;
    let mut nll = 0.0f64;
    for (i, &tok) in continuation.iter().enumerate() {
        let posn = prompt.len() + i - 1;
        let off = posn * vocab;
        nll -= super::log_prob(&logits.data[off..off + vocab], tok as usize);
    }
    Ok((nll, nll / continuation.len() as f64))
}

/// Judge a pair of generations; positive verdicts favor `gen_a`.
pub fn judge_pair(
    rt: &Runtime,
    manifest: &Manifest,
    judge: &ModelRunner,
    prompt: &[u32],
    gen_a: &[u32],
    gen_b: &[u32],
    result: &mut JudgeResult,
) -> Result<()> {
    // Strip trailing EOS/pad-ish tokens beyond score capacity.
    let (_, t) = manifest.score_shape;
    let cap = t.saturating_sub(prompt.len() + 1);
    let a = &gen_a[..gen_a.len().min(cap)];
    let b = &gen_b[..gen_b.len().min(cap)];
    if a.is_empty() || b.is_empty() {
        result.n += 1;
        result.ties += 1;
        return Ok(());
    }
    let (nll_a, pt_a) = continuation_nll(rt, manifest, judge, prompt, a)?;
    let (nll_b, pt_b) = continuation_nll(rt, manifest, judge, prompt, b)?;
    result.n += 1;
    if (nll_a - nll_b).abs() < 1e-9 {
        result.ties += 1;
    } else if nll_a < nll_b {
        result.wins += 1;
    }
    if pt_a < pt_b {
        result.lc_wins += 1;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn win_rates_count_ties_as_half() {
        let r = JudgeResult { n: 4, wins: 1, lc_wins: 2, ties: 2 };
        assert!((r.win_rate() - 0.5).abs() < 1e-12);
        assert!((r.lc_win_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn empty_result_safe() {
        let r = JudgeResult::default();
        assert_eq!(r.win_rate(), 0.0);
    }
}
