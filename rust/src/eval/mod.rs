//! Evaluators running entirely through the rust serving runtime (the same
//! path a deployment would use — this is what makes the Table 2/3/4/6
//! numbers end-to-end rather than a python simulation).
//!
//! * [`ppl`]   — WikiText-style perplexity over the held-out token stream
//! * [`tasks`] — the six downstream tasks via length-normalized option
//!   log-likelihood (lm-eval-harness style)
//! * [`judge`] — AlpacaEval-style pairwise win-rate with the FP16 model as
//!   the judge

pub mod judge;
pub mod ppl;
pub mod tasks;

/// Numerically stable log-softmax of one logits row, returning the log-prob
/// of `target`.
pub fn log_prob(logits: &[f32], target: usize) -> f64 {
    let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let mut denom = 0.0f64;
    for &x in logits {
        denom += ((x as f64) - mx).exp();
    }
    (logits[target] as f64) - mx - denom.ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_prob_uniform() {
        let logits = vec![0.0f32; 4];
        let lp = log_prob(&logits, 2);
        assert!((lp - (0.25f64).ln()).abs() < 1e-9);
    }

    #[test]
    fn log_prob_peaked() {
        let mut logits = vec![0.0f32; 8];
        logits[3] = 50.0;
        assert!(log_prob(&logits, 3) > -1e-6);
        assert!(log_prob(&logits, 0) < -40.0);
    }

    #[test]
    fn log_prob_stable_for_large_values() {
        let logits = vec![1e4f32, 1e4 - 1.0];
        let lp = log_prob(&logits, 0);
        assert!(lp.is_finite() && lp < 0.0);
    }
}
