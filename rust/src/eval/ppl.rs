//! Perplexity over a token stream through the score graph.
//!
//! The stream is cut into non-overlapping windows of the score shape
//! (B, T); within each window, position i predicts token i+1 (the first
//! token of each row is context only).  This mirrors the python trainer's
//! validation metric and the standard WikiText-2 protocol.

use anyhow::Result;

use crate::config::Manifest;
use crate::runtime::{ModelRunner, Runtime};

#[derive(Debug, Clone)]
pub struct PplResult {
    pub ppl: f64,
    pub nll: f64,
    pub tokens: usize,
    pub windows: usize,
}

/// Evaluate perplexity of `runner` on `stream`, using up to `max_windows`
/// (B,T) windows (0 = all).
pub fn perplexity(
    rt: &Runtime,
    manifest: &Manifest,
    runner: &ModelRunner,
    stream: &[u16],
    max_windows: usize,
) -> Result<PplResult> {
    let (b, t) = manifest.score_shape;
    let vocab = runner.model.vocab;
    let window = b * t;
    let mut nll_sum = 0.0f64;
    let mut count = 0usize;
    let mut windows = 0usize;

    let total = stream.len() / window;
    let n_windows = if max_windows == 0 {
        total
    } else {
        total.min(max_windows)
    };
    for w in 0..n_windows {
        let chunk = &stream[w * window..(w + 1) * window];
        let tokens: Vec<i32> = chunk.iter().map(|&x| x as i32).collect();
        let logits = runner.score(rt, manifest, &tokens, b, t)?;
        debug_assert_eq!(logits.shape, vec![b, t, vocab]);
        for row in 0..b {
            for posn in 0..t - 1 {
                let target = tokens[row * t + posn + 1] as usize;
                let off = (row * t + posn) * vocab;
                nll_sum -= super::log_prob(
                    &logits.data[off..off + vocab],
                    target,
                );
                count += 1;
            }
        }
        windows += 1;
    }
    anyhow::ensure!(count > 0, "empty evaluation stream");
    let nll = nll_sum / count as f64;
    Ok(PplResult { ppl: nll.exp(), nll, tokens: count, windows })
}
