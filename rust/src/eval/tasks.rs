//! Downstream-task evaluation (the six tasks of Table 4, lm-eval-harness
//! protocol): each option is appended to the item context and scored by
//! length-normalized option log-likelihood under the model; the highest
//! scoring option wins.  `lambada` is exact final-word prediction
//! (argmax over the vocabulary at the final context position).
//!
//! Scoring runs through the (B, T) score graph: the options of one item
//! are packed into one batch (2-way tasks pad the batch with repeats).

use std::path::Path;

use anyhow::Result;

use crate::config::Manifest;
use crate::runtime::{ModelRunner, Runtime};
use crate::util::json;

#[derive(Debug, Clone)]
pub struct TaskItem {
    pub task: String,
    pub context: Vec<u32>,
    pub options: Vec<Vec<u32>>,
    pub answer: usize,
}

#[derive(Debug, Clone)]
pub struct TaskScores {
    /// (task name, accuracy, n items)
    pub per_task: Vec<(String, f64, usize)>,
}

impl TaskScores {
    pub fn average(&self) -> f64 {
        if self.per_task.is_empty() {
            return 0.0;
        }
        self.per_task.iter().map(|(_, a, _)| a).sum::<f64>()
            / self.per_task.len() as f64
    }

    pub fn accuracy(&self, task: &str) -> Option<f64> {
        self.per_task
            .iter()
            .find(|(t, _, _)| t == task)
            .map(|(_, a, _)| *a)
    }
}

fn ids(v: &json::Value) -> Vec<u32> {
    v.as_array()
        .unwrap_or(&[])
        .iter()
        .filter_map(|x| x.as_usize().map(|u| u as u32))
        .collect()
}

/// Load `artifacts/data/tasks.json`.
pub fn load_tasks(path: &Path) -> Result<Vec<TaskItem>> {
    let v = json::parse_file(path)?;
    let mut out = Vec::new();
    for item in v.req("tasks")?.as_array().unwrap_or(&[]) {
        out.push(TaskItem {
            task: item.str_at("task")?,
            context: ids(item.req("context")?),
            options: item
                .req("options")?
                .as_array()
                .unwrap_or(&[])
                .iter()
                .map(ids)
                .collect(),
            answer: item.usize_at("answer")?,
        });
    }
    Ok(out)
}

/// Score one item: returns the model's chosen option index.
pub fn choose_option(
    rt: &Runtime,
    manifest: &Manifest,
    runner: &ModelRunner,
    item: &TaskItem,
) -> Result<usize> {
    let (b, t) = manifest.score_shape;
    let vocab = runner.model.vocab;

    if item.task == "lambada" {
        // Exact final-token prediction.
        let ctx = &item.context;
        anyhow::ensure!(ctx.len() < t, "context too long");
        let mut tokens = vec![0i32; b * t];
        for (i, &tok) in ctx.iter().enumerate() {
            tokens[i] = tok as i32;
        }
        let logits = runner.score(rt, manifest, &tokens, b, t)?;
        let off = (ctx.len() - 1) * vocab;
        let row = &logits.data[off..off + vocab];
        let target = item.options[0][0] as usize;
        let mut best = 0usize;
        for (i, x) in row.iter().enumerate() {
            if *x > row[best] {
                best = i;
            }
        }
        return Ok(if best == target { item.answer } else { usize::MAX });
    }

    anyhow::ensure!(item.options.len() <= b, "too many options for batch");
    let mut tokens = vec![0i32; b * t];
    let mut spans = Vec::new(); // (start, len) of each option's tokens
    for (o, opt) in item.options.iter().enumerate() {
        let ctx_len = item.context.len();
        anyhow::ensure!(ctx_len + opt.len() < t, "item too long");
        for (i, &tok) in item.context.iter().enumerate() {
            tokens[o * t + i] = tok as i32;
        }
        for (i, &tok) in opt.iter().enumerate() {
            tokens[o * t + ctx_len + i] = tok as i32;
        }
        spans.push((ctx_len, opt.len()));
    }
    // Pad unused batch rows with a copy of row 0 (ignored).
    for o in item.options.len()..b {
        let (src, dst) = tokens.split_at_mut(o * t);
        dst[..t].copy_from_slice(&src[..t]);
    }

    let logits = runner.score(rt, manifest, &tokens, b, t)?;
    let mut best = (f64::NEG_INFINITY, 0usize);
    for (o, (start, len)) in spans.iter().enumerate() {
        let mut lp = 0.0f64;
        for i in 0..*len {
            // position (start + i - 1) predicts token (start + i)
            let posn = start + i - 1;
            let target = tokens[o * t + start + i] as usize;
            let off = (o * t + posn) * vocab;
            lp += super::log_prob(&logits.data[off..off + vocab], target);
        }
        let norm = lp / *len as f64; // length-normalized
        if norm > best.0 {
            best = (norm, o);
        }
    }
    Ok(best.1)
}

/// Evaluate all tasks, using up to `per_task` items each (0 = all).
pub fn evaluate(
    rt: &Runtime,
    manifest: &Manifest,
    runner: &ModelRunner,
    items: &[TaskItem],
    per_task: usize,
) -> Result<TaskScores> {
    let mut names: Vec<String> = Vec::new();
    for it in items {
        if !names.contains(&it.task) {
            names.push(it.task.clone());
        }
    }
    let mut per = Vec::new();
    for name in names {
        let subset: Vec<&TaskItem> = items
            .iter()
            .filter(|i| i.task == name)
            .take(if per_task == 0 { usize::MAX } else { per_task })
            .collect();
        let mut correct = 0usize;
        for item in &subset {
            let choice = choose_option(rt, manifest, runner, item)?;
            if choice == item.answer {
                correct += 1;
            }
        }
        per.push((name, correct as f64 / subset.len() as f64, subset.len()));
    }
    Ok(TaskScores { per_task: per })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_task_items() {
        let txt = r#"{"tasks": [{"task": "piqa", "context": [1, 4],
                      "options": [[5], [6, 7]], "answer": 1}],
                     "names": ["piqa"]}"#;
        let dir = std::env::temp_dir().join("lqer_tasks_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("tasks.json");
        std::fs::write(&p, txt).unwrap();
        let items = load_tasks(&p).unwrap();
        assert_eq!(items.len(), 1);
        assert_eq!(items[0].options[1], vec![6, 7]);
        assert_eq!(items[0].answer, 1);
    }

    #[test]
    fn scores_average() {
        let s = TaskScores {
            per_task: vec![
                ("a".into(), 0.5, 10),
                ("b".into(), 1.0, 10),
            ],
        };
        assert!((s.average() - 0.75).abs() < 1e-12);
        assert_eq!(s.accuracy("b"), Some(1.0));
        assert_eq!(s.accuracy("c"), None);
    }
}
