//! Circuit-area model: LUT counts for the processing engines (PEs) behind
//! the paper's hardware-efficiency claims (Table 3 "Circuit area" column,
//! breakdown Tables 7/8/9).
//!
//! The paper synthesized real arithmetic cores with Vivado 2023.1 on a
//! Xilinx Alveo U250 at a matched throughput of **16 MACs/cycle** and
//! reports LUTs (1 DSP counted as 100 LUTs).  We have no Vivado in this
//! image, so this module is an *analytical* model with primitive costs
//! **calibrated against the paper's own breakdown tables**:
//!
//! * integer MAC (a-bit x b-bit):  `0.9*a*b + 2*(a+b) + 8` LUTs — fitted
//!   so the L2QER PE breakdown reproduces Table 9 within a few percent
//!   (model 1033/1772/937 vs paper 1028/1782/992 LUTs);
//! * FP16 MAC: 717 LUTs (Table 8's 16-MAC FP16 GEMM / 16);
//! * runtime dequantizer lane (INT-g128 -> FP16): 3932 LUTs (Table 8's
//!   dequantize block / 16);
//! * LLM.int4() scatter/gather + casting blocks: Table 7's synthesized
//!   constants;
//! * "other" (control, FIFOs): per-method fraction from Tables 7-9.
//!
//! Everything downstream (Table 3's relative column, the breakdowns) is
//! *derived* from these primitives.  EXPERIMENTS.md notes where the
//! derived relative factors deviate from the paper's (the paper's FP16
//! baseline PE is evidently smaller than its FP16-GEMM-inside-AWQ block).

/// Integer MAC cost in LUTs for an a-bit x b-bit multiply-accumulate.
pub fn int_mac_luts(a_bits: u32, b_bits: u32) -> f64 {
    0.9 * (a_bits * b_bits) as f64 + 2.0 * (a_bits + b_bits) as f64 + 8.0
}

/// FP16 multiply-accumulate (calibrated, includes pipeline registers).
pub const FP16_MAC_LUTS: f64 = 717.0;

/// One runtime dequantization lane: unpack INT-gG word, FP16 scale
/// multiply, group index machinery (calibrated to Table 8).
pub const DEQUANT_LANE_LUTS: f64 = 3932.0;

/// LLM.int4() blocks (calibrated to Table 7).
pub const SCATTER_GATHER_LUTS: f64 = 11_579.0;
pub const LLMINT4_GEMM_CAST_LUTS: f64 = 106_959.0;
pub const LLMINT4_GEMM_H_LUTS: f64 = 404.0;

/// MXINT extras: shared-exponent adder + alignment shifter per PE.
pub const MX_EXP_ALIGN_LUTS: f64 = 60.0;
/// On-the-fly MXINT activation quantizer (max-tree + shift) per PE.
pub const MX_ACT_QUANT_LUTS: f64 = 150.0;
/// Per-token INT activation quantizer + per-output rescale unit.
pub const INT_ACT_RESCALE_LUTS: f64 = 430.0;
/// Duty factor of the skinny (X A_k) B_k GEMM (output-stationary, shallower
/// accumulation network than the full-width panels).
pub const MATMUL3_DUTY: f64 = 0.6;

pub const LANES: usize = 16; // matched throughput: 16 MACs/cycle

use crate::quant::spec::{Algo, LayerSpec, QuantSpec, WeightFormat};

/// Per-method "other" share (control/FIFO/AXI), from Tables 7-9.
fn other_frac(method: &str) -> f64 {
    if method.starts_with("llmint4") {
        0.103
    } else if method.starts_with("awq")
        || method.starts_with("gptq")
        || method.starts_with("rtn")
        || method.starts_with("clipq-w2")
    {
        0.130
    } else {
        0.264
    }
}

/// Plan-derived "other" share: the same three buckets, discriminated by
/// what the PE actually contains instead of by method-name prefix.
fn other_frac_for(ls: &LayerSpec) -> f64 {
    if ls.algo == Algo::Llmint4 {
        0.103
    } else if ls.act.bits() == 16
        && ls.lowrank.is_none()
        && !matches!(ls.weight, WeightFormat::Fp16)
    {
        0.130 // w-only runtime-dequant engine
    } else {
        0.264
    }
}

/// A processing engine area report.
#[derive(Debug, Clone)]
pub struct PeArea {
    pub method: String,
    pub components: Vec<(String, f64)>,
    pub total: f64,
}

impl PeArea {
    fn build(method: &str, comps: Vec<(&str, f64)>) -> PeArea {
        PeArea::build_frac(method, other_frac(method), comps)
    }

    fn build_frac(
        method: &str,
        frac: f64,
        comps: Vec<(&str, f64)>,
    ) -> PeArea {
        let subtotal: f64 = comps.iter().map(|(_, v)| v).sum();
        let other = subtotal * frac / (1.0 - frac);
        let mut components: Vec<(String, f64)> = comps
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect();
        components.push(("other".to_string(), other));
        PeArea {
            method: method.to_string(),
            total: subtotal + other,
            components,
        }
    }

    /// Relative to the FP16 baseline PE.
    pub fn relative(&self) -> f64 {
        self.total / fp16_pe().total
    }
}

/// FP16 baseline: 16 FP16 MACs.
pub fn fp16_pe() -> PeArea {
    PeArea::build(
        "fp16",
        vec![("fp16_gemm", LANES as f64 * FP16_MAC_LUTS)],
    )
}

/// w-only dequantization PE (GPTQ / AWQ / RTN-INT4): runtime dequant lanes
/// feeding an FP16 GEMM (paper Table 8).
pub fn dequant_pe(method: &str) -> PeArea {
    PeArea::build(
        method,
        vec![
            ("dequantize", LANES as f64 * DEQUANT_LANE_LUTS),
            ("fp16_gemm", LANES as f64 * FP16_MAC_LUTS),
        ],
    )
}

/// LLM.int4() mixed-precision PE (paper Table 7).
pub fn llmint4_pe() -> PeArea {
    PeArea::build(
        "llmint4",
        vec![
            ("gemm_l+cast", LLMINT4_GEMM_CAST_LUTS),
            ("scatter+gather", SCATTER_GATHER_LUTS),
            ("gemm_h", LLMINT4_GEMM_H_LUTS),
        ],
    )
}

/// Plain integer w&a PE (SmoothQuant W8A8, OmniQuant-style W6A6, ...).
pub fn int_wa_pe(method: &str, w_bits: u32, a_bits: u32) -> PeArea {
    PeArea::build(
        method,
        vec![
            (
                "int_gemm",
                LANES as f64 * int_mac_luts(w_bits, a_bits),
            ),
            ("act_quant+rescale", INT_ACT_RESCALE_LUTS),
        ],
    )
}

/// MXINT w&a PE without low-rank correction (plain MXINT WxAy).
pub fn mxint_pe(method: &str, w_bits: u32, a_bits: u32) -> PeArea {
    PeArea::build(
        method,
        vec![
            (
                "mx_gemm",
                LANES as f64 * int_mac_luts(w_bits, a_bits)
                    + MX_EXP_ALIGN_LUTS,
            ),
            ("act_quant", MX_ACT_QUANT_LUTS),
        ],
    )
}

/// The L2QER PE (paper Table 9): three parallel GEMM blocks.
///   matmul1: X W_q     (a_bits x w_bits, the big low-precision panel)
///   matmul2: X A_k     (a_bits x 8, full activation throughput)
///   matmul3: (X A_k) B_k  (8 x 8, skinny)
/// `mx` selects MXINT (shared-exponent) vs INT-g128 arithmetic.
pub fn l2qer_pe(method: &str, w_bits: u32, a_bits: u32, mx: bool) -> PeArea {
    let exp = if mx { MX_EXP_ALIGN_LUTS } else { 0.0 };
    let actq = if mx {
        MX_ACT_QUANT_LUTS
    } else {
        INT_ACT_RESCALE_LUTS
    };
    let m1 = LANES as f64 * int_mac_luts(w_bits, a_bits) + exp;
    let m2 = LANES as f64 * int_mac_luts(8, a_bits) + exp + actq;
    let m3 = LANES as f64 * int_mac_luts(8, 8) * MATMUL3_DUTY;
    PeArea::build(
        method,
        vec![("matmul2", m2), ("matmul1", m1), ("matmul3", m3)],
    )
}

/// Area for one layer's quantization spec — the processing engine the
/// plan implies, derived from the typed spec instead of a method-name
/// match.  This is what the plan-aware paths (`lqer plan`, per-layer
/// mixed-precision costing) use; [`area_for_method`] is the legacy shim
/// over it.  Returns `None` for configurations the analytic model has
/// no primitives for (fp32 low-rank factors, `lowrank.bits: null`).
pub fn area_for_layer(label: &str, ls: &LayerSpec) -> Option<PeArea> {
    let frac = other_frac_for(ls);
    let w_bits = ls.weight.elem_bits();
    // w-only setups run their skinny GEMMs at the paper's A8 operating
    // point (Table 3's L2QER-INT w-only row).
    let a_bits = if ls.act.bits() == 16 { 8 } else { ls.act.bits() };
    let mx = matches!(ls.weight, WeightFormat::Mxint { .. });

    if ls.algo == Algo::Llmint4 {
        return Some(PeArea::build_frac(
            label,
            frac,
            vec![
                ("gemm_l+cast", LLMINT4_GEMM_CAST_LUTS),
                ("scatter+gather", SCATTER_GATHER_LUTS),
                ("gemm_h", LLMINT4_GEMM_H_LUTS),
            ],
        ));
    }
    if let Some(lr) = ls.lowrank {
        // Three parallel GEMM blocks (paper Table 9), MXINT or INT; the
        // factor GEMMs run at the plan's b_h (fp32 factors have no
        // integer-MAC model).
        let h_bits = lr.bits?;
        let exp = if mx { MX_EXP_ALIGN_LUTS } else { 0.0 };
        let actq = if mx { MX_ACT_QUANT_LUTS } else { INT_ACT_RESCALE_LUTS };
        let m1 = LANES as f64 * int_mac_luts(w_bits, a_bits) + exp;
        let m2 = LANES as f64 * int_mac_luts(h_bits, a_bits) + exp + actq;
        let m3 = LANES as f64 * int_mac_luts(h_bits, h_bits) * MATMUL3_DUTY;
        return Some(PeArea::build_frac(
            label,
            frac,
            vec![("matmul2", m2), ("matmul1", m1), ("matmul3", m3)],
        ));
    }
    Some(match ls.weight {
        WeightFormat::Fp16 => PeArea::build_frac(
            label,
            frac,
            vec![("fp16_gemm", LANES as f64 * FP16_MAC_LUTS)],
        ),
        _ if ls.act.bits() == 16 => PeArea::build_frac(
            label,
            frac,
            vec![
                ("dequantize", LANES as f64 * DEQUANT_LANE_LUTS),
                ("fp16_gemm", LANES as f64 * FP16_MAC_LUTS),
            ],
        ),
        WeightFormat::Mxint { .. } => PeArea::build_frac(
            label,
            frac,
            vec![
                (
                    "mx_gemm",
                    LANES as f64 * int_mac_luts(w_bits, a_bits)
                        + MX_EXP_ALIGN_LUTS,
                ),
                ("act_quant", MX_ACT_QUANT_LUTS),
            ],
        ),
        WeightFormat::IntGroup { .. } => PeArea::build_frac(
            label,
            frac,
            vec![
                ("int_gemm", LANES as f64 * int_mac_luts(w_bits, a_bits)),
                ("act_quant+rescale", INT_ACT_RESCALE_LUTS),
            ],
        ),
    })
}

/// Model-level area: the maximum per-layer PE of a plan (a serving
/// engine must instantiate the widest datapath any layer needs).
/// `None` if any layer's configuration is un-modeled.
pub fn area_for_plan(label: &str, plan: &QuantSpec) -> Option<PeArea> {
    plan.layer_specs()
        .map(|ls| area_for_layer(label, ls))
        .collect::<Option<Vec<_>>>()?
        .into_iter()
        .max_by(|a, b| a.total.total_cmp(&b.total))
}

/// Area for a named experiment method (Table 3 rows) — the legacy
/// string shim over [`area_for_layer`].
pub fn area_for_method(method: &str) -> Option<PeArea> {
    let plan = QuantSpec::from_method_name(method).ok()?;
    area_for_layer(method, &plan.default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproduces_table9_within_tolerance() {
        // Paper Table 9: matmul2 1782, matmul1 1028, matmul3 992 LUTs.
        let pe = l2qer_pe("l2qer-w4a8", 4, 8, true);
        let get = |name: &str| {
            pe.components
                .iter()
                .find(|(k, _)| k == name)
                .map(|(_, v)| *v)
                .unwrap()
        };
        assert!((get("matmul2") - 1782.0).abs() / 1782.0 < 0.05,
                "matmul2 {}", get("matmul2"));
        assert!((get("matmul1") - 1028.0).abs() / 1028.0 < 0.05,
                "matmul1 {}", get("matmul1"));
        assert!((get("matmul3") - 992.0).abs() / 992.0 < 0.10,
                "matmul3 {}", get("matmul3"));
    }

    #[test]
    fn reproduces_table8_shape() {
        // Paper Table 8: dequant 62907 (73.6%), matmul 11476 (13.4%).
        let pe = dequant_pe("awq");
        let dq = pe.components[0].1;
        let mm = pe.components[1].1;
        assert!((dq - 62907.0).abs() / 62907.0 < 0.02, "dequant {dq}");
        assert!((mm - 11476.0).abs() / 11476.0 < 0.01, "matmul {mm}");
        assert!(dq / pe.total > 0.65 && dq / pe.total < 0.80);
    }

    #[test]
    fn reproduces_table7_total() {
        let pe = llmint4_pe();
        // Paper total = 106959 + 11579 + 404 + 13604 = 132546.
        assert!((pe.total - 132_546.0).abs() / 132_546.0 < 0.02,
                "total {}", pe.total);
    }

    #[test]
    fn relative_ordering_matches_table3() {
        // LLM.int4 >> dequant w-only >> FP16 > L2QER-INT > L2QER-MXINT.
        let fp16 = fp16_pe().relative();
        let awq = dequant_pe("awq").relative();
        let llm = llmint4_pe().relative();
        let l2_int = l2qer_pe("l2qer-int-w4a8", 4, 8, false).relative();
        let l2_mx8 = l2qer_pe("l2qer-w4a8", 4, 8, true).relative();
        let l2_mx6 = l2qer_pe("l2qer-w4a6", 4, 6, true).relative();
        assert!((fp16 - 1.0).abs() < 1e-9);
        assert!(llm > awq && awq > 3.0, "llm {llm} awq {awq}");
        assert!(l2_int < 1.0 && l2_mx8 < l2_int);
        assert!(l2_mx6 < l2_mx8, "W4A6 must be cheaper than W4A8");
        // Paper: L2QER-MXINT W4A8 = 0.33x; our derived model lands nearby.
        assert!(l2_mx8 > 0.15 && l2_mx8 < 0.55, "l2_mx8 {l2_mx8}");
    }

    #[test]
    fn int_mac_monotone_in_bits() {
        assert!(int_mac_luts(4, 8) < int_mac_luts(8, 8));
        assert!(int_mac_luts(2, 8) < int_mac_luts(4, 8));
        assert!(int_mac_luts(6, 6) < int_mac_luts(8, 8));
    }

    #[test]
    fn plan_derived_area_matches_legacy_builders() {
        // The typed-spec path must reproduce the method-name builders
        // exactly for every registry configuration.
        let legacy: Vec<(&str, PeArea)> = vec![
            ("fp16", fp16_pe()),
            ("gptq-w4", dequant_pe("gptq-w4")),
            ("awq-w4", dequant_pe("awq-w4")),
            ("rtn-w4", dequant_pe("rtn-w4")),
            ("awq-w2", dequant_pe("awq-w2")),
            ("clipq-w2", dequant_pe("clipq-w2")),
            ("llmint4", llmint4_pe()),
            ("smoothquant-w8a8", int_wa_pe("smoothquant-w8a8", 8, 8)),
            ("clipq-w6a6", int_wa_pe("clipq-w6a6", 6, 6)),
            ("mxint-w4a8", mxint_pe("mxint-w4a8", 4, 8)),
            ("mxint-w3a8", mxint_pe("mxint-w3a8", 3, 8)),
            ("lqer-w4a8", l2qer_pe("lqer-w4a8", 4, 8, true)),
            ("l2qer-w4a8", l2qer_pe("l2qer-w4a8", 4, 8, true)),
            ("l2qer-w4a6", l2qer_pe("l2qer-w4a6", 4, 6, true)),
            ("l2qer-w2a8", l2qer_pe("l2qer-w2a8", 2, 8, true)),
            ("l2qer-int-w4", l2qer_pe("l2qer-int-w4", 4, 8, false)),
            ("l2qer-int-w4a8", l2qer_pe("l2qer-int-w4a8", 4, 8, false)),
        ];
        for (name, want) in legacy {
            let got = area_for_method(name).unwrap();
            assert!(
                (got.total - want.total).abs() < 1e-9,
                "{name}: plan-derived {} != legacy {}",
                got.total,
                want.total
            );
            assert_eq!(got.components.len(), want.components.len(), "{name}");
        }
    }

    #[test]
    fn heterogeneous_plan_prices_widest_layer() {
        // A plan mixing MXINT4 (k=8) with an INT4 override must cost at
        // least as much as its widest per-layer engine.
        let mut plan = QuantSpec::from_method_name("l2qer-w4a8").unwrap();
        let mut int_ls = plan.default;
        int_ls.weight = WeightFormat::IntGroup { bits: 4, group: 128 };
        plan.overrides.push(crate::quant::spec::Override {
            pattern: "layers.*.wo".into(),
            spec: int_ls,
        });
        let whole = area_for_plan("het", &plan).unwrap();
        let mx_only = area_for_layer("mx", &plan.default).unwrap();
        let int_only = area_for_layer("int", &int_ls).unwrap();
        assert!((whole.total - mx_only.total.max(int_only.total)).abs()
                    < 1e-9);
        // INT arithmetic without the shared-exponent trick is larger.
        assert!(int_only.total > mx_only.total);
    }

    #[test]
    fn lowrank_factor_bits_change_the_engine() {
        // The factor GEMMs run at the plan's b_h: 4-bit factors shrink
        // matmul2/matmul3 vs the default 8-bit engine, and fp32 factors
        // have no integer-MAC model at all.
        let b8 = area_for_method("l2qer-w2a8").unwrap();
        let b4 = area_for_method("l2qer-w2a8-lr4").unwrap();
        assert!(b4.total < b8.total, "b4 {} !< b8 {}", b4.total, b8.total);
        assert!(area_for_method("l2qer-w2a8-lrfp").is_none());
    }

    #[test]
    fn every_registered_method_priced() {
        for m in [
            "fp16", "gptq-w4", "awq-w4", "rtn-w4", "llmint4",
            "smoothquant-w8a8", "clipq-w6a6", "mxint-w4a8", "lqer-w4a8",
            "l2qer-w4a8", "l2qer-w4a6", "l2qer-w2a8", "l2qer-int-w4",
            "l2qer-int-w4a8", "awq-w2", "clipq-w2",
        ] {
            let pe = area_for_method(m).unwrap_or_else(|| panic!("{m}"));
            assert!(pe.total > 0.0);
        }
        assert!(area_for_method("nope").is_none());
    }
}
