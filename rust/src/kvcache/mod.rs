//! Slot-based KV-cache manager for batched decode.
//!
//! The decode graph is shape-specialized to a batch bucket `B`; the engine
//! owns one `KvCache` per bucket holding host-side key/value arrays of
//! shape (L, B, T_max, d) plus per-slot occupancy.  Sequences claim a slot
//! at admission, fill positions `0..len` from the prefill outputs, append
//! one row per decode step, and release the slot at completion.
//!
//! Invariants (property-tested in rust/tests/proptests.rs):
//! * a slot is never double-allocated or double-freed,
//! * `pos(slot) <= t_max` always; append past `t_max` is rejected,
//! * freeing zeroes occupancy so the scheduler's accounting stays exact.

use anyhow::Result;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Slot {
    Free,
    Active { request_id: u64, pos: usize },
}

#[derive(Debug)]
pub struct KvCache {
    pub layers: usize,
    pub t_max: usize,
    pub d: usize,
    pub batch: usize,
    k: Vec<f32>,
    v: Vec<f32>,
    slots: Vec<Slot>,
}

impl KvCache {
    pub fn new(layers: usize, batch: usize, t_max: usize, d: usize) -> Self {
        let n = layers * batch * t_max * d;
        KvCache {
            layers,
            t_max,
            d,
            batch,
            k: vec![0.0; n],
            v: vec![0.0; n],
            slots: vec![Slot::Free; batch],
        }
    }

    #[inline]
    fn idx(&self, layer: usize, slot: usize, t: usize) -> usize {
        ((layer * self.batch + slot) * self.t_max + t) * self.d
    }

    pub fn k_data(&self) -> &[f32] {
        &self.k
    }

    pub fn v_data(&self) -> &[f32] {
        &self.v
    }

    pub fn slots(&self) -> &[Slot] {
        &self.slots
    }

    pub fn free_count(&self) -> usize {
        self.slots.iter().filter(|s| matches!(s, Slot::Free)).count()
    }

    pub fn active_slots(&self) -> Vec<usize> {
        (0..self.batch)
            .filter(|&i| matches!(self.slots[i], Slot::Active { .. }))
            .collect()
    }

    pub fn pos(&self, slot: usize) -> usize {
        match self.slots[slot] {
            Slot::Active { pos, .. } => pos,
            Slot::Free => 0,
        }
    }

    pub fn request_id(&self, slot: usize) -> Option<u64> {
        match self.slots[slot] {
            Slot::Active { request_id, .. } => Some(request_id),
            Slot::Free => None,
        }
    }

    /// Claim a free slot for a request.
    pub fn alloc(&mut self, request_id: u64) -> Option<usize> {
        let slot = self.slots.iter().position(|s| matches!(s, Slot::Free))?;
        self.slots[slot] = Slot::Active { request_id, pos: 0 };
        Some(slot)
    }

    /// Release a slot (panics on double-free: that is a scheduler bug).
    pub fn free(&mut self, slot: usize) {
        assert!(
            matches!(self.slots[slot], Slot::Active { .. }),
            "double free of slot {slot}"
        );
        self.slots[slot] = Slot::Free;
    }

    /// Copy prefill K/V (shape (L, 1, t, d) row-major) into a slot and set
    /// its position to `len` (`len <= t`: right-padded prefill).
    pub fn write_prefill(
        &mut self,
        slot: usize,
        k_pre: &[f32],
        v_pre: &[f32],
        t: usize,
        len: usize,
    ) -> Result<()> {
        anyhow::ensure!(len <= t && len <= self.t_max, "prefill len {len}");
        anyhow::ensure!(
            k_pre.len() == self.layers * t * self.d,
            "prefill kv size {} != {}",
            k_pre.len(),
            self.layers * t * self.d
        );
        for l in 0..self.layers {
            let src = l * t * self.d;
            let dst = self.idx(l, slot, 0);
            let n = len * self.d;
            self.k[dst..dst + n].copy_from_slice(&k_pre[src..src + n]);
            self.v[dst..dst + n].copy_from_slice(&v_pre[src..src + n]);
        }
        match &mut self.slots[slot] {
            Slot::Active { pos, .. } => *pos = len,
            Slot::Free => anyhow::bail!("prefill into free slot"),
        }
        Ok(())
    }

    /// Append one decode step's K/V rows (shape (L, B, d)) for the given
    /// slots, advancing each slot's position.
    pub fn append_rows(
        &mut self,
        slots: &[usize],
        k_new: &[f32],
        v_new: &[f32],
    ) -> Result<()> {
        anyhow::ensure!(
            k_new.len() == self.layers * self.batch * self.d,
            "k_new size"
        );
        for &slot in slots {
            let pos = self.pos(slot);
            anyhow::ensure!(pos < self.t_max, "slot {slot} cache overflow");
            for l in 0..self.layers {
                let src = (l * self.batch + slot) * self.d;
                let dst = self.idx(l, slot, pos);
                self.k[dst..dst + self.d]
                    .copy_from_slice(&k_new[src..src + self.d]);
                self.v[dst..dst + self.d]
                    .copy_from_slice(&v_new[src..src + self.d]);
            }
            match &mut self.slots[slot] {
                Slot::Active { pos, .. } => *pos += 1,
                Slot::Free => anyhow::bail!("append into free slot"),
            }
        }
        Ok(())
    }

    /// Position vector (length B) for the decode graph.
    pub fn pos_vector(&self) -> Vec<i32> {
        (0..self.batch).map(|i| self.pos(i) as i32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache() -> KvCache {
        KvCache::new(2, 3, 8, 4)
    }

    #[test]
    fn alloc_until_full_then_none() {
        let mut c = cache();
        assert_eq!(c.free_count(), 3);
        let a = c.alloc(1).unwrap();
        let b = c.alloc(2).unwrap();
        let d = c.alloc(3).unwrap();
        assert_eq!(c.free_count(), 0);
        assert!(c.alloc(4).is_none());
        assert_ne!(a, b);
        assert_ne!(b, d);
        c.free(b);
        assert_eq!(c.alloc(5).unwrap(), b);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut c = cache();
        let s = c.alloc(1).unwrap();
        c.free(s);
        c.free(s);
    }

    #[test]
    fn prefill_sets_pos_and_copies() {
        let mut c = cache();
        let s = c.alloc(7).unwrap();
        let t = 4;
        let n = 2 * t * 4; // L * t * d
        let k: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..n).map(|i| (i as f32) * 10.0).collect();
        c.write_prefill(s, &k, &v, t, 3).unwrap();
        assert_eq!(c.pos(s), 3);
        // layer 1, position 2, feature 1:
        let src = (1 * t + 2) * 4 + 1;
        let dst = c.idx(1, s, 2) + 1;
        assert_eq!(c.k[dst], k[src]);
        assert_eq!(c.v[dst], v[src]);
    }

    #[test]
    fn append_advances_and_overflows() {
        let mut c = cache();
        let s = c.alloc(1).unwrap();
        let kn = vec![1.0f32; 2 * 3 * 4];
        let vn = vec![2.0f32; 2 * 3 * 4];
        for i in 0..8 {
            assert_eq!(c.pos(s), i);
            c.append_rows(&[s], &kn, &vn).unwrap();
        }
        assert!(c.append_rows(&[s], &kn, &vn).is_err(), "overflow");
    }

    #[test]
    fn pos_vector_covers_all_slots() {
        let mut c = cache();
        let s = c.alloc(1).unwrap();
        let kn = vec![0.0f32; 2 * 3 * 4];
        c.append_rows(&[s], &kn, &kn).unwrap();
        let pv = c.pos_vector();
        assert_eq!(pv.len(), 3);
        assert_eq!(pv[s], 1);
    }
}
