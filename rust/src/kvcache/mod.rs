//! KV-cache management for batched decode, split into two concerns
//! (DESIGN.md §6):
//!
//! * [`SlotMap`] — the pure slot/position manager.  It owns *no* tensor
//!   data; it tracks which batch lane belongs to which request and how
//!   many cache rows are valid per lane.  Both cache backings (the
//!   device-resident [`crate::runtime::DeviceKvSession`] and the host
//!   mirror below) are driven by one `SlotMap` on the engine thread.
//! * [`HostKvMirror`] — host-side key/value arrays of shape
//!   (L, B, T_max, d).  On the serving path this is only used when the
//!   legacy host-cache mode is selected (`EngineConfig::host_cache`,
//!   the bit-exactness oracle); eval and tests use it directly.
//!
//! [`KvCache`] is the legacy façade combining both with the original
//! API; existing tests and the microbench keep working against it.
//!
//! Invariants (property-tested in rust/tests/proptests.rs and
//! rust/tests/device_cache.rs):
//! * a slot is never double-allocated or double-freed,
//! * `pos(slot) <= t_max` always; append past `t_max` is rejected,
//! * freeing zeroes occupancy so the scheduler's accounting stays exact.

/// Block-granular pool: refcounted allocator, block tables, prefix
/// index, swap pool (DESIGN.md §10–§11).
pub mod paged;

use anyhow::Result;

/// One decode lane's occupancy: free, or owned by a request with
/// `pos` rows already written.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Slot {
    /// Unoccupied and claimable.
    Free,
    /// Owned by `request_id` with `pos` valid rows.
    Active { request_id: u64, pos: usize },
}

// ---------------------------------------------------------------------------
// SlotMap: occupancy + positions, no tensor data
// ---------------------------------------------------------------------------

/// Lane occupancy and write positions — the bookkeeping layer every
/// cache variant (flat, mirror, paged) shares; holds no tensor data.
#[derive(Debug, Clone)]
pub struct SlotMap {
    t_max: usize,
    slots: Vec<Slot>,
}

impl SlotMap {
    /// All-free map with `batch` lanes of `t_max` rows each.
    pub fn new(batch: usize, t_max: usize) -> Self {
        SlotMap { t_max, slots: vec![Slot::Free; batch] }
    }

    /// Number of lanes.
    pub fn batch(&self) -> usize {
        self.slots.len()
    }

    /// Row capacity per lane.
    pub fn t_max(&self) -> usize {
        self.t_max
    }

    /// Raw per-lane occupancy.
    pub fn slots(&self) -> &[Slot] {
        &self.slots
    }

    /// Lanes currently [`Slot::Free`].
    pub fn free_count(&self) -> usize {
        self.slots.iter().filter(|s| matches!(s, Slot::Free)).count()
    }

    /// Active lane indices, freshly collected (see
    /// [`Self::active_iter`] for the allocation-free form).
    pub fn active_slots(&self) -> Vec<usize> {
        self.active_iter().collect()
    }

    /// Active slot indices without allocating (hot path: `Engine::tick`
    /// used to build a fresh `Vec` per tick via [`Self::active_slots`]).
    pub fn active_iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, s)| matches!(s, Slot::Active { .. }))
            .map(|(i, _)| i)
    }

    /// Fill a caller-owned scratch buffer with the active slot indices
    /// (cleared first), reusing its capacity across ticks.
    pub fn active_into(&self, out: &mut Vec<usize>) {
        out.clear();
        out.extend(self.active_iter());
    }

    /// True when at least one lane is occupied.
    pub fn any_active(&self) -> bool {
        self.slots.iter().any(|s| matches!(s, Slot::Active { .. }))
    }

    /// Rows written in `slot` (0 for a free lane).
    pub fn pos(&self, slot: usize) -> usize {
        match self.slots[slot] {
            Slot::Active { pos, .. } => pos,
            Slot::Free => 0,
        }
    }

    /// Owner of `slot`, if occupied.
    pub fn request_id(&self, slot: usize) -> Option<u64> {
        match self.slots[slot] {
            Slot::Active { request_id, .. } => Some(request_id),
            Slot::Free => None,
        }
    }

    /// Claim a free slot for a request.
    pub fn alloc(&mut self, request_id: u64) -> Option<usize> {
        let slot = self.slots.iter().position(|s| matches!(s, Slot::Free))?;
        self.slots[slot] = Slot::Active { request_id, pos: 0 };
        Some(slot)
    }

    /// Release a slot (panics on double-free: that is a scheduler bug).
    pub fn free(&mut self, slot: usize) {
        assert!(
            matches!(self.slots[slot], Slot::Active { .. }),
            "double free of slot {slot}"
        );
        self.slots[slot] = Slot::Free;
    }

    /// Set a slot's position after prefill (`len` valid cache rows).
    pub fn set_pos(&mut self, slot: usize, len: usize) -> Result<()> {
        anyhow::ensure!(len <= self.t_max, "prefill len {len}");
        match &mut self.slots[slot] {
            Slot::Active { pos, .. } => *pos = len,
            Slot::Free => anyhow::bail!("prefill into free slot"),
        }
        Ok(())
    }

    /// Advance each listed slot by one appended row.
    pub fn advance(&mut self, slots: &[usize]) -> Result<()> {
        for &slot in slots {
            anyhow::ensure!(
                self.pos(slot) < self.t_max,
                "slot {slot} cache overflow"
            );
            match &mut self.slots[slot] {
                Slot::Active { pos, .. } => *pos += 1,
                Slot::Free => anyhow::bail!("append into free slot"),
            }
        }
        Ok(())
    }

    /// Position vector (length B) for the decode graphs.
    pub fn pos_vector(&self) -> Vec<i32> {
        let mut out = Vec::new();
        self.pos_into(&mut out);
        out
    }

    /// Fill a caller-owned position vector (cleared first), reusing its
    /// capacity across decode steps.
    pub fn pos_into(&self, out: &mut Vec<i32>) {
        out.clear();
        out.extend((0..self.slots.len()).map(|i| self.pos(i) as i32));
    }
}

// ---------------------------------------------------------------------------
// HostKvMirror: host-side cache arrays (legacy serving path, eval, tests)
// ---------------------------------------------------------------------------

/// Host-side K/V arrays (legacy serving path, eval, tests) with
/// right-padded prefill and per-row append writes.
#[derive(Debug)]
pub struct HostKvMirror {
    pub layers: usize,
    pub t_max: usize,
    pub d: usize,
    pub batch: usize,
    k: Vec<f32>,
    v: Vec<f32>,
}

impl HostKvMirror {
    /// Zeroed host K/V arrays of shape `(layers, batch, t_max, d)`.
    pub fn new(layers: usize, batch: usize, t_max: usize, d: usize) -> Self {
        let n = layers * batch * t_max * d;
        HostKvMirror {
            layers,
            t_max,
            d,
            batch,
            k: vec![0.0; n],
            v: vec![0.0; n],
        }
    }

    #[inline]
    fn idx(&self, layer: usize, slot: usize, t: usize) -> usize {
        ((layer * self.batch + slot) * self.t_max + t) * self.d
    }

    /// The K array, row-major `(layers, batch, t_max, d)`.
    pub fn k_data(&self) -> &[f32] {
        &self.k
    }

    /// The V array, same layout as [`Self::k_data`].
    pub fn v_data(&self) -> &[f32] {
        &self.v
    }

    /// Copy prefill K/V (shape (L, 1, t, d) row-major) into a slot
    /// (positions `0..len`, `len <= t`: right-padded prefill).
    pub fn write_prefill(
        &mut self,
        slot: usize,
        k_pre: &[f32],
        v_pre: &[f32],
        t: usize,
        len: usize,
    ) -> Result<()> {
        anyhow::ensure!(len <= t && len <= self.t_max, "prefill len {len}");
        anyhow::ensure!(
            k_pre.len() == self.layers * t * self.d,
            "prefill kv size {} != {}",
            k_pre.len(),
            self.layers * t * self.d
        );
        for l in 0..self.layers {
            let src = l * t * self.d;
            let dst = self.idx(l, slot, 0);
            let n = len * self.d;
            self.k[dst..dst + n].copy_from_slice(&k_pre[src..src + n]);
            self.v[dst..dst + n].copy_from_slice(&v_pre[src..src + n]);
        }
        Ok(())
    }

    /// Write one decode step's K/V rows (shape (L, B, d)) at the given
    /// (slot, position) pairs.
    pub fn append_rows(
        &mut self,
        rows: &[(usize, usize)],
        k_new: &[f32],
        v_new: &[f32],
    ) -> Result<()> {
        anyhow::ensure!(
            k_new.len() == self.layers * self.batch * self.d
                && v_new.len() == k_new.len(),
            "k_new size"
        );
        for &(slot, pos) in rows {
            anyhow::ensure!(pos < self.t_max, "slot {slot} cache overflow");
            for l in 0..self.layers {
                let src = (l * self.batch + slot) * self.d;
                let dst = self.idx(l, slot, pos);
                self.k[dst..dst + self.d]
                    .copy_from_slice(&k_new[src..src + self.d]);
                self.v[dst..dst + self.d]
                    .copy_from_slice(&v_new[src..src + self.d]);
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// KvCache: legacy façade (SlotMap + HostKvMirror, original API)
// ---------------------------------------------------------------------------

/// Legacy facade: [`SlotMap`] + [`HostKvMirror`] behind the original
/// pre-paged API.
#[derive(Debug)]
pub struct KvCache {
    pub layers: usize,
    pub t_max: usize,
    pub d: usize,
    pub batch: usize,
    slots: SlotMap,
    mirror: HostKvMirror,
}

impl KvCache {
    /// Fresh cache: all lanes free, mirrors zeroed.
    pub fn new(layers: usize, batch: usize, t_max: usize, d: usize) -> Self {
        KvCache {
            layers,
            t_max,
            d,
            batch,
            slots: SlotMap::new(batch, t_max),
            mirror: HostKvMirror::new(layers, batch, t_max, d),
        }
    }

    /// The host K mirror (see [`HostKvMirror::k_data`]).
    pub fn k_data(&self) -> &[f32] {
        self.mirror.k_data()
    }

    /// The host V mirror.
    pub fn v_data(&self) -> &[f32] {
        self.mirror.v_data()
    }

    /// Raw per-lane occupancy.
    pub fn slots(&self) -> &[Slot] {
        self.slots.slots()
    }

    /// Lanes currently [`Slot::Free`].
    pub fn free_count(&self) -> usize {
        self.slots.free_count()
    }

    /// Active lane indices.
    pub fn active_slots(&self) -> Vec<usize> {
        self.slots.active_slots()
    }

    /// Rows written in `slot` (0 for a free lane).
    pub fn pos(&self, slot: usize) -> usize {
        self.slots.pos(slot)
    }

    /// Owner of `slot`, if occupied.
    pub fn request_id(&self, slot: usize) -> Option<u64> {
        self.slots.request_id(slot)
    }

    /// Claim a free slot for a request.
    pub fn alloc(&mut self, request_id: u64) -> Option<usize> {
        self.slots.alloc(request_id)
    }

    /// Release a slot (panics on double-free: that is a scheduler bug).
    pub fn free(&mut self, slot: usize) {
        self.slots.free(slot);
    }

    /// Copy prefill K/V (shape (L, 1, t, d) row-major) into a slot and set
    /// its position to `len` (`len <= t`: right-padded prefill).
    pub fn write_prefill(
        &mut self,
        slot: usize,
        k_pre: &[f32],
        v_pre: &[f32],
        t: usize,
        len: usize,
    ) -> Result<()> {
        self.slots.set_pos(slot, len)?;
        self.mirror.write_prefill(slot, k_pre, v_pre, t, len)
    }

    /// Append one decode step's K/V rows (shape (L, B, d)) for the given
    /// slots, advancing each slot's position.
    pub fn append_rows(
        &mut self,
        slots: &[usize],
        k_new: &[f32],
        v_new: &[f32],
    ) -> Result<()> {
        anyhow::ensure!(
            k_new.len() == self.layers * self.batch * self.d
                && v_new.len() == k_new.len(),
            "k_new size"
        );
        let rows: Vec<(usize, usize)> =
            slots.iter().map(|&s| (s, self.slots.pos(s))).collect();
        // Validate occupancy/overflow first so a failed append leaves
        // both halves untouched.
        self.slots.advance(slots)?;
        self.mirror.append_rows(&rows, k_new, v_new)
    }

    /// Position vector (length B) for the decode graph.
    pub fn pos_vector(&self) -> Vec<i32> {
        self.slots.pos_vector()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache() -> KvCache {
        KvCache::new(2, 3, 8, 4)
    }

    #[test]
    fn alloc_until_full_then_none() {
        let mut c = cache();
        assert_eq!(c.free_count(), 3);
        let a = c.alloc(1).unwrap();
        let b = c.alloc(2).unwrap();
        let d = c.alloc(3).unwrap();
        assert_eq!(c.free_count(), 0);
        assert!(c.alloc(4).is_none());
        assert_ne!(a, b);
        assert_ne!(b, d);
        c.free(b);
        assert_eq!(c.alloc(5).unwrap(), b);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut c = cache();
        let s = c.alloc(1).unwrap();
        c.free(s);
        c.free(s);
    }

    #[test]
    fn prefill_sets_pos_and_copies() {
        let mut c = cache();
        let s = c.alloc(7).unwrap();
        let t = 4;
        let n = 2 * t * 4; // L * t * d
        let k: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..n).map(|i| (i as f32) * 10.0).collect();
        c.write_prefill(s, &k, &v, t, 3).unwrap();
        assert_eq!(c.pos(s), 3);
        // layer 1, position 2, feature 1:
        let src = (t + 2) * 4 + 1;
        let dst = ((c.batch + s) * c.t_max + 2) * c.d + 1; // idx(1, s, 2)+1
        assert_eq!(c.k_data()[dst], k[src]);
        assert_eq!(c.v_data()[dst], v[src]);
    }

    #[test]
    fn prefill_into_free_slot_rejected() {
        let mut c = cache();
        let k = vec![0.0f32; 2 * 4 * 4];
        assert!(c.write_prefill(0, &k, &k, 4, 2).is_err());
    }

    #[test]
    fn append_advances_and_overflows() {
        let mut c = cache();
        let s = c.alloc(1).unwrap();
        let kn = vec![1.0f32; 2 * 3 * 4];
        let vn = vec![2.0f32; 2 * 3 * 4];
        for i in 0..8 {
            assert_eq!(c.pos(s), i);
            c.append_rows(&[s], &kn, &vn).unwrap();
        }
        assert!(c.append_rows(&[s], &kn, &vn).is_err(), "overflow");
    }

    #[test]
    fn pos_vector_covers_all_slots() {
        let mut c = cache();
        let s = c.alloc(1).unwrap();
        let kn = vec![0.0f32; 2 * 3 * 4];
        c.append_rows(&[s], &kn, &kn).unwrap();
        let pv = c.pos_vector();
        assert_eq!(pv.len(), 3);
        assert_eq!(pv[s], 1);
    }

    #[test]
    fn slotmap_set_pos_and_advance_guard_bounds() {
        let mut m = SlotMap::new(2, 4);
        assert!(m.set_pos(0, 1).is_err(), "free slot");
        let s = m.alloc(9).unwrap();
        assert!(m.set_pos(s, 5).is_err(), "past t_max");
        m.set_pos(s, 4).unwrap();
        assert!(m.advance(&[s]).is_err(), "overflow");
        m.set_pos(s, 3).unwrap();
        m.advance(&[s]).unwrap();
        assert_eq!(m.pos(s), 4);
        assert_eq!(m.request_id(s), Some(9));
    }

    #[test]
    fn active_into_reuses_buffer_and_matches_active_slots() {
        let mut m = SlotMap::new(3, 4);
        assert!(!m.any_active());
        let a = m.alloc(1).unwrap();
        let b = m.alloc(2).unwrap();
        assert!(m.any_active());
        let mut buf = vec![99usize; 8]; // stale contents must be cleared
        m.active_into(&mut buf);
        assert_eq!(buf, m.active_slots());
        m.free(a);
        m.active_into(&mut buf);
        assert_eq!(buf, vec![b]);
    }

    #[test]
    fn mirror_append_rows_places_rows() {
        let (layers, batch, t_max, d) = (2, 3, 8, 4);
        let mut m = HostKvMirror::new(layers, batch, t_max, d);
        let mut kn = vec![0.0f32; layers * batch * d];
        // distinct values for slot 1's rows in both layers
        for l in 0..layers {
            for j in 0..d {
                kn[(l * batch + 1) * d + j] = (10 * l + j) as f32 + 0.5;
            }
        }
        m.append_rows(&[(1, 6)], &kn, &kn).unwrap();
        for l in 0..layers {
            for j in 0..d {
                let at = ((l * batch + 1) * t_max + 6) * d + j;
                assert_eq!(m.k_data()[at], (10 * l + j) as f32 + 0.5);
            }
        }
        assert!(m.append_rows(&[(0, 8)], &kn, &kn).is_err(), "past t_max");
    }
}
