//! Paged KV cache: block-granular allocation over a shared pool
//! (DESIGN.md §10).
//!
//! The flat [`super::HostKvMirror`] reserves a full `T_max`-row lane per
//! sequence, so a 12-token decode strands `T_max - 12` rows and admission
//! capacity is `batch`, not memory.  This module splits storage into
//! fixed-size blocks of `block_size` token rows (vLLM-style):
//!
//! * [`BlockAllocator`] — free-list over the block pool.  Block 0 is the
//!   **sentinel**: never handed out, it is where the device DUS lattice
//!   parks the dead writes of free lanes (the flat `decode_dev` graph
//!   wrote those into the lane's own region; a paged graph needs a
//!   harmless physical target).  Usable capacity is `num_blocks - 1`.
//! * [`BlockTable`] — one sequence's ordered block list.  Logical row
//!   `r` lives at `(blocks[r / block_size], r % block_size)`.
//! * [`PagedHostKv`] — host K/V arrays of shape
//!   `(L, num_blocks, block_size, d)` addressed through block tables;
//!   the paged twin of `HostKvMirror`.
//!
//! Invariants (property-tested in rust/tests/proptests.rs):
//! * a block is never double-allocated and never handed out twice
//!   without an intervening free,
//! * the sentinel is never allocated,
//! * freeing every table returns the allocator to full capacity,
//! * every table row maps to a block owned by that table.

use anyhow::Result;

/// Physical block id reserved for dead writes (never allocated).
pub const SENTINEL_BLOCK: u32 = 0;

// ---------------------------------------------------------------------------
// BlockAllocator: free-list over the block pool
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct BlockAllocator {
    block_size: usize,
    /// Free-list (stack). Never contains the sentinel.
    free: Vec<u32>,
    /// Occupancy by block id; the sentinel reads as allocated forever.
    allocated: Vec<bool>,
}

impl BlockAllocator {
    /// Pool of `num_blocks` blocks of `block_size` rows each.  Block 0 is
    /// reserved as the sentinel, so usable capacity is `num_blocks - 1`.
    pub fn new(num_blocks: usize, block_size: usize) -> Self {
        assert!(num_blocks >= 2, "need at least one usable block");
        assert!(block_size >= 1, "block_size must be positive");
        let mut allocated = vec![false; num_blocks];
        allocated[SENTINEL_BLOCK as usize] = true;
        // LIFO over descending ids => first alloc returns block 1.
        let free: Vec<u32> = (1..num_blocks as u32).rev().collect();
        BlockAllocator { block_size, free, allocated }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// Total pool size including the sentinel.
    pub fn num_blocks(&self) -> usize {
        self.allocated.len()
    }

    /// Usable blocks (excludes the sentinel).
    pub fn capacity(&self) -> usize {
        self.allocated.len() - 1
    }

    pub fn free_count(&self) -> usize {
        self.free.len()
    }

    pub fn in_use(&self) -> usize {
        self.capacity() - self.free.len()
    }

    /// Fraction of usable blocks currently allocated.
    pub fn utilization(&self) -> f64 {
        if self.capacity() == 0 {
            0.0
        } else {
            self.in_use() as f64 / self.capacity() as f64
        }
    }

    /// Blocks needed to hold `rows` token rows.
    pub fn blocks_for_rows(&self, rows: usize) -> usize {
        rows.div_ceil(self.block_size)
    }

    /// Usable capacity in token rows.
    pub fn capacity_rows(&self) -> usize {
        self.capacity() * self.block_size
    }

    pub fn alloc(&mut self) -> Option<u32> {
        let id = self.free.pop()?;
        debug_assert!(!self.allocated[id as usize], "free-list corruption");
        self.allocated[id as usize] = true;
        Some(id)
    }

    /// Return a block (panics on double-free or sentinel: scheduler bug).
    pub fn free(&mut self, id: u32) {
        assert_ne!(id, SENTINEL_BLOCK, "freed the sentinel block");
        assert!(
            self.allocated[id as usize],
            "double free of block {id}"
        );
        self.allocated[id as usize] = false;
        self.free.push(id);
    }
}

// ---------------------------------------------------------------------------
// BlockTable: one sequence's logical-row -> physical-block mapping
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Default)]
pub struct BlockTable {
    blocks: Vec<u32>,
}

impl BlockTable {
    pub fn new() -> Self {
        BlockTable { blocks: Vec::new() }
    }

    pub fn blocks(&self) -> &[u32] {
        &self.blocks
    }

    pub fn len(&self) -> usize {
        self.blocks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.blocks.is_empty()
    }

    pub fn push(&mut self, id: u32) {
        self.blocks.push(id);
    }

    /// Rows addressable through this table.
    pub fn capacity_rows(&self, block_size: usize) -> usize {
        self.blocks.len() * block_size
    }

    /// Physical (block, offset) of logical row `row`, if mapped.
    pub fn physical(&self, row: usize, block_size: usize)
        -> Option<(u32, usize)> {
        self.blocks
            .get(row / block_size)
            .map(|&b| (b, row % block_size))
    }

    /// Drain the table for freeing (the caller returns each id to the
    /// allocator); leaves an empty table behind.
    pub fn take_blocks(&mut self) -> Vec<u32> {
        std::mem::take(&mut self.blocks)
    }
}

// ---------------------------------------------------------------------------
// PagedHostKv: block-pool K/V storage addressed through tables
// ---------------------------------------------------------------------------

/// Host K/V arrays of shape `(L, num_blocks, block_size, d)`.  The paged
/// twin of [`super::HostKvMirror`]: rows are addressed through a
/// [`BlockTable`] instead of a flat `(lane, t)` pair.  Pure storage —
/// allocation policy lives in [`BlockAllocator`], scheduling in the
/// engine.
#[derive(Debug)]
pub struct PagedHostKv {
    pub layers: usize,
    pub d: usize,
    block_size: usize,
    num_blocks: usize,
    k: Vec<f32>,
    v: Vec<f32>,
}

impl PagedHostKv {
    pub fn new(
        layers: usize,
        num_blocks: usize,
        block_size: usize,
        d: usize,
    ) -> Self {
        let n = layers * num_blocks * block_size * d;
        PagedHostKv {
            layers,
            d,
            block_size,
            num_blocks,
            k: vec![0.0; n],
            v: vec![0.0; n],
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn num_blocks(&self) -> usize {
        self.num_blocks
    }

    pub fn k_data(&self) -> &[f32] {
        &self.k
    }

    pub fn v_data(&self) -> &[f32] {
        &self.v
    }

    #[inline]
    fn idx(&self, layer: usize, block: u32, off: usize) -> usize {
        ((layer * self.num_blocks + block as usize) * self.block_size
            + off)
            * self.d
    }

    /// Raw K/V rows at a physical (layer, block, offset) — lets test
    /// backends share this pool's layout instead of re-implementing
    /// the index math.
    pub fn rows_at(&self, layer: usize, block: u32, off: usize)
        -> (&[f32], &[f32]) {
        let i = self.idx(layer, block, off);
        (&self.k[i..i + self.d], &self.v[i..i + self.d])
    }

    /// Mutable twin of [`Self::rows_at`].
    pub fn rows_at_mut(&mut self, layer: usize, block: u32, off: usize)
        -> (&mut [f32], &mut [f32]) {
        let i = self.idx(layer, block, off);
        let d = self.d;
        (&mut self.k[i..i + d], &mut self.v[i..i + d])
    }

    fn physical(&self, table: &BlockTable, row: usize)
        -> Result<(u32, usize)> {
        table.physical(row, self.block_size).ok_or_else(|| {
            anyhow::anyhow!(
                "row {row} beyond table capacity {}",
                table.capacity_rows(self.block_size)
            )
        })
    }

    /// Copy prefill K/V (shape (L, 1, t, d) row-major) into a sequence's
    /// blocks (logical rows `0..len`, `len <= t`: right-padded prefill).
    pub fn write_prefill(
        &mut self,
        table: &BlockTable,
        k_pre: &[f32],
        v_pre: &[f32],
        t: usize,
        len: usize,
    ) -> Result<()> {
        anyhow::ensure!(len <= t, "prefill len {len} > bucket {t}");
        anyhow::ensure!(
            k_pre.len() == self.layers * t * self.d
                && v_pre.len() == k_pre.len(),
            "prefill kv size {} != {}",
            k_pre.len(),
            self.layers * t * self.d
        );
        for row in 0..len {
            let (block, off) = self.physical(table, row)?;
            for l in 0..self.layers {
                let src = (l * t + row) * self.d;
                let dst = self.idx(l, block, off);
                self.k[dst..dst + self.d]
                    .copy_from_slice(&k_pre[src..src + self.d]);
                self.v[dst..dst + self.d]
                    .copy_from_slice(&v_pre[src..src + self.d]);
            }
        }
        Ok(())
    }

    /// Write one decode step's K/V row for batch lane `lane` (out of
    /// `batch`; `k_new`/`v_new` are (L, batch, d)) at logical row `row`
    /// of the sequence mapped by `table`.
    pub fn append_row(
        &mut self,
        table: &BlockTable,
        row: usize,
        lane: usize,
        batch: usize,
        k_new: &[f32],
        v_new: &[f32],
    ) -> Result<()> {
        anyhow::ensure!(
            k_new.len() == self.layers * batch * self.d
                && v_new.len() == k_new.len(),
            "k_new size"
        );
        let (block, off) = self.physical(table, row)?;
        for l in 0..self.layers {
            let src = (l * batch + lane) * self.d;
            let dst = self.idx(l, block, off);
            self.k[dst..dst + self.d]
                .copy_from_slice(&k_new[src..src + self.d]);
            self.v[dst..dst + self.d]
                .copy_from_slice(&v_new[src..src + self.d]);
        }
        Ok(())
    }

    /// Gather a sequence's first `rows` logical rows into flat
    /// `(L, batch, t_max, d)` buffers at lane `lane` — the bridge that
    /// lets the legacy flat decode graph (the bit-exactness oracle) run
    /// on paged storage.
    #[allow(clippy::too_many_arguments)]
    pub fn gather_lane(
        &self,
        table: &BlockTable,
        rows: usize,
        lane: usize,
        batch: usize,
        t_max: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) -> Result<()> {
        anyhow::ensure!(rows <= t_max, "gather rows {rows} > t_max");
        anyhow::ensure!(
            k_out.len() == self.layers * batch * t_max * self.d
                && v_out.len() == k_out.len(),
            "gather output size"
        );
        for row in 0..rows {
            let (block, off) = self.physical(table, row)?;
            for l in 0..self.layers {
                let src = self.idx(l, block, off);
                let dst = ((l * batch + lane) * t_max + row) * self.d;
                k_out[dst..dst + self.d]
                    .copy_from_slice(&self.k[src..src + self.d]);
                v_out[dst..dst + self.d]
                    .copy_from_slice(&self.v[src..src + self.d]);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocator_reserves_sentinel_and_tracks_counts() {
        let mut a = BlockAllocator::new(4, 8);
        assert_eq!(a.capacity(), 3);
        assert_eq!(a.free_count(), 3);
        assert_eq!(a.in_use(), 0);
        let b1 = a.alloc().unwrap();
        let b2 = a.alloc().unwrap();
        let b3 = a.alloc().unwrap();
        assert!(a.alloc().is_none(), "pool exhausted");
        for b in [b1, b2, b3] {
            assert_ne!(b, SENTINEL_BLOCK);
        }
        assert_eq!(a.in_use(), 3);
        assert!((a.utilization() - 1.0).abs() < 1e-12);
        a.free(b2);
        assert_eq!(a.alloc().unwrap(), b2, "LIFO reuse");
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn allocator_double_free_panics() {
        let mut a = BlockAllocator::new(3, 4);
        let b = a.alloc().unwrap();
        a.free(b);
        a.free(b);
    }

    #[test]
    #[should_panic(expected = "sentinel")]
    fn allocator_rejects_sentinel_free() {
        let mut a = BlockAllocator::new(3, 4);
        a.free(SENTINEL_BLOCK);
    }

    #[test]
    fn blocks_for_rows_is_ceil() {
        let a = BlockAllocator::new(8, 4);
        assert_eq!(a.blocks_for_rows(0), 0);
        assert_eq!(a.blocks_for_rows(1), 1);
        assert_eq!(a.blocks_for_rows(4), 1);
        assert_eq!(a.blocks_for_rows(5), 2);
    }

    #[test]
    fn table_maps_rows_to_block_offsets() {
        let mut t = BlockTable::new();
        t.push(3);
        t.push(7);
        assert_eq!(t.capacity_rows(4), 8);
        assert_eq!(t.physical(0, 4), Some((3, 0)));
        assert_eq!(t.physical(3, 4), Some((3, 3)));
        assert_eq!(t.physical(4, 4), Some((7, 0)));
        assert_eq!(t.physical(8, 4), None);
        let drained = t.take_blocks();
        assert_eq!(drained, vec![3, 7]);
        assert!(t.is_empty());
    }

    #[test]
    fn paged_store_roundtrips_against_flat_mirror() {
        // Write the same prefill + appended rows into the flat mirror and
        // the paged store (through a non-trivial table), then gather the
        // paged lane back: both must hold identical bytes.
        let (layers, batch, t_max, d, bs) = (2, 3, 8, 4, 4);
        let mut flat = super::super::HostKvMirror::new(
            layers, batch, t_max, d);
        let mut paged = PagedHostKv::new(layers, 6, bs, d);
        let mut table = BlockTable::new();
        table.push(4); // deliberately out-of-order physical blocks
        table.push(2);

        let t = 6;
        let len = 5;
        let n = layers * t * d;
        let k_pre: Vec<f32> = (0..n).map(|i| i as f32 * 0.5).collect();
        let v_pre: Vec<f32> = (0..n).map(|i| i as f32 - 7.0).collect();
        let lane = 1;
        flat.write_prefill(lane, &k_pre, &v_pre, t, len).unwrap();
        paged.write_prefill(&table, &k_pre, &v_pre, t, len).unwrap();

        let m = layers * batch * d;
        let k_new: Vec<f32> = (0..m).map(|i| 100.0 + i as f32).collect();
        let v_new: Vec<f32> = (0..m).map(|i| -(i as f32)).collect();
        flat.append_rows(&[(lane, len)], &k_new, &v_new).unwrap();
        paged
            .append_row(&table, len, lane, batch, &k_new, &v_new)
            .unwrap();

        let sz = layers * batch * t_max * d;
        let (mut gk, mut gv) = (vec![0.0f32; sz], vec![0.0f32; sz]);
        paged
            .gather_lane(&table, len + 1, lane, batch, t_max, &mut gk,
                         &mut gv)
            .unwrap();
        for l in 0..layers {
            for row in 0..len + 1 {
                for j in 0..d {
                    let at = ((l * batch + lane) * t_max + row) * d + j;
                    assert_eq!(gk[at], flat.k_data()[at], "k l{l} r{row}");
                    assert_eq!(gv[at], flat.v_data()[at], "v l{l} r{row}");
                }
            }
        }
    }

    #[test]
    fn paged_store_rejects_unmapped_rows() {
        let mut p = PagedHostKv::new(1, 3, 4, 2);
        let mut table = BlockTable::new();
        table.push(1);
        let k = vec![0.0f32; 8 * 2];
        // prefill longer than the table's 4 rows
        assert!(p.write_prefill(&table, &k, &k, 8, 5).is_err());
        let row = vec![0.0f32; 2 * 2];
        assert!(p.append_row(&table, 4, 0, 2, &row, &row).is_err());
    }
}
