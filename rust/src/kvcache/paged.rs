//! Paged KV cache: block-granular allocation over a shared pool
//! (DESIGN.md §10), plus the shared-block policies layered on top of it
//! (DESIGN.md §11).
//!
//! The flat [`super::HostKvMirror`] reserves a full `T_max`-row lane per
//! sequence, so a 12-token decode strands `T_max - 12` rows and admission
//! capacity is `batch`, not memory.  This module splits storage into
//! fixed-size blocks of `block_size` token rows (vLLM-style):
//!
//! * [`BlockAllocator`] — **refcounted** free-list over the block pool.
//!   Block 0 is the **sentinel**: never handed out, it is where the
//!   device DUS lattice parks the dead writes of free lanes (the flat
//!   `decode_dev` graph wrote those into the lane's own region; a paged
//!   graph needs a harmless physical target).  Usable capacity is
//!   `num_blocks - 1`.  A block with refcount > 1 is *shared*: mapped
//!   read-only into several tables; writers must copy-on-write first.
//! * [`BlockTable`] — one sequence's ordered block list.  Logical row
//!   `r` lives at `(blocks[r / block_size], r % block_size)`.
//! * [`PrefixIndex`] — content-addressed map from token prefixes to the
//!   block holding their K/V rows, so admission can map a block-aligned
//!   shared prompt prefix instead of recomputing and re-storing it.
//!   Entries survive the owning sequence (recently-freed blocks are
//!   *revived* from the free list on a hit) until the block is
//!   reallocated for new content.
//! * [`PagedHostKv`] — host K/V arrays of shape
//!   `(L, num_blocks, block_size, d)` addressed through block tables;
//!   the paged twin of `HostKvMirror`.  Also provides the whole-block
//!   copy/export/import primitives behind COW forks and block-level
//!   swap.
//! * [`SwapPool`] — accounting for a bounded host-side swap area:
//!   preemption copies a sequence's blocks out instead of discarding
//!   them for re-prefill (the engine stores the bytes, this tracks the
//!   bound).
//!
//! Invariants (property-tested in rust/tests/proptests.rs):
//! * a block is never double-allocated and never handed out twice
//!   without an intervening free,
//! * a block is never returned to the free list while its refcount is
//!   nonzero; copy-on-write never mutates a shared block,
//! * the sentinel is never allocated,
//! * freeing every table returns the allocator to full capacity,
//! * every table row maps to a block owned by that table,
//! * block export/import round-trips bytes exactly.

use std::collections::HashMap;

use anyhow::Result;

/// Physical block id reserved for dead writes (never allocated).
pub const SENTINEL_BLOCK: u32 = 0;

// ---------------------------------------------------------------------------
// BlockAllocator: free-list over the block pool
// ---------------------------------------------------------------------------

/// `pos_in_free` marker for "not in the free list".
const NOT_FREE: u32 = u32::MAX;

#[derive(Debug, Clone)]
pub struct BlockAllocator {
    block_size: usize,
    /// Free-list (stack) of refcount-0 blocks. Never contains the
    /// sentinel.
    free: Vec<u32>,
    /// Reference count by block id; the sentinel is pinned at 1 forever.
    /// `alloc` hands a block out at refcount 1, [`Self::retain`] maps it
    /// into another table (prefix sharing), [`Self::free`] drops one
    /// reference and only returns the block to the free list at zero.
    refcount: Vec<u32>,
    /// Index of each block inside `free` ([`NOT_FREE`] when allocated) —
    /// keeps [`Self::revive`] O(1) instead of scanning the free list
    /// per prefix hit on the admission path.
    pos_in_free: Vec<u32>,
}

impl BlockAllocator {
    /// Pool of `num_blocks` blocks of `block_size` rows each.  Block 0 is
    /// reserved as the sentinel, so usable capacity is `num_blocks - 1`.
    pub fn new(num_blocks: usize, block_size: usize) -> Self {
        assert!(num_blocks >= 2, "need at least one usable block");
        assert!(block_size >= 1, "block_size must be positive");
        let mut refcount = vec![0u32; num_blocks];
        refcount[SENTINEL_BLOCK as usize] = 1;
        // LIFO over descending ids => first alloc returns block 1.
        let free: Vec<u32> = (1..num_blocks as u32).rev().collect();
        let mut pos_in_free = vec![NOT_FREE; num_blocks];
        for (at, &id) in free.iter().enumerate() {
            pos_in_free[id as usize] = at as u32;
        }
        BlockAllocator { block_size, free, refcount, pos_in_free }
    }

    /// Token rows per block.
    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// Total pool size including the sentinel.
    pub fn num_blocks(&self) -> usize {
        self.refcount.len()
    }

    /// Usable blocks (excludes the sentinel).
    pub fn capacity(&self) -> usize {
        self.refcount.len() - 1
    }

    /// Blocks on the free list (refcount 0, claimable or revivable).
    pub fn free_count(&self) -> usize {
        self.free.len()
    }

    /// Usable blocks with refcount >= 1.
    pub fn in_use(&self) -> usize {
        self.capacity() - self.free.len()
    }

    /// Fraction of usable blocks currently allocated.
    pub fn utilization(&self) -> f64 {
        if self.capacity() == 0 {
            0.0
        } else {
            self.in_use() as f64 / self.capacity() as f64
        }
    }

    /// Blocks needed to hold `rows` token rows.
    pub fn blocks_for_rows(&self, rows: usize) -> usize {
        rows.div_ceil(self.block_size)
    }

    /// Usable capacity in token rows.
    pub fn capacity_rows(&self) -> usize {
        self.capacity() * self.block_size
    }

    /// Claim a free block (LIFO) at refcount 1, or `None` on a dry
    /// pool.
    pub fn alloc(&mut self) -> Option<u32> {
        let id = self.free.pop()?;
        debug_assert_eq!(
            self.refcount[id as usize], 0,
            "free-list corruption"
        );
        self.refcount[id as usize] = 1;
        self.pos_in_free[id as usize] = NOT_FREE;
        Some(id)
    }

    /// Drop one reference to a block; it returns to the free list only
    /// when the last reference is gone (panics on refcount underflow or
    /// sentinel: scheduler bug).
    pub fn free(&mut self, id: u32) {
        assert_ne!(id, SENTINEL_BLOCK, "freed the sentinel block");
        assert!(
            self.refcount[id as usize] > 0,
            "double free of block {id}"
        );
        self.refcount[id as usize] -= 1;
        if self.refcount[id as usize] == 0 {
            self.pos_in_free[id as usize] = self.free.len() as u32;
            self.free.push(id);
        }
    }

    /// Map a live block into one more table (prefix sharing / COW fork
    /// source).  Panics on the sentinel or a free block: the caller must
    /// [`Self::revive`] those instead.
    pub fn retain(&mut self, id: u32) {
        assert_ne!(id, SENTINEL_BLOCK, "retained the sentinel block");
        assert!(
            self.refcount[id as usize] > 0,
            "retain of free block {id}"
        );
        self.refcount[id as usize] += 1;
    }

    /// Pull a *recently-freed* block (refcount 0, still holding its old
    /// contents) back out of the free list at refcount 1 — the prefix
    /// index hit path for blocks whose owner already finished.  Returns
    /// false if the block is not currently free.  O(1): the free list
    /// tracks each member's slot, and the swap-removed tail member is
    /// re-pointed.
    pub fn revive(&mut self, id: u32) -> bool {
        if id == SENTINEL_BLOCK || self.refcount[id as usize] != 0 {
            return false;
        }
        let at = self.pos_in_free[id as usize];
        if at == NOT_FREE {
            return false;
        }
        let at = at as usize;
        debug_assert_eq!(self.free[at], id, "free-list position drift");
        self.free.swap_remove(at);
        if at < self.free.len() {
            self.pos_in_free[self.free[at] as usize] = at as u32;
        }
        self.pos_in_free[id as usize] = NOT_FREE;
        self.refcount[id as usize] = 1;
        true
    }

    /// Current reference count of a block (sentinel reads as 1).
    pub fn ref_count(&self, id: u32) -> u32 {
        self.refcount[id as usize]
    }

    /// A shared block is mapped into more than one table: read-only, a
    /// writer must copy-on-write first.
    pub fn is_shared(&self, id: u32) -> bool {
        self.refcount[id as usize] > 1
    }

    /// Number of usable blocks currently mapped into >1 table.
    pub fn shared_blocks(&self) -> usize {
        self.refcount[1..].iter().filter(|&&c| c > 1).count()
    }

    /// References beyond the first across all usable blocks — the number
    /// of block copies prefix sharing is currently saving.
    pub fn shared_refs(&self) -> u64 {
        self.refcount[1..]
            .iter()
            .map(|&c| u64::from(c.saturating_sub(1)))
            .sum()
    }
}

// ---------------------------------------------------------------------------
// BlockTable: one sequence's logical-row -> physical-block mapping
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Default)]
pub struct BlockTable {
    blocks: Vec<u32>,
}

impl BlockTable {
    /// Empty table (no rows mapped).
    pub fn new() -> Self {
        BlockTable { blocks: Vec::new() }
    }

    /// Physical block ids in logical order.
    pub fn blocks(&self) -> &[u32] {
        &self.blocks
    }

    /// Mapped block count.
    pub fn len(&self) -> usize {
        self.blocks.len()
    }

    /// True when no blocks are mapped.
    pub fn is_empty(&self) -> bool {
        self.blocks.is_empty()
    }

    /// Append the next logical block.
    pub fn push(&mut self, id: u32) {
        self.blocks.push(id);
    }

    /// Rows addressable through this table.
    pub fn capacity_rows(&self, block_size: usize) -> usize {
        self.blocks.len() * block_size
    }

    /// Physical (block, offset) of logical row `row`, if mapped.
    pub fn physical(&self, row: usize, block_size: usize)
        -> Option<(u32, usize)> {
        self.blocks
            .get(row / block_size)
            .map(|&b| (b, row % block_size))
    }

    /// Swap the block backing one table entry (copy-on-write fork):
    /// returns the id previously mapped there.
    pub fn replace(&mut self, idx: usize, id: u32) -> u32 {
        std::mem::replace(&mut self.blocks[idx], id)
    }

    /// Drain the table for freeing (the caller returns each id to the
    /// allocator); leaves an empty table behind.
    pub fn take_blocks(&mut self) -> Vec<u32> {
        std::mem::take(&mut self.blocks)
    }

    /// Shrink the table to the minimum number of blocks that still hold
    /// `rows` logical rows, returning the drained tail block ids for the
    /// caller to release (speculative-decode rewind, DESIGN.md §13).
    /// A block containing any kept row survives even when the rewind
    /// lands mid-block: its tail rows are logically dead but stay
    /// physically parked until overwritten by the next append.  No-op
    /// (empty return) when the table already fits in that many blocks.
    pub fn truncate_rows(
        &mut self,
        rows: usize,
        block_size: usize,
    ) -> Vec<u32> {
        let keep = rows.div_ceil(block_size);
        if keep >= self.blocks.len() {
            return Vec::new();
        }
        self.blocks.split_off(keep)
    }
}

// ---------------------------------------------------------------------------
// PrefixIndex: content-addressed prompt-prefix -> block map
// ---------------------------------------------------------------------------

/// Seed of the prefix hash chain (FNV-1a offset basis).
pub const PREFIX_SEED: u64 = 0xcbf2_9ce4_8422_2325;

/// Extend a prefix chain hash over one span of tokens (FNV-1a).
pub fn chain_hash(parent: u64, toks: &[u32]) -> u64 {
    let mut h = parent;
    for &t in toks {
        for b in t.to_le_bytes() {
            h ^= u64::from(b);
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// One registered prefix: the chain hash of everything before the span,
/// the exact tokens the span covers, and the block holding their rows.
/// Storing the tokens makes every hit an *equality* check — a hash
/// collision can cause a miss, never aliasing.
#[derive(Debug)]
struct PrefixEntry {
    parent: u64,
    toks: Vec<u32>,
    block: u32,
}

/// Maps token prefixes to the physical block holding their K/V rows
/// (DESIGN.md §11).  Full prompt blocks are registered under their
/// block-aligned prefix; a trailing partial block is registered under
/// the whole-prompt prefix, which is what lets identical prompts share
/// their tail (and is the write target that makes copy-on-write real).
///
/// Entries outlive their sequence: a freed block keeps its entry — and
/// its bytes — until the allocator hands the block out for *new*
/// content, at which point the engine calls [`Self::forget_block`].
/// Lookups are allocation-free (they run per block per admission plan,
/// re-planned every tick while a queue head is capacity-blocked): the
/// probe hashes the span and verifies token equality against the
/// stored entry.
#[derive(Debug, Default)]
pub struct PrefixIndex {
    by_hash: HashMap<u64, PrefixEntry>,
    by_block: HashMap<u32, u64>,
}

impl PrefixIndex {
    /// Empty index.
    pub fn new() -> Self {
        Self::default()
    }

    /// Registered spans.
    pub fn len(&self) -> usize {
        self.by_hash.len()
    }

    /// True when nothing is registered.
    pub fn is_empty(&self) -> bool {
        self.by_hash.is_empty()
    }

    /// Block registered for `(parent, toks)`, if any.
    pub fn lookup(&self, parent: u64, toks: &[u32]) -> Option<u32> {
        let e = self.by_hash.get(&chain_hash(parent, toks))?;
        (e.parent == parent && e.toks == toks).then_some(e.block)
    }

    /// Register `block` as holding the rows of `(parent, toks)`.  First
    /// writer wins: an existing entry under the same hash is kept (its
    /// block already serves sharers — and on the astronomically rare
    /// collision, keeping the old entry only costs the newcomer a
    /// miss), and a stale entry for this block is dropped first.
    pub fn insert(&mut self, parent: u64, toks: &[u32], block: u32) {
        debug_assert_ne!(block, SENTINEL_BLOCK, "indexed the sentinel");
        let h = chain_hash(parent, toks);
        if self.by_hash.contains_key(&h) {
            return;
        }
        self.forget_block(block);
        self.by_block.insert(block, h);
        self.by_hash.insert(
            h,
            PrefixEntry { parent, toks: toks.to_vec(), block },
        );
    }

    /// Drop whatever prefix `block` was registered under — called when
    /// the allocator reuses the block for new content (its old bytes are
    /// about to be overwritten).
    pub fn forget_block(&mut self, block: u32) {
        if let Some(h) = self.by_block.remove(&block) {
            self.by_hash.remove(&h);
        }
    }
}

// ---------------------------------------------------------------------------
// SwapPool: bounded accounting for host-swapped blocks
// ---------------------------------------------------------------------------

/// One block's worth of swapped-out K/V bytes (layer-major, as produced
/// by [`PagedHostKv::export_block`] / the backend's `export_block`).
#[derive(Debug, Clone, PartialEq)]
pub struct SwappedBlock {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

/// Bounded accounting for the host swap area (DESIGN.md §11).  The
/// engine owns the swapped bytes (they travel with the preempted
/// sequence); this tracks the bound so swap-out degrades to re-prefill
/// instead of growing host memory without limit.
#[derive(Debug, Clone, Default)]
pub struct SwapPool {
    max_blocks: usize,
    in_use: usize,
}

impl SwapPool {
    /// A pool admitting at most `max_blocks` swapped blocks (0 disables
    /// swapping entirely).
    pub fn new(max_blocks: usize) -> Self {
        SwapPool { max_blocks, in_use: 0 }
    }

    /// Admission ceiling in blocks.
    pub fn max_blocks(&self) -> usize {
        self.max_blocks
    }

    /// Blocks currently parked host-side.
    pub fn blocks_in_use(&self) -> usize {
        self.in_use
    }

    /// Would `n` more blocks fit?
    pub fn fits(&self, n: usize) -> bool {
        self.in_use + n <= self.max_blocks
    }

    /// Account `n` blocks swapped out (the caller checked [`Self::fits`]).
    pub fn reserve(&mut self, n: usize) {
        assert!(self.fits(n), "swap pool overflow");
        self.in_use += n;
    }

    /// Account `n` blocks swapped back in.
    pub fn release(&mut self, n: usize) {
        assert!(self.in_use >= n, "swap pool underflow");
        self.in_use -= n;
    }
}

// ---------------------------------------------------------------------------
// PagedHostKv: block-pool K/V storage addressed through tables
// ---------------------------------------------------------------------------

/// Host K/V arrays of shape `(L, num_blocks, block_size, d)`.  The paged
/// twin of [`super::HostKvMirror`]: rows are addressed through a
/// [`BlockTable`] instead of a flat `(lane, t)` pair.  Pure storage —
/// allocation policy lives in [`BlockAllocator`], scheduling in the
/// engine.
#[derive(Debug)]
pub struct PagedHostKv {
    pub layers: usize,
    pub d: usize,
    block_size: usize,
    num_blocks: usize,
    k: Vec<f32>,
    v: Vec<f32>,
}

impl PagedHostKv {
    /// Zeroed pool storage for `num_blocks` blocks of `block_size`
    /// rows across `layers` layers.
    pub fn new(
        layers: usize,
        num_blocks: usize,
        block_size: usize,
        d: usize,
    ) -> Self {
        let n = layers * num_blocks * block_size * d;
        PagedHostKv {
            layers,
            d,
            block_size,
            num_blocks,
            k: vec![0.0; n],
            v: vec![0.0; n],
        }
    }

    /// Token rows per block.
    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// Total pool size including the sentinel block 0.
    pub fn num_blocks(&self) -> usize {
        self.num_blocks
    }

    /// The K array, row-major `(layers, num_blocks, block_size, d)`.
    pub fn k_data(&self) -> &[f32] {
        &self.k
    }

    /// The V array, same layout as [`Self::k_data`].
    pub fn v_data(&self) -> &[f32] {
        &self.v
    }

    #[inline]
    fn idx(&self, layer: usize, block: u32, off: usize) -> usize {
        ((layer * self.num_blocks + block as usize) * self.block_size
            + off)
            * self.d
    }

    /// Raw K/V rows at a physical (layer, block, offset) — lets test
    /// backends share this pool's layout instead of re-implementing
    /// the index math.
    pub fn rows_at(&self, layer: usize, block: u32, off: usize)
        -> (&[f32], &[f32]) {
        let i = self.idx(layer, block, off);
        (&self.k[i..i + self.d], &self.v[i..i + self.d])
    }

    /// Mutable twin of [`Self::rows_at`].
    pub fn rows_at_mut(&mut self, layer: usize, block: u32, off: usize)
        -> (&mut [f32], &mut [f32]) {
        let i = self.idx(layer, block, off);
        let d = self.d;
        (&mut self.k[i..i + d], &mut self.v[i..i + d])
    }

    fn physical(&self, table: &BlockTable, row: usize)
        -> Result<(u32, usize)> {
        table.physical(row, self.block_size).ok_or_else(|| {
            anyhow::anyhow!(
                "row {row} beyond table capacity {}",
                table.capacity_rows(self.block_size)
            )
        })
    }

    /// Floats per block per K (or V) array across all layers.
    pub fn block_len(&self) -> usize {
        self.layers * self.block_size * self.d
    }

    /// Bytes of K/V payload one block holds (both arrays).
    pub fn block_bytes(&self) -> usize {
        self.block_len() * 2 * std::mem::size_of::<f32>()
    }

    fn check_block(&self, id: u32) -> Result<()> {
        anyhow::ensure!(
            (id as usize) < self.num_blocks,
            "block {id} out of pool ({})",
            self.num_blocks
        );
        Ok(())
    }

    /// Copy every layer's rows of block `src` over block `dst`
    /// (copy-on-write fork).  The sentinel is never a valid destination.
    pub fn copy_block(&mut self, src: u32, dst: u32) -> Result<()> {
        self.check_block(src)?;
        self.check_block(dst)?;
        anyhow::ensure!(dst != SENTINEL_BLOCK, "COW into the sentinel");
        if src == dst {
            return Ok(());
        }
        let n = self.block_size * self.d;
        for l in 0..self.layers {
            let s = self.idx(l, src, 0);
            let d = self.idx(l, dst, 0);
            self.k.copy_within(s..s + n, d);
            self.v.copy_within(s..s + n, d);
        }
        Ok(())
    }

    /// Copy a block's K/V rows out (layer-major contiguous) — the
    /// swap-out primitive.
    pub fn export_block(&self, id: u32) -> Result<SwappedBlock> {
        self.check_block(id)?;
        let n = self.block_size * self.d;
        let mut k = Vec::with_capacity(self.layers * n);
        let mut v = Vec::with_capacity(self.layers * n);
        for l in 0..self.layers {
            let s = self.idx(l, id, 0);
            k.extend_from_slice(&self.k[s..s + n]);
            v.extend_from_slice(&self.v[s..s + n]);
        }
        Ok(SwappedBlock { k, v })
    }

    /// Copy swapped-out rows back into a (fresh) block — the swap-in
    /// primitive; the exact inverse of [`Self::export_block`].
    pub fn import_block(&mut self, id: u32, blk: &SwappedBlock)
        -> Result<()> {
        self.check_block(id)?;
        anyhow::ensure!(id != SENTINEL_BLOCK, "swap-in into the sentinel");
        let n = self.block_size * self.d;
        anyhow::ensure!(
            blk.k.len() == self.layers * n && blk.v.len() == blk.k.len(),
            "swapped block size {} != {}",
            blk.k.len(),
            self.layers * n
        );
        for l in 0..self.layers {
            let s = self.idx(l, id, 0);
            self.k[s..s + n].copy_from_slice(&blk.k[l * n..(l + 1) * n]);
            self.v[s..s + n].copy_from_slice(&blk.v[l * n..(l + 1) * n]);
        }
        Ok(())
    }

    /// Copy prefill K/V (shape (L, 1, t, d) row-major) into a sequence's
    /// blocks (logical rows `0..len`, `len <= t`: right-padded prefill).
    pub fn write_prefill(
        &mut self,
        table: &BlockTable,
        k_pre: &[f32],
        v_pre: &[f32],
        t: usize,
        len: usize,
    ) -> Result<()> {
        self.write_prefill_from(table, k_pre, v_pre, t, len, 0)
    }

    /// Like [`Self::write_prefill`], but rows `0..start_row` are left
    /// untouched: they live in shared read-only blocks already holding
    /// exactly this content (prefix sharing, DESIGN.md §11).
    #[allow(clippy::too_many_arguments)]
    pub fn write_prefill_from(
        &mut self,
        table: &BlockTable,
        k_pre: &[f32],
        v_pre: &[f32],
        t: usize,
        len: usize,
        start_row: usize,
    ) -> Result<()> {
        anyhow::ensure!(len <= t, "prefill len {len} > bucket {t}");
        anyhow::ensure!(
            k_pre.len() == self.layers * t * self.d
                && v_pre.len() == k_pre.len(),
            "prefill kv size {} != {}",
            k_pre.len(),
            self.layers * t * self.d
        );
        for row in start_row.min(len)..len {
            let (block, off) = self.physical(table, row)?;
            for l in 0..self.layers {
                let src = (l * t + row) * self.d;
                let dst = self.idx(l, block, off);
                self.k[dst..dst + self.d]
                    .copy_from_slice(&k_pre[src..src + self.d]);
                self.v[dst..dst + self.d]
                    .copy_from_slice(&v_pre[src..src + self.d]);
            }
        }
        Ok(())
    }

    /// Write one decode step's K/V row for batch lane `lane` (out of
    /// `batch`; `k_new`/`v_new` are (L, batch, d)) at logical row `row`
    /// of the sequence mapped by `table`.
    pub fn append_row(
        &mut self,
        table: &BlockTable,
        row: usize,
        lane: usize,
        batch: usize,
        k_new: &[f32],
        v_new: &[f32],
    ) -> Result<()> {
        anyhow::ensure!(
            k_new.len() == self.layers * batch * self.d
                && v_new.len() == k_new.len(),
            "k_new size"
        );
        let (block, off) = self.physical(table, row)?;
        for l in 0..self.layers {
            let src = (l * batch + lane) * self.d;
            let dst = self.idx(l, block, off);
            self.k[dst..dst + self.d]
                .copy_from_slice(&k_new[src..src + self.d]);
            self.v[dst..dst + self.d]
                .copy_from_slice(&v_new[src..src + self.d]);
        }
        Ok(())
    }

    /// Gather a sequence's first `rows` logical rows into flat
    /// `(L, batch, t_max, d)` buffers at lane `lane` — the bridge that
    /// lets the legacy flat decode graph (the bit-exactness oracle) run
    /// on paged storage.
    #[allow(clippy::too_many_arguments)]
    pub fn gather_lane(
        &self,
        table: &BlockTable,
        rows: usize,
        lane: usize,
        batch: usize,
        t_max: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) -> Result<()> {
        anyhow::ensure!(rows <= t_max, "gather rows {rows} > t_max");
        anyhow::ensure!(
            k_out.len() == self.layers * batch * t_max * self.d
                && v_out.len() == k_out.len(),
            "gather output size"
        );
        for row in 0..rows {
            let (block, off) = self.physical(table, row)?;
            for l in 0..self.layers {
                let src = self.idx(l, block, off);
                let dst = ((l * batch + lane) * t_max + row) * self.d;
                k_out[dst..dst + self.d]
                    .copy_from_slice(&self.k[src..src + self.d]);
                v_out[dst..dst + self.d]
                    .copy_from_slice(&self.v[src..src + self.d]);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocator_reserves_sentinel_and_tracks_counts() {
        let mut a = BlockAllocator::new(4, 8);
        assert_eq!(a.capacity(), 3);
        assert_eq!(a.free_count(), 3);
        assert_eq!(a.in_use(), 0);
        let b1 = a.alloc().unwrap();
        let b2 = a.alloc().unwrap();
        let b3 = a.alloc().unwrap();
        assert!(a.alloc().is_none(), "pool exhausted");
        for b in [b1, b2, b3] {
            assert_ne!(b, SENTINEL_BLOCK);
        }
        assert_eq!(a.in_use(), 3);
        assert!((a.utilization() - 1.0).abs() < 1e-12);
        a.free(b2);
        assert_eq!(a.alloc().unwrap(), b2, "LIFO reuse");
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn allocator_double_free_panics() {
        let mut a = BlockAllocator::new(3, 4);
        let b = a.alloc().unwrap();
        a.free(b);
        a.free(b);
    }

    #[test]
    #[should_panic(expected = "sentinel")]
    fn allocator_rejects_sentinel_free() {
        let mut a = BlockAllocator::new(3, 4);
        a.free(SENTINEL_BLOCK);
    }

    #[test]
    fn blocks_for_rows_is_ceil() {
        let a = BlockAllocator::new(8, 4);
        assert_eq!(a.blocks_for_rows(0), 0);
        assert_eq!(a.blocks_for_rows(1), 1);
        assert_eq!(a.blocks_for_rows(4), 1);
        assert_eq!(a.blocks_for_rows(5), 2);
    }

    #[test]
    fn table_maps_rows_to_block_offsets() {
        let mut t = BlockTable::new();
        t.push(3);
        t.push(7);
        assert_eq!(t.capacity_rows(4), 8);
        assert_eq!(t.physical(0, 4), Some((3, 0)));
        assert_eq!(t.physical(3, 4), Some((3, 3)));
        assert_eq!(t.physical(4, 4), Some((7, 0)));
        assert_eq!(t.physical(8, 4), None);
        let drained = t.take_blocks();
        assert_eq!(drained, vec![3, 7]);
        assert!(t.is_empty());
    }

    #[test]
    fn table_truncate_rows_frees_only_whole_tail_blocks() {
        let bs = 4;
        let mut t = BlockTable::new();
        for id in [3u32, 7, 9] {
            t.push(id);
        }
        // Rewind to 5 rows: rows 0..5 span blocks 3 (rows 0..4) and 7
        // (row 4), so only block 9 drains; the partial block stays.
        assert_eq!(t.truncate_rows(5, bs), vec![9]);
        assert_eq!(t.blocks(), &[3, 7]);
        assert_eq!(t.physical(4, bs), Some((7, 0)));
        // Already fits: no-op.
        assert!(t.truncate_rows(8, bs).is_empty());
        assert!(t.truncate_rows(5, bs).is_empty());
        // Block-aligned rewind drains the exact tail.
        assert_eq!(t.truncate_rows(4, bs), vec![7]);
        // Rewind to zero rows drains everything.
        assert_eq!(t.truncate_rows(0, bs), vec![3]);
        assert!(t.is_empty());
    }

    #[test]
    fn paged_store_roundtrips_against_flat_mirror() {
        // Write the same prefill + appended rows into the flat mirror and
        // the paged store (through a non-trivial table), then gather the
        // paged lane back: both must hold identical bytes.
        let (layers, batch, t_max, d, bs) = (2, 3, 8, 4, 4);
        let mut flat = super::super::HostKvMirror::new(
            layers, batch, t_max, d);
        let mut paged = PagedHostKv::new(layers, 6, bs, d);
        let mut table = BlockTable::new();
        table.push(4); // deliberately out-of-order physical blocks
        table.push(2);

        let t = 6;
        let len = 5;
        let n = layers * t * d;
        let k_pre: Vec<f32> = (0..n).map(|i| i as f32 * 0.5).collect();
        let v_pre: Vec<f32> = (0..n).map(|i| i as f32 - 7.0).collect();
        let lane = 1;
        flat.write_prefill(lane, &k_pre, &v_pre, t, len).unwrap();
        paged.write_prefill(&table, &k_pre, &v_pre, t, len).unwrap();

        let m = layers * batch * d;
        let k_new: Vec<f32> = (0..m).map(|i| 100.0 + i as f32).collect();
        let v_new: Vec<f32> = (0..m).map(|i| -(i as f32)).collect();
        flat.append_rows(&[(lane, len)], &k_new, &v_new).unwrap();
        paged
            .append_row(&table, len, lane, batch, &k_new, &v_new)
            .unwrap();

        let sz = layers * batch * t_max * d;
        let (mut gk, mut gv) = (vec![0.0f32; sz], vec![0.0f32; sz]);
        paged
            .gather_lane(&table, len + 1, lane, batch, t_max, &mut gk,
                         &mut gv)
            .unwrap();
        for l in 0..layers {
            for row in 0..len + 1 {
                for j in 0..d {
                    let at = ((l * batch + lane) * t_max + row) * d + j;
                    assert_eq!(gk[at], flat.k_data()[at], "k l{l} r{row}");
                    assert_eq!(gv[at], flat.v_data()[at], "v l{l} r{row}");
                }
            }
        }
    }

    #[test]
    fn refcounts_share_and_release() {
        let mut a = BlockAllocator::new(4, 8);
        let b = a.alloc().unwrap();
        assert_eq!(a.ref_count(b), 1);
        assert!(!a.is_shared(b));
        a.retain(b);
        assert!(a.is_shared(b));
        assert_eq!(a.shared_blocks(), 1);
        assert_eq!(a.shared_refs(), 1);
        a.free(b);
        // One reference left: still allocated, no longer shared.
        assert_eq!(a.ref_count(b), 1);
        assert!(!a.is_shared(b));
        assert_eq!(a.free_count(), 2);
        a.free(b);
        assert_eq!(a.ref_count(b), 0);
        assert_eq!(a.free_count(), 3, "block returned at refcount 0");
    }

    #[test]
    #[should_panic(expected = "retain of free block")]
    fn retain_of_free_block_panics() {
        let mut a = BlockAllocator::new(3, 4);
        a.retain(2);
    }

    #[test]
    fn revive_pulls_a_freed_block_back() {
        let mut a = BlockAllocator::new(4, 8);
        let b = a.alloc().unwrap();
        a.free(b);
        assert_eq!(a.free_count(), 3);
        assert!(a.revive(b), "freed block revivable");
        assert_eq!(a.ref_count(b), 1);
        assert_eq!(a.free_count(), 2);
        assert!(!a.revive(b), "live block is retained, not revived");
        assert!(!a.revive(SENTINEL_BLOCK));
        // The revived block is out of the free list: allocs skip it.
        while let Some(x) = a.alloc() {
            assert_ne!(x, b);
        }
    }

    #[test]
    fn prefix_index_registers_looks_up_and_forgets() {
        let mut idx = PrefixIndex::new();
        let toks: Vec<u32> = (0..8).collect();
        let h1 = chain_hash(PREFIX_SEED, &toks);
        idx.insert(PREFIX_SEED, &toks, 3);
        assert_eq!(idx.lookup(PREFIX_SEED, &toks), Some(3));
        // Different parent or tokens: miss (exact equality, no aliasing).
        assert_eq!(idx.lookup(h1, &toks), None);
        assert_eq!(idx.lookup(PREFIX_SEED, &toks[..7]), None);
        // First writer wins for an identical prefix.
        idx.insert(PREFIX_SEED, &toks, 5);
        assert_eq!(idx.lookup(PREFIX_SEED, &toks), Some(3));
        // Chained second level.
        idx.insert(h1, &[9, 9], 4);
        assert_eq!(idx.lookup(h1, &[9, 9]), Some(4));
        assert_eq!(idx.len(), 2);
        // Reallocation of block 3 drops only its entry.
        idx.forget_block(3);
        assert_eq!(idx.lookup(PREFIX_SEED, &toks), None);
        assert_eq!(idx.lookup(h1, &[9, 9]), Some(4));
    }

    #[test]
    fn block_export_import_roundtrip_and_cow_copy() {
        let (layers, nb, bs, d) = (2, 4, 4, 3);
        let mut p = PagedHostKv::new(layers, nb, bs, d);
        let mut table = BlockTable::new();
        table.push(2);
        let n = layers * bs * d;
        let k: Vec<f32> = (0..n).map(|i| i as f32 + 0.25).collect();
        let v: Vec<f32> = (0..n).map(|i| -(i as f32)).collect();
        p.write_prefill(&table, &k, &v, bs, bs).unwrap();

        let blk = p.export_block(2).unwrap();
        assert_eq!(blk.k.len(), p.block_len());
        p.import_block(3, &blk).unwrap();
        for l in 0..layers {
            for off in 0..bs {
                assert_eq!(p.rows_at(l, 2, off), p.rows_at(l, 3, off));
            }
        }
        // COW copy: the fork matches, then diverges without touching the
        // original.
        p.copy_block(2, 1).unwrap();
        let (kr, _) = p.rows_at_mut(0, 1, 0);
        kr[0] = 999.0;
        assert_eq!(p.rows_at(0, 2, 0).0[0], blk.k[0], "original intact");
        assert!(p.copy_block(2, SENTINEL_BLOCK).is_err());
        assert!(p.import_block(SENTINEL_BLOCK, &blk).is_err());
        assert!(p.export_block(99).is_err());
    }

    #[test]
    fn swap_pool_bounds_accounting() {
        let mut s = SwapPool::new(4);
        assert!(s.fits(4));
        s.reserve(3);
        assert_eq!(s.blocks_in_use(), 3);
        assert!(!s.fits(2));
        s.release(2);
        assert!(s.fits(3));
        let none = SwapPool::new(0);
        assert!(!none.fits(1), "zero-size pool disables swap");
    }

    #[test]
    fn paged_store_rejects_unmapped_rows() {
        let mut p = PagedHostKv::new(1, 3, 4, 2);
        let mut table = BlockTable::new();
        table.push(1);
        let k = vec![0.0f32; 8 * 2];
        // prefill longer than the table's 4 rows
        assert!(p.write_prefill(&table, &k, &k, 8, 5).is_err());
        let row = vec![0.0f32; 2 * 2];
        assert!(p.append_row(&table, 4, 0, 2, &row, &row).is_err());
    }
}
