//! # lqer — Low-Rank Quantization Error Reconstruction for LLMs
//!
//! Rust + JAX + Pallas reproduction of *LQER: Low-Rank Quantization Error
//! Reconstruction for LLMs* (Zhang et al., ICML 2024).
//!
//! This crate is **Layer 3** of the three-layer stack (DESIGN.md §3): the
//! self-contained serving coordinator and evaluation harness.  Python/JAX
//! runs only at build time (`make artifacts`) to train the synthetic model
//! family, run the PTQ pipeline, and lower the model graphs to HLO text;
//! this crate loads those artifacts through the PJRT CPU client and owns
//! everything on the request path:
//!
//! * [`runtime`]     — PJRT client, HLO-text loader, weight store (LQTW),
//!   staged execution + device-resident KV sessions
//! * [`xla`]         — offline build shim of the `xla` crate (DESIGN.md §7)
//! * [`coordinator`] — bounded admission queue, continuous batcher,
//!   engine loop with block accounting + preemption (generic over a
//!   decode backend; device-resident cache by default)
//! * [`kvcache`]     — slot/position manager, host cache mirror, and the
//!   paged block allocator/tables/pool (DESIGN.md §10)
//! * [`tokenizer`]   — word-level tokenizer over the corpus vocabulary
//! * [`eval`]        — perplexity / downstream-task / pairwise-judge evaluators
//! * [`quant`]       — bit-exact MXINT + fixed-point twins of the L1 kernels
//! * [`linalg`]      — dense matrices + one-sided Jacobi SVD
//! * [`analysis`]    — singular-value spectra & approximation-error tooling
//! * [`hwcost`]      — the circuit-area model behind the paper's Tables 3/7/8/9
//! * [`config`]      — typed experiment / serving configuration
//! * [`util`]        — JSON, argparse, RNG, logging, timers, mini-proptest
//!   (no external crates are reachable offline; these substrates are built
//!   from scratch and unit-tested like everything else)

pub mod analysis;
pub mod config;
pub mod coordinator;
pub mod eval;
pub mod hwcost;
pub mod kvcache;
pub mod linalg;
pub mod quant;
pub mod runtime;
pub mod tokenizer;
pub mod util;
pub mod xla;

/// Repository-relative default artifacts directory.
pub fn default_artifacts_dir() -> std::path::PathBuf {
    // Honour LQER_ARTIFACTS, else walk up from CWD looking for `artifacts/`.
    if let Ok(p) = std::env::var("LQER_ARTIFACTS") {
        return std::path::PathBuf::from(p);
    }
    let mut dir = std::env::current_dir().unwrap_or_default();
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return std::path::PathBuf::from("artifacts");
        }
    }
}
