//! Dense linear algebra: row-major matrices, GEMM, norms, and a one-sided
//! Jacobi SVD — the substrate behind the Figure-1a spectra analysis and the
//! LQER algebra tests.  No BLAS/LAPACK offline; everything here is written
//! from scratch and property-tested.

pub mod svd;

/// Row-major dense f64 matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat {
            rows,
            cols,
            data: data.iter().map(|x| *x as f64).collect(),
        }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f64 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f64 {
        &mut self.data[r * self.cols + c]
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.at(r, c);
            }
        }
        out
    }

    /// self (r x k) * other (k x c), blocked i-k-j loop order.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        let n = other.cols;
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self.at(i, k);
                if a == 0.0 {
                    continue;
                }
                let orow = &other.data[k * n..(k + 1) * n];
                let dst = &mut out.data[i * n..(i + 1) * n];
                for (d, o) in dst.iter_mut().zip(orow) {
                    *d += a * o;
                }
            }
        }
        out
    }

    /// Scale row r by s (in place).
    pub fn scale_row(&mut self, r: usize, s: f64) {
        for v in &mut self.data[r * self.cols..(r + 1) * self.cols] {
            *v *= s;
        }
    }

    pub fn frobenius(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    pub fn mean_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .sum::<f64>()
            / self.data.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let i = Mat::eye(3);
        assert_eq!(a.matmul(&i), a);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Mat::from_vec(2, 2, vec![5., 6., 7., 8.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19., 22., 43., 50.]);
    }

    #[test]
    fn transpose_involution() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn frobenius_matches_manual() {
        let a = Mat::from_vec(1, 2, vec![3.0, 4.0]);
        assert!((a.frobenius() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn scale_row_only_touches_row() {
        let mut a = Mat::from_vec(2, 2, vec![1., 1., 1., 1.]);
        a.scale_row(0, 2.0);
        assert_eq!(a.data, vec![2., 2., 1., 1.]);
    }
}
