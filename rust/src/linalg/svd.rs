//! One-sided Jacobi SVD.
//!
//! `svd(A)` for A (m x n) returns (U, s, V^T) with A = U diag(s) V^T,
//! singular values sorted descending.  The one-sided Jacobi method rotates
//! *column pairs* of a working copy of A until all pairs are mutually
//! orthogonal; the column norms are then the singular values.  It is
//! O(n^2 m) per sweep but numerically excellent — more than enough for the
//! Figure-1a spectra (192x768) and the LQER reconstruction tests.
//!
//! For m < n we factor A^T and swap U/V.

use super::Mat;

pub struct Svd {
    pub u: Mat,       // m x r
    pub s: Vec<f64>,  // r, descending
    pub vt: Mat,      // r x n
}

const MAX_SWEEPS: usize = 60;
const TOL: f64 = 1e-12;

/// Compute the thin SVD of `a`.
pub fn svd(a: &Mat) -> Svd {
    if a.rows < a.cols {
        // A = U S V^T  <=>  A^T = V S U^T
        let t = svd(&a.transpose());
        return Svd {
            u: t.vt.transpose(),
            s: t.s,
            vt: t.u.transpose(),
        };
    }
    let m = a.rows;
    let n = a.cols;
    // Work on columns of W (a copy of A); accumulate V.
    let mut w = a.clone();
    let mut v = Mat::eye(n);

    for _sweep in 0..MAX_SWEEPS {
        let mut off = 0.0f64;
        for p in 0..n - 1 {
            for q in (p + 1)..n {
                // Gram entries for columns p, q.
                let (mut app, mut aqq, mut apq) = (0.0, 0.0, 0.0);
                for i in 0..m {
                    let wp = w.at(i, p);
                    let wq = w.at(i, q);
                    app += wp * wp;
                    aqq += wq * wq;
                    apq += wp * wq;
                }
                if apq.abs() <= TOL * (app * aqq).sqrt().max(1e-300) {
                    continue;
                }
                off += apq.abs();
                // Jacobi rotation zeroing the (p,q) Gram entry.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let wp = w.at(i, p);
                    let wq = w.at(i, q);
                    *w.at_mut(i, p) = c * wp - s * wq;
                    *w.at_mut(i, q) = s * wp + c * wq;
                }
                for i in 0..n {
                    let vp = v.at(i, p);
                    let vq = v.at(i, q);
                    *v.at_mut(i, p) = c * vp - s * vq;
                    *v.at_mut(i, q) = s * vp + c * vq;
                }
            }
        }
        if off == 0.0 {
            break;
        }
    }

    // Column norms -> singular values; normalize columns of W into U.
    let mut order: Vec<usize> = (0..n).collect();
    let mut sigmas = vec![0.0f64; n];
    for (j, sig) in sigmas.iter_mut().enumerate() {
        let mut nrm = 0.0;
        for i in 0..m {
            nrm += w.at(i, j) * w.at(i, j);
        }
        *sig = nrm.sqrt();
    }
    order.sort_by(|&x, &y| sigmas[y].partial_cmp(&sigmas[x]).unwrap());

    let mut u = Mat::zeros(m, n);
    let mut s = vec![0.0f64; n];
    let mut vt = Mat::zeros(n, n);
    for (new_j, &old_j) in order.iter().enumerate() {
        let sig = sigmas[old_j];
        s[new_j] = sig;
        let inv = if sig > 0.0 { 1.0 / sig } else { 0.0 };
        for i in 0..m {
            *u.at_mut(i, new_j) = w.at(i, old_j) * inv;
        }
        for i in 0..n {
            *vt.at_mut(new_j, i) = v.at(i, old_j);
        }
    }
    Svd { u, s, vt }
}

/// Singular values only.
pub fn singular_values(a: &Mat) -> Vec<f64> {
    svd(a).s
}

/// Rank-k reconstruction U_k diag(s_k) Vt_k.
pub fn truncated_product(f: &Svd, k: usize) -> Mat {
    let k = k.min(f.s.len());
    let m = f.u.rows;
    let n = f.vt.cols;
    let mut out = Mat::zeros(m, n);
    for j in 0..k {
        let sig = f.s[j];
        for i in 0..m {
            let uij = f.u.at(i, j) * sig;
            if uij == 0.0 {
                continue;
            }
            for c in 0..n {
                out.data[i * n + c] += uij * f.vt.at(j, c);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_mat(m: usize, n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_vec(m, n, (0..m * n).map(|_| rng.normal()).collect())
    }

    fn assert_reconstructs(a: &Mat, tol: f64) {
        let f = svd(a);
        let recon = truncated_product(&f, f.s.len());
        assert!(
            a.max_abs_diff(&recon) < tol,
            "reconstruction err {} (shape {}x{})",
            a.max_abs_diff(&recon),
            a.rows,
            a.cols
        );
    }

    #[test]
    fn reconstructs_small() {
        assert_reconstructs(&random_mat(8, 5, 1), 1e-9);
        assert_reconstructs(&random_mat(5, 8, 2), 1e-9);
        assert_reconstructs(&random_mat(16, 16, 3), 1e-9);
    }

    #[test]
    fn diag_matrix_svd_is_diag() {
        let mut a = Mat::zeros(4, 4);
        for (i, v) in [3.0, 7.0, 1.0, 5.0].iter().enumerate() {
            a.data[i * 4 + i] = *v;
        }
        let s = singular_values(&a);
        assert!((s[0] - 7.0).abs() < 1e-10);
        assert!((s[1] - 5.0).abs() < 1e-10);
        assert!((s[2] - 3.0).abs() < 1e-10);
        assert!((s[3] - 1.0).abs() < 1e-10);
    }

    #[test]
    fn values_sorted_and_nonnegative() {
        let s = singular_values(&random_mat(20, 12, 4));
        for w in s.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
        assert!(s.iter().all(|x| *x >= 0.0));
    }

    #[test]
    fn u_and_v_orthonormal() {
        let a = random_mat(12, 7, 5);
        let f = svd(&a);
        let utu = f.u.transpose().matmul(&f.u);
        let vvt = f.vt.matmul(&f.vt.transpose());
        assert!(utu.max_abs_diff(&Mat::eye(7)) < 1e-9, "U^T U != I");
        assert!(vvt.max_abs_diff(&Mat::eye(7)) < 1e-9, "V V^T != I");
    }

    #[test]
    fn rank_one_matrix() {
        // outer product has exactly one nonzero singular value = |u||v|
        let u = vec![1.0, 2.0, -1.0];
        let v = vec![0.5, 1.5];
        let mut a = Mat::zeros(3, 2);
        for i in 0..3 {
            for j in 0..2 {
                a.data[i * 2 + j] = u[i] * v[j];
            }
        }
        let s = singular_values(&a);
        let expect = (6.0f64).sqrt() * (2.5f64).sqrt();
        assert!((s[0] - expect).abs() < 1e-10);
        assert!(s[1].abs() < 1e-10);
    }

    #[test]
    fn truncation_error_equals_tail_energy() {
        // ||A - A_k||_F^2 == sum of squared dropped singular values.
        let a = random_mat(10, 6, 6);
        let f = svd(&a);
        for k in [1, 3, 5] {
            let ak = truncated_product(&f, k);
            let mut diff2 = 0.0;
            for (x, y) in a.data.iter().zip(&ak.data) {
                diff2 += (x - y) * (x - y);
            }
            let tail: f64 = f.s[k..].iter().map(|s| s * s).sum();
            assert!(
                (diff2 - tail).abs() < 1e-9 * (1.0 + tail),
                "k={k}: {diff2} vs {tail}"
            );
        }
    }

    #[test]
    fn frobenius_preserved() {
        let a = random_mat(9, 9, 7);
        let s = singular_values(&a);
        let f2: f64 = s.iter().map(|x| x * x).sum();
        assert!((f2.sqrt() - a.frobenius()).abs() < 1e-9);
    }
}
