//! `lqer` CLI — leader entrypoint for the L3 coordinator.
//!
//! Subcommands map one-to-one onto the paper's experiments:
//!
//! ```text
//! lqer info                           artifact inventory
//! lqer serve     --addr host:port     HTTP serving frontend
//! lqer generate  --prompt "..."       serve one request end-to-end
//! lqer serve-bench                    batched serving load test
//! lqer trace     --file TRACE.json    summarize a recorded engine trace
//! lqer bench kv                       paged-KV engine bench (no PJRT)
//! lqer bench kvshared                 prefix-sharing / swap bench (no PJRT)
//! lqer bench chunked                  chunked-prefill ITL bench (no PJRT)
//! lqer bench sessions                 multi-turn session bench (no PJRT)
//! lqer eval-ppl  --model --method     WikiText-style perplexity (Tables 2/3/6)
//! lqer eval-tasks --model --method    downstream accuracy (Table 4)
//! lqer judge     --a --b              pairwise win rate (Table 5)
//! lqer spectra                        Figure 1a singular-value series
//! lqer rank-sweep                     Figure 3 perplexity vs rank
//! lqer area      [--method ...]       circuit-area model (Tables 3/7/8/9)
//! lqer plan      --model --method     per-layer quantization plan + bits
//! ```

use anyhow::Result;
use lqer::config::Manifest;
use lqer::coordinator::{
    AdmissionPolicy, EngineConfig, EngineHandle, PagedKvConfig, Priority,
    Request, Sampling, SpecConfig,
};
use lqer::runtime::{ModelRunner, Runtime};
use lqer::util::argparse::Args;
use lqer::util::bench::Table;
use lqer::{analysis, eval, hwcost};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if std::env::var("LQER_DEBUG").is_ok() {
        lqer::util::log::set_level(2);
    }
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("{e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(argv: &[String]) -> Result<()> {
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = if argv.is_empty() { &[] } else { &argv[1..] };
    match cmd {
        "info" => info(rest),
        "serve" => serve(rest),
        "generate" => generate(rest),
        "serve-bench" => serve_bench(rest),
        "trace" => trace_cmd(rest),
        "bench" => bench(rest),
        "eval-ppl" => eval_ppl(rest),
        "eval-tasks" => eval_tasks(rest),
        "judge" => judge(rest),
        "spectra" => spectra(rest),
        "rank-sweep" => rank_sweep(rest),
        "area" => area(rest),
        "plan" => plan_cmd(rest),
        _ => {
            println!(
                "lqer — LQER (ICML 2024) reproduction CLI\n\n\
                 subcommands: info serve generate serve-bench trace \
                 bench eval-ppl eval-tasks judge spectra rank-sweep \
                 area plan\n\
                 run `lqer <cmd> --help` for options"
            );
            Ok(())
        }
    }
}

fn manifest() -> Result<Manifest> {
    Manifest::load(&lqer::default_artifacts_dir())
}

fn info(argv: &[String]) -> Result<()> {
    let _ = Args::new("info", "artifact inventory").parse(argv)?;
    let m = manifest()?;
    println!("artifacts: {}", m.dir.display());
    if let Some(created) = &m.created {
        println!("built: {created}");
    }
    let mut t = Table::new("models", &["name", "d", "layers", "heads",
                                       "ffn", "params"]);
    for mi in &m.models {
        t.row(vec![
            mi.name.clone(),
            mi.d.to_string(),
            mi.layers.to_string(),
            mi.heads.to_string(),
            mi.ffn.to_string(),
            mi.n_params.to_string(),
        ]);
    }
    print!("{}", t.render());
    println!("\n{} PTQ runs, {} lowered graphs", m.runs.len(),
             m.graphs.len());
    println!("serve model: {} (methods: {})", m.serve.model,
             m.serve.methods.join(", "));
    println!("data dir: {}", m.data_dir().display());
    if let Some(f) = &m.fig1a {
        println!("fig1a export: {} ({}x{})", f.layer, f.shape.0,
                 f.shape.1);
    }
    Ok(())
}

/// Resolve the per-tick token budget from the CLI.  `--tokens-per-step`
/// is the real knob (0 = engine default: batch + largest prefill
/// bucket); the deprecated `--max-prefill-per-step N` is kept as a
/// parsed alias — N whole prefills of the largest bucket per tick, its
/// legacy unit — so existing scripts and CI invocations keep working,
/// with a one-time warning.
fn tokens_per_step_arg(a: &Args, m: &Manifest, batch: usize)
    -> Result<usize> {
    let legacy = a.get("max-prefill-per-step");
    if legacy.is_empty() {
        return a.get_usize("tokens-per-step");
    }
    anyhow::ensure!(
        a.get_usize("tokens-per-step")? == 0,
        "--max-prefill-per-step (deprecated) conflicts with \
         --tokens-per-step; set only the latter"
    );
    static WARN_ONCE: std::sync::Once = std::sync::Once::new();
    WARN_ONCE.call_once(|| {
        eprintln!(
            "warning: --max-prefill-per-step is deprecated; use \
             --tokens-per-step (per-tick token budget, DESIGN.md §12). \
             Mapping N whole-bucket prefills to an equivalent budget."
        );
    });
    let n: usize = legacy.parse().map_err(|_| {
        anyhow::anyhow!("--max-prefill-per-step must be an integer")
    })?;
    let max_bucket = m
        .serve
        .prefill_shapes
        .iter()
        .map(|(_, t)| *t)
        .max()
        .unwrap_or(1);
    Ok(batch + n.max(1) * max_bucket)
}

/// `--speculate` / `--gamma` → the engine's speculative-decode knob:
/// `None` = off, `Some(0)` = on with the manifest's compiled gamma,
/// `Some(g)` = on with an explicit override.
fn spec_arg(a: &Args) -> Result<Option<usize>> {
    let gamma = a.get_usize("gamma")?;
    if a.get_flag("speculate") {
        Ok(Some(gamma))
    } else {
        anyhow::ensure!(gamma == 0, "--gamma needs --speculate");
        Ok(None)
    }
}

#[allow(clippy::too_many_arguments)]
fn engine_cfg(m: &Manifest, model: &str, method: &str, batch: usize,
              tokens_per_step: usize, host_cache: bool, paged: bool,
              prefix_share: bool, swap_blocks: usize,
              session_blocks: usize, spec_gamma: Option<usize>,
              trace_capacity: usize)
              -> Result<EngineConfig> {
    anyhow::ensure!(
        paged || (!prefix_share && swap_blocks == 0),
        "--prefix-share / --swap-blocks require --paged"
    );
    anyhow::ensure!(
        session_blocks == 0 || prefix_share,
        "--session-blocks needs --prefix-share (sessions re-admit \
         through the prefix index, DESIGN.md §16)"
    );
    // --gamma 0 defers to the manifest's serve.spec section (compiled
    // next to the decode graphs), falling back to 4 for legacy
    // artifacts without one.
    let spec = match spec_gamma {
        None => None,
        Some(g) => {
            anyhow::ensure!(
                host_cache,
                "--speculate needs the host-cache oracle backend for \
                 now: the PJRT decode_draft / verify_batch graphs are \
                 compiled into the manifest but the device execution \
                 path is gated (ROADMAP)"
            );
            let gamma = match g {
                0 => m.serve.spec.as_ref().map(|s| s.gamma).unwrap_or(4),
                g => g,
            };
            Some(SpecConfig { gamma })
        }
    };
    anyhow::ensure!(
        !(prefix_share || swap_blocks > 0) || host_cache,
        "--prefix-share / --swap-blocks need the host-paged backing \
         (--host-cache); the device-paged path has no block ops yet \
         (ROADMAP)"
    );
    let paged_cfg = if paged {
        let info = m.model(model)?;
        let geometry = match &m.serve.paged {
            Some(p) => p.clone(),
            // Legacy artifacts carry no paged graphs; the host-oracle
            // paged path still works with a derived geometry.
            None => {
                anyhow::ensure!(
                    info.t_max % 16 == 0,
                    "t_max {} not divisible by the default block size 16",
                    info.t_max
                );
                lqer::config::PagedServeInfo {
                    block_size: 16,
                    blocks_per_lane: info.t_max / 16,
                }
            }
        };
        // Same memory as the flat (batch, t_max) cache + the sentinel.
        Some(PagedKvConfig {
            block_size: geometry.block_size,
            num_blocks: geometry.num_blocks(batch),
            prefix_sharing: prefix_share,
            swap_blocks,
            session_blocks,
        })
    } else {
        None
    };
    Ok(EngineConfig {
        model: model.to_string(),
        method: method.to_string(),
        decode_batch: batch,
        prefill_buckets: m
            .serve
            .prefill_shapes
            .iter()
            .map(|(_, t)| *t)
            .collect(),
        tokens_per_step,
        host_cache,
        paged: paged_cfg,
        spec,
        admission: AdmissionPolicy::default(),
        trace_capacity,
    })
}

fn serve(argv: &[String]) -> Result<()> {
    let m = manifest()?;
    let a = Args::new("serve", "HTTP serving frontend")
        .opt("model", &m.serve.model, "model name")
        .opt("method", "l2qer-w4a8", "PTQ method")
        .opt("addr", "127.0.0.1:8317", "listen address")
        .opt("batch", "8", "decode batch bucket")
        .opt("tokens-per-step", "0",
             "per-tick token budget (DESIGN.md \u{a7}12): decoding lanes \
              first, the rest packed with chunked-prefill slices \
              (0 = batch + largest prefill bucket)")
        .opt("max-prefill-per-step", "",
             "deprecated alias: N whole-bucket prefills per tick \
              (mapped to a token budget; prefer --tokens-per-step)")
        .flag("host-cache", "legacy host-side KV cache (oracle mode)")
        .flag("paged", "block-granular KV allocation (DESIGN.md §10)")
        .flag("prefix-share",
              "share block-aligned prompt prefixes copy-on-write \
               (DESIGN.md §11; needs --paged --host-cache)")
        .opt("swap-blocks", "0",
             "host swap pool size in blocks (0 = re-prefill on \
              preemption; needs --paged --host-cache)")
        .opt("session-blocks", "0",
             "multi-turn session budget in blocks (DESIGN.md \u{a7}16): \
              finished conversations keep their KV tail registered for \
              near-zero-prefill follow-up turns (0 = off; needs \
              --prefix-share)")
        .flag("speculate",
              "self-speculative decode (DESIGN.md §13): the \
               lowrank-free backbone drafts, the corrected model \
               verifies (needs --host-cache)")
        .opt("gamma", "0",
             "max draft tokens per lane per speculation round \
              (0 = manifest serve.spec gamma; needs --speculate)")
        .opt("trace-file", "",
             "flight-recorder Chrome trace output path (serve runs \
              until killed — fetch GET /trace/chrome instead)")
        .opt("trace-capacity", "0",
             "flight-recorder ring capacity in events (DESIGN.md \
              \u{a7}15; 0 = default 4096)")
        .parse(argv)?;
    let tok = lqer::tokenizer::Tokenizer::from_file(
        &m.data_dir().join("vocab.json"))?;
    let batch = a.get_usize("batch")?;
    let engine = EngineHandle::spawn(
        m.dir.clone(),
        engine_cfg(&m, &a.get("model"), &a.get("method"), batch,
                   tokens_per_step_arg(&a, &m, batch)?,
                   a.get_flag("host-cache"),
                   a.get_flag("paged"), a.get_flag("prefix-share"),
                   a.get_usize("swap-blocks")?,
                   a.get_usize("session-blocks")?, spec_arg(&a)?,
                   a.get_usize("trace-capacity")?)?,
    )?;
    if !a.get("trace-file").is_empty() {
        eprintln!(
            "note: serve runs until killed, so --trace-file is never \
             written; fetch the live ring via GET /trace/chrome"
        );
    }
    println!("serving {} / {} on http://{}  (POST /generate, \
              GET /metrics, GET /metrics/prom, GET /trace, \
              GET /healthz)",
             a.get("model"), a.get("method"), a.get("addr"));
    lqer::coordinator::server::serve(&a.get("addr"), engine, tok)
}

fn generate(argv: &[String]) -> Result<()> {
    let m = manifest()?;
    let a = Args::new("generate", "serve one request end-to-end")
        .opt("model", &m.serve.model, "model name")
        .opt("method", "l2qer-w4a8", "PTQ method")
        .opt("prompt", "the", "prompt text (corpus vocabulary)")
        .opt("max-new", "24", "max generated tokens")
        .opt("topk", "0", "top-k sampling (0 = greedy)")
        .opt("batch", "4", "decode batch bucket")
        .opt("tokens-per-step", "0",
             "per-tick token budget (DESIGN.md \u{a7}12): decoding lanes \
              first, the rest packed with chunked-prefill slices \
              (0 = batch + largest prefill bucket)")
        .opt("max-prefill-per-step", "",
             "deprecated alias: N whole-bucket prefills per tick \
              (mapped to a token budget; prefer --tokens-per-step)")
        .flag("host-cache", "legacy host-side KV cache (oracle mode)")
        .flag("paged", "block-granular KV allocation (DESIGN.md §10)")
        .flag("prefix-share",
              "share block-aligned prompt prefixes copy-on-write \
               (DESIGN.md §11; needs --paged --host-cache)")
        .opt("swap-blocks", "0",
             "host swap pool size in blocks (0 = re-prefill on \
              preemption; needs --paged --host-cache)")
        .opt("session-blocks", "0",
             "multi-turn session budget in blocks (DESIGN.md \u{a7}16): \
              finished conversations keep their KV tail registered for \
              near-zero-prefill follow-up turns (0 = off; needs \
              --prefix-share)")
        .flag("speculate",
              "self-speculative decode (DESIGN.md §13): the \
               lowrank-free backbone drafts, the corrected model \
               verifies (needs --host-cache)")
        .opt("gamma", "0",
             "max draft tokens per lane per speculation round \
              (0 = manifest serve.spec gamma; needs --speculate)")
        .opt("n", "1",
             "parallel samples per prompt (DESIGN.md \u{a7}16): fork n \
              decode tails COW-sharing the prompt blocks (needs \
              --paged --prefix-share --host-cache)")
        .opt("best-of", "0",
             "over-generate max(n, best_of) candidates, return the \
              best n by cumulative logprob (0 = n)")
        .opt("beams", "0",
             "beam-search width (DESIGN.md \u{a7}16; 0/1 = off; \
              mutually exclusive with --n; needs --paged \
              --prefix-share --host-cache)")
        .opt("session", "0",
             "session id for multi-turn KV reuse (0 = none; needs \
              --session-blocks on the engine)")
        .opt("priority", "normal", "eviction class: low|normal|high")
        .opt("trace-file", "",
             "write the flight-recorder Chrome trace here on exit \
              (DESIGN.md \u{a7}15; empty = off)")
        .opt("trace-capacity", "0",
             "flight-recorder ring capacity in events (DESIGN.md \
              \u{a7}15; 0 = default 4096)")
        .parse(argv)?;
    let tok = lqer::tokenizer::Tokenizer::from_file(
        &m.data_dir().join("vocab.json"))?;
    let batch = a.get_usize("batch")?;
    let engine = EngineHandle::spawn(
        m.dir.clone(),
        engine_cfg(&m, &a.get("model"), &a.get("method"), batch,
                   tokens_per_step_arg(&a, &m, batch)?,
                   a.get_flag("host-cache"),
                   a.get_flag("paged"), a.get_flag("prefix-share"),
                   a.get_usize("swap-blocks")?,
                   a.get_usize("session-blocks")?, spec_arg(&a)?,
                   a.get_usize("trace-capacity")?)?,
    )?;
    let sampling = match a.get_usize("topk")? {
        0 => Sampling::Greedy,
        k => Sampling::TopK { k, temperature: 0.8, seed: 17 },
    };
    let priority = Priority::parse(&a.get("priority")).ok_or_else(|| {
        anyhow::anyhow!("--priority must be low|normal|high")
    })?;
    let n = a.get_usize("n")?.max(1);
    let best_of = match a.get_usize("best-of")? {
        0 => n,
        b => {
            anyhow::ensure!(b >= n, "--best-of must be >= --n");
            b
        }
    };
    let session = match a.get_usize("session")? {
        0 => None,
        s => Some(s as u64),
    };
    let beams = a.get_usize("beams")?;
    let resp = engine.generate(Request {
        id: 1,
        prompt: tok.encode_prompt(&a.get("prompt")),
        max_new_tokens: a.get_usize("max-new")?,
        sampling,
        priority,
        n: best_of,
        beams,
        session,
    })?;
    println!("prompt : {}", a.get("prompt"));
    println!("output : {}", tok.decode_clean(&resp.tokens));
    println!(
        "finish={:?} ttft={:.0}ms total={:.0}ms tokens={}",
        resp.finish, resp.ttft_ms, resp.total_ms, resp.tokens.len()
    );
    // Over-generated (`best_of > n`) candidates are engine-sorted
    // best-first; show only what the user asked for.
    let show = if beams > 1 { beams } else { n };
    for (i, c) in resp.candidates.iter().take(show).enumerate() {
        println!(
            "cand {i} : {}  (score {:.3}, finish {:?})",
            tok.decode_clean(&c.tokens), c.score, c.finish
        );
    }
    let trace_file = a.get("trace-file");
    if !trace_file.is_empty() {
        let records = engine.trace()?;
        std::fs::write(
            &trace_file,
            lqer::coordinator::trace::to_chrome_json(&records)
                .to_string(),
        )?;
        println!("wrote {trace_file} ({} events)", records.len());
    }
    engine.shutdown();
    Ok(())
}

fn serve_bench(argv: &[String]) -> Result<()> {
    let m = manifest()?;
    let a = Args::new("serve-bench", "batched serving load test")
        .opt("model", &m.serve.model, "model name")
        .opt("method", "l2qer-w4a8", "PTQ method")
        .opt("requests", "16", "number of requests")
        .opt("max-new", "24", "tokens per request")
        .opt("batch", "8", "decode batch bucket")
        .opt("tokens-per-step", "0",
             "per-tick token budget (DESIGN.md \u{a7}12): decoding lanes \
              first, the rest packed with chunked-prefill slices \
              (0 = batch + largest prefill bucket)")
        .opt("max-prefill-per-step", "",
             "deprecated alias: N whole-bucket prefills per tick \
              (mapped to a token budget; prefer --tokens-per-step)")
        .flag("host-cache", "legacy host-side KV cache (oracle mode)")
        .flag("paged", "block-granular KV allocation (DESIGN.md §10)")
        .flag("prefix-share",
              "share block-aligned prompt prefixes copy-on-write \
               (DESIGN.md §11; needs --paged --host-cache)")
        .opt("swap-blocks", "0",
             "host swap pool size in blocks (0 = re-prefill on \
              preemption; needs --paged --host-cache)")
        .opt("session-blocks", "0",
             "multi-turn session budget in blocks (DESIGN.md \u{a7}16): \
              finished conversations keep their KV tail registered for \
              near-zero-prefill follow-up turns (0 = off; needs \
              --prefix-share)")
        .flag("speculate",
              "self-speculative decode (DESIGN.md §13): the \
               lowrank-free backbone drafts, the corrected model \
               verifies (needs --host-cache)")
        .opt("gamma", "0",
             "max draft tokens per lane per speculation round \
              (0 = manifest serve.spec gamma; needs --speculate)")
        .opt("shape", "oneshot",
             "traffic shape (DESIGN.md \u{a7}16): oneshot | chat \
              (multi-turn sessions) | agent (one long session) | \
              batch (n=4 parallel sampling)")
        .opt("trace-file", "",
             "write the flight-recorder Chrome trace here on exit \
              (DESIGN.md \u{a7}15; empty = off)")
        .opt("trace-capacity", "0",
             "flight-recorder ring capacity in events (DESIGN.md \
              \u{a7}15; 0 = default 4096)")
        .parse(argv)?;
    let batch = a.get_usize("batch")?;
    let (stats, records) =
        lqer::coordinator::loadtest::run_loadtest_traced(
            &m,
            &engine_cfg(&m, &a.get("model"), &a.get("method"), batch,
                        tokens_per_step_arg(&a, &m, batch)?,
                        a.get_flag("host-cache"),
                        a.get_flag("paged"), a.get_flag("prefix-share"),
                        a.get_usize("swap-blocks")?,
                        a.get_usize("session-blocks")?, spec_arg(&a)?,
                        a.get_usize("trace-capacity")?)?,
            a.get_usize("requests")?,
            a.get_usize("max-new")?,
            &a.get("shape"),
        )?;
    println!("{}", stats.report());
    let trace_file = a.get("trace-file");
    if !trace_file.is_empty() {
        std::fs::write(
            &trace_file,
            lqer::coordinator::trace::to_chrome_json(&records)
                .to_string(),
        )?;
        println!("wrote {trace_file} ({} events)", records.len());
    }
    Ok(())
}

/// `lqer trace` — dump / summarize a recorded flight-recorder file
/// (the Chrome `trace_event` JSON written by `--trace-file`,
/// DESIGN.md §15): per-event counts and accumulated span time, the
/// track labels, and optionally the newest N raw events.
fn trace_cmd(argv: &[String]) -> Result<()> {
    use lqer::util::json;

    let a = Args::new("trace", "dump / summarize a recorded trace file")
        .opt("file", "TRACE_serve.json", "Chrome trace JSON path")
        .opt("last", "0", "also print the newest N raw events")
        .parse(argv)?;
    let path = a.get("file");
    let v = json::parse_file(std::path::Path::new(&path))?;
    let events = v.req("traceEvents")?.as_array().unwrap_or(&[]);

    let mut tracks: Vec<(usize, String)> = Vec::new();
    // kind -> (count, accumulated span microseconds)
    let mut by_kind: Vec<(String, u64, f64)> = Vec::new();
    let mut n_events = 0usize;
    let mut spans = 0usize;
    let mut t_min = f64::INFINITY;
    let mut t_max = f64::NEG_INFINITY;
    for e in events {
        let ph = e.get("ph").and_then(|p| p.as_str()).unwrap_or("");
        let name = e
            .get("name")
            .and_then(|n| n.as_str())
            .unwrap_or("?")
            .to_string();
        if ph == "M" {
            if name == "thread_name" {
                let tid = e
                    .get("tid")
                    .and_then(|t| t.as_usize())
                    .unwrap_or(0);
                let label = e
                    .get("args")
                    .and_then(|x| x.get("name"))
                    .and_then(|n| n.as_str())
                    .unwrap_or("?")
                    .to_string();
                tracks.push((tid, label));
            }
            continue;
        }
        n_events += 1;
        let ts = e.get("ts").and_then(|t| t.as_f64()).unwrap_or(0.0);
        let dur = e.get("dur").and_then(|d| d.as_f64()).unwrap_or(0.0);
        if dur > 0.0 {
            spans += 1;
        }
        t_min = t_min.min(ts);
        t_max = t_max.max(ts + dur);
        match by_kind.iter_mut().find(|(k, _, _)| *k == name) {
            Some(row) => {
                row.1 += 1;
                row.2 += dur;
            }
            None => by_kind.push((name, 1, dur)),
        }
    }

    let mut t = Table::new(
        &format!("trace summary — {path}"),
        &["event", "count", "span ms"],
    );
    for (kind, count, dur_us) in &by_kind {
        t.row(vec![
            kind.clone(),
            count.to_string(),
            format!("{:.2}", dur_us / 1e3),
        ]);
    }
    print!("{}", t.render());
    tracks.sort_unstable();
    let labels: Vec<&str> =
        tracks.iter().map(|(_, l)| l.as_str()).collect();
    println!(
        "{n_events} events ({spans} spans) on {} tracks [{}] over \
         {:.2} ms",
        tracks.len(),
        labels.join(", "),
        if t_max > t_min { (t_max - t_min) / 1e3 } else { 0.0 },
    );
    let last = a.get_usize("last")?;
    if last > 0 {
        let raw: Vec<&json::Value> = events
            .iter()
            .filter(|e| {
                e.get("ph").and_then(|p| p.as_str()) != Some("M")
            })
            .collect();
        for e in raw.iter().skip(raw.len().saturating_sub(last)) {
            println!("{e}");
        }
    }
    Ok(())
}

/// `lqer bench <suite>` — synthetic engine benchmarks that need no
/// artifacts or PJRT (they drive the deterministic FakeBackend).
fn bench(argv: &[String]) -> Result<()> {
    let a = Args::new("bench", "synthetic engine benchmarks")
        .pos("suite",
             "bench suite: kv | kvshared | chunked | spec | sessions")
        .opt("batch", "4", "decode lanes")
        .opt("requests", "16", "concurrent requests (4x lanes default)")
        .opt("max-new", "12", "max tokens per request")
        .opt("block-size", "8", "paged block size (token rows)")
        .opt("blocks", "0", "usable pool blocks (0 = lanes * t_max / bs)")
        .opt("gamma", "4", "spec suite: max draft tokens per round")
        .opt("out", "", "output JSON path (default per suite)")
        .parse(argv)?;
    match a.get_pos(0) {
        Some("kv") => bench_kv(&a),
        Some("kvshared") => bench_kvshared(&a),
        Some("chunked") => bench_chunked(&a),
        Some("spec") => bench_spec(&a),
        Some("sessions") => bench_sessions(&a),
        other => anyhow::bail!(
            "unknown bench suite {:?} (expected: kv, kvshared, chunked, \
             spec, sessions)",
            other
        ),
    }
}

/// Paged-vs-baseline KV bench on a synthetic mixed-length workload:
/// emits BENCH_kvpaged.json with block occupancy, utilization,
/// preemptions, and throughput.  The baseline is the flat cache under
/// `AdmissionPolicy::RejectOnFull` — an instant-shed policy for the
/// A/B, not the seed engine's unbounded-wait behavior.
fn bench_kv(a: &Args) -> Result<()> {
    use lqer::coordinator::testbackend::{FakeBackend, FakeCacheMode};
    use lqer::coordinator::Engine;
    use lqer::util::json;
    use lqer::util::rng::Rng;

    const VOCAB: usize = 48;
    const LAYERS: usize = 2;
    const DIM: usize = 8;
    const T_MAX: usize = 64;
    const EOS: u32 = 2;
    let buckets = vec![8usize, 32];

    let batch = a.get_usize("batch")?;
    let requests = a.get_usize("requests")?;
    let max_new = a.get_usize("max-new")?;
    let bs = a.get_usize("block-size")?;
    anyhow::ensure!(T_MAX % bs == 0 && buckets.iter().all(|b| b % bs == 0),
                    "--block-size must divide {buckets:?} and {T_MAX}");
    let blocks = match a.get_usize("blocks")? {
        0 => batch * T_MAX / bs,
        n => n,
    };

    // Mixed-length workload: short and long prompts, varied budgets.
    let mk_requests = || -> Vec<Request> {
        let mut rng = Rng::new(1234);
        (0..requests as u64)
            .map(|i| {
                let plen = 1 + rng.below(24);
                Request {
                    id: i + 1,
                    prompt: (0..plen)
                        .map(|_| rng.below(VOCAB) as u32)
                        .collect(),
                    max_new_tokens: 1 + rng.below(max_new),
                    sampling: Sampling::Greedy,
                    priority: Priority::Normal,
                    n: 1,
                    beams: 0,
                    session: None,
                }
            })
            .collect()
    };

    let drive = |mut engine: Engine<FakeBackend>|
        -> Result<lqer::coordinator::EngineMetrics> {
        let mut rxs = Vec::new();
        for r in mk_requests() {
            let (tx, rx) = std::sync::mpsc::channel();
            engine.enqueue(r, tx);
            rxs.push(rx);
        }
        let mut guard = 0;
        while engine.has_work() {
            engine.tick();
            guard += 1;
            anyhow::ensure!(guard < 1_000_000, "engine did not drain");
        }
        for rx in rxs {
            rx.recv().map_err(|_| anyhow::anyhow!("reply dropped"))?;
        }
        Ok(engine.metrics_snapshot())
    };

    let base = EngineConfig {
        model: "fake".into(),
        method: "fake".into(),
        decode_batch: batch,
        prefill_buckets: buckets.clone(),
        tokens_per_step: 0, // auto: batch + largest bucket
        host_cache: true,
        paged: None,
        spec: None,
        admission: AdmissionPolicy::default(),
        trace_capacity: 0,
    };

    // Paged engine: bounded waiting queue, preemption under pressure.
    let paged_cfg = EngineConfig {
        paged: Some(PagedKvConfig {
            block_size: bs,
            num_blocks: blocks + 1,
            prefix_sharing: false,
            swap_blocks: 0,
            session_blocks: 0,
        }),
        admission: AdmissionPolicy::Wait {
            queue_depth: requests.max(16),
            deadline_ms: 0,
        },
        ..base.clone()
    };
    let paged_m = drive(Engine::with_backend(
        FakeBackend::new_paged(
            FakeCacheMode::Host, VOCAB, LAYERS, DIM, T_MAX, batch,
            blocks + 1, bs,
        ),
        paged_cfg,
        EOS,
    ))?;

    // Baseline engine: flat lanes, instant reject when capacity is gone.
    let shed_cfg = EngineConfig {
        admission: AdmissionPolicy::RejectOnFull,
        ..base
    };
    let shed_m = drive(Engine::with_backend(
        FakeBackend::new(FakeCacheMode::Host, VOCAB, LAYERS, DIM, T_MAX,
                         batch),
        shed_cfg,
        EOS,
    ))?;

    let side = |m: &lqer::coordinator::EngineMetrics| {
        json::obj(vec![
            ("completed", json::num(m.completed as f64)),
            ("rejected", json::num(m.rejected as f64)),
            ("expired", json::num(m.expired as f64)),
            ("preemptions", json::num(m.preemptions as f64)),
            ("tokens", json::num(m.tokens_generated as f64)),
            ("tokens_per_sec", json::num(m.decode_tokens_per_sec())),
            ("mean_batch_occupancy",
             json::num(m.mean_batch_occupancy())),
            ("kv_blocks_total", json::num(m.kv_blocks_total as f64)),
            ("kv_utilization_mean_pct", json::num(m.kv_util.mean())),
            ("kv_utilization_peak_pct", json::num(m.kv_util.max())),
        ])
    };
    let out = json::obj(vec![
        ("suite", json::s("kv")),
        ("batch", json::num(batch as f64)),
        ("requests", json::num(requests as f64)),
        ("block_size", json::num(bs as f64)),
        ("usable_blocks", json::num(blocks as f64)),
        ("paged", side(&paged_m)),
        ("flat_reject_on_full", side(&shed_m)),
    ]);
    let path = match a.get("out").as_str() {
        "" => "BENCH_kvpaged.json".to_string(),
        p => p.to_string(),
    };
    std::fs::write(&path, out.to_string())?;

    let mut t = Table::new(
        &format!(
            "paged KV bench — {requests} requests x {batch} lanes \
             (block {bs} rows, {blocks} blocks)"
        ),
        &["engine", "done", "rejected", "preempted", "occupancy",
          "kv peak %", "tok/s"],
    );
    for (name, m) in
        [("paged", &paged_m), ("flat/reject-on-full", &shed_m)]
    {
        t.row(vec![
            name.into(),
            format!("{}/{}", m.completed, m.submitted),
            (m.rejected + m.expired).to_string(),
            m.preemptions.to_string(),
            format!("{:.2}", m.mean_batch_occupancy()),
            format!("{:.0}", m.kv_util.max()),
            format!("{:.0}", m.decode_tokens_per_sec()),
        ]);
    }
    print!("{}", t.render());
    println!("wrote {path}");
    Ok(())
}

/// Shared-prefix overload + preemption-recovery bench (DESIGN.md §11),
/// on the deterministic FakeBackend:
///
/// * **overload** — N requests with one identical prompt against an
///   instant-shed (`RejectOnFull`) paged engine at equal pool size,
///   prefix sharing on vs off.  Sharing maps the prompt's blocks once,
///   so admission capacity is bounded by private decode blocks instead
///   of full prompt copies; the JSON records both `completed` counts
///   and their ratio (the acceptance bar is >= 2x).
/// * **recovery** — a starved pool that must preempt, with the host
///   swap pool on vs off.  Swap preserves the sequence (no re-prefill,
///   no token recompute); the JSON records preemption counters and mean
///   total latency of both engines.
fn bench_kvshared(a: &Args) -> Result<()> {
    use lqer::coordinator::testbackend::{FakeBackend, FakeCacheMode};
    use lqer::coordinator::{Engine, EngineMetrics};
    use lqer::util::json;

    const VOCAB: usize = 48;
    const LAYERS: usize = 2;
    const DIM: usize = 8;
    const T_MAX: usize = 64;
    const BS: usize = 8;
    // EOS outside the vocab: streams never end early by chance, so the
    // block arithmetic below is exact.
    const NO_EOS: u32 = VOCAB as u32 + 1;
    let buckets = vec![8usize, 32];

    let requests = a.get_usize("requests")?.clamp(4, 16);
    // One identical 3-block prompt (24 tokens) per request; 6 decode
    // tokens spill into one private block each.  8 usable blocks hold
    // two unshared copies — or one shared copy plus 5 private tails.
    let prompt: Vec<u32> = (0..24).map(|i| (i % 7) as u32 + 10).collect();
    let usable = 8usize;
    let mk_requests = |n: usize| -> Vec<Request> {
        (0..n as u64)
            .map(|i| Request {
                id: i + 1,
                prompt: prompt.clone(),
                max_new_tokens: 6,
                sampling: Sampling::Greedy,
                priority: Priority::Normal,
                n: 1,
                beams: 0,
                session: None,
            })
            .collect()
    };

    let drive = |mut engine: Engine<FakeBackend>, reqs: Vec<Request>|
        -> Result<EngineMetrics> {
        let mut rxs = Vec::new();
        for r in reqs {
            let (tx, rx) = std::sync::mpsc::channel();
            engine.enqueue(r, tx);
            rxs.push(rx);
        }
        let mut guard = 0;
        while engine.has_work() {
            engine.tick();
            guard += 1;
            anyhow::ensure!(guard < 1_000_000, "engine did not drain");
        }
        for rx in rxs {
            rx.recv().map_err(|_| anyhow::anyhow!("reply dropped"))?;
        }
        Ok(engine.metrics_snapshot())
    };

    let cfg = |sharing: bool, swap: usize, admission: AdmissionPolicy|
        -> EngineConfig {
        EngineConfig {
            model: "fake".into(),
            method: "fake".into(),
            decode_batch: requests,
            prefill_buckets: buckets.clone(),
            tokens_per_step: 0, // auto: batch + largest bucket
            host_cache: false,
            paged: Some(PagedKvConfig {
                block_size: BS,
                num_blocks: usable + 1,
                prefix_sharing: sharing,
                swap_blocks: swap,
                session_blocks: 0,
            }),
            spec: None,
            admission,
            trace_capacity: 0,
        }
    };
    let backend = || {
        FakeBackend::new_paged(
            FakeCacheMode::Host, VOCAB, LAYERS, DIM, T_MAX, requests,
            usable + 1, BS,
        )
    };

    // --- overload: admission capacity, sharing on vs off --------------
    let shared_m = drive(
        Engine::with_backend(
            backend(),
            cfg(true, 0, AdmissionPolicy::RejectOnFull),
            NO_EOS,
        ),
        mk_requests(requests),
    )?;
    let unshared_m = drive(
        Engine::with_backend(
            backend(),
            cfg(false, 0, AdmissionPolicy::RejectOnFull),
            NO_EOS,
        ),
        mk_requests(requests),
    )?;
    let ratio =
        shared_m.completed as f64 / (unshared_m.completed.max(1) as f64);

    // --- recovery: starved pool, swap vs re-prefill -------------------
    let starved = |swap: usize| -> Result<EngineMetrics> {
        let wait =
            AdmissionPolicy::Wait { queue_depth: 64, deadline_ms: 0 };
        let mut cfg = cfg(false, swap, wait);
        cfg.decode_batch = 2;
        cfg.paged = Some(PagedKvConfig {
            block_size: BS,
            num_blocks: 5 + 1,
            prefix_sharing: false,
            swap_blocks: swap,
            session_blocks: 0,
        });
        let reqs: Vec<Request> = (1..=2u64)
            .map(|id| Request {
                id,
                prompt: (0..14)
                    .map(|j| ((id as usize + j) % 5) as u32 + 10)
                    .collect(),
                max_new_tokens: 12,
                sampling: Sampling::Greedy,
                priority: Priority::Normal,
                n: 1,
                beams: 0,
                session: None,
            })
            .collect();
        drive(
            Engine::with_backend(
                FakeBackend::new_paged(
                    FakeCacheMode::Host, VOCAB, LAYERS, DIM, T_MAX, 2,
                    5 + 1, BS,
                ),
                cfg,
                NO_EOS,
            ),
            reqs,
        )
    };
    let swap_m = starved(8)?;
    let reprefill_m = starved(0)?;

    let side = |m: &EngineMetrics| {
        json::obj(vec![
            ("completed", json::num(m.completed as f64)),
            ("rejected", json::num(m.rejected as f64)),
            ("preemptions", json::num(m.preemptions as f64)),
            ("swap_outs", json::num(m.swap_outs as f64)),
            ("swap_ins", json::num(m.swap_ins as f64)),
            ("cow_copies", json::num(m.cow_copies as f64)),
            ("prefix_hit_blocks",
             json::num(m.prefix_hit_blocks as f64)),
            ("prefix_bytes_saved",
             json::num(m.prefix_bytes_saved as f64)),
            ("tokens", json::num(m.tokens_generated as f64)),
            ("tokens_per_sec", json::num(m.decode_tokens_per_sec())),
            ("total_ms_mean", json::num(m.total_ms.mean())),
            ("kv_utilization_peak_pct", json::num(m.kv_util.max())),
        ])
    };
    let out = json::obj(vec![
        ("suite", json::s("kvshared")),
        ("lanes", json::num(requests as f64)),
        ("requests", json::num(requests as f64)),
        ("block_size", json::num(BS as f64)),
        ("usable_blocks", json::num(usable as f64)),
        ("prompt_blocks", json::num((prompt.len() / BS) as f64)),
        ("shared", side(&shared_m)),
        ("unshared", side(&unshared_m)),
        ("capacity_ratio", json::num(ratio)),
        ("recovery_swap", side(&swap_m)),
        ("recovery_reprefill", side(&reprefill_m)),
    ]);
    let path = match a.get("out").as_str() {
        "" => "BENCH_kvshared.json".to_string(),
        p => p.to_string(),
    };
    std::fs::write(&path, out.to_string())?;

    let mut t = Table::new(
        &format!(
            "shared-prefix KV bench — {requests} identical prompts, \
             {usable} blocks (block {BS} rows)"
        ),
        &["engine", "done", "rejected", "preempted", "swap out/in",
          "cow", "prefix hits"],
    );
    for (name, m) in [
        ("paged+shared", &shared_m),
        ("paged", &unshared_m),
        ("starved+swap", &swap_m),
        ("starved", &reprefill_m),
    ] {
        t.row(vec![
            name.into(),
            format!("{}/{}", m.completed, m.submitted),
            m.rejected.to_string(),
            m.preemptions.to_string(),
            format!("{}/{}", m.swap_outs, m.swap_ins),
            m.cow_copies.to_string(),
            m.prefix_hit_blocks.to_string(),
        ]);
    }
    print!("{}", t.render());
    println!(
        "admission capacity: shared {} vs unshared {} ({ratio:.1}x)",
        shared_m.completed, unshared_m.completed
    );
    println!("wrote {path}");
    Ok(())
}

/// Chunked-prefill inter-token-latency bench (DESIGN.md §12), on the
/// deterministic FakeBackend under a mixed long-prompt/short-decode
/// overload: long prompts keep being admitted while short sequences
/// decode.  Two identical paged engines differ only in the per-tick
/// token budget —
///
/// * **chunked**: `batch + block_size`, so a long prompt streams in
///   block-sized slices and each tick's prefill work is bounded;
/// * **monolithic**: `batch + largest bucket`, so a whole prompt
///   prefills inside one tick (the legacy admit-then-decode behavior)
///   and every running decode stalls behind it.
///
/// The headline number is the p99 inter-token latency of the decode
/// stream (`itl_ms`); the JSON also records the decode-stall gauge and
/// per-tick packed-token stats.  `itl_p99_speedup` (monolithic p99 /
/// chunked p99) is the guarded ratio — wall-clock based, so the CI
/// guard treats it like the other machine-dependent bench metrics.
fn bench_chunked(a: &Args) -> Result<()> {
    use lqer::coordinator::testbackend::{FakeBackend, FakeCacheMode};
    use lqer::coordinator::{Engine, EngineMetrics};
    use lqer::util::json;
    use lqer::util::rng::Rng;

    // A model big enough that a 96-token prefill costs real wall-clock
    // on the fake backend (the stall being measured), while one decode
    // step stays cheap.
    const VOCAB: usize = 48;
    const LAYERS: usize = 4;
    const DIM: usize = 32;
    const T_MAX: usize = 128;
    const BS: usize = 16;
    // EOS outside the vocab: streams run to max_new_tokens, so both
    // engines sample identical ITL counts.
    const NO_EOS: u32 = VOCAB as u32 + 1;
    let buckets = vec![16usize, 96];

    let batch = a.get_usize("batch")?;
    let requests = a.get_usize("requests")?.max(12);
    let usable = batch * T_MAX / BS; // same memory as a flat cache

    // Mixed overload: every 4th request is a long prompt (~5 blocks),
    // the rest are short prompts that decode for a while — their token
    // gaps are what the long prefills stall.
    let mk_requests = || -> Vec<Request> {
        let mut rng = Rng::new(7);
        (0..requests as u64)
            .map(|i| {
                let long = i % 4 == 2;
                let plen = if long {
                    80 + rng.below(11)
                } else {
                    2 + rng.below(5)
                };
                Request {
                    id: i + 1,
                    prompt: (0..plen)
                        .map(|_| rng.below(VOCAB) as u32)
                        .collect(),
                    max_new_tokens: if long { 4 } else { 24 },
                    sampling: Sampling::Greedy,
                    priority: Priority::Normal,
                    n: 1,
                    beams: 0,
                    session: None,
                }
            })
            .collect()
    };

    let drive = |tokens_per_step: usize| -> Result<EngineMetrics> {
        let cfg = EngineConfig {
            model: "fake".into(),
            method: "fake".into(),
            decode_batch: batch,
            prefill_buckets: buckets.clone(),
            tokens_per_step,
            host_cache: false,
            paged: Some(PagedKvConfig {
                block_size: BS,
                num_blocks: usable + 1,
                prefix_sharing: false,
                swap_blocks: 0,
                session_blocks: 0,
            }),
            spec: None,
            admission: AdmissionPolicy::Wait {
                queue_depth: requests.max(16),
                deadline_ms: 0,
            },
            trace_capacity: 0,
        };
        let mut engine = Engine::with_backend(
            FakeBackend::new_paged(
                FakeCacheMode::Host, VOCAB, LAYERS, DIM, T_MAX, batch,
                usable + 1, BS,
            ),
            cfg,
            NO_EOS,
        );
        let mut rxs = Vec::new();
        for r in mk_requests() {
            let (tx, rx) = std::sync::mpsc::channel();
            engine.enqueue(r, tx);
            rxs.push(rx);
        }
        let mut guard = 0;
        while engine.has_work() {
            engine.tick();
            guard += 1;
            anyhow::ensure!(guard < 1_000_000, "engine did not drain");
        }
        for rx in rxs {
            rx.recv().map_err(|_| anyhow::anyhow!("reply dropped"))?;
        }
        Ok(engine.metrics_snapshot())
    };

    let chunked_budget = batch + BS;
    let mono_budget = batch + buckets.iter().max().copied().unwrap();
    let chunked_m = drive(chunked_budget)?;
    let mono_m = drive(mono_budget)?;
    let speedup = mono_m.itl_ms.percentile(99.0)
        / chunked_m.itl_ms.percentile(99.0).max(1e-9);

    let side = |m: &EngineMetrics| {
        json::obj(vec![
            ("completed", json::num(m.completed as f64)),
            ("tokens", json::num(m.tokens_generated as f64)),
            ("itl_ms_p50", json::num(m.itl_ms.percentile(50.0))),
            ("itl_ms_p99", json::num(m.itl_ms.percentile(99.0))),
            ("itl_ms_max", json::num(m.itl_ms.max())),
            ("ttft_ms_p99", json::num(m.ttft_ms.percentile(99.0))),
            ("decode_stall_ms", json::num(m.decode_stall_ms())),
            ("packed_tokens_mean", json::num(m.packed_tokens.mean())),
            ("packed_tokens_max", json::num(m.packed_tokens.max())),
            ("prefill_chunks", json::num(m.prefill_steps as f64)),
            ("tokens_per_sec", json::num(m.decode_tokens_per_sec())),
        ])
    };
    let out = json::obj(vec![
        ("suite", json::s("chunked")),
        ("lanes", json::num(batch as f64)),
        ("requests", json::num(requests as f64)),
        ("block_size", json::num(BS as f64)),
        ("chunked_tokens_per_step", json::num(chunked_budget as f64)),
        ("monolithic_tokens_per_step", json::num(mono_budget as f64)),
        ("chunked", side(&chunked_m)),
        ("monolithic", side(&mono_m)),
        ("itl_p99_speedup", json::num(speedup)),
    ]);
    let path = match a.get("out").as_str() {
        "" => "BENCH_chunked.json".to_string(),
        p => p.to_string(),
    };
    std::fs::write(&path, out.to_string())?;

    let mut t = Table::new(
        &format!(
            "chunked-prefill ITL bench — {requests} requests x {batch} \
             lanes (block {BS} rows)"
        ),
        &["engine", "budget/tick", "itl p50", "itl p99", "itl max",
          "stall ms", "chunks"],
    );
    for (name, budget, m) in [
        ("chunked", chunked_budget, &chunked_m),
        ("monolithic", mono_budget, &mono_m),
    ] {
        t.row(vec![
            name.into(),
            budget.to_string(),
            format!("{:.2}", m.itl_ms.percentile(50.0)),
            format!("{:.2}", m.itl_ms.percentile(99.0)),
            format!("{:.2}", m.itl_ms.max()),
            format!("{:.1}", m.decode_stall_ms()),
            m.prefill_steps.to_string(),
        ]);
    }
    print!("{}", t.render());
    println!(
        "p99 inter-token latency: monolithic {:.2} ms vs chunked \
         {:.2} ms ({speedup:.2}x)",
        mono_m.itl_ms.percentile(99.0),
        chunked_m.itl_ms.percentile(99.0)
    );
    println!("wrote {path}");
    Ok(())
}

/// Self-speculative decoding bench (DESIGN.md §13) on the deterministic
/// FakeBackend: the same workload runs through a plain engine and a
/// speculating one, the token streams are asserted bit-identical, and
/// throughput is compared under a *modeled* per-step cost — the weight
/// bits each pass streams, derived from a real serving plan
/// (`l2qer-w2a8`) and its `draft_of` clamp.  The draft pass skips the
/// `(m+n)k` low-rank term, so one draft step costs `draft_bits /
/// full_bits` of a corrected step; a speculation round of `g` drafts +
/// one verify emits `accepted + 1` tokens for `g * C_draft + C_full`
/// units, vs one token per `C_full` without speculation.
fn bench_spec(a: &Args) -> Result<()> {
    use lqer::coordinator::testbackend::{FakeBackend, FakeCacheMode};
    use lqer::coordinator::{trace, Engine, EngineMetrics};
    use lqer::util::json;
    use lqer::util::rng::Rng;

    const VOCAB: usize = 48;
    const LAYERS: usize = 2;
    const DIM: usize = 8;
    const T_MAX: usize = 64;
    // EOS outside the vocab: every request runs to max_new_tokens, so
    // both engines generate identical token counts by construction.
    const NO_EOS: u32 = VOCAB as u32 + 1;
    let buckets = vec![8usize, 32];

    let requests = a.get_usize("requests")?;
    let max_new = a.get_usize("max-new")?.max(8);
    let gamma = a.get_usize("gamma")?;
    anyhow::ensure!(gamma >= 1, "--gamma must be >= 1");

    let mk_requests = || -> Vec<Request> {
        let mut rng = Rng::new(99);
        (0..requests as u64)
            .map(|i| {
                let plen = 1 + rng.below(16);
                Request {
                    id: i + 1,
                    prompt: (0..plen)
                        .map(|_| rng.below(VOCAB) as u32)
                        .collect(),
                    max_new_tokens: max_new,
                    sampling: Sampling::Greedy,
                    priority: Priority::Normal,
                    n: 1,
                    beams: 0,
                    session: None,
                }
            })
            .collect()
    };

    // The modeled-cost drives run one lane: a decode step streams the
    // weights for exactly one token (baseline) or one per-lane
    // speculation round, so the modeled units below map 1:1 onto
    // metric counters.  The launch-economics drives run `batch` lanes,
    // with `serial` flipping the engine onto the retained per-lane
    // speculation loop.
    let drive = |batch: usize, spec: Option<SpecConfig>, serial: bool|
        -> Result<(EngineMetrics, Vec<Vec<u32>>, Vec<trace::TraceRecord>)> {
        let cfg = EngineConfig {
            model: "fake".into(),
            method: "fake".into(),
            decode_batch: batch,
            prefill_buckets: buckets.clone(),
            tokens_per_step: 0, // auto: batch + largest bucket
            host_cache: true,
            paged: None,
            spec,
            admission: AdmissionPolicy::default(),
            // Large enough that no event of this workload is evicted:
            // the SpecRound-vs-verify_steps equality below needs the
            // complete record.
            trace_capacity: 1 << 20,
        };
        let mut engine = Engine::with_backend(
            FakeBackend::new(FakeCacheMode::Host, VOCAB, LAYERS, DIM,
                             T_MAX, batch),
            cfg,
            NO_EOS,
        );
        engine.set_spec_serial(serial);
        let mut rxs = Vec::new();
        for r in mk_requests() {
            let (tx, rx) = std::sync::mpsc::channel();
            engine.enqueue(r, tx);
            rxs.push(rx);
        }
        let mut guard = 0;
        while engine.has_work() {
            engine.tick();
            guard += 1;
            anyhow::ensure!(guard < 1_000_000, "engine did not drain");
        }
        let mut streams = Vec::new();
        for rx in rxs {
            let r = rx.recv().map_err(|_| anyhow::anyhow!("reply dropped"))?;
            streams.push(r.tokens);
        }
        let records = engine.trace_snapshot();
        Ok((engine.metrics_snapshot(), streams, records))
    };

    let (base_m, base_streams, base_trace) = drive(1, None, false)?;
    let (spec_m, spec_streams, spec_trace) =
        drive(1, Some(SpecConfig { gamma }), false)?;
    anyhow::ensure!(
        spec_streams == base_streams,
        "speculative token streams diverged from the baseline \
         (the golden invariant — see rust/tests/spec_decode.rs)"
    );

    // The flight recorder doubles as a correctness instrument here:
    // every sequential token must have a Decoded event and every
    // verify pass exactly one SpecRound event.
    let decoded_events = base_trace
        .iter()
        .filter(|r| matches!(r.event, trace::TraceEvent::Decoded))
        .count() as u64;
    anyhow::ensure!(
        decoded_events == base_m.tokens_generated,
        "recorder lost decode events: {} Decoded vs {} tokens",
        decoded_events,
        base_m.tokens_generated
    );
    let spec_rounds = spec_trace
        .iter()
        .filter(|r| {
            matches!(r.event, trace::TraceEvent::SpecRound { .. })
        })
        .count() as u64;
    anyhow::ensure!(
        spec_rounds == spec_m.decode_steps,
        "recorder lost speculation rounds: {} SpecRound events vs {} \
         verify steps",
        spec_rounds,
        spec_m.decode_steps
    );

    // Recorder overhead: per-event emit cost measured on a
    // default-capacity ring, held against the measured mean tick time
    // (the ≤2% budget of DESIGN.md §15).
    let mut probe = trace::Recorder::new(0);
    let emits = 100_000u64;
    let probe_t0 = trace::now_ns();
    for i in 0..emits {
        probe.emit(i, i, Some(0), 0, trace::TraceEvent::Decoded);
    }
    let per_event_ns = trace::now_ns().saturating_sub(probe_t0) as f64
        / emits as f64;
    std::hint::black_box(&probe);
    let overhead_pct = 100.0
        * (spec_m.trace_events_total as f64 * per_event_ns)
        / spec_m.tick_ns.max(1) as f64;
    anyhow::ensure!(
        overhead_pct <= 2.0,
        "flight-recorder overhead {overhead_pct:.3}% of tick time \
         exceeds the 2% budget (DESIGN.md §15)"
    );

    // Modeled per-pass costs: avg streamed weight bits of the serving
    // plan vs its lowrank-clamped draft, on serve-class layer shapes.
    let plan = lqer::quant::spec::QuantSpec::from_method_name(
        "l2qer-w2a8",
    )?;
    let draft_plan = lqer::quant::spec::draft_of(&plan);
    let shapes = lqer::quant::spec::layer_shapes(256, 1024, 4);
    let c_full = plan.model_avg_bits(&shapes);
    let c_draft = draft_plan.model_avg_bits(&shapes);
    let units_spec = spec_m.draft_tokens as f64 * c_draft
        + spec_m.decode_steps as f64 * c_full;
    let units_base = base_m.decode_steps as f64 * c_full;
    anyhow::ensure!(
        spec_m.tokens_generated == base_m.tokens_generated,
        "token counts diverged: spec {} vs baseline {}",
        spec_m.tokens_generated,
        base_m.tokens_generated
    );
    let tokens = base_m.tokens_generated as f64;
    let speedup = units_base / units_spec.max(1e-9);

    // Launch economics on a multi-lane engine: the batched round must
    // collapse the per-lane B·(γ+1) launch pattern into at most γ
    // draft launches plus one verify launch per tick, while emitting
    // bit-identical streams to the retained per-lane loop.
    const LANES: usize = 4;
    let (b4_m, b4_streams, _) =
        drive(LANES, Some(SpecConfig { gamma }), false)?;
    let (s4_m, s4_streams, _) =
        drive(LANES, Some(SpecConfig { gamma }), true)?;
    anyhow::ensure!(
        b4_streams == s4_streams,
        "batched speculation diverged from the per-lane loop at \
         batch {LANES} (the golden invariant — see \
         rust/tests/spec_decode.rs)"
    );
    anyhow::ensure!(
        b4_m.verify_launches <= b4_m.ticks,
        "more than one verify launch per tick: {} launches over {} \
         ticks",
        b4_m.verify_launches,
        b4_m.ticks
    );
    anyhow::ensure!(
        b4_m.draft_launches <= gamma as u64 * b4_m.verify_launches,
        "more than γ draft launches per verify tick: {} draft vs {} \
         verify launches at γ {gamma}",
        b4_m.draft_launches,
        b4_m.verify_launches
    );
    if requests >= 2 * LANES {
        anyhow::ensure!(
            b4_m.verify_launches < b4_m.decode_steps,
            "batched verify never served more than one lane per \
             launch ({} launches for {} lane-rounds)",
            b4_m.verify_launches,
            b4_m.decode_steps
        );
        anyhow::ensure!(
            b4_m.draft_tokens > b4_m.draft_launches,
            "batched draft rounds never carried more than one lane \
             ({} tokens over {} launches)",
            b4_m.draft_tokens,
            b4_m.draft_launches
        );
    }
    let b4_launches = b4_m.draft_launches + b4_m.verify_launches;
    let s4_launches = s4_m.draft_launches + s4_m.verify_launches;
    let launches_per_token =
        b4_launches as f64 / b4_m.tokens_generated.max(1) as f64;
    let launch_reduction =
        s4_launches as f64 / b4_launches.max(1) as f64;

    let out = json::obj(vec![
        ("suite", json::s("spec")),
        ("requests", json::num(requests as f64)),
        ("max_new", json::num(max_new as f64)),
        ("gamma", json::num(gamma as f64)),
        ("cost_model", json::obj(vec![
            ("method", json::s("l2qer-w2a8")),
            ("full_bits", json::num(c_full)),
            ("draft_bits", json::num(c_draft)),
            ("cost_ratio", json::num(c_full / c_draft)),
        ])),
        ("speculative", json::obj(vec![
            ("completed", json::num(spec_m.completed as f64)),
            ("tokens", json::num(spec_m.tokens_generated as f64)),
            ("draft_tokens", json::num(spec_m.draft_tokens as f64)),
            ("accepted_tokens",
             json::num(spec_m.accepted_tokens as f64)),
            ("acceptance_rate", json::num(spec_m.acceptance_rate())),
            ("rewind_blocks", json::num(spec_m.rewind_blocks as f64)),
            ("verify_steps", json::num(spec_m.decode_steps as f64)),
            ("spec_rounds", json::num(spec_rounds as f64)),
            // Armed deterministic invariant: one SpecRound trace
            // event per verify step, always exactly 1.0.
            ("spec_rounds_per_verify",
             json::num(spec_rounds as f64
                       / spec_m.decode_steps.max(1) as f64)),
            ("modeled_units", json::num(units_spec)),
            ("modeled_tokens_per_kunit",
             json::num(1e3 * tokens / units_spec.max(1e-9))),
        ])),
        ("baseline", json::obj(vec![
            ("completed", json::num(base_m.completed as f64)),
            ("tokens", json::num(base_m.tokens_generated as f64)),
            ("decode_steps", json::num(base_m.decode_steps as f64)),
            ("modeled_units", json::num(units_base)),
            ("modeled_tokens_per_kunit",
             json::num(1e3 * tokens / units_base.max(1e-9))),
        ])),
        ("spec_speedup", json::num(speedup)),
        // Launch economics of the batched round at LANES lanes.
        // `launches_per_token` is armed lower-is-better in the guard;
        // the launch *counts* and the reduction ratio are recorded as
        // context (the hard bounds are the in-run ensure!s above).
        ("batched", json::obj(vec![
            ("decode_batch", json::num(LANES as f64)),
            ("completed", json::num(b4_m.completed as f64)),
            ("tokens", json::num(b4_m.tokens_generated as f64)),
            ("draft_launches",
             json::num(b4_m.draft_launches as f64)),
            ("verify_launches",
             json::num(b4_m.verify_launches as f64)),
            ("serial_launches", json::num(s4_launches as f64)),
            ("launch_reduction", json::num(launch_reduction)),
            ("launches_per_token", json::num(launches_per_token)),
        ])),
        // Wall-clock based, so reported but never armed in the guard.
        ("trace_overhead_pct", json::num(overhead_pct)),
    ]);
    let path = match a.get("out").as_str() {
        "" => "BENCH_spec.json".to_string(),
        p => p.to_string(),
    };
    std::fs::write(&path, out.to_string())?;

    let mut t = Table::new(
        &format!(
            "self-speculative decode bench — {requests} requests x \
             {max_new} tokens (gamma {gamma}, cost ratio {:.2})",
            c_full / c_draft
        ),
        &["engine", "tokens", "drafted", "accepted", "accept %",
          "steps", "units", "tok/kunit"],
    );
    for (name, m, units) in [
        ("speculative", &spec_m, units_spec),
        ("baseline", &base_m, units_base),
    ] {
        t.row(vec![
            name.into(),
            m.tokens_generated.to_string(),
            m.draft_tokens.to_string(),
            m.accepted_tokens.to_string(),
            if m.draft_tokens > 0 {
                format!("{:.0}", 100.0 * m.acceptance_rate())
            } else {
                "-".into()
            },
            m.decode_steps.to_string(),
            format!("{units:.0}"),
            format!("{:.2}", 1e3 * tokens / units.max(1e-9)),
        ]);
    }
    print!("{}", t.render());
    println!(
        "modeled decode speedup: {speedup:.2}x at {:.0}% acceptance \
         (streams bit-identical)",
        100.0 * spec_m.acceptance_rate()
    );
    println!(
        "flight recorder: {} events, {per_event_ns:.0} ns/event, \
         {overhead_pct:.3}% of tick time (budget 2%)",
        spec_m.trace_events_total
    );
    println!(
        "batched speculation ({LANES} lanes): {} draft + {} verify \
         launches for {} tokens ({launches_per_token:.2} \
         launches/token, {launch_reduction:.1}x fewer than per-lane)",
        b4_m.draft_launches,
        b4_m.verify_launches,
        b4_m.tokens_generated
    );
    println!("wrote {path}");
    Ok(())
}

/// Multi-turn session bench (DESIGN.md §16) on the deterministic
/// FakeBackend: one conversation runs two turns against an engine with
/// a session budget (the finished first turn parks its KV chain in the
/// prefix index) and against a cold engine that re-prefills from
/// scratch.  The block arithmetic is exact by construction — EOS sits
/// outside the vocabulary, so turn 1 generates exactly `max-new`
/// tokens and its chain covers `prompt + max-new - 1` rows (the last
/// sampled token is never written) — and the headline numbers are
/// deterministic: `turn2_prefill_rows` (rows the second turn still
/// had to prefill) and `prefill_saved_pct` (chain rows re-mapped from
/// the parked session instead of recomputed).
fn bench_sessions(a: &Args) -> Result<()> {
    use lqer::coordinator::testbackend::{FakeBackend, FakeCacheMode};
    use lqer::coordinator::{Engine, EngineMetrics};
    use lqer::util::json;

    const VOCAB: usize = 48;
    const LAYERS: usize = 2;
    const DIM: usize = 8;
    const T_MAX: usize = 64;
    const BS: usize = 8;
    // EOS outside the vocab: turns never end early, so the chain /
    // block arithmetic below is exact.
    const NO_EOS: u32 = VOCAB as u32 + 1;
    const SESSION: u64 = 7;
    let buckets = vec![8usize, 48];

    let max_new = 8usize;
    // Turn 1: a 3-block prompt (24 tokens).  Turn 2 replays the whole
    // visible history — prompt + the 8 generated tokens — plus a
    // 7-token user suffix: 39 rows, of which the first 24 (3 full
    // blocks) are resident in the parked session chain.
    let prompt1: Vec<u32> = (0..24).map(|i| (i % 7) as u32 + 10).collect();
    let suffix: Vec<u32> = (0..7).map(|i| (i % 5) as u32 + 20).collect();
    let usable = 16usize;

    let drive_turn = |engine: &mut Engine<FakeBackend>, id: u64,
                      prompt: Vec<u32>, session: Option<u64>|
        -> Result<Vec<u32>> {
        let (tx, rx) = std::sync::mpsc::channel();
        engine.enqueue(
            Request {
                id,
                prompt,
                max_new_tokens: max_new,
                sampling: Sampling::Greedy,
                priority: Priority::Normal,
                n: 1,
                beams: 0,
                session,
            },
            tx,
        );
        let mut guard = 0;
        while engine.has_work() {
            engine.tick();
            guard += 1;
            anyhow::ensure!(guard < 1_000_000, "engine did not drain");
        }
        let r = rx.recv().map_err(|_| anyhow::anyhow!("reply dropped"))?;
        anyhow::ensure!(
            r.finish == lqer::coordinator::FinishReason::Length,
            "turn {id} did not run to max-new: {:?}",
            r.finish
        );
        Ok(r.tokens)
    };

    let mk_engine = |sessions: bool| -> Engine<FakeBackend> {
        Engine::with_backend(
            FakeBackend::new_paged(
                FakeCacheMode::Host, VOCAB, LAYERS, DIM, T_MAX, 2,
                usable + 1, BS,
            ),
            EngineConfig {
                model: "fake".into(),
                method: "fake".into(),
                decode_batch: 2,
                prefill_buckets: buckets.clone(),
                tokens_per_step: 0, // auto: batch + largest bucket
                host_cache: false,
                paged: Some(PagedKvConfig {
                    block_size: BS,
                    num_blocks: usable + 1,
                    prefix_sharing: sessions,
                    swap_blocks: 0,
                    session_blocks: if sessions { 8 } else { 0 },
                }),
                spec: None,
                admission: AdmissionPolicy::Wait {
                    queue_depth: 16,
                    deadline_ms: 0,
                },
                trace_capacity: 0,
            },
            NO_EOS,
        )
    };

    // --- warm: session budget parks the turn-1 chain -------------------
    let mut warm = mk_engine(true);
    let turn1 = drive_turn(&mut warm, 1, prompt1.clone(), Some(SESSION))?;
    let m1 = warm.metrics_snapshot();
    // Chain rows: prompt + generated tokens except the never-written
    // last one; its whole-block prefix is what turn 2 can re-map.
    let chain_rows = prompt1.len() + turn1.len() - 1;
    let chain_blocks = chain_rows / BS;
    anyhow::ensure!(
        m1.sessions_live == 1,
        "turn 1 did not park a session (sessions_live {})",
        m1.sessions_live
    );
    let mut prompt2 = prompt1.clone();
    prompt2.extend_from_slice(&turn1);
    prompt2.extend_from_slice(&suffix);
    let turn2 =
        drive_turn(&mut warm, 2, prompt2.clone(), Some(SESSION))?;
    let m2 = warm.metrics_snapshot();
    let hit_blocks =
        (m2.prefix_hit_blocks - m1.prefix_hit_blocks) as usize;
    anyhow::ensure!(
        hit_blocks == chain_blocks,
        "turn 2 re-mapped {hit_blocks} blocks, want the chain's \
         {chain_blocks} full blocks"
    );
    anyhow::ensure!(
        m2.session_hits == 1,
        "turn 2 did not match the parked session ({} hits)",
        m2.session_hits
    );
    let turn2_prefill_rows = prompt2.len() - hit_blocks * BS;
    let prefill_saved_pct =
        100.0 * (hit_blocks * BS) as f64 / prompt2.len() as f64;

    // --- cold: no sharing, turn 2 re-prefills all 39 rows --------------
    let mut cold = mk_engine(false);
    let cold1 = drive_turn(&mut cold, 1, prompt1.clone(), None)?;
    anyhow::ensure!(
        cold1 == turn1,
        "session machinery changed turn-1 tokens (the golden \
         invariant — see rust/tests/fork_sessions.rs)"
    );
    let _ = drive_turn(&mut cold, 2, prompt2.clone(), None)?;
    let cold_m = cold.metrics_snapshot();

    let side = |m: &EngineMetrics| {
        json::obj(vec![
            ("completed", json::num(m.completed as f64)),
            ("tokens", json::num(m.tokens_generated as f64)),
            ("session_hits", json::num(m.session_hits as f64)),
            ("sessions_live", json::num(m.sessions_live as f64)),
            ("session_blocks_held",
             json::num(m.session_blocks_held as f64)),
            ("prefix_hit_blocks",
             json::num(m.prefix_hit_blocks as f64)),
            ("prefix_bytes_saved",
             json::num(m.prefix_bytes_saved as f64)),
            ("tokens_per_sec", json::num(m.decode_tokens_per_sec())),
            ("ttft_ms_p99", json::num(m.ttft_ms.percentile(99.0))),
        ])
    };
    let out = json::obj(vec![
        ("suite", json::s("sessions")),
        ("block_size", json::num(BS as f64)),
        ("usable_blocks", json::num(usable as f64)),
        ("turn1_prompt_rows", json::num(prompt1.len() as f64)),
        ("turn2_prompt_rows", json::num(prompt2.len() as f64)),
        ("chain_rows", json::num(chain_rows as f64)),
        ("chain_blocks", json::num(chain_blocks as f64)),
        ("session_hits", json::num(m2.session_hits as f64)),
        ("turn2_prefill_rows",
         json::num(turn2_prefill_rows as f64)),
        ("prefill_saved_pct", json::num(prefill_saved_pct)),
        ("warm", side(&m2)),
        ("cold", side(&cold_m)),
    ]);
    let path = match a.get("out").as_str() {
        "" => "BENCH_sessions.json".to_string(),
        p => p.to_string(),
    };
    std::fs::write(&path, out.to_string())?;

    let mut t = Table::new(
        &format!(
            "multi-turn session bench — 2 turns, block {BS} rows, \
             session budget 8 blocks"
        ),
        &["engine", "done", "session hits", "prefix hits",
          "turn-2 prefill rows", "saved %"],
    );
    for (name, m, rows, saved) in [
        ("warm (sessions)", &m2, turn2_prefill_rows,
         prefill_saved_pct),
        ("cold (re-prefill)", &cold_m, prompt2.len(), 0.0),
    ] {
        t.row(vec![
            name.into(),
            format!("{}/{}", m.completed, m.submitted),
            m.session_hits.to_string(),
            m.prefix_hit_blocks.to_string(),
            rows.to_string(),
            format!("{saved:.1}"),
        ]);
    }
    print!("{}", t.render());
    println!(
        "turn 2 prefilled {turn2_prefill_rows}/{} rows \
         ({prefill_saved_pct:.1}% re-mapped from the parked session); \
         {} tokens match the cold engine bit-for-bit",
        prompt2.len(),
        turn2.len()
    );
    println!("wrote {path}");
    Ok(())
}

fn eval_ppl(argv: &[String]) -> Result<()> {
    let m = manifest()?;
    let a = Args::new("eval-ppl", "perplexity on the held-out stream")
        .opt("model", "opt-mini", "model name")
        .opt("method", "", "method (empty = all runs for the model)")
        .opt("windows", "16", "number of (B,T) windows (0 = all)")
        .parse(argv)?;
    let rt = Runtime::cpu()?;
    let stream =
        lqer::util::read_u16_file(&m.data_dir().join("test.u16"))?;
    let methods = if a.get("method").is_empty() {
        m.methods_for(&a.get("model"))
    } else {
        vec![a.get("method")]
    };
    let mut t = Table::new("perplexity", &["model", "method", "ppl",
                                           "nll", "tokens"]);
    for method in methods {
        let runner = ModelRunner::new(&m, &a.get("model"), &method)?;
        let r = eval::ppl::perplexity(&rt, &m, &runner, &stream,
                                      a.get_usize("windows")?)?;
        t.row(vec![
            a.get("model"),
            method,
            format!("{:.3}", r.ppl),
            format!("{:.4}", r.nll),
            r.tokens.to_string(),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn eval_tasks(argv: &[String]) -> Result<()> {
    let m = manifest()?;
    let a = Args::new("eval-tasks", "six downstream tasks")
        .opt("model", "opt-mini", "model name")
        .opt("method", "l2qer-w4a8", "method")
        .opt("per-task", "32", "items per task (0 = all)")
        .parse(argv)?;
    let rt = Runtime::cpu()?;
    let items =
        eval::tasks::load_tasks(&m.data_dir().join("tasks.json"))?;
    let runner = ModelRunner::new(&m, &a.get("model"), &a.get("method"))?;
    let scores = eval::tasks::evaluate(&rt, &m, &runner, &items,
                                       a.get_usize("per-task")?)?;
    let mut t = Table::new("downstream accuracy",
                           &["task", "accuracy", "items"]);
    for (name, acc, n) in &scores.per_task {
        t.row(vec![name.clone(), format!("{:.1}%", acc * 100.0),
                   n.to_string()]);
    }
    t.row(vec!["AVERAGE".into(),
               format!("{:.1}%", scores.average() * 100.0), "".into()]);
    print!("{}", t.render());
    Ok(())
}

fn judge(argv: &[String]) -> Result<()> {
    let m = manifest()?;
    let a = Args::new("judge", "pairwise win rate, FP16 model as judge")
        .opt("model", &m.serve.model, "model name")
        .opt("a", "l2qer-w4a8", "generation method A")
        .opt("b", "fp16", "generation method B (reference)")
        .opt("n", "32", "number of prompts")
        .opt("max-new", "16", "tokens per generation")
        .parse(argv)?;
    let result = lqer::coordinator::loadtest::run_judge(
        &m, &a.get("model"), &a.get("a"), &a.get("b"),
        a.get_usize("n")?, a.get_usize("max-new")?)?;
    println!(
        "{} vs {} on {}: win rate {:.1}%  length-controlled {:.1}%  \
         (n={}, ties={})",
        a.get("a"), a.get("b"), a.get("model"),
        result.win_rate() * 100.0, result.lc_win_rate() * 100.0,
        result.n, result.ties
    );
    Ok(())
}

fn spectra(argv: &[String]) -> Result<()> {
    let _ = Args::new("spectra", "Figure 1a singular-value series")
        .parse(argv)?;
    let m = manifest()?;
    let s = analysis::fig1a_spectra(&m.dir.join("fig1a"))?;
    println!("layer: {} (W3 MXINT quantization error)", s.layer);
    let mut t = Table::new("normalized singular values (Figure 1a)",
                           &["i", "LQER sigma_i(E_q)",
                             "L2QER sigma_i(S E_q)"]);
    for i in (0..s.lqer.len()).step_by(8.max(s.lqer.len() / 24)) {
        t.row(vec![i.to_string(), format!("{:.4}", s.lqer[i]),
                   format!("{:.4}", s.l2qer[i])]);
    }
    print!("{}", t.render());
    for k in [8, 16, 32, 64] {
        println!(
            "top-{k} energy: LQER {:.3}  L2QER {:.3}",
            analysis::Spectra::energy_at(&s.lqer, k),
            analysis::Spectra::energy_at(&s.l2qer, k)
        );
    }
    Ok(())
}

fn rank_sweep(argv: &[String]) -> Result<()> {
    let m = manifest()?;
    let a = Args::new("rank-sweep", "Figure 3: perplexity vs rank")
        .opt("windows", "8", "ppl windows per point")
        .parse(argv)?;
    let rt = Runtime::cpu()?;
    let stream =
        lqer::util::read_u16_file(&m.data_dir().join("test.u16"))?;
    let model = m.fig3_model.clone();
    let mut t = Table::new(
        "Figure 3: W2A8 perplexity vs rank k",
        &["k", "LQER ppl", "L2QER ppl"],
    );
    let windows = a.get_usize("windows")?;
    for &k in &m.fig3_ranks {
        let mut row = vec![k.to_string()];
        for prefix in ["lqer", "l2qer"] {
            let method = format!("{prefix}-w2a8-k{k}");
            let runner = ModelRunner::new(&m, &model, &method)?;
            let r = eval::ppl::perplexity(&rt, &m, &runner, &stream,
                                          windows)?;
            row.push(format!("{:.3}", r.ppl));
        }
        t.row(row);
    }
    print!("{}", t.render());
    Ok(())
}

fn plan_cmd(argv: &[String]) -> Result<()> {
    let m = manifest()?;
    let a = Args::new("plan", "inspect a run's quantization plan")
        .opt("model", &m.serve.model, "model name")
        .opt("method", "l2qer-w4a8", "PTQ method / run name")
        .flag("json", "print the canonical plan JSON and exit")
        .parse(argv)?;
    let model = a.get("model");
    let method = a.get("method");
    let run = m.run(&model, &method)?;
    if a.get_flag("json") {
        println!("{}", run.plan.to_canonical_json());
        return Ok(());
    }
    let mi = m.model(&model)?;
    let shapes = lqer::quant::spec::layer_shapes(mi.d, mi.ffn, mi.layers);
    let mut t = Table::new(
        &format!("quantization plan: {model} / {method}"),
        &["layer", "weight", "act", "algo", "k", "bits/elem", "overhead",
          "PE LUTs"],
    );
    for (name, (mw, nw)) in &shapes {
        let ls = run.plan.resolve(name);
        let bits = ls.avg_bits(*mw, *nw);
        let base = ls.weight.avg_bits();
        t.row(vec![
            name.clone(),
            ls.weight.to_string(),
            ls.act.as_str().to_string(),
            ls.algo.as_str().to_string(),
            ls.lowrank
                .map(|lr| lr.k.to_string())
                .unwrap_or_else(|| "-".into()),
            format!("{bits:.4}"),
            format!("+{:.1}%", (bits / base - 1.0) * 100.0),
            hwcost::area_for_layer(&method, ls)
                .map(|pe| format!("{:.0}", pe.total))
                .unwrap_or_else(|| "-".into()),
        ]);
    }
    print!("{}", t.render());
    println!(
        "model avg weight bits: {:.4}  graph: {}  overrides: {}",
        run.plan.model_avg_bits(&shapes),
        run.graph,
        run.plan.overrides.len()
    );
    // The speculation draft plan (DESIGN.md §13): lowrank clamped off.
    let draft = lqer::quant::spec::draft_of(&run.plan);
    if draft != run.plan {
        let full_bits = run.plan.model_avg_bits(&shapes);
        let draft_bits = draft.model_avg_bits(&shapes);
        let area = match (
            hwcost::area_for_plan(&method, &run.plan),
            hwcost::area_for_plan(&method, &draft),
        ) {
            (Some(f), Some(d)) => format!(
                "  PE LUTs: {:.0} -> {:.0} ({:+.1}%)",
                f.total,
                d.total,
                (d.total / f.total - 1.0) * 100.0
            ),
            _ => String::new(),
        };
        println!(
            "draft plan (lowrank off): {draft_bits:.4} bits \
             ({:+.4} vs full, {:.2}x cheaper stream){area}",
            draft_bits - full_bits,
            full_bits / draft_bits
        );
    }
    // Cross-check the plan-derived numbers against the python-side meta
    // (the acceptance contract: both languages derive identical bits
    // from one plan).
    match m.run_meta(run) {
        Ok(meta) => {
            let pb = meta.get("plan_bits").ok_or_else(|| {
                anyhow::anyhow!(
                    "meta {} has no plan_bits (rebuild artifacts)",
                    run.meta.display()
                )
            })?;
            let mut checked = 0;
            for (name, (mw, nw)) in &shapes {
                let want = pb.f64_at(name)?;
                let got = run.plan.resolve(name).avg_bits(*mw, *nw);
                anyhow::ensure!(
                    (got - want).abs() < 1e-9,
                    "{name}: rust plan bits {got} != python meta {want}"
                );
                checked += 1;
            }
            let py_avg = meta.f64_at("plan_avg_bits")?;
            let rs_avg = run.plan.model_avg_bits(&shapes);
            anyhow::ensure!(
                (py_avg - rs_avg).abs() < 1e-9,
                "model avg bits: rust {rs_avg} != python meta {py_avg}"
            );
            println!("python meta agreement: OK ({checked} layers)");
        }
        Err(_) => println!(
            "(meta not built — run `make artifacts` for the python \
             cross-check)"
        ),
    }
    Ok(())
}

fn area(argv: &[String]) -> Result<()> {
    let a = Args::new("area", "circuit-area model (Tables 3/7/8/9)")
        .opt("method", "", "single method (empty = all)")
        .parse(argv)?;
    let methods: Vec<String> = if a.get("method").is_empty() {
        vec![
            "fp16", "gptq-w4", "awq-w4", "llmint4", "smoothquant-w8a8",
            "clipq-w6a6", "mxint-w4a8", "l2qer-int-w4", "l2qer-int-w4a8",
            "l2qer-w4a6", "l2qer-w4a8",
        ]
        .into_iter()
        .map(str::to_string)
        .collect()
    } else {
        vec![a.get("method")]
    };
    let mut t = Table::new("circuit area (16 MACs/cycle PE)",
                           &["method", "LUTs", "vs FP16"]);
    for method in &methods {
        let pe = hwcost::area_for_method(method)
            .ok_or_else(|| anyhow::anyhow!("no area model for {method}"))?;
        t.row(vec![
            method.clone(),
            format!("{:.0}", pe.total),
            format!("{:.2}x", pe.relative()),
        ]);
    }
    print!("{}", t.render());
    for method in &methods {
        if let Some(pe) = hwcost::area_for_method(method) {
            if matches!(method.as_str(),
                        "llmint4" | "awq-w4" | "l2qer-w4a8") {
                let mut bt = Table::new(
                    &format!("area breakdown: {method}"),
                    &["component", "LUTs", "share"]);
                for (name, luts) in &pe.components {
                    bt.row(vec![name.clone(), format!("{luts:.0}"),
                                format!("{:.1}%",
                                        luts / pe.total * 100.0)]);
                }
                print!("{}", bt.render());
            }
        }
    }
    Ok(())
}
