//! IEEE 754 binary16 conversion (round-to-nearest-even), used to model the
//! FP16 group scales of the INT-gG quantizers exactly as numpy's
//! `astype(float16)` does.

/// f32 -> f16 bit pattern with round-to-nearest-even.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let frac = bits & 0x007F_FFFF;

    if exp == 0xFF {
        // Inf / NaN
        let nan = if frac != 0 { 0x0200 } else { 0 };
        return sign | 0x7C00 | nan | ((frac >> 13) as u16 & 0x03FF);
    }
    // Re-bias: f32 bias 127, f16 bias 15.
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7C00; // overflow -> inf
    }
    if unbiased >= -14 {
        // Normal half. Round mantissa 23 -> 10 bits, ties to even.
        let mant = frac >> 13;
        let rest = frac & 0x1FFF;
        let half = 0x1000u32;
        let mut h = sign as u32 | (((unbiased + 15) as u32) << 10) | mant;
        if rest > half || (rest == half && (mant & 1) == 1) {
            h += 1; // may carry into exponent: correct behaviour
        }
        return h as u16;
    }
    if unbiased >= -25 {
        // Subnormal half.
        let full = frac | 0x0080_0000; // implicit 1
        let shift = (-14 - unbiased) as u32 + 13;
        let mant = full >> shift;
        let rest = full & ((1u32 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let mut h = sign as u32 | mant;
        if rest > half || (rest == half && (mant & 1) == 1) {
            h += 1;
        }
        return h as u16;
    }
    sign // underflow to zero
}

/// f16 bit pattern -> f32.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let frac = (h & 0x03FF) as u32;
    let bits = if exp == 0 {
        if frac == 0 {
            sign
        } else {
            // subnormal: value = frac * 2^-24; normalize so the top set
            // bit (position p) becomes the implicit one.
            let p = 31 - frac.leading_zeros(); // 0..=9
            let frac_n = (frac << (10 - p)) & 0x03FF;
            let exp_n = 103 + p; // (p - 24) + 127
            sign | (exp_n << 23) | (frac_n << 13)
        }
    } else if exp == 0x1F {
        sign | 0x7F80_0000 | (frac << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (frac << 13)
    };
    f32::from_bits(bits)
}

/// Round an f32 through f16 precision (numpy `x.astype(f16).astype(f32)`).
pub fn round_via_f16(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values_roundtrip() {
        for v in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 65504.0, 6.1035156e-5] {
            assert_eq!(round_via_f16(v), v, "{v}");
        }
    }

    #[test]
    fn rounds_to_nearest_even() {
        // 1 + 2^-11 is exactly between 1.0 and 1+2^-10: ties to even -> 1.0
        let x = 1.0f32 + f32::powi(2.0, -11);
        assert_eq!(round_via_f16(x), 1.0);
        // slightly above the tie rounds up
        let y = 1.0f32 + f32::powi(2.0, -11) + f32::powi(2.0, -13);
        assert_eq!(round_via_f16(y), 1.0 + f32::powi(2.0, -10));
    }

    #[test]
    fn overflow_and_underflow() {
        assert!(round_via_f16(1e6).is_infinite());
        assert_eq!(round_via_f16(1e-10), 0.0);
        // subnormal half range
        let sub = 2.0f32.powi(-24);
        assert_eq!(round_via_f16(sub), sub);
    }

    #[test]
    fn matches_native_reference_on_grid() {
        // Cross-check against rust's own f32->f64 path by exhaustively
        // round-tripping all f16 bit patterns: to_f32 then back must be id.
        for h in 0u16..=0xFFFF {
            let exp = (h >> 10) & 0x1F;
            if exp == 0x1F {
                continue; // skip inf/nan payload identity
            }
            let f = f16_bits_to_f32(h);
            let back = f32_to_f16_bits(f);
            assert_eq!(back, h, "h={h:#06x} f={f}");
        }
    }
}
