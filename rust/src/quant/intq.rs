//! Symmetric fixed-point quantization (the INTb-gG weight grid and the
//! per-token activation quantizer) — rust twin of
//! `python/compile/quant/formats.py::int_quant_group / int_quant_per_token`.

use super::f16::round_via_f16;

/// Quantize-dequantize one group sharing an FP16 scale = amax / qmax.
pub fn int_quant_group_slice(vals: &mut [f32], bits: u32, fp16_scale: bool) {
    let qmax = ((1i64 << (bits - 1)) - 1) as f32;
    let qmin = -qmax - 1.0;
    let amax = vals.iter().fold(0.0f32, |a, x| a.max(x.abs()));
    let mut scale = if amax > 0.0 { amax / qmax } else { 1.0 };
    if fp16_scale {
        scale = round_via_f16(scale);
    }
    for x in vals.iter_mut() {
        let q = (*x / scale).round_ties_even().clamp(qmin, qmax);
        *x = q * scale;
    }
}

/// Group quantization along the *first* axis of a row-major (rows, cols)
/// matrix (weight orientation, groups of `group` input features per
/// output column).
/// Largest divisor of n <= group (mirrors python's `effective_group`).
pub fn effective_group(n: usize, group: usize) -> usize {
    let mut g = group.min(n);
    while n % g != 0 {
        g -= 1;
    }
    g
}

pub fn int_quant_group_cols(
    data: &mut [f32],
    cols: usize,
    bits: u32,
    group: usize,
) {
    let rows = data.len() / cols;
    assert_eq!(data.len() % cols, 0);
    let g = effective_group(rows, group);
    let mut buf = vec![0.0f32; g];
    for c in 0..cols {
        for g0 in (0..rows).step_by(g) {
            for (i, slot) in buf.iter_mut().enumerate() {
                *slot = data[(g0 + i) * cols + c];
            }
            int_quant_group_slice(&mut buf, bits, true);
            for (i, v) in buf.iter().enumerate() {
                data[(g0 + i) * cols + c] = *v;
            }
        }
    }
}

/// Per-token (per-row) symmetric quantization; scale stays f32 (matches
/// the python activation quantizer).
pub fn int_quant_per_token(data: &mut [f32], cols: usize, bits: u32) {
    assert_eq!(data.len() % cols, 0);
    for row in data.chunks_exact_mut(cols) {
        int_quant_group_slice_f32_scale(row, bits);
    }
}

fn int_quant_group_slice_f32_scale(vals: &mut [f32], bits: u32) {
    int_quant_group_slice(vals, bits, false);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, VecF32};

    #[test]
    fn grid_has_at_most_2b_levels() {
        check("int-levels", 100,
              &VecF32 { min_len: 8, max_len: 64, scale: 3.0 }, |v| {
            let mut q = v.clone();
            int_quant_group_slice(&mut q, 3, true);
            let mut levels: Vec<i64> =
                q.iter().map(|x| (x.to_bits() as i64)).collect();
            levels.sort_unstable();
            levels.dedup();
            if levels.len() <= 8 {
                Ok(())
            } else {
                Err(format!("{} distinct levels for 3 bits", levels.len()))
            }
        });
    }

    #[test]
    fn preserves_sign_and_bound() {
        check("int-bound", 100,
              &VecF32 { min_len: 4, max_len: 32, scale: 2.0 }, |v| {
            let mut q = v.clone();
            int_quant_group_slice(&mut q, 8, true);
            let amax = v.iter().fold(0.0f32, |a, x| a.max(x.abs()));
            for (x, y) in v.iter().zip(&q) {
                if x.abs() > 1e-3 && x.signum() != y.signum() && *y != 0.0 {
                    return Err(format!("sign flip {x} -> {y}"));
                }
                if y.abs() > amax * 1.01 + 1e-6 {
                    return Err(format!("|q|={} > amax={amax}", y.abs()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn zero_group_unchanged() {
        let mut v = vec![0.0f32; 16];
        int_quant_group_slice(&mut v, 4, true);
        assert!(v.iter().all(|x| *x == 0.0));
    }

    #[test]
    fn per_token_rows_independent() {
        let mut a = vec![1.0f32, -2.0, 0.5, 100.0, 50.0, -25.0];
        int_quant_per_token(&mut a, 3, 8);
        // first row small scale, second row large; both near-exact at 8 bits
        assert!((a[0] - 1.0).abs() < 0.02);
        assert!((a[3] - 100.0).abs() < 1.0);
    }
}
