//! Bit-exact rust twins of the L1/L2 quantizers, plus the QuantSpec
//! plan schema and memory-footprint accounting (the "Avg. w bits"
//! column of Table 3).
//!
//! The number-grid modules mirror `python/compile/quant/formats.py`
//! exactly — same floor(log2) via the f32 bit pattern, same
//! round-half-to-even, same clamping — and are verified against
//! cross-language golden vectors in `rust/tests/golden_quant.rs`.
//! [`spec`] mirrors `python/compile/quant/spec.py` (the typed
//! quantization-plan contract) and owns the avg-bits formulas as the
//! single source of truth; the historical free functions below re-export
//! from it.  The [`spec::Quantizer`] trait unifies the grids behind one
//! object-safe API.

pub mod f16;
pub mod intq;
pub mod mxint;
pub mod spec;

pub use spec::{
    int_group_avg_bits, lqer_avg_bits, mxint_avg_bits, QuantSpec, Quantizer,
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn avg_bits_formulas() {
        // MXINT4 with 4-bit exponent over block 16 = 4.25 bits (paper 4.1).
        assert!((mxint_avg_bits(4, 4, 16) - 4.25).abs() < 1e-12);
        // MXINT8 act with 8-bit exponent = 8.5.
        assert!((mxint_avg_bits(8, 8, 16) - 8.5).abs() < 1e-12);
        // INT4 g128 = 4.125 (paper's "4.1" column).
        assert!((int_group_avg_bits(4, 128) - 4.125).abs() < 1e-12);
    }

    #[test]
    fn lqer_avg_bits_overhead_shrinks_with_size() {
        let small = lqer_avg_bits(128, 128, 16, 4.25, 8.25);
        let large = lqer_avg_bits(4096, 4096, 16, 4.25, 8.25);
        assert!(small > large);
        assert!(large < 4.35); // paper: "4.3" at OPT scale with k=32
        // At the paper's FFN scale with k=32:
        let paper = lqer_avg_bits(12288, 49152, 32, 4.25, 8.25);
        assert!(paper < 4.26 + 0.1);
    }
}
