//! MXINT block floating point (fake quantization) — the rust twin of
//! `python/compile/quant/formats.py::mxint_quant` and of the L1 Pallas
//! kernel.  Bit-exact with the python implementation (golden-tested).
//!
//! MXINT(e, m, B): B consecutive values share an e-bit exponent
//! E = clamp(floor(log2 max|block|), -2^(e-1), 2^(e-1)-1); each element is
//! an m-bit signed mantissa on the grid step = 2^(E - m + 2).

/// floor(log2(x)) for finite x > 0, exact via the bit pattern
/// (frexp semantics; handles subnormals).
pub fn floor_log2(x: f32) -> i32 {
    debug_assert!(x > 0.0 && x.is_finite());
    let bits = x.to_bits();
    let exp = ((bits >> 23) & 0xFF) as i32;
    if exp != 0 {
        exp - 127
    } else {
        // subnormal: value = frac * 2^-149
        let frac = bits & 0x007F_FFFF;
        -149 + (31 - frac.leading_zeros() as i32)
    }
}

/// Parameters of one MXINT format.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MxFormat {
    pub elem_bits: u32,
    pub exp_bits: u32,
    pub block: usize,
}

impl MxFormat {
    /// Paper §4.1 weight format: e=4, block [16,1].
    pub fn weight(elem_bits: u32) -> Self {
        MxFormat { elem_bits, exp_bits: 4, block: 16 }
    }

    /// Paper §4.1 activation format: e=8, block [1,16].
    pub fn act(elem_bits: u32) -> Self {
        MxFormat { elem_bits, exp_bits: 8, block: 16 }
    }

    pub fn avg_bits(&self) -> f64 {
        super::mxint_avg_bits(self.elem_bits, self.exp_bits, self.block)
    }

    fn exp_min(&self) -> i32 {
        -(1 << (self.exp_bits - 1))
    }

    fn exp_max(&self) -> i32 {
        (1 << (self.exp_bits - 1)) - 1
    }

    /// Quantize-dequantize one contiguous block in place.
    pub fn quant_block(&self, block: &mut [f32]) {
        let amax = block.iter().fold(0.0f32, |a, x| a.max(x.abs()));
        let e = if amax > 0.0 {
            floor_log2(amax).clamp(self.exp_min(), self.exp_max())
        } else {
            self.exp_min()
        };
        let step = (e as f32 - (self.elem_bits as f32 - 2.0)).exp2();
        let qmin = -((1i64 << (self.elem_bits - 1)) as f32);
        let qmax = ((1i64 << (self.elem_bits - 1)) - 1) as f32;
        for x in block.iter_mut() {
            let q = (*x / step).round_ties_even().clamp(qmin, qmax);
            *x = q * step;
        }
    }

    /// Fake-quantize a (rows, cols) row-major matrix with blocks along the
    /// last axis (activation orientation: [1, block]).
    pub fn quant_rows(&self, data: &mut [f32], cols: usize) {
        assert_eq!(data.len() % cols, 0);
        assert_eq!(cols % self.block, 0, "cols {cols} % block {}", self.block);
        for row in data.chunks_exact_mut(cols) {
            for blk in row.chunks_exact_mut(self.block) {
                self.quant_block(blk);
            }
        }
    }

    /// Fake-quantize a (rows, cols) row-major matrix with blocks along the
    /// first axis (weight orientation: [block, 1] over input features).
    pub fn quant_cols(&self, data: &mut [f32], cols: usize) {
        let rows = data.len() / cols;
        assert_eq!(data.len() % cols, 0);
        assert_eq!(rows % self.block, 0, "rows {rows} % block {}", self.block);
        let mut blk = vec![0.0f32; self.block];
        for c in 0..cols {
            for b0 in (0..rows).step_by(self.block) {
                for (i, slot) in blk.iter_mut().enumerate() {
                    *slot = data[(b0 + i) * cols + c];
                }
                self.quant_block(&mut blk);
                for (i, v) in blk.iter().enumerate() {
                    data[(b0 + i) * cols + c] = *v;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, VecF32};
    use crate::util::rng::Rng;

    #[test]
    fn floor_log2_exact() {
        assert_eq!(floor_log2(1.0), 0);
        assert_eq!(floor_log2(2.0), 1);
        assert_eq!(floor_log2(1.99), 0);
        assert_eq!(floor_log2(0.5), -1);
        assert_eq!(floor_log2(0.4999), -2);
        assert_eq!(floor_log2(f32::MIN_POSITIVE), -126);
        assert_eq!(floor_log2(f32::from_bits(1)), -149); // min subnormal
    }

    #[test]
    fn requantization_drift_bounded() {
        // Exact idempotence fails when a value hits -2^(m-1) (the block
        // max doubles and the shared exponent shifts) — a property of
        // the MXINT grid itself.  Drift is bounded by one coarse step.
        let fmt = MxFormat::weight(4);
        let mut rng = Rng::new(9);
        for _ in 0..50 {
            let mut v: Vec<f32> =
                (0..16).map(|_| rng.normal() as f32 * 0.3).collect();
            fmt.quant_block(&mut v);
            let once = v.clone();
            fmt.quant_block(&mut v);
            let amax = once.iter().fold(0.0f32, |a, x| a.max(x.abs()));
            if amax == 0.0 {
                continue;
            }
            let step = (floor_log2(amax) as f32 - 2.0).exp2();
            for (a, b) in once.iter().zip(&v) {
                assert!((a - b).abs() <= step, "{a} -> {b} (step {step})");
            }
        }
    }

    #[test]
    fn zero_block_stays_zero() {
        let fmt = MxFormat::weight(4);
        let mut v = vec![0.0f32; 16];
        fmt.quant_block(&mut v);
        assert!(v.iter().all(|x| *x == 0.0));
    }

    #[test]
    fn error_bounded_by_step() {
        // |x - q(x)| <= step/2 when no clipping occurs (amax defines E, so
        // elements <= amax < 2^(E+1) can clip only at the positive edge by
        // at most one step).
        let fmt = MxFormat::act(8);
        check("mx-err-bound", 200,
              &VecF32 { min_len: 16, max_len: 16, scale: 2.0 }, |v| {
            let mut q = v.clone();
            fmt.quant_block(&mut q);
            let amax = v.iter().fold(0.0f32, |a, x| a.max(x.abs()));
            if amax == 0.0 {
                return Ok(());
            }
            let e = floor_log2(amax).clamp(-128, 127);
            let step = (e as f32 - 6.0).exp2();
            for (x, y) in v.iter().zip(&q) {
                if (x - y).abs() > step {
                    return Err(format!("err {} > step {step}", (x - y).abs()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn more_bits_never_worse() {
        let mut rng = Rng::new(4);
        for _ in 0..50 {
            let v: Vec<f32> =
                (0..16).map(|_| rng.normal() as f32).collect();
            let mut err = Vec::new();
            for bits in [2, 3, 4, 8] {
                let fmt = MxFormat::weight(bits);
                let mut q = v.clone();
                fmt.quant_block(&mut q);
                let e: f32 =
                    v.iter().zip(&q).map(|(a, b)| (a - b).abs()).sum();
                err.push(e);
            }
            for w in err.windows(2) {
                assert!(w[1] <= w[0] + 1e-6, "{err:?}");
            }
        }
    }

    #[test]
    fn orientation_transpose_equivalence() {
        // quant_cols on M == quant_rows on M^T.
        let rows = 32;
        let cols = 8;
        let mut rng = Rng::new(11);
        let m: Vec<f32> =
            (0..rows * cols).map(|_| rng.normal() as f32).collect();
        let fmt = MxFormat::weight(4);
        let mut a = m.clone();
        fmt.quant_cols(&mut a, cols);
        // transpose
        let mut t = vec![0.0f32; rows * cols];
        for r in 0..rows {
            for c in 0..cols {
                t[c * rows + r] = m[r * cols + c];
            }
        }
        fmt.quant_rows(&mut t, rows);
        for r in 0..rows {
            for c in 0..cols {
                assert_eq!(a[r * cols + c], t[c * rows + r]);
            }
        }
    }
}
