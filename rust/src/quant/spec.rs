//! QuantSpec: the typed, per-layer quantization-plan schema — the rust
//! mirror of `python/compile/quant/spec.py`, kept bit-for-bit identical
//! (canonical JSON serialization, validation rules, avg-bits formulas,
//! override matching) and asserted so by the cross-language golden
//! fixture `rust/tests/fixtures/quantspec_golden.json`.
//!
//! A plan is a model-wide default [`LayerSpec`] plus ordered
//! per-layer-name overrides:
//!
//! ```json
//! {"version": 1,
//!  "default": {"weight": {"kind": "mxint", "bits": 4,
//!                         "exp_bits": 4, "block": 16},
//!              "act": "mx8", "algo": "rtn",
//!              "lowrank": {"k": 16, "scaled": true, "bits": 8}},
//!  "overrides": [{"match": "layers.*.fc1", "spec": {...}}]}
//! ```
//!
//! Override patterns match full layer keys (`layers.3.fc1`) literally
//! except that `*` matches any run of characters; the first matching
//! override wins.  `act` must be uniform across a plan (the activation
//! mode is graph structure — one lowered HLO variant per act mode).
//!
//! Legacy method-name strings (`"l2qer-w4a8"`, the fig-3 sweep names
//! `"lqer-w2a8-k8"`) resolve through [`QuantSpec::from_method_name`],
//! which mirrors the python `METHODS` registry exactly.

use std::fmt;

use anyhow::{anyhow, bail, Result};

use super::f16::round_via_f16;
use super::{intq, mxint::MxFormat};
use crate::util::json::{self, Value};

pub const SCHEMA_VERSION: i64 = 1;

// ---------------------------------------------------------------------------
// Average-bits accounting — single source of truth for "Avg. w bits"
// (Table 3), mirrored in python/compile/quant/spec.py.
// ---------------------------------------------------------------------------

/// Average bits per element of an MXINT tensor: the shared exponent is
/// amortized over the block.
pub fn mxint_avg_bits(elem_bits: u32, exp_bits: u32, block: usize) -> f64 {
    elem_bits as f64 + exp_bits as f64 / block as f64
}

/// Average bits per element of group-quantized fixed point with an FP16
/// scale per group.
pub fn int_group_avg_bits(bits: u32, group: usize) -> f64 {
    bits as f64 + 16.0 / group as f64
}

/// Average weight bits of an LQER layer: W_q plus the rank-k factors
/// amortized over the m*n nominal weights (paper Appendix D).
pub fn lqer_avg_bits(
    m: usize,
    n: usize,
    k: usize,
    w_bits_avg: f64,
    lowrank_bits_avg: f64,
) -> f64 {
    let total =
        (m * n) as f64 * w_bits_avg + ((m + n) * k) as f64 * lowrank_bits_avg;
    total / (m * n) as f64
}

// ---------------------------------------------------------------------------
// The object-safe quantizer API unifying the f16 / intq / mxint modules
// ---------------------------------------------------------------------------

/// One number format's fake-quantizer: every weight/activation grid in
/// the repo behind a single object-safe interface.
pub trait Quantizer {
    /// Human-readable format label (e.g. `MXINT4[e4/b16]`).
    fn describe(&self) -> String;
    /// Average storage bits per element.
    fn avg_bits(&self) -> f64;
    /// Fake-quantize a row-major (rows x cols) matrix in place.
    fn quantize(&self, data: &mut [f32], cols: usize);
}

/// FP16 baseline weights: stored unquantized (identity grid, 16 bits).
struct Fp16Identity;

impl Quantizer for Fp16Identity {
    fn describe(&self) -> String {
        "FP16".to_string()
    }
    fn avg_bits(&self) -> f64 {
        16.0
    }
    fn quantize(&self, _data: &mut [f32], _cols: usize) {}
}

/// MXINT weights: blocks along the first axis ([block, 1]).
struct MxintWeight(MxFormat);

impl Quantizer for MxintWeight {
    fn describe(&self) -> String {
        format!("MXINT{}[e{}/b{}]", self.0.elem_bits, self.0.exp_bits,
                self.0.block)
    }
    fn avg_bits(&self) -> f64 {
        self.0.avg_bits()
    }
    fn quantize(&self, data: &mut [f32], cols: usize) {
        self.0.quant_cols(data, cols);
    }
}

/// MXINT activations: blocks along the last axis ([1, block]).
struct MxintAct(MxFormat);

impl Quantizer for MxintAct {
    fn describe(&self) -> String {
        format!("MXINT{}[e{}/b{}] act", self.0.elem_bits, self.0.exp_bits,
                self.0.block)
    }
    fn avg_bits(&self) -> f64 {
        self.0.avg_bits()
    }
    fn quantize(&self, data: &mut [f32], cols: usize) {
        self.0.quant_rows(data, cols);
    }
}

/// INT-gG weights: FP16 group scales along the first axis; `group == 0`
/// is vector-wise (one FP16 scale per input row, LLM.int8 style).
struct IntGroupWeight {
    bits: u32,
    group: usize,
}

impl Quantizer for IntGroupWeight {
    fn describe(&self) -> String {
        if self.group == 0 {
            format!("INT{} vec", self.bits)
        } else {
            format!("INT{} g{}", self.bits, self.group)
        }
    }
    fn avg_bits(&self) -> f64 {
        int_group_avg_bits(self.bits, if self.group == 0 { 4096 }
                           else { self.group })
    }
    fn quantize(&self, data: &mut [f32], cols: usize) {
        if self.group == 0 {
            for row in data.chunks_exact_mut(cols) {
                intq::int_quant_group_slice(row, self.bits, true);
            }
        } else {
            intq::int_quant_group_cols(data, cols, self.bits, self.group);
        }
    }
}

/// Per-token symmetric INT activations (f32 scale).
struct IntPerToken {
    bits: u32,
}

impl Quantizer for IntPerToken {
    fn describe(&self) -> String {
        format!("INT{} per-token", self.bits)
    }
    fn avg_bits(&self) -> f64 {
        self.bits as f64
    }
    fn quantize(&self, data: &mut [f32], cols: usize) {
        intq::int_quant_per_token(data, cols, self.bits);
    }
}

/// Full-precision activations: no quantization.
struct NoopAct;

impl Quantizer for NoopAct {
    fn describe(&self) -> String {
        "f32".to_string()
    }
    fn avg_bits(&self) -> f64 {
        32.0
    }
    fn quantize(&self, _data: &mut [f32], _cols: usize) {}
}

/// FP16 rounding quantizer (numpy `astype(f16).astype(f32)`) — exposed
/// for completeness; the FP16 *weight* grid is identity by convention.
pub struct F16Round;

impl Quantizer for F16Round {
    fn describe(&self) -> String {
        "f16-round".to_string()
    }
    fn avg_bits(&self) -> f64 {
        16.0
    }
    fn quantize(&self, data: &mut [f32], _cols: usize) {
        for x in data.iter_mut() {
            *x = round_via_f16(*x);
        }
    }
}

// ---------------------------------------------------------------------------
// Schema types
// ---------------------------------------------------------------------------

/// Weight number format of one linear layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WeightFormat {
    /// Unquantized FP16 baseline.
    Fp16,
    /// Block floating point: `bits`-bit mantissas sharing an
    /// `exp_bits`-bit exponent per `block` input features.
    Mxint { bits: u32, exp_bits: u32, block: usize },
    /// Fixed point with an FP16 scale per `group` input features;
    /// `group == 0` is vector-wise (LLM.int8 style).
    IntGroup { bits: u32, group: usize },
}

impl WeightFormat {
    pub fn avg_bits(&self) -> f64 {
        match *self {
            WeightFormat::Fp16 => 16.0,
            WeightFormat::Mxint { bits, exp_bits, block } => {
                mxint_avg_bits(bits, exp_bits, block)
            }
            // Vector-wise scales amortize over the whole row; 4096 is
            // the legacy accounting stand-in for "a full LLM row".
            WeightFormat::IntGroup { bits, group } => {
                int_group_avg_bits(bits, if group == 0 { 4096 } else { group })
            }
        }
    }

    /// Element (mantissa) width, the `Wx` of "WxAy".
    pub fn elem_bits(&self) -> u32 {
        match *self {
            WeightFormat::Fp16 => 16,
            WeightFormat::Mxint { bits, .. }
            | WeightFormat::IntGroup { bits, .. } => bits,
        }
    }

    /// The matching fake-quantizer (weight orientation).
    pub fn quantizer(&self) -> Box<dyn Quantizer> {
        match *self {
            WeightFormat::Fp16 => Box::new(Fp16Identity),
            WeightFormat::Mxint { bits, exp_bits, block } => {
                Box::new(MxintWeight(MxFormat {
                    elem_bits: bits,
                    exp_bits,
                    block,
                }))
            }
            WeightFormat::IntGroup { bits, group } => {
                Box::new(IntGroupWeight { bits, group })
            }
        }
    }

    fn to_value(self) -> Value {
        match self {
            WeightFormat::Fp16 => json::obj(vec![("kind", json::s("fp16"))]),
            WeightFormat::Mxint { bits, exp_bits, block } => json::obj(vec![
                ("kind", json::s("mxint")),
                ("bits", json::num(bits as f64)),
                ("exp_bits", json::num(exp_bits as f64)),
                ("block", json::num(block as f64)),
            ]),
            WeightFormat::IntGroup { bits, group } => json::obj(vec![
                ("kind", json::s("int")),
                ("bits", json::num(bits as f64)),
                ("group", json::num(group as f64)),
            ]),
        }
    }

    fn parse(v: &Value, path: &str) -> Result<Self> {
        let o = as_obj(v, path)?;
        let kind = str_field(v, "kind", path)?;
        match kind.as_str() {
            "fp16" => {
                check_keys(o, &["kind"], path)?;
                Ok(WeightFormat::Fp16)
            }
            "mxint" => {
                check_keys(o, &["kind", "bits", "exp_bits", "block"], path)?;
                Ok(WeightFormat::Mxint {
                    bits: int_field(v, "bits", path, 2, 8)? as u32,
                    exp_bits: int_field(v, "exp_bits", path, 1, 8)? as u32,
                    block: int_field(v, "block", path, 1, i64::MAX)? as usize,
                })
            }
            "int" => {
                check_keys(o, &["kind", "bits", "group"], path)?;
                Ok(WeightFormat::IntGroup {
                    bits: int_field(v, "bits", path, 2, 8)? as u32,
                    group: int_field(v, "group", path, 0, i64::MAX)? as usize,
                })
            }
            other => bail!("{path}.kind: unknown weight format '{other}'"),
        }
    }
}

impl fmt::Display for WeightFormat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.quantizer().describe())
    }
}

/// Activation number format (graph structure: one lowered HLO variant
/// per act mode).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ActFormat {
    None,
    Mx8,
    Mx6,
    Int8,
    Int6,
}

impl ActFormat {
    pub fn as_str(&self) -> &'static str {
        match self {
            ActFormat::None => "none",
            ActFormat::Mx8 => "mx8",
            ActFormat::Mx6 => "mx6",
            ActFormat::Int8 => "int8",
            ActFormat::Int6 => "int6",
        }
    }

    pub fn from_str(s: &str, path: &str) -> Result<Self> {
        Ok(match s {
            "none" => ActFormat::None,
            "mx8" => ActFormat::Mx8,
            "mx6" => ActFormat::Mx6,
            "int8" => ActFormat::Int8,
            "int6" => ActFormat::Int6,
            other => bail!("{path}: unknown activation mode '{other}'"),
        })
    }

    /// The `Ay` of "WxAy" (16 = full precision).
    pub fn bits(&self) -> u32 {
        match self {
            ActFormat::None => 16,
            ActFormat::Mx8 | ActFormat::Int8 => 8,
            ActFormat::Mx6 | ActFormat::Int6 => 6,
        }
    }

    /// The matching fake-quantizer (activation orientation).
    pub fn quantizer(&self) -> Box<dyn Quantizer> {
        match self {
            ActFormat::None => Box::new(NoopAct),
            ActFormat::Mx8 => Box::new(MxintAct(MxFormat::act(8))),
            ActFormat::Mx6 => Box::new(MxintAct(MxFormat::act(6))),
            ActFormat::Int8 => Box::new(IntPerToken { bits: 8 }),
            ActFormat::Int6 => Box::new(IntPerToken { bits: 6 }),
        }
    }
}

/// Weight-optimization algorithm producing W_eff.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algo {
    None,
    Rtn,
    Gptq,
    Awq,
    Llmint4,
    Smoothquant,
    Clipq,
}

impl Algo {
    pub fn as_str(&self) -> &'static str {
        match self {
            Algo::None => "none",
            Algo::Rtn => "rtn",
            Algo::Gptq => "gptq",
            Algo::Awq => "awq",
            Algo::Llmint4 => "llmint4",
            Algo::Smoothquant => "smoothquant",
            Algo::Clipq => "clipq",
        }
    }

    pub fn from_str(s: &str, path: &str) -> Result<Self> {
        Ok(match s {
            "none" => Algo::None,
            "rtn" => Algo::Rtn,
            "gptq" => Algo::Gptq,
            "awq" => Algo::Awq,
            "llmint4" => Algo::Llmint4,
            "smoothquant" => Algo::Smoothquant,
            "clipq" => Algo::Clipq,
            other => bail!("{path}: unknown algorithm '{other}'"),
        })
    }

    /// Algorithms that operate on the INT grid (they take bits and,
    /// except llmint4, a group size) and therefore require an IntGroup
    /// weight format; plain rtn rounding works on any grid.
    pub fn needs_int_weights(&self) -> bool {
        matches!(
            self,
            Algo::Gptq | Algo::Awq | Algo::Smoothquant | Algo::Clipq
                | Algo::Llmint4
        )
    }
}

/// LQER/L2QER error-reconstruction factors: rank `k`, Appendix-A scaling
/// when `scaled`, stored at `bits`-bit MXINT (`None` = fp32 factors).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LowRank {
    pub k: usize,
    pub scaled: bool,
    pub bits: Option<u32>,
}

pub const LOWRANK_DEFAULT_BITS: u32 = 8;

impl LowRank {
    pub fn avg_bits(&self) -> f64 {
        match self.bits {
            None => 32.0,
            Some(b) => mxint_avg_bits(b, 4, 16),
        }
    }
}

/// How one linear layer is quantized.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerSpec {
    pub weight: WeightFormat,
    pub act: ActFormat,
    pub algo: Algo,
    pub lowrank: Option<LowRank>,
}

impl LayerSpec {
    /// Plan-derived average weight bits of an (m, n) linear.
    pub fn avg_bits(&self, m: usize, n: usize) -> f64 {
        let base = self.weight.avg_bits();
        match self.lowrank {
            None => base,
            Some(lr) => lqer_avg_bits(m, n, lr.k, base, lr.avg_bits()),
        }
    }

    pub fn to_value(&self) -> Value {
        let lowrank = match self.lowrank {
            None => Value::Null,
            Some(lr) => json::obj(vec![
                ("k", json::num(lr.k as f64)),
                ("scaled", Value::Bool(lr.scaled)),
                ("bits", match lr.bits {
                    None => Value::Null,
                    Some(b) => json::num(b as f64),
                }),
            ]),
        };
        json::obj(vec![
            ("weight", self.weight.to_value()),
            ("act", json::s(self.act.as_str())),
            ("algo", json::s(self.algo.as_str())),
            ("lowrank", lowrank),
        ])
    }

    pub fn parse(v: &Value, path: &str) -> Result<Self> {
        Self::parse_with_base(v, path, None)
    }

    /// Parse a layer spec.  With `base` (override specs), keys may be
    /// omitted and inherit from the plan default — so an override of
    /// `{"lowrank": null}` alone cleanly strips the low-rank term of
    /// the matching layers (the draft-plan idiom, DESIGN.md §13).
    /// The default spec (`base == None`) must be complete.  Canonical
    /// emission is always the full form, so partial input does not
    /// round-trip byte-identically — only semantically.
    pub fn parse_with_base(
        v: &Value,
        path: &str,
        base: Option<&LayerSpec>,
    ) -> Result<Self> {
        let o = as_obj(v, path)?;
        check_keys(o, &["weight", "act", "algo", "lowrank"], path)?;
        let base_or = |key: &str| -> Result<&LayerSpec> {
            base.ok_or_else(|| anyhow!("{path}: missing key '{key}'"))
        };
        let act = match v.get("act") {
            None => base_or("act")?.act,
            Some(_) => ActFormat::from_str(&str_field(v, "act", path)?,
                                           &format!("{path}.act"))?,
        };
        let algo = match v.get("algo") {
            None => base_or("algo")?.algo,
            Some(_) => Algo::from_str(&str_field(v, "algo", path)?,
                                      &format!("{path}.algo"))?,
        };
        let lowrank = match v.get("lowrank") {
            None => base_or("lowrank")?.lowrank,
            Some(Value::Null) => None,
            Some(other) => {
                let lpath = format!("{path}.lowrank");
                let lo = as_obj(other, &lpath)?;
                check_keys(lo, &["k", "scaled", "bits"], &lpath)?;
                let bits = match field(other, "bits", &lpath)? {
                    Value::Null => None,
                    _ => Some(int_field(other, "bits", &lpath, 2, 8)? as u32),
                };
                Some(LowRank {
                    k: int_field(other, "k", &lpath, 1, i64::MAX)? as usize,
                    scaled: bool_field(other, "scaled", &lpath)?,
                    bits,
                })
            }
        };
        let weight = match v.get("weight") {
            None => base_or("weight")?.weight,
            Some(val) => {
                WeightFormat::parse(val, &format!("{path}.weight"))?
            }
        };
        Ok(LayerSpec { weight, act, algo, lowrank })
    }

    fn validate(&self, path: &str) -> Result<()> {
        if self.algo.needs_int_weights()
            && !matches!(self.weight, WeightFormat::IntGroup { .. })
        {
            bail!(
                "{path}: algo '{}' requires an int weight format, got '{}'",
                self.algo.as_str(),
                self.weight
            );
        }
        if let Some(lr) = self.lowrank {
            if lr.k < 1 {
                bail!("{path}.lowrank.k: must be >= 1");
            }
            if let Some(b) = lr.bits {
                if !(2..=8).contains(&b) {
                    bail!("{path}.lowrank.bits: {b} out of range [2, 8]");
                }
            }
        }
        Ok(())
    }
}

/// One per-layer-name override: a full LayerSpec for matching layers.
#[derive(Debug, Clone, PartialEq)]
pub struct Override {
    /// Layer-key pattern; `*` matches any run of characters.
    pub pattern: String,
    pub spec: LayerSpec,
}

/// A complete quantization plan: default + ordered overrides.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantSpec {
    pub default: LayerSpec,
    pub overrides: Vec<Override>,
}

impl QuantSpec {
    /// First matching override wins; else the model-wide default.
    pub fn resolve(&self, layer_name: &str) -> &LayerSpec {
        for ov in &self.overrides {
            if glob_match(&ov.pattern, layer_name) {
                return &ov.spec;
            }
        }
        &self.default
    }

    pub fn layer_specs(&self) -> impl Iterator<Item = &LayerSpec> {
        std::iter::once(&self.default)
            .chain(self.overrides.iter().map(|ov| &ov.spec))
    }

    /// Largest low-rank k any layer may use (the graph's pad rank).
    pub fn max_rank(&self) -> usize {
        self.layer_specs()
            .filter_map(|ls| ls.lowrank.map(|lr| lr.k))
            .max()
            .unwrap_or(0)
    }

    /// Plan-derived model average weight bits over named linears.
    pub fn model_avg_bits(
        &self,
        shapes: &[(String, (usize, usize))],
    ) -> f64 {
        let mut total_w = 0usize;
        let mut total_bits = 0.0f64;
        for (name, (m, n)) in shapes {
            total_w += m * n;
            total_bits += (m * n) as f64 * self.resolve(name).avg_bits(*m, *n);
        }
        total_bits / total_w.max(1) as f64
    }

    pub fn validate(&self) -> Result<()> {
        self.default.validate("plan.default")?;
        for (i, ov) in self.overrides.iter().enumerate() {
            let path = format!("plan.overrides[{i}]");
            if ov.pattern.is_empty() {
                bail!("{path}.match: must be a non-empty string");
            }
            // Printable ASCII only: layer keys are ASCII, and this
            // keeps the canonical JSON byte-identical across the two
            // emitters (python escapes non-ASCII, this writer does not).
            if !ov.pattern.is_ascii() || ov.pattern.bytes().any(|b| b < 0x20)
            {
                bail!("{path}.match: must be printable ASCII");
            }
            ov.spec.validate(&format!("{path}.spec"))?;
            if ov.spec.act != self.default.act {
                bail!(
                    "{path}.spec.act: '{}' differs from the default act \
                     '{}' — the activation mode is graph structure and \
                     must be uniform",
                    ov.spec.act.as_str(),
                    self.default.act.as_str()
                );
            }
        }
        Ok(())
    }

    // -- serialization ------------------------------------------------------

    pub fn to_value(&self) -> Value {
        json::obj(vec![
            ("version", json::num(SCHEMA_VERSION as f64)),
            ("default", self.default.to_value()),
            (
                "overrides",
                json::arr(self.overrides.iter().map(|ov| {
                    json::obj(vec![
                        ("match", json::s(&ov.pattern)),
                        ("spec", ov.spec.to_value()),
                    ])
                })),
            ),
        ])
    }

    /// Canonical form: byte-identical to the python emitter
    /// (`json.dumps(plan.to_json_dict(), separators=(",", ":"))`).
    pub fn to_canonical_json(&self) -> String {
        self.to_value().to_string()
    }

    pub fn parse(v: &Value, path: &str) -> Result<Self> {
        let o = as_obj(v, path)?;
        check_keys(o, &["version", "default", "overrides"], path)?;
        let version = int_field(v, "version", path, 0, i64::MAX)?;
        if version != SCHEMA_VERSION {
            bail!(
                "{path}.version: unsupported version {version} \
                 (expected {SCHEMA_VERSION})"
            );
        }
        let default = LayerSpec::parse(field(v, "default", path)?,
                                       &format!("{path}.default"))?;
        let mut overrides = Vec::new();
        if let Some(ovs) = v.get("overrides") {
            let opath = format!("{path}.overrides");
            let arr = ovs
                .as_array()
                .ok_or_else(|| anyhow!("{opath}: expected an array"))?;
            for (i, ov) in arr.iter().enumerate() {
                let ipath = format!("{opath}[{i}]");
                let oo = as_obj(ov, &ipath)?;
                check_keys(oo, &["match", "spec"], &ipath)?;
                overrides.push(Override {
                    pattern: str_field(ov, "match", &ipath)?,
                    spec: LayerSpec::parse_with_base(
                        field(ov, "spec", &ipath)?,
                        &format!("{ipath}.spec"),
                        Some(&default),
                    )?,
                });
            }
        }
        let plan = QuantSpec { default, overrides };
        plan.validate()?;
        Ok(plan)
    }

    pub fn from_json(text: &str) -> Result<Self> {
        let v = json::parse(text)
            .map_err(|e| anyhow!("plan: invalid JSON ({e})"))?;
        QuantSpec::parse(&v, "plan")
    }

    // -- legacy compatibility shim ------------------------------------------

    /// Resolve a legacy method-name string (the pre-QuantSpec contract)
    /// to its plan.  Mirrors the python `METHODS` registry and the
    /// fig-3 sweep names (`lqer-w2a8-k8`) exactly.
    pub fn from_method_name(name: &str) -> Result<QuantSpec> {
        if let Some(plan) = method_registry(name) {
            return Ok(plan);
        }
        if let Some(plan) = sweep_plan(name) {
            return Ok(plan);
        }
        bail!("unknown method name '{name}'")
    }
}

/// The self-speculative draft plan (DESIGN.md §13): the same quantized
/// backbone with every low-rank error-reconstruction term clamped to
/// `null` — default and overrides alike.  The draft shares W_q with the
/// corrected model, so drafting streams only the backbone weights; the
/// `(m + n) * k` low-rank traffic is paid once per *verify* pass
/// instead of once per token.  Mirrors `spec.draft_of` in
/// python/compile/quant/spec.py.
pub fn draft_of(plan: &QuantSpec) -> QuantSpec {
    let mut draft = plan.clone();
    draft.default.lowrank = None;
    for ov in &mut draft.overrides {
        ov.spec.lowrank = None;
    }
    draft
}

// ---------------------------------------------------------------------------
// The method registry (mirror of python spec.METHODS)
// ---------------------------------------------------------------------------

fn mx(bits: u32) -> WeightFormat {
    WeightFormat::Mxint { bits, exp_bits: 4, block: 16 }
}

fn ig(bits: u32, group: usize) -> WeightFormat {
    WeightFormat::IntGroup { bits, group }
}

fn lr(k: usize, scaled: bool) -> Option<LowRank> {
    Some(LowRank { k, scaled, bits: Some(LOWRANK_DEFAULT_BITS) })
}

fn plan(
    weight: WeightFormat,
    act: ActFormat,
    algo: Algo,
    lowrank: Option<LowRank>,
) -> QuantSpec {
    QuantSpec {
        default: LayerSpec { weight, act, algo, lowrank },
        overrides: Vec::new(),
    }
}

fn method_registry(name: &str) -> Option<QuantSpec> {
    use ActFormat::{Int6, Int8, Mx6, Mx8, None as ANone};
    use Algo::{Awq, Clipq, Gptq, Llmint4, None as GNone, Rtn, Smoothquant};
    Some(match name {
        "fp16" => plan(WeightFormat::Fp16, ANone, GNone, None),
        "mxint-w4a8" => plan(mx(4), Mx8, Rtn, None),
        "lqer-w4a8" => plan(mx(4), Mx8, Rtn, lr(16, false)),
        "l2qer-w4a8" => plan(mx(4), Mx8, Rtn, lr(16, true)),
        "l2qer-w4a6" => plan(mx(4), Mx6, Rtn, lr(16, true)),
        "l2qer-int-w4" => plan(ig(4, 128), ANone, Rtn, lr(16, true)),
        "l2qer-int-w4a8" => plan(ig(4, 128), Int8, Rtn, lr(16, true)),
        "gptq-w4" => plan(ig(4, 128), ANone, Gptq, None),
        "awq-w4" => plan(ig(4, 128), ANone, Awq, None),
        "rtn-w4" => plan(ig(4, 128), ANone, Rtn, None),
        "llmint4" => plan(ig(4, 0), Int8, Llmint4, None),
        "smoothquant-w8a8" => plan(ig(8, 128), Int8, Smoothquant, None),
        "clipq-w6a6" => plan(ig(6, 128), Int6, Clipq, None),
        "awq-w2" => plan(ig(2, 128), ANone, Awq, None),
        "clipq-w2" => plan(ig(2, 128), ANone, Clipq, None),
        "l2qer-w2a8" => plan(mx(2), Mx8, Rtn, lr(64, true)),
        "mxint-w2a8" => plan(mx(2), Mx8, Rtn, None),
        "lqer-w2a8" => plan(mx(2), Mx8, Rtn, lr(64, false)),
        "mxint-w3a8" => plan(mx(3), Mx8, Rtn, None),
        "l2qer-w2a8-lr4" => plan(
            mx(2),
            Mx8,
            Rtn,
            Some(LowRank { k: 64, scaled: true, bits: Some(4) }),
        ),
        "l2qer-w2a8-lrfp" => plan(
            mx(2),
            Mx8,
            Rtn,
            Some(LowRank { k: 64, scaled: true, bits: None }),
        ),
        "l2qer-w2a8-rank16" => plan(mx(2), Mx8, Rtn, lr(16, true)),
        _ => return None,
    })
}

/// The fig-3 sweep names: `lqer-w2a8-k{N}` / `l2qer-w2a8-k{N}`.
fn sweep_plan(name: &str) -> Option<QuantSpec> {
    let (scaled, rest) = if let Some(r) = name.strip_prefix("l2qer-w2a8-k") {
        (true, r)
    } else if let Some(r) = name.strip_prefix("lqer-w2a8-k") {
        (false, r)
    } else {
        return None;
    };
    if rest.is_empty() || !rest.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    let k: usize = rest.parse().ok()?;
    if k == 0 {
        return None;
    }
    Some(plan(mx(2), ActFormat::Mx8, Algo::Rtn, lr(k, scaled)))
}

// ---------------------------------------------------------------------------
// Pattern matching (mirror of python glob_match — keep trivially simple)
// ---------------------------------------------------------------------------

/// Literal match except `*` matches any (possibly empty) run.
pub fn glob_match(pattern: &str, name: &str) -> bool {
    let p = pattern.as_bytes();
    let s = name.as_bytes();
    let (mut pi, mut si) = (0usize, 0usize);
    let mut star: Option<usize> = None;
    let mut mark = 0usize;
    while si < s.len() {
        if pi < p.len() && p[pi] == b'*' {
            star = Some(pi);
            mark = si;
            pi += 1;
        } else if pi < p.len() && p[pi] == s[si] {
            pi += 1;
            si += 1;
        } else if let Some(st) = star {
            pi = st + 1;
            mark += 1;
            si = mark;
        } else {
            return false;
        }
    }
    while pi < p.len() && p[pi] == b'*' {
        pi += 1;
    }
    pi == p.len()
}

// ---------------------------------------------------------------------------
// Model layer shapes (mirror of python spec.layer_shapes / model.py's
// LINEAR_NAMES)
// ---------------------------------------------------------------------------

/// (in, out) shape of every linear key `layers.{i}.{name}`, in model
/// walk order.
pub fn layer_shapes(
    d: usize,
    ffn: usize,
    layers: usize,
) -> Vec<(String, (usize, usize))> {
    let dims: [(&str, (usize, usize)); 6] = [
        ("wq", (d, d)),
        ("wk", (d, d)),
        ("wv", (d, d)),
        ("wo", (d, d)),
        ("fc1", (d, ffn)),
        ("fc2", (ffn, d)),
    ];
    let mut out = Vec::with_capacity(layers * dims.len());
    for li in 0..layers {
        for (name, shape) in dims {
            out.push((format!("layers.{li}.{name}"), shape));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Strict parsing helpers (path-qualified errors)
// ---------------------------------------------------------------------------

fn as_obj<'a>(v: &'a Value, path: &str) -> Result<&'a [(String, Value)]> {
    v.as_object()
        .ok_or_else(|| anyhow!("{path}: expected an object"))
}

fn check_keys(
    o: &[(String, Value)],
    allowed: &[&str],
    path: &str,
) -> Result<()> {
    for (k, _) in o {
        if !allowed.contains(&k.as_str()) {
            bail!("{path}: unknown key '{k}'");
        }
    }
    Ok(())
}

fn field<'a>(v: &'a Value, key: &str, path: &str) -> Result<&'a Value> {
    v.get(key)
        .ok_or_else(|| anyhow!("{path}: missing key '{key}'"))
}

fn str_field(v: &Value, key: &str, path: &str) -> Result<String> {
    Ok(field(v, key, path)?
        .as_str()
        .ok_or_else(|| anyhow!("{path}.{key}: expected a string"))?
        .to_string())
}

fn bool_field(v: &Value, key: &str, path: &str) -> Result<bool> {
    field(v, key, path)?
        .as_bool()
        .ok_or_else(|| anyhow!("{path}.{key}: expected a boolean"))
}

fn int_field(v: &Value, key: &str, path: &str, lo: i64, hi: i64) -> Result<i64> {
    let f = field(v, key, path)?
        .as_f64()
        .ok_or_else(|| anyhow!("{path}.{key}: expected an integer"))?;
    if f.fract() != 0.0 {
        bail!("{path}.{key}: expected an integer");
    }
    let n = f as i64;
    if n < lo || n > hi {
        bail!("{path}.{key}: {n} out of range [{lo}, {hi}]");
    }
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn l2qer_w4a8() -> QuantSpec {
        QuantSpec::from_method_name("l2qer-w4a8").unwrap()
    }

    #[test]
    fn canonical_roundtrip() {
        let plan = l2qer_w4a8();
        let text = plan.to_canonical_json();
        assert_eq!(
            text,
            "{\"version\":1,\"default\":{\"weight\":{\"kind\":\"mxint\",\
             \"bits\":4,\"exp_bits\":4,\"block\":16},\"act\":\"mx8\",\
             \"algo\":\"rtn\",\"lowrank\":{\"k\":16,\"scaled\":true,\
             \"bits\":8}},\"overrides\":[]}"
        );
        let back = QuantSpec::from_json(&text).unwrap();
        assert_eq!(back, plan);
    }

    #[test]
    fn overrides_first_match_wins() {
        let mut plan = l2qer_w4a8();
        let mut ffn = plan.default;
        ffn.lowrank = Some(LowRank { k: 32, scaled: true, bits: Some(8) });
        plan.overrides.push(Override {
            pattern: "layers.*.fc1".into(),
            spec: ffn,
        });
        let mut shadow = plan.default;
        shadow.lowrank = None;
        plan.overrides.push(Override {
            pattern: "layers.0.*".into(),
            spec: shadow,
        });
        // fc1 hits the first override even in layer 0.
        assert_eq!(plan.resolve("layers.0.fc1").lowrank.unwrap().k, 32);
        assert_eq!(plan.resolve("layers.0.wq").lowrank, None);
        assert_eq!(plan.resolve("layers.3.wq").lowrank.unwrap().k, 16);
        assert_eq!(plan.max_rank(), 32);
        // Round-trips with overrides intact.
        let back = QuantSpec::from_json(&plan.to_canonical_json()).unwrap();
        assert_eq!(back, plan);
    }

    #[test]
    fn partial_override_inherits_default() {
        // An override carrying only `lowrank: null` strips the
        // low-rank term and inherits weight/act/algo from the default.
        let text = "{\"version\":1,\"default\":{\"weight\":{\"kind\":\
                    \"mxint\",\"bits\":4,\"exp_bits\":4,\"block\":16},\
                    \"act\":\"mx8\",\"algo\":\"rtn\",\"lowrank\":\
                    {\"k\":16,\"scaled\":true,\"bits\":8}},\"overrides\":\
                    [{\"match\":\"layers.*.fc2\",\"spec\":\
                    {\"lowrank\":null}}]}";
        let plan = QuantSpec::from_json(text).unwrap();
        let ov = plan.resolve("layers.1.fc2");
        assert_eq!(ov.lowrank, None);
        assert_eq!(ov.weight, plan.default.weight);
        assert_eq!(ov.act, plan.default.act);
        assert_eq!(ov.algo, plan.default.algo);
        // Canonical emission is the full form; it round-trips to the
        // same plan even though the input was partial.
        let back =
            QuantSpec::from_json(&plan.to_canonical_json()).unwrap();
        assert_eq!(back, plan);
        // The default itself must still be complete.
        let err = QuantSpec::from_json(
            "{\"version\":1,\"default\":{\"lowrank\":null},\
             \"overrides\":[]}",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("missing key"), "{err}");
    }

    #[test]
    fn draft_of_clamps_all_lowrank() {
        let mut plan = l2qer_w4a8();
        let mut ffn = plan.default;
        ffn.lowrank = Some(LowRank { k: 32, scaled: true, bits: Some(8) });
        plan.overrides.push(Override {
            pattern: "layers.*.fc1".into(),
            spec: ffn,
        });
        let draft = draft_of(&plan);
        assert!(draft.layer_specs().all(|ls| ls.lowrank.is_none()));
        assert_eq!(draft.max_rank(), 0);
        // Structure untouched: same weight grid, act, algo, patterns.
        assert_eq!(draft.default.weight, plan.default.weight);
        assert_eq!(draft.overrides.len(), 1);
        assert_eq!(draft.overrides[0].pattern, "layers.*.fc1");
        draft.validate().unwrap();
        // The draft streams strictly fewer weight bits.
        let shapes = layer_shapes(64, 256, 2);
        assert!(draft.model_avg_bits(&shapes)
                < plan.model_avg_bits(&shapes));
        // Idempotent, and a no-op on plans without low-rank terms.
        assert_eq!(draft_of(&draft), draft);
    }

    #[test]
    fn glob_match_semantics() {
        assert!(glob_match("layers.*.fc1", "layers.12.fc1"));
        assert!(!glob_match("layers.*.fc1", "layers.1.fc2"));
        assert!(glob_match("*", "anything"));
        assert!(glob_match("layers.0.wq", "layers.0.wq"));
        assert!(!glob_match("layers.0.wq", "layers.0.wqx"));
        assert!(glob_match("*.fc*", "layers.3.fc2"));
        assert!(glob_match("a*b*c", "axxbyyc"));
        assert!(!glob_match("a*b*c", "axxbyy"));
        assert!(glob_match("ab**", "ab"));
    }

    #[test]
    fn rejects_with_path_qualified_errors() {
        let cases: &[(&str, &str)] = &[
            (
                "{\"version\":1,\"default\":{\"weight\":{\"kind\":\"fp8\"},\
                 \"act\":\"none\",\"algo\":\"none\",\"lowrank\":null},\
                 \"overrides\":[]}",
                "plan.default.weight.kind",
            ),
            (
                "{\"version\":1,\"default\":{\"weight\":{\"kind\":\"fp16\",\
                 \"zero\":1},\"act\":\"none\",\"algo\":\"none\",\
                 \"lowrank\":null},\"overrides\":[]}",
                "unknown key 'zero'",
            ),
            (
                "{\"version\":3,\"default\":{\"weight\":{\"kind\":\"fp16\"},\
                 \"act\":\"none\",\"algo\":\"none\",\"lowrank\":null},\
                 \"overrides\":[]}",
                "version",
            ),
            (
                "{\"version\":1,\"default\":{\"weight\":{\"kind\":\"mxint\",\
                 \"bits\":4,\"exp_bits\":4,\"block\":16},\"act\":\"none\",\
                 \"algo\":\"gptq\",\"lowrank\":null},\"overrides\":[]}",
                "requires an int weight format",
            ),
        ];
        for (text, needle) in cases {
            let err = QuantSpec::from_json(text).unwrap_err().to_string();
            assert!(err.contains(needle), "'{err}' missing '{needle}'");
        }
    }

    #[test]
    fn rejects_non_ascii_override_pattern() {
        let mut plan = l2qer_w4a8();
        plan.overrides.push(Override {
            pattern: "läyers.*".into(),
            spec: plan.default,
        });
        let err = plan.validate().unwrap_err().to_string();
        assert!(err.contains("printable ASCII"), "{err}");
    }

    #[test]
    fn sweep_names_resolve() {
        let p = QuantSpec::from_method_name("lqer-w2a8-k8").unwrap();
        let lr = p.default.lowrank.unwrap();
        assert_eq!((lr.k, lr.scaled), (8, false));
        let p = QuantSpec::from_method_name("l2qer-w2a8-k128").unwrap();
        let lr = p.default.lowrank.unwrap();
        assert_eq!((lr.k, lr.scaled), (128, true));
        assert!(QuantSpec::from_method_name("l2qer-w2a8-k").is_err());
        assert!(QuantSpec::from_method_name("l2qer-w2a8-kx4").is_err());
        assert!(QuantSpec::from_method_name("nope").is_err());
    }

    #[test]
    fn avg_bits_formulas() {
        // MXINT4 with 4-bit exponent over block 16 = 4.25 bits (paper 4.1).
        assert!((mxint_avg_bits(4, 4, 16) - 4.25).abs() < 1e-12);
        // INT4 g128 = 4.125 (paper's "4.1" column).
        assert!((int_group_avg_bits(4, 128) - 4.125).abs() < 1e-12);
        assert_eq!(mx(4).avg_bits(), 4.25);
        assert_eq!(ig(4, 128).avg_bits(), 4.125);
        assert_eq!(WeightFormat::Fp16.avg_bits(), 16.0);
        // Plan-level: l2qer-w4a8 on a square layer.
        let ls = l2qer_w4a8().default;
        let want = lqer_avg_bits(256, 256, 16, 4.25, 8.25);
        assert!((ls.avg_bits(256, 256) - want).abs() < 1e-12);
    }

    #[test]
    fn model_avg_bits_weights_by_layer_size() {
        let shapes = layer_shapes(64, 256, 2);
        assert_eq!(shapes.len(), 12);
        let fp = QuantSpec::from_method_name("fp16").unwrap();
        assert_eq!(fp.model_avg_bits(&shapes), 16.0);
        let mx4 = QuantSpec::from_method_name("mxint-w4a8").unwrap();
        assert!((mx4.model_avg_bits(&shapes) - 4.25).abs() < 1e-12);
    }

    #[test]
    fn quantizer_trait_matches_direct_calls() {
        let mut rng = Rng::new(7);
        let cols = 32;
        let data: Vec<f32> =
            (0..64 * cols).map(|_| rng.normal() as f32 * 0.4).collect();

        // MXINT weight orientation.
        let mut via_trait = data.clone();
        mx(4).quantizer().quantize(&mut via_trait, cols);
        let mut direct = data.clone();
        MxFormat::weight(4).quant_cols(&mut direct, cols);
        assert_eq!(via_trait, direct);

        // INT-g128 weight orientation.
        let mut via_trait = data.clone();
        ig(4, 16).quantizer().quantize(&mut via_trait, cols);
        let mut direct = data.clone();
        intq::int_quant_group_cols(&mut direct, cols, 4, 16);
        assert_eq!(via_trait, direct);

        // Per-token int8 activations.
        let mut via_trait = data.clone();
        ActFormat::Int8.quantizer().quantize(&mut via_trait, cols);
        let mut direct = data.clone();
        intq::int_quant_per_token(&mut direct, cols, 8);
        assert_eq!(via_trait, direct);

        // FP16 weights are identity; "none" acts are identity.
        let mut w = data.clone();
        WeightFormat::Fp16.quantizer().quantize(&mut w, cols);
        assert_eq!(w, data);
        let mut a = data.clone();
        ActFormat::None.quantizer().quantize(&mut a, cols);
        assert_eq!(a, data);
    }

    #[test]
    fn vector_wise_int_is_per_row_fp16_scale() {
        let cols = 8;
        let data: Vec<f32> = (0..2 * cols).map(|i| i as f32 - 3.0).collect();
        let mut via_trait = data.clone();
        ig(4, 0).quantizer().quantize(&mut via_trait, cols);
        let mut direct = data.clone();
        for row in direct.chunks_exact_mut(cols) {
            intq::int_quant_group_slice(row, 4, true);
        }
        assert_eq!(via_trait, direct);
    }
}
