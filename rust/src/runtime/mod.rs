//! PJRT runtime: loads AOT artifacts (HLO text + LQTW weights) and executes
//! them on the CPU PJRT client.  This is the only module that touches the
//! `xla` crate; everything above it (coordinator, eval) sees plain slices.
//!
//! Key decisions (see DESIGN.md §6 and /opt/xla-example/README.md):
//! * HLO **text** interchange — `HloModuleProto::from_text_file` reassigns
//!   the 64-bit instruction ids jax ≥ 0.5 emits that XLA 0.5.1 rejects.
//! * Weights are HLO *parameters*, uploaded once as device buffers and
//!   reused across every call (`execute_b`), so the request path never
//!   re-serializes the model.
//! * Graphs are lowered with `return_tuple=True`, so outputs arrive as one
//!   tuple literal that we decompose.

pub mod weights;

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{Context, Result};

pub use weights::WeightStore;

/// Execution statistics for the perf pass (§Perf of EXPERIMENTS.md).
#[derive(Debug, Default, Clone)]
pub struct ExecStats {
    pub calls: u64,
    pub exec_ns: u64,
    pub upload_ns: u64,
    pub download_ns: u64,
}

impl ExecStats {
    pub fn merge(&mut self, other: &ExecStats) {
        self.calls += other.calls;
        self.exec_ns += other.exec_ns;
        self.upload_ns += other.upload_ns;
        self.download_ns += other.download_ns;
    }
}

/// A compiled graph plus the device-resident weight buffers it closes over.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    weights: Vec<xla::PjRtBuffer>,
    pub n_outputs: usize,
    stats: Mutex<ExecStats>,
}

/// Dense f32 host tensor crossing the runtime boundary.
#[derive(Debug, Clone)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor { shape, data }
    }
}

/// Inputs that follow the weight parameters in a call.
pub enum Arg<'a> {
    I32(&'a [i32], Vec<usize>),
    F32(&'a [f32], Vec<usize>),
}

pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an HLO-text file and bind the weight store's tensors as the
    /// leading parameters.
    pub fn load(
        &self,
        hlo_path: &Path,
        store: &WeightStore,
        n_outputs: usize,
    ) -> Result<Executable> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| {
            anyhow::anyhow!("parsing {}: {e:?}", hlo_path.display())
        })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| {
                anyhow::anyhow!("compiling {}: {e:?}", hlo_path.display())
            })?;
        let mut weights = Vec::with_capacity(store.tensors.len());
        for t in &store.tensors {
            weights.push(
                self.client
                    .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)
                    .map_err(|e| {
                        anyhow::anyhow!("uploading {}: {e:?}", t.name)
                    })?,
            );
        }
        crate::debug!(
            "loaded {} ({} weight tensors) in {:.1}s",
            hlo_path.file_name().unwrap_or_default().to_string_lossy(),
            weights.len(),
            t0.elapsed().as_secs_f64()
        );
        Ok(Executable {
            exe,
            weights,
            n_outputs,
            stats: Mutex::new(ExecStats::default()),
        })
    }
}

impl Executable {
    /// Execute with the bound weights plus `args`; returns the decomposed
    /// output tuple as host tensors (f32; integer outputs are not used by
    /// any of our graphs).
    pub fn call(&self, rt: &Runtime, args: &[Arg]) -> Result<Vec<HostTensor>> {
        let mut stats = ExecStats { calls: 1, ..Default::default() };
        let t0 = Instant::now();
        let mut bufs: Vec<&xla::PjRtBuffer> = self.weights.iter().collect();
        let mut owned = Vec::with_capacity(args.len());
        for arg in args {
            let buf = match arg {
                Arg::I32(data, dims) => rt
                    .client
                    .buffer_from_host_buffer::<i32>(data, dims, None),
                Arg::F32(data, dims) => rt
                    .client
                    .buffer_from_host_buffer::<f32>(data, dims, None),
            }
            .map_err(|e| anyhow::anyhow!("arg upload: {e:?}"))?;
            owned.push(buf);
        }
        bufs.extend(owned.iter());
        stats.upload_ns = t0.elapsed().as_nanos() as u64;

        let t1 = Instant::now();
        let result = self
            .exe
            .execute_b(&bufs)
            .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?;
        stats.exec_ns = t1.elapsed().as_nanos() as u64;

        let t2 = Instant::now();
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("download: {e:?}"))?;
        let parts = tuple
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("tuple: {e:?}"))?;
        anyhow::ensure!(
            parts.len() == self.n_outputs,
            "expected {} outputs, got {}",
            self.n_outputs,
            parts.len()
        );
        let mut out = Vec::with_capacity(parts.len());
        for lit in parts {
            let shape = lit
                .array_shape()
                .map_err(|e| anyhow::anyhow!("shape: {e:?}"))?;
            let dims: Vec<usize> =
                shape.dims().iter().map(|d| *d as usize).collect();
            let data = lit
                .to_vec::<f32>()
                .map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))?;
            out.push(HostTensor::new(dims, data));
        }
        stats.download_ns = t2.elapsed().as_nanos() as u64;
        self.stats.lock().unwrap().merge(&stats);
        Ok(out)
    }

    pub fn stats(&self) -> ExecStats {
        self.stats.lock().unwrap().clone()
    }
}

// ---------------------------------------------------------------------------
// Model runner: the three graphs of one (model, method) run.
// ---------------------------------------------------------------------------

/// Identifies one loadable graph for caching.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct GraphKey {
    pub entry: String,
    pub b: usize,
    pub t: usize,
}

/// All executables of one PTQ run, loaded lazily per shape bucket.
pub struct ModelRunner {
    pub model: crate::config::ModelInfo,
    pub method: String,
    pub graph_tag: String,
    store: WeightStore,
    exes: Mutex<HashMap<GraphKey, std::sync::Arc<Executable>>>,
}

impl ModelRunner {
    /// Load the weight store for a run (graphs attach lazily).
    pub fn new(
        manifest: &crate::config::Manifest,
        model: &str,
        method: &str,
    ) -> Result<Self> {
        let run = manifest.run(model, method)?;
        let info = manifest.model(model)?.clone();
        let store = WeightStore::load(&run.weights)?;
        Ok(ModelRunner {
            model: info,
            method: method.to_string(),
            graph_tag: run.graph.clone(),
            store,
            exes: Mutex::new(HashMap::new()),
        })
    }

    fn outputs_for(entry: &str) -> usize {
        match entry {
            "score" => 1,
            "prefill" | "decode" => 3,
            _ => 1,
        }
    }

    /// Get (compiling if needed) the executable for an entry point.
    pub fn executable(
        &self,
        rt: &Runtime,
        manifest: &crate::config::Manifest,
        entry: &str,
        b: usize,
        t: usize,
    ) -> Result<std::sync::Arc<Executable>> {
        let key = GraphKey { entry: entry.to_string(), b, t };
        if let Some(e) = self.exes.lock().unwrap().get(&key) {
            return Ok(e.clone());
        }
        let g = manifest.graph(&self.model.name, &self.graph_tag, entry, b, t)?;
        let exe = std::sync::Arc::new(rt.load(
            &g.path,
            &self.store,
            Self::outputs_for(entry),
        )?);
        self.exes.lock().unwrap().insert(key, exe.clone());
        Ok(exe)
    }

    /// Full-sequence logits: tokens (b*t) -> logits (b, t, vocab).
    pub fn score(
        &self,
        rt: &Runtime,
        manifest: &crate::config::Manifest,
        tokens: &[i32],
        b: usize,
        t: usize,
    ) -> Result<HostTensor> {
        anyhow::ensure!(tokens.len() == b * t, "token count");
        let exe = self.executable(rt, manifest, "score", b, t)?;
        let mut out = exe.call(rt, &[Arg::I32(tokens, vec![b, t])])?;
        Ok(out.remove(0))
    }

    /// Prefill: tokens (b*t) -> (logits (b,t,v), k (L,b,t,d), v (L,b,t,d)).
    pub fn prefill(
        &self,
        rt: &Runtime,
        manifest: &crate::config::Manifest,
        tokens: &[i32],
        b: usize,
        t: usize,
    ) -> Result<(HostTensor, HostTensor, HostTensor)> {
        let exe = self.executable(rt, manifest, "prefill", b, t)?;
        let mut out = exe.call(rt, &[Arg::I32(tokens, vec![b, t])])?;
        anyhow::ensure!(out.len() == 3);
        let v = out.pop().unwrap();
        let k = out.pop().unwrap();
        let logits = out.pop().unwrap();
        Ok((logits, k, v))
    }

    /// One decode step over a batch bucket of size b.
    ///
    /// caches: (L, b, t_max, d) row-major; pos[b] marks the next position.
    /// Returns (logits (b,v), k_new (L,b,d), v_new (L,b,d)).
    #[allow(clippy::too_many_arguments)]
    pub fn decode(
        &self,
        rt: &Runtime,
        manifest: &crate::config::Manifest,
        token: &[i32],
        k_cache: &[f32],
        v_cache: &[f32],
        pos: &[i32],
        b: usize,
    ) -> Result<(HostTensor, HostTensor, HostTensor)> {
        let m = &self.model;
        let cache_dims = vec![m.layers, b, m.t_max, m.d];
        let n: usize = cache_dims.iter().product();
        anyhow::ensure!(k_cache.len() == n && v_cache.len() == n,
                        "cache size");
        let exe = self.executable(rt, manifest, "decode", b, 0)?;
        let mut out = exe.call(
            rt,
            &[
                Arg::I32(token, vec![b]),
                Arg::F32(k_cache, cache_dims.clone()),
                Arg::F32(v_cache, cache_dims),
                Arg::I32(pos, vec![b]),
            ],
        )?;
        anyhow::ensure!(out.len() == 3);
        let v = out.pop().unwrap();
        let k = out.pop().unwrap();
        let logits = out.pop().unwrap();
        Ok((logits, k, v))
    }

    /// Aggregate stats across all loaded executables.
    pub fn stats(&self) -> ExecStats {
        let mut agg = ExecStats::default();
        for exe in self.exes.lock().unwrap().values() {
            agg.merge(&exe.stats());
        }
        agg
    }
}
