//! PJRT runtime: loads AOT artifacts (HLO text + LQTW weights) and executes
//! them on the PJRT client.  This is the only module that touches the
//! `xla` backend (stubbed offline — see [`crate::xla`]); everything above
//! it (coordinator, eval) sees plain slices and opaque device handles.
//!
//! Key decisions (see DESIGN.md §6 and §7):
//! * HLO **text** interchange — `HloModuleProto::from_text_file` reassigns
//!   the 64-bit instruction ids jax ≥ 0.5 emits that XLA 0.5.1 rejects.
//! * Weights are HLO *parameters*, uploaded once as device buffers and
//!   reused across every call, so the request path never re-serializes
//!   the model.
//! * [`Executable::call_staged`] splits a call into upload / execute /
//!   download stages: inputs may be host slices (uploaded, counted in
//!   `upload_bytes`) or device-retained buffers from a previous step
//!   (free), and each output is either downloaded or retained on device.
//! * [`DeviceKvSession`] owns the persistent K/V cache buffers of one
//!   decode batch and re-feeds each step's cache *outputs* as the next
//!   step's cache *inputs*, so the steady-state decode path moves only
//!   O(B) token ids/positions up and O(B·vocab) logits down — never the
//!   O(L·B·T_max·d) caches (DESIGN.md §6).

pub mod weights;

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::kvcache::paged::{BlockTable, SENTINEL_BLOCK};
use crate::xla;

pub use weights::WeightStore;

/// Execution statistics for the perf pass (§Perf of EXPERIMENTS.md).
#[derive(Debug, Default, Clone)]
pub struct ExecStats {
    pub calls: u64,
    pub exec_ns: u64,
    pub upload_ns: u64,
    pub download_ns: u64,
    /// Host→device bytes actually uploaded (device-retained inputs are
    /// free and not counted).
    pub upload_bytes: u64,
    /// Device→host bytes actually downloaded (retained outputs are not
    /// counted).
    pub download_bytes: u64,
}

impl ExecStats {
    pub fn merge(&mut self, other: &ExecStats) {
        self.calls += other.calls;
        self.exec_ns += other.exec_ns;
        self.upload_ns += other.upload_ns;
        self.download_ns += other.download_ns;
        self.upload_bytes += other.upload_bytes;
        self.download_bytes += other.download_bytes;
    }

    /// Mean host↔device traffic per call, in bytes.
    pub fn bytes_per_call(&self) -> f64 {
        if self.calls == 0 {
            0.0
        } else {
            (self.upload_bytes + self.download_bytes) as f64
                / self.calls as f64
        }
    }
}

/// A compiled graph plus the device-resident weight buffers it closes over.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    weights: Vec<xla::PjRtBuffer>,
    pub n_outputs: usize,
    stats: Mutex<ExecStats>,
}

/// Dense f32 host tensor crossing the runtime boundary.
#[derive(Debug, Clone)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor { shape, data }
    }
}

/// One input to a staged call: host data (uploaded per call) or a
/// device-retained buffer from a previous call (no transfer).
pub enum Input<'a> {
    I32(&'a [i32], Vec<usize>),
    F32(&'a [f32], Vec<usize>),
    Device(&'a xla::PjRtBuffer),
}

/// One output of a staged call: downloaded to host or retained on device.
pub enum Output {
    Host(HostTensor),
    Device(xla::PjRtBuffer),
}

fn expect_host(o: Option<Output>) -> Result<HostTensor> {
    match o {
        Some(Output::Host(t)) => Ok(t),
        _ => anyhow::bail!("expected downloaded output"),
    }
}

fn expect_device(o: Option<Output>) -> Result<xla::PjRtBuffer> {
    match o {
        Some(Output::Device(b)) => Ok(b),
        _ => anyhow::bail!("expected device-retained output"),
    }
}

pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an HLO-text file and bind the weight store's tensors as the
    /// leading parameters.
    pub fn load(
        &self,
        hlo_path: &Path,
        store: &WeightStore,
        n_outputs: usize,
    ) -> Result<Executable> {
        self.load_impl(hlo_path, Some(store), n_outputs)
    }

    /// Compile an HLO-text file that takes no weight parameters (pure
    /// data-movement graphs like the KV-cache prefill scatter).
    pub fn load_unparameterized(
        &self,
        hlo_path: &Path,
        n_outputs: usize,
    ) -> Result<Executable> {
        self.load_impl(hlo_path, None, n_outputs)
    }

    fn load_impl(
        &self,
        hlo_path: &Path,
        store: Option<&WeightStore>,
        n_outputs: usize,
    ) -> Result<Executable> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| {
            anyhow::anyhow!("parsing {}: {e:?}", hlo_path.display())
        })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| {
                anyhow::anyhow!("compiling {}: {e:?}", hlo_path.display())
            })?;
        let mut weights = Vec::new();
        if let Some(store) = store {
            weights.reserve(store.tensors.len());
            for t in &store.tensors {
                weights.push(
                    self.client
                        .buffer_from_host_buffer::<f32>(
                            &t.data, &t.shape, None,
                        )
                        .map_err(|e| {
                            anyhow::anyhow!("uploading {}: {e:?}", t.name)
                        })?,
                );
            }
        }
        crate::debug!(
            "loaded {} ({} weight tensors) in {:.1}s",
            hlo_path.file_name().unwrap_or_default().to_string_lossy(),
            weights.len(),
            t0.elapsed().as_secs_f64()
        );
        Ok(Executable {
            exe,
            weights,
            n_outputs,
            stats: Mutex::new(ExecStats::default()),
        })
    }
}

impl Executable {
    /// Execute with the bound weights plus `inputs`, downloading every
    /// output (f32; integer outputs are not used by any of our graphs).
    pub fn call(&self, rt: &Runtime, inputs: &[Input]) -> Result<Vec<HostTensor>> {
        let retain = vec![false; self.n_outputs];
        let outs = self.call_staged(rt, inputs, &retain)?;
        let mut host = Vec::with_capacity(outs.len());
        for o in outs {
            host.push(expect_host(Some(o))?);
        }
        Ok(host)
    }

    /// Staged execution: upload host inputs, execute, then download or
    /// retain each output according to `retain` (length `n_outputs`;
    /// `true` keeps the output on device as an [`Output::Device`] buffer
    /// that later calls can re-feed via [`Input::Device`]).
    pub fn call_staged(
        &self,
        rt: &Runtime,
        inputs: &[Input],
        retain: &[bool],
    ) -> Result<Vec<Output>> {
        anyhow::ensure!(
            retain.len() == self.n_outputs,
            "retain mask {} != outputs {}",
            retain.len(),
            self.n_outputs
        );
        let mut stats = ExecStats { calls: 1, ..Default::default() };

        // Stage 1: upload host inputs (device inputs are free).
        let t0 = Instant::now();
        enum Slot<'a> {
            Owned(usize),
            Borrowed(&'a xla::PjRtBuffer),
        }
        let mut owned: Vec<xla::PjRtBuffer> = Vec::new();
        let mut slots: Vec<Slot> = Vec::with_capacity(inputs.len());
        for input in inputs {
            match input {
                Input::I32(data, dims) => {
                    stats.upload_bytes += (data.len() * 4) as u64;
                    let buf = rt
                        .client
                        .buffer_from_host_buffer::<i32>(data, dims, None)
                        .map_err(|e| anyhow::anyhow!("arg upload: {e:?}"))?;
                    slots.push(Slot::Owned(owned.len()));
                    owned.push(buf);
                }
                Input::F32(data, dims) => {
                    stats.upload_bytes += (data.len() * 4) as u64;
                    let buf = rt
                        .client
                        .buffer_from_host_buffer::<f32>(data, dims, None)
                        .map_err(|e| anyhow::anyhow!("arg upload: {e:?}"))?;
                    slots.push(Slot::Owned(owned.len()));
                    owned.push(buf);
                }
                Input::Device(b) => slots.push(Slot::Borrowed(*b)),
            }
        }
        stats.upload_ns = t0.elapsed().as_nanos() as u64;

        // Stage 2: execute with weights + inputs in parameter order.
        let t1 = Instant::now();
        let mut bufs: Vec<&xla::PjRtBuffer> = self.weights.iter().collect();
        for slot in &slots {
            bufs.push(match slot {
                Slot::Owned(i) => &owned[*i],
                Slot::Borrowed(b) => *b,
            });
        }
        let mut result = self
            .exe
            .execute_b(&bufs)
            .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?;
        stats.exec_ns = t1.elapsed().as_nanos() as u64;
        anyhow::ensure!(!result.is_empty(), "no device results");
        let outs_dev = result.swap_remove(0);
        anyhow::ensure!(
            outs_dev.len() == self.n_outputs,
            "expected {} outputs, got {}",
            self.n_outputs,
            outs_dev.len()
        );

        // Stage 3: download unretained outputs.
        let t2 = Instant::now();
        let mut out = Vec::with_capacity(outs_dev.len());
        for (i, buf) in outs_dev.into_iter().enumerate() {
            if retain[i] {
                out.push(Output::Device(buf));
                continue;
            }
            let lit = buf
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("download: {e:?}"))?;
            let shape = lit
                .array_shape()
                .map_err(|e| anyhow::anyhow!("shape: {e:?}"))?;
            let dims: Vec<usize> =
                shape.dims().iter().map(|d| *d as usize).collect();
            let data = lit
                .to_vec::<f32>()
                .map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))?;
            stats.download_bytes += (data.len() * 4) as u64;
            out.push(Output::Host(HostTensor::new(dims, data)));
        }
        stats.download_ns = t2.elapsed().as_nanos() as u64;
        self.stats.lock().unwrap().merge(&stats);
        Ok(out)
    }

    pub fn stats(&self) -> ExecStats {
        self.stats.lock().unwrap().clone()
    }
}

// ---------------------------------------------------------------------------
// Device-resident KV session
// ---------------------------------------------------------------------------

/// Persistent device-side K/V cache of one decode batch (DESIGN.md §6).
///
/// The session owns the `(L, B, T_max, d)` cache buffers.  Each
/// `decode_dev` step consumes them as inputs and produces *updated full
/// caches* as retained outputs, which the session swaps in for the next
/// step — the caches never cross the PJRT boundary after creation.  Slot
/// occupancy/positions live in [`crate::kvcache::SlotMap`] on the host;
/// this type is pure storage.
pub struct DeviceKvSession {
    k: xla::PjRtBuffer,
    v: xla::PjRtBuffer,
    pub layers: usize,
    pub batch: usize,
    pub t_max: usize,
    pub d: usize,
    /// Block rows per block when the session is paged
    /// (`(L, num_blocks, block_size, d)` layout, DESIGN.md §10);
    /// 0 for the flat per-lane layout.
    pub block_size: usize,
}

impl DeviceKvSession {
    /// Allocate zeroed resident caches (one-time O(L·B·T_max·d) upload).
    pub fn new(
        rt: &Runtime,
        layers: usize,
        batch: usize,
        t_max: usize,
        d: usize,
    ) -> Result<DeviceKvSession> {
        let dims = [layers, batch, t_max, d];
        let zeros = vec![0.0f32; layers * batch * t_max * d];
        let k = rt
            .client
            .buffer_from_host_buffer::<f32>(&zeros, &dims, None)
            .map_err(|e| anyhow::anyhow!("k cache upload: {e:?}"))?;
        let v = rt
            .client
            .buffer_from_host_buffer::<f32>(&zeros, &dims, None)
            .map_err(|e| anyhow::anyhow!("v cache upload: {e:?}"))?;
        Ok(DeviceKvSession { k, v, layers, batch, t_max, d,
                             block_size: 0 })
    }

    /// Allocate a zeroed *paged* resident cache: `(L, num_blocks,
    /// block_size, d)`, a block pool addressed through block-table
    /// operands by the `decode_paged` / `kvwrite_paged` graphs.  The
    /// pool's second/third dims reuse the `batch`/`t_max` fields (same
    /// roles: rows = dim2 × dim3).
    pub fn new_paged(
        rt: &Runtime,
        layers: usize,
        num_blocks: usize,
        block_size: usize,
        d: usize,
    ) -> Result<DeviceKvSession> {
        let mut s = Self::new(rt, layers, num_blocks, block_size, d)?;
        s.block_size = block_size;
        Ok(s)
    }

    /// Number of pool blocks of a paged session.
    pub fn num_blocks(&self) -> usize {
        self.batch
    }

    /// Total resident cache footprint in bytes.
    pub fn cache_bytes(&self) -> usize {
        2 * self.layers * self.batch * self.t_max * self.d * 4
    }

    /// Scatter device-retained prefill outputs (`(L, 1, t, d)`) into batch
    /// `slot` via the `kvwrite` graph; no host↔device tensor traffic
    /// beyond the 4-byte slot index.
    pub fn write_prefill(
        &mut self,
        rt: &Runtime,
        exe: &Executable,
        k_pre: &xla::PjRtBuffer,
        v_pre: &xla::PjRtBuffer,
        slot: usize,
    ) -> Result<()> {
        let slot_id = [slot as i32];
        let outs = exe.call_staged(
            rt,
            &[
                Input::Device(&self.k),
                Input::Device(&self.v),
                Input::Device(k_pre),
                Input::Device(v_pre),
                Input::I32(&slot_id, vec![]),
            ],
            &[true, true],
        )?;
        let mut it = outs.into_iter();
        self.k = expect_device(it.next())?;
        self.v = expect_device(it.next())?;
        Ok(())
    }

    /// One `decode_dev` step: uploads O(B) token ids + positions,
    /// downloads O(B·vocab) logits, retains the updated caches on device.
    pub fn decode(
        &mut self,
        rt: &Runtime,
        exe: &Executable,
        token: &[i32],
        pos: &[i32],
    ) -> Result<HostTensor> {
        let b = self.batch;
        anyhow::ensure!(
            token.len() == b && pos.len() == b,
            "decode batch size"
        );
        let outs = exe.call_staged(
            rt,
            &[
                Input::I32(token, vec![b]),
                Input::Device(&self.k),
                Input::Device(&self.v),
                Input::I32(pos, vec![b]),
            ],
            &[false, true, true],
        )?;
        let mut it = outs.into_iter();
        let logits = expect_host(it.next())?;
        self.k = expect_device(it.next())?;
        self.v = expect_device(it.next())?;
        Ok(logits)
    }

    /// One `decode_paged` step: like [`Self::decode`], plus the flattened
    /// `(b, max_blocks)` block-table operand that turns the in-graph DUS
    /// append into a table-indexed write (free lanes point at the
    /// sentinel block).
    #[allow(clippy::too_many_arguments)]
    pub fn decode_paged(
        &mut self,
        rt: &Runtime,
        exe: &Executable,
        token: &[i32],
        pos: &[i32],
        tables_flat: &[i32],
        b: usize,
        max_blocks: usize,
    ) -> Result<HostTensor> {
        anyhow::ensure!(self.block_size > 0, "session is not paged");
        anyhow::ensure!(
            token.len() == b
                && pos.len() == b
                && tables_flat.len() == b * max_blocks,
            "paged decode operand sizes"
        );
        let outs = exe.call_staged(
            rt,
            &[
                Input::I32(token, vec![b]),
                Input::Device(&self.k),
                Input::Device(&self.v),
                Input::I32(pos, vec![b]),
                Input::I32(tables_flat, vec![b, max_blocks]),
            ],
            &[false, true, true],
        )?;
        let mut it = outs.into_iter();
        let logits = expect_host(it.next())?;
        self.k = expect_device(it.next())?;
        self.v = expect_device(it.next())?;
        Ok(logits)
    }

    /// One fused chunked-prefill step (`prefill_chunk` graph,
    /// DESIGN.md §12): uploads the prefix tokens, computes the prefill
    /// in-graph, scatters the listed chunks' K/V into their pool blocks
    /// (sentinel ids mark chunks earlier ticks already installed, plus
    /// right-padding), retains the updated caches on device, and
    /// downloads only the `(1, t, vocab)` logits.
    pub fn prefill_chunk_paged(
        &mut self,
        rt: &Runtime,
        exe: &Executable,
        toks: &[i32],
        block_ids: &[i32],
    ) -> Result<HostTensor> {
        anyhow::ensure!(self.block_size > 0, "session is not paged");
        let t = toks.len();
        let outs = exe.call_staged(
            rt,
            &[
                Input::I32(toks, vec![1, t]),
                Input::Device(&self.k),
                Input::Device(&self.v),
                Input::I32(block_ids, vec![block_ids.len()]),
            ],
            &[false, true, true],
        )?;
        let mut it = outs.into_iter();
        let logits = expect_host(it.next())?;
        self.k = expect_device(it.next())?;
        self.v = expect_device(it.next())?;
        Ok(logits)
    }

    /// Scatter device-retained prefill outputs (`(L, 1, t, d)`) into the
    /// pool blocks listed in `block_ids` (one id per `block_size`-row
    /// chunk; padding chunks carry the sentinel id) via the
    /// `kvwrite_paged` graph.
    pub fn write_prefill_paged(
        &mut self,
        rt: &Runtime,
        exe: &Executable,
        k_pre: &xla::PjRtBuffer,
        v_pre: &xla::PjRtBuffer,
        block_ids: &[i32],
    ) -> Result<()> {
        anyhow::ensure!(self.block_size > 0, "session is not paged");
        let outs = exe.call_staged(
            rt,
            &[
                Input::Device(&self.k),
                Input::Device(&self.v),
                Input::Device(k_pre),
                Input::Device(v_pre),
                Input::I32(block_ids, vec![block_ids.len()]),
            ],
            &[true, true],
        )?;
        let mut it = outs.into_iter();
        self.k = expect_device(it.next())?;
        self.v = expect_device(it.next())?;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Model runner: the lowered graphs of one (model, method) run.
// ---------------------------------------------------------------------------

/// Identifies one loadable graph for caching.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct GraphKey {
    pub entry: String,
    pub b: usize,
    pub t: usize,
}

/// All executables of one PTQ run, loaded lazily per shape bucket.
pub struct ModelRunner {
    pub model: crate::config::ModelInfo,
    pub method: String,
    pub graph_tag: String,
    store: WeightStore,
    exes: Mutex<HashMap<GraphKey, std::sync::Arc<Executable>>>,
}

impl ModelRunner {
    /// Load the weight store for a run (graphs attach lazily).
    pub fn new(
        manifest: &crate::config::Manifest,
        model: &str,
        method: &str,
    ) -> Result<Self> {
        let run = manifest.run(model, method)?;
        let info = manifest.model(model)?.clone();
        let store = WeightStore::load(&run.weights)?;
        Ok(ModelRunner {
            model: info,
            method: method.to_string(),
            graph_tag: run.graph.clone(),
            store,
            exes: Mutex::new(HashMap::new()),
        })
    }

    fn outputs_for(entry: &str) -> usize {
        match entry {
            "score" => 1,
            "prefill" | "decode" | "decode_dev" | "decode_paged"
            | "prefill_chunk" | "decode_draft" | "verify_batch" => 3,
            "kvwrite" | "kvwrite_paged" => 2,
            _ => 1,
        }
    }

    /// Get (compiling if needed) the executable for an entry point.
    pub fn executable(
        &self,
        rt: &Runtime,
        manifest: &crate::config::Manifest,
        entry: &str,
        b: usize,
        t: usize,
    ) -> Result<std::sync::Arc<Executable>> {
        let key = GraphKey { entry: entry.to_string(), b, t };
        if let Some(e) = self.exes.lock().unwrap().get(&key) {
            return Ok(e.clone());
        }
        // kvwrite/kvwrite_paged are pure data movement: lowered once
        // without weight params under the fixed "cache" tag, shared by
        // every method.
        let unparameterized =
            entry == "kvwrite" || entry == "kvwrite_paged";
        let tag = if unparameterized { "cache" } else { &self.graph_tag };
        let g = manifest.graph(&self.model.name, tag, entry, b, t)?;
        let n_out = Self::outputs_for(entry);
        let exe = std::sync::Arc::new(if unparameterized {
            rt.load_unparameterized(&g.path, n_out)?
        } else {
            rt.load(&g.path, &self.store, n_out)?
        });
        self.exes.lock().unwrap().insert(key, exe.clone());
        Ok(exe)
    }

    /// Full-sequence logits: tokens (b*t) -> logits (b, t, vocab).
    pub fn score(
        &self,
        rt: &Runtime,
        manifest: &crate::config::Manifest,
        tokens: &[i32],
        b: usize,
        t: usize,
    ) -> Result<HostTensor> {
        anyhow::ensure!(tokens.len() == b * t, "token count");
        let exe = self.executable(rt, manifest, "score", b, t)?;
        let mut out = exe.call(rt, &[Input::I32(tokens, vec![b, t])])?;
        Ok(out.remove(0))
    }

    /// Prefill: tokens (b*t) -> (logits (b,t,v), k (L,b,t,d), v (L,b,t,d)),
    /// all downloaded to host (legacy host-cache path, eval, tests).
    pub fn prefill(
        &self,
        rt: &Runtime,
        manifest: &crate::config::Manifest,
        tokens: &[i32],
        b: usize,
        t: usize,
    ) -> Result<(HostTensor, HostTensor, HostTensor)> {
        anyhow::ensure!(tokens.len() == b * t, "token count");
        let exe = self.executable(rt, manifest, "prefill", b, t)?;
        let mut out = exe.call(rt, &[Input::I32(tokens, vec![b, t])])?;
        anyhow::ensure!(out.len() == 3);
        let v = out.pop().unwrap();
        let k = out.pop().unwrap();
        let logits = out.pop().unwrap();
        Ok((logits, k, v))
    }

    /// Prefill with the K/V outputs retained on device for a
    /// [`DeviceKvSession`] scatter; only the logits are downloaded.
    pub fn prefill_retained(
        &self,
        rt: &Runtime,
        manifest: &crate::config::Manifest,
        tokens: &[i32],
        b: usize,
        t: usize,
    ) -> Result<(HostTensor, xla::PjRtBuffer, xla::PjRtBuffer)> {
        anyhow::ensure!(tokens.len() == b * t, "token count");
        let exe = self.executable(rt, manifest, "prefill", b, t)?;
        let outs = exe.call_staged(
            rt,
            &[Input::I32(tokens, vec![b, t])],
            &[false, true, true],
        )?;
        let mut it = outs.into_iter();
        let logits = expect_host(it.next())?;
        let k = expect_device(it.next())?;
        let v = expect_device(it.next())?;
        Ok((logits, k, v))
    }

    /// One legacy host-cache decode step over a batch bucket of size b.
    ///
    /// caches: (L, b, t_max, d) row-major; pos[b] marks the next position.
    /// Returns (logits (b,v), k_new (L,b,d), v_new (L,b,d)).  Uploads the
    /// full caches every step — kept as the bit-exactness oracle for the
    /// device-resident path.
    #[allow(clippy::too_many_arguments)]
    pub fn decode(
        &self,
        rt: &Runtime,
        manifest: &crate::config::Manifest,
        token: &[i32],
        k_cache: &[f32],
        v_cache: &[f32],
        pos: &[i32],
        b: usize,
    ) -> Result<(HostTensor, HostTensor, HostTensor)> {
        let m = &self.model;
        let cache_dims = vec![m.layers, b, m.t_max, m.d];
        let n: usize = cache_dims.iter().product();
        anyhow::ensure!(k_cache.len() == n && v_cache.len() == n,
                        "cache size");
        let exe = self.executable(rt, manifest, "decode", b, 0)?;
        let mut out = exe.call(
            rt,
            &[
                Input::I32(token, vec![b]),
                Input::F32(k_cache, cache_dims.clone()),
                Input::F32(v_cache, cache_dims),
                Input::I32(pos, vec![b]),
            ],
        )?;
        anyhow::ensure!(out.len() == 3);
        let v = out.pop().unwrap();
        let k = out.pop().unwrap();
        let logits = out.pop().unwrap();
        Ok((logits, k, v))
    }

    /// One device-resident decode step (`decode_dev` graph): the session's
    /// cache buffers are re-fed as inputs and the updated caches are
    /// retained on device.
    pub fn decode_resident(
        &self,
        rt: &Runtime,
        manifest: &crate::config::Manifest,
        session: &mut DeviceKvSession,
        token: &[i32],
        pos: &[i32],
    ) -> Result<HostTensor> {
        let exe =
            self.executable(rt, manifest, "decode_dev", session.batch, 0)?;
        session.decode(rt, &exe, token, pos)
    }

    /// Scatter retained prefill outputs into a session slot (`kvwrite`
    /// graph for this batch and prefill bucket `t`).
    pub fn write_prefill_resident(
        &self,
        rt: &Runtime,
        manifest: &crate::config::Manifest,
        session: &mut DeviceKvSession,
        slot: usize,
        k_pre: &xla::PjRtBuffer,
        v_pre: &xla::PjRtBuffer,
        t: usize,
    ) -> Result<()> {
        let exe =
            self.executable(rt, manifest, "kvwrite", session.batch, t)?;
        session.write_prefill(rt, &exe, k_pre, v_pre, slot)
    }

    /// One paged device-resident decode step (`decode_paged` graph):
    /// `tables` is indexed by lane; each lane's table is padded to
    /// `t_max / block_size` entries with the sentinel block id (free
    /// lanes are all-sentinel, which is where their dead DUS write
    /// parks).
    #[allow(clippy::too_many_arguments)]
    pub fn decode_resident_paged(
        &self,
        rt: &Runtime,
        manifest: &crate::config::Manifest,
        session: &mut DeviceKvSession,
        token: &[i32],
        pos: &[i32],
        tables: &[BlockTable],
        t_max: usize,
    ) -> Result<HostTensor> {
        let b = token.len();
        anyhow::ensure!(session.block_size > 0, "session is not paged");
        anyhow::ensure!(
            t_max % session.block_size == 0,
            "t_max {t_max} not a multiple of block_size {}",
            session.block_size
        );
        let max_blocks = t_max / session.block_size;
        let mut flat = vec![SENTINEL_BLOCK as i32; b * max_blocks];
        for (lane, table) in tables.iter().enumerate() {
            anyhow::ensure!(
                table.len() <= max_blocks,
                "lane {lane} table longer than t_max/block_size"
            );
            for (c, &id) in table.blocks().iter().enumerate() {
                flat[lane * max_blocks + c] = id as i32;
            }
        }
        let exe =
            self.executable(rt, manifest, "decode_paged", b, 0)?;
        session.decode_paged(rt, &exe, token, pos, &flat, b, max_blocks)
    }

    /// Block-id operand of a chunked paged prefill scatter: one id per
    /// `block_size`-row chunk of the `t`-row bucket.  Chunks fully
    /// below `from_row` were installed by earlier ticks and chunks past
    /// the table are right-padding — both park in the sentinel, so a
    /// chunk write never re-touches finalized blocks.
    fn chunk_block_ids(
        table: &BlockTable,
        t: usize,
        block_size: usize,
        from_row: usize,
    ) -> Vec<i32> {
        (0..t / block_size)
            .map(|c| {
                if (c + 1) * block_size <= from_row {
                    return SENTINEL_BLOCK as i32;
                }
                table
                    .blocks()
                    .get(c)
                    .map(|&id| id as i32)
                    .unwrap_or(SENTINEL_BLOCK as i32)
            })
            .collect()
    }

    /// Scatter retained prefill outputs into pool blocks
    /// (`kvwrite_paged` graph for prefill bucket `t`): one block id per
    /// `block_size`-row chunk, with chunks below `from_row` (already
    /// installed by earlier prefill chunks) and padding chunks parked
    /// in the sentinel.  A monolithic prefill passes `from_row == 0`.
    #[allow(clippy::too_many_arguments)]
    pub fn write_prefill_resident_paged(
        &self,
        rt: &Runtime,
        manifest: &crate::config::Manifest,
        session: &mut DeviceKvSession,
        table: &BlockTable,
        k_pre: &xla::PjRtBuffer,
        v_pre: &xla::PjRtBuffer,
        t: usize,
        from_row: usize,
    ) -> Result<()> {
        anyhow::ensure!(session.block_size > 0, "session is not paged");
        anyhow::ensure!(
            t % session.block_size == 0,
            "prefill bucket {t} not a multiple of block_size {}",
            session.block_size
        );
        let ids =
            Self::chunk_block_ids(table, t, session.block_size, from_row);
        let exe = self.executable(
            rt, manifest, "kvwrite_paged",
            session.num_blocks(), t,
        )?;
        session.write_prefill_paged(rt, &exe, k_pre, v_pre, &ids)
    }

    /// One fused chunked-prefill step (`prefill_chunk` graph, gated on
    /// artifacts carrying manifest `serve.chunk`): computes the
    /// `t`-bucket prefill of `toks` and scatters only the chunks at or
    /// above `from_row` into their table blocks, caches staying
    /// resident.  Returns the prefill logits.
    #[allow(clippy::too_many_arguments)]
    pub fn prefill_chunk_resident_paged(
        &self,
        rt: &Runtime,
        manifest: &crate::config::Manifest,
        session: &mut DeviceKvSession,
        table: &BlockTable,
        toks: &[i32],
        t: usize,
        from_row: usize,
    ) -> Result<HostTensor> {
        anyhow::ensure!(session.block_size > 0, "session is not paged");
        anyhow::ensure!(toks.len() == t, "token count");
        anyhow::ensure!(
            t % session.block_size == 0,
            "prefill bucket {t} not a multiple of block_size {}",
            session.block_size
        );
        let ids =
            Self::chunk_block_ids(table, t, session.block_size, from_row);
        let exe = self.executable(
            rt, manifest, "prefill_chunk",
            session.num_blocks(), t,
        )?;
        session.prefill_chunk_paged(rt, &exe, toks, &ids)
    }

    /// Aggregate stats across all loaded executables.
    pub fn stats(&self) -> ExecStats {
        let mut agg = ExecStats::default();
        for exe in self.exes.lock().unwrap().values() {
            agg.merge(&exe.stats());
        }
        agg
    }

    /// Aggregate stats for one entry point (e.g. per-decode-step
    /// host↔device traffic).
    pub fn entry_stats(&self, entry: &str) -> ExecStats {
        let mut agg = ExecStats::default();
        for (key, exe) in self.exes.lock().unwrap().iter() {
            if key.entry == entry {
                agg.merge(&exe.stats());
            }
        }
        agg
    }
}
