//! LQTW weight-file loader.
//!
//! Format (written by `python/compile/aot.py::write_lqtw`):
//!
//! ```text
//! magic  b"LQTW0001"
//! u32    manifest length (little endian)
//! bytes  JSON manifest {"tensors": [{name, shape, offset, nbytes}...],
//!                       "meta": {...}}
//! pad    zero bytes to a 64-byte boundary
//! data   raw f32 little-endian tensors, in manifest order
//! ```
//!
//! Tensor order in the manifest is jax tree-flatten order, which is the
//! HLO parameter order of every lowered graph for this run.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json;

#[derive(Debug, Clone)]
pub struct Tensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

#[derive(Debug)]
pub struct WeightStore {
    pub tensors: Vec<Tensor>,
    pub meta: json::Value,
    /// name -> index into `tensors`, built once at load so per-tensor
    /// lookups are O(1) instead of a linear scan.
    index: HashMap<String, usize>,
}

pub const MAGIC: &[u8; 8] = b"LQTW0001";

impl WeightStore {
    pub fn load(path: &Path) -> Result<WeightStore> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading {}", path.display()))?;
        anyhow::ensure!(bytes.len() > 12, "file too small");
        anyhow::ensure!(&bytes[..8] == MAGIC, "bad magic in {}",
                        path.display());
        let mlen =
            u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]])
                as usize;
        anyhow::ensure!(bytes.len() >= 12 + mlen, "truncated manifest");
        let manifest: json::Value = json::parse(
            std::str::from_utf8(&bytes[12..12 + mlen])
                .context("manifest not utf-8")?,
        )?;
        let data_start = (12 + mlen).div_ceil(64) * 64;

        let mut tensors = Vec::new();
        for t in manifest.req("tensors")?.as_array().unwrap_or(&[]) {
            let name = t.str_at("name")?;
            let shape: Vec<usize> = t
                .req("shape")?
                .as_array()
                .unwrap_or(&[])
                .iter()
                .filter_map(|x| x.as_usize())
                .collect();
            let offset = t.usize_at("offset")?;
            let nbytes = t.usize_at("nbytes")?;
            let n = shape.iter().product::<usize>();
            anyhow::ensure!(nbytes == n * 4, "{name}: nbytes/shape mismatch");
            let start = data_start + offset;
            anyhow::ensure!(
                start + nbytes <= bytes.len(),
                "{name}: data out of range"
            );
            let data: Vec<f32> = bytes[start..start + nbytes]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            tensors.push(Tensor { name, shape, data });
        }
        let meta = manifest
            .get("meta")
            .cloned()
            .unwrap_or(json::Value::Obj(vec![]));
        let mut index = HashMap::with_capacity(tensors.len());
        for (i, t) in tensors.iter().enumerate() {
            anyhow::ensure!(
                index.insert(t.name.clone(), i).is_none(),
                "duplicate tensor name '{}' in {}",
                t.name,
                path.display()
            );
        }
        Ok(WeightStore { tensors, meta, index })
    }

    pub fn tensor(&self, name: &str) -> Option<&Tensor> {
        self.index.get(name).map(|&i| &self.tensors[i])
    }

    pub fn total_params(&self) -> usize {
        self.tensors.iter().map(|t| t.data.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_test_file(path: &Path) {
        let manifest = r#"{"tensors": [
            {"name": "a", "shape": [2, 2], "offset": 0, "nbytes": 16},
            {"name": "b", "shape": [3], "offset": 16, "nbytes": 12}],
            "meta": {"model": "m"}}"#;
        let mut f = std::fs::File::create(path).unwrap();
        f.write_all(MAGIC).unwrap();
        f.write_all(&(manifest.len() as u32).to_le_bytes()).unwrap();
        f.write_all(manifest.as_bytes()).unwrap();
        let pos = 12 + manifest.len();
        f.write_all(&vec![0u8; pos.div_ceil(64) * 64 - pos]).unwrap();
        for v in [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0] {
            f.write_all(&v.to_le_bytes()).unwrap();
        }
    }

    #[test]
    fn loads_tensors_in_order() {
        let path = std::env::temp_dir().join("lqtw_test.bin");
        write_test_file(&path);
        let ws = WeightStore::load(&path).unwrap();
        assert_eq!(ws.tensors.len(), 2);
        assert_eq!(ws.tensors[0].name, "a");
        assert_eq!(ws.tensors[0].data, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(ws.tensors[1].data, vec![5.0, 6.0, 7.0]);
        assert_eq!(ws.total_params(), 7);
        assert_eq!(ws.meta.str_at("model").unwrap(), "m");
        assert!(ws.tensor("b").is_some());
        assert!(ws.tensor("c").is_none());
    }

    #[test]
    fn rejects_duplicate_tensor_names() {
        let path = std::env::temp_dir().join("lqtw_dup.bin");
        let manifest = r#"{"tensors": [
            {"name": "a", "shape": [2], "offset": 0, "nbytes": 8},
            {"name": "a", "shape": [2], "offset": 8, "nbytes": 8}],
            "meta": {}}"#;
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(MAGIC).unwrap();
        f.write_all(&(manifest.len() as u32).to_le_bytes()).unwrap();
        f.write_all(manifest.as_bytes()).unwrap();
        let pos = 12 + manifest.len();
        f.write_all(&vec![0u8; pos.div_ceil(64) * 64 - pos]).unwrap();
        for v in [1.0f32, 2.0, 3.0, 4.0] {
            f.write_all(&v.to_le_bytes()).unwrap();
        }
        drop(f);
        let err = WeightStore::load(&path).unwrap_err().to_string();
        assert!(err.contains("duplicate tensor name"), "{err}");
    }

    #[test]
    fn rejects_bad_magic() {
        let path = std::env::temp_dir().join("lqtw_bad.bin");
        std::fs::write(&path, b"NOTLQTW0____").unwrap();
        assert!(WeightStore::load(&path).is_err());
    }
}
