//! Word-level tokenizer over the TinyPajama vocabulary
//! (`artifacts/data/vocab.json`).  Whitespace-split words map to ids;
//! unknown words to `<unk>`.  Mirrors `python/compile/data.py`.

use std::collections::HashMap;
use std::path::Path;

use crate::util::json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Specials {
    pub pad: u32,
    pub bos: u32,
    pub eos: u32,
    pub unk: u32,
}

#[derive(Debug)]
pub struct Tokenizer {
    words: Vec<String>,
    ids: HashMap<String, u32>,
    pub specials: Specials,
}

impl Tokenizer {
    pub fn from_file(path: &Path) -> anyhow::Result<Self> {
        let v = json::parse_file(path)?;
        let words: Vec<String> = v
            .req("words")?
            .as_array()
            .ok_or_else(|| anyhow::anyhow!("words not an array"))?
            .iter()
            .map(|w| {
                w.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| anyhow::anyhow!("non-string word"))
            })
            .collect::<anyhow::Result<_>>()?;
        let sp = v.req("specials")?;
        let specials = Specials {
            pad: sp.usize_at("pad")? as u32,
            bos: sp.usize_at("bos")? as u32,
            eos: sp.usize_at("eos")? as u32,
            unk: sp.usize_at("unk")? as u32,
        };
        Ok(Self::new(words, specials))
    }

    pub fn new(words: Vec<String>, specials: Specials) -> Self {
        let ids = words
            .iter()
            .enumerate()
            .map(|(i, w)| (w.clone(), i as u32))
            .collect();
        Tokenizer { words, ids, specials }
    }

    pub fn vocab_size(&self) -> usize {
        self.words.len()
    }

    pub fn token(&self, id: u32) -> &str {
        self.words
            .get(id as usize)
            .map(|s| s.as_str())
            .unwrap_or("<bad>")
    }

    pub fn id(&self, word: &str) -> u32 {
        self.ids.get(word).copied().unwrap_or(self.specials.unk)
    }

    /// Whitespace-split encode (no BOS/EOS added).
    pub fn encode(&self, text: &str) -> Vec<u32> {
        text.split_whitespace().map(|w| self.id(w)).collect()
    }

    /// Encode with a leading BOS.
    pub fn encode_prompt(&self, text: &str) -> Vec<u32> {
        let mut out = vec![self.specials.bos];
        out.extend(self.encode(text));
        out
    }

    pub fn decode(&self, ids: &[u32]) -> String {
        ids.iter()
            .map(|&i| self.token(i))
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// Decode, skipping special tokens.
    pub fn decode_clean(&self, ids: &[u32]) -> String {
        let sp = self.specials;
        ids.iter()
            .filter(|&&i| i != sp.pad && i != sp.bos && i != sp.eos)
            .map(|&i| self.token(i))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Tokenizer {
        let words = ["<pad>", "<bos>", "<eos>", "<unk>", "the", "cat",
                     "sings"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        Tokenizer::new(words, Specials { pad: 0, bos: 1, eos: 2, unk: 3 })
    }

    #[test]
    fn encode_decode_roundtrip() {
        let t = toy();
        let ids = t.encode("the cat sings");
        assert_eq!(ids, vec![4, 5, 6]);
        assert_eq!(t.decode(&ids), "the cat sings");
    }

    #[test]
    fn unknown_maps_to_unk() {
        let t = toy();
        assert_eq!(t.encode("the dog"), vec![4, 3]);
    }

    #[test]
    fn prompt_gets_bos_and_clean_strips() {
        let t = toy();
        let ids = t.encode_prompt("cat");
        assert_eq!(ids, vec![1, 5]);
        assert_eq!(t.decode_clean(&[1, 5, 2, 0]), "cat");
    }

    #[test]
    fn whitespace_robust() {
        let t = toy();
        assert_eq!(t.encode("  the \n cat  "), vec![4, 5]);
        assert_eq!(t.encode(""), Vec::<u32>::new());
    }
}
