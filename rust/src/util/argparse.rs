//! Tiny declarative CLI argument parser (clap is unreachable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! defaults, and auto-generated `--help` text.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
struct Spec {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

/// Declarative argument set for one (sub)command.
#[derive(Debug, Default)]
pub struct Args {
    command: String,
    about: String,
    specs: Vec<Spec>,
    positional: Vec<(String, String)>, // (name, help)
    values: BTreeMap<String, String>,
    pos_values: Vec<String>,
}

impl Args {
    pub fn new(command: &str, about: &str) -> Self {
        Args {
            command: command.to_string(),
            about: about.to_string(),
            ..Default::default()
        }
    }

    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.specs.push(Spec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    pub fn req(mut self, name: &str, help: &str) -> Self {
        self.specs.push(Spec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: false,
        });
        self
    }

    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.specs.push(Spec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: true,
        });
        self
    }

    pub fn pos(mut self, name: &str, help: &str) -> Self {
        self.positional.push((name.to_string(), help.to_string()));
        self
    }

    pub fn usage(&self) -> String {
        let mut out = format!("{} — {}\n\nOPTIONS:\n", self.command, self.about);
        for (name, help) in &self.positional {
            out.push_str(&format!("  <{name}>  {help}\n"));
        }
        for s in &self.specs {
            let d = match (&s.default, s.is_flag) {
                (_, true) => String::new(),
                (Some(d), _) if !d.is_empty() => format!(" [default: {d}]"),
                _ => " (required)".to_string(),
            };
            out.push_str(&format!("  --{:<18} {}{}\n", s.name, s.help, d));
        }
        out
    }

    /// Parse a token list (without argv[0]/subcommand).
    pub fn parse(mut self, argv: &[String]) -> anyhow::Result<Self> {
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if tok == "--help" || tok == "-h" {
                anyhow::bail!("{}", self.usage());
            }
            if let Some(stripped) = tok.strip_prefix("--") {
                let (key, inline) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| {
                        anyhow::anyhow!("unknown option --{key}\n{}", self.usage())
                    })?
                    .clone();
                let value = if spec.is_flag {
                    anyhow::ensure!(inline.is_none(), "--{key} takes no value");
                    "true".to_string()
                } else if let Some(v) = inline {
                    v
                } else {
                    i += 1;
                    argv.get(i)
                        .ok_or_else(|| anyhow::anyhow!("--{key} needs a value"))?
                        .clone()
                };
                self.values.insert(key, value);
            } else {
                anyhow::ensure!(
                    self.pos_values.len() < self.positional.len(),
                    "unexpected positional argument '{tok}'\n{}",
                    self.usage()
                );
                self.pos_values.push(tok.clone());
            }
            i += 1;
        }
        // Required options present?
        for s in &self.specs {
            if s.default.is_none() && !s.is_flag && !self.values.contains_key(&s.name)
            {
                anyhow::bail!("missing required --{}\n{}", s.name, self.usage());
            }
        }
        Ok(self)
    }

    pub fn get(&self, name: &str) -> String {
        if let Some(v) = self.values.get(name) {
            return v.clone();
        }
        self.specs
            .iter()
            .find(|s| s.name == name)
            .and_then(|s| s.default.clone())
            .unwrap_or_default()
    }

    pub fn get_usize(&self, name: &str) -> anyhow::Result<usize> {
        self.get(name)
            .parse()
            .map_err(|_| anyhow::anyhow!("--{name} must be an integer"))
    }

    pub fn get_f64(&self, name: &str) -> anyhow::Result<f64> {
        self.get(name)
            .parse()
            .map_err(|_| anyhow::anyhow!("--{name} must be a number"))
    }

    pub fn get_flag(&self, name: &str) -> bool {
        self.values.get(name).map(|v| v == "true").unwrap_or(false)
    }

    pub fn get_pos(&self, idx: usize) -> Option<&str> {
        self.pos_values.get(idx).map(|s| s.as_str())
    }

    /// Comma-separated list option.
    pub fn get_list(&self, name: &str) -> Vec<String> {
        let raw = self.get(name);
        if raw.is_empty() {
            return vec![];
        }
        raw.split(',').map(|s| s.trim().to_string()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(toks: &[&str]) -> Vec<String> {
        toks.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = Args::new("x", "")
            .opt("model", "opt-mini", "")
            .opt("batch", "4", "")
            .parse(&argv(&["--batch", "8"]))
            .unwrap();
        assert_eq!(a.get("model"), "opt-mini");
        assert_eq!(a.get_usize("batch").unwrap(), 8);
    }

    #[test]
    fn equals_syntax_and_flags() {
        let a = Args::new("x", "")
            .opt("k", "1", "")
            .flag("verbose", "")
            .parse(&argv(&["--k=32", "--verbose"]))
            .unwrap();
        assert_eq!(a.get_usize("k").unwrap(), 32);
        assert!(a.get_flag("verbose"));
    }

    #[test]
    fn required_and_unknown() {
        let spec = || Args::new("x", "").req("path", "");
        assert!(spec().parse(&argv(&[])).is_err());
        assert!(spec().parse(&argv(&["--nope", "1"])).is_err());
        let ok = spec().parse(&argv(&["--path", "/tmp"])).unwrap();
        assert_eq!(ok.get("path"), "/tmp");
    }

    #[test]
    fn positionals_and_lists() {
        let a = Args::new("x", "")
            .pos("input", "")
            .opt("models", "a,b", "")
            .parse(&argv(&["file.txt", "--models", "m1, m2,m3"]))
            .unwrap();
        assert_eq!(a.get_pos(0), Some("file.txt"));
        assert_eq!(a.get_list("models"), vec!["m1", "m2", "m3"]);
    }
}
