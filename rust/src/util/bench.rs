//! Micro-benchmark harness (criterion is unreachable offline).
//!
//! `Bench::run` measures a closure with warmup, adaptive iteration counts,
//! and reports mean / p50 / p99 wall-clock.  Used by the `cargo bench`
//! targets that regenerate the paper's tables and the serving-perf runs.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
}

impl Stats {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    pub fn report(&self) -> String {
        format!(
            "{:<42} {:>10.3} ms/iter  (p50 {:.3}, p99 {:.3}, min {:.3}; n={})",
            self.name,
            self.mean_ns / 1e6,
            self.p50_ns / 1e6,
            self.p99_ns / 1e6,
            self.min_ns / 1e6,
            self.iters
        )
    }
}

/// Percentile of a sorted slice (nearest-rank).
fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

pub struct Bench {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub target_secs: f64,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup_iters: 3,
            min_iters: 10,
            target_secs: 1.0,
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench {
            warmup_iters: 1,
            min_iters: 3,
            target_secs: 0.2,
        }
    }

    /// Measure `f`, which should perform one unit of work per call.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Stats {
        for _ in 0..self.warmup_iters {
            f();
        }
        // Estimate per-iter cost from one timed call.
        let t0 = Instant::now();
        f();
        let est = t0.elapsed().as_secs_f64().max(1e-9);
        let iters = ((self.target_secs / est) as usize)
            .clamp(self.min_iters, 100_000);
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_nanos() as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        Stats {
            name: name.to_string(),
            iters,
            mean_ns: mean,
            p50_ns: percentile(&samples, 50.0),
            p99_ns: percentile(&samples, 99.0),
            min_ns: samples[0],
        }
    }
}

/// Pretty table printer for bench outputs (paper-style rows).
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!(" {:<width$} ", c, width = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = format!("\n== {} ==\n", self.title);
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_orders_percentiles() {
        let b = Bench::quick();
        let mut x = 0u64;
        let s = b.run("noop", || {
            x = x.wrapping_add(1);
            std::hint::black_box(x);
        });
        assert!(s.iters >= 3);
        assert!(s.min_ns <= s.p50_ns && s.p50_ns <= s.p99_ns);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("T", &["a", "bb"]);
        t.row(vec!["xxx".into(), "1".into()]);
        let r = t.render();
        assert!(r.contains("== T =="));
        assert!(r.contains("xxx"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_rows() {
        let mut t = Table::new("T", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }
}
